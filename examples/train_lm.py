"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
the synthetic Markov stream, with checkpointing + restart.

This is the deliverable-(b) end-to-end example.  Default settings run on
CPU in tens of minutes; pass --steps 50 for a quick look.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import ModelConfig, RunConfig  # noqa: E402
from repro.data.pipeline import (DataConfig, Prefetcher,  # noqa: E402
                                 SyntheticDataset, loss_floor)
from repro.models.transformer import DecoderLM  # noqa: E402
from repro.train.checkpoint import Checkpointer  # noqa: E402
from repro.train.trainer import Trainer  # noqa: E402


def lm_100m() -> ModelConfig:
    """~106M params: 10L, d=640, ff=2560, vocab=32000, GQA 10/2."""
    return ModelConfig(
        arch_id="lm-100m", family="dense", n_layers=10, d_model=640,
        n_heads=10, n_kv_heads=2, d_ff=2560, vocab_size=32_000,
        param_dtype="float32", activation_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = lm_100m()
    run = RunConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    model = DecoderLM(cfg, run)
    trainer = Trainer(model, run)
    print(f"[train_lm] params: {model.param_count():,}")

    dcfg = DataConfig(kind="lcg", vocab_size=cfg.vocab_size,
                      seq_len=args.seq_len, global_batch=args.global_batch,
                      temperature=0.25)
    ds = SyntheticDataset(dcfg)
    print(f"[train_lm] entropy floor {loss_floor(dcfg):.3f} nats "
          f"(uniform baseline {jnp.log(cfg.vocab_size):.3f})")

    ck = Checkpointer(args.ckpt_dir, keep=2)
    state = trainer.init_state(jax.random.PRNGKey(0))
    start = 0
    if args.resume and ck.latest_step() is not None:
        state, start = ck.restore(state)
        print(f"[train_lm] resumed at step {start}")

    pf = Prefetcher(ds, start_step=start)
    try:
        state, hist = trainer.fit(state, pf, steps=args.steps - start,
                                  log_every=10,
                                  callback=lambda m: print(
                                      f"  step {m['step']:4d} "
                                      f"loss {m['loss']:.4f} "
                                      f"gnorm {m['grad_norm']:.2f} "
                                      f"({m['elapsed_s']:.0f}s)"))
    finally:
        pf.close()
    ck.save(args.steps, state)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"[train_lm] loss {first:.3f} -> {last:.3f} "
          f"(floor {loss_floor(dcfg):.3f}); checkpoint in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
