"""Topology planner: given a target NIC count and NIC bandwidth, enumerate
feasible MPHX(n, p, D_1..D_D) configurations plus Fat-Tree/Dragonfly
baselines, and rank them by cost/NIC and diameter — the paper's §3/§4
design procedure as a tool.

Run:  PYTHONPATH=src python examples/topology_planner.py --nics 65536
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (DEFAULT_SWITCH, Dragonfly, MPHX,  # noqa: E402
                        MultiPlaneFatTree, ThreeTierFatTree, cost_report)
from repro.core.netsim import zero_load_latency  # noqa: E402


def enumerate_mphx(nics: int, nic_bw: float, tolerance: float = 0.12):
    """All MPHX(n, p, dims) within +-tolerance of the NIC target."""
    out = []
    for n in (1, 2, 4, 8):
        radix = DEFAULT_SWITCH.radix_at(nic_bw / n)
        for D in (1, 2, 3):
            # balanced-ish: p = D_i = s
            import itertools
            lo = max(2, int((nics / radix) ** (1 / (D + 0.999)) * 0.5))
            hi = int(nics ** (1 / (D + 1)) * 2) + 2
            for s in range(lo, hi):
                for p in range(max(s - 8, 1), s + 9):
                    if p + D * (s - 1) > radix:
                        continue
                    N = p * s**D
                    if abs(N - nics) / nics > tolerance:
                        continue
                    try:
                        t = MPHX(n=n, p=p, dims=(s,) * D,
                                 nic_bw_gbps=nic_bw)
                        t.validate()
                        out.append(t)
                    except (ValueError, KeyError):
                        continue
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nics", type=int, default=65_536)
    ap.add_argument("--nic-bw-gbps", type=float, default=1600.0)
    ap.add_argument("--top", type=int, default=10)
    args = ap.parse_args()

    cands = enumerate_mphx(args.nics, args.nic_bw_gbps)
    baselines = []
    try:
        baselines.append(ThreeTierFatTree(nics=args.nics,
                                          nic_bw_gbps=args.nic_bw_gbps))
    except ValueError:
        pass
    try:
        baselines.append(MultiPlaneFatTree(n=8, nics=args.nics,
                                           nic_bw_gbps=args.nic_bw_gbps))
    except ValueError:
        pass

    rows = []
    for t in cands + baselines:
        try:
            rep = cost_report(t)
        except KeyError:
            continue
        rows.append((rep.per_nic_usd, t.diameter, t, rep))
    rows.sort(key=lambda r: (r[0], r[1]))

    print(f"Target: {args.nics:,} NICs @ {args.nic_bw_gbps:.0f} Gbps — "
          f"{len(cands)} MPHX candidates, best {args.top}:")
    print(f"{'topology':32s} {'N':>8s} {'d':>2s} {'$/NIC':>8s} "
          f"{'bisec Tbps':>10s} {'0-load us':>9s}")
    for cost, dia, t, rep in rows[:args.top]:
        print(f"{t.name:32s} {t.n_nics:8,d} {dia:2d} {cost:8,.0f} "
              f"{t.bisection_bw_tbps():10.0f} "
              f"{zero_load_latency(t) * 1e6:9.2f}")


if __name__ == "__main__":
    main()
