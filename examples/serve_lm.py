"""Batched serving example: prefill + lockstep decode over request waves,
with KV ring caches and greedy/temperature sampling.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.models.registry import get_config, get_model  # noqa: E402
from repro.serve.engine import Request, ServeEngine  # noqa: E402


def main():
    # mixtral smoke config: MoE + sliding-window attention serving
    cfg = get_config("mixtral-8x22b", smoke=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"[serve_lm] mixtral-8x22b (smoke): {model.param_count():,} params,"
          f" window={cfg.sliding_window}")

    rng = np.random.default_rng(0)
    engine = ServeEngine(model, params, max_batch=4, max_len=96,
                         temperature=0.8, seed=0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=24)
                    .astype(np.int32), max_new_tokens=16)
            for _ in range(10)]
    engine.run(reqs)
    for i, r in enumerate(reqs[:3]):
        print(f"  req{i}: prompt[:6]={r.prompt[:6].tolist()} -> "
              f"out={r.output}")
    s = engine.stats
    print(f"[serve_lm] {s.tokens_out} tokens | prefill {s.prefill_s:.2f}s | "
          f"decode {s.decode_s:.2f}s | {s.decode_tok_per_s:.1f} tok/s | "
          f"{s.waves} waves")


if __name__ == "__main__":
    main()
