"""Multi-plane collectives demo: runs the plane-decomposed / hierarchical /
compressed all-reduces on 8 forced host devices and compares against the
single-psum oracle; then models the same collectives on the paper's
topologies with the flow-level simulator.

Run:  PYTHONPATH=src python examples/multiplane_demo.py
(re-execs itself with XLA_FLAGS to get 8 host devices)
"""

import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

if os.environ.get("_MPHX_DEMO_CHILD") != "1":
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["_MPHX_DEMO_CHILD"] = "1"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    raise SystemExit(subprocess.call([sys.executable, __file__], env=env))

sys.path.insert(0, SRC)

import jax  # noqa: E402
from repro.compat import shard_map
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import MPHX, table2_topologies  # noqa: E402
from repro.core.collectives import (decomposed_psum,  # noqa: E402
                                    hierarchical_psum, int8_psum,
                                    multiplane_psum)
from repro.core.netsim import allreduce_time  # noqa: E402


def device_demo():
    print(f"devices: {jax.device_count()}")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    x = jnp.linspace(-1, 1, 8 * 1024 * 4).reshape(8, 1024, 4)

    def run(fn, in_spec=P("data", None, None)):
        return jax.jit(shard_map(fn, mesh=mesh, in_specs=in_spec,
                                     out_specs=in_spec, check_vma=False))(x)

    oracle = run(lambda v: jax.lax.psum(v, "model"))
    for name, fn in [
        ("multiplane_psum (4 plane-chunks)",
         lambda v: multiplane_psum(v, "model", 4, split_axis=1)),
        ("decomposed_psum (RS+AG)",
         lambda v: decomposed_psum(v, "model", split_axis=1)),
        ("int8_psum (compressed)", lambda v: int8_psum(v, "model")),
    ]:
        out = run(fn)
        err = float(jnp.abs(out - oracle).max())
        print(f"  {name:36s} max|err| = {err:.2e}")
    h = jax.jit(shard_map(
        lambda v: hierarchical_psum(v, ("data", "model"), split_axis=1),
        mesh=mesh, in_specs=P(None, None, None), out_specs=P(None, None, None),
        check_vma=False))(x)
    o2 = jax.jit(shard_map(
        lambda v: jax.lax.psum(v, ("data", "model")), mesh=mesh,
        in_specs=P(None, None, None), out_specs=P(None, None, None),
        check_vma=False))(x)
    print(f"  {'hierarchical_psum (dim walk)':36s} max|err| = "
          f"{float(jnp.abs(h - o2).max()):.2e}")


def fabric_model():
    print("\nModeled 256 MiB all-reduce on the paper's fabrics:")
    for t in table2_topologies():
        est = allreduce_time(t, 256 * 2**20)
        print(f"  {t.name:28s} {est.total_s * 1e3:9.3f} ms  ({est.algo})")


if __name__ == "__main__":
    device_demo()
    fabric_model()
