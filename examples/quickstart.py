"""Quickstart: the paper in 60 seconds.

1. Reproduce Table 2 (cost of 8 topologies at 65K NICs).
2. Show the §5.2 routing result (minimal vs adaptive on MPHX).
3. Train a tiny LM end-to-end on the synthetic pipeline (CPU, ~30 s).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.core import MPHX, table2  # noqa: E402
from repro.core.netsim import zero_load_latency  # noqa: E402
from repro.core.routing import minimal_vs_adaptive_report  # noqa: E402


def topology_tour():
    print("=" * 72)
    print("Paper Table 2 — cost of ~65K-NIC systems (reproduced exactly)")
    print("=" * 72)
    for rep in table2():
        row = rep.row()
        print(f"  {row['topology']:28s} {row['switch_config']:9s} "
              f"N_s={row['N_s']:5d}  N_o={row['N_o']:9,d}  "
              f"${row['cost_per_nic_usd']:6,d}/NIC")
    print("\n-> 8-plane 1D HyperX: cheapest AND lowest diameter (3 hops).")

    from repro.core import ThreeTierFatTree

    t = MPHX(n=8, p=256, dims=(256,))
    ft = ThreeTierFatTree()
    print(f"   zero-load latency: {zero_load_latency(t) * 1e6:.2f} us "
          f"(vs 3-tier Fat-Tree {zero_load_latency(ft) * 1e6:.2f} us)")

    print("\n§5.2 — why MPHX needs adaptive routing (adjacent-switch traffic):")
    rep = minimal_vs_adaptive_report(MPHX(n=2, p=8, dims=(8, 8)), 1600.0)
    for mode in ("minimal", "valiant", "adaptive"):
        print(f"  {mode:9s} throughput fraction: "
              f"{rep[mode]['throughput_fraction']:.3f}")


def tiny_training_run():
    print("\n" + "=" * 72)
    print("End-to-end training (tiny LM, synthetic Markov data, CPU)")
    print("=" * 72)
    from repro.launch.train import main as train_main

    train_main(["--arch", "yi-9b", "--smoke", "--steps", "60",
                "--seq-len", "64", "--global-batch", "8",
                "--log-every", "15"])


if __name__ == "__main__":
    topology_tour()
    tiny_training_run()
    print("\nNext: examples/train_lm.py (100M model), "
          "examples/topology_planner.py, examples/multiplane_demo.py")
