"""Elastic-restart demo: train on an 8-device mesh, checkpoint, "lose" half
the machines, replan the mesh (model axis preserved), restore the sharded
checkpoint onto the smaller mesh, and continue training — loss continues
from where it left off.

Run:  PYTHONPATH=src python examples/elastic_restart.py
(re-execs itself with XLA_FLAGS to get 8 host devices)
"""

import os
import subprocess
import sys
import tempfile

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

if os.environ.get("_MPHX_ELASTIC_CHILD") != "1":
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["_MPHX_ELASTIC_CHILD"] = "1"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    raise SystemExit(subprocess.call([sys.executable, __file__], env=env))

sys.path.insert(0, SRC)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import ModelConfig, RunConfig  # noqa: E402
from repro.data.pipeline import DataConfig, SyntheticDataset  # noqa: E402
from repro.models.sharding import MeshPlan  # noqa: E402
from repro.models.transformer import DecoderLM  # noqa: E402
from repro.train.checkpoint import Checkpointer  # noqa: E402
from repro.train.fault import plan_remesh  # noqa: E402
from repro.train.trainer import Trainer  # noqa: E402


def make(mesh, run):
    model = DecoderLM(CFG, run, mesh=mesh, plan=MeshPlan())
    return Trainer(model, run, mesh=mesh, plan=MeshPlan())


CFG = ModelConfig(arch_id="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  param_dtype="float32", activation_dtype="float32")


def main():
    print(f"devices: {jax.device_count()}")
    run = RunConfig(lr=3e-3, warmup_steps=5, total_steps=40)
    ds = SyntheticDataset(DataConfig(vocab_size=256, seq_len=32,
                                     global_batch=8, temperature=0.25))

    # phase 1: healthy cluster, 4x2 mesh
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    trainer = make(mesh, run)
    state = jax.device_put(trainer.init_state(jax.random.PRNGKey(0)),
                           trainer.state_shardings())
    step = trainer.make_train_step()
    for i in range(10):
        state, m = step(state, jax.tree.map(jnp.asarray, ds.batch(i)))
    print(f"[phase1 4x2] step 10 loss {float(m['loss']):.4f}")

    ckdir = tempfile.mkdtemp(prefix="elastic_")
    Checkpointer(ckdir).save(10, state)

    # disaster: 4 of 8 hosts die -> replan (model axis preserved)
    plan = plan_remesh((4, 2), ("data", "model"), available=4)
    print(f"[fault] 8 -> 4 hosts; remesh {plan.old_shape} -> "
          f"{plan.new_shape} (usable {plan.hosts_used})")

    # phase 2: restore the SAME checkpoint onto the smaller mesh
    mesh2 = jax.make_mesh(plan.new_shape, plan.axis_names)
    trainer2 = make(mesh2, run)
    template = jax.eval_shape(
        lambda: trainer2.init_state(jax.random.PRNGKey(0)))
    restored, at_step = Checkpointer(ckdir).restore(
        template, shardings=trainer2.state_shardings())
    step2 = trainer2.make_train_step()
    for i in range(at_step, at_step + 10):
        restored, m2 = step2(restored,
                             jax.tree.map(jnp.asarray, ds.batch(i)))
    print(f"[phase2 {plan.new_shape[0]}x{plan.new_shape[1]}] "
          f"resumed at {at_step}, step {at_step + 10} loss "
          f"{float(m2['loss']):.4f}")
    assert float(m2["loss"]) < float(m["loss"]) + 0.1, "loss regressed"
    print("elastic restart OK: training continued on the degraded mesh")


if __name__ == "__main__":
    main()
