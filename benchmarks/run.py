"""Benchmark harness — one function per paper table/figure + framework
benches.  Prints ``name,us_per_call,derived`` CSV rows (us_per_call is the
wall time of computing the bench itself where meaningful, or the modeled
quantity's latency in us where the bench IS a latency model).

  table2      — paper Table 2 cost reproduction        (§4)
  diameter    — diameter / latency comparison          (§1, §2)
  flattening  — Dragonfly -> 2D HyperX breakout        (§5.1, Frontier)
  routing     — minimal vs DAL adaptive throughput     (§5.2)
  traffic     — synthetic-traffic + collective sweep   (§6 future work)
  collectives — JAX multi-plane collective equivalence + wall time
  cosim       — training-step co-sim on the fabric     (§6 future work)
  serving     — multi-tenant serving SLOs per fabric   (§6 future work)
  reroute     — local vs global failure recovery gap   (resilience)
  spray       — NIC plane-spraying efficiency model    (§2)
  roofline    — per (arch x shape) roofline terms from the dry-run
"""

from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core import (MPHX, PAPER_TABLE2, SprayConfig, table2,  # noqa: E402
                        table2_topologies)
from repro.core.dragonfly import frontier_flattening_example  # noqa: E402
from repro.core.netsim import (allreduce_time, alltoall_time,  # noqa: E402
                               compare_topologies, zero_load_latency)
from repro.core.planes import spray_efficiency  # noqa: E402
from repro.core.routing import minimal_vs_adaptive_report  # noqa: E402

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us: float, derived: str):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.3f},{derived}")


def timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


# ------------------------------------------------------------- Table 2 ----


def bench_table2():
    reports, us = timed(table2)
    for rep, paper in zip(reports, PAPER_TABLE2):
        ok = "match" if abs(rep.per_nic_usd - paper[4]) < 1.0 else "MISMATCH"
        emit(f"table2/{rep.name.replace(' ', '_')}", us / len(reports),
             f"cost_per_nic=${rep.per_nic_usd:.0f};paper=${paper[4]};{ok}")
    mpft = next(r for r in reports if "2-layer" in r.name)
    mphx = next(r for r in reports if "8-Plane 1D" in r.name)
    emit("table2/mphx_vs_mpft_reduction", us,
         f"reduction={1 - mphx.per_nic_usd / mpft.per_nic_usd:.3f};paper=0.280")


# ------------------------------------------------------------ diameter ----


def bench_diameter():
    topos, us = timed(table2_topologies)
    for t in topos:
        lat = zero_load_latency(t, msg_bytes=4096)
        emit(f"diameter/{t.name.replace(' ', '_')}", lat * 1e6,
             f"diameter={t.diameter};avg_hops={t.avg_hops():.2f};"
             f"zero_load_us={lat * 1e6:.3f}")


# ---------------------------------------------------------- flattening ----


def bench_flattening():
    ex, us = timed(frontier_flattening_example)
    emit("flattening/frontier_x2_breakout", us,
         f"groups:{ex['before']['groups']}->{ex['after']['groups']};"
         f"nics_per_group:{ex['before']['nics_per_group']}->"
         f"{ex['after']['nics_per_group']};"
         f"becomes={ex['after']['flattened_to']}")


# ------------------------------------------------------------- routing ----


def bench_routing():
    t = MPHX(n=2, p=8, dims=(8, 8))
    rep, us = timed(lambda: minimal_vs_adaptive_report(t, 1600.0))
    for mode in ("minimal", "valiant", "adaptive"):
        emit(f"routing/{mode}", us / 3,
             f"throughput={rep[mode]['throughput_fraction']:.3f};"
             f"max_util={rep[mode]['max_util']:.2f}")
    emit("routing/adaptive_gain", us,
         f"gain={rep['adaptive']['throughput_fraction'] / max(rep['minimal']['throughput_fraction'], 1e-9):.1f}x")


# ------------------------------------------------------------- traffic ----


def bench_traffic():
    topos = table2_topologies()
    rows, us = timed(lambda: compare_topologies(topos, collective_mb=256))
    for r in rows:
        emit(f"traffic/{r['topology'].replace(' ', '_')}",
             r["zero_load_us"],
             f"uniform_thpt={r['uniform_thpt']};"
             f"allreduce_256MB_ms={r['allreduce_256MB_ms']};"
             f"algo={r['allreduce_algo']}")
    for mb in (1, 64, 1024):
        t = MPHX(n=8, p=256, dims=(256,))
        est = allreduce_time(t, mb * 2**20)
        emit(f"traffic/mphx8_allreduce_{mb}MB", est.total_s * 1e6,
             f"algo={est.algo};lat_us={est.latency_s*1e6:.1f};"
             f"bw_us={est.bandwidth_s*1e6:.1f}")


# --------------------------------------------------------- collectives ----


def bench_collectives():
    """Wall-time the JAX multi-plane collectives on an 8-device host mesh
    (subprocess, to keep this process at 1 device)."""
    import subprocess

    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time, jax, jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P
from repro.core.collectives import multiplane_psum, decomposed_psum, psum_auto
mesh = jax.make_mesh((8,), ("model",))
x = jnp.ones((8, 1 << 16), jnp.float32)
for name, fn in [
    ("psum", lambda v: jax.lax.psum(v, "model")),
    ("multiplane_psum", lambda v: multiplane_psum(v, "model", 8, 1)),
    ("decomposed_psum", lambda v: decomposed_psum(v, "model", 1)),
]:
    f = jax.jit(shard_map(fn, mesh=mesh, in_specs=P("model", None),
                              out_specs=P("model", None), check_vma=False))
    f(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        r = f(x)
    r.block_until_ready()
    print(f"BENCH {name} {(time.perf_counter()-t0)/20*1e6:.1f}")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    for line in proc.stdout.splitlines():
        if line.startswith("BENCH"):
            _, name, us = line.split()
            emit(f"collectives/{name}", float(us),
                 "8_host_devices;2MB_payload")
    if proc.returncode != 0:
        emit("collectives/error", 0.0, proc.stderr[-120:].replace(",", ";"))


# ---------------------------------------------------------------- spray ----


def bench_spray():
    for n in (1, 2, 4, 8):
        cfg = SprayConfig(n_planes=n)
        eff, us = timed(lambda c=cfg: spray_efficiency(1 << 26, 1600.0, c))
        emit(f"spray/n{n}_64MB", us, f"efficiency={eff:.4f}")


# ------------------------------------------------------------- roofline ----


def bench_roofline():
    d = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
    if not os.path.isdir(d):
        emit("roofline/missing", 0.0, "run repro.launch.dryrun first")
        return
    from repro.launch.roofline import roofline_table

    rows = roofline_table(d)
    for r in rows:
        emit(f"roofline/{r['cell']}", r["dominant_s"] * 1e6,
             f"compute_s={r['compute_s']:.4f};memory_s={r['memory_s']:.4f};"
             f"coll_s={r['collective_s']:.4f};bound={r['bound']};"
             f"useful_ratio={r['useful_ratio']:.2f}")


# ------------------------------------------------------ fabric projection ----


def bench_fabric_projection():
    """Project the dry-run's measured per-step collective profile (wire
    bytes + op counts) onto the paper's Table-2 fabrics — the §6 evaluation
    the paper deferred: how much faster does the SAME training step's
    communication phase complete on MPHX vs Fat-Tree vs Dragonfly.

    Model: t = wire_bytes / (per-NIC eff. bandwidth x uniform throughput)
             + ops x alpha(topology diameter)."""
    d = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
    if not os.path.isdir(d):
        emit("fabric/missing", 0.0, "run repro.launch.dryrun first")
        return
    from repro.core.netsim import DEFAULT_NET, _alpha, gbps_to_Bps, \
        uniform_throughput_fraction

    cells = ["kimi-k2-1t-a32b__train_4k__2_16_16",
             "mixtral-8x22b__train_4k__16_16",
             "yi-9b__train_4k__16_16"]
    topos = table2_topologies()
    for cell in cells:
        path = os.path.join(d, cell + ".json")
        if not os.path.exists(path):
            continue
        rec = json.load(open(path))
        wire = rec["collectives"]["total_wire_bytes"]
        ops = rec["collectives"]["total_count"]
        from repro.core import cost_report

        times, costs = {}, {}
        for t in topos:
            eff = gbps_to_Bps(t.nic_bw_gbps) * uniform_throughput_fraction(t)
            alpha = _alpha(t, float(t.diameter), DEFAULT_NET)
            times[t.name] = wire / eff + ops * alpha
            costs[t.name] = cost_report(t).per_nic_usd
        ft = times["3-layer Fat-Tree"]
        ftc = costs["3-layer Fat-Tree"]
        # headline finding: full-bisection fabrics serve a bandwidth-
        # dominated step near-equally; MPHX wins on alpha (diameter) and,
        # decisively, on COST — report comm-perf-per-dollar vs FT3.
        for name, tt in times.items():
            ppd = (ft / tt) * (ftc / costs[name])
            emit(f"fabric/{cell.split('__')[0]}/{name.replace(' ', '_')}",
                 tt * 1e6,
                 f"comm_s={tt:.2f};vs_FT3={ft / tt:.3f}x;"
                 f"perf_per_dollar_vs_FT3={ppd:.2f}x")


# --------------------------------------------------- vectorized routing ----


def bench_vectorized():
    """Vectorized array routing vs the legacy dict router: equivalence on a
    small MPHX, speedup at Table-2 scale (66,564 NICs).  Writes
    results/BENCH_vectorized_routing.json."""
    from repro.core.routing import HyperXRouter, uniform_traffic
    from repro.core.routing_vec import (VectorizedHyperXRouter,
                                        demands_from_dict, get_backend,
                                        uniform_demands)

    record = {"schema_version": 1, "bench": "vectorized_routing",
              "backend": get_backend("auto")[0]}

    # equivalence on a small topology (no legacy path subsampling)
    small = MPHX(n=2, p=8, dims=(8, 8))
    legacy = HyperXRouter(small)
    vec = VectorizedHyperXRouter(small)
    demands = uniform_traffic(small, 1600.0)
    eq = {}
    for mode in ("minimal", "valiant"):
        ld = dict(legacy.route(demands, mode=mode).loads)
        vd = vec.route(demands_from_dict(demands), mode=mode).to_dict()
        keys = {k for k, v in ld.items() if v > 0} | set(vd)
        eq[mode] = max(abs(ld.get(k, 0.0) - vd.get(k, 0.0)) for k in keys)
        emit(f"vectorized/equivalence_{mode}", 0.0,
             f"max_abs_diff_gbps={eq[mode]:.3e};n_edges={len(keys)}")
    record["equivalence"] = {
        "topology": small.name, "traffic": "uniform",
        "max_abs_diff_gbps": eq,
    }

    # speedup at Table-2 scale: 4-Plane 2D HyperX row, 66,564 NICs
    big = MPHX(n=4, p=86, dims=(86, 9), links_per_dim=(85, 85),
               name="4-Plane 2D HyperX")
    dem_arrays, t_build = timed(lambda: uniform_demands(big, 1600.0))
    router = VectorizedHyperXRouter(big)
    ll_vec, t_vec = timed(lambda: router.route(dem_arrays, "minimal"))
    dem_dict, t_dict_build = timed(lambda: uniform_traffic(big, 1600.0))
    ll_leg, t_leg = timed(
        lambda: HyperXRouter(big).route(dem_dict, mode="minimal"))
    speedup = t_leg / t_vec
    match = abs(ll_vec.max_utilization() - ll_leg.max_utilization()) < 1e-9
    emit("vectorized/route_66564nic_uniform_vec", t_vec,
         f"speedup={speedup:.1f}x;max_util={ll_vec.max_utilization():.4f};"
         f"pairs={dem_arrays.n}")
    emit("vectorized/route_66564nic_uniform_legacy", t_leg,
         f"max_util={ll_leg.max_utilization():.4f};"
         f"match={'yes' if match else 'NO'}")
    record["scale"] = {
        "topology": big.name, "n_nics": big.n_nics,
        "demand_pairs": dem_arrays.n, "traffic": "uniform",
        "mode": "minimal",
        "vectorized_s": t_vec / 1e6, "legacy_s": t_leg / 1e6,
        "demand_build_vec_s": t_build / 1e6,
        "demand_build_legacy_s": t_dict_build / 1e6,
        "speedup": speedup,
        "speedup_target": 10.0,
        "meets_target": speedup >= 10.0,
        "max_util_vectorized": ll_vec.max_utilization(),
        "max_util_legacy": ll_leg.max_utilization(),
        "max_util_match": match,
    }
    out = os.path.join(os.path.dirname(__file__), "..", "results")
    os.makedirs(out, exist_ok=True)
    path = os.path.join(out, "BENCH_vectorized_routing.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    emit("vectorized/bench_artifact", 0.0,
         f"wrote={os.path.relpath(path, os.path.join(out, '..'))};"
         f"meets_10x_target={'yes' if speedup >= 10 else 'NO'}")


# ------------------------------------------------------- graph routing ----


def bench_graph_routing():
    """Generic graph engine: cross-engine equivalence against the MPHX
    array engine (minimal ECMP, 1e-9), timings for both, and routed
    baseline topologies (the Table-2 comparison closed forms can't give).
    Writes results/BENCH_graph_routing.json."""
    from repro.core.dragonfly import Dragonfly, DragonflyPlus
    from repro.core.fattree import MultiPlaneFatTree, ThreeTierFatTree
    from repro.core.routing_graph import (GraphRouter, graph_shift_demands,
                                          graph_uniform_demands)
    from repro.core.routing_vec import (VectorizedHyperXRouter, get_backend,
                                        uniform_demands)

    record = {"schema_version": 1, "bench": "graph_routing",
              "backend": get_backend("auto")[0]}

    # cross-engine equivalence + timing on untrunked MPHX (equal per-dim
    # multiplicity -> multiplicity-proportional ECMP == ordering ECMP)
    eq = {}
    for topo in (MPHX(n=2, p=8, dims=(8, 8)),
                 MPHX(n=2, p=16, dims=(16, 16))):
        d = uniform_demands(topo, 1600.0)
        vec_router = VectorizedHyperXRouter(topo)
        g_router = GraphRouter(topo)
        ll_vec, t_vec = timed(lambda: vec_router.route(d, "minimal"))
        ll_g, t_g = timed(lambda: g_router.route(d, "minimal"))
        vd, gd = ll_vec.to_dict(), ll_g.to_dict()
        keys = set(vd) | set(gd)
        diff = max(abs(vd.get(k, 0.0) - gd.get(k, 0.0)) for k in keys)
        eq[topo.name] = {
            "traffic": "uniform", "mode": "minimal",
            "max_abs_diff_gbps": diff, "n_edges": len(keys),
            "array_engine_s": t_vec / 1e6, "graph_engine_s": t_g / 1e6,
            "graph_over_array": t_g / t_vec,
            "within_1e-9": bool(diff < 1e-9),
        }
        emit(f"graph/equivalence_{topo.name.replace(' ', '_')}", t_g,
             f"max_abs_diff_gbps={diff:.3e};"
             f"graph_over_array={t_g / t_vec:.1f}x;"
             f"match={'yes' if diff < 1e-9 else 'NO'}")
    record["equivalence_vs_array_engine"] = eq

    # routed baselines: adversarial shift, minimal vs UGAL adaptive —
    # the §6 cross-topology result closed forms cannot produce
    baselines = [
        ThreeTierFatTree(radix=8, nics=128, name="3-layer Fat-Tree (small)"),
        MultiPlaneFatTree(n=2, nics=32, base_radix=4,
                          name="2-Plane 2-layer Fat-Tree (small)"),
        Dragonfly(p=2, a=4, h=2, groups=9, name="Dragonfly (small)"),
        DragonflyPlus(p=2, leaves=4, spines=4, groups=8, global_per_spine=7,
                      name="Dragonfly+ (small)"),
    ]
    rows = {}
    for topo in baselines:
        router = GraphRouter(topo)
        shift = graph_shift_demands(topo, 1600.0)
        out = {}
        for mode in ("minimal", "valiant", "adaptive"):
            ll, us = timed(lambda m=mode: router.route(shift, m))
            out[mode] = {"max_util": ll.max_utilization(),
                         "route_s": us / 1e6}
        uni, us = timed(lambda: router.route(
            graph_uniform_demands(topo, 1600.0), "minimal"))
        out["uniform_minimal_max_util"] = uni.max_utilization()
        gain = (out["minimal"]["max_util"]
                / max(out["adaptive"]["max_util"], 1e-9))
        out["adaptive_gain_on_shift"] = gain
        rows[topo.name] = out
        emit(f"graph/{topo.name.replace(' ', '_')}",
             out["minimal"]["route_s"] * 1e6,
             f"shift_minimal={out['minimal']['max_util']:.2f};"
             f"shift_adaptive={out['adaptive']['max_util']:.2f};"
             f"gain={gain:.2f}x")
    record["routed_baselines"] = rows

    out_dir = os.path.join(os.path.dirname(__file__), "..", "results")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "BENCH_graph_routing.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    ok = all(v["within_1e-9"] for v in eq.values())
    emit("graph/bench_artifact", 0.0,
         f"wrote={os.path.relpath(path, os.path.join(out_dir, '..'))};"
         f"cross_engine_1e-9={'yes' if ok else 'NO'}")


# ------------------------------------------------------ flow simulator ----


def bench_flow_sim():
    """Flow-level simulator: steady-state cross-validation against both
    analytic engines (1e-6), single-flow FCT vs the closed form, measured
    FCT sweep timings, and a failure sweep.  Writes
    results/BENCH_flow_sim.json."""
    from repro.core.dragonfly import Dragonfly
    from repro.core.netsim import gbps_to_Bps, make_router
    from repro.core.routing_graph import graph_uniform_demands
    from repro.core.routing_vec import get_backend, uniform_demands
    from repro.sim import (FlowSpec, failure_throughput, flow_incidence,
                           parse_failure_spec, simulate_demands,
                           simulate_flows)
    from repro.sim.events import path_latency

    record = {"schema_version": 1, "bench": "flow_sim",
              "backend": get_backend("auto")[0]}

    mphx = MPHX(n=2, p=8, dims=(8, 8))
    df = Dragonfly(p=2, a=4, h=2, groups=9, name="Dragonfly (small)")

    # steady-state agreement: sim load accounting vs analytic engines
    agree = {}
    for topo, dem_builder in ((mphx, uniform_demands),
                              (df, graph_uniform_demands)):
        router = make_router(topo)
        dem = dem_builder(topo, 1600.0)
        ll, t_route = timed(lambda: router.route(dem, "minimal"))
        inc, t_inc = timed(lambda: flow_incidence(router, dem, "minimal"))
        diff = float(abs(inc.utilization(dem.gbps)
                         - ll.utilization_array()).max())
        agree[topo.name] = {
            "engine": "array" if isinstance(topo, MPHX) else "graph",
            "traffic": "uniform", "n_flows": dem.n,
            "max_abs_util_diff": diff, "within_1e-6": bool(diff < 1e-6),
            "route_s": t_route / 1e6, "incidence_s": t_inc / 1e6,
        }
        emit(f"sim/steady_{topo.name.replace(' ', '_')}", t_inc,
             f"max_abs_util_diff={diff:.3e};"
             f"match={'yes' if diff < 1e-6 else 'NO'}")
    record["steady_state_agreement"] = agree

    # single-flow FCT vs closed form bytes/bandwidth + latency
    router = make_router(mphx)
    res, t_sim = timed(
        lambda: simulate_flows(router, [FlowSpec(0, 5, 1 << 24)]))
    inc = res.incidence
    rate = min(mphx.port_gbps, float(inc.bottleneck_gbps()[0]))
    closed = (1 << 24) / gbps_to_Bps(rate) + float(path_latency(inc)[0])
    fct_err = abs(float(res.fct_s[0]) - closed) / closed
    record["single_flow_fct"] = {
        "topology": mphx.name, "bytes": 1 << 24,
        "fct_s": float(res.fct_s[0]), "closed_form_s": closed,
        "rel_err": fct_err, "matches_closed_form": bool(fct_err < 1e-9),
    }
    emit("sim/single_flow_fct", res.fct_s[0] * 1e6,
         f"closed_form_us={closed * 1e6:.3f};rel_err={fct_err:.2e}")

    # measured-FCT sweep wall time (uniform @ 0.9 load, both engines)
    sweeps = {}
    for topo, dem_builder in ((mphx, uniform_demands),
                              (df, graph_uniform_demands)):
        router = make_router(topo)
        dem = dem_builder(topo, 0.9 * topo.nic_bw_gbps)
        row, us = timed(lambda: simulate_demands(router, dem, 200e-6))
        sweeps[topo.name] = {"load": 0.9, "wall_s": us / 1e6, **row}
        emit(f"sim/fct_sweep_{topo.name.replace(' ', '_')}", us,
             f"flows={row['sim_flows']};epochs={row['sim_epochs']};"
             f"fct_p99_us={row['fct_p99_us']};"
             f"delivered={row['sim_delivered_fraction']}")
    record["fct_sweep"] = sweeps

    # failure sweep: one link-failure rate x two topologies
    spec = parse_failure_spec("link:0.05")
    fails = {}
    for topo in (mphx, df):
        build = lambda t, o, g: graph_uniform_demands(t, o, graph=g)
        ft, us = timed(lambda: failure_throughput(topo, build, spec,
                                                  800.0, mode="adaptive"))
        fails[topo.name] = {"spec": spec.label(), **ft,
                            "wall_s": us / 1e6}
        emit(f"sim/failures_{topo.name.replace(' ', '_')}", us,
             f"spec={spec.label()};retained={ft['throughput_retained']};"
             f"degraded_util={ft['degraded_max_util']}")
    record["failure_sweep"] = fails

    out = os.path.join(os.path.dirname(__file__), "..", "results")
    os.makedirs(out, exist_ok=True)
    path = os.path.join(out, "BENCH_flow_sim.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    ok = (all(v["within_1e-6"] for v in agree.values())
          and record["single_flow_fct"]["matches_closed_form"])
    emit("sim/bench_artifact", 0.0,
         f"wrote={os.path.relpath(path, os.path.join(out, '..'))};"
         f"cross_validates={'yes' if ok else 'NO'}")


# ------------------------------------------------------- 65K sim scale ----


def bench_sim_scale():
    """Water-filling solver + event loop at scale: the numpy reference
    path vs the in-jit ``lax.while_loop`` (jax) and Pallas segment-kernel
    paths, up the preset ladder to the 65K-NIC Table-2 fabrics.  Pins the
    >=10x jit speedup at the largest scale where every backend is timed
    (the 65,536-NIC ``mphx-8p-256``), plus three-way <=1e-6 agreement on
    steady-state link loads and FCT percentiles at every rung.  Writes
    results/BENCH_sim_scale.json."""
    from repro.core.netsim import make_router
    from repro.core.routing_vec import neighbor_shift_demands, uniform_demands
    from repro.experiments.sweep import SWEEP_TOPOLOGIES
    from repro.sim.events import simulate_demands, simulate_incidence
    from repro.sim.fairshare import flow_incidence, max_min_rates

    # scale ladder: small CI fabrics -> the two 65K-NIC Table-2 presets.
    # Workload: staggered-arrival neighbor-shift (seeded) — every flow
    # set re-solves ~2F epochs, which is exactly the regime the Python
    # round-trip per re-solve dominated before the rewrite.
    #
    # The bool marks rungs where the numpy reference wall is comparable:
    # at mphx-4p-86x9 (E=73,530, 1,547 epochs) the reference loop streams
    # ~0.6 MB temporaries per vector op and its wall swings 1.0-3.1 s
    # across otherwise identical runs of this host (memory-placement
    # lottery on shared hardware; the jit path's compressed arrays are
    # cache-resident and insensitive, ~0.2 s).  That ratio cannot be
    # pinned, so the reference runs once there for agreement/epoch
    # checks and is excluded from the speedup comparison.
    ladder = [("mphx-2p-8x8", True), ("mphx-2p-16x16", True),
              ("mphx-8p-256", True), ("mphx-4p-86x9", False)]
    backends = ("numpy", "jax", "pallas")
    record = {"schema_version": 1, "bench": "sim_scale",
              "workload": {"scenario": "neighbor_shift", "seed": 7,
                           "offered_fraction": 0.9,
                           "size_bytes_max": 1 << 24,
                           "start_window_s": 200e-6},
              "backends": list(backends), "scales": []}

    for preset, ref_timed in ladder:
        topo = SWEEP_TOPOLOGIES[preset]
        router = make_router(topo, backend="numpy")
        dem = neighbor_shift_demands(topo, 0.9 * topo.nic_bw_gbps)
        inc = flow_incidence(router, dem, "minimal")
        rng = np.random.default_rng(7)
        size = rng.uniform(0.2, 1.0, inc.n_flows) * (1 << 24)
        start = rng.uniform(0.0, 200e-6, inc.n_flows)
        caps = np.asarray(dem.gbps)

        res, wall, loads = {}, {}, {}
        for b in backends:
            n_reps = 3 if (b != "numpy" or ref_timed) else 1
            if n_reps > 1:
                simulate_incidence(inc, size, caps, start_s=start,
                                   backend=b)  # warm-up (jit: compile)
            reps = []
            for _ in range(n_reps):
                t0 = time.perf_counter()
                res[b] = simulate_incidence(inc, size, caps,
                                            start_s=start, backend=b)
                reps.append(time.perf_counter() - t0)
            wall[b] = float(np.median(reps))
            wall[b + "_reps"] = [round(t, 4) for t in reps]
            loads[b] = inc.loads(max_min_rates(inc, caps, backend=b))
        ref = res["numpy"]
        pct_ref = ref.fct_percentiles()
        load_scale = max(float(loads["numpy"].max()), 1.0)
        agreement = {}
        for b in ("jax", "pallas"):
            pct = res[b].fct_percentiles()
            agreement[b] = {
                "max_abs_finish_err_s":
                    float(np.abs(res[b].finish_s - ref.finish_s).max()),
                "max_rel_link_load_err":
                    float(np.abs(loads[b] - loads["numpy"]).max())
                    / load_scale,
                "max_rel_fct_pct_err": max(
                    abs(pct[k] - pct_ref[k]) / pct_ref[k]
                    for k in pct_ref),
            }
            agreement[b]["within_1e-6"] = bool(
                agreement[b]["max_rel_link_load_err"] < 1e-6
                and agreement[b]["max_rel_fct_pct_err"] < 1e-6)
        row = {
            "preset": preset, "topology": topo.name,
            "n_nics": int(topo.n_nics), "n_flows": inc.n_flows,
            "n_edges": inc.n_edges, "nnz": inc.nnz,
            "n_epochs": ref.n_epochs,
            "fct_p50_us": pct_ref["p50"] * 1e6,
            "fct_p99_us": pct_ref["p99"] * 1e6,
            "reference_timed": ref_timed,
            "wall_s": {b: wall[b] for b in backends},
            "wall_reps_s": {b: wall[b + "_reps"] for b in backends},
            "agreement": agreement,
        }
        if ref_timed:
            row["speedup_jax"] = wall["numpy"] / wall["jax"]
            row["speedup_pallas"] = wall["numpy"] / wall["pallas"]
            speed = f"speedup_jax={row['speedup_jax']:.1f}"
        else:
            row["reference_note"] = (
                "numpy wall is host-placement sensitive at this scale "
                "(1.0-3.1 s across runs); single untimed-comparison run, "
                "excluded from the speedup ladder")
            speed = "speedup_jax=n/a(ref untimed)"
        record["scales"].append(row)
        emit(f"sim_scale/{preset}", wall["jax"] * 1e6,
             f"nics={topo.n_nics};flows={inc.n_flows};"
             f"epochs={ref.n_epochs};{speed};"
             f"agree={'yes' if all(a['within_1e-6'] for a in agreement.values()) else 'NO'}")

    largest = [r for r in record["scales"] if r["reference_timed"]][-1]
    record["largest_common_scale"] = largest["preset"]
    record["speedup_at_largest_common_scale"] = largest["speedup_jax"]
    record["meets_10x"] = bool(largest["speedup_jax"] >= 10.0)
    record["all_within_1e-6"] = bool(all(
        a["within_1e-6"] for row in record["scales"]
        for a in row["agreement"].values()))

    # 65K-NIC simulated sweep rows through the jit path: every (src, dst)
    # switch pair of each Table-2 preset as one finite flow
    sweep = {}
    for preset in ("mphx-8p-256", "mphx-4p-86x9"):
        topo = SWEEP_TOPOLOGIES[preset]
        router = make_router(topo, backend="numpy")
        dem = uniform_demands(topo, 0.9 * topo.nic_bw_gbps)
        t0 = time.perf_counter()
        row = simulate_demands(router, dem, 200e-6, backend="jax")
        wall_s = time.perf_counter() - t0
        sweep[preset] = {"load": 0.9, "wall_s": wall_s,
                         "n_nics": int(topo.n_nics), **row}
        emit(f"sim_scale/sweep_{preset}", wall_s * 1e6,
             f"nics={topo.n_nics};flows={row['sim_flows']};"
             f"fct_p99_us={row['fct_p99_us']};"
             f"delivered={row['sim_delivered_fraction']}")
    record["sweep_65k"] = sweep

    out = os.path.join(os.path.dirname(__file__), "..", "results")
    os.makedirs(out, exist_ok=True)
    path = os.path.join(out, "BENCH_sim_scale.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    emit("sim_scale/bench_artifact", 0.0,
         f"wrote={os.path.relpath(path, os.path.join(out, '..'))};"
         f"speedup_at_largest={record['speedup_at_largest_common_scale']:.1f};"
         f"meets_10x={'yes' if record['meets_10x'] else 'NO'}")


# ------------------------------------------------- step co-simulation ----


def bench_cosim():
    """Training-step co-simulation: measured step time & tokens/sec for
    two MoE configs on a small MPHX (both routing engines) and two
    Table-2 baseline fabrics.  Writes results/BENCH_cosim.json."""
    from repro.core.dragonfly import Dragonfly
    from repro.core.fattree import ThreeTierFatTree
    from repro.core.netsim import make_router
    from repro.cosim import job_from_model, simulate_step
    from repro.models.registry import get_config

    record = {"schema_version": 1, "bench": "cosim", "shape": "train_4k",
              "n_ranks": 64, "device_tflops": 989.0, "cells": []}
    meshes = {"kimi-k2-1t-a32b": dict(dp=4, tp=16, ep=4),
              "mixtral-8x22b": dict(dp=8, tp=8, ep=8)}
    jobs = {arch: job_from_model(get_config(arch), **mesh)
            for arch, mesh in meshes.items()}
    topos = [
        (MPHX(n=2, p=8, dims=(8, 8)), ("array", "graph")),
        (ThreeTierFatTree(radix=8, nics=128,
                          name="3-layer Fat-Tree (small)"), ("graph",)),
        (Dragonfly(p=2, a=4, h=2, groups=9,
                   name="Dragonfly (small)"), ("graph",)),
    ]
    for topo, engines in topos:
        for engine in engines:
            router = make_router(topo, engine=engine)
            for arch, job in jobs.items():
                res, us = timed(lambda j=job, r=router, e=engine:
                                simulate_step(topo, j, engine=e, router=r))
                record["cells"].append(
                    {"mesh": meshes[arch], "engine": engine,
                     "sim_wall_s": us / 1e6, **res.row()})
                emit(f"cosim/{arch}/{topo.name.replace(' ', '_')}/{engine}",
                     res.step_s * 1e6,
                     f"tokens_per_s={res.tokens_per_s:.0f};"
                     f"comm_ms={res.comm_s * 1e3:.1f};"
                     f"x_analytic={res.comm_s / res.analytic_comm_s:.3f}")
    # cross-engine pin: both engines must measure the same MPHX step
    by = {}
    for c in record["cells"]:
        if "HyperX" in c["topology"] or "MPHX" in c["topology"]:
            by.setdefault(c["arch"], {})[c["engine"]] = c["step_ms"]
    agree = all(abs(v["array"] - v["graph"]) <= 1e-6 * v["array"]
                for v in by.values() if len(v) == 2)
    record["mphx_engines_agree_1e-6"] = agree
    out = os.path.join(os.path.dirname(__file__), "..", "results")
    os.makedirs(out, exist_ok=True)
    path = os.path.join(out, "BENCH_cosim.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    emit("cosim/bench_artifact", 0.0,
         f"wrote={os.path.relpath(path, os.path.join(out, '..'))};"
         f"engines_agree={'yes' if agree else 'NO'}")


# ------------------------------------------------------------- serving ----


def bench_serving():
    """Multi-tenant serving on MPHX vs two Table-2 baselines at matched
    cost: per-tenant SLO rows (FCT/TTFT percentiles, goodput,
    slowdown-vs-isolation), cost-normalized serving goodput, the
    uncontended closed-form KV-transfer pin at 1e-6, and a same-seed
    reproducibility check.  Writes results/BENCH_serving.json."""
    from repro.core.cost import cost_report
    from repro.core.netsim import gbps_to_Bps, make_router
    from repro.experiments.servesuite import (DEFAULT_SERVING_TOPOS,
                                              DEFAULT_TENANTS, tenant_specs)
    from repro.experiments.sweep import SWEEP_TOPOLOGIES
    from repro.sim.events import (flows_to_demands, path_latency,
                                  simulate_incidence)
    from repro.sim.fairshare import flow_incidence
    from repro.workload import (ServingTenantSpec, SizeDist,
                                build_serving_workload, run_tenant_mix,
                                slo_rows)
    from repro.cosim.placement import rank_to_switch

    seed = 0
    specs = tenant_specs(list(DEFAULT_TENANTS))
    record = {"schema_version": 1, "bench": "serving", "seed": seed,
              "tenants": list(DEFAULT_TENANTS), "cells": []}
    first_rows = {}
    for tn in DEFAULT_SERVING_TOPOS:
        topo = SWEEP_TOPOLOGIES[tn]
        mix, us = timed(lambda t=topo: run_tenant_mix(t, specs, seed=seed))
        rows = slo_rows(mix)
        first_rows[tn] = rows
        per_nic = cost_report(topo).per_nic_usd
        nics_used = sum(t.n_nics for t in mix.traffic)
        serving = [r for r in rows if r["kind"] == "serving"]
        goodput = sum(r["goodput_gbps"] or 0.0 for r in serving)
        worst_ttft = max(r["ttft_p99_us"] for r in serving)
        cell = {
            "topology": tn, "sim_wall_s": us / 1e6,
            "cost_per_nic_usd": round(per_nic, 2),
            "nics_used": nics_used,
            "serving_goodput_gbps": round(goodput, 3),
            "serving_ttft_p99_us": worst_ttft,
            "goodput_gbps_per_kusd": round(
                goodput / (per_nic * nics_used / 1e3), 4),
            "rows": rows,
        }
        record["cells"].append(cell)
        emit(f"serving/{tn}", worst_ttft,
             f"goodput_gbps={goodput:.0f};per_nic_usd={per_nic:.0f};"
             f"gbps_per_kusd={cell['goodput_gbps_per_kusd']:.2f}")
    # same-seed reproducibility: an identical second run must produce
    # identical SLO rows on every fabric
    mix2 = run_tenant_mix(SWEEP_TOPOLOGIES[DEFAULT_SERVING_TOPOS[0]],
                          specs, seed=seed)
    record["runs_agree"] = slo_rows(mix2) == \
        first_rows[DEFAULT_SERVING_TOPOS[0]]
    # closed-form pin: one uncontended KV-transfer flow's FCT must equal
    # share_bytes / min(cap, bottleneck) + path alpha exactly
    topo = SWEEP_TOPOLOGIES[DEFAULT_SERVING_TOPOS[0]]
    router = make_router(topo, engine="auto")
    switch_of = rank_to_switch(topo, getattr(router, "graph", None))
    # tp spans a full switch so the prefill -> decode shards cross the
    # fabric (a replica inside one switch is intra-switch by design)
    pin_spec = ServingTenantSpec(
        "pin", rate_hz=40.0, duration_s=0.05,
        prompt_tokens=SizeDist("fixed", mean=1000.0),
        prefill_replicas=1, decode_replicas=1, tp=topo.p)
    w = build_serving_workload(pin_spec, switch_of, 0, topo.port_gbps,
                               np.random.default_rng(seed))
    f = w.flows[0]
    share = f.size_bytes / topo.n_planes
    cap = float(w.caps_gbps[0])
    inc = flow_incidence(router, flows_to_demands([f]), "minimal")
    res = simulate_incidence(inc, share, cap, start_s=f.start_s)
    bneck = float(inc.bottleneck_gbps()[0])
    expected = (share / gbps_to_Bps(min(cap, bneck))
                + float(path_latency(inc)[0]))
    rel = abs(float(res.fct_s[0]) - expected) / expected
    record["closed_form"] = {
        "kv_bytes": f.size_bytes, "share_bytes": share,
        "cap_gbps": cap, "bottleneck_gbps": bneck,
        "expected_us": expected * 1e6,
        "measured_us": float(res.fct_s[0]) * 1e6,
        "rel_err": rel,
    }
    record["matches_closed_form"] = bool(rel < 1e-6)
    out = os.path.join(os.path.dirname(__file__), "..", "results")
    os.makedirs(out, exist_ok=True)
    path = os.path.join(out, "BENCH_serving.json")
    with open(path, "w") as fh:
        json.dump(record, fh, indent=2)
        fh.write("\n")
    emit("serving/closed_form", record["closed_form"]["measured_us"],
         f"rel_err={rel:.2e};"
         f"match={'yes' if record['matches_closed_form'] else 'NO'};"
         f"runs_agree={'yes' if record['runs_agree'] else 'NO'}")


def bench_reroute():
    """Fast-reroute under failure: precomputed-backup local reroute vs
    global reconvergence on MPHX and the Table-2 baseline fabrics.

    Pins, per fabric and reroute mode, (a) byte conservation
    (``injected == delivered + stalled``) and zero load on failed
    elements at 1e-9, and (b) the recovery gap — local backup-path
    reroute must reach 90% of healthy throughput strictly faster than
    the global recompute (best-of-``REPEATS`` walls; per-phase recovery
    walls stay on the artifact rows).  Also pins flowlet-spray
    stability: killing a plane re-hashes only the flowlets that were on
    it.  Writes results/BENCH_reroute.json."""
    from repro.experiments.scenarios import SCENARIOS
    from repro.experiments.sweep import SWEEP_TOPOLOGIES
    from repro.routing import ProtectedRouter
    from repro.sim.failures import (DegradedGraph, degrade_graph,
                                    parse_failure_spec, recovery_curve,
                                    time_to_recover)
    from repro.sim.spray import flowlet_split

    TOL = 1e-9
    REPEATS = 3
    N_LAYERS = 8
    SPEC = "link:0.05"
    # reconvergence re-routes with the production mode (the failures
    # suite default): UGAL-adaptive — its relaxation cost is part of
    # the global recovery wall the local table-lookup path avoids
    MODE = "adaptive"
    OFFERED_FRACTION = 0.5
    fabrics = ["mphx-2p-8x8", "ft3-small", "dragonfly-small",
               "dfplus-small"]
    spec = parse_failure_spec(SPEC)
    build = SCENARIOS["uniform"].build
    record = {"schema_version": 1, "bench": "reroute", "spec": SPEC,
              "offered_fraction": OFFERED_FRACTION,
              "mode": MODE,
              "protection_layers": N_LAYERS, "repeats": REPEATS,
              "tolerance": TOL, "cells": []}
    for tn in fabrics:
        topo = SWEEP_TOPOLOGIES[tn]
        offered = OFFERED_FRACTION * topo.nic_bw_gbps
        g = topo.build_graph()
        prot, prov_us = timed(
            lambda t=topo: ProtectedRouter(t, n_layers=N_LAYERS))
        _, bnh_us = timed(prot.backup_next_hops)
        dem = build(topo, offered, graph=g)
        dg = degrade_graph(g, spec)
        # -- pin (a): conservation + no load on failed elements --------
        # local reroute: loads live on healthy edge ids, so dead edges
        # are directly checkable (shared by the local and global modes)
        lr = prot.local_reroute_loads(dem, dg)
        surv_mult, _, _ = prot._degraded_state(dg)
        dead_load = float(np.abs(lr.loads[surv_mult <= 0]).max()) \
            if (surv_mult <= 0).any() else 0.0
        # global recompute: route the rebuilt demands on the degraded
        # graph through the same accounting pull (identity failure
        # state), and check the survivor graph is structurally free of
        # failed elements mapped back to healthy ids
        dem_deg = build(topo, offered, graph=dg.graph)
        prot_deg = ProtectedRouter(dg.graph, n_layers=2)
        n_deg = dg.graph.n_switches
        dg0 = DegradedGraph(dg.graph,
                            np.arange(n_deg, dtype=np.int64),
                            [], 0.0, [], dg.graph.total_links())
        lg = prot_deg.local_reroute_loads(dem_deg, dg0)
        inv = {int(dg.node_map[u]): u for u in range(len(dg.node_map))
               if dg.node_map[u] >= 0}
        gone = {tuple(e) for e in dg.fully_failed_edges}
        dead_sw = set(dg.failed_switches)
        structural_bad = 0
        for e in range(prot_deg.csr.n_edges):
            u = inv[int(prot_deg.csr.src[e])]
            v = inv[int(prot_deg.csr.dst[e])]
            if (min(u, v), max(u, v)) in gone or u in dead_sw \
                    or v in dead_sw:
                structural_bad += 1
        conservation_ok = bool(lr.conservation_residual < TOL
                               and lg.conservation_residual < TOL
                               and lg.stalled_share < TOL)
        no_dead_load_ok = bool(dead_load < TOL and structural_bad == 0)
        # -- pin (b): measured local-vs-global recovery gap ------------
        t90, curves = {}, {}
        for rm in ("none", "local", "global"):
            best, best_rows = None, None
            for _ in range(REPEATS):
                rows = recovery_curve(
                    topo, lambda t, o, gg: build(t, o, graph=gg), spec,
                    offered, mode=MODE, reroute=rm,
                    protection=prot if rm != "none" else None)
                t = time_to_recover(rows)
                if t is not None and (best is None or t < best):
                    best, best_rows = t, rows
            t90[rm], curves[rm] = best, best_rows
        # faster means: local recovers, and either strictly sooner than
        # the global recompute or the recompute never reaches 90% at all
        local_faster = (t90["local"] is not None
                        and (t90["none"] is None
                             or t90["local"] < t90["none"]))
        cell = {
            "topology": tn, "is_mphx": tn.startswith("mphx"),
            "protection_coverage": round(prot.protection_coverage(), 6),
            "provision_wall_s": round((prov_us + bnh_us) / 1e6, 6),
            "conservation_residual_local": lr.conservation_residual,
            "conservation_residual_global": lg.conservation_residual,
            "max_dead_edge_load_gbps": dead_load,
            "structural_failed_elements": structural_bad,
            "conservation_ok": conservation_ok,
            "no_dead_load_ok": no_dead_load_ok,
            "t90_none_s": t90["none"], "t90_local_s": t90["local"],
            "t90_global_s": t90["global"],
            "recovery_gap_s": round(t90["none"] - t90["local"], 6)
            if local_faster and t90["none"] is not None else None,
            "local_faster_ok": bool(local_faster),
            "recovery_curves": curves,
        }
        record["cells"].append(cell)
        emit(f"reroute/{tn}",
             (t90["local"] or 0.0) * 1e6,
             f"t90_local_s={t90['local']};t90_none_s={t90['none']};"
             f"conserved={'yes' if conservation_ok else 'NO'};"
             f"dead_load={'0' if no_dead_load_ok else 'NONZERO'};"
             f"local_faster={'yes' if local_faster else 'NO'}")
    # flowlet stability: kill one plane, only its flowlets move
    rng = np.random.default_rng(7)
    sizes = rng.uniform(4096, 8e6, 512)
    healthy_b, _ = flowlet_split(sizes, 4, 1 << 17, seed=7)
    alive = np.array([True, True, False, True])
    dead_b, _ = flowlet_split(sizes, 4, 1 << 17, seed=7, alive=alive)
    stable = bool((dead_b[:, alive] >= healthy_b[:, alive] - 1e-9).all()
                  and dead_b[:, 2].sum() == 0.0
                  and np.allclose(dead_b.sum(axis=1), sizes))
    record["flowlet_stability_ok"] = stable
    cells = record["cells"]
    record["conservation_ok"] = all(c["conservation_ok"] for c in cells)
    record["no_dead_load_ok"] = all(c["no_dead_load_ok"] for c in cells)
    mphx_faster = [c for c in cells
                   if c["is_mphx"] and c["local_faster_ok"]]
    base_faster = [c for c in cells
                   if not c["is_mphx"] and c["local_faster_ok"]]
    record["local_faster_ok"] = bool(mphx_faster and len(base_faster) >= 2)
    out = os.path.join(os.path.dirname(__file__), "..", "results")
    os.makedirs(out, exist_ok=True)
    path = os.path.join(out, "BENCH_reroute.json")
    with open(path, "w") as fh:
        json.dump(record, fh, indent=2)
        fh.write("\n")
    emit("reroute/summary", 0.0,
         f"conservation={'yes' if record['conservation_ok'] else 'NO'};"
         f"no_dead_load={'yes' if record['no_dead_load_ok'] else 'NO'};"
         f"local_faster={'yes' if record['local_faster_ok'] else 'NO'};"
         f"flowlet_stable={'yes' if stable else 'NO'}")


# --------------------------------------------------- experiment suites ----


def bench_experiments():
    """Smoke the repro.experiments suites and time them (artifacts land in
    results/experiments)."""
    from repro.experiments import run_sweep_suite, run_table2_suite

    t2, us = timed(lambda: run_table2_suite())
    emit("experiments/table2", us, f"rows={len(t2['rows'])}")
    sw, us = timed(lambda: run_sweep_suite(topo_names=["mphx-2p-8x8"]))
    emit("experiments/sweep_small", us, f"rows={len(sw['rows'])}")


BENCHES = {
    "table2": bench_table2,
    "vectorized": bench_vectorized,
    "graph": bench_graph_routing,
    "sim": bench_flow_sim,
    "sim-scale": bench_sim_scale,
    "cosim": bench_cosim,
    "serving": bench_serving,
    "reroute": bench_reroute,
    "experiments": bench_experiments,
    "diameter": bench_diameter,
    "flattening": bench_flattening,
    "routing": bench_routing,
    "traffic": bench_traffic,
    "collectives": bench_collectives,
    "spray": bench_spray,
    "fabric": bench_fabric_projection,
    "roofline": bench_roofline,
}


def main(argv: "list[str] | None" = None) -> int:
    import argparse
    from contextlib import nullcontext

    p = argparse.ArgumentParser(
        prog="benchmarks/run.py",
        description="paper-table / framework benches; BENCH_*.json "
                    "artifacts are consolidated by benchmarks/report.py")
    p.add_argument("benches", nargs="*", metavar="BENCH",
                   help=f"benches to run (default all): {' '.join(BENCHES)}")
    p.add_argument("--trace", default=None, metavar="OUT.json",
                   help="run under the fabric flight recorder and export "
                   "a Chrome/Perfetto trace_event JSON; benches whose "
                   "path never crosses the simulator leave explicit "
                   "skip records (docs/observability.md)")
    args = p.parse_args(argv)
    which = args.benches or list(BENCHES)
    unknown = [n for n in which if n not in BENCHES]
    if unknown:
        p.error(f"unknown bench(es) {unknown}; known: {' '.join(BENCHES)}")
    rec, ctx = None, nullcontext()
    if args.trace:
        from repro.telemetry import TraceRecorder, recording
        rec = TraceRecorder()
        ctx = recording(rec)
    with ctx:
        print("name,us_per_call,derived")
        for name in which:
            n0 = rec.n_events if rec else 0
            BENCHES[name]()
            if rec is not None and rec.n_events == n0:
                rec.note_skip(f"bench:{name}",
                              "bench path crossed no traced layer "
                              "(analytic/closed-form only)")
    if rec is not None:
        rec.export(args.trace)
        print(f"trace: {rec.n_events} events, {len(rec.notes)} untraced "
              f"benches -> {args.trace}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
