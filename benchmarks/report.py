"""Perf-regression dashboard over the committed ``results/BENCH_*.json``.

Consolidates every benchmark artifact into one metric set (wall-clock
``time`` metrics, ``speedup`` ratios, boolean ``flag`` gates), keeps a
bounded snapshot history in ``results/BENCH_report.json``, and renders a
delta table to ``results/BENCH_report.md``.

Modes::

    python benchmarks/report.py                  # append snapshot + md
    python benchmarks/report.py --check          # read-only CI gate

``--check`` exits nonzero when any flag is falsy, any time metric is
more than ``--threshold`` (default 1.5x) slower than the baseline
snapshot, or any speedup metric dropped below ``base / threshold``.
The baseline is the last snapshot in the history (or ``--baseline``).

Write mode also deletes the stale ``results/bench_results.csv`` left by
older ``benchmarks/run.py`` revisions — the history JSON supersedes it.

Pure stdlib; no PYTHONPATH needed.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys

HISTORY_SCHEMA = 1
MAX_SNAPSHOTS = 20
DEFAULT_THRESHOLD = 1.5
STALE_CSV = "bench_results.csv"

# wall-clock keys (lower is better); simulated-time results such as
# fct_p50_us, ttft_p99_us or closed_form_s are deterministic outputs,
# not perf metrics, and are deliberately NOT matched.  Absent artifacts
# (e.g. an older result set without BENCH_serving.json) simply
# contribute no metrics — --check only gates what exists.
_TIME_KEYS = {"route_s", "incidence_s", "vectorized_s", "legacy_s",
              "demand_build_vec_s", "demand_build_legacy_s",
              "sim_wall_s"}


def _is_flag_key(key: str) -> bool:
    """Assertion-style booleans only — informational booleans such as
    sim_scale's ``reference_timed`` are not pass/fail gates."""
    return (key in ("meets_target", "ok", "passed")
            or key.startswith("within_") or key.startswith("matches_")
            or key.endswith("_match") or key.endswith("_agree")
            or key.endswith("_ok"))


def _is_time_key(key: str) -> bool:
    return key in _TIME_KEYS or key == "wall_s" \
        or key.endswith("_wall_s") or key.endswith("_engine_s")


def _is_speedup_key(key: str) -> bool:
    return key == "speedup" or key.startswith("speedup_")


def _element_id(item: dict, index: int) -> str:
    for k in ("preset", "topology", "name", "label", "arch", "tenant"):
        v = item.get(k)
        if isinstance(v, str) and v:
            return v
    return str(index)


def _walk(node, path: str, out: dict) -> None:
    if isinstance(node, dict):
        for k, v in node.items():
            sub = f"{path}.{k}" if path else k
            if isinstance(v, bool):
                if _is_flag_key(k):
                    out[sub] = {"kind": "flag", "value": v}
            elif isinstance(v, (int, float)) and _is_speedup_key(k):
                out[sub] = {"kind": "speedup", "value": float(v)}
            elif isinstance(v, (int, float)) and _is_time_key(k):
                out[sub] = {"kind": "time", "value": float(v)}
            elif isinstance(v, dict) and _is_time_key(k):
                # e.g. sim_scale "wall_s": {"numpy": ..., "jax": ...}
                for bk, bv in v.items():
                    if isinstance(bv, (int, float)) \
                            and not isinstance(bv, bool):
                        out[f"{sub}.{bk}"] = {"kind": "time",
                                              "value": float(bv)}
            elif isinstance(v, (dict, list)):
                _walk(v, sub, out)
    elif isinstance(node, list):
        for i, item in enumerate(node):
            if isinstance(item, dict):
                _walk(item, f"{path}[{_element_id(item, i)}]", out)
            # scalar lists (rep timings) are raw samples, not metrics


def extract_metrics(payload: dict) -> dict:
    """Flatten one BENCH payload into ``{metric: {kind, value}}``."""
    bench = payload.get("bench", "unknown")
    out: dict = {}
    _walk(payload, bench, out)
    return out


def collect(results_dir: str) -> dict:
    """Metrics from every ``BENCH_*.json`` in ``results_dir``."""
    metrics: dict = {}
    for path in sorted(glob.glob(os.path.join(results_dir,
                                              "BENCH_*.json"))):
        if os.path.basename(path) == "BENCH_report.json":
            continue
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"report: skipping unreadable {path}: {e}",
                  file=sys.stderr)
            continue
        metrics.update(extract_metrics(payload))
    return metrics


def _git_label() -> str:
    try:
        rev = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, timeout=10)
        if rev.returncode == 0 and rev.stdout.strip():
            return rev.stdout.strip()
    except OSError:
        pass
    return "local"


def load_history(path: str) -> dict:
    if os.path.exists(path):
        with open(path) as f:
            hist = json.load(f)
        if hist.get("schema_version") == HISTORY_SCHEMA \
                and isinstance(hist.get("snapshots"), list):
            return hist
        print(f"report: discarding incompatible history at {path}",
              file=sys.stderr)
    return {"schema_version": HISTORY_SCHEMA, "snapshots": []}


def baseline_metrics(hist: dict) -> "dict | None":
    snaps = hist.get("snapshots", [])
    return snaps[-1]["metrics"] if snaps else None


def compare(current: dict, base: "dict | None",
            threshold: float) -> "list[dict]":
    """Per-metric verdicts; ``ok=False`` rows are regressions."""
    rows = []
    for name in sorted(current):
        cur = current[name]
        row = {"metric": name, "kind": cur["kind"],
               "value": cur["value"], "base": None, "ratio": None,
               "ok": True, "why": ""}
        b = base.get(name) if base else None
        if b is not None and b.get("kind") == cur["kind"]:
            row["base"] = b["value"]
        if cur["kind"] == "flag":
            if not cur["value"]:
                row["ok"] = False
                row["why"] = "flag is false"
        elif row["base"] is not None and row["base"] > 0:
            row["ratio"] = cur["value"] / row["base"]
            if cur["kind"] == "time" and row["ratio"] > threshold:
                row["ok"] = False
                row["why"] = (f"{row['ratio']:.2f}x slower than "
                              f"baseline (threshold {threshold:g}x)")
            elif cur["kind"] == "speedup" \
                    and row["ratio"] < 1.0 / threshold:
                row["ok"] = False
                row["why"] = (f"speedup fell to {row['ratio']:.2f}x of "
                              f"baseline (threshold "
                              f"1/{threshold:g})")
        rows.append(row)
    return rows


def render_markdown(rows: "list[dict]", hist: dict,
                    threshold: float) -> str:
    lines = ["# Benchmark regression report", "",
             f"Metrics: {len(rows)} "
             f"({sum(1 for r in rows if not r['ok'])} regressions, "
             f"threshold {threshold:g}x). Baseline: last snapshot in "
             "`results/BENCH_report.json`.", "",
             "| metric | kind | baseline | current | ratio | status |",
             "| --- | --- | --- | --- | --- | --- |"]
    for r in rows:
        def fmt(v):
            if v is None:
                return "—"
            if isinstance(v, bool):
                return "yes" if v else "no"
            return f"{v:.6g}"
        status = "ok" if r["ok"] else f"**FAIL** ({r['why']})"
        lines.append(f"| {r['metric']} | {r['kind']} | {fmt(r['base'])} "
                     f"| {fmt(r['value'])} | {fmt(r['ratio'])} "
                     f"| {status} |")
    lines += ["", "## History", ""]
    for snap in hist.get("snapshots", []):
        lines.append(f"- `{snap['label']}` — "
                     f"{len(snap['metrics'])} metrics")
    return "\n".join(lines) + "\n"


def run_check(current: dict, base: "dict | None",
              threshold: float) -> int:
    if not current:
        print("report: no BENCH_*.json metrics found", file=sys.stderr)
        return 1
    rows = compare(current, base, threshold)
    bad = [r for r in rows if not r["ok"]]
    for r in bad:
        print(f"REGRESSION {r['metric']}: {r['why']} "
              f"(base={r['base']}, current={r['value']})",
              file=sys.stderr)
    n_base = sum(1 for r in rows if r["base"] is not None)
    print(f"report --check: {len(rows)} metrics, {n_base} compared "
          f"against baseline, {len(bad)} regressions")
    return 1 if bad else 0


def main(argv: "list[str] | None" = None) -> int:
    p = argparse.ArgumentParser(
        prog="python benchmarks/report.py", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    p.add_argument("--results-dir", default="results",
                   help="directory holding BENCH_*.json (default "
                   "results)")
    p.add_argument("--check", action="store_true",
                   help="read-only gate: exit 1 on regressions vs the "
                   "baseline snapshot; writes nothing")
    p.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                   help=f"slowdown ratio that fails --check (default "
                   f"{DEFAULT_THRESHOLD})")
    p.add_argument("--baseline", default=None, metavar="FILE",
                   help="history JSON to compare against (default "
                   "<results-dir>/BENCH_report.json)")
    p.add_argument("--label", default=None,
                   help="snapshot label (default: git short rev)")
    args = p.parse_args(argv)

    hist_path = args.baseline or os.path.join(args.results_dir,
                                              "BENCH_report.json")
    current = collect(args.results_dir)
    hist = load_history(hist_path)
    base = baseline_metrics(hist)

    if args.check:
        return run_check(current, base, args.threshold)

    if not current:
        print("report: no BENCH_*.json metrics found", file=sys.stderr)
        return 1
    snap = {"label": args.label or _git_label(), "metrics": current}
    hist["snapshots"] = (hist["snapshots"] + [snap])[-MAX_SNAPSHOTS:]
    out_json = os.path.join(args.results_dir, "BENCH_report.json")
    with open(out_json, "w") as f:
        json.dump(hist, f, indent=2)
        f.write("\n")
    rows = compare(current, base, args.threshold)
    out_md = os.path.join(args.results_dir, "BENCH_report.md")
    with open(out_md, "w") as f:
        f.write(render_markdown(rows, hist, args.threshold))
    stale = os.path.join(args.results_dir, STALE_CSV)
    if os.path.exists(stale):
        os.remove(stale)
        print(f"report: removed stale {stale} (superseded by "
              f"{out_json})")
    bad = sum(1 for r in rows if not r["ok"])
    print(f"report: {len(current)} metrics -> {out_json}, {out_md} "
          f"({bad} regressions flagged)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
