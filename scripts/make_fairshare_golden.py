#!/usr/bin/env python3
"""Regenerate the water-filling golden fixture.

``tests/golden/fairshare_golden.json`` pins the *reference* (numpy)
max-min solver's steady-state rates, link loads and measured-FCT
percentiles on small fabrics for both routing engines.  The fixture was
captured from the pre-jit solver; every rewritten path (in-jit
``lax.while_loop``, Pallas segment kernel) must reproduce it to 1e-9
(``tests/test_fairshare_golden.py``), so the fast paths are provably the
same solver.

Only rerun this script if the *model* intentionally changes (and say so
in the PR): regenerating to paper over a diff defeats the fixture.

Usage:  PYTHONPATH=src python scripts/make_fairshare_golden.py
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.core.dragonfly import Dragonfly
from repro.core.hyperx import MPHX
from repro.core.netsim import make_router
from repro.core.routing_graph import graph_uniform_demands
from repro.core.routing_vec import (hotspot_demands, neighbor_shift_demands,
                                    uniform_demands)
from repro.sim.events import simulate_demands, simulate_incidence
from repro.sim.fairshare import flow_incidence, max_min_rates

OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "tests", "golden", "fairshare_golden.json")

# (cell name, topology factory, demand builder, incidence mode)
CELLS = [
    ("array/mphx-2p-8x8/uniform",
     lambda: MPHX(n=2, p=8, dims=(8, 8)),
     lambda t, o: uniform_demands(t, o), "minimal"),
    ("array/mphx-2p-8x8/neighbor_shift",
     lambda: MPHX(n=2, p=8, dims=(8, 8)),
     lambda t, o: neighbor_shift_demands(t, o), "minimal"),
    ("array/mphx-2p-8x8/hotspot_valiant",
     lambda: MPHX(n=2, p=8, dims=(8, 8)),
     lambda t, o: hotspot_demands(t, o), "valiant"),
    ("graph/dragonfly-small/uniform",
     lambda: Dragonfly(p=2, a=4, h=2, groups=9, name="Dragonfly (small)"),
     lambda t, o: graph_uniform_demands(t, o), "minimal"),
]

# offered fractions of NIC bandwidth: one comfortably feasible level and
# one past saturation, so the fixture freezes both cap-limited and
# edge-saturated flows
LOADS = (0.5, 1.2)
FLOW_TIME_S = 200e-6


def cell_record(topo, build, mode) -> dict:
    router = make_router(topo, backend="numpy")
    rec = {"topology": topo.name, "mode": mode, "loads": {}}
    for frac in LOADS:
        dem = build(topo, frac * topo.nic_bw_gbps)
        inc = flow_incidence(router, dem, mode)
        caps = np.asarray(dem.gbps, dtype=np.float64)
        rates = max_min_rates(inc, caps, backend="numpy")
        loads = inc.loads(rates)
        row = simulate_demands(router, dem, FLOW_TIME_S, mode=mode,
                               backend="numpy", inc=inc)
        rec["loads"][str(frac)] = {
            "n_flows": int(inc.n_flows),
            "n_edges": int(inc.n_edges),
            "nnz": int(inc.flow.shape[0]),
            "rates_gbps": rates.tolist(),
            "link_loads_gbps_nonzero": {
                str(int(e)): float(loads[e]) for e in np.flatnonzero(loads)},
            "fct": {k: row[k] for k in
                    ("fct_p50_us", "fct_p95_us", "fct_p99_us",
                     "slowdown_mean", "slowdown_p99", "sim_epochs",
                     "sim_stalled", "sim_delivered_fraction")},
        }
    return rec


def staggered_record() -> dict:
    """A staggered-arrival event-loop trace: the full per-flow finish
    times, not just percentiles — pins the epoch semantics exactly."""
    topo = MPHX(n=2, p=8, dims=(8, 8))
    router = make_router(topo, backend="numpy")
    dem = neighbor_shift_demands(topo, 800.0)
    inc = flow_incidence(router, dem, "minimal")
    rng = np.random.default_rng(7)
    size = rng.uniform(0.2, 1.0, inc.n_flows) * (1 << 22)
    start = rng.uniform(0.0, 50e-6, inc.n_flows)
    caps = rng.uniform(200.0, 1600.0, inc.n_flows)
    res = simulate_incidence(inc, size, caps, start_s=start,
                             backend="numpy")
    return {
        "topology": topo.name, "scenario": "neighbor_shift", "seed": 7,
        "size_bytes": size.tolist(), "start_s": start.tolist(),
        "rate_caps_gbps": caps.tolist(),
        "finish_s": res.finish_s.tolist(),
        "fct_s": res.fct_s.tolist(),
        "edge_bytes_nonzero": {
            str(int(e)): float(res.edge_bytes[e])
            for e in np.flatnonzero(res.edge_bytes)},
        "makespan_s": res.makespan_s, "n_epochs": res.n_epochs,
    }


def main() -> None:
    fixture = {
        "comment": "Golden pins of the reference (numpy) max-min "
                   "water-filling solver and event loop, captured before "
                   "the jit/Pallas rewrite.  See "
                   "tests/test_fairshare_golden.py.",
        "flow_time_s": FLOW_TIME_S,
        "load_fractions": list(LOADS),
        "cells": {},
        "staggered": staggered_record(),
    }
    for name, topo_fn, build, mode in CELLS:
        fixture["cells"][name] = cell_record(topo_fn(), build, mode)
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(fixture, f, indent=1)
        f.write("\n")
    n = sum(len(c["loads"]) for c in fixture["cells"].values())
    print(f"wrote {OUT}: {len(fixture['cells'])} cells x {n} load rows "
          f"+ 1 staggered trace")


if __name__ == "__main__":
    main()
