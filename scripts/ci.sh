#!/usr/bin/env bash
# Single CI gate: install deps (unless SKIP_INSTALL=1), run the tier-1
# suite from ROADMAP.md, then smoke every CLI command quoted in the docs
# (skip with SKIP_DOCS_SMOKE=1).  Usage:  ./scripts/ci.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${SKIP_INSTALL:-0}" != "1" ]]; then
    python -m pip install -q -r requirements.txt
    # dev extras (hypothesis) are optional — the suite falls back to
    # tests/_hypothesis_shim.py if this fails (e.g. offline)
    python -m pip install -q -r requirements-dev.txt || \
        python -m pip install -q pytest
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# fast lane skips @pytest.mark.slow (suite-artifact tests); the nightly
# lane runs everything: PYTEST_MARKERS="" ./scripts/ci.sh
PYTEST_MARKERS="${PYTEST_MARKERS-not slow}"
if [[ -n "$PYTEST_MARKERS" ]]; then
    python -m pytest -x -q -m "$PYTEST_MARKERS" "$@"
else
    python -m pytest -x -q "$@"
fi

if [[ "${SKIP_BENCH_CHECK:-0}" != "1" ]]; then
    # perf-regression gate: the committed BENCH_*.json snapshots must
    # not regress vs the committed history (benchmarks/report.py);
    # runs before any smoke regenerates a BENCH artifact
    python benchmarks/report.py --check
fi

if [[ "${SKIP_JAX_LANE:-0}" != "1" ]]; then
    # jax-backend lane: the in-jit water-filling/event-loop paths and
    # the Pallas segment kernels, pinned to the CPU backend so the lane
    # is deterministic on any runner.  The nightly lane (PYTEST_MARKERS="")
    # additionally runs the slow-marked 65K-NIC sim smoke in
    # tests/test_sim_scale.py; the BENCH_sim_scale.json schema smoke runs
    # in every lane.
    JAX_PLATFORMS=cpu python -m pytest -x -q \
        tests/test_fairshare_props.py tests/test_fairshare_golden.py \
        tests/test_sim_scale.py tests/test_kernels.py \
        ${PYTEST_MARKERS:+-m "$PYTEST_MARKERS"}
fi

if [[ "${SKIP_DOCS_SMOKE:-0}" != "1" ]]; then
    # docs can't rot: run the bash blocks of docs/routing.md +
    # docs/experiments.md + docs/simulation.md (smallest presets) end to end
    python scripts/docs_smoke.py
fi

if [[ "${SKIP_SIM_SMOKE:-0}" != "1" ]]; then
    # flow-simulator smoke on a tiny fabric: steady-state sim/analytic
    # agreement (the sim CLI exits nonzero on divergence) + a
    # degraded-fabric sweep.  A throwaway --out so the reduced smoke
    # presets never clobber the committed results/experiments artifacts.
    SIM_SMOKE_OUT="$(mktemp -d)"
    python -m repro.experiments.run --suite sim \
        --topos mphx-2p-8x8 --scenarios uniform --loads 0.5 \
        --out "$SIM_SMOKE_OUT"
    python -m repro.experiments.run --suite failures \
        --topos mphx-2p-8x8 dragonfly-small --failures link:0.05 \
        --out "$SIM_SMOKE_OUT"
    rm -rf "$SIM_SMOKE_OUT"
fi

if [[ "${SKIP_SERVING_SMOKE:-0}" != "1" ]]; then
    # multi-tenant serving smoke on a tiny fabric: open-loop tenant mix
    # with per-tenant SLO rows, run twice with the same seed to catch
    # any nondeterminism (the artifacts must be byte-identical)
    SERVING_SMOKE_OUT="$(mktemp -d)"
    python -m repro.experiments.run --suite serving \
        --topos mphx-2p-8x8 --seed 0 --serving-duration-ms 20 \
        --out "$SERVING_SMOKE_OUT/a"
    python -m repro.experiments.run --suite serving \
        --topos mphx-2p-8x8 --seed 0 --serving-duration-ms 20 \
        --out "$SERVING_SMOKE_OUT/b"
    cmp "$SERVING_SMOKE_OUT/a/serving.json" "$SERVING_SMOKE_OUT/b/serving.json"
    rm -rf "$SERVING_SMOKE_OUT"
fi

if [[ "${SKIP_COSIM_SMOKE:-0}" != "1" ]]; then
    # training-step co-sim smoke: one model config on a tiny fabric,
    # both routing engines + the mapped placement (MPHX cells run all
    # three variants), throwaway --out
    COSIM_SMOKE_OUT="$(mktemp -d)"
    python -m repro.experiments.run --suite cosim \
        --config mixtral_8x22b --ranks 16 --topos mphx-2p-8x8 \
        --out "$COSIM_SMOKE_OUT" \
        --trace "$COSIM_SMOKE_OUT/trace.json"
    rm -rf "$COSIM_SMOKE_OUT"
fi

if [[ "${SKIP_REROUTE_SMOKE:-0}" != "1" ]]; then
    # fast-reroute determinism smoke: the failures suite in all three
    # reroute modes, twice with the same fixed seed — the steady-state
    # rows must be identical (only measured wall-clock fields may
    # differ between runs)
    REROUTE_SMOKE_OUT="$(mktemp -d)"
    for run in a b; do
        python -m repro.experiments.run --suite failures \
            --topos mphx-2p-8x8 --scenarios uniform \
            --failures link:0.01,plane:1 \
            --reroute-modes none local global \
            --out "$REROUTE_SMOKE_OUT/$run"
    done
    python - "$REROUTE_SMOKE_OUT" <<'PY'
import json, sys
WALLS = {"phase_wall_s", "t_offset_s", "sim_wall_s", "time_to_90_s"}
def strip(o):
    if isinstance(o, dict):
        return {k: strip(v) for k, v in o.items() if k not in WALLS}
    if isinstance(o, list):
        return [strip(v) for v in o]
    return o
out = sys.argv[1]
a = strip(json.load(open(f"{out}/a/failures.json")))
b = strip(json.load(open(f"{out}/b/failures.json")))
a.pop("telemetry", None); b.pop("telemetry", None)
assert a == b, "reroute smoke: steady-state rows differ between runs"
modes = {r["reroute"] for r in a["rows"] if r.get("kind") == "recovery"}
assert modes == {"none", "local", "global"}, modes
print("reroute smoke: deterministic across runs, all three modes present")
PY
    rm -rf "$REROUTE_SMOKE_OUT"
fi
