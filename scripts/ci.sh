#!/usr/bin/env bash
# Single CI gate: install deps (unless SKIP_INSTALL=1) and run the tier-1
# suite from ROADMAP.md.  Usage:  ./scripts/ci.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${SKIP_INSTALL:-0}" != "1" ]]; then
    python -m pip install -q -r requirements.txt
    # dev extras (hypothesis) are optional — the suite falls back to
    # tests/_hypothesis_shim.py if this fails (e.g. offline)
    python -m pip install -q -r requirements-dev.txt || \
        python -m pip install -q pytest
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
