"""§Perf hillclimb driver: run tagged dry-run variants of the three chosen
cells and print before/after roofline terms.

Usage: PYTHONPATH=src python scripts/hillclimb.py [cellname ...]
"""

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.dryrun import run_cell  # noqa: E402
from repro.launch.roofline import roofline_row  # noqa: E402

# (cell, variant-tag, overrides)
ROUND2 = {
    "kimi_train": [
        ("kimi-k2-1t-a32b", "train_4k", True, "ws2",
         {"moe_weight_stationary": True}),
        ("kimi-k2-1t-a32b", "train_4k", True, "ws2_mb4",
         {"moe_weight_stationary": True, "microbatches": 4}),
        ("kimi-k2-1t-a32b", "train_4k", True, "mb4", {"microbatches": 4}),
    ],
    "mixtral_train": [
        ("mixtral-8x22b", "train_4k", False, "tpf", {"moe_tp_f": True}),
        ("mixtral-8x22b", "train_4k", False, "tpf_mb4",
         {"moe_tp_f": True, "microbatches": 4}),
    ],
    "yi_train": [
        ("yi-9b", "train_4k", False, "dots", {"remat": "dots"}),
        ("yi-9b", "train_4k", False, "mb16", {"microbatches": 16}),
        ("yi-9b", "train_4k", False, "dots_mb16",
         {"remat": "dots", "microbatches": 16}),
    ],
}

VARIANTS = {
    # most representative of the paper's technique (EP all-to-all on the
    # full-mesh dims) AND most collective-bound
    "kimi_train": [
        ("kimi-k2-1t-a32b", "train_4k", True, "base", {}),
        ("kimi-k2-1t-a32b", "train_4k", True, "ws",
         {"moe_weight_stationary": True}),
        ("kimi-k2-1t-a32b", "train_4k", True, "sp",
         {"sequence_parallel": True}),
        ("kimi-k2-1t-a32b", "train_4k", True, "ws_sp",
         {"moe_weight_stationary": True, "sequence_parallel": True}),
        ("kimi-k2-1t-a32b", "train_4k", True, "ws_sp_mb4",
         {"moe_weight_stationary": True, "sequence_parallel": True,
          "microbatches": 4}),
    ],
    # worst roofline fraction of the MoE cells (dispatch-mode MoE)
    "mixtral_train": [
        ("mixtral-8x22b", "train_4k", False, "base", {}),
        ("mixtral-8x22b", "train_4k", False, "sp",
         {"sequence_parallel": True}),
        ("mixtral-8x22b", "train_4k", False, "sp_mb4",
         {"sequence_parallel": True, "microbatches": 4}),
        ("mixtral-8x22b", "train_4k", False, "sp_remat_dots",
         {"sequence_parallel": True, "remat": "dots"}),
    ],
    # dense memory-bound representative
    "yi_train": [
        ("yi-9b", "train_4k", False, "base", {}),
        ("yi-9b", "train_4k", False, "sp", {"sequence_parallel": True}),
        ("yi-9b", "train_4k", False, "sp_mb4",
         {"sequence_parallel": True, "microbatches": 4}),
        ("yi-9b", "train_4k", False, "sp_dots",
         {"sequence_parallel": True, "remat": "dots"}),
        ("yi-9b", "train_4k", False, "sp_dots_mb4",
         {"sequence_parallel": True, "remat": "dots", "microbatches": 4}),
    ],
}


def main():
    args = sys.argv[1:]
    table = ROUND2 if args and args[0] == "--round2" else VARIANTS
    which = [a for a in args if not a.startswith("--")] or list(table)
    out = {}
    for name in which:
        rows = []
        for arch, shape, mp, tag, overrides in table[name]:
            rec = run_cell(arch, shape, mp, tag=f"hc_{tag}", verbose=False,
                           **overrides)
            rec["tag"] = tag
            r = roofline_row(rec)
            rows.append((tag, r, rec))
            print(f"[{name}/{tag}] compute={r['compute_s']:.3f}s "
                  f"memory={r['memory_s']:.3f}s "
                  f"coll={r['collective_s']:.3f}s bound={r['bound']} "
                  f"frac={r['roofline_fraction']:.4f} "
                  f"hbm={r['hbm_gib']:.1f}GiB")
        out[name] = [(t, {k: r[k] for k in
                          ("compute_s", "memory_s", "collective_s", "bound",
                           "roofline_fraction", "hbm_gib")})
                     for t, r, _ in rows]
    suffix = "_round2" if table is ROUND2 else ""
    with open(os.path.join(os.path.dirname(__file__), "..", "results",
                           f"hillclimb{suffix}.json"), "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
