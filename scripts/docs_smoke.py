#!/usr/bin/env python3
"""Docs smoke: execute every CLI command quoted in the doc set.

Extracts fenced ```bash blocks from the docs listed below, joins
backslash-continued lines, and runs each resulting command from the repo
root with ``PYTHONPATH=src`` — so a doc example that drifts from the CLI
breaks CI instead of rotting.  The quoted examples deliberately use the
smallest presets; keep them that way.

Usage:  python scripts/docs_smoke.py [doc.md ...]
"""

from __future__ import annotations

import os
import re
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_DOCS = [
    os.path.join("docs", "routing.md"),
    os.path.join("docs", "experiments.md"),
    os.path.join("docs", "simulation.md"),
    os.path.join("docs", "cosim.md"),
    os.path.join("docs", "observability.md"),
    os.path.join("docs", "serving.md"),
    os.path.join("docs", "resilience.md"),
]


def bash_blocks(markdown: str) -> list[str]:
    """Contents of every ```bash fenced block."""
    return re.findall(r"```bash\n(.*?)```", markdown, flags=re.DOTALL)


def commands(block: str) -> list[str]:
    """Split a bash block into commands: join backslash continuations and
    lines inside an unterminated double-quoted string (multi-line
    ``python -c "..."`` examples); drop comments and blank lines."""
    out: list[str] = []
    cont = ""
    for line in block.splitlines():
        line = cont + line
        cont = ""
        if line.rstrip().endswith("\\"):
            cont = line.rstrip()[:-1] + " "
            continue
        if line.count('"') % 2:          # inside a quoted heredoc-style arg
            cont = line + "\n"
            continue
        stripped = line.strip()
        if stripped and not stripped.startswith("#"):
            out.append(stripped)
    if cont.strip():
        out.append(cont.strip())
    return out


def main(argv: list[str]) -> int:
    docs = argv or DEFAULT_DOCS
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    n = 0
    for doc in docs:
        path = os.path.join(REPO, doc)
        with open(path) as f:
            text = f.read()
        cmds = [c for block in bash_blocks(text) for c in commands(block)]
        if not cmds:
            print(f"docs-smoke: WARNING no bash commands found in {doc}")
        for cmd in cmds:
            n += 1
            t0 = time.perf_counter()
            print(f"docs-smoke [{doc}] $ {cmd}")
            proc = subprocess.run(cmd, shell=True, cwd=REPO, env=env,
                                  capture_output=True, text=True,
                                  timeout=1200)
            dt = time.perf_counter() - t0
            if proc.returncode != 0:
                sys.stdout.write(proc.stdout)
                sys.stderr.write(proc.stderr)
                print(f"docs-smoke: FAILED ({proc.returncode}) after "
                      f"{dt:.1f}s: {cmd}")
                return 1
            print(f"docs-smoke: ok ({dt:.1f}s)")
    print(f"docs-smoke: {n} commands from {len(docs)} docs all passed")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
