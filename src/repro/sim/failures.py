"""Link / switch / plane failure injection (degraded-fabric evaluation).

The resilience axis the analytic stack could not express: sample physical
link and switch failures out of a topology's :class:`SwitchGraph`, rebuild
the CSR routing state over the survivors, and measure degraded throughput
and recovery behaviour.  Degraded fabrics always route on the generic
graph engine (:class:`~repro.core.routing_graph.GraphRouter`) — the MPHX
array engine's coordinate arithmetic assumes an intact mesh, so MPHX
degrades through its own ``build_graph()`` (explicit skip records are
emitted for engines without re-route support, never silent drops).

Whole-plane failures are handled at the spray layer (surviving planes
re-carry ``n / alive`` of the load, delivering at most ``alive / n`` —
:func:`repro.core.planes.plane_failure_degradation`); this module folds
that factor into the degraded-throughput rows.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core.routing_graph import GraphRouter
from repro.core.topology import SwitchGraph, Topology
from repro.routing.protection import (ProtectedRouter, REROUTE_MODES,
                                      validate_reroute_mode)
from repro.telemetry import get_metrics, get_recorder
from .fairshare import flow_incidence

__all__ = ["FailureSpec", "parse_failure_spec", "DegradedGraph",
           "degrade_graph", "degraded_router", "plane_capacity_factor",
           "failure_throughput", "recovery_curve", "time_to_recover",
           "REROUTE_MODES", "validate_reroute_mode"]


@dataclass(frozen=True)
class FailureSpec:
    """What to break: fractions of physical links / switches, whole planes."""

    link_fraction: float = 0.0
    switch_fraction: float = 0.0
    planes_down: int = 0
    seed: int = 0

    def __post_init__(self):
        if not (0 <= self.link_fraction < 1):
            raise ValueError("link_fraction must be in [0, 1)")
        if not (0 <= self.switch_fraction < 1):
            raise ValueError("switch_fraction must be in [0, 1)")
        if self.planes_down < 0:
            raise ValueError("planes_down must be >= 0")

    @property
    def is_noop(self) -> bool:
        return (self.link_fraction == 0 and self.switch_fraction == 0
                and self.planes_down == 0)

    def label(self) -> str:
        parts = []
        if self.link_fraction:
            parts.append(f"link:{self.link_fraction:g}")
        if self.switch_fraction:
            parts.append(f"switch:{self.switch_fraction:g}")
        if self.planes_down:
            parts.append(f"plane:{self.planes_down}")
        return ",".join(parts) or "none"


def parse_failure_spec(text: str) -> FailureSpec:
    """Parse the CLI grammar ``link:0.01,switch:0.02,plane:1[,seed:3]``.

    Rejects (with a ``ValueError`` naming the offending part) duplicate
    element kinds (``link:0.01,link:0.02`` would otherwise silently keep
    the last), unknown keys, non-numeric values, and negative
    fractions/counts — a mistyped spec must never half-run a suite.
    """
    kw: dict = {}
    keys = {"link": "link_fraction", "switch": "switch_fraction",
            "plane": "planes_down", "seed": "seed"}
    for part in text.split(","):
        part = part.strip()
        if not part:
            continue
        if ":" not in part:
            raise ValueError(
                f"bad failure spec {part!r}: expected key:value with key "
                f"in {sorted(keys)} (e.g. 'link:0.01,plane:1')")
        k, v = part.split(":", 1)
        k = k.strip().lower()
        if k not in keys:
            raise ValueError(f"unknown failure key {k!r} in {text!r}; "
                             f"known: {sorted(keys)}")
        if keys[k] in kw:
            raise ValueError(f"duplicate failure key {k!r} in {text!r}: "
                             f"each element kind may appear once")
        v = v.strip()
        is_int = keys[k] in ("planes_down", "seed")
        try:
            val = int(v) if is_int else float(v)
        except ValueError:
            raise ValueError(
                f"bad value {v!r} for failure key {k!r} in {text!r}: "
                f"expected {'an integer' if is_int else 'a number'}"
            ) from None
        if val < 0:
            raise ValueError(f"negative value {v!r} for failure key {k!r} "
                             f"in {text!r}")
        kw[keys[k]] = val
    return FailureSpec(**kw)


@dataclass
class DegradedGraph:
    """A failed-down copy of a :class:`SwitchGraph` plus what broke.

    Surviving switches are *compacted* (dead nodes dropped, survivors
    renumbered 0..S'-1 via ``node_map``) so the graph stays BFS-routable;
    with link-only failures ``node_map`` is the identity and healthy-id
    demand matrices transfer unchanged.  ``failed_switches`` and
    ``fully_failed_edges`` are in HEALTHY ids (for pre-reroute loss
    estimates on the healthy fabric's incidence).
    """

    graph: SwitchGraph
    node_map: np.ndarray         # (S_healthy,) old -> new id, -1 = dead
    failed_switches: list        # healthy ids
    failed_links: float          # physical links removed (multiplicity sum)
    fully_failed_edges: list     # healthy-id (u, v) with no surviving links
    total_links: float

    def info(self) -> dict:
        return {
            "failed_switches": len(self.failed_switches),
            "failed_links": round(self.failed_links, 3),
            "fully_failed_edges": len(self.fully_failed_edges),
            "failed_link_fraction":
                round(self.failed_links / self.total_links, 6)
                if self.total_links else 0.0,
        }


def degrade_graph(graph: SwitchGraph, spec: FailureSpec) -> DegradedGraph:
    """Sample failures from ``spec`` and rebuild the surviving multigraph.

    Each physical link fails independently with ``link_fraction``
    (trunked edges lose a Binomial share of their multiplicity); each
    switch fails with ``switch_fraction``, dropping all incident links and
    its NICs.
    """
    rng = np.random.default_rng(spec.seed)
    S = graph.n_switches
    dead = np.zeros(S, dtype=bool)
    if spec.switch_fraction > 0:
        dead = rng.random(S) < spec.switch_fraction
        if dead.all():
            dead[int(rng.integers(S))] = False
    node_map = np.full(S, -1, dtype=np.int64)
    node_map[~dead] = np.arange(int((~dead).sum()))
    out = SwitchGraph(int((~dead).sum()), graph.nics_per_switch,
                      graph.link_gbps,
                      name=f"{graph.name} (degraded {spec.label()})",
                      nic_nodes=[int(node_map[u]) for u in graph.nic_nodes
                                 if not dead[u]])
    failed_links = 0.0
    fully_failed = []
    for u in range(S):
        for v, m in graph.adj[u].items():
            if v < u:
                continue
            if dead[u] or dead[v]:
                failed_links += m
                continue
            keep = m
            if spec.link_fraction > 0:
                n_phys = max(1, int(round(m)))
                k_fail = rng.binomial(n_phys, spec.link_fraction)
                keep = m * (1.0 - k_fail / n_phys)
            if keep <= 0:
                failed_links += m
                fully_failed.append((u, v))
                continue
            failed_links += m - keep
            out.add_edge(int(node_map[u]), int(node_map[v]), keep,
                         tier=graph.tier.get((u, v), ""))
    return DegradedGraph(out, node_map, [int(u) for u in np.flatnonzero(dead)],
                         failed_links, fully_failed, graph.total_links())


def degraded_router(topo: Topology, spec: FailureSpec,
                    backend: str = "auto"):
    """(GraphRouter over the degraded fabric, DegradedGraph).

    Raises ``NotImplementedError`` if ``topo`` has no explicit switch
    graph, ``ValueError`` if the failures disconnect the fabric — callers
    (the failures suite) turn both into explicit artifact records.
    """
    dg = degrade_graph(topo.build_graph(), spec)
    router = GraphRouter(dg.graph, backend=backend)
    router.hops  # force the BFS: raises ValueError when disconnected
    get_metrics().inc("failures.reroute_recomputes")
    return router, dg


def plane_capacity_factor(topo: Topology, spec: FailureSpec) -> float:
    """Delivered-bandwidth factor of whole-plane failures: survivors
    re-carry the sprayed load, so at most ``alive / n`` gets through."""
    n = topo.n_planes
    if spec.planes_down >= n:
        raise ValueError(f"planes_down={spec.planes_down} >= {n} planes")
    return (n - spec.planes_down) / n


def failure_throughput(topo: Topology, demand_builder, spec: FailureSpec,
                       offered_per_nic_gbps: float, mode: str = "adaptive",
                       backend: str = "auto") -> dict:
    """Healthy-vs-degraded saturation throughput for one traffic matrix.

    ``demand_builder(topo, offered, graph) -> DemandArrays`` (the scenario
    ``build`` signature).  Both sides route on the graph engine so the
    comparison is apples-to-apples; surviving planes carry ``n / alive``
    of the sprayed load when planes are down.
    """
    healthy_g = topo.build_graph()
    healthy = GraphRouter(healthy_g, backend=backend)
    router, dg = degraded_router(topo, spec, backend=backend)
    factor = plane_capacity_factor(topo, spec)
    scale = 1.0 / factor                   # per-surviving-plane load
    dem_h = demand_builder(topo, offered_per_nic_gbps, healthy_g)
    dem_d = demand_builder(topo, offered_per_nic_gbps * scale, dg.graph)
    ll_h = healthy.route(dem_h, mode)
    ll_d = router.route(dem_d, mode)
    thpt_h = ll_h.saturation_throughput()
    thpt_d = ll_d.saturation_throughput() * factor
    return {
        "mode": mode,
        "healthy_max_util": round(ll_h.max_utilization(), 6),
        "degraded_max_util": round(ll_d.max_utilization(), 6),
        "healthy_throughput_fraction": round(thpt_h, 6),
        "degraded_throughput_fraction": round(thpt_d, 6),
        "throughput_retained": round(thpt_d / thpt_h, 6) if thpt_h else 0.0,
        "plane_capacity_factor": round(factor, 6),
        **dg.info(),
    }


def recovery_curve(topo: Topology, demand_builder, spec: FailureSpec,
                   offered_per_nic_gbps: float, mode: str = "adaptive",
                   backend: str = "auto",
                   throughput_row: "dict | None" = None,
                   reroute_wall_s: "float | None" = None,
                   reroute: str = "none",
                   protection: "ProtectedRouter | None" = None,
                   n_layers: int = 4) -> "list[dict]":
    """Degraded-fabric recovery curve for one traffic matrix.

    The phase sequence depends on ``reroute`` (the three-way comparison
    the resilience literature measures):

    * ``"none"`` — today's global recompute: ``healthy`` / ``failed`` /
      ``rerouted`` (survivors re-route on the degraded graph — a full
      BFS + re-route, the reconvergence cost every flow pays);
    * ``"local"`` — precomputed protection: ``healthy`` / ``failed`` /
      ``local_reroute`` (stale distances + MRC backup layers, *no* BFS —
      the phase wall is table lookups and load propagation only);
    * ``"global"`` — the full story: ``healthy`` / ``failed`` /
      ``local_reroute`` / ``reconverged`` (protection bridges the gap,
      then global reconvergence restores optimal routing).

    ``failed`` is the pre-reroute instant: traffic still follows healthy
    minimal paths, so the ECMP share crossing a failed element stalls
    (first-order estimate from the incidence tensor).

    For ``"local"``/``"global"``, pass a prebuilt
    :class:`~repro.routing.protection.ProtectedRouter` as ``protection``
    to amortize provisioning across specs; otherwise one is built with
    ``n_layers`` layers.  Protection state (per-layer BFS + backup
    next-hop table) is forced *before* the failure instant — it is
    provisioning-time work and never counts against a recovery wall.

    Pass a precomputed :func:`failure_throughput` record as
    ``throughput_row`` to reuse its degraded routing for the
    ``rerouted``/``reconverged`` phase instead of re-deriving it — and
    its measured wall time as ``reroute_wall_s`` so the phase still has
    a real duration.

    Each row carries ``reroute``, ``phase_wall_s`` (measured wall time
    of that phase's computation) and ``t_offset_s`` (cumulative start
    offset), so the recovery window is a measured span, not an inferred
    one; an active flight recorder gets the same spans on a ``failures``
    track.  Feed the rows to :func:`time_to_recover` for the
    time-to-X%-throughput scalar.
    """
    validate_reroute_mode(reroute)
    if reroute != "none":
        if protection is None:
            protection = ProtectedRouter(topo, n_layers=n_layers,
                                         backend=backend)
        protection.backup_next_hops()   # provisioning-time, pre-failure
        healthy = protection.router
        healthy_g = healthy.graph
    else:
        healthy_g = topo.build_graph()
        healthy = GraphRouter(healthy_g, backend=backend)
    t0 = time.perf_counter()
    dem = demand_builder(topo, offered_per_nic_gbps, healthy_g)
    ll_h = healthy.route(dem, mode)
    wall_h = time.perf_counter() - t0
    rows = [{"phase": "healthy", "delivered_fraction":
             round(min(1.0, ll_h.saturation_throughput()), 6),
             "max_util": round(ll_h.max_utilization(), 6)}]
    # detect window: sample what broke + estimate the pre-reroute loss
    t0 = time.perf_counter()
    dg = degrade_graph(healthy_g, spec)
    # pre-reroute: flows lose the ECMP share that crossed failed edges
    inc = flow_incidence(healthy, dem, "minimal")
    csr = healthy.csr
    gone = {tuple(e) for e in dg.fully_failed_edges}
    dead = set(dg.failed_switches)
    edge_ids = np.array(
        [e for e, (u, v) in enumerate(zip(csr.src.tolist(),
                                          csr.dst.tolist()))
         if (min(u, v), max(u, v)) in gone or u in dead or v in dead],
        dtype=np.int64)
    lost = inc.edge_share(edge_ids) if edge_ids.size else \
        np.zeros(dem.n)
    g = np.asarray(dem.gbps)
    factor = plane_capacity_factor(topo, spec)
    stall_delivered = float((g * (1 - lost)).sum() / g.sum()) if g.sum() \
        else 1.0
    wall_f = time.perf_counter() - t0
    rows.append({"phase": "failed",
                 "delivered_fraction":
                     round(min(1.0, ll_h.saturation_throughput())
                           * stall_delivered * factor, 6),
                 "stalled_share": round(1 - stall_delivered, 6)})
    walls = [wall_h, wall_f]
    mx = get_metrics()
    if reroute != "none":
        # local window: precomputed-backup reroute — table lookups +
        # load propagation over stale distances, no BFS, no rebuild
        t0 = time.perf_counter()
        lr = protection.local_reroute_loads(dem, dg)
        sat = lr.saturation_throughput()
        rows.append({"phase": "local_reroute",
                     "delivered_fraction":
                         round(min(1.0, sat * lr.delivered_share)
                               * factor, 6),
                     "max_util": round(lr.max_utilization(), 6),
                     "stalled_share": round(lr.stalled_share, 6),
                     "diverted_gbps": round(lr.diverted_gbps, 6),
                     "conservation_residual": lr.conservation_residual})
        wall_l = time.perf_counter() - t0
        walls.append(wall_l)
        mx.observe("failures.local_reroute_wall_s", wall_l)
    if reroute in ("none", "global"):
        # re-route window: the global degraded-routing recompute
        phase = "rerouted" if reroute == "none" else "reconverged"
        t0 = time.perf_counter()
        try:
            rr = throughput_row if throughput_row is not None else \
                failure_throughput(topo, demand_builder, spec,
                                   offered_per_nic_gbps, mode, backend)
            rows.append({"phase": phase,
                         "delivered_fraction":
                             round(min(1.0,
                                       rr["degraded_throughput_fraction"]),
                                   6),
                         "max_util": rr["degraded_max_util"]})
        except ValueError as e:           # disconnected survivors
            rows.append({"phase": phase, "disconnected": True,
                         "reason": str(e)})
        wall_r = time.perf_counter() - t0
        if throughput_row is not None and reroute_wall_s is not None:
            wall_r = reroute_wall_s           # the reused recompute's wall
        walls.append(wall_r)
        mx.observe("failures.reroute_wall_s", wall_r)
    offset = 0.0
    rec = get_recorder()
    for row, wall in zip(rows, walls):
        row["reroute"] = reroute
        row["phase_wall_s"] = round(wall, 6)
        row["t_offset_s"] = round(offset, 6)
        if rec is not None:
            rec.span(f"{spec.label()}:{row['phase']}", offset, wall,
                     process="failures", thread=f"{topo.name}:{reroute}",
                     cat="recovery",
                     args={k: v for k, v in row.items()
                           if k not in ("phase_wall_s", "t_offset_s")})
        offset += wall
    mx.observe("failures.detect_wall_s", wall_f)
    return rows


def time_to_recover(rows: "list[dict]", target: float = 0.9
                    ) -> "float | None":
    """Seconds from the failure instant (start of the detect window)
    until delivered throughput first returns to ``target`` × the healthy
    level, measured at the end of the phase that gets there.

    ``None`` when no phase recovers (e.g. disconnected survivors) — the
    fabric never comes back without repair.
    """
    if not rows or rows[0].get("phase") != "healthy":
        raise ValueError("rows must start with the healthy phase")
    if len(rows) < 2:               # nothing ever failed
        return None
    healthy = rows[0].get("delivered_fraction", 0.0)
    fail_t = rows[1]["t_offset_s"]
    for row in rows[1:]:
        df = row.get("delivered_fraction")
        if df is not None and df >= target * healthy - 1e-12:
            return round(row["t_offset_s"] + row["phase_wall_s"] - fail_t,
                         6)
    return None
