"""repro.sim — vectorized flow-level fabric simulator (time domain).

Layers on the batched routing engines: per-flow edge incidence
(:mod:`.fairshare`) + max-min fair water-filling give measured flow
completion times (:mod:`.events`), plane spraying with skew/failure
re-spray (:mod:`.spray`), link/switch/plane failure injection with
re-routing (:mod:`.failures`), and measured collective schedules
(:mod:`.collective_sim`).  ``docs/simulation.md`` is the guide.
"""

from .collective_sim import SIM_COLLECTIVES, simulate_collective
from .events import (BatchSimResult, FlowSimResult, FlowSpec,
                     flows_to_demands, path_latency, simulate_demands,
                     simulate_flow_batches, simulate_flows,
                     simulate_incidence)
from .failures import (DegradedGraph, FailureSpec, degrade_graph,
                       degraded_router, failure_throughput,
                       parse_failure_spec, plane_capacity_factor,
                       recovery_curve)
from .fairshare import FlowIncidence, flow_incidence, max_min_rates
from .spray import SprayedSimResult, simulate_sprayed

__all__ = [
    "SIM_COLLECTIVES", "simulate_collective",
    "BatchSimResult", "FlowSimResult", "FlowSpec", "flows_to_demands",
    "path_latency", "simulate_demands", "simulate_flow_batches",
    "simulate_flows", "simulate_incidence",
    "DegradedGraph", "FailureSpec", "degrade_graph", "degraded_router",
    "failure_throughput", "parse_failure_spec", "plane_capacity_factor",
    "recovery_curve",
    "FlowIncidence", "flow_incidence", "max_min_rates",
    "SprayedSimResult", "simulate_sprayed",
]
