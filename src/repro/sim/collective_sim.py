"""Measured collective completion on the simulated fabric.

Executes the chunked collective schedules of
:mod:`repro.experiments.scenarios` as sequences of sprayed flow batches,
so the alpha-beta estimates of :mod:`repro.core.netsim`
(``ring_allreduce_time`` / ``allgather_time`` / ``alltoall_time``) get a
*measured* counterpart: per-step flows route through the real fabric,
share links max-min fairly, and spray over planes with the NIC chunk
schedule (whole-chunk rounding penalties included — a step chunk that
does not split over the planes rides one plane, exactly the
``plane_chunk_count == 1`` case the scenario registry charges).

Ring collectives are steady-state symmetric — every step moves the same
flow pattern — so one step is simulated and scaled by the step count.
"""

from __future__ import annotations

import numpy as np

from repro.core.netsim import (DEFAULT_NET, NetParams, allgather_time,
                               alltoall_time, make_router,
                               ring_allreduce_time)
from repro.core.hyperx import MPHX
from repro.core.planes import SprayConfig
from repro.core.topology import Topology
from .events import FlowSpec
from .spray import simulate_sprayed

SIM_COLLECTIVES = ("allreduce_ring", "allgather_ring", "alltoall")


def ring_participants(topo: Topology, graph=None) -> np.ndarray:
    """Switch-level ring order: all switches of one MPHX plane, or the
    NIC-bearing switches of a generic graph (the
    ``scenarios.ring_demands`` convention)."""
    if isinstance(topo, MPHX):
        return np.arange(topo.switches_per_plane, dtype=np.int64)
    g = graph if graph is not None else topo.build_graph()
    return np.asarray(g.nic_nodes, dtype=np.int64)


def _step_flows(ring: np.ndarray, step_bytes: float) -> "list[FlowSpec]":
    nxt = np.roll(ring, -1)
    return [FlowSpec(int(s), int(d), step_bytes)
            for s, d in zip(ring, nxt) if s != d]


def _alltoall_flows(topo: Topology, ring: np.ndarray, bytes_per_nic: float,
                    nics_per_switch: int) -> "list[FlowSpec]":
    per_pair = nics_per_switch * bytes_per_nic / max(len(ring) - 1, 1)
    return [FlowSpec(int(s), int(d), per_pair)
            for s in ring for d in ring if s != d]


def simulate_collective(topo: Topology, kind: str, bytes_per_nic: float,
                        cfg: "SprayConfig | None" = None,
                        mode: str = "minimal",
                        net: NetParams = DEFAULT_NET,
                        engine: str = "auto", backend: str = "numpy",
                        router=None) -> dict:
    """Measured completion of one collective vs. the analytic estimate.

    ``kind`` is one of :data:`SIM_COLLECTIVES` (the scenario registry's
    collective schedules).  Returns a flat artifact row with
    ``measured_us``, the matching ``analytic_us`` closed form, and their
    ratio (>1 = the fabric under-delivers the alpha-beta model, e.g.
    spray rounding or link contention the closed form ignores).
    """
    if kind not in SIM_COLLECTIVES:
        raise ValueError(f"unknown collective {kind!r}; "
                         f"known: {SIM_COLLECTIVES}")
    if router is None:
        router = make_router(topo, backend="auto", engine=engine)
    graph = getattr(router, "graph", None)
    ring = ring_participants(topo, graph)
    nics_per_switch = getattr(topo, "p", None) or (
        graph.nics_per_switch if graph is not None else 1)
    m = int(topo.n_nics)
    if kind == "allreduce_ring":
        steps = 2 * (m - 1)
        step_bytes = bytes_per_nic / m
        flows = _step_flows(ring, step_bytes)
        analytic = ring_allreduce_time(topo, bytes_per_nic, net=net)
    elif kind == "allgather_ring":
        steps = m - 1
        step_bytes = bytes_per_nic
        flows = _step_flows(ring, step_bytes)
        analytic = allgather_time(topo, bytes_per_nic, net=net)
    else:  # alltoall
        steps = 1
        step_bytes = bytes_per_nic
        flows = _alltoall_flows(topo, ring, bytes_per_nic, nics_per_switch)
        analytic = alltoall_time(topo, bytes_per_nic, net=net)
    res = simulate_sprayed(topo, flows, cfg=cfg, mode=mode, net=net,
                           backend=backend, router=router)
    step_s = res.makespan_s + net.software_alpha
    measured = steps * step_s
    return {
        "collective": kind,
        "topology": topo.name,
        "bytes_per_nic": int(bytes_per_nic),
        "steps": steps,
        "step_bytes": int(step_bytes),
        "sim_flows_per_step": len(flows),
        "measured_us": round(measured * 1e6, 3),
        "analytic_us": round(analytic.total_s * 1e6, 3),
        "analytic_algo": analytic.algo,
        "measured_over_analytic":
            round(measured / analytic.total_s, 4)
            if analytic.total_s > 0 else None,
    }
