"""Event-driven flow-level simulation loop.

Finite flows start, share the fabric max-min fairly, and complete; the
loop advances time between start/completion events, re-solving fair
shares (:func:`repro.sim.fairshare.max_min_rates`) each epoch.  This
turns the analytic engines' asymptotic utilizations into *measured* flow
completion times (FCTs) — the FatPaths-style evaluation the closed forms
cannot give.

Conventions (matching :mod:`repro.core.netsim`): sizes are bytes,
rates/capacities Gbps, times seconds.  A flow's FCT is its transfer time
(size over its time-varying fair share) plus the path alpha term
``t_nic + sw_hops * t_switch + (sw_hops + 2) * t_prop`` where ``sw_hops``
is the flow's expected hop count from the incidence tensor — so an
uncontended flow's FCT is exactly the closed-form
``bytes / min(rate_cap, bottleneck) + alpha`` bound
(``tests/test_sim.py`` pins it).
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass

import numpy as np

from repro.core.netsim import DEFAULT_NET, NetParams, gbps_to_Bps
from repro.core.routing_vec import DemandArrays
from repro.telemetry import get_metrics, get_recorder
from .fairshare import (FlowIncidence, _segment_sum, _waterfill_body,
                        _waterfill_scale, flow_incidence, max_min_rates,
                        resolve_sim_backend)


@dataclass(frozen=True)
class FlowSpec:
    """One finite flow: ``size_bytes`` from switch ``src`` to ``dst``.

    ``tag`` is an opaque attribution handle (e.g. a tenant id, or a
    ``(tenant, request)`` tuple) carried through the simulation into the
    per-flow results and telemetry — callers never re-derive ownership
    by index arithmetic.  It does not affect the simulated float
    sequence in any way.
    """

    src: int
    dst: int
    size_bytes: float
    start_s: float = 0.0
    tag: object = None


def flows_to_demands(flows: "list[FlowSpec]") -> DemandArrays:
    return DemandArrays(
        np.array([f.src for f in flows], dtype=np.int64),
        np.array([f.dst for f in flows], dtype=np.int64),
        np.ones(len(flows)))


@dataclass
class FlowSimResult:
    """Per-flow outcome of one fabric simulation."""

    start_s: np.ndarray        # (F,)
    finish_s: np.ndarray       # (F,) transfer-complete time (inf = stalled)
    fct_s: np.ndarray          # (F,) finish - start + path alpha term
    latency_s: np.ndarray      # (F,) the per-flow path alpha term
    size_bytes: np.ndarray     # (F,)
    edge_bytes: np.ndarray     # (E,) bytes carried per edge
    incidence: FlowIncidence
    makespan_s: float = 0.0    # last finish (stalled flows excluded)
    n_epochs: int = 0
    tags: "np.ndarray | None" = None   # (F,) object — opaque flow tags

    @property
    def stalled(self) -> np.ndarray:
        return ~np.isfinite(self.finish_s)

    def tag_mask(self, tag) -> np.ndarray:
        """(F,) bool — flows whose tag equals ``tag`` (requires tags)."""
        if self.tags is None:
            raise ValueError("simulation was run without flow tags")
        return np.array([t == tag for t in self.tags], dtype=bool)

    def flow_records(self) -> "list[dict]":
        """Per-flow FCT records (start/finish/fct/size/tag), the
        attribution-ready view tenant accounting consumes."""
        tags = self.tags if self.tags is not None \
            else np.full(self.size_bytes.shape[0], None, dtype=object)
        return [
            {"flow": f, "tag": tags[f],
             "start_s": float(self.start_s[f]),
             "finish_s": float(self.finish_s[f]),
             "fct_s": float(self.fct_s[f]),
             "size_bytes": float(self.size_bytes[f]),
             "stalled": bool(~np.isfinite(self.finish_s[f]))}
            for f in range(self.size_bytes.shape[0])]

    def transfer_s(self) -> np.ndarray:
        return self.finish_s - self.start_s

    def fct_percentiles(self, qs=(50, 95, 99)) -> dict:
        ok = self.fct_s[~self.stalled]
        if ok.size == 0:
            return {f"p{q}": None for q in qs}
        return {f"p{q}": float(np.percentile(ok, q)) for q in qs}

    def slowdown(self, rate_caps_gbps: np.ndarray) -> np.ndarray:
        """(F,) FCT over the uncontended closed-form FCT at each flow's
        own rate cap (1.0 = no queueing/contention inflation)."""
        caps = np.broadcast_to(np.asarray(rate_caps_gbps, dtype=np.float64),
                               self.size_bytes.shape)
        bneck = self.incidence.bottleneck_gbps()
        ideal = (self.size_bytes / gbps_to_Bps(np.minimum(caps, bneck))
                 + self.latency_s)
        return self.fct_s / ideal

    def delivered_gbps(self) -> float:
        """Aggregate delivered injection rate over the makespan."""
        done = self.size_bytes[~self.stalled].sum()
        return float(done * 8 / 1e9 / self.makespan_s) \
            if self.makespan_s > 0 else 0.0

    def mean_utilization_weighted(self) -> np.ndarray:
        """(E,) time-averaged edge utilization over the makespan."""
        cap = self.incidence.capacity
        if self.makespan_s <= 0:
            return np.zeros_like(cap)
        with np.errstate(divide="ignore", invalid="ignore"):
            gbps = self.edge_bytes * 8 / 1e9 / self.makespan_s
            return np.where(cap > 0, gbps / cap, 0.0)


def path_latency(inc: FlowIncidence, net: NetParams = DEFAULT_NET
                 ) -> np.ndarray:
    """(F,) per-flow path alpha term from the incidence hop counts
    (+2 access hops, same hop convention as ``netsim.avg_latency``)."""
    sw = inc.switch_hops()
    return (net.t_nic + sw * net.t_switch
            + (sw + 2.0) * net.t_prop_per_hop)


def _journal_util(inc: FlowIncidence, rates_act: np.ndarray,
                  sel: np.ndarray) -> np.ndarray:
    """(K,) utilization of the selected global edges at the epoch's
    active-flow rates (the numpy-loop side of the epoch journal — the jit
    loop computes the same quantity over compressed edges)."""
    if sel.size == 0:
        return np.zeros(0)
    loads = np.zeros(inc.n_edges)
    np.add.at(loads, inc.edge, rates_act[inc.flow] * inc.frac)
    cap = inc.capacity
    with np.errstate(divide="ignore", invalid="ignore"):
        return np.where(cap[sel] > 0, loads[sel] / cap[sel], 0.0)


def _normalize_tags(tags, F: int) -> "np.ndarray | None":
    """(F,) object array of opaque flow tags, or None when absent."""
    if tags is None:
        return None
    tag_list = list(tags)
    if len(tag_list) != F:
        raise ValueError(f"expected {F} tags, got {len(tag_list)}")
    out = np.empty(F, dtype=object)
    out[:] = tag_list
    return out


def simulate_incidence(inc: FlowIncidence, size_bytes, rate_caps_gbps,
                       start_s=None, net: NetParams = DEFAULT_NET,
                       backend: str = "numpy", tags=None) -> FlowSimResult:
    """Run the event loop over a prebuilt incidence tensor.

    ``size_bytes`` / ``rate_caps_gbps`` / ``start_s`` broadcast to (F,).
    Active flows whose fair share is 0 (every path crosses a
    zero-capacity edge — e.g. after failure injection) are marked stalled
    (``finish_s = inf``) rather than looping forever.

    ``backend`` picks the epoch engine: ``numpy`` is the reference Python
    event loop (one :func:`max_min_rates` call per epoch); ``jax`` /
    ``pallas`` run the *entire* loop — epoch advance plus the nested
    water-filling — as one jitted ``lax.while_loop``, so a simulation is
    a single device call instead of a Python round-trip per re-solve
    (semantics pinned to the numpy loop at 1e-9 by the golden fixtures).

    When a flight recorder is active (:func:`repro.telemetry.recording`)
    both engines additionally journal one row per epoch — epoch clock,
    active-flow count, utilization of the recorder's selected link subset
    — with identical row count and ordering, plus per-flow transfer
    spans.  With no recorder the numpy loop skips the journal code
    entirely and the jitted loop compiles the exact pre-telemetry graph
    (``record`` is a static argument), so disabled telemetry cannot
    perturb the golden float sequences.
    """
    F = inc.n_flows
    size = np.broadcast_to(np.asarray(size_bytes, dtype=np.float64),
                           (F,)).copy()
    caps = np.broadcast_to(np.asarray(rate_caps_gbps, dtype=np.float64),
                           (F,)).copy()
    start = (np.zeros(F) if start_s is None else
             np.broadcast_to(np.asarray(start_s, dtype=np.float64),
                             (F,)).copy())
    if np.any(size < 0) or np.any(caps <= 0):
        raise ValueError("sizes must be >= 0 and rate caps > 0")
    backend = resolve_sim_backend(backend)
    tag_arr = _normalize_tags(tags, F)
    rec = get_recorder()
    mx = get_metrics()
    t0_wall = time.perf_counter()
    if backend != "numpy" and F > 0:
        res = _simulate_incidence_jit(inc, size, caps, start, net,
                                      use_pallas=(backend == "pallas"),
                                      recorder=rec)
    else:
        res = _simulate_incidence_numpy(inc, size, caps, start, net,
                                        backend, recorder=rec)
    res.tags = tag_arr
    mx.inc("sim.runs")
    mx.inc("sim.flows", F)
    mx.inc("sim.epochs", res.n_epochs)
    mx.observe("sim.wall_s", time.perf_counter() - t0_wall)
    if rec is not None:
        rec.record_flow_sim(res)
    return res


def _simulate_incidence_numpy(inc: FlowIncidence, size, caps, start,
                              net: NetParams, backend: str,
                              recorder=None) -> FlowSimResult:
    F = inc.n_flows
    record = recorder is not None and recorder.link_policy is not None
    if record:
        sel = recorder.link_policy.select(inc, caps)
        max_j = recorder.link_policy.max_epochs
        j_t, j_dt, j_act, j_util = [], [], [], []
        dropped = 0

        def journal(t, dt, act_mask, rates_act):
            nonlocal dropped
            if len(j_t) >= max_j:
                dropped += 1
                return
            j_t.append(t)
            j_dt.append(dt)
            j_act.append(int(act_mask.sum()))
            j_util.append(_journal_util(inc, rates_act, sel))
    remaining = size.copy()
    finish = np.full(F, np.inf)
    finish[size == 0] = start[size == 0]
    edge_bytes = np.zeros(inc.n_edges)
    stalled = np.zeros(F, dtype=bool)
    t = float(start.min()) if F else 0.0
    eps = 1e-9
    n_epochs = 0
    # each epoch completes a flow, admits an arrival batch, or stalls a
    # dead flow set — so 4F + 8 bounds any run
    for _ in range(4 * F + 8):
        open_f = (remaining > eps * np.maximum(size, 1.0)) & ~stalled
        active = open_f & (start <= t * (1 + 1e-12) + 1e-18)
        pending = start[open_f & ~active]
        if not active.any():
            if pending.size == 0:
                break
            t = float(pending.min())
            continue
        n_epochs += 1
        rates = max_min_rates(inc, caps, active=active, backend=backend)
        rates = np.where(active, rates, 0.0)
        dead = active & (rates <= 0)
        if dead.any() and pending.size == 0:
            stalled |= dead
            active &= ~dead
            if not active.any():
                if record:
                    journal(t, 0.0, active, np.zeros(F))
                continue
        Bps = gbps_to_Bps(rates[active])
        dt_fin = float((remaining[active] / np.maximum(Bps, 1e-30)).min())
        dt_arr = float(pending.min() - t) if pending.size else np.inf
        dt = min(dt_fin, dt_arr)
        if record:
            journal(t, dt, active, np.where(active, rates, 0.0))
        moved = gbps_to_Bps(rates) * dt
        remaining = np.maximum(remaining - moved, 0.0)
        np.add.at(edge_bytes, inc.edge,
                  moved[inc.flow] * inc.frac)
        t += dt
        just_done = active & (remaining <= eps * np.maximum(size, 1.0))
        finish[just_done] = t
    else:
        raise RuntimeError(f"flow sim failed to converge ({F} flows)")
    if record:
        recorder.record_epoch_journal(
            j_t, j_dt, j_act, sel,
            np.asarray(j_util).reshape(len(j_t), sel.size),
            dropped=dropped)
    return _finalize_result(inc, size, caps, start, finish, edge_bytes,
                            n_epochs, net)


def _finalize_result(inc: FlowIncidence, size, caps, start, finish,
                     edge_bytes, n_epochs: int, net: NetParams
                     ) -> FlowSimResult:
    lat = path_latency(inc, net)
    fct = finish - start + lat
    done = np.isfinite(finish)
    return FlowSimResult(
        start_s=start, finish_s=finish, fct_s=fct, latency_s=lat,
        size_bytes=size, edge_bytes=edge_bytes, incidence=inc,
        makespan_s=float((finish[done] - start.min()).max())
        if done.any() else 0.0,
        n_epochs=n_epochs)


@functools.lru_cache(maxsize=1)
def _event_loop_jit():
    """Build (once) the jitted whole-simulation loop.

    One ``lax.while_loop`` iteration is one epoch of the reference loop
    in :func:`simulate_incidence`: admit arrivals / detect completion,
    re-solve max-min fair shares with the nested water-filling
    while_loop (:func:`repro.sim.fairshare._waterfill_body`), advance to
    the next start/finish event.  Same constants, same branch structure,
    same freeze tolerances — the golden fixtures hold it to 1e-9.

    ``record`` (static) threads the flight-recorder epoch journal —
    per-epoch clock/dt/active-count plus utilization of the ``sel``
    compressed-edge subset, written into fixed ``max_j``-row arrays with
    masked writes (rows past ``max_j`` are counted, not written, matching
    the reference loop's journal cap).  With ``record=False`` the journal
    keys never enter the loop state, so the compiled graph is exactly the
    pre-telemetry one.
    """
    import jax
    import jax.numpy as jnp

    @functools.partial(jax.jit,
                       static_argnames=("E", "use_pallas", "record",
                                        "max_j"))
    def run(flow, edge, frac, cap_e, size, caps, start, tol, sel=None, *,
            E: int, use_pallas: bool, record: bool = False,
            max_j: int = 0):
        F = size.shape[0]
        eps = 1e-9
        thresh = eps * jnp.maximum(size, 1.0)
        wf_cond, wf_body, wf_init = _waterfill_body(
            flow, edge, frac, cap_e, caps, tol, E, use_pallas)

        def solve(active):
            rates, unfrozen, _, _ = jax.lax.while_loop(
                wf_cond, wf_body, wf_init(active))
            return rates, jnp.logical_not(unfrozen.any())

        def cond(s):
            return jnp.logical_and(~s["done"], s["i"] < 4 * F + 8)

        def body(s):
            t = s["t"]
            open_f = (s["remaining"] > thresh) & ~s["stalled"]
            active = open_f & (start <= t * (1 + 1e-12) + 1e-18)
            pend = open_f & ~active
            has_pending = pend.any()
            pending_min = jnp.where(pend, start, jnp.inf).min()

            def no_active(s):
                # break if nothing is pending, else jump to next arrival
                return dict(s, t=jnp.where(has_pending, pending_min, t),
                            done=s["done"] | ~has_pending)

            def with_active(s):
                rates, conv = solve(active)
                rates = jnp.where(active, rates, 0.0)
                dead = active & (rates <= 0)
                do_stall = dead.any() & ~has_pending
                stall_set = dead & do_stall
                act = active & ~stall_set
                proceed = act.any()
                Bps = rates * (1e9 / 8.0)
                per_dt = jnp.where(
                    act, s["remaining"] / jnp.maximum(Bps, 1e-30),
                    jnp.inf)
                dt_arr = jnp.where(has_pending, pending_min - t, jnp.inf)
                dt = jnp.where(proceed,
                               jnp.minimum(per_dt.min(), dt_arr), 0.0)
                # dt=0 when everything active just stalled — the
                # reference loop's stall-continue epoch
                moved = Bps * dt
                remaining = jnp.maximum(s["remaining"] - moved, 0.0)
                t2 = t + dt
                just_done = act & (remaining <= thresh)
                s2 = dict(
                    s, t=t2, remaining=remaining,
                    finish=jnp.where(just_done, t2, s["finish"]),
                    stalled=s["stalled"] | stall_set,
                    edge_bytes=s["edge_bytes"] + _segment_sum(
                        moved[flow] * frac, edge, E, use_pallas),
                    n_epochs=s["n_epochs"] + 1, ok=s["ok"] & conv)
                if record:
                    idx = jnp.minimum(s["n_epochs"], max_j - 1)
                    okr = s["n_epochs"] < max_j
                    loads = _segment_sum(
                        jnp.where(act, rates, 0.0)[flow] * frac, edge,
                        E, use_pallas)
                    util = jnp.where(cap_e[sel] > 0,
                                     loads[sel] / cap_e[sel], 0.0)
                    s2["j_t"] = s["j_t"].at[idx].set(
                        jnp.where(okr, t, s["j_t"][idx]))
                    s2["j_dt"] = s["j_dt"].at[idx].set(
                        jnp.where(okr, dt, s["j_dt"][idx]))
                    s2["j_act"] = s["j_act"].at[idx].set(
                        jnp.where(okr, act.sum().astype(jnp.int32),
                                  s["j_act"][idx]))
                    s2["j_util"] = s["j_util"].at[idx].set(
                        jnp.where(okr, util, s["j_util"][idx]))
                return s2

            s2 = jax.lax.cond(active.any(), with_active, no_active, s)
            return dict(s2, i=s["i"] + 1)

        state = {
            "t": start.min(),
            "remaining": size,
            "finish": jnp.where(size == 0, start, jnp.inf),
            "stalled": jnp.zeros(F, dtype=bool),
            "edge_bytes": jnp.zeros(E, dtype=size.dtype),
            "n_epochs": jnp.int32(0),
            "i": jnp.int32(0),
            "done": jnp.bool_(False),
            "ok": jnp.bool_(True),
        }
        if record:
            state["j_t"] = jnp.zeros(max_j, dtype=size.dtype)
            state["j_dt"] = jnp.zeros(max_j, dtype=size.dtype)
            state["j_act"] = jnp.zeros(max_j, dtype=jnp.int32)
            state["j_util"] = jnp.zeros((max_j, sel.shape[0]),
                                        dtype=size.dtype)
        out = jax.lax.while_loop(cond, body, state)
        base = (out["finish"], out["edge_bytes"], out["n_epochs"],
                out["done"], out["ok"])
        if record:
            return base + (out["j_t"], out["j_dt"], out["j_act"],
                           out["j_util"])
        return base

    return run


_JIT_SEEN: set = set()


def _simulate_incidence_jit(inc: FlowIncidence, size, caps, start,
                            net: NetParams, use_pallas: bool,
                            recorder=None) -> FlowSimResult:
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from .fairshare import _compress_edges

    tol = 1e-12 * _waterfill_scale(inc, caps)
    # solve over the used-edge subset (identical float sequence — unused
    # edges never saturate) and scatter edge_bytes back at the end
    used, edge_c, cap_c = _compress_edges(inc)
    record = recorder is not None and recorder.link_policy is not None
    if record:
        sel_g = recorder.link_policy.select(inc, caps)
        # selected edges carry load, so they all appear in `used`; keep
        # the intersection anyway (degenerate degraded incidences)
        sel_g = sel_g[np.isin(sel_g, used)]
        sel_c = np.searchsorted(used, sel_g)
        max_j = max(1, recorder.link_policy.max_epochs)
    else:
        sel_c, max_j = None, 0
    key = (size.shape[0], int(used.size), int(inc.flow.shape[0]),
           use_pallas, record, max_j,
           int(sel_c.shape[0]) if record else 0)
    cold = key not in _JIT_SEEN
    _JIT_SEEN.add(key)
    t0_wall = time.perf_counter()
    with enable_x64():
        out = _event_loop_jit()(
            jnp.asarray(inc.flow), jnp.asarray(edge_c),
            jnp.asarray(inc.frac), jnp.asarray(cap_c),
            jnp.asarray(size), jnp.asarray(caps), jnp.asarray(start),
            jnp.asarray(tol),
            jnp.asarray(sel_c) if record else None,
            E=used.size, use_pallas=use_pallas, record=record,
            max_j=max_j)
        finish, used_bytes, n_epochs, done, ok = out[:5]
        if not bool(ok):
            raise RuntimeError("water-filling failed to converge "
                               f"({inc.n_flows} flows, {inc.n_edges} "
                               "edges)")
        if not bool(done):
            raise RuntimeError(
                f"flow sim failed to converge ({inc.n_flows} flows)")
        finish = np.asarray(finish)
        edge_bytes = np.zeros(inc.n_edges)
        edge_bytes[used] = np.asarray(used_bytes)
        n_epochs = int(n_epochs)
        if record:
            j_t, j_dt, j_act, j_util = (np.asarray(a) for a in out[5:9])
            n = min(n_epochs, max_j)
            recorder.record_epoch_journal(
                j_t[:n], j_dt[:n], j_act[:n], sel_g, j_util[:n],
                dropped=n_epochs - n)
    get_metrics().observe(
        "sim.jit_cold_call_s" if cold else "sim.jit_exec_s",
        time.perf_counter() - t0_wall)
    return _finalize_result(inc, size, caps, start, finish, edge_bytes,
                            n_epochs, net)


def simulate_flows(router, flows: "list[FlowSpec]", mode: str = "minimal",
                   rate_cap_gbps: "float | np.ndarray | None" = None,
                   net: NetParams = DEFAULT_NET,
                   backend: str = "numpy") -> FlowSimResult:
    """Simulate a list of :class:`FlowSpec` on one plane's fabric.

    ``router`` is a batched router (``netsim.make_router``); routes come
    from its ``mode`` path spread.  ``rate_cap_gbps`` defaults to the
    topology's per-plane port bandwidth (each flow is one NIC port's
    traffic on this plane).
    """
    dem = flows_to_demands(flows)
    inc = flow_incidence(router, dem, mode)
    if rate_cap_gbps is None:
        rate_cap_gbps = router.topo.port_gbps if hasattr(router, "topo") \
            else router.graph.link_gbps
    tags = [f.tag for f in flows]
    return simulate_incidence(
        inc, np.array([f.size_bytes for f in flows]),
        rate_cap_gbps,
        np.array([f.start_s for f in flows]), net=net, backend=backend,
        tags=tags if any(t is not None for t in tags) else None)


def simulate_demands(router, demands: DemandArrays, flow_time_s: float,
                     mode: str = "minimal", net: NetParams = DEFAULT_NET,
                     backend: str = "numpy",
                     inc: "FlowIncidence | None" = None,
                     start_s=None, tags=None) -> dict:
    """Measured-FCT summary of one traffic matrix at its offered rates.

    Each demand row becomes one flow sized so that at its offered Gbps it
    transfers for exactly ``flow_time_s`` (so under zero contention every
    FCT is ``flow_time_s + alpha`` and slowdown is 1.0).  Returns the flat
    row the sweep/sim suites merge into their artifacts.

    The static path spreads don't depend on the offered rates, so a
    caller sweeping load levels of one scenario can extract ``inc`` once
    and pass it in — it must come from a demand matrix with the same
    (src, dst) rows.

    ``start_s`` (scalar or (F,)) staggers per-flow arrival offsets — e.g.
    dependent collective phases of a co-simulated training step arriving
    as the previous phase drains (:mod:`repro.cosim`).

    ``tags`` (length-F, opaque — e.g. tenant ids) attributes each demand
    row; when given, the returned row gains a ``per_tag`` breakdown of
    flow counts and FCT percentiles keyed by ``str(tag)``.
    """
    gbps = np.asarray(demands.gbps, dtype=np.float64)
    if inc is None:
        inc = flow_incidence(router, demands, mode)
    res = simulate_incidence(inc, gbps_to_Bps(gbps) * flow_time_s, gbps,
                             start_s=start_s, net=net, backend=backend,
                             tags=tags)
    pct = res.fct_percentiles()
    slow = res.slowdown(gbps)
    ok = ~res.stalled
    offered = float(gbps.sum())
    row: dict = {
        "sim_flows": int(inc.n_flows),
        "sim_epochs": res.n_epochs,
        "sim_stalled": int(res.stalled.sum()),
        "sim_delivered_fraction":
            round(res.delivered_gbps() / offered, 6) if offered else 1.0,
        "fct_p50_us": round(pct["p50"] * 1e6, 3)
            if pct["p50"] is not None else None,
        "fct_p95_us": round(pct["p95"] * 1e6, 3)
            if pct["p95"] is not None else None,
        "fct_p99_us": round(pct["p99"] * 1e6, 3)
            if pct["p99"] is not None else None,
        "slowdown_mean": round(float(slow[ok].mean()), 4) if ok.any()
            else None,
        "slowdown_p99": round(float(np.percentile(slow[ok], 99)), 4)
            if ok.any() else None,
    }
    if res.tags is not None:
        per_tag: dict = {}
        for tag in dict.fromkeys(res.tags.tolist()):   # stable order
            m = res.tag_mask(tag) & ok
            fct = res.fct_s[m]
            per_tag[str(tag)] = {
                "flows": int(res.tag_mask(tag).sum()),
                "stalled": int((res.tag_mask(tag) & ~ok).sum()),
                "fct_p50_us": round(float(np.percentile(fct, 50)) * 1e6, 3)
                    if fct.size else None,
                "fct_p99_us": round(float(np.percentile(fct, 99)) * 1e6, 3)
                    if fct.size else None,
            }
        row["per_tag"] = per_tag
    return row


@dataclass
class BatchSimResult:
    """Outcome of a serialized sequence of flow batches.

    ``batch_start_s[k]`` / ``batch_finish_s[k]`` bound batch ``k`` on the
    shared fabric clock; ``makespan_s`` is the finish of the last batch.
    ``results[k]`` is the per-batch :class:`FlowSimResult` (its times are
    on the same shared clock).
    """

    batch_start_s: np.ndarray    # (K,)
    batch_finish_s: np.ndarray   # (K,)
    makespan_s: float
    results: "list[FlowSimResult]"

    def batch_span_s(self) -> np.ndarray:
        return self.batch_finish_s - self.batch_start_s


def simulate_flow_batches(router, batches: "list[list[FlowSpec]]",
                          mode: str = "minimal",
                          rate_cap_gbps: "float | np.ndarray | None" = None,
                          gap_s: float = 0.0,
                          net: NetParams = DEFAULT_NET,
                          backend: str = "numpy") -> BatchSimResult:
    """Run dependent flow batches back-to-back on one plane's fabric.

    Batch ``k`` is admitted at the transfer-finish time of batch ``k-1``
    plus ``gap_s`` (e.g. a per-phase software alpha) — the dependency
    structure of a collective schedule, where one phase's flows cannot
    start until the previous phase has drained.  Within a batch, each
    flow's ``start_s`` is relative to the batch admission time, so
    staggered starts inside a phase still work.  Because batches never
    overlap on the fabric, simulating them independently and accumulating
    the clock is exact.

    Incidence extraction goes through the router's pair-level cache
    (``incidence_cached``): a schedule that reuses (src, dst) pairs
    across phases — every collective does — only walks each pair once,
    instead of re-extracting the full batch every phase
    (the ``incidence.walks`` metric counts the actual engine walks).
    """
    if rate_cap_gbps is None:
        rate_cap_gbps = router.topo.port_gbps if hasattr(router, "topo") \
            else router.graph.link_gbps
    t = 0.0
    starts, finishes, results = [], [], []
    for flows in batches:
        starts.append(t)
        if not flows:
            finishes.append(t)
            results.append(None)
            continue
        dem = flows_to_demands(flows)
        inc = flow_incidence(router, dem, mode, cached=True)
        tags = [f.tag for f in flows]
        res = simulate_incidence(
            inc, np.array([f.size_bytes for f in flows]),
            rate_cap_gbps,
            t + np.array([f.start_s for f in flows]),
            net=net, backend=backend,
            tags=tags if any(tg is not None for tg in tags) else None)
        done = np.isfinite(res.finish_s)
        if not done.all():
            raise RuntimeError("stalled flows in batch: fabric has a "
                               "zero-capacity cut for this phase")
        t = float(res.finish_s.max()) + gap_s
        finishes.append(float(res.finish_s.max()))
        results.append(res)
    return BatchSimResult(
        batch_start_s=np.asarray(starts),
        batch_finish_s=np.asarray(finishes),
        makespan_s=finishes[-1] if finishes else 0.0,
        results=results)
