"""Plane spraying on the simulated fabric (paper §2 on measured FCTs).

A sprayed flow splits into per-plane subflows by the NIC's whole-chunk
round-robin schedule (:func:`repro.core.planes.split_chunks`); every
plane is an identical fabric copy, so each plane runs the same incidence
tensor over its own subflow sizes.  A flow completes when its *slowest*
plane does (max over planes) — plane skew multiplies a plane's transfer
time, a dead plane (skew = inf) re-sprays its bytes over survivors, and
per-chunk overheads are charged per plane.  The uncontended single-flow
case reproduces :func:`repro.core.planes.spray_completion_time` exactly
when all planes are alive (any skew), and for dead planes when per-chunk
overhead is zero and survivors are unskewed (``tests/test_sim.py``):
re-sprayed bytes here are added to the survivor subflows *before*
chunking and skewing — they incur chunk overhead and survivor skew,
where ``planes.py`` charges them as overhead-free unskewed transfer
time.  Under contention the byte-level model is the honest one.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.core.netsim import DEFAULT_NET, NetParams, make_router
from repro.core.planes import SprayConfig
from repro.telemetry import get_metrics
from .events import (FlowSpec, flows_to_demands, path_latency,
                     simulate_incidence)
from .fairshare import flow_incidence


@dataclass
class SprayedSimResult:
    """Per-flow sprayed completion over all planes."""

    completion_s: np.ndarray      # (F,) max-over-planes FCT incl. alpha
    plane_transfer_s: np.ndarray  # (F, n_planes) skewed transfer+overhead
    per_plane_bytes: np.ndarray   # (F, n_planes) bytes after re-spray
    latency_s: np.ndarray         # (F,) path alpha term (charged once)
    stalled: np.ndarray           # (F,) bool

    @property
    def makespan_s(self) -> float:
        ok = self.completion_s[~self.stalled]
        return float(ok.max()) if ok.size else 0.0


def _per_plane_bytes(sizes: np.ndarray, cfg: SprayConfig) -> np.ndarray:
    """(F, n) whole-chunk round-robin split of each flow (vectorized
    :func:`repro.core.planes.split_chunks`)."""
    n = cfg.n_planes
    c = cfg.chunk_bytes
    out = np.zeros((sizes.shape[0], n))
    n_chunks = np.ceil(sizes / c).astype(np.int64)
    full, rem = np.divmod(n_chunks, n)
    out += full[:, None] * c
    # planes 0..rem-1 get one extra chunk each
    extra = np.arange(n)[None, :] < rem[:, None]
    out += extra * c
    # the final (possibly partial) chunk lands on plane (n_chunks-1) % n
    tail = sizes - (n_chunks - 1) * c
    has = n_chunks > 0
    last = (n_chunks - 1) % n
    out[np.arange(sizes.shape[0])[has], last[has]] += tail[has] - c
    return out


def simulate_sprayed(topo, flows: "list[FlowSpec]",
                     cfg: "SprayConfig | None" = None,
                     mode: str = "minimal",
                     plane_skew: "list[float] | None" = None,
                     rate_cap_gbps: "float | None" = None,
                     net: NetParams = DEFAULT_NET,
                     engine: str = "auto", backend: str = "numpy",
                     router=None) -> SprayedSimResult:
    """Simulate sprayed flows across all ``topo.n_planes`` planes.

    ``plane_skew[k] >= 1`` multiplies plane ``k``'s transfer time
    (congested/degraded plane); ``inf`` marks a dead plane whose bytes are
    re-sprayed evenly over the survivors before simulation.  All planes
    share one incidence tensor (identical fabric copies), so the cost is
    ``n_alive`` event-loop runs over the same routes.
    """
    cfg = cfg or SprayConfig(n_planes=topo.n_planes)
    skew = list(plane_skew or [1.0] * cfg.n_planes)
    if len(skew) != cfg.n_planes:
        raise ValueError("plane_skew length mismatch")
    if router is None:
        router = make_router(topo, backend="auto", engine=engine)
    sizes = np.array([f.size_bytes for f in flows], dtype=np.float64)
    starts = np.array([f.start_s for f in flows])
    per_plane = _per_plane_bytes(sizes, cfg)
    alive = [k for k, s in enumerate(skew) if not math.isinf(s)]
    if not alive:
        raise RuntimeError("all planes down")
    dead = [k for k in range(cfg.n_planes) if k not in alive]
    mx = get_metrics()
    mx.inc("spray.plane_sims", len(alive))
    if dead:
        mx.inc("spray.respray_events", len(dead))
        extra = per_plane[:, dead].sum(axis=1) / len(alive)
        per_plane[:, dead] = 0.0
        for k in alive:
            per_plane[:, k] += extra
    inc = flow_incidence(router, flows_to_demands(flows), mode)
    cap = rate_cap_gbps if rate_cap_gbps is not None else topo.port_gbps
    F = sizes.shape[0]
    plane_t = np.zeros((F, cfg.n_planes))
    stalled = np.zeros(F, dtype=bool)
    for k in alive:
        res = simulate_incidence(inc, per_plane[:, k], cap,
                                 start_s=starts, net=net, backend=backend)
        n_chunks = np.ceil(per_plane[:, k] / cfg.chunk_bytes)
        transfer = res.transfer_s() + n_chunks * cfg.per_chunk_overhead_s
        plane_t[:, k] = transfer * skew[k]
        stalled |= res.stalled
    lat = path_latency(inc, net)
    completion = plane_t.max(axis=1) + lat
    completion[stalled] = np.inf
    return SprayedSimResult(completion_s=completion,
                            plane_transfer_s=plane_t,
                            per_plane_bytes=per_plane,
                            latency_s=lat, stalled=stalled)
