"""Plane spraying on the simulated fabric (paper §2 on measured FCTs).

A sprayed flow splits into per-plane subflows by the NIC's whole-chunk
round-robin schedule (:func:`repro.core.planes.split_chunks`); every
plane is an identical fabric copy, so each plane runs the same incidence
tensor over its own subflow sizes.  A flow completes when its *slowest*
plane does (max over planes) — plane skew multiplies a plane's transfer
time, a dead plane (skew = inf) re-sprays its bytes over survivors, and
per-chunk overheads are charged per plane.  The uncontended single-flow
case reproduces :func:`repro.core.planes.spray_completion_time` exactly
when all planes are alive (any skew), and for dead planes when per-chunk
overhead is zero and survivors are unskewed (``tests/test_sim.py``):
re-sprayed bytes here are added to the survivor subflows *before*
chunking and skewing — they incur chunk overhead and survivor skew,
where ``planes.py`` charges them as overhead-free unskewed transfer
time.  Under contention the byte-level model is the honest one.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.core.netsim import DEFAULT_NET, NetParams, make_router
from repro.core.planes import SprayConfig
from repro.telemetry import get_metrics
from .events import (FlowSpec, flows_to_demands, path_latency,
                     simulate_incidence)
from .fairshare import flow_incidence


@dataclass
class SprayedSimResult:
    """Per-flow sprayed completion over all planes."""

    completion_s: np.ndarray      # (F,) max-over-planes FCT incl. alpha
    plane_transfer_s: np.ndarray  # (F, n_planes) skewed transfer+overhead
    per_plane_bytes: np.ndarray   # (F, n_planes) bytes after re-spray
    latency_s: np.ndarray         # (F,) path alpha term (charged once)
    stalled: np.ndarray           # (F,) bool

    @property
    def makespan_s(self) -> float:
        ok = self.completion_s[~self.stalled]
        return float(ok.max()) if ok.size else 0.0


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer, vectorized over uint64 (wraparound is the
    point — numpy unsigned arithmetic is modular)."""
    x = x.astype(np.uint64, copy=True)
    x += np.uint64(0x9E3779B97F4A7C15)
    x ^= x >> np.uint64(30)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(27)
    x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(31)
    return x


def flowlet_split(sizes: np.ndarray, n_buckets: int, flowlet_bytes: float,
                  seed: int = 0, alive: "np.ndarray | None" = None
                  ) -> "tuple[np.ndarray, np.ndarray]":
    """Hash each flow's flowlets over ``n_buckets`` planes/layers.

    FatPaths-style flowlet switching: flow ``i`` is cut into
    ``ceil(sizes[i] / flowlet_bytes)`` flowlets (the last one partial)
    and flowlet ``j`` lands on bucket ``mix64(flow, j, seed) %
    n_buckets``.  When ``alive`` marks dead buckets, only the flowlets
    that hashed onto a dead bucket re-hash (salted) over the alive set —
    every alive-bucket assignment is *identical* to the healthy split,
    which is the stability property that makes flowlet reroute local
    (pinned by ``tests/test_sim.py``).

    Returns ``(bytes (F, n_buckets), counts (F, n_buckets))``.
    """
    sizes = np.asarray(sizes, dtype=np.float64)
    if flowlet_bytes <= 0:
        raise ValueError("flowlet_bytes must be positive")
    if n_buckets < 1:
        raise ValueError("n_buckets must be >= 1")
    if alive is None:
        alive = np.ones(n_buckets, dtype=bool)
    alive = np.asarray(alive, dtype=bool)
    if alive.shape != (n_buckets,):
        raise ValueError("alive mask length mismatch")
    if not alive.any():
        raise RuntimeError("all buckets down")
    F = sizes.shape[0]
    n_fl = np.ceil(sizes / flowlet_bytes).astype(np.int64)
    tot = int(n_fl.sum())
    bytes_out = np.zeros((F, n_buckets))
    counts = np.zeros((F, n_buckets), dtype=np.int64)
    if tot == 0:
        return bytes_out, counts
    flow_of = np.repeat(np.arange(F, dtype=np.uint64), n_fl)
    offsets = np.concatenate([[0], np.cumsum(n_fl)[:-1]])
    idx = (np.arange(tot, dtype=np.uint64)
           - np.repeat(offsets, n_fl).astype(np.uint64))
    h = _mix64(_mix64(flow_of ^ (np.uint64(seed) * np.uint64(0x9E3779B1)))
               ^ idx)
    b = (h % np.uint64(n_buckets)).astype(np.int64)
    dead_sel = ~alive[b]
    if dead_sel.any():
        alive_ids = np.flatnonzero(alive)
        h2 = _mix64(h[dead_sel] ^ np.uint64(0xD6E8FEB86659FD93))
        b[dead_sel] = alive_ids[(h2 % np.uint64(alive_ids.shape[0]))
                                .astype(np.int64)]
        get_metrics().inc("spray.flowlet_rehashes", int(dead_sel.sum()))
    sizes_fl = np.full(tot, float(flowlet_bytes))
    has = n_fl > 0
    last_pos = (np.cumsum(n_fl) - 1)[has]
    sizes_fl[last_pos] = sizes[has] - (n_fl[has] - 1) * flowlet_bytes
    np.add.at(bytes_out, (flow_of.astype(np.int64), b), sizes_fl)
    np.add.at(counts, (flow_of.astype(np.int64), b), 1)
    return bytes_out, counts


def _per_plane_bytes(sizes: np.ndarray, cfg: SprayConfig) -> np.ndarray:
    """(F, n) whole-chunk round-robin split of each flow (vectorized
    :func:`repro.core.planes.split_chunks`)."""
    n = cfg.n_planes
    c = cfg.chunk_bytes
    out = np.zeros((sizes.shape[0], n))
    n_chunks = np.ceil(sizes / c).astype(np.int64)
    full, rem = np.divmod(n_chunks, n)
    out += full[:, None] * c
    # planes 0..rem-1 get one extra chunk each
    extra = np.arange(n)[None, :] < rem[:, None]
    out += extra * c
    # the final (possibly partial) chunk lands on plane (n_chunks-1) % n
    tail = sizes - (n_chunks - 1) * c
    has = n_chunks > 0
    last = (n_chunks - 1) % n
    out[np.arange(sizes.shape[0])[has], last[has]] += tail[has] - c
    return out


def simulate_sprayed(topo, flows: "list[FlowSpec]",
                     cfg: "SprayConfig | None" = None,
                     mode: str = "minimal",
                     plane_skew: "list[float] | None" = None,
                     rate_cap_gbps: "float | None" = None,
                     net: NetParams = DEFAULT_NET,
                     engine: str = "auto", backend: str = "numpy",
                     router=None, granularity: str = "chunk",
                     flowlet_bytes: "float | None" = None,
                     flowlet_seed: int = 0) -> SprayedSimResult:
    """Simulate sprayed flows across all ``topo.n_planes`` planes.

    ``plane_skew[k] >= 1`` multiplies plane ``k``'s transfer time
    (congested/degraded plane); ``inf`` marks a dead plane whose bytes are
    re-sprayed evenly over the survivors before simulation.  All planes
    share one incidence tensor (identical fabric copies), so the cost is
    ``n_alive`` event-loop runs over the same routes.

    ``granularity`` selects the plane split: ``"chunk"`` (default) is the
    NIC's deterministic whole-chunk round-robin; ``"flowlet"`` hashes
    ``flowlet_bytes``-sized flowlets over the planes
    (:func:`flowlet_split`), and dead planes only re-hash the flowlets
    that landed on them — surviving assignments are stable, so a plane
    death perturbs exactly the traffic that was on the dead plane.
    """
    cfg = cfg or SprayConfig(n_planes=topo.n_planes)
    skew = list(plane_skew or [1.0] * cfg.n_planes)
    if len(skew) != cfg.n_planes:
        raise ValueError("plane_skew length mismatch")
    if granularity not in ("chunk", "flowlet"):
        raise ValueError(f"unknown spray granularity {granularity!r}")
    if router is None:
        router = make_router(topo, backend="auto", engine=engine)
    sizes = np.array([f.size_bytes for f in flows], dtype=np.float64)
    starts = np.array([f.start_s for f in flows])
    alive = [k for k, s in enumerate(skew) if not math.isinf(s)]
    if not alive:
        raise RuntimeError("all planes down")
    dead = [k for k in range(cfg.n_planes) if k not in alive]
    mx = get_metrics()
    mx.inc("spray.plane_sims", len(alive))
    if granularity == "flowlet":
        alive_mask = np.zeros(cfg.n_planes, dtype=bool)
        alive_mask[alive] = True
        fl_bytes = flowlet_bytes if flowlet_bytes is not None \
            else cfg.chunk_bytes
        per_plane, fl_counts = flowlet_split(sizes, cfg.n_planes, fl_bytes,
                                             seed=flowlet_seed,
                                             alive=alive_mask)
        mx.inc("spray.flowlets", int(fl_counts.sum()))
        if dead:
            mx.inc("spray.respray_events", len(dead))
    else:
        per_plane = _per_plane_bytes(sizes, cfg)
        if dead:
            mx.inc("spray.respray_events", len(dead))
            extra = per_plane[:, dead].sum(axis=1) / len(alive)
            per_plane[:, dead] = 0.0
            for k in alive:
                per_plane[:, k] += extra
    inc = flow_incidence(router, flows_to_demands(flows), mode)
    cap = rate_cap_gbps if rate_cap_gbps is not None else topo.port_gbps
    F = sizes.shape[0]
    plane_t = np.zeros((F, cfg.n_planes))
    stalled = np.zeros(F, dtype=bool)
    for k in alive:
        res = simulate_incidence(inc, per_plane[:, k], cap,
                                 start_s=starts, net=net, backend=backend)
        if granularity == "flowlet":
            n_chunks = fl_counts[:, k].astype(np.float64)
        else:
            n_chunks = np.ceil(per_plane[:, k] / cfg.chunk_bytes)
        transfer = res.transfer_s() + n_chunks * cfg.per_chunk_overhead_s
        plane_t[:, k] = transfer * skew[k]
        stalled |= res.stalled
    lat = path_latency(inc, net)
    completion = plane_t.max(axis=1) + lat
    completion[stalled] = np.inf
    return SprayedSimResult(completion_s=completion,
                            plane_transfer_s=plane_t,
                            per_plane_bytes=per_plane,
                            latency_s=lat, stalled=stalled)
