"""Batched max-min fair bandwidth allocation (the flow simulator's core).

A routed flow set becomes a *flow-incidence tensor*: COO arrays
``(flow, edge, frac)`` where ``frac`` is the fraction of flow ``f``'s rate
crossing directed edge ``e`` — extracted from the routing engines'
own walk code (``VectorizedHyperXRouter.incidence`` /
``GraphRouter.incidence``), so the simulator's load accounting is the
analytic engines' load accounting by construction (pinned to 1e-6 by
``tests/test_sim.py`` and ``results/BENCH_flow_sim.json``).

Fair shares come from classic progressive water-filling: all unfrozen
flows raise their rate at the same pace until an edge saturates (freezing
every flow crossing it) or a flow hits its demand cap, repeated until all
flows freeze.  Three solver paths compute the identical fixpoint:

``numpy``   the reference: a Python round loop of ``np.bincount``
            scatter-adds — the pre-jit solver the golden fixtures pin
            (``tests/golden/fairshare_golden.json``).
``jax``     the whole solve as ONE jitted ``lax.while_loop`` over sparse
            COO segment ops (``jax.ops.segment_sum``) — no Python
            round-trip per round, float64 via a ``jax.experimental
            .enable_x64`` scope regardless of the global flag.  This is
            the 65K-NIC path (``results/BENCH_sim_scale.json``).
``pallas``  the same while_loop with the segment reductions lowered to
            the Pallas one-hot contraction kernels
            (:mod:`repro.kernels.segment_fairshare`), interpreter-mode
            on CPU (the ref fallback), compiled on real TPUs.
``auto``    jax when 64-bit mode is on (the
            :func:`~repro.core.routing_vec.get_backend` contract),
            numpy otherwise.

All paths agree to 1e-9 (``tests/test_fairshare_props.py`` /
``tests/test_fairshare_golden.py``).  All rates and capacities are Gbps;
``frac`` is dimensionless.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

from repro.core.routing_vec import DemandArrays, _scatter_add, get_backend
from repro.telemetry import get_metrics

FAIRSHARE_BACKENDS = ("numpy", "jax", "pallas", "auto")


@dataclass
class FlowIncidence:
    """Per-flow edge usage of a routed flow set, plus edge capacities.

    ``flow`` / ``edge`` / ``frac`` are parallel COO arrays (coalesced:
    one entry per (flow, edge) pair); ``capacity`` is the per-edge Gbps of
    the router that produced the incidence.  ``sum_e frac[f, e]`` is flow
    ``f``'s expected switch-switch hop count (every unit of flow crosses
    each hop of its path spread once).
    """

    flow: np.ndarray       # (NNZ,) int64 flow index
    edge: np.ndarray       # (NNZ,) int64 directed-edge id / edge slot
    frac: np.ndarray       # (NNZ,) float64 fraction of the flow's rate
    n_flows: int
    capacity: np.ndarray   # (E,) Gbps

    @property
    def n_edges(self) -> int:
        return int(self.capacity.shape[0])

    @property
    def nnz(self) -> int:
        return int(self.flow.shape[0])

    def loads(self, rates_gbps: np.ndarray) -> np.ndarray:
        """(E,) offered Gbps per edge when flow ``f`` runs at
        ``rates_gbps[f]`` — the steady-state link loads."""
        out = np.zeros(self.n_edges)
        np.add.at(out, self.edge, np.asarray(rates_gbps)[self.flow]
                  * self.frac)
        return out

    def utilization(self, rates_gbps: np.ndarray) -> np.ndarray:
        l = self.loads(rates_gbps)
        with np.errstate(divide="ignore", invalid="ignore"):
            return np.where(self.capacity > 0, l / self.capacity, 0.0)

    def switch_hops(self) -> np.ndarray:
        """(F,) expected switch-switch hops per flow (0 for flows with no
        fabric path, e.g. src == dst)."""
        out = np.zeros(self.n_flows)
        np.add.at(out, self.flow, self.frac)
        return out

    def bottleneck_gbps(self) -> np.ndarray:
        """(F,) max rate each flow could sustain *alone* on an idle
        fabric: ``min_e capacity[e] / frac[f, e]`` over its edges
        (inf for flows with no fabric path)."""
        out = np.full(self.n_flows, np.inf)
        with np.errstate(divide="ignore"):
            per_entry = self.capacity[self.edge] / self.frac
        np.minimum.at(out, self.flow, per_entry)
        return out

    def edge_share(self, edges: np.ndarray) -> np.ndarray:
        """(F,) fraction of each flow's rate crossing any edge in
        ``edges`` (clipped to 1) — first-order stalled share when those
        edges fail before re-routing (:mod:`repro.sim.failures`)."""
        sel = np.isin(self.edge, edges)
        out = np.zeros(self.n_flows)
        np.add.at(out, self.flow[sel], self.frac[sel])
        return np.minimum(out, 1.0)


def flow_incidence(router, demands: DemandArrays,
                   mode: str = "minimal",
                   cached: bool = False) -> FlowIncidence:
    """Extract the per-flow incidence tensor from a batched router
    (:func:`repro.core.netsim.make_router` product: MPHX array engine or
    generic graph engine — both expose ``incidence`` and
    ``edge_capacity``).

    ``cached=True`` routes the extraction through the router's pair-level
    incidence cache (``incidence_cached``): only (src, dst) pairs not
    seen before are walked, so repeated flow sets (collective phases,
    epoch re-solves) skip the ~20x-route-cost extraction entirely.
    """
    if cached and hasattr(router, "incidence_cached"):
        flow, edge, frac = router.incidence_cached(demands, mode)
    else:
        flow, edge, frac = router.incidence(demands, mode)
    return FlowIncidence(flow, edge, frac, demands.n,
                         np.asarray(router.edge_capacity(),
                                    dtype=np.float64))


def resolve_sim_backend(backend: str = "numpy") -> str:
    """Normalize a fair-share solver backend name (``auto`` follows the
    router engines' :func:`get_backend` contract: jax only under x64)."""
    if backend not in FAIRSHARE_BACKENDS:
        raise ValueError(f"unknown fairshare backend {backend!r}; "
                         f"expected one of {FAIRSHARE_BACKENDS}")
    if backend == "auto":
        return get_backend("auto")[0]       # "jax" under x64, else "numpy"
    return backend


def _waterfill_scale(inc: FlowIncidence, caps: np.ndarray) -> float:
    return float(max(np.max(inc.capacity, initial=0.0),
                     caps.max() if caps.size else 0.0, 1.0))


def max_min_rates(inc: FlowIncidence, rate_caps_gbps: np.ndarray,
                  active: "np.ndarray | None" = None,
                  backend: str = "numpy") -> np.ndarray:
    """(F,) max-min fair rates by progressive water-filling.

    Every active flow's rate rises at unit pace until either an edge
    saturates (``sum_f frac * rate == capacity`` — all flows crossing it
    freeze) or the flow reaches its own ``rate_caps_gbps`` demand cap.
    Inactive flows hold rate 0 and consume nothing.  Terminates in at most
    F + E rounds (each round freezes a flow or saturates an edge); rounds
    are O(NNZ) segment reductions on the selected ``backend`` (see the
    module docstring for the numpy/jax/pallas paths).
    """
    F = inc.n_flows
    caps = np.broadcast_to(np.asarray(rate_caps_gbps, dtype=np.float64),
                           (F,))
    if not np.all(np.isfinite(caps)):
        raise ValueError("rate caps must be finite (a flow with no fabric "
                         "path would otherwise fill forever)")
    if active is None:
        active = np.ones(F, dtype=bool)
    backend = resolve_sim_backend(backend)
    if F == 0:
        return np.zeros(0)
    if backend == "numpy":
        return _max_min_rates_reference(inc, caps, active)
    tol = 1e-12 * _waterfill_scale(inc, caps)
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    used, edge_c, cap_c = _compress_edges(inc)
    with enable_x64():
        rates, converged, rounds = _waterfill_jit()(
            jnp.asarray(inc.flow), jnp.asarray(edge_c),
            jnp.asarray(inc.frac), jnp.asarray(cap_c),
            jnp.asarray(caps), jnp.asarray(active), jnp.asarray(tol),
            E=used.size, use_pallas=(backend == "pallas"))
        if not bool(converged):
            raise RuntimeError("water-filling failed to converge "
                               f"({F} flows, {inc.n_edges} edges)")
        mx = get_metrics()
        if mx.enabled:
            mx.inc("waterfill.solves")
            mx.inc("waterfill.rounds", int(rounds))
        return np.asarray(rates)


# ---------------------------------------------------------------------------
# Reference path (pre-jit solver — the golden fixtures pin this loop)
# ---------------------------------------------------------------------------


def _max_min_rates_reference(inc: FlowIncidence, caps: np.ndarray,
                             active: np.ndarray) -> np.ndarray:
    xp = np
    F, E = inc.n_flows, inc.n_edges
    flow = xp.asarray(inc.flow)
    edge = xp.asarray(inc.edge)
    frac = xp.asarray(inc.frac)
    cap_e = xp.asarray(inc.capacity)
    caps_x = xp.asarray(caps)
    tol = 1e-12 * _waterfill_scale(inc, caps)
    rates = xp.zeros(F)
    unfrozen = xp.asarray(active.copy())
    cap_left = cap_e
    rounds = 0
    for _ in range(F + E + 2):
        if not bool(unfrozen.any()):
            break
        rounds += 1
        live = xp.where(unfrozen[flow], frac, 0.0)
        wsum = _scatter_add(xp, xp.zeros(E), edge, live)
        open_e = wsum > tol
        delta_e = xp.where(open_e, cap_left / xp.where(open_e, wsum, 1.0),
                           xp.inf)
        delta_f = xp.where(unfrozen, caps_x - rates, xp.inf)
        delta = float(xp.minimum(delta_e.min() if E else xp.inf,
                                 delta_f.min()))
        delta = max(delta, 0.0)
        rates = xp.where(unfrozen, rates + delta, rates)
        cap_left = cap_left - delta * wsum
        sat = open_e & (cap_left <= tol)
        on_sat = _scatter_add(xp, xp.zeros(F), flow,
                              xp.where(sat[edge], frac, 0.0)) > 0
        capped = rates >= caps_x - tol
        unfrozen = unfrozen & ~on_sat & ~capped
    else:
        raise RuntimeError("water-filling failed to converge "
                           f"({F} flows, {E} edges)")
    mx = get_metrics()
    if mx.enabled:
        mx.inc("waterfill.solves")
        mx.inc("waterfill.rounds", rounds)
    return np.asarray(rates)


# ---------------------------------------------------------------------------
# In-jit path: the whole solve as one lax.while_loop over segment ops
# ---------------------------------------------------------------------------


def _compress_edges(inc: FlowIncidence):
    """Drop edges no flow crosses before solving.

    An edge with zero incidence weight can never saturate (``wsum = 0``
    keeps it out of ``open_e``), so it contributes nothing to any round's
    ``delta`` — the solve over the used-edge subset runs the *identical*
    float sequence.  Fabric edge sets are much larger than any one flow
    set's footprint (a 65K-NIC fabric has ~72K directed edges; a
    neighbor-shift flow set touches ~2 per flow), so this is the main
    constant-factor win of the jit paths.  Returns ``(used_edge_ids,
    remapped_edge_col, used_capacities)``.
    """
    used, edge_c = np.unique(inc.edge, return_inverse=True)
    return used, edge_c.astype(np.int64), inc.capacity[used]


def _segment_sum(vals, ids, n_segments: int, use_pallas: bool):
    """Backend-selected COO scatter-add (traced inside jit)."""
    if use_pallas:
        from repro.kernels.segment_fairshare import segment_sum

        return segment_sum(vals, ids, n_segments)
    import jax

    return jax.ops.segment_sum(vals, ids, num_segments=n_segments)


def _waterfill_body(flow, edge, frac, cap_e, caps, tol, E: int,
                    use_pallas: bool):
    """(cond, body, init-builder) of the water-filling while_loop —
    shared by the standalone solver and the in-jit event loop."""
    import jax.numpy as jnp

    F = caps.shape[0]

    def cond(state):
        _, unfrozen, _, i = state
        return jnp.logical_and(unfrozen.any(), i < F + E + 2)

    def body(state):
        rates, unfrozen, cap_left, i = state
        live = jnp.where(unfrozen[flow], frac, 0.0)
        wsum = _segment_sum(live, edge, E, use_pallas)
        open_e = wsum > tol
        delta_e = jnp.where(open_e,
                            cap_left / jnp.where(open_e, wsum, 1.0),
                            jnp.inf)
        delta_f = jnp.where(unfrozen, caps - rates, jnp.inf)
        d_edges = delta_e.min() if E else jnp.inf
        delta = jnp.maximum(jnp.minimum(d_edges, delta_f.min()), 0.0)
        rates = jnp.where(unfrozen, rates + delta, rates)
        cap_left = cap_left - delta * wsum
        sat = open_e & (cap_left <= tol)
        on_sat = _segment_sum(jnp.where(sat[edge], frac, 0.0), flow, F,
                              use_pallas) > 0
        capped = rates >= caps - tol
        return rates, unfrozen & ~on_sat & ~capped, cap_left, i + 1

    def init(active):
        return (jnp.zeros(F, dtype=caps.dtype), active, cap_e,
                jnp.int32(0))

    return cond, body, init


@functools.lru_cache(maxsize=1)
def _waterfill_jit():
    """Build (once) the jitted standalone solve:
    ``(rates, converged, rounds)`` (``rounds`` = while-loop iterations —
    the telemetry layer's ``waterfill.rounds`` counter; numerically
    inert, it was always part of the loop state)."""
    import jax
    import jax.numpy as jnp

    @functools.partial(jax.jit, static_argnames=("E", "use_pallas"))
    def solve(flow, edge, frac, cap_e, caps, active, tol, *,
              E: int, use_pallas: bool):
        cond, body, init = _waterfill_body(flow, edge, frac, cap_e, caps,
                                           tol, E, use_pallas)
        rates, unfrozen, _, i = jax.lax.while_loop(cond, body,
                                                   init(active))
        return rates, jnp.logical_not(unfrozen.any()), i

    return solve
