"""Batched max-min fair bandwidth allocation (the flow simulator's core).

A routed flow set becomes a *flow-incidence tensor*: COO arrays
``(flow, edge, frac)`` where ``frac`` is the fraction of flow ``f``'s rate
crossing directed edge ``e`` — extracted from the routing engines'
own walk code (``VectorizedHyperXRouter.incidence`` /
``GraphRouter.incidence``), so the simulator's load accounting is the
analytic engines' load accounting by construction (pinned to 1e-6 by
``tests/test_sim.py`` and ``results/BENCH_flow_sim.json``).

Fair shares come from classic progressive water-filling: all unfrozen
flows raise their rate at the same pace until an edge saturates (freezing
every flow crossing it) or a flow hits its demand cap, repeated until all
flows freeze.  Each round is a handful of scatter-adds over the COO
entries — ``numpy`` or ``jax.numpy`` backend, the same
:func:`~repro.core.routing_vec.get_backend` contract as the routing
engines (``auto`` picks jax only under x64, preserving the equivalence
tolerances).

All rates and capacities are Gbps; ``frac`` is dimensionless.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.routing_vec import DemandArrays, _scatter_add, get_backend


@dataclass
class FlowIncidence:
    """Per-flow edge usage of a routed flow set, plus edge capacities.

    ``flow`` / ``edge`` / ``frac`` are parallel COO arrays (coalesced:
    one entry per (flow, edge) pair); ``capacity`` is the per-edge Gbps of
    the router that produced the incidence.  ``sum_e frac[f, e]`` is flow
    ``f``'s expected switch-switch hop count (every unit of flow crosses
    each hop of its path spread once).
    """

    flow: np.ndarray       # (NNZ,) int64 flow index
    edge: np.ndarray       # (NNZ,) int64 directed-edge id / edge slot
    frac: np.ndarray       # (NNZ,) float64 fraction of the flow's rate
    n_flows: int
    capacity: np.ndarray   # (E,) Gbps

    @property
    def n_edges(self) -> int:
        return int(self.capacity.shape[0])

    def loads(self, rates_gbps: np.ndarray) -> np.ndarray:
        """(E,) offered Gbps per edge when flow ``f`` runs at
        ``rates_gbps[f]`` — the steady-state link loads."""
        out = np.zeros(self.n_edges)
        np.add.at(out, self.edge, np.asarray(rates_gbps)[self.flow]
                  * self.frac)
        return out

    def utilization(self, rates_gbps: np.ndarray) -> np.ndarray:
        l = self.loads(rates_gbps)
        with np.errstate(divide="ignore", invalid="ignore"):
            return np.where(self.capacity > 0, l / self.capacity, 0.0)

    def switch_hops(self) -> np.ndarray:
        """(F,) expected switch-switch hops per flow (0 for flows with no
        fabric path, e.g. src == dst)."""
        out = np.zeros(self.n_flows)
        np.add.at(out, self.flow, self.frac)
        return out

    def bottleneck_gbps(self) -> np.ndarray:
        """(F,) max rate each flow could sustain *alone* on an idle
        fabric: ``min_e capacity[e] / frac[f, e]`` over its edges
        (inf for flows with no fabric path)."""
        out = np.full(self.n_flows, np.inf)
        with np.errstate(divide="ignore"):
            per_entry = self.capacity[self.edge] / self.frac
        np.minimum.at(out, self.flow, per_entry)
        return out

    def edge_share(self, edges: np.ndarray) -> np.ndarray:
        """(F,) fraction of each flow's rate crossing any edge in
        ``edges`` (clipped to 1) — first-order stalled share when those
        edges fail before re-routing (:mod:`repro.sim.failures`)."""
        sel = np.isin(self.edge, edges)
        out = np.zeros(self.n_flows)
        np.add.at(out, self.flow[sel], self.frac[sel])
        return np.minimum(out, 1.0)


def flow_incidence(router, demands: DemandArrays,
                   mode: str = "minimal") -> FlowIncidence:
    """Extract the per-flow incidence tensor from a batched router
    (:func:`repro.core.netsim.make_router` product: MPHX array engine or
    generic graph engine — both expose ``incidence`` and
    ``edge_capacity``)."""
    flow, edge, frac = router.incidence(demands, mode)
    return FlowIncidence(flow, edge, frac, demands.n,
                         np.asarray(router.edge_capacity(),
                                    dtype=np.float64))


def max_min_rates(inc: FlowIncidence, rate_caps_gbps: np.ndarray,
                  active: "np.ndarray | None" = None,
                  backend: str = "numpy") -> np.ndarray:
    """(F,) max-min fair rates by progressive water-filling.

    Every active flow's rate rises at unit pace until either an edge
    saturates (``sum_f frac * rate == capacity`` — all flows crossing it
    freeze) or the flow reaches its own ``rate_caps_gbps`` demand cap.
    Inactive flows hold rate 0 and consume nothing.  Terminates in at most
    F + E rounds (each round freezes a flow or saturates an edge); rounds
    are O(NNZ) scatter-adds on the selected backend.
    """
    _, xp = get_backend(backend)
    F, E = inc.n_flows, inc.n_edges
    caps = np.broadcast_to(np.asarray(rate_caps_gbps, dtype=np.float64),
                           (F,))
    if not np.all(np.isfinite(caps)):
        raise ValueError("rate caps must be finite (a flow with no fabric "
                         "path would otherwise fill forever)")
    if active is None:
        active = np.ones(F, dtype=bool)
    flow = xp.asarray(inc.flow)
    edge = xp.asarray(inc.edge)
    frac = xp.asarray(inc.frac)
    cap_e = xp.asarray(inc.capacity)
    caps_x = xp.asarray(caps)
    scale = float(max(np.max(inc.capacity, initial=0.0),
                      caps.max() if F else 0.0, 1.0))
    tol = 1e-12 * scale
    rates = xp.zeros(F)
    unfrozen = xp.asarray(active.copy())
    cap_left = cap_e
    for _ in range(F + E + 2):
        if not bool(unfrozen.any()):
            break
        live = xp.where(unfrozen[flow], frac, 0.0)
        wsum = _scatter_add(xp, xp.zeros(E), edge, live)
        open_e = wsum > tol
        delta_e = xp.where(open_e, cap_left / xp.where(open_e, wsum, 1.0),
                           xp.inf)
        delta_f = xp.where(unfrozen, caps_x - rates, xp.inf)
        delta = float(xp.minimum(delta_e.min() if E else xp.inf,
                                 delta_f.min()))
        delta = max(delta, 0.0)
        rates = xp.where(unfrozen, rates + delta, rates)
        cap_left = cap_left - delta * wsum
        sat = open_e & (cap_left <= tol)
        on_sat = _scatter_add(xp, xp.zeros(F), flow,
                              xp.where(sat[edge], frac, 0.0)) > 0
        capped = rates >= caps_x - tol
        unfrozen = unfrozen & ~on_sat & ~capped
    else:
        raise RuntimeError("water-filling failed to converge "
                           f"({F} flows, {E} edges)")
    return np.asarray(rates)
