"""repro.core — the paper's contribution: Multi-Plane HyperX topology,
cost model, routing, flow-level simulation, plane spraying, and the
JAX-side realization (plane-decomposed collectives + mesh mapping)."""

from .topology import LinkClass, SwitchGraph, SwitchModel, Topology, DEFAULT_SWITCH
from .hyperx import MPHX, flattened_butterfly, table2_mphx_rows
from .fattree import MultiPlaneFatTree, ThreeTierFatTree
from .dragonfly import Dragonfly, DragonflyPlus, frontier_flattening_example
from .cost import (CostModel, CostReport, DEFAULT_COST, PAPER_TABLE2,
                   cost_report, table2, table2_topologies)
from .planes import (SprayConfig, plane_chunk_fractions, split_chunks,
                     spray_completion_time)
from .routing_vec import (ArrayLinkLoads, DemandArrays, EdgeIndex,
                          VectorizedHyperXRouter, demands_from_dict)
from .routing_graph import (CSRGraph, GraphLinkLoads, GraphRouter,
                            graph_hotspot_demands, graph_reverse_demands,
                            graph_ring_demands, graph_shift_demands,
                            graph_uniform_demands)
from . import netsim, routing, routing_graph, routing_vec

__all__ = [
    "LinkClass", "SwitchGraph", "SwitchModel", "Topology", "DEFAULT_SWITCH",
    "MPHX", "flattened_butterfly", "table2_mphx_rows",
    "MultiPlaneFatTree", "ThreeTierFatTree",
    "Dragonfly", "DragonflyPlus", "frontier_flattening_example",
    "CostModel", "CostReport", "DEFAULT_COST", "PAPER_TABLE2",
    "cost_report", "table2", "table2_topologies",
    "SprayConfig", "plane_chunk_fractions", "split_chunks",
    "spray_completion_time",
    "ArrayLinkLoads", "DemandArrays", "EdgeIndex", "VectorizedHyperXRouter",
    "demands_from_dict",
    "CSRGraph", "GraphLinkLoads", "GraphRouter",
    "graph_hotspot_demands", "graph_reverse_demands", "graph_ring_demands",
    "graph_shift_demands", "graph_uniform_demands",
    "netsim", "routing", "routing_graph", "routing_vec",
]
