"""Multi-plane traffic spraying model (paper §2, §5.2).

A multi-port NIC splits each flow into chunks and sprays them round-robin
across its n plane ports.  Requirements the paper calls out: the NIC needs
switching functionality + out-of-order RX (chunks complete out of order
across planes).  This module models the *effective* bandwidth and completion
time of sprayed flows, including plane skew and chunking overhead, and
provides the deterministic chunk schedule used by
:mod:`repro.core.collectives` to realize spraying as chunk-interleaved
JAX collectives.
"""

from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class SprayConfig:
    n_planes: int = 8
    chunk_bytes: int = 1 << 17          # 128 KiB spray granularity
    per_chunk_overhead_s: float = 200e-9  # header/DMA per chunk
    reorder_window_chunks: int = 64     # RX out-of-order window

    def __post_init__(self):
        if not (1 <= self.n_planes <= 8):
            raise ValueError("paper assumes 1 <= n <= 8 planes")
        if self.chunk_bytes <= 0:
            raise ValueError("chunk_bytes must be positive")


def split_chunks(total_bytes: int, cfg: SprayConfig) -> list[int]:
    """Bytes assigned to each plane (round-robin whole chunks, remainder to
    plane 0...).  sum == total_bytes, and balance within one chunk."""
    n = cfg.n_planes
    n_chunks = math.ceil(total_bytes / cfg.chunk_bytes)
    per_plane = [0] * n
    remaining = total_bytes
    for i in range(n_chunks):
        take = min(cfg.chunk_bytes, remaining)
        per_plane[i % n] += take
        remaining -= take
    assert remaining == 0
    return per_plane


def plane_chunk_fractions(total_bytes: int, cfg: SprayConfig) -> list[float]:
    """Fraction of a sprayed flow's bytes carried by each plane.

    With perfect spray every entry is 1/n; small flows round to whole chunks,
    so early planes carry more.  The *max* entry scales per-plane offered
    load when a chunk schedule (collective) is mapped onto one plane's
    fabric — see :mod:`repro.experiments.scenarios`.
    """
    per_plane = split_chunks(total_bytes, cfg)
    return [b / total_bytes for b in per_plane] if total_bytes else \
        [0.0] * cfg.n_planes


def spray_completion_time(total_bytes: int, nic_bw_gbps: float,
                          cfg: SprayConfig,
                          plane_skew: list[float] | None = None) -> float:
    """Completion = slowest plane.  ``plane_skew[i]`` >= 1.0 multiplies plane
    i's transfer time (models a congested / degraded plane — fault tolerance:
    a dead plane is skew=inf and the NIC re-sprays over n-1 planes)."""
    per_plane = split_chunks(total_bytes, cfg)
    port_Bps = nic_bw_gbps / cfg.n_planes * 1e9 / 8
    skew = plane_skew or [1.0] * cfg.n_planes
    if len(skew) != cfg.n_planes:
        raise ValueError("plane_skew length mismatch")
    times = []
    for b, s in zip(per_plane, skew):
        if math.isinf(s):
            continue  # plane down: its bytes must be resprayed (handled below)
        n_chunks = math.ceil(b / cfg.chunk_bytes) if b else 0
        times.append((b / port_Bps + n_chunks * cfg.per_chunk_overhead_s) * s)
    dead = [i for i, s in enumerate(skew) if math.isinf(s)]
    if dead:
        # re-spray dead planes' bytes across survivors (second pass)
        dead_bytes = sum(per_plane[i] for i in dead)
        alive = cfg.n_planes - len(dead)
        if alive == 0:
            raise RuntimeError("all planes down")
        extra = dead_bytes / alive / port_Bps
        times = [t + extra for t in times]
    return max(times) if times else 0.0


def effective_bandwidth_gbps(total_bytes: int, nic_bw_gbps: float,
                             cfg: SprayConfig,
                             plane_skew: list[float] | None = None) -> float:
    t = spray_completion_time(total_bytes, nic_bw_gbps, cfg, plane_skew)
    return (total_bytes * 8 / 1e9) / t if t > 0 else 0.0


def spray_efficiency(total_bytes: int, nic_bw_gbps: float,
                     cfg: SprayConfig) -> float:
    """Fraction of ideal NIC bandwidth achieved (1.0 = perfect spray)."""
    return effective_bandwidth_gbps(total_bytes, nic_bw_gbps, cfg) / nic_bw_gbps


def plane_failure_degradation(cfg: SprayConfig) -> float:
    """Bandwidth retained when one plane dies: (n-1)/n with re-spray."""
    return (cfg.n_planes - 1) / cfg.n_planes
