"""Cost model (paper §4, Table 2).

Assumptions from the paper:
  * 102.4 Tbps switch, bare-metal $40,000.
  * Optical transceivers: 200G $100 / 400G $200 / 800G $450 / 1.6T $1,200.
  * Every link is optical unless ``access_copper`` is set on the topology
    (the paper notes copper NIC-access further amplifies MPHX's advantage,
    since MPHX has no dedicated access layer beyond the NIC-switch hop).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .dragonfly import Dragonfly, DragonflyPlus
from .fattree import MultiPlaneFatTree, ThreeTierFatTree
from .hyperx import MPHX
from .topology import Topology


@dataclass(frozen=True)
class CostModel:
    switch_usd: float = 40_000.0
    optics_usd: dict = field(default_factory=lambda: {
        200: 100.0, 400: 200.0, 800: 450.0, 1600: 1200.0,
    })

    def optic_price(self, speed_gbps: float) -> float:
        key = int(round(speed_gbps))
        if key not in self.optics_usd:
            raise KeyError(f"no transceiver price for {speed_gbps} Gbps")
        return self.optics_usd[key]


DEFAULT_COST = CostModel()


@dataclass(frozen=True)
class CostReport:
    name: str
    switch_config: str
    n_nics: int
    n_switches: int
    n_optics: int
    optics_speed_gbps: float
    switches_usd: float
    optics_usd: float

    @property
    def total_usd(self) -> float:
        return self.switches_usd + self.optics_usd

    @property
    def per_nic_usd(self) -> float:
        return self.total_usd / self.n_nics

    def row(self) -> dict:
        return {
            "topology": self.name,
            "switch_config": self.switch_config,
            "N": self.n_nics,
            "N_s": self.n_switches,
            "N_o": self.n_optics,
            "optics_gbps": int(self.optics_speed_gbps),
            "cost_per_nic_usd": round(self.per_nic_usd),
        }


def cost_report(topo: Topology, cost: CostModel = DEFAULT_COST) -> CostReport:
    links = topo.link_classes()
    optics_usd = 0.0
    n_optics = 0
    speeds = set()
    for lc in links:
        if not lc.optical:
            continue
        optics_usd += lc.transceivers * cost.optic_price(lc.speed_gbps)
        n_optics += lc.transceivers
        speeds.add(lc.speed_gbps)
    speed = max(speeds) if speeds else 0.0
    radix = int(round(topo.switch.total_bw_gbps / topo.port_gbps)) \
        if hasattr(topo, "switch") else 0
    cfg = f"{radix}x{_fmt_speed(topo.port_gbps)}" if radix else ""
    return CostReport(
        name=topo.name,
        switch_config=cfg,
        n_nics=topo.n_nics,
        n_switches=topo.n_switches,
        n_optics=n_optics,
        optics_speed_gbps=speed,
        switches_usd=topo.n_switches * cost.switch_usd,
        optics_usd=optics_usd,
    )


def _fmt_speed(gbps: float) -> str:
    return f"{gbps/1000:g}T" if gbps >= 1000 else f"{int(gbps)}G"


# ----------------------------------------------------------------------------
# Table 2: all eight topologies at ~65K NICs
# ----------------------------------------------------------------------------


def table2_topologies() -> list[Topology]:
    from .hyperx import table2_mphx_rows

    return [
        ThreeTierFatTree(radix=64, nics=65_536),
        MultiPlaneFatTree(n=8, nics=65_536),
        Dragonfly(p=16, a=32, h=16, groups=128),
        DragonflyPlus(),
        *table2_mphx_rows(),
    ]


def table2(cost: CostModel = DEFAULT_COST,
           access_copper: bool = False) -> list[CostReport]:
    """Reproduce paper Table 2 (optionally with copper access links, §4)."""
    topos = table2_topologies()
    if access_copper:
        for t in topos:
            t.access_copper = True
    return [cost_report(t, cost) for t in topos]


# Paper-published values for validation (tests/test_topology_table2.py).
# Note: the paper's 3-layer-FT N_o "393,126" is a transposition typo for
# 393,216 = 6 * 65,536 (three optical link tiers, two transceivers each);
# the published cost/NIC ($10,323) was computed from the typo'd count, so we
# allow +-3$/NIC on that row and exact match elsewhere.
PAPER_TABLE2 = [
    # name,                        N,      N_s,   N_o,       cost/NIC
    ("3-layer Fat-Tree",           65_536, 5_120, 393_216,   10_325),
    ("8-Plane 2-layer Fat-Tree",   65_536, 3_072, 2_097_152, 5_075),
    ("Dragonfly",                  65_536, 4_096, 323_584,   8_425),
    ("Dragonfly+",                 65_536, 4_096, 327_680,   8_500),
    ("1-Plane 3D HyperX",          65_536, 4_096, 315_392,   8_275),
    ("2-Plane 2D HyperX",          68_921, 3_362, 544_644,   5_507),
    ("4-Plane 2D HyperX",          66_564, 3_096, 1_058_832, 5_042),
    ("8-Plane 1D HyperX",          65_536, 2_048, 1_570_816, 3_647),
]
