"""Topology-agnostic batched graph routing engine.

:mod:`repro.core.routing_vec` routes by *coordinate arithmetic* and is
therefore MPHX-only; the Table-2 baselines (3-tier Fat-Tree, multi-plane
Fat-Tree, Dragonfly, Dragonfly+) were previously compared through
closed-form bisection bounds, which cannot capture non-minimal path
diversity (FatPaths) — the very thing low-diameter topologies live or die
by.  This module routes over any :class:`~repro.core.topology.SwitchGraph`
instead:

* the multigraph becomes a CSR adjacency with per-edge multiplicity and
  capacity (:class:`CSRGraph`);
* all-pairs hop distances come from a batched frontier BFS (one boolean
  frontier matrix per level — ``numpy`` or ``jax.numpy`` backend, same
  :func:`~repro.core.routing_vec.get_backend` contract);
* a whole demand matrix is routed by **ECMP next-hop splitting**: at every
  switch, flow toward a destination splits over the distance-decreasing
  ("downhill") edges proportionally to link multiplicity, accumulated by
  scatter-add into per-edge loads.  This is a level-by-level *pull* over the
  shortest-path DAG — no path enumeration, O(diameter x E) per destination
  batch.

Routing modes
-------------
``minimal``   ECMP over the shortest-path DAG (multiplicity-weighted).  On
              untrunked MPHX this reproduces ``routing_vec``'s
              ordering-ECMP loads to 1e-9 (pinned by
              ``tests/test_routing_graph.py`` and
              ``results/BENCH_graph_routing.json``); on trunked dims the
              graph engine deliberately weights by physical link count
              where the array engine splits orderings equally.
``valiant``   Classic VLB: route via a uniformly random intermediate switch
              — computed analytically as the two-stage expected load
              (src -> every via at 1/S, via -> dst at 1/S), each stage
              minimal-ECMP.  NOTE: the MPHX array engine's ``valiant`` is
              DAL single-deroute spreading, a *different* non-minimal
              scheme; see ``docs/routing.md``.
``adaptive``  UGAL-style: each demand splits between its minimal DAG and
              the VLB spread, choosing by comparing ``h_min * c_min``
              against ``h_val * c_val`` (hops x congestion, the UGAL
              decision rule) and relaxing the split over a few damped
              rounds.  ``c_min`` is the demand's bottleneck utilization on
              its own minimal DAG (exact, via a backward max-propagation);
              ``c_val`` is the fabric-mean utilization (VLB spreads load
              near-uniformly).

All loads are offered Gbps on *directed* edges; utilization is
load / (multiplicity x link_gbps), matching both existing engines.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .routing_vec import (BaseLinkLoads, DemandArrays, IncidenceCacheMixin,
                          backend_zeros, get_backend)
from .topology import SwitchGraph, Topology

Edge = tuple[int, int]


def _row_scatter_add(xp, mat, rows, vals):
    """mat[rows] += vals along axis 0 (duplicate rows accumulate)."""
    if xp is np:
        np.add.at(mat, rows, vals)
        return mat
    return mat.at[rows].add(vals)


# ---------------------------------------------------------------------------
# CSR adjacency
# ---------------------------------------------------------------------------


@dataclass
class CSRGraph:
    """CSR view of a :class:`SwitchGraph`'s directed edges.

    Directed edge ``e`` leaves ``src[e]`` toward ``dst[e]`` with
    ``mult[e]`` parallel physical links and capacity
    ``cap[e] = mult[e] * link_gbps``.  Edges are sorted by (source,
    target) so edge ids are deterministic.
    """

    graph: SwitchGraph

    def __post_init__(self):
        g = self.graph
        self.n_switches = g.n_switches
        us, vs, mult = g.directed_edge_arrays()
        order = np.lexsort((np.asarray(vs), np.asarray(us)))
        self.src = np.asarray(us, dtype=np.int64)[order]
        self.dst = np.asarray(vs, dtype=np.int64)[order]
        self.mult = np.asarray(mult, dtype=np.float64)[order]
        self.cap = self.mult * g.link_gbps
        self.n_edges = int(self.src.shape[0])
        self.nic_counts = np.asarray(g.nic_counts(), dtype=np.int64)

    def all_pairs_hops(self, xp=np) -> np.ndarray:
        """(S, S) switch-to-switch hop distances via batched frontier BFS.

        One boolean (S, S) frontier per BFS level, expanded with a single
        frontier x adjacency matmul — ``diameter`` matmuls total, on the
        selected backend.  Raises on a disconnected graph.
        """
        S = self.n_switches
        adj = np.zeros((S, S), dtype=np.float32)
        adj[self.src, self.dst] = 1.0
        adj = xp.asarray(adj)
        frontier = xp.eye(S, dtype=bool)
        visited = frontier
        dist = xp.zeros((S, S), dtype=np.int32)
        d = 0
        while True:
            d += 1
            nxt = ((frontier.astype(np.float32) @ adj) > 0) & ~visited
            if not bool(nxt.any()):
                break
            dist = xp.where(nxt, np.int32(d), dist)
            visited = visited | nxt
            frontier = nxt
        visited = np.asarray(visited)
        if not visited.all():
            raise ValueError(f"{self.graph.name}: graph is disconnected")
        return np.asarray(dist)

    def edge_list(self) -> list[Edge]:
        return list(zip(self.src.tolist(), self.dst.tolist()))


# ---------------------------------------------------------------------------
# Link-load result (same API as routing.LinkLoads / routing_vec.ArrayLinkLoads)
# ---------------------------------------------------------------------------


class GraphLinkLoads(BaseLinkLoads):
    """Per-directed-edge loads of a routed demand matrix."""

    def __init__(self, csr: CSRGraph, loads):
        self.csr = csr
        self.loads = loads

    def capacity_array(self) -> np.ndarray:
        return self.csr.cap

    def to_dict(self) -> dict[Edge, float]:
        """Nonzero loads as the legacy ``{(u, v): gbps}`` dict."""
        l = self._np_loads()
        nz = np.nonzero(l)[0]
        return {(int(self.csr.src[e]), int(self.csr.dst[e])): float(l[e])
                for e in nz}


# ---------------------------------------------------------------------------
# Router
# ---------------------------------------------------------------------------


class GraphRouter(IncidenceCacheMixin):
    """Batched routing over any :class:`SwitchGraph` (or any
    :class:`Topology` exposing ``build_graph()``)."""

    def __init__(self, topo_or_graph: "Topology | SwitchGraph",
                 backend: str = "auto", dst_chunk: "int | None" = None):
        if isinstance(topo_or_graph, SwitchGraph):
            graph = topo_or_graph
        else:
            graph = topo_or_graph.build_graph()
        self.graph = graph
        self.csr = CSRGraph(graph)
        self.backend, self.xp = get_backend(backend)
        # destinations routed per batch; auto-sized so the (E, chunk)
        # work matrices stay ~64 MB
        if dst_chunk is None:
            dst_chunk = max(1, int(8e6 // max(self.csr.n_edges, 1)))
        self.dst_chunk = dst_chunk
        self._hops: "np.ndarray | None" = None

    @property
    def hops(self) -> np.ndarray:
        """(S, S) all-pairs switch hop distances (lazy, cached)."""
        if self._hops is None:
            self._hops = self.csr.all_pairs_hops(self.xp)
        return self._hops

    # -------------------------------------------------------- propagation ----

    def _downhill(self, dests: np.ndarray):
        """Downhill structure toward a destination batch.

        Returns ``(dist_to, frac)``: ``dist_to`` (S, C) hop counts,
        ``frac`` (E, C) the ECMP split fraction of edge ``e`` for flow at
        ``src[e]`` headed to ``dests[j]`` (0 on non-downhill edges).
        """
        csr = self.csr
        dist_to = self.hops[:, dests]                       # (S, C)
        down = dist_to[csr.dst] == dist_to[csr.src] - 1     # (E, C)
        w = csr.mult[:, None] * down
        denom = np.zeros((csr.n_switches, dests.shape[0]))
        np.add.at(denom, csr.src, w)
        with np.errstate(divide="ignore", invalid="ignore"):
            frac = np.where(down, w / denom[csr.src], 0.0)
        return dist_to, frac

    def _route_to_dests(self, dests: np.ndarray, inject: np.ndarray, loads):
        """Push ``inject`` (S, C) Gbps minimally toward ``dests``; add the
        resulting edge loads into ``loads`` (E,)."""
        csr, xp = self.csr, self.xp
        dist_to, frac = self._downhill(dests)
        frac = xp.asarray(frac)
        f = xp.asarray(inject)
        for level in range(int(dist_to.max()), 0, -1):
            fa = f * xp.asarray(dist_to == level)
            contrib = frac * fa[csr.src]                    # (E, C)
            loads = loads + contrib.sum(axis=1)
            f = _row_scatter_add(xp, f, csr.dst, contrib)
        return loads

    def _incidence_to_dests(self, dests: np.ndarray, inject: np.ndarray
                            ) -> np.ndarray:
        """Like :meth:`_route_to_dests` but keeps per-column attribution:
        returns ``(E, C)`` — the load each column's injection places on
        every edge (numpy; incidence extraction is sim-scale, not 65K)."""
        csr = self.csr
        dist_to, frac = self._downhill(dests)
        f = np.asarray(inject, dtype=np.float64).copy()
        out = np.zeros((csr.n_edges, dests.shape[0]))
        for level in range(int(dist_to.max()), 0, -1):
            fa = f * (dist_to == level)
            contrib = frac * fa[csr.src]
            out += contrib
            np.add.at(f, csr.dst, contrib)
        return out

    def incidence(self, demands: DemandArrays, mode: str = "minimal"):
        """Per-flow edge incidence of minimal ECMP routing.

        Returns ``(flow, edge, frac)`` COO arrays: ``frac`` is the fraction
        of flow ``flow``'s rate on directed edge ``edge``, so
        scatter-adding ``rates[flow] * frac`` reproduces
        :meth:`route_minimal`'s loads (flow-simulator steady-state
        cross-check, ``tests/test_sim.py``).  ``flow`` indexes rows of
        ``demands``; self-pairs (src == dst) get no entries.  Only
        ``minimal`` has a static per-flow spread here — ``valiant``
        averages over every intermediate switch and ``adaptive`` re-routes
        under load.
        """
        if mode != "minimal":
            raise ValueError(
                f"no static per-flow incidence for graph-engine mode "
                f"{mode!r} (valiant averages over all intermediates, "
                "adaptive re-routes under load); use minimal")
        self._count_walk()
        src = np.asarray(demands.src, dtype=np.int64)
        dst = np.asarray(demands.dst, dtype=np.int64)
        keep = np.flatnonzero(src != dst)
        pairs = np.stack([src[keep], dst[keep]], axis=1)
        upairs, pair_of = np.unique(pairs, axis=0, return_inverse=True)
        # flows grouped by pair: flows_sorted[pair_start[p]:pair_start[p+1]]
        # are the flow rows sharing unique pair p
        order = np.argsort(pair_of, kind="stable")
        flows_sorted = keep[order]
        pair_start = np.searchsorted(pair_of[order],
                                     np.arange(upairs.shape[0] + 1))
        S = self.csr.n_switches
        chunk = min(self.dst_chunk, 256)
        flows, edges, fracs = [], [], []
        for lo in range(0, upairs.shape[0], chunk):
            cols = np.arange(lo, min(lo + chunk, upairs.shape[0]))
            inject = np.zeros((S, cols.shape[0]))
            inject[upairs[cols, 0], np.arange(cols.shape[0])] = 1.0
            out = self._incidence_to_dests(upairs[cols, 1], inject)
            # transposed nonzero scan -> entries arrive grouped by column
            c_idx, e_idx = np.nonzero(out.T)
            vals = out.T[c_idx, e_idx]
            # replicate each column's entry block once per flow of its pair
            n_ent = np.bincount(c_idx, minlength=cols.shape[0])
            ent_start = np.concatenate(([0], np.cumsum(n_ent)))
            for ci, p in enumerate(cols):
                ent = slice(ent_start[ci], ent_start[ci + 1])
                for f in flows_sorted[pair_start[p]:pair_start[p + 1]]:
                    flows.append(np.full(int(n_ent[ci]), f, dtype=np.int64))
                    edges.append(e_idx[ent])
                    fracs.append(vals[ent])
        if not flows:
            z = np.zeros(0, dtype=np.int64)
            return z, z.copy(), np.zeros(0)
        return (np.concatenate(flows), np.concatenate(edges),
                np.concatenate(fracs))

    def mean_switch_hops(self) -> float:
        """Measured mean switch-switch hops over NIC-weighted switch pairs
        (``hops[u, u] = 0`` same-switch pairs included — the same uniform
        NIC-pair convention as ``MPHX.avg_hops() - 2``)."""
        nics = self.csr.nic_counts.astype(np.float64)
        w = nics / nics.sum()
        return float(w @ self.hops @ w)

    def edge_capacity(self) -> np.ndarray:
        """(E,) directed-edge capacity in Gbps (shared router interface
        with :class:`~repro.core.routing_vec.VectorizedHyperXRouter`)."""
        return self.csr.cap

    def _zeros(self):
        return backend_zeros(self.xp, self.csr.n_edges)

    def _accumulate_minimal(self, src, dst, gbps, loads):
        """ECMP-route (src, dst, gbps) triplets; add into ``loads``."""
        S = self.csr.n_switches
        dests, inv = np.unique(dst, return_inverse=True)
        for lo in range(0, dests.shape[0], self.dst_chunk):
            cols = np.arange(lo, min(lo + self.dst_chunk, dests.shape[0]))
            sel = (inv >= cols[0]) & (inv <= cols[-1])
            inject = np.zeros((S, cols.shape[0]))
            np.add.at(inject, (src[sel], inv[sel] - cols[0]), gbps[sel])
            loads = self._route_to_dests(dests[cols], inject, loads)
        return loads

    # -------------------------------------------------------------- modes ----

    def route(self, demands: DemandArrays, mode: str = "minimal",
              rounds: int = 4) -> GraphLinkLoads:
        if mode == "minimal":
            return self.route_minimal(demands)
        if mode == "valiant":
            return self.route_valiant(demands)
        if mode == "adaptive":
            return self.route_adaptive(demands, rounds=rounds)
        raise ValueError(f"unknown mode {mode}")

    def _prep(self, demands: DemandArrays):
        src = np.asarray(demands.src, dtype=np.int64)
        dst = np.asarray(demands.dst, dtype=np.int64)
        gbps = np.asarray(demands.gbps, dtype=np.float64)
        keep = src != dst
        return src[keep], dst[keep], gbps[keep]

    def route_minimal(self, demands: DemandArrays) -> GraphLinkLoads:
        src, dst, gbps = self._prep(demands)
        return GraphLinkLoads(
            self.csr, self._accumulate_minimal(src, dst, gbps, self._zeros()))

    def route_valiant(self, demands: DemandArrays) -> GraphLinkLoads:
        src, dst, gbps = self._prep(demands)
        return GraphLinkLoads(
            self.csr, self._valiant_loads(src, dst, gbps, self._zeros()))

    def _valiant_loads(self, src, dst, gbps, loads):
        """Expected VLB loads: every demand routes via a uniform random
        intermediate switch, so stage 1 carries each source's total egress
        spread 1/S to every switch and stage 2 each destination's total
        ingress collected 1/S from every switch — both minimal-ECMP."""
        S = self.csr.n_switches
        g_out = np.zeros(S)
        np.add.at(g_out, src, gbps)
        # stage 1: src -> via, for all vias (dest batch = every switch)
        vias = np.arange(S, dtype=np.int64)
        for lo in range(0, S, self.dst_chunk):
            cols = vias[lo:lo + self.dst_chunk]
            inject = np.repeat(g_out[:, None] / S, cols.shape[0], axis=1)
            loads = self._route_to_dests(cols, inject, loads)
        # stage 2: via -> dst, injected equally at every switch
        g_in = np.zeros(S)
        np.add.at(g_in, dst, gbps)
        dests = np.flatnonzero(g_in).astype(np.int64)
        for lo in range(0, dests.shape[0], self.dst_chunk):
            cols = dests[lo:lo + self.dst_chunk]
            inject = np.repeat((g_in[cols] / S)[None, :], S, axis=0)
            loads = self._route_to_dests(cols, inject, loads)
        return loads

    # ----------------------------------------------------- UGAL adaptive ----

    def _bottleneck_to_dests(self, dests: np.ndarray, util: np.ndarray
                             ) -> np.ndarray:
        """(S, C) worst edge utilization on the minimal DAG from every
        switch to each destination (backward max-propagation by level)."""
        csr = self.csr
        dist_to, frac = self._downhill(dests)
        down = frac > 0
        b = np.zeros((csr.n_switches, dests.shape[0]))
        for level in range(1, int(dist_to.max()) + 1):
            cand = np.where(down, np.maximum(util[:, None], b[csr.dst]),
                            -np.inf)
            tmp = np.full_like(b, -np.inf)
            np.maximum.at(tmp, csr.src, cand)
            b = np.where(dist_to == level, tmp, b)
        return b

    def route_adaptive(self, demands: DemandArrays, rounds: int = 4,
                       hop_alpha: float = 0.05) -> GraphLinkLoads:
        """UGAL-style adaptive: per demand, split between minimal ECMP and
        the VLB spread.  Each round compares the UGAL costs
        ``h_min * (c_min + hop_alpha)`` vs ``h_val * (c_val + hop_alpha)``
        under the current loads and damps the split 50% toward the winner
        (``hop_alpha`` keeps minimal preferred at zero load).  This is a
        deterministic batched relaxation of per-packet UGAL — same spirit
        as ``routing_vec``'s parallel-UGAL, generalized to any graph."""
        src, dst, gbps = self._prep(demands)
        csr = self.csr
        if src.size == 0:
            return GraphLinkLoads(csr, self._zeros())
        h_min = self.hops[src, dst].astype(np.float64)
        h_val = self.hops.mean(axis=1)[src] + self.hops.mean(axis=0)[dst]
        dests, inv = np.unique(dst, return_inverse=True)
        phi = np.ones(src.shape[0])          # fraction routed minimally
        loads = None
        for r in range(rounds + 1):
            loads = self._accumulate_minimal(src, dst, gbps * phi,
                                             self._zeros())
            loads = self._valiant_loads(src, dst, gbps * (1 - phi), loads)
            if r == rounds:
                break
            util = GraphLinkLoads(csr, loads).utilization_array()
            c_val = float(util[csr.cap > 0].mean())
            c_min = np.empty(src.shape[0])
            for lo in range(0, dests.shape[0], self.dst_chunk):
                cols = np.arange(lo, min(lo + self.dst_chunk,
                                         dests.shape[0]))
                b = self._bottleneck_to_dests(dests[cols], util)
                sel = (inv >= cols[0]) & (inv <= cols[-1])
                c_min[sel] = b[src[sel], inv[sel] - cols[0]]
            prefer_min = (h_min * (c_min + hop_alpha)
                          <= h_val * (c_val + hop_alpha))
            phi = 0.5 * phi + 0.5 * prefer_min
        return GraphLinkLoads(csr, loads)


# ---------------------------------------------------------------------------
# Generic demand generators (any SwitchGraph, NIC-bearing switches only)
# ---------------------------------------------------------------------------
#
# These generalize the MPHX coordinate generators of ``routing_vec``:
# traffic originates/terminates only at NIC-bearing switches
# (``SwitchGraph.nic_nodes``), each injecting its NIC count's share of
# ``offered_per_nic_gbps`` divided by the plane count (one plane's load,
# like the MPHX builders).  Patterns that need a coordinate system
# (``transpose``) stay MPHX-only.


def _nic_switches(topo: Topology, graph: "SwitchGraph | None"):
    g = graph if graph is not None else topo.build_graph()
    nics = np.asarray(g.nic_counts(), dtype=np.float64)
    nic_sw = np.flatnonzero(nics).astype(np.int64)
    if nic_sw.size < 2:
        raise ValueError(f"{g.name}: needs >= 2 NIC-bearing switches")
    return g, nics, nic_sw


def graph_uniform_demands(topo: Topology, offered_per_nic_gbps: float,
                          graph: "SwitchGraph | None" = None) -> DemandArrays:
    """Every NIC sprays uniformly over all *other* NIC-bearing switches,
    weighted by destination NIC count."""
    g, nics, nic_sw = _nic_switches(topo, graph)
    out = nics * offered_per_nic_gbps / topo.n_planes
    s, d = np.meshgrid(nic_sw, nic_sw, indexing="ij")
    mask = s != d
    s, d = s[mask], d[mask]
    total = nics.sum()
    gbps = out[s] * nics[d] / (total - nics[s])
    return DemandArrays(s, d, gbps)


def graph_shift_demands(topo: Topology, offered_per_nic_gbps: float,
                        graph: "SwitchGraph | None" = None) -> DemandArrays:
    """+1 shift over NIC-bearing switches in id order (the generic
    analogue of the MPHX dim-0 neighbor shift: a permutation with a single
    'adjacent' target per switch)."""
    g, nics, nic_sw = _nic_switches(topo, graph)
    out = nics * offered_per_nic_gbps / topo.n_planes
    dst = np.roll(nic_sw, -1)
    return DemandArrays(nic_sw, dst, out[nic_sw])


def graph_reverse_demands(topo: Topology, offered_per_nic_gbps: float,
                          graph: "SwitchGraph | None" = None) -> DemandArrays:
    """Reverse pairing (switch k -> switch K-1-k over NIC-bearing switches
    in id order) — the generic analogue of MPHX bit-complement: every
    demand crosses the whole fabric."""
    g, nics, nic_sw = _nic_switches(topo, graph)
    out = nics * offered_per_nic_gbps / topo.n_planes
    dst = nic_sw[::-1].copy()
    keep = nic_sw != dst
    return DemandArrays(nic_sw[keep], dst[keep], out[nic_sw][keep])


def graph_hotspot_demands(topo: Topology, offered_per_nic_gbps: float,
                          graph: "SwitchGraph | None" = None,
                          hot_fraction: float = 0.5) -> DemandArrays:
    """``hot_fraction`` of every switch's load incasts on the first
    NIC-bearing switch; the rest sprays uniformly."""
    g, nics, nic_sw = _nic_switches(topo, graph)
    uni = graph_uniform_demands(topo, offered_per_nic_gbps * (1 - hot_fraction),
                                graph=g)
    hot = int(nic_sw[0])
    out = nics * offered_per_nic_gbps * hot_fraction / topo.n_planes
    srcs = nic_sw[nic_sw != hot]
    return DemandArrays(
        np.concatenate([uni.src, srcs]),
        np.concatenate([uni.dst, np.full(srcs.shape[0], hot,
                                         dtype=np.int64)]),
        np.concatenate([uni.gbps, out[srcs]]),
    )


def graph_ring_demands(topo: Topology, offered_per_nic_gbps: float,
                       graph: "SwitchGraph | None" = None) -> DemandArrays:
    """Steady-state link pattern of a ring collective over NIC-bearing
    switches in id order (same convention as ``routing_vec.ring_demands``)."""
    return graph_shift_demands(topo, offered_per_nic_gbps, graph=graph)
