"""Base abstractions for network topologies (paper §3, Table 1).

Every topology reports the paper's Table-2 quantities:

  * ``n_nics``      — N,   number of NICs the network hosts
  * ``n_switches``  — N_s, number of *physical* switch units
  * ``n_optics``    — N_o, number of optical transceivers (2 per optical link)
  * ``diameter``    — d,   worst-case NIC-to-NIC hop count (links traversed)
  * link inventory by speed class, used by :mod:`repro.core.cost`

plus structural quantities used by the routing / flow-simulation layers:

  * ``bisection_links`` — min #links crossing an even bisection (per speed)
  * ``avg_hops``        — expected NIC-to-NIC minimal hop count, uniform pairs
  * ``build_graph``     — explicit switch-level multigraph (where tractable)

Conventions
-----------
* Bandwidths are in Gbps.  The paper's B = 1600 Gbps NIC and B*k = 102.4 Tbps
  switch (k = 64) are defaults, both overridable.
* A "hop" is one traversed link, counting the NIC-switch access links:
  NIC -> sw -> sw -> NIC is 3 hops.  This matches the paper's Fig.1 framing
  (MPHX(8,256,256) has diameter 3; a 3-tier fat-tree has diameter 6).
* Optical-transceiver counting: every optical link consumes exactly two
  transceivers of the link's speed class, one per end.  Copper access links
  consume zero (paper §4 "when factoring in the use of copper cables ...").
"""

from __future__ import annotations

import abc
import dataclasses
import math
from dataclasses import dataclass
from typing import Iterable, Sequence


# --------------------------------------------------------------------------
# Link inventory
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class LinkClass:
    """A set of identical links.

    Attributes:
      speed_gbps: per-link bandwidth in Gbps.
      count: number of links (each link = 2 transceivers if optical).
      tier: free-form label ("access", "dim0", "leaf-spine", "global", ...).
      optical: False for copper (e.g. in-rack NIC-access DACs).
    """

    speed_gbps: float
    count: int
    tier: str = ""
    optical: bool = True

    @property
    def transceivers(self) -> int:
        return 2 * self.count if self.optical else 0

    @property
    def bandwidth_tbps(self) -> float:
        return self.speed_gbps * self.count / 1000.0


def total_optics(links: Iterable[LinkClass]) -> int:
    return sum(l.transceivers for l in links)


# --------------------------------------------------------------------------
# Switch model
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class SwitchModel:
    """A physical switch unit with breakout support (paper §2).

    The paper's reference unit: 102.4 Tbps total switching bandwidth,
    configurable as 64x1.6T, 128x800G, 256x400G, 512x200G.
    """

    total_bw_gbps: float = 102_400.0
    max_breakout_ports: int = 512  # finest supported breakout

    def radix_at(self, port_gbps: float) -> int:
        """Number of ports when broken out to ``port_gbps`` per port."""
        r = int(self.total_bw_gbps // port_gbps)
        if r > self.max_breakout_ports:
            raise ValueError(
                f"breakout to {port_gbps} Gbps needs radix {r} > "
                f"max {self.max_breakout_ports}"
            )
        return r

    def supports(self, port_gbps: float, ports_used: int) -> bool:
        return ports_used <= self.radix_at(port_gbps)


DEFAULT_SWITCH = SwitchModel()


# --------------------------------------------------------------------------
# Topology base class
# --------------------------------------------------------------------------


class Topology(abc.ABC):
    """Abstract network topology (paper Table 1 symbols)."""

    name: str = "topology"
    nic_bw_gbps: float = 1600.0  # B

    # -- Table-2 quantities ------------------------------------------------

    @property
    @abc.abstractmethod
    def n_nics(self) -> int:
        """N — number of NICs."""

    @property
    @abc.abstractmethod
    def n_switches(self) -> int:
        """N_s — number of physical switch units."""

    @abc.abstractmethod
    def link_classes(self) -> list[LinkClass]:
        """All links in the network, grouped by (speed, tier)."""

    @property
    def n_optics(self) -> int:
        """N_o — total optical transceivers."""
        return total_optics(self.link_classes())

    @property
    @abc.abstractmethod
    def diameter(self) -> int:
        """d — worst-case NIC-to-NIC hop count (links traversed)."""

    # -- structural quantities ----------------------------------------------

    @property
    def n_planes(self) -> int:
        return 1

    @property
    def port_gbps(self) -> float:
        """Per-port bandwidth of switch ports (= NIC-port bandwidth B/n)."""
        return self.nic_bw_gbps / self.n_planes

    @abc.abstractmethod
    def avg_hops(self) -> float:
        """Expected minimal NIC-to-NIC hops over uniform random pairs."""

    @abc.abstractmethod
    def bisection_links(self) -> int:
        """#links crossing the worst even bisection (all planes summed)."""

    def bisection_bw_tbps(self) -> float:
        return self.bisection_links() * self.port_gbps / 1000.0

    def bisection_per_nic_gbps(self) -> float:
        """Bisection bandwidth per NIC on one side (2x links since full duplex
        counts once per direction here we report injection-normalized)."""
        return self.bisection_links() * self.port_gbps / (self.n_nics / 2)

    # -- optional explicit graph ---------------------------------------------

    def build_graph(self) -> "SwitchGraph":
        raise NotImplementedError(f"{self.name} has no explicit graph builder")

    # -- reporting -----------------------------------------------------------

    def summary(self) -> dict:
        return {
            "name": self.name,
            "planes": self.n_planes,
            "N": self.n_nics,
            "N_s": self.n_switches,
            "N_o": self.n_optics,
            "diameter": self.diameter,
            "avg_hops": round(self.avg_hops(), 3),
            "port_gbps": self.port_gbps,
            "bisection_tbps": round(self.bisection_bw_tbps(), 1),
        }

    def validate(self, switch: SwitchModel = DEFAULT_SWITCH) -> None:
        """Raise if the topology is infeasible with the given switch unit."""
        for check, msg in self.feasibility(switch):
            if not check:
                raise ValueError(f"{self.name}: infeasible — {msg}")

    def feasibility(self, switch: SwitchModel) -> list[tuple[bool, str]]:
        return []


# --------------------------------------------------------------------------
# Explicit switch-level multigraph (for routing / flow simulation)
# --------------------------------------------------------------------------


class SwitchGraph:
    """Switch-level multigraph of ONE network plane.

    Nodes are integers 0..S-1.  Edges carry a multiplicity (number of
    parallel physical links — paper Table 2's MPHX(4,86,86,9) trunks 85
    links over 8 neighbours in dim 2) and a tier label.

    ``nics_per_switch`` NIC ports hang off every *NIC-bearing* node.  By
    default every node bears NICs (HyperX, Dragonfly); hierarchical
    topologies whose upper tiers are transit-only (fat-tree spines/cores,
    Dragonfly+ spines) restrict that with ``nic_nodes``.
    """

    def __init__(self, n_switches: int, nics_per_switch: int,
                 link_gbps: float, name: str = "plane",
                 nic_nodes: "Sequence[int] | None" = None):
        self.name = name
        self.n_switches = n_switches
        self.nics_per_switch = nics_per_switch
        self.link_gbps = link_gbps
        # NIC-bearing nodes (traffic sources/sinks); None = all nodes
        self.nic_nodes: list[int] = (list(range(n_switches))
                                     if nic_nodes is None else list(nic_nodes))
        # adjacency: dict[node] -> dict[neighbor] -> multiplicity (float ok)
        self.adj: list[dict[int, float]] = [dict() for _ in range(n_switches)]
        self.tier: dict[tuple[int, int], str] = {}

    def add_edge(self, u: int, v: int, multiplicity: float = 1.0,
                 tier: str = "") -> None:
        if u == v:
            raise ValueError("self-loop")
        self.adj[u][v] = self.adj[u].get(v, 0.0) + multiplicity
        self.adj[v][u] = self.adj[v].get(u, 0.0) + multiplicity
        self.tier[(min(u, v), max(u, v))] = tier

    @property
    def n_edges(self) -> int:
        return sum(len(a) for a in self.adj) // 2

    def total_links(self) -> float:
        return sum(sum(a.values()) for a in self.adj) / 2.0

    def degree(self, u: int) -> float:
        return sum(self.adj[u].values())

    def neighbors(self, u: int) -> dict[int, float]:
        return self.adj[u]

    def multiplicity(self, u: int, v: int) -> float:
        return self.adj[u].get(v, 0.0)

    def nic_counts(self) -> list[int]:
        """Per-node NIC port counts (0 for transit-only switches)."""
        out = [0] * self.n_switches
        for u in self.nic_nodes:
            out[u] = self.nics_per_switch
        return out

    @property
    def total_nics(self) -> int:
        return self.nics_per_switch * len(self.nic_nodes)

    def directed_edge_arrays(self):
        """All directed edges as parallel lists ``(u, v, multiplicity)`` —
        the multigraph in array form, for structural cross-checks against
        the analytic edge-slot tensor (tests/test_experiments.py) and for
        generic array consumers."""
        us, vs, mult = [], [], []
        for u, nbrs in enumerate(self.adj):
            for v, m in nbrs.items():
                us.append(u)
                vs.append(v)
                mult.append(m)
        return us, vs, mult

    def bfs_dist(self, src: int) -> list[int]:
        dist = [-1] * self.n_switches
        dist[src] = 0
        frontier = [src]
        while frontier:
            nxt = []
            for u in frontier:
                for v in self.adj[u]:
                    if dist[v] < 0:
                        dist[v] = dist[u] + 1
                        nxt.append(v)
            frontier = nxt
        return dist

    def switch_diameter(self, sample: int | None = None) -> int:
        """Worst-case switch-to-switch distance (exact, or over a sample)."""
        import random

        nodes = range(self.n_switches)
        if sample is not None and self.n_switches > sample:
            rng = random.Random(0)
            nodes = rng.sample(range(self.n_switches), sample)
        best = 0
        for s in nodes:
            d = self.bfs_dist(s)
            m = max(d)
            if m < 0:
                raise ValueError("graph is disconnected")
            best = max(best, m)
        return best

    def avg_switch_hops(self, sample: int | None = None) -> float:
        import random

        nodes = list(range(self.n_switches))
        if sample is not None and self.n_switches > sample:
            rng = random.Random(0)
            nodes = rng.sample(nodes, sample)
        tot, cnt = 0, 0
        for s in nodes:
            d = self.bfs_dist(s)
            tot += sum(d)
            cnt += self.n_switches - 1
        return tot / max(cnt, 1)


# --------------------------------------------------------------------------
# Helpers shared by concrete topologies
# --------------------------------------------------------------------------


def product(xs: Sequence[int]) -> int:
    return math.prod(xs)


def check_even_split(n: int, what: str) -> None:
    if n % 2:
        raise ValueError(f"{what} must be even for bisection, got {n}")
