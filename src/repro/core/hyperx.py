"""Multi-Plane HyperX (MPHX) topology — the paper's contribution (§3).

``MPHX(n, p, D_1, ..., D_D)``:

* ``n``    — number of NIC ports == number of independent network planes.
             Each NIC port has bandwidth B/n; switches are broken out to the
             matching B/n port speed, multiplying their radix by n (§2).
* ``p``    — NIC ports attached to each switch (per plane).
* ``D_i``  — switches along dimension i; switches within a dimension are
             fully interconnected (full mesh), as in HyperX [Ahn et al. SC'09].

Eq. 1:  N     = p * prod(D_i)
Eq. 2:  N_max = (n*k / (D+1)) ** (D+1)   for the balanced maximum-scale net
                with p = D_1 = ... = D_D = n*k/(D+1).

Every plane is an identical copy of the single-plane HyperX; each NIC has one
port in every plane (Fig. 1).  Table 2's MPHX(4,86,86,9) additionally *trunks*
dimension 2: each switch keeps 85 in-dimension links (same as dim 1) spread
over its 8 in-dimension neighbours — supported via ``links_per_dim``.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field

from .topology import (
    DEFAULT_SWITCH,
    LinkClass,
    SwitchGraph,
    SwitchModel,
    Topology,
    product,
)


@dataclass
class MPHX(Topology):
    """Multi-Plane HyperX network MPHX(n, p, D_1..D_D)."""

    n: int                               # planes (NIC ports)
    p: int                               # NIC ports per switch per plane
    dims: tuple[int, ...]                # D_1..D_D
    nic_bw_gbps: float = 1600.0          # B
    switch: SwitchModel = field(default_factory=lambda: DEFAULT_SWITCH)
    links_per_dim: tuple[int, ...] | None = None  # trunking override
    access_copper: bool = False          # copper NIC-access links (§4)
    name: str = ""

    def __post_init__(self):
        self.dims = tuple(int(d) for d in self.dims)
        if self.links_per_dim is None:
            self.links_per_dim = tuple(d - 1 for d in self.dims)
        else:
            self.links_per_dim = tuple(self.links_per_dim)
        if len(self.links_per_dim) != len(self.dims):
            raise ValueError("links_per_dim must match dims")
        for d, l in zip(self.dims, self.links_per_dim):
            if d > 1 and l < d - 1:
                raise ValueError(
                    f"dimension with {d} switches needs >= {d-1} links, got {l}")
        if not self.name:
            self.name = f"MPHX({self.n},{self.p},{','.join(map(str, self.dims))})"

    # ---------------------------------------------------------- Table 2 ----

    @property
    def D(self) -> int:
        return len(self.dims)

    @property
    def n_planes(self) -> int:
        return self.n

    @property
    def switches_per_plane(self) -> int:
        return product(self.dims)

    @property
    def n_nics(self) -> int:
        # Eq. 1
        return self.p * self.switches_per_plane

    @property
    def n_switches(self) -> int:
        return self.n * self.switches_per_plane

    @property
    def radix_used(self) -> int:
        return self.p + sum(self.links_per_dim)

    def link_classes(self) -> list[LinkClass]:
        out = [
            LinkClass(self.port_gbps, self.n * self.n_nics, tier="access",
                      optical=not self.access_copper)
        ]
        for i, (d, l) in enumerate(zip(self.dims, self.links_per_dim)):
            if d <= 1:
                continue
            # every switch contributes l in-dim links; each link joins 2
            count = self.n * self.switches_per_plane * l // 2
            if (self.switches_per_plane * l) % 2:
                raise ValueError(f"odd link endpoint count in dim {i}")
            out.append(LinkClass(self.port_gbps, count, tier=f"dim{i}"))
        return out

    @property
    def diameter(self) -> int:
        # one switch-switch hop per dimension with >1 switch, plus 2 access
        return 2 + sum(1 for d in self.dims if d > 1)

    def avg_hops(self) -> float:
        # P(coordinate differs in dim i) = (D_i - 1)/D_i for uniform pairs
        return 2.0 + sum((d - 1) / d for d in self.dims if d > 1)

    def bisection_links(self) -> int:
        """Worst (minimum) dimension-aligned even bisection, all planes."""
        best = None
        for i, (d, l) in enumerate(zip(self.dims, self.links_per_dim)):
            if d <= 1:
                continue
            h = d // 2
            per_pair = l / (d - 1)  # trunked multiplicity per neighbour pair
            crossing = (self.switches_per_plane // d) * h * (d - h) * per_pair
            total = self.n * crossing
            if best is None or total < best:
                best = total
        if best is None:  # single-switch network
            return 0
        return int(round(best))

    # ------------------------------------------------------- feasibility ----

    def feasibility(self, switch: SwitchModel | None = None):
        sw = switch or self.switch
        radix = sw.radix_at(self.port_gbps)
        return [
            (self.n >= 1 and self.n <= 8,
             f"n={self.n} planes out of range [1,8] (paper assumes n<=8)"),
            (self.radix_used <= radix,
             f"radix used {self.radix_used} > breakout radix {radix} "
             f"at {self.port_gbps} Gbps"),
        ]

    # -------------------------------------------------------------- Eq. 2 ----

    @staticmethod
    def max_scale(n: int, k: int, D: int) -> int:
        """Eq. 2: NICs of the balanced maximum-scale MPHX."""
        side = n * k // (D + 1)
        return side ** (D + 1)

    @staticmethod
    def balanced(n: int, k: int, D: int, nic_bw_gbps: float = 1600.0) -> "MPHX":
        """The balanced maximum-scale network behind Eq. 2."""
        side = n * k // (D + 1)
        return MPHX(n=n, p=side, dims=(side,) * D, nic_bw_gbps=nic_bw_gbps)

    # ------------------------------------------------------------- graph ----

    def coord_to_id(self, coord: tuple[int, ...]) -> int:
        idx = 0
        for c, d in zip(coord, self.dims):
            idx = idx * d + c
        return idx

    def id_to_coord(self, idx: int) -> tuple[int, ...]:
        coord = []
        for d in reversed(self.dims):
            coord.append(idx % d)
            idx //= d
        return tuple(reversed(coord))

    def build_graph(self) -> SwitchGraph:
        """One plane's switch graph (all n planes are identical copies)."""
        g = SwitchGraph(self.switches_per_plane, self.p, self.port_gbps,
                        name=self.name)
        for idx in range(self.switches_per_plane):
            coord = self.id_to_coord(idx)
            for i, (d, l) in enumerate(zip(self.dims, self.links_per_dim)):
                if d <= 1:
                    continue
                mult = l / (d - 1)
                for c in range(coord[i] + 1, d):
                    other = list(coord)
                    other[i] = c
                    g.add_edge(idx, self.coord_to_id(tuple(other)), mult,
                               tier=f"dim{i}")
        return g


def flattened_butterfly(p: int, side: int, D: int, **kw) -> MPHX:
    """Flattened Butterfly = HyperX restricted to equal dims [Kim ISCA'07]."""
    return MPHX(n=1, p=p, dims=(side,) * D, **kw)


# ----------------------------------------------------------------------------
# Paper Table 2 MPHX rows
# ----------------------------------------------------------------------------


def table2_mphx_rows() -> list[MPHX]:
    """The four MPHX configurations of Table 2 (B=1.6T NIC, 102.4T switch)."""
    return [
        MPHX(n=1, p=16, dims=(16, 16, 16), name="1-Plane 3D HyperX"),
        MPHX(n=2, p=41, dims=(41, 41), name="2-Plane 2D HyperX"),
        # dim 2 keeps 85 links like dim 1 -> trunked over its 8 neighbours
        MPHX(n=4, p=86, dims=(86, 9), links_per_dim=(85, 85),
             name="4-Plane 2D HyperX"),
        MPHX(n=8, p=256, dims=(256,), name="8-Plane 1D HyperX"),
    ]
