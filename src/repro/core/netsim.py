"""Flow-level (alpha-beta) network simulator for topology comparison.

The paper defers performance evaluation to future work (§6): "a comprehensive
performance evaluation comparing it against topologies such as Dragonfly,
Dragonfly+, multi-plane Fat-Tree ... under synthetic traffic, as well as HPC
and AI application workloads.  We anticipate demonstrating the low-latency
advantages of MPHX stemming from its reduced network diameter."  This module
builds that evaluation:

* zero-load latency  = hops * t_hop + serialization + propagation
* uniform throughput = closed-form bisection / channel-load bound
* routed throughput  = link-load accounting over whole demand matrices —
  the MPHX array engine (:mod:`routing_vec`) or, for any topology with an
  explicit switch graph (all 8 Table-2 rows), the generic graph engine
  (:mod:`routing_graph`)
* collective completion times (all-reduce / all-gather / reduce-scatter /
  all-to-all) with plane spraying — latency term counts *hops* so MPHX's
  smaller diameter shows up directly, bandwidth term counts bottleneck bytes.

All times are seconds, sizes bytes, bandwidths Gbps unless noted.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

from .hyperx import MPHX
from .topology import Topology


@dataclass(frozen=True)
class NetParams:
    """Per-hop and per-endpoint overheads (flow-level constants)."""

    t_switch: float = 300e-9        # per-switch-hop latency (pipeline+SerDes)
    t_nic: float = 600e-9           # endpoint injection/ejection overhead
    t_prop_per_hop: float = 50e-9   # ~10m optics per hop
    software_alpha: float = 1.5e-6  # per collective step software overhead


DEFAULT_NET = NetParams()


def gbps_to_Bps(gbps: float) -> float:
    return gbps * 1e9 / 8.0


# ----------------------------------------------------------------------------
# Point-to-point
# ----------------------------------------------------------------------------


def zero_load_latency(topo: Topology, msg_bytes: float = 4096,
                      net: NetParams = DEFAULT_NET, spray: bool = True) -> float:
    """Worst-case (diameter) small-message latency.

    With plane spraying the message is split across the n planes, so
    serialization uses the FULL NIC bandwidth B even though each plane's port
    runs at B/n — the multi-plane latency benefit (§2) comes from the smaller
    hop count, the bandwidth is unchanged.
    """
    hops = topo.diameter
    sw_hops = hops - 2
    bw = topo.nic_bw_gbps if spray else topo.port_gbps
    ser = msg_bytes / gbps_to_Bps(bw)
    return (net.t_nic + sw_hops * net.t_switch + hops * net.t_prop_per_hop + ser)


def avg_latency(topo: Topology, msg_bytes: float = 4096,
                net: NetParams = DEFAULT_NET) -> float:
    hops = topo.avg_hops()
    sw_hops = max(hops - 2.0, 0.0)
    ser = msg_bytes / gbps_to_Bps(topo.nic_bw_gbps)
    return net.t_nic + sw_hops * net.t_switch + hops * net.t_prop_per_hop + ser


# ----------------------------------------------------------------------------
# Synthetic-traffic throughput (closed forms)
# ----------------------------------------------------------------------------


def uniform_throughput_fraction(topo: Topology) -> float:
    """Sustainable fraction of injection bandwidth under uniform random
    traffic, bisection-bound: half the traffic crosses the bisection."""
    inj = topo.n_nics * topo.nic_bw_gbps  # total injection
    cross = inj / 2.0
    cap = 2.0 * topo.bisection_links() * topo.port_gbps  # full duplex
    return min(1.0, cap / cross)


def adversarial_throughput_fraction(topo: Topology, mode: str = "minimal",
                                    dim: int = 0,
                                    engine: str = "array") -> float:
    """Neighbor-shift adversarial pattern (MPHX only — the §5.2 scenario).

    ``engine="array"`` (default) runs the batched routing engine.  For
    ``minimal`` it matches the legacy dict engine whenever the legacy
    router enumerates all orderings (m! <= 24 mismatched-dim orderings —
    always true here, neighbor shift has m = 1); ``valiant`` additionally
    requires <= 16 deroutes per pair or the legacy engine subsamples;
    ``adaptive`` is the parallel-UGAL relaxation, not the sequential
    greedy.  Pass ``engine="dict"`` for the exact legacy behaviour.
    """
    if not isinstance(topo, MPHX):
        raise TypeError("adversarial model implemented for MPHX")
    offered = topo.nic_bw_gbps
    if engine == "array":
        from .routing_vec import VectorizedHyperXRouter, neighbor_shift_demands

        ll = VectorizedHyperXRouter(topo).route(
            neighbor_shift_demands(topo, offered, dim), mode=mode)
        return ll.saturation_throughput(offered)
    from .routing import HyperXRouter, neighbor_shift_traffic

    router = HyperXRouter(topo)
    ll = router.route(neighbor_shift_traffic(topo, offered, dim), mode=mode)
    return ll.saturation_throughput(offered)


def resolve_engine(topo: Topology, engine: str = "auto") -> str:
    """Routing engine for ``topo``: the MPHX array engine where it applies
    (fastest, coordinate arithmetic), the generic graph engine otherwise."""
    if engine == "auto":
        return "array" if isinstance(topo, MPHX) else "graph"
    if engine not in ("array", "graph"):
        raise ValueError(f"unknown engine {engine!r}")
    if engine == "array" and not isinstance(topo, MPHX):
        raise ValueError(f"array engine is MPHX-only, got {topo.name}")
    return engine


def make_router(topo: Topology, backend: str = "auto",
                engine: str = "auto"):
    """Construct the batched router for ``topo`` (shared ``route(demands,
    mode) -> link loads`` interface across engines)."""
    if resolve_engine(topo, engine) == "graph":
        from .routing_graph import GraphRouter

        return GraphRouter(topo, backend=backend)
    from .routing_vec import VectorizedHyperXRouter

    return VectorizedHyperXRouter(topo, backend=backend)


def pattern_throughput(topo: Topology, demands, mode: str = "adaptive",
                       backend: str = "auto", engine: str = "auto",
                       simulate: bool = False) -> dict:
    """Saturation throughput of one :class:`~.routing_vec.DemandArrays`
    traffic matrix on one plane, via the batched engine for ``topo``.

    ``simulate=True`` additionally runs the flow simulator's steady-state
    load accounting (:mod:`repro.sim.fairshare`) over the same routes and
    reports the cross-check (``max_util_sim`` and the max absolute
    utilization difference — the 1e-6 agreement
    ``results/BENCH_flow_sim.json`` pins).  Requires a fixed path spread
    (``minimal``, or ``valiant`` on the array engine) — note the default
    mode here is ``adaptive``, so ``simulate=True`` needs an explicit
    ``mode``.
    """
    if simulate and mode == "adaptive":
        raise ValueError("simulate=True needs a static path spread "
                         "(minimal, or valiant on the array engine); "
                         "adaptive re-routes under load — pass "
                         "mode='minimal'")
    router = make_router(topo, backend=backend, engine=engine)
    ll = router.route(demands, mode)
    out = {
        "max_util": ll.max_utilization(),
        "mean_util": ll.mean_utilization(),
        "throughput_fraction": ll.saturation_throughput(),
        "total_load_gbps": ll.total_load(),
    }
    if simulate:
        from repro.sim.fairshare import flow_incidence

        inc = flow_incidence(router, demands, mode)
        u_sim = inc.utilization(demands.gbps)
        u_analytic = ll.utilization_array()
        out["max_util_sim"] = float(u_sim.max()) if u_sim.size else 0.0
        out["sim_max_abs_util_diff"] = (
            float(abs(u_sim - u_analytic).max()) if u_sim.size else 0.0)
    return out


def latency_under_load(topo: Topology, utilization: float,
                       msg_bytes: float = 4096,
                       net: NetParams = DEFAULT_NET, router=None) -> float:
    """Average message latency at a given bottleneck utilization.

    Flow-level M/M/1-style queueing approximation: each switch hop's service
    time inflates by ``rho / (1 - rho)``.  Saturated (util >= 1) returns inf.

    With a ``router`` (a :func:`make_router` product) the switch-hop count
    is the router's *measured* mean over NIC-weighted switch pairs
    (``mean_switch_hops``); without one it falls back to the
    ``avg_hops() - 2`` heuristic, which over-counts queueing hops on
    topologies whose NIC-NIC walks are not uniform (e.g. fat-trees where
    many pairs stay under one leaf).
    """
    if utilization >= 1.0:
        return math.inf
    base = avg_latency(topo, msg_bytes, net)
    sw_hops = (router.mean_switch_hops() if router is not None
               else max(topo.avg_hops() - 2.0, 0.0))
    rho = max(utilization, 0.0)
    return base + sw_hops * net.t_switch * rho / (1.0 - rho)


def load_sweep(topo: Topology, demand_builder, mode: str = "adaptive",
               load_fractions: "list[float]" = (0.1, 0.25, 0.5, 0.75, 0.9, 1.0),
               msg_bytes: float = 4096, backend: str = "auto",
               net: NetParams = DEFAULT_NET,
               engine: str = "auto", router=None,
               simulate: bool = False,
               flow_time_s: float = 1e-3,
               sim_backend: "str | None" = None) -> "list[dict]":
    """Latency/throughput vs offered load for one traffic scenario.

    ``demand_builder(topo, offered_per_nic_gbps) -> DemandArrays``.  The
    per-link utilizations scale linearly with offered load for ``minimal``/
    ``valiant`` (fixed path spread); ``adaptive`` re-routes at every level,
    so each level is simulated independently.  ``engine`` picks the batched
    router (:func:`make_router`): MPHX array engine or generic graph engine;
    pass a prebuilt ``router`` to reuse its graph/BFS state across sweeps.

    ``simulate=True`` adds *measured* flow-completion-time columns per
    level (``fct_p50_us`` ... ``sim_delivered_fraction``) from the flow
    simulator (:mod:`repro.sim.events`): each demand pair becomes one
    finite flow sized to transfer for ``flow_time_s`` at its offered rate,
    and the event loop reports real FCT percentiles under max-min fair
    sharing.  Requires a fixed path spread (``minimal``, or ``valiant``
    on the array engine) — ``adaptive`` has no static per-flow routes.

    ``sim_backend`` picks the fair-share solver path (``numpy`` / ``jax``
    / ``pallas`` / ``auto`` — see :mod:`repro.sim.fairshare`); it defaults
    to following ``backend`` (``jax`` routing → jit simulation).
    """
    if router is None:
        router = make_router(topo, backend=backend, engine=engine)
    if simulate and mode == "adaptive":
        raise ValueError("simulate=True needs a static path spread "
                         "(minimal, or valiant on the array engine); "
                         "adaptive re-routes under load")
    rows = []
    base_ll = None
    sim_inc = None
    for frac in load_fractions:
        offered = frac * topo.nic_bw_gbps
        demands = None
        if frac == 0:
            max_util = 0.0
        elif mode == "adaptive" or base_ll is None:
            demands = demand_builder(topo, offered)
            ll = router.route(demands, mode)
            if mode != "adaptive":
                base_ll, base_frac = ll, frac
            max_util = ll.max_utilization()
        else:
            max_util = base_ll.max_utilization() * frac / base_frac
        row = {
            "offered_fraction": frac,
            "offered_per_nic_gbps": offered,
            "max_util": round(max_util, 6),
            "throughput_fraction":
                1.0 if max_util == 0 else round(min(1.0, 1.0 / max_util), 6),
            "delivered_fraction": round(min(frac, frac / max_util)
                                        if max_util > 0 else frac, 6),
            "latency_us": (round(latency_under_load(topo, max_util,
                                                    msg_bytes, net,
                                                    router=router) * 1e6, 3)
                           if max_util < 1.0 else None),
        }
        if simulate and frac > 0:
            from repro.sim.events import simulate_demands
            from repro.sim.fairshare import flow_incidence

            if demands is None:
                demands = demand_builder(topo, offered)
            if sim_inc is None:
                # static spreads don't depend on offered load — one
                # extraction serves every level of the sweep
                sim_inc = flow_incidence(router, demands, mode)
            row.update(simulate_demands(
                router, demands, flow_time_s, mode=mode, net=net,
                inc=sim_inc,
                backend=backend if sim_backend is None else sim_backend))
        rows.append(row)
    return rows


# ----------------------------------------------------------------------------
# Collectives
# ----------------------------------------------------------------------------


@dataclass
class CollectiveEstimate:
    kind: str
    algo: str
    bytes_per_nic: float
    steps: int
    hops_per_step: float
    latency_s: float          # alpha terms
    bandwidth_s: float        # beta  terms

    @property
    def total_s(self) -> float:
        return self.latency_s + self.bandwidth_s

    def row(self) -> dict:
        return {
            "kind": self.kind, "algo": self.algo,
            "bytes_per_nic": int(self.bytes_per_nic),
            "steps": self.steps,
            "latency_us": round(self.latency_s * 1e6, 2),
            "bandwidth_us": round(self.bandwidth_s * 1e6, 2),
            "total_us": round(self.total_s * 1e6, 2),
        }


def _alpha(topo: Topology, hops: float, net: NetParams) -> float:
    sw_hops = max(hops - 2.0, 0.0)
    return (net.software_alpha + net.t_nic + sw_hops * net.t_switch
            + hops * net.t_prop_per_hop)


def ring_allreduce_time(topo: Topology, bytes_per_nic: float, m: int | None = None,
                        net: NetParams = DEFAULT_NET) -> CollectiveEstimate:
    """Classic ring all-reduce over m endpoints: 2(m-1) steps of size S/m.

    Ring neighbours are placed adjacently, so each step traverses the
    topology's *minimum* NIC-NIC distance (3 hops on any of these nets:
    NIC->sw->sw->NIC, or 2 if same switch).  Bandwidth term uses the full NIC
    bandwidth (all planes sprayed).
    """
    m = m or topo.n_nics
    steps = 2 * (m - 1)
    chunk = bytes_per_nic / m
    # consecutive ring ranks share a switch p-at-a-time
    same_switch = getattr(topo, "p", 1)
    hops = 2.0 if same_switch > 1 else 3.0
    lat = steps * _alpha(topo, hops, net)
    bw = steps * chunk / gbps_to_Bps(topo.nic_bw_gbps)
    return CollectiveEstimate("all_reduce", "ring", bytes_per_nic, steps, hops,
                              lat, bw)


def hierarchical_allreduce_time(topo: MPHX, bytes_per_nic: float,
                                net: NetParams = DEFAULT_NET
                                ) -> CollectiveEstimate:
    """MPHX-native hierarchical all-reduce (the paper-technique schedule):

      stage 0: reduce-scatter among the p NICs of each switch (2 hops/step)
      stage i: all-reduce across dimension i (full mesh -> one-step
               direct exchange per dim, a 'butterfly over the mesh')
      stage 0': all-gather among the p NICs of each switch

    Every plane carries 1/n of the bytes concurrently (plane spraying).
    """
    p = topo.p
    lat = 0.0
    bw = 0.0
    steps = 0
    # stage 0: RS over p endpoints via their shared switch, ring of p
    if p > 1:
        s = (p - 1)
        steps += 2 * s  # RS now + AG at the end
        lat += 2 * s * _alpha(topo, 2.0, net)
        bw += 2 * s * (bytes_per_nic / p) / gbps_to_Bps(topo.nic_bw_gbps)
    shard = bytes_per_nic / max(p, 1)
    # dimension stages: all-to-all exchange within the full mesh (1 switch hop)
    for d in topo.dims:
        if d <= 1:
            continue
        # reduce-scatter + all-gather across d peers, direct mesh: 2 steps
        # each moving shard*(d-1)/d bytes
        steps += 2
        lat += 2 * _alpha(topo, 3.0, net)
        bw += 2 * shard * (d - 1) / d / gbps_to_Bps(topo.nic_bw_gbps)
        shard = shard / d
    return CollectiveEstimate("all_reduce", "mphx-hierarchical", bytes_per_nic,
                              steps, 3.0, lat, bw)


def hd_allreduce_time(topo: Topology, bytes_per_nic: float,
                      m: int | None = None,
                      net: NetParams = DEFAULT_NET) -> CollectiveEstimate:
    """Recursive halving-doubling all-reduce: 2*log2(m) steps.

    Step k exchanges with a peer 2^k ranks away, so early steps stay local and
    late steps traverse up to the topology diameter; we charge the average of
    min-distance and diameter per step (exact distances depend on placement).
    """
    m = m or topo.n_nics
    k = max(1, math.ceil(math.log2(m)))
    steps = 2 * k
    hops = (3.0 + float(topo.diameter)) / 2.0
    lat = steps * _alpha(topo, hops, net)
    bw = 2.0 * (m - 1) / m * bytes_per_nic / gbps_to_Bps(topo.nic_bw_gbps)
    return CollectiveEstimate("all_reduce", "halving-doubling", bytes_per_nic,
                              steps, hops, lat, bw)


def alltoall_time(topo: Topology, bytes_per_nic: float,
                  net: NetParams = DEFAULT_NET) -> CollectiveEstimate:
    """All-to-all of S bytes per NIC (total), uniform: bisection-bound."""
    frac = uniform_throughput_fraction(topo)
    eff = gbps_to_Bps(topo.nic_bw_gbps) * frac
    lat = _alpha(topo, float(topo.diameter), net)
    return CollectiveEstimate("all_to_all", "direct", bytes_per_nic, 1,
                              float(topo.diameter), lat, bytes_per_nic / eff)


def allgather_time(topo: Topology, bytes_per_nic: float, m: int | None = None,
                   net: NetParams = DEFAULT_NET) -> CollectiveEstimate:
    m = m or topo.n_nics
    steps = m - 1
    hops = 3.0
    lat = steps * _alpha(topo, hops, net)
    bw = steps * (bytes_per_nic) / gbps_to_Bps(topo.nic_bw_gbps)
    return CollectiveEstimate("all_gather", "ring", bytes_per_nic, steps, hops,
                              lat, bw)


def allreduce_time(topo: Topology, bytes_per_nic: float,
                   net: NetParams = DEFAULT_NET) -> CollectiveEstimate:
    """Best available all-reduce schedule for the topology."""
    cands = [ring_allreduce_time(topo, bytes_per_nic, net=net),
             hd_allreduce_time(topo, bytes_per_nic, net=net)]
    if isinstance(topo, MPHX):
        cands.append(hierarchical_allreduce_time(topo, bytes_per_nic, net))
    return min(cands, key=lambda c: c.total_s)


# ----------------------------------------------------------------------------
# Cross-topology comparison report (benchmarks/bench_netsim_traffic.py)
# ----------------------------------------------------------------------------


def compare_topologies(topos: list[Topology], msg_bytes: float = 4096,
                       collective_mb: float = 256.0,
                       net: NetParams = DEFAULT_NET) -> list[dict]:
    rows = []
    for t in topos:
        ar = allreduce_time(t, collective_mb * 2**20, net)
        rows.append({
            "topology": t.name,
            "diameter": t.diameter,
            "avg_hops": round(t.avg_hops(), 2),
            "zero_load_us": round(zero_load_latency(t, msg_bytes, net) * 1e6, 3),
            "avg_latency_us": round(avg_latency(t, msg_bytes, net) * 1e6, 3),
            "uniform_thpt": round(uniform_throughput_fraction(t), 3),
            f"allreduce_{int(collective_mb)}MB_ms":
                round(ar.total_s * 1e3, 3),
            "allreduce_algo": ar.algo,
        })
    return rows
