"""HyperX routing: dimension-ordered minimal, DAL non-minimal, and the
adaptive-routing necessity argument of paper §5.2.

§5.2: "the number of links between adjacent switches within a single plane is
limited; consequently, the bandwidth of minimal paths is relatively low during
cross-switch communication, necessitating the use of non-minimal paths".

We implement three routing modes over a plane's :class:`SwitchGraph`:

* ``minimal``  — split each demand equally over all minimal paths
  (ECMP across dimension orderings; on HyperX a minimal path corrects each
  mismatched coordinate exactly once, in some order).
* ``valiant``  — per-dimension deroute via a random intermediate coordinate
  (DAL's non-minimal option, modeled as uniform spreading over deroutes).
* ``adaptive`` — greedy online DAL: each demand unit takes the candidate
  (minimal or 1-deroute) path whose bottleneck link is least loaded.  This is
  an idealized UGAL/DAL and upper-bounds real adaptive behaviour.

Link loads are per *directed* link, in units of offered Gbps; utilization is
load / (multiplicity * port_gbps).
"""

from __future__ import annotations

import itertools
import random
from collections import defaultdict
from dataclasses import dataclass, field

from .hyperx import MPHX


Edge = tuple[int, int]  # directed (u, v)


@dataclass
class LinkLoads:
    """Directed link loads in Gbps over one plane."""

    topo: MPHX
    loads: dict[Edge, float] = field(default_factory=lambda: defaultdict(float))

    def add_path(self, switches: list[int], gbps: float) -> None:
        for u, v in zip(switches, switches[1:]):
            self.loads[(u, v)] += gbps

    def utilization(self) -> dict[Edge, float]:
        g = self.topo.build_graph() if not hasattr(self, "_g") else self._g
        self._g = g
        cap = self.topo.port_gbps
        return {e: l / (g.multiplicity(*e) * cap) for e, l in self.loads.items()}

    def max_utilization(self) -> float:
        u = self.utilization()
        return max(u.values()) if u else 0.0

    def mean_utilization(self) -> float:
        u = self.utilization()
        return sum(u.values()) / len(u) if u else 0.0

    def saturation_throughput(self, offered_per_nic_gbps: float) -> float:
        """Fraction of offered load sustainable before the hottest link
        saturates (>=1.0 means the pattern fits at full injection)."""
        mx = self.max_utilization()
        return 1.0 if mx == 0 else min(1.0, 1.0 / mx)


class HyperXRouter:
    """Routing over one plane of an MPHX network."""

    def __init__(self, topo: MPHX, seed: int = 0):
        self.topo = topo
        self.rng = random.Random(seed)
        self.graph = topo.build_graph()

    # ------------------------------------------------------------ paths ----

    def mismatched_dims(self, src: int, dst: int) -> list[int]:
        cs, cd = self.topo.id_to_coord(src), self.topo.id_to_coord(dst)
        return [i for i, (a, b) in enumerate(zip(cs, cd)) if a != b]

    def minimal_paths(self, src: int, dst: int,
                      max_orderings: int = 24) -> list[list[int]]:
        """Minimal paths = one hop per mismatched dim, over dim orderings."""
        dims = self.mismatched_dims(src, dst)
        if not dims:
            return [[src]]
        orderings = list(itertools.permutations(dims))
        if len(orderings) > max_orderings:
            orderings = self.rng.sample(orderings, max_orderings)
        cd = self.topo.id_to_coord(dst)
        paths = []
        for order in orderings:
            cur = list(self.topo.id_to_coord(src))
            path = [src]
            for dim in order:
                cur[dim] = cd[dim]
                path.append(self.topo.coord_to_id(tuple(cur)))
            paths.append(path)
        return paths

    def deroute_paths(self, src: int, dst: int,
                      max_paths: int = 16) -> list[list[int]]:
        """DAL non-minimal: deroute via one intermediate coordinate in ONE
        dimension (at most one deroute per path, as in DAL)."""
        cs, cd = self.topo.id_to_coord(src), self.topo.id_to_coord(dst)
        dims = self.mismatched_dims(src, dst)
        paths = []
        for dim in dims or range(self.topo.D):
            d = self.topo.dims[dim]
            for via in range(d):
                if via == cs[dim] or via == cd[dim]:
                    continue
                mid1 = list(cs)
                mid1[dim] = via
                # after deroute, finish minimally in dimension order
                path = [src, self.topo.coord_to_id(tuple(mid1))]
                cur = mid1
                for dim2 in range(self.topo.D):
                    if cur[dim2] != cd[dim2]:
                        cur = list(cur)
                        cur[dim2] = cd[dim2]
                        path.append(self.topo.coord_to_id(tuple(cur)))
                paths.append(path)
        if len(paths) > max_paths:
            paths = self.rng.sample(paths, max_paths)
        return paths

    # ------------------------------------------------------- load routing ----

    def route(self, demands: dict[tuple[int, int], float],
              mode: str = "minimal", granularity: int = 8) -> LinkLoads:
        """Route a switch-level demand matrix; return per-link loads.

        demands: {(src_switch, dst_switch): gbps}
        """
        ll = LinkLoads(self.topo)
        if mode == "minimal":
            for (s, d), gbps in demands.items():
                paths = self.minimal_paths(s, d)
                for p in paths:
                    ll.add_path(p, gbps / len(paths))
        elif mode == "valiant":
            for (s, d), gbps in demands.items():
                paths = self.minimal_paths(s, d) + self.deroute_paths(s, d)
                for p in paths:
                    ll.add_path(p, gbps / len(paths))
        elif mode == "adaptive":
            # greedy online DAL over demand quanta
            cap = self.topo.port_gbps
            for (s, d), gbps in sorted(demands.items()):
                cands = self.minimal_paths(s, d) + self.deroute_paths(s, d)
                quantum = gbps / granularity
                for _ in range(granularity):
                    best, best_cost = None, None
                    for p in cands:
                        # bottleneck utilization if this quantum is added,
                        # with a mild hop penalty to prefer minimal at low load
                        cost = max(
                            (ll.loads[(u, v)] + quantum)
                            / (self.graph.multiplicity(u, v) * cap)
                            for u, v in zip(p, p[1:])
                        ) + 0.01 * (len(p) - 1)
                        if best_cost is None or cost < best_cost:
                            best, best_cost = p, cost
                    ll.add_path(best, quantum)
        else:
            raise ValueError(f"unknown mode {mode}")
        return ll


# ----------------------------------------------------------------------------
# Switch-level traffic patterns (per plane)
# ----------------------------------------------------------------------------


def uniform_traffic(topo: MPHX, offered_per_nic_gbps: float
                    ) -> dict[tuple[int, int], float]:
    """Each NIC sprays uniformly to all other NICs -> switch-level matrix
    (uniform over other switches; same-switch NIC pairs never hit the fabric).

    O(S^2) pairs — intended for plane sizes up to a few thousand switches;
    large-scale uniform throughput has a closed form in :mod:`netsim`.
    """
    S = topo.switches_per_plane
    per_switch_out = topo.p * offered_per_nic_gbps / topo.n  # this plane's share
    return {(s, d): per_switch_out / (S - 1)
            for s in range(S) for d in range(S) if s != d}


def neighbor_shift_traffic(topo: MPHX, offered_per_nic_gbps: float,
                           dim: int = 0) -> dict[tuple[int, int], float]:
    """Adversarial for minimal routing: every switch sends all traffic to its
    +1 neighbour in ``dim`` — exactly one direct link (x multiplicity) exists,
    so minimal-path bandwidth is thin (paper §5.2)."""
    per_switch_out = topo.p * offered_per_nic_gbps / topo.n
    demands = {}
    for s in range(topo.switches_per_plane):
        c = list(topo.id_to_coord(s))
        c[dim] = (c[dim] + 1) % topo.dims[dim]
        demands[(s, topo.coord_to_id(tuple(c)))] = per_switch_out
    return demands


def bit_complement_traffic(topo: MPHX, offered_per_nic_gbps: float
                           ) -> dict[tuple[int, int], float]:
    per_switch_out = topo.p * offered_per_nic_gbps / topo.n
    demands = {}
    for s in range(topo.switches_per_plane):
        c = topo.id_to_coord(s)
        cc = tuple(D - 1 - x for x, D in zip(c, topo.dims))
        d = topo.coord_to_id(cc)
        if d != s:
            demands[(s, d)] = per_switch_out
    return demands


def route_demands(topo: MPHX, demands: dict[tuple[int, int], float],
                  mode: str = "minimal", engine: str = "dict",
                  backend: str = "auto", seed: int = 0):
    """Route a demand dict with either engine.

    ``engine="dict"`` — the per-flow Python reference implementation above.
    ``engine="array"`` — the batched :mod:`repro.core.routing_vec` engine
    (same link loads for ``minimal``/``valiant``; parallel-UGAL relaxation
    for ``adaptive``).  Returns an object with the shared LinkLoads
    interface (``max_utilization`` / ``mean_utilization`` /
    ``saturation_throughput``).
    """
    if engine == "dict":
        return HyperXRouter(topo, seed=seed).route(demands, mode=mode)
    if engine == "array":
        from .routing_vec import VectorizedHyperXRouter, demands_from_dict

        router = VectorizedHyperXRouter(topo, backend=backend)
        return router.route(demands_from_dict(demands), mode=mode)
    raise ValueError(f"unknown engine {engine!r}")


def minimal_vs_adaptive_report(topo: MPHX, offered_per_nic_gbps: float = 200.0,
                               dim: int = 0) -> dict:
    """Quantify §5.2: adjacent-switch traffic throughput, minimal vs DAL."""
    router = HyperXRouter(topo)
    demands = neighbor_shift_traffic(topo, offered_per_nic_gbps, dim)
    out = {}
    for mode in ("minimal", "valiant", "adaptive"):
        ll = router.route(demands, mode=mode)
        out[mode] = {
            "max_util": round(ll.max_utilization(), 4),
            "throughput_fraction": round(
                ll.saturation_throughput(offered_per_nic_gbps), 4),
        }
    # analytic check: minimal uses the single direct trunk: load/cap =
    # p*B_eff / (mult * port_bw)
    mult = topo.links_per_dim[dim] / (topo.dims[dim] - 1)
    out["analytic_minimal_max_util"] = round(
        (topo.p * offered_per_nic_gbps / topo.n) / (mult * topo.port_gbps), 4)
    return out
