"""Vectorized HyperX routing — batched array engine behind ``repro.experiments``.

The legacy :mod:`repro.core.routing` enumerates per-flow Python paths and
accumulates link loads into dicts; that cannot reach Table-2 scale
(MPHX(4,86,86,9) hosts 66,564 NICs) or sweep many traffic scenarios.  This
module recomputes the same quantities over batched integer/float arrays:

* a whole demand matrix is three parallel arrays ``(src, dst, gbps)``;
* directed links of one plane live in a flat *edge-slot* tensor indexed by
  ``(switch, dimension, target coordinate)`` (:class:`EdgeIndex`);
* path enumeration becomes a walk over dimension *orderings* shared by all
  demands, and link-load accounting a scatter-add over edge slots
  (``np.bincount`` / ``jnp .at[].add``) instead of dict updates.

Equivalence with the legacy router (mode ``minimal`` and ``valiant``) is
exact — the ECMP split over orderings/deroutes is reproduced analytically —
whenever the legacy router does not randomly subsample paths, i.e. for
``m! <= max_orderings`` and ``n_deroutes <= max_paths``; this holds for every
small topology the tests compare on, and ``tests/test_experiments.py`` pins
it to 1e-9.  Mode ``adaptive`` is a *parallel* UGAL/DAL relaxation (loads
update once per quantum round across all demands, not after every greedy
placement), so it tracks but does not bit-match the legacy greedy router.

Backend: ``jax.numpy`` when available (``backend="jax"`` or ``"auto"``),
plain numpy otherwise — the engine is pure index arithmetic, so both give
identical results.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Sequence

import numpy as np

from .hyperx import MPHX

Edge = tuple[int, int]


# ---------------------------------------------------------------------------
# Backend selection
# ---------------------------------------------------------------------------


def get_backend(backend: str = "auto"):
    """Return ``(name, xp)`` — ``jax.numpy`` with a numpy fallback.

    ``auto`` picks jax only when 64-bit mode is on: without
    ``jax_enable_x64`` the accumulators truncate to float32, which would
    break the 1e-9 equivalence guarantee against the legacy dict engine.
    """
    if backend == "numpy":
        return "numpy", np
    if backend in ("auto", "jax"):
        try:
            import jax
            import jax.numpy as jnp

            if backend == "jax" or jax.config.jax_enable_x64:
                return "jax", jnp
        except ImportError:
            if backend == "jax":
                raise
        return "numpy", np
    raise ValueError(f"unknown backend {backend!r}")


def _scatter_add(xp, loads, idx, w):
    """loads[idx] += w, vectorized (duplicate indices accumulate)."""
    if xp is np:
        loads += np.bincount(idx, weights=w, minlength=loads.size)
        return loads
    return loads.at[idx].add(w)


def backend_zeros(xp, n: int):
    """A length-``n`` float accumulator on the selected backend (float64
    under numpy or jax-x64, float32 otherwise)."""
    if xp is np:
        return np.zeros(n)
    import jax

    dtype = xp.float64 if jax.config.jax_enable_x64 else xp.float32
    return xp.zeros(n, dtype=dtype)


class BaseLinkLoads:
    """Shared result API of the batched routing engines.

    Subclasses hold per-link ``loads`` (offered Gbps, backend array) and
    expose the matching capacities via :meth:`capacity_array`; everything
    downstream (``netsim.load_sweep``, the sweep suite, benchmarks) only
    touches this interface.
    """

    loads = None  # set by subclasses

    def capacity_array(self) -> np.ndarray:
        raise NotImplementedError

    def _np_loads(self) -> np.ndarray:
        return np.asarray(self.loads)

    def utilization_array(self) -> np.ndarray:
        l = self._np_loads()
        cap = self.capacity_array()
        with np.errstate(divide="ignore", invalid="ignore"):
            return np.where(cap > 0, l / cap, 0.0)

    def max_utilization(self) -> float:
        u = self.utilization_array()
        return float(u.max()) if u.size else 0.0

    def mean_utilization(self) -> float:
        """Mean over *loaded* slots (legacy averages over its dict entries)."""
        u = self.utilization_array()
        nz = self._np_loads() > 0
        return float(u[nz].mean()) if nz.any() else 0.0

    def saturation_throughput(self, offered_per_nic_gbps: float = 0.0) -> float:
        mx = self.max_utilization()
        return 1.0 if mx == 0 else min(1.0, 1.0 / mx)

    def total_load(self) -> float:
        return float(self._np_loads().sum())


# ---------------------------------------------------------------------------
# Edge-slot tensor
# ---------------------------------------------------------------------------


@dataclass
class EdgeIndex:
    """Flat index over the directed links of one MPHX plane.

    Slot of the directed link leaving switch ``u`` along dimension ``i``
    toward in-dimension coordinate ``c``:

        slot(u, i, c) = dim_base[i] + u * dims[i] + c

    ``dim_base[i] = S * sum(dims[:i])``.  Slots with ``c == coord_i(u)``
    (self-links) exist in the tensor but never receive load.  Capacity of
    every dim-``i`` slot is ``multiplicity_i * port_gbps`` where
    ``multiplicity_i = links_per_dim[i] / (dims[i] - 1)`` — MPHX trunking
    (Table 2's MPHX(4,86,86,9)) is uniform within a dimension.
    """

    topo: MPHX

    def __post_init__(self):
        t = self.topo
        dims = np.asarray(t.dims, dtype=np.int64)
        self.dims = dims
        self.D = len(t.dims)
        self.S = t.switches_per_plane
        self.dim_base = self.S * np.concatenate(
            ([0], np.cumsum(dims)[:-1])).astype(np.int64)
        self.n_slots = int(self.S * dims.sum())
        # coord <-> id strides (row-major, matching MPHX.coord_to_id)
        stride = np.ones(self.D, dtype=np.int64)
        for i in range(self.D - 2, -1, -1):
            stride[i] = stride[i + 1] * dims[i + 1]
        self.stride = stride
        # per-slot capacity in Gbps
        mult = np.array([l / (d - 1) if d > 1 else 0.0
                         for d, l in zip(t.dims, t.links_per_dim)])
        cap = np.empty(self.n_slots, dtype=np.float64)
        for i in range(self.D):
            lo = self.dim_base[i]
            hi = lo + self.S * dims[i]
            cap[lo:hi] = mult[i] * t.port_gbps
        self.capacity = cap

    # ------------------------------------------------------------ coords ----

    def ids_to_coords(self, ids: np.ndarray) -> np.ndarray:
        """(M,) switch ids -> (M, D) coordinates."""
        out = np.empty((ids.shape[0], self.D), dtype=np.int64)
        rem = ids.astype(np.int64)
        for i in range(self.D - 1, -1, -1):
            out[:, i] = rem % self.dims[i]
            rem = rem // self.dims[i]
        return out

    def coords_to_ids(self, coords: np.ndarray) -> np.ndarray:
        return coords @ self.stride

    def slots(self, u_ids, dim: int, c_target):
        return self.dim_base[dim] + u_ids * int(self.dims[dim]) + c_target

    def slot_to_edge(self, slot: int) -> Edge:
        """Flat slot -> directed (u, v) switch pair."""
        dim = int(np.searchsorted(self.dim_base, slot, side="right") - 1)
        rel = slot - int(self.dim_base[dim])
        u, c = divmod(rel, int(self.dims[dim]))
        coord = list(self.topo.id_to_coord(u))
        coord[dim] = c
        return u, self.topo.coord_to_id(tuple(coord))


class ArrayLinkLoads(BaseLinkLoads):
    """Array counterpart of :class:`repro.core.routing.LinkLoads`."""

    def __init__(self, index: EdgeIndex, loads):
        self.index = index
        self.topo = index.topo
        self.loads = loads

    def capacity_array(self) -> np.ndarray:
        return self.index.capacity

    def to_dict(self) -> dict[Edge, float]:
        """Nonzero loads as the legacy ``{(u, v): gbps}`` dict."""
        l = self._np_loads()
        out = {}
        for slot in np.nonzero(l)[0]:
            out[self.index.slot_to_edge(int(slot))] = float(l[slot])
        return out


# ---------------------------------------------------------------------------
# Demand matrices as arrays
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DemandArrays:
    """A switch-level traffic matrix as three parallel arrays."""

    src: np.ndarray    # (M,) int64 switch ids
    dst: np.ndarray    # (M,) int64 switch ids
    gbps: np.ndarray   # (M,) float64 offered Gbps per (src, dst) pair

    def __post_init__(self):
        assert self.src.shape == self.dst.shape == self.gbps.shape

    @property
    def n(self) -> int:
        return int(self.src.shape[0])

    def total_gbps(self) -> float:
        return float(self.gbps.sum())

    def to_dict(self) -> dict[Edge, float]:
        out: dict[Edge, float] = {}
        # accumulate: a matrix may list the same (src, dst) pair twice
        # (e.g. hotspot = uniform part + incast part)
        for s, d, g in zip(self.src, self.dst, self.gbps):
            key = (int(s), int(d))
            out[key] = out.get(key, 0.0) + float(g)
        return out


def demands_from_dict(demands: dict[Edge, float]) -> DemandArrays:
    if not demands:
        z = np.zeros(0, dtype=np.int64)
        return DemandArrays(z, z.copy(), np.zeros(0))
    items = sorted(demands.items())
    src = np.array([s for (s, _), _ in items], dtype=np.int64)
    dst = np.array([d for (_, d), _ in items], dtype=np.int64)
    g = np.array([v for _, v in items], dtype=np.float64)
    return DemandArrays(src, dst, g)


def _per_switch_out(topo: MPHX, offered_per_nic_gbps: float) -> float:
    # one plane's share of each switch's p NICs worth of injection
    return topo.p * offered_per_nic_gbps / topo.n


def uniform_demands(topo: MPHX, offered_per_nic_gbps: float) -> DemandArrays:
    """All-pairs uniform spray (matches ``routing.uniform_traffic``)."""
    S = topo.switches_per_plane
    s, d = np.meshgrid(np.arange(S, dtype=np.int64),
                       np.arange(S, dtype=np.int64), indexing="ij")
    mask = s != d
    g = np.full(int(mask.sum()),
                _per_switch_out(topo, offered_per_nic_gbps) / (S - 1))
    return DemandArrays(s[mask], d[mask], g)


def neighbor_shift_demands(topo: MPHX, offered_per_nic_gbps: float,
                           dim: int = 0) -> DemandArrays:
    """+1 shift along ``dim`` (adversarial for minimal routing, §5.2)."""
    idx = EdgeIndex(topo)
    src = np.arange(topo.switches_per_plane, dtype=np.int64)
    c = idx.ids_to_coords(src)
    c[:, dim] = (c[:, dim] + 1) % topo.dims[dim]
    dst = idx.coords_to_ids(c)
    g = np.full(src.shape, _per_switch_out(topo, offered_per_nic_gbps))
    return DemandArrays(src, dst, g)


def bit_complement_demands(topo: MPHX, offered_per_nic_gbps: float
                           ) -> DemandArrays:
    idx = EdgeIndex(topo)
    src = np.arange(topo.switches_per_plane, dtype=np.int64)
    c = idx.ids_to_coords(src)
    cc = (np.asarray(topo.dims, dtype=np.int64) - 1)[None, :] - c
    dst = idx.coords_to_ids(cc)
    keep = dst != src
    g = np.full(src.shape, _per_switch_out(topo, offered_per_nic_gbps))
    return DemandArrays(src[keep], dst[keep], g[keep])


def transpose_demands(topo: MPHX, offered_per_nic_gbps: float) -> DemandArrays:
    """Matrix-transpose permutation: swap the first two (equal) dims.

    Classic adversarial pattern for dimension-ordered routing; defined when
    the topology has >= 2 dimensions and ``dims[0] == dims[1]``.
    """
    if topo.D < 2 or topo.dims[0] != topo.dims[1]:
        raise ValueError(f"transpose undefined for dims={topo.dims}")
    idx = EdgeIndex(topo)
    src = np.arange(topo.switches_per_plane, dtype=np.int64)
    c = idx.ids_to_coords(src)
    ct = c.copy()
    ct[:, 0], ct[:, 1] = c[:, 1], c[:, 0]
    dst = idx.coords_to_ids(ct)
    keep = dst != src
    g = np.full(src.shape, _per_switch_out(topo, offered_per_nic_gbps))
    return DemandArrays(src[keep], dst[keep], g[keep])


def hotspot_demands(topo: MPHX, offered_per_nic_gbps: float,
                    hot: int = 0, hot_fraction: float = 0.5) -> DemandArrays:
    """Every switch sends ``hot_fraction`` of its load to one hot switch and
    sprays the rest uniformly (incast — the hot switch's access links and
    surrounding fabric saturate first)."""
    uni = uniform_demands(topo, offered_per_nic_gbps * (1 - hot_fraction))
    src = np.arange(topo.switches_per_plane, dtype=np.int64)
    keep = src != hot
    g = np.full(src.shape,
                _per_switch_out(topo, offered_per_nic_gbps) * hot_fraction)
    return DemandArrays(
        np.concatenate([uni.src, src[keep]]),
        np.concatenate([uni.dst, np.full(int(keep.sum()), hot,
                                         dtype=np.int64)]),
        np.concatenate([uni.gbps, g[keep]]),
    )


def ring_demands(topo: MPHX, offered_per_nic_gbps: float) -> DemandArrays:
    """Steady-state link pattern of a switch-id-ordered ring collective
    (ring all-reduce / all-gather): switch s -> s+1 mod S at full rate."""
    S = topo.switches_per_plane
    src = np.arange(S, dtype=np.int64)
    dst = (src + 1) % S
    g = np.full(S, _per_switch_out(topo, offered_per_nic_gbps))
    return DemandArrays(src, dst, g)


# ---------------------------------------------------------------------------
# Vectorized router
# ---------------------------------------------------------------------------


class IncidenceCacheMixin:
    """Pair-level cache for per-flow incidence extraction.

    A fixed path spread depends only on the (src, dst) switch pair and the
    mode — not on the offered Gbps — so the per-pair COO rows
    ``(edge_slots, fracs)`` can be reused across flow sets.  The epoch /
    batch loops of the flow simulator re-extract the same pairs over and
    over (collective phases reuse a schedule's pairs every phase; epoch
    re-solves reuse the whole flow set); routing them through
    :meth:`incidence_cached` only walks pairs never seen before.

    Cache effectiveness is reported uniformly by both engines through a
    per-router :class:`~repro.telemetry.MetricsRegistry`
    (``router.metrics``): ``incidence.walks`` counts *engine walks* (full
    :meth:`incidence` extractions — the hook ``tests/test_sim_scale.py``
    uses to assert re-solves stop re-extracting), and
    ``incidence.cache_hits`` / ``incidence.cache_misses`` count pairs
    served from / added to the cache.  When an ambient registry is
    collecting (:func:`repro.telemetry.collecting`), the same events are
    mirrored there.  ``incidence_calls`` remains as a deprecated alias of
    the walk counter.  Invalidate with :meth:`reset_incidence_cache`
    after anything that changes routes (e.g. failure masking builds a new
    router, which starts cold anyway).
    """

    @property
    def metrics(self):
        """This router's private metrics registry (lazy)."""
        m = getattr(self, "_metrics", None)
        if m is None:
            from ..telemetry import MetricsRegistry
            m = self._metrics = MetricsRegistry()
        return m

    @metrics.setter
    def metrics(self, registry) -> None:
        self._metrics = registry

    @property
    def incidence_calls(self) -> int:
        """Deprecated alias of ``metrics.value("incidence.walks")``."""
        return int(self.metrics.value("incidence.walks"))

    @incidence_calls.setter
    def incidence_calls(self, value: int) -> None:
        import warnings
        warnings.warn(
            "incidence_calls is deprecated; use "
            "router.metrics.value('incidence.walks')",
            DeprecationWarning, stacklevel=2)
        self.metrics.set_counter("incidence.walks", int(value))

    def _count_walk(self) -> None:
        from ..telemetry import get_metrics
        self.metrics.inc("incidence.walks")
        get_metrics().inc("incidence.walks")

    def _count_cache(self, hits: int, misses: int) -> None:
        from ..telemetry import get_metrics
        ambient = get_metrics()
        for reg in (self.metrics, ambient):
            reg.inc("incidence.cache_hits", hits)
            reg.inc("incidence.cache_misses", misses)

    def _pair_cache(self, mode: str) -> dict:
        if not hasattr(self, "_inc_cache"):
            self._inc_cache: dict = {}
        return self._inc_cache.setdefault(mode, {})

    def reset_incidence_cache(self) -> None:
        self._inc_cache = {}

    def incidence_cached(self, demands: "DemandArrays", mode: str = "minimal"):
        """:meth:`incidence`, but only walking (src, dst) pairs not in the
        cache; cached pairs' rows are replayed.  Same COO contract (rows
        grouped by flow, slot-sorted within a flow)."""
        cache = self._pair_cache(mode)
        src = np.asarray(demands.src, dtype=np.int64)
        dst = np.asarray(demands.dst, dtype=np.int64)
        uniq, inv = np.unique(np.stack([src, dst], axis=1), axis=0,
                              return_inverse=True)
        pairs = [tuple(p) for p in uniq.tolist()]
        miss = [p for p in pairs if p not in cache]
        self._count_cache(hits=len(pairs) - len(miss), misses=len(miss))
        if miss:
            ma = np.asarray(miss, dtype=np.int64)
            sub = DemandArrays(ma[:, 0], ma[:, 1], np.ones(ma.shape[0]))
            f, s, fr = self.incidence(sub, mode)
            order = np.argsort(f, kind="stable")
            f, s, fr = f[order], s[order], fr[order]
            bounds = np.searchsorted(f, np.arange(ma.shape[0] + 1))
            for j, p in enumerate(miss):
                lo, hi = int(bounds[j]), int(bounds[j + 1])
                cache[p] = (s[lo:hi], fr[lo:hi])
        per_pair = [cache[p] for p in pairs]
        counts = np.array([e.size for e, _ in per_pair], dtype=np.int64)
        n = src.shape[0]
        if n == 0 or int(counts[inv].sum()) == 0:
            z = np.zeros(0, dtype=np.int64)
            return z, z.copy(), np.zeros(0)
        flow = np.repeat(np.arange(n, dtype=np.int64), counts[inv])
        edge = np.concatenate([per_pair[j][0] for j in inv])
        frac = np.concatenate([per_pair[j][1] for j in inv])
        return flow, edge, frac


class VectorizedHyperXRouter(IncidenceCacheMixin):
    """Array engine for routing whole demand matrices over one MPHX plane."""

    def __init__(self, topo: MPHX, backend: str = "auto"):
        self.topo = topo
        self.index = EdgeIndex(topo)
        self.backend, self.xp = get_backend(backend)

    # ------------------------------------------------------------ helpers ----

    def _prep(self, demands: DemandArrays):
        src = np.asarray(demands.src, dtype=np.int64)
        dst = np.asarray(demands.dst, dtype=np.int64)
        gbps = np.asarray(demands.gbps, dtype=np.float64)
        cs = self.index.ids_to_coords(src)
        cd = self.index.ids_to_coords(dst)
        return src, dst, gbps, cs, cd

    def _zeros(self):
        return backend_zeros(self.xp, self.index.n_slots)

    def _iter_minimal_hops(self, src, cs, cd):
        """Yield ``(slots, mask)`` per hop of every D! full-dimension
        ordering — the single source of truth for the minimal walk, shared
        by load accounting (:meth:`_walk_minimal`) and per-flow incidence
        extraction (:meth:`incidence`, for the flow simulator)."""
        idx = self.index
        for perm in itertools.permutations(range(idx.D)):
            cur_id = src.copy()
            cur = cs.copy()
            for i in perm:
                mask = cur[:, i] != cd[:, i]
                if mask.any():   # skip the O(M) slot math on matched dims
                    yield idx.slots(cur_id, i, cd[:, i]), mask
                cur_id = cur_id + (cd[:, i] - cur[:, i]) * idx.stride[i]
                cur[:, i] = cd[:, i]

    def _walk_minimal(self, loads, src, gbps, cs, cd, perm_weight):
        """Add minimal ECMP loads.  ``perm_weight`` (M,) is the Gbps each of
        the D! full-dimension orderings carries for each demand; a distinct
        mismatched-dim ordering is induced by D!/m! full orderings, so every
        minimal path receives ``perm_weight * D!/m!`` total — set
        ``perm_weight = gbps/D!`` for the plain gbps/m! ECMP split."""
        xp = self.xp
        for slots, mask in self._iter_minimal_hops(src, cs, cd):
            loads = _scatter_add(xp, loads, slots[mask], perm_weight[mask])
        return loads

    def _mismatch_stats(self, cs, cd):
        mism = cs != cd                      # (M, D)
        m = mism.sum(axis=1)                 # mismatched dims per demand
        fact = np.array([math.factorial(k) for k in range(self.index.D + 1)])
        n_minimal = fact[m]                  # m! minimal paths
        dims = np.asarray(self.topo.dims, dtype=np.int64)
        n_deroute = (mism * np.maximum(dims - 2, 0)[None, :]).sum(axis=1)
        return mism, m, n_minimal, n_deroute

    # ------------------------------------------------------------- modes ----

    def route(self, demands: DemandArrays, mode: str = "minimal",
              granularity: int = 8) -> ArrayLinkLoads:
        if mode == "minimal":
            return self.route_minimal(demands)
        if mode == "valiant":
            return self.route_valiant(demands)
        if mode == "adaptive":
            return self.route_adaptive(demands, granularity)
        raise ValueError(f"unknown mode {mode}")

    def route_minimal(self, demands: DemandArrays) -> ArrayLinkLoads:
        src, dst, gbps, cs, cd = self._prep(demands)
        n_perms = math.factorial(self.index.D)
        loads = self._walk_minimal(self._zeros(), src, gbps, cs, cd,
                                   gbps / n_perms)
        return ArrayLinkLoads(self.index, loads)

    def _iter_deroute_hops(self, src, cs, cd, mism):
        """Yield ``(slots, mask)`` per hop of every single-deroute DAL path
        (src -> dim ``i`` := ``via`` -> fix dims in index order) — shared by
        :meth:`route_valiant` and :meth:`incidence`."""
        idx = self.index
        dims = self.topo.dims
        for i in range(idx.D):
            for via in range(dims[i]):
                mask = mism[:, i] & (cs[:, i] != via) & (cd[:, i] != via)
                if not mask.any():
                    continue
                yield idx.slots(src, i, np.full_like(src, via)), mask
                cur_id = src + (via - cs[:, i]) * idx.stride[i]
                cur = cs.copy()
                cur[:, i] = via
                for j in range(idx.D):
                    step = mask & (cur[:, j] != cd[:, j])
                    if step.any():   # skip the O(M) slot math on idle hops
                        yield idx.slots(cur_id, j, cd[:, j]), step
                    cur_id = cur_id + (cd[:, j] - cur[:, j]) * idx.stride[j]
                    cur[:, j] = cd[:, j]

    def route_valiant(self, demands: DemandArrays) -> ArrayLinkLoads:
        """Minimal + all single-deroute DAL paths, load split equally —
        the legacy ``mode="valiant"`` spread, computed in one batch."""
        src, dst, gbps, cs, cd = self._prep(demands)
        idx, xp = self.index, self.xp
        if np.any(src == dst):
            raise ValueError("valiant routing expects src != dst demands")
        mism, m, n_minimal, n_deroute = self._mismatch_stats(cs, cd)
        n_paths = (n_minimal + n_deroute).astype(np.float64)
        per_path = gbps / n_paths
        # minimal component: each of the m! minimal paths carries per_path
        n_full = math.factorial(idx.D)
        loads = self._walk_minimal(self._zeros(), src, gbps, cs, cd,
                                   per_path * n_minimal / n_full)
        # deroute component: src -> (dim i := via) -> fix dims in index order
        for slots, mask in self._iter_deroute_hops(src, cs, cd, mism):
            loads = _scatter_add(xp, loads, slots[mask], per_path[mask])
        return ArrayLinkLoads(self.index, loads)

    # ------------------------------------------------- per-flow incidence ----

    def incidence(self, demands: DemandArrays, mode: str = "minimal"):
        """Per-flow edge incidence of a fixed-spread routing mode.

        Returns ``(flow, slot, frac)`` COO int64/int64/float64 arrays where
        ``frac`` is the fraction of flow ``flow``'s rate carried on edge
        slot ``slot`` — so scatter-adding ``rates[flow] * frac`` over slots
        reproduces :meth:`route`'s loads exactly (the flow simulator's
        steady-state cross-validation, ``tests/test_sim.py``).  ``flow``
        indexes rows of ``demands``.  Supported modes are the fixed path
        spreads: ``minimal`` (ordering ECMP) and ``valiant`` (DAL
        deroutes); ``adaptive`` re-routes under load and has no static
        incidence.
        """
        self._count_walk()
        src, dst, gbps, cs, cd = self._prep(demands)
        n_full = math.factorial(self.index.D)
        flows, slots_l, fracs = [], [], []

        def emit(slots, mask, w):
            f = np.flatnonzero(mask)
            if f.size:
                flows.append(f)
                slots_l.append(slots[mask])
                fracs.append(w[mask] if w.ndim else np.full(f.size, w))

        if mode == "minimal":
            w = np.float64(1.0 / n_full)
            for slots, mask in self._iter_minimal_hops(src, cs, cd):
                emit(slots, mask, w)
        elif mode == "valiant":
            if np.any(src == dst):
                raise ValueError("valiant routing expects src != dst demands")
            mism, m, n_minimal, n_deroute = self._mismatch_stats(cs, cd)
            n_paths = (n_minimal + n_deroute).astype(np.float64)
            w_min = n_minimal / (n_paths * n_full)
            w_der = 1.0 / n_paths
            for slots, mask in self._iter_minimal_hops(src, cs, cd):
                emit(slots, mask, w_min)
            for slots, mask in self._iter_deroute_hops(src, cs, cd, mism):
                emit(slots, mask, w_der)
        else:
            raise ValueError(
                f"no static per-flow incidence for mode {mode!r} "
                "(adaptive re-routes under load); use minimal or valiant")
        if not flows:
            z = np.zeros(0, dtype=np.int64)
            return z, z.copy(), np.zeros(0)
        flow = np.concatenate(flows)
        slot = np.concatenate(slots_l)
        frac = np.concatenate(fracs)
        # coalesce duplicate (flow, slot) entries
        key = flow * np.int64(self.index.n_slots) + slot
        uniq, inv = np.unique(key, return_inverse=True)
        out = np.zeros(uniq.size)
        np.add.at(out, inv, frac)
        return (uniq // self.index.n_slots, uniq % self.index.n_slots, out)

    def mean_switch_hops(self) -> float:
        """Expected switch-switch minimal hops over uniform NIC pairs
        (coordinates differ in dim ``i`` with probability ``(D_i-1)/D_i``)."""
        return float(sum((d - 1) / d for d in self.topo.dims if d > 1))

    def edge_capacity(self) -> np.ndarray:
        """(n_slots,) per-edge-slot capacity in Gbps (shared router
        interface with :class:`~repro.core.routing_graph.GraphRouter`)."""
        return self.index.capacity

    # ------------------------------------------------- parallel UGAL/DAL ----

    def _candidate_paths(self, src, cs, cd):
        """Enumerate candidate paths as slot matrices.

        Returns a list of ``(slots, valid)`` pairs, one per candidate:
        ``slots`` (M, hops) edge slots (entries only meaningful where the
        hop mask is set), ``valid`` (M, hops) bool.  Candidates are the D!
        minimal orderings plus every (dim, via) single deroute.
        """
        idx = self.index
        cands = []
        for perm in itertools.permutations(range(idx.D)):
            cur_id = src.copy()
            cur = cs.copy()
            slots, valid = [], []
            for i in perm:
                mask = cur[:, i] != cd[:, i]
                slots.append(idx.slots(cur_id, i, cd[:, i]))
                valid.append(mask)
                cur_id = cur_id + (cd[:, i] - cur[:, i]) * idx.stride[i]
                cur[:, i] = cd[:, i]
            cands.append((np.stack(slots, 1), np.stack(valid, 1), None))
        dims = self.topo.dims
        mism = cs != cd
        for i in range(idx.D):
            for via in range(dims[i]):
                usable = mism[:, i] & (cs[:, i] != via) & (cd[:, i] != via)
                if not usable.any():
                    continue
                slots, valid = [], []
                slots.append(idx.slots(src, i, np.full_like(src, via)))
                valid.append(usable)
                cur_id = src + (via - cs[:, i]) * idx.stride[i]
                cur = cs.copy()
                cur[:, i] = via
                for j in range(idx.D):
                    step = usable & (cur[:, j] != cd[:, j])
                    slots.append(idx.slots(cur_id, j, cd[:, j]))
                    valid.append(step)
                    cur_id = cur_id + (cd[:, j] - cur[:, j]) * idx.stride[j]
                    cur[:, j] = cd[:, j]
                cands.append((np.stack(slots, 1), np.stack(valid, 1), usable))
        return cands

    def route_adaptive(self, demands: DemandArrays, granularity: int = 8,
                       sub_batches: int = 8) -> ArrayLinkLoads:
        """Parallel UGAL/DAL: ``granularity`` quantum rounds; per round every
        demand places one quantum on its least-bottlenecked candidate
        (minimal orderings + single deroutes), with the same 0.01/hop
        penalty the legacy greedy router uses.  Link loads refresh between
        ``sub_batches`` interleaved demand groups within each round — with
        one demand per group this *is* the legacy sequential greedy; with
        large groups it is an idealized parallel relaxation that tracks,
        but does not bit-match, the legacy router."""
        src, dst, gbps, cs, cd = self._prep(demands)
        idx, xp = self.index, self.xp
        loads = self._zeros()
        cands = self._candidate_paths(src, cs, cd)
        quantum = gbps / granularity
        safe_cap = np.where(idx.capacity > 0, idx.capacity, np.inf)
        M = src.shape[0]
        # deterministic per-(demand, candidate) jitter: equal-cost candidates
        # would otherwise tie-break identically across the whole batch and
        # herd every demand onto the same deroute each round
        jitter = np.random.default_rng(0).random((M, len(cands))) * 1e-5
        batches = [np.arange(b, M, sub_batches) for b in range(sub_batches)
                   if b < M]
        for _ in range(granularity):
            for rows in batches:
                l_np = np.asarray(loads)
                q = quantum[rows]
                costs = np.full((rows.size, len(cands)), np.inf)
                for k, (slots, valid, usable) in enumerate(cands):
                    sl, va = slots[rows], valid[rows]
                    util = (l_np[sl] + q[:, None]) / safe_cap[sl]
                    util = np.where(va, util, -np.inf)
                    hops = va.sum(axis=1)
                    cost = util.max(axis=1) + 0.01 * hops
                    ok = hops > 0 if usable is None else usable[rows]
                    costs[:, k] = np.where(ok, cost, np.inf)
                choice = np.argmin(costs + jitter[rows], axis=1)
                placeable = np.isfinite(costs[np.arange(rows.size), choice])
                for k, (slots, valid, _) in enumerate(cands):
                    sel = (choice == k) & placeable
                    if not sel.any():
                        continue
                    sel_rows = rows[sel]
                    hop_sel = valid[sel_rows]
                    w = np.repeat(q[sel], hop_sel.sum(axis=1))
                    loads = _scatter_add(xp, loads, slots[sel_rows][hop_sel],
                                         w)
        return ArrayLinkLoads(self.index, loads)
