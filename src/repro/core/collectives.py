"""Multi-plane collectives in JAX — the compute-side realization of the
paper's plane spraying (DESIGN.md §3.1).

An MPHX NIC sprays each flow across n independent planes.  XLA collectives
are ordered, so the JAX-native analogue is *chunk decomposition*: split the
tensor into n chunks and issue n independent collectives the scheduler can
overlap (``multiplane_psum``), and *dimension decomposition*: express one
big all-reduce as reduce-scatter -> (recurse) -> all-gather across distinct
mesh axes (``hierarchical_psum``) the way an MPHX hierarchical all-reduce
walks the HyperX dimensions (netsim.hierarchical_allreduce_time).

Everything here runs inside ``shard_map``.  Each function has the same
semantics as a single ``lax.psum`` over the named axes — property-tested
against that oracle in tests/test_collectives.py.

``int8_psum`` is the wire-level compressed all-reduce (cross-pod/DCN axis):
quantize-per-chunk -> integer psum -> dequantize, with the scale reduced by
max.  Error feedback for it lives in train/trainer.py.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import axis_size


def plane_chunk_count(size: int, n_planes: int) -> int:
    """Number of per-plane chunks a sprayed collective splits into: the
    largest ``n <= n_planes`` dividing ``size`` evenly, or 1 (no split).
    Shared by :func:`multiplane_psum` / :func:`multiplane_all_gather` and by
    :mod:`repro.experiments.scenarios` to size collective chunk schedules."""
    n = min(n_planes, size)
    if size % n:
        return 1
    return n


def multiplane_psum(x, axis_name: str, n_planes: int = 8, split_axis: int = 0):
    """All-reduce as ``n_planes`` independent chunk all-reduces.

    Same result as ``lax.psum(x, axis_name)``; the chunks model the NIC
    spraying a flow over n planes (each chunk rides one plane).  On TPU the
    chunks pipeline through the ICI links and overlap with surrounding
    compute; XLA may also fuse them back together — the decomposition is a
    scheduling hint, not a semantic change.
    """
    n = plane_chunk_count(x.shape[split_axis], n_planes)
    if n == 1:
        return lax.psum(x, axis_name)
    chunks = jnp.split(x, n, axis=split_axis)
    return jnp.concatenate([lax.psum(c, axis_name) for c in chunks],
                           axis=split_axis)


def decomposed_psum(x, axis_name: str, split_axis: int = 0):
    """All-reduce as reduce-scatter + all-gather over the SAME axis.

    Equivalent bytes to a ring all-reduce but exposes the two phases to the
    scheduler separately (overlap the all-gather with downstream compute).
    Requires ``x.shape[split_axis]`` divisible by the axis size.
    """
    n = axis_size(axis_name)
    if x.shape[split_axis] % n:
        return lax.psum(x, axis_name)
    scattered = lax.psum_scatter(x, axis_name, scatter_dimension=split_axis,
                                 tiled=True)
    return lax.all_gather(scattered, axis_name, axis=split_axis, tiled=True)


def hierarchical_psum(x, axis_names: Sequence[str], split_axis: int = 0):
    """All-reduce over multiple mesh axes as the MPHX dimension walk:
    reduce-scatter along axis 0, recurse over the remaining axes on the
    shard, then all-gather along axis 0.  Traffic per step matches the
    hierarchical schedule in core/netsim.hierarchical_allreduce_time."""
    axis_names = list(axis_names)
    if len(axis_names) == 0:
        return x
    if len(axis_names) == 1:
        return decomposed_psum(x, axis_names[0], split_axis)
    a0 = axis_names[0]
    n = axis_size(a0)
    if x.shape[split_axis] % n:
        # fall back: reduce this axis whole, recurse on the rest
        return hierarchical_psum(lax.psum(x, a0), axis_names[1:], split_axis)
    scattered = lax.psum_scatter(x, a0, scatter_dimension=split_axis,
                                 tiled=True)
    reduced = hierarchical_psum(scattered, axis_names[1:], split_axis)
    return lax.all_gather(reduced, a0, axis=split_axis, tiled=True)


def multiplane_all_gather(x, axis_name: str, n_planes: int = 8,
                          gather_axis: int = 0, chunk_axis: int = -1):
    """All-gather with the payload chunk-split over planes."""
    ca = chunk_axis % x.ndim
    n = plane_chunk_count(x.shape[ca], n_planes)
    if n == 1:
        return lax.all_gather(x, axis_name, axis=gather_axis, tiled=True)
    chunks = jnp.split(x, n, axis=ca)
    outs = [lax.all_gather(c, axis_name, axis=gather_axis, tiled=True)
            for c in chunks]
    return jnp.concatenate(outs, axis=ca)


def int8_psum(x, axis_name: str):
    """Compressed all-reduce: int8 quantized payload + shared max-scale.

    Wire bytes: 1/4 of fp32 (plus one scalar).  Biased per call (quantization
    error does not cancel); pair with error feedback across steps
    (train/trainer.compress_grads_ef) for convergence.
    """
    amax = lax.pmax(jnp.max(jnp.abs(x)), axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    # accumulate in int32 (axis size < 2^24 safe)
    total = lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale


def latency_optimal_psum(x, axis_name: str):
    """Small-payload all-reduce: a single psum (alpha-bound); provided so
    callers can dispatch on payload size like the netsim algo picker."""
    return lax.psum(x, axis_name)


def psum_auto(x, axis_name: str, n_planes: int = 8,
              small_cutoff_bytes: int = 1 << 14):
    """Dispatch between latency-optimal and plane-decomposed all-reduce by
    payload size (mirrors netsim.allreduce_time's algo choice)."""
    nbytes = x.size * x.dtype.itemsize
    if nbytes <= small_cutoff_bytes:
        return latency_optimal_psum(x, axis_name)
    return multiplane_psum(x, axis_name, n_planes)
