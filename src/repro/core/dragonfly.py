"""Dragonfly and Dragonfly+ baselines (Table 2 rows 3-4) and the §5.1
flattening argument: with enough port breakout a (multi-plane) Dragonfly
degenerates into a 2D HyperX, and Dragonfly+ into 2-layer-FT x HyperX and
eventually a multi-plane Fat-Tree.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .hyperx import MPHX
from .topology import (
    DEFAULT_SWITCH,
    LinkClass,
    SwitchGraph,
    SwitchModel,
    Topology,
)


@dataclass
class Dragonfly(Topology):
    """Dragonfly(p, a, h) [Kim et al. ISCA'08].

    p NICs per router, a routers per group (intra-group full mesh),
    h global links per router.  Balanced: a = 2p = 2h.  Full scale:
    g_max = a*h + 1 groups.  Below full scale the a*h global ports per
    group are trunked evenly over the g-1 other groups.
    """

    p: int = 16
    a: int = 32
    h: int = 16
    groups: int = 128
    nic_bw_gbps: float = 1600.0
    switch: SwitchModel = field(default_factory=lambda: DEFAULT_SWITCH)
    access_copper: bool = False
    name: str = "Dragonfly"

    def __post_init__(self):
        if self.groups > self.a * self.h + 1:
            raise ValueError("groups exceed a*h+1")
        if (self.groups * self.a * self.h) % 2:
            raise ValueError("odd global endpoint count")

    @property
    def radix_used(self) -> int:
        return self.p + (self.a - 1) + self.h

    @property
    def n_nics(self) -> int:
        return self.p * self.a * self.groups

    @property
    def n_switches(self) -> int:
        return self.a * self.groups

    def link_classes(self) -> list[LinkClass]:
        local = self.groups * self.a * (self.a - 1) // 2
        global_ = self.groups * self.a * self.h // 2
        return [
            LinkClass(self.port_gbps, self.n_nics, tier="access",
                      optical=not self.access_copper),
            LinkClass(self.port_gbps, local, tier="local"),
            LinkClass(self.port_gbps, global_, tier="global"),
        ]

    @property
    def diameter(self) -> int:
        return 5  # NIC-l-g-l-NIC

    def avg_hops(self) -> float:
        n = self.n_nics
        p_same_sw = (self.p - 1) / (n - 1)
        p_same_grp = (self.p * self.a - self.p) / (n - 1)
        p_diff = 1 - p_same_sw - p_same_grp
        # diff-group: 1 global hop; src/dst local hop unless the gateway
        # router is the endpoint's router.
        gateways_per_dst_group = min(self.a, self.a * self.h / (self.groups - 1))
        p_local = 1 - gateways_per_dst_group / self.a
        diff_hops = 2 + 1 + 2 * p_local  # 2 access + global + expected locals
        return 2 * p_same_sw + 3 * p_same_grp + diff_hops * p_diff

    def bisection_links(self) -> int:
        # cut splits groups in half: crossing global links
        half = self.groups // 2
        total_global = self.groups * self.a * self.h // 2
        # uniform trunking: fraction of global links crossing
        pairs_cross = half * (self.groups - half)
        pairs_all = self.groups * (self.groups - 1) // 2
        return int(round(total_global * pairs_cross / pairs_all))

    def feasibility(self, switch: SwitchModel | None = None):
        sw = switch or self.switch
        return [(self.radix_used <= sw.radix_at(self.port_gbps),
                 f"radix {self.radix_used} > {sw.radix_at(self.port_gbps)}")]

    # ------------------------------------------------------ §5.1 flattening

    def breakout(self, factor: int) -> "Dragonfly | MPHX":
        """Break each switch port into ``factor`` finer ports (paper §5.1).

        Doubling the radix doubles h, quadruples NICs/group, quarters the
        group count.  Once a single router's global ports cover all other
        groups, the network *is* a 2D HyperX: dims = (a', groups'), trunked.
        """
        if factor < 1 or factor & (factor - 1):
            raise ValueError("factor must be a power of two")
        p2, a2, h2 = self.p * factor, self.a * factor, self.h * factor
        nics = self.n_nics  # keep system scale fixed
        g2 = max(2, nics // (p2 * a2))
        if h2 >= g2 - 1:
            # flattened: every router reaches every other group directly ->
            # 2D HyperX with dims (a2, g2); global links trunked evenly.
            per_router_global = h2
            return MPHX(
                n=factor, p=p2, dims=(a2, g2),
                nic_bw_gbps=self.nic_bw_gbps,
                links_per_dim=(a2 - 1, per_router_global),
                name=f"Dragonfly->2D HyperX (x{factor} breakout)",
            )
        return Dragonfly(p=p2, a=a2, h=h2, groups=g2,
                         nic_bw_gbps=self.nic_bw_gbps,
                         name=f"Dragonfly (x{factor} breakout)")

    def build_graph(self) -> SwitchGraph:
        g = SwitchGraph(self.n_switches, self.p, self.port_gbps, name=self.name)
        a, G, h = self.a, self.groups, self.h
        sid = lambda grp, r: grp * a + r
        for grp in range(G):
            for r in range(a):
                for r2 in range(r + 1, a):
                    g.add_edge(sid(grp, r), sid(grp, r2), 1.0, tier="local")
        # trunk a*h global ports per group evenly across other groups;
        # attach trunked links round-robin over routers.
        per_pair = a * h / (G - 1)
        for grp in range(G):
            for grp2 in range(grp + 1, G):
                # spread multiplicity over router pairs deterministically
                r1 = grp2 % a
                r2 = grp % a
                g.add_edge(sid(grp, r1), sid(grp2, r2), per_pair, tier="global")
        return g


@dataclass
class DragonflyPlus(Topology):
    """Dragonfly+ [Shpiner et al. HiPINEB'17]: groups are leaf/spine Clos;
    spines carry global links (Table 2 row 4: 32 leaves + 32 spines/group,
    radix-64 switches, 64 groups)."""

    p: int = 32                  # NICs per leaf
    leaves: int = 32             # per group
    spines: int = 32             # per group
    groups: int = 64
    global_per_spine: int = 32
    nic_bw_gbps: float = 1600.0
    switch: SwitchModel = field(default_factory=lambda: DEFAULT_SWITCH)
    access_copper: bool = False
    name: str = "Dragonfly+"

    @property
    def n_nics(self) -> int:
        return self.p * self.leaves * self.groups

    @property
    def n_switches(self) -> int:
        return (self.leaves + self.spines) * self.groups

    def link_classes(self) -> list[LinkClass]:
        leaf_spine = self.groups * self.leaves * self.spines
        global_ = self.groups * self.spines * self.global_per_spine // 2
        return [
            LinkClass(self.port_gbps, self.n_nics, tier="access",
                      optical=not self.access_copper),
            LinkClass(self.port_gbps, leaf_spine, tier="leaf-spine"),
            LinkClass(self.port_gbps, global_, tier="global"),
        ]

    @property
    def diameter(self) -> int:
        return 6  # NIC-leaf-spine-(global)-spine-leaf-NIC

    def avg_hops(self) -> float:
        n = self.n_nics
        p_same_leaf = (self.p - 1) / (n - 1)
        per_group = self.p * self.leaves
        p_same_group = (per_group - self.p) / (n - 1)
        p_diff = 1 - p_same_leaf - p_same_group
        return 2 * p_same_leaf + 4 * p_same_group + 6 * p_diff

    def bisection_links(self) -> int:
        half = self.groups // 2
        total_global = self.groups * self.spines * self.global_per_spine // 2
        pairs_cross = half * (self.groups - half)
        pairs_all = self.groups * (self.groups - 1) // 2
        return int(round(total_global * pairs_cross / pairs_all))

    def feasibility(self, switch: SwitchModel | None = None):
        sw = switch or self.switch
        leaf_radix = self.p + self.spines
        spine_radix = self.leaves + self.global_per_spine
        r = sw.radix_at(self.port_gbps)
        return [
            (leaf_radix <= r, f"leaf radix {leaf_radix} > {r}"),
            (spine_radix <= r, f"spine radix {spine_radix} > {r}"),
        ]

    def build_graph(self) -> SwitchGraph:
        """Group-major graph: group ``grp`` owns leaves ``grp*(l+s)..+l-1``
        then spines; leaf-spine is a full bipartite Clos inside each group,
        and the ``spines*global_per_spine`` global ports per group are
        trunked evenly over the other groups (round-robin over spines, like
        :meth:`Dragonfly.build_graph`).  NICs hang off leaves only."""
        l, s, G = self.leaves, self.spines, self.groups
        per_grp = l + s
        g = SwitchGraph(
            per_grp * G, self.p, self.port_gbps, name=self.name,
            nic_nodes=[grp * per_grp + i for grp in range(G)
                       for i in range(l)])
        leaf = lambda grp, i: grp * per_grp + i
        spine = lambda grp, j: grp * per_grp + l + j
        for grp in range(G):
            for i in range(l):
                for j in range(s):
                    g.add_edge(leaf(grp, i), spine(grp, j), 1.0,
                               tier="leaf-spine")
        per_pair = s * self.global_per_spine / (G - 1)
        for grp in range(G):
            for grp2 in range(grp + 1, G):
                g.add_edge(spine(grp, grp2 % s), spine(grp2, grp % s),
                           per_pair, tier="global")
        return g


def frontier_flattening_example() -> dict:
    """Paper §5.1 worked example, Frontier: radix 64, 16 global ports/switch,
    512 NICs/group, 80 groups.  x2 breakout -> 2,048 NICs/group, 20 groups,
    32 global ports/switch >= 19 -> flattens to 2D HyperX."""
    frontier = Dragonfly(p=16, a=32, h=16, groups=80, nic_bw_gbps=200.0,
                         name="Frontier (Slingshot Dragonfly)")
    flat = frontier.breakout(2)
    return {
        "before": {
            "radix": frontier.radix_used + 0,
            "nics_per_group": frontier.p * frontier.a,
            "groups": frontier.groups,
            "global_ports_per_switch": frontier.h,
            "nics": frontier.n_nics,
        },
        "after": {
            "flattened_to": type(flat).__name__,
            "name": flat.name,
            "nics_per_group": 2048,
            "groups": 20,
            "global_ports_per_switch": 32,
            "nics": flat.n_nics,
        },
    }
