"""Fat-Tree topologies used as baselines in Table 2.

* :class:`ThreeTierFatTree` — classic k-ary 3-tier Clos with non-breakout
  switches (Table 2 row 1).
* :class:`MultiPlaneFatTree` — n-plane 2-layer (leaf/spine) Fat-Tree in the
  style of DeepSeek's ideal multi-plane network / Alibaba HPN / Rail-only:
  every physical switch is broken out to n*k thin ports and belongs to one
  plane; every NIC has one port in every plane (Table 2 row 2).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .topology import (
    DEFAULT_SWITCH,
    LinkClass,
    SwitchGraph,
    SwitchModel,
    Topology,
)


@dataclass
class ThreeTierFatTree(Topology):
    """k-ary 3-tier fat-tree, full bisection.

    With radix k: edge/agg switches have k/2 down + k/2 up ports; the network
    hosts N = k^3/4 NICs at full scale.  For N below full scale the pod count
    shrinks proportionally (N must divide evenly into pods).
    """

    radix: int = 64
    nics: int = 65_536
    nic_bw_gbps: float = 1600.0
    switch: SwitchModel = field(default_factory=lambda: DEFAULT_SWITCH)
    access_copper: bool = False
    name: str = "3-layer Fat-Tree"

    def __post_init__(self):
        k = self.radix
        if self.nics > k**3 // 4:
            raise ValueError(f"{self.nics} NICs exceeds k^3/4 = {k**3//4}")
        if self.nics % (k // 2) or (2 * self.nics // k) % (k // 2):
            raise ValueError("NIC count must fill edge switches evenly")

    @property
    def n_planes(self) -> int:
        return 1

    @property
    def port_gbps(self) -> float:
        return self.nic_bw_gbps

    @property
    def n_nics(self) -> int:
        return self.nics

    @property
    def n_edge(self) -> int:
        return 2 * self.nics // self.radix

    @property
    def n_agg(self) -> int:
        return self.n_edge

    @property
    def n_core(self) -> int:
        return self.nics // self.radix

    @property
    def n_switches(self) -> int:
        return self.n_edge + self.n_agg + self.n_core

    @property
    def n_pods(self) -> int:
        return self.n_edge // (self.radix // 2)

    def link_classes(self) -> list[LinkClass]:
        n = self.nics
        return [
            LinkClass(self.port_gbps, n, tier="access",
                      optical=not self.access_copper),
            LinkClass(self.port_gbps, n, tier="edge-agg"),
            LinkClass(self.port_gbps, n, tier="agg-core"),
        ]

    @property
    def diameter(self) -> int:
        return 6  # NIC-edge-agg-core-agg-edge-NIC

    def avg_hops(self) -> float:
        n = self.nics
        per_edge = self.radix // 2
        per_pod = per_edge * (self.radix // 2)
        p_same_edge = (per_edge - 1) / (n - 1)
        p_same_pod = (per_pod - per_edge) / (n - 1)
        p_diff_pod = 1 - p_same_edge - p_same_pod
        return 2 * p_same_edge + 4 * p_same_pod + 6 * p_diff_pod

    def bisection_links(self) -> int:
        return self.nics // 2

    def feasibility(self, switch: SwitchModel | None = None):
        sw = switch or self.switch
        return [(self.radix <= sw.radix_at(self.port_gbps),
                 f"radix {self.radix} > {sw.radix_at(self.port_gbps)}")]

    def build_graph(self) -> SwitchGraph:
        """Explicit 3-tier Clos graph: edge 0..E-1, agg E..E+A-1, core rest.

        Pod-major numbering: pod ``q`` owns edge/agg switches
        ``q*(k/2) + i``.  Agg slot ``j`` of every pod connects to core group
        ``j`` (``n_core/(k/2)`` cores per group), with multiplicity spread
        so each agg uses exactly its k/2 up ports.  NICs hang off edge
        switches only (k/2 per edge).
        """
        k = self.radix
        E, A, C = self.n_edge, self.n_agg, self.n_core
        half = k // 2
        if C % half:
            raise ValueError(
                f"graph builder needs cores ({C}) divisible by k/2 ({half})")
        g = SwitchGraph(E + A + C, half, self.port_gbps, name=self.name,
                        nic_nodes=range(E))
        cores_per_slot = C // half
        mult_up = half / cores_per_slot  # agg up ports per core in its group
        for pod in range(self.n_pods):
            for i in range(half):          # edge i of this pod
                edge = pod * half + i
                for j in range(half):      # agg j of this pod
                    agg = E + pod * half + j
                    g.add_edge(edge, agg, 1.0, tier="edge-agg")
            for j in range(half):
                agg = E + pod * half + j
                for c in range(cores_per_slot):
                    core = E + A + j * cores_per_slot + c
                    g.add_edge(agg, core, mult_up, tier="agg-core")
        return g


@dataclass
class MultiPlaneFatTree(Topology):
    """n-plane 2-layer (leaf/spine) fat-tree with port breakout (Table 2 row 2).

    Each physical switch is broken out to ``radix = n*k`` ports of B/n Gbps and
    assigned to exactly one plane.  Per plane: leaves take radix/2 NIC ports
    down and radix/2 up; spines provide full bisection.
    """

    n: int = 8
    nics: int = 65_536
    nic_bw_gbps: float = 1600.0
    base_radix: int = 64                 # k, at full NIC speed B
    switch: SwitchModel = field(default_factory=lambda: DEFAULT_SWITCH)
    access_copper: bool = False
    name: str = ""

    def __post_init__(self):
        if not self.name:
            self.name = f"{self.n}-Plane 2-layer Fat-Tree"
        r = self.radix
        if self.nics % (r // 2):
            raise ValueError("NICs must fill leaves evenly")
        if self.nics > r * r // 2:
            raise ValueError(
                f"{self.nics} NICs exceeds 2-layer max {r*r//2} at radix {r}")

    @property
    def radix(self) -> int:
        return self.n * self.base_radix

    @property
    def n_planes(self) -> int:
        return self.n

    @property
    def n_nics(self) -> int:
        return self.nics

    @property
    def leaves_per_plane(self) -> int:
        return self.nics // (self.radix // 2)

    @property
    def spines_per_plane(self) -> int:
        # full bisection: leaf up-links = nics per plane, spread over spines
        return self.nics // self.radix

    @property
    def n_switches(self) -> int:
        return self.n * (self.leaves_per_plane + self.spines_per_plane)

    def link_classes(self) -> list[LinkClass]:
        per_plane_access = self.nics           # one port per NIC per plane
        per_plane_up = self.nics               # full bisection leaf-spine
        return [
            LinkClass(self.port_gbps, self.n * per_plane_access, tier="access",
                      optical=not self.access_copper),
            LinkClass(self.port_gbps, self.n * per_plane_up, tier="leaf-spine"),
        ]

    @property
    def diameter(self) -> int:
        return 4  # NIC-leaf-spine-leaf-NIC

    def avg_hops(self) -> float:
        per_leaf = self.radix // 2
        p_same_leaf = (per_leaf - 1) / (self.nics - 1)
        return 2 * p_same_leaf + 4 * (1 - p_same_leaf)

    def bisection_links(self) -> int:
        return self.n * self.nics // 2

    def feasibility(self, switch: SwitchModel | None = None):
        sw = switch or self.switch
        return [(self.radix <= sw.radix_at(self.port_gbps),
                 f"breakout radix {self.radix} > "
                 f"{sw.radix_at(self.port_gbps)} at {self.port_gbps} Gbps")]

    def build_graph(self) -> SwitchGraph:
        """One plane's leaf/spine graph (leaves 0..L-1 bear the NICs)."""
        L, S = self.leaves_per_plane, self.spines_per_plane
        g = SwitchGraph(L + S, self.radix // 2, self.port_gbps, name=self.name,
                        nic_nodes=range(L))
        up_per_leaf = self.radix // 2
        mult = up_per_leaf / S
        for leaf in range(L):
            for spine in range(S):
                g.add_edge(leaf, L + spine, mult, tier="leaf-spine")
        return g
