"""Logical mesh -> physical MPHX placement (DESIGN.md §3.2).

A training job's logical mesh ("pod", "data", "model") produces distinct
collective traffic per axis (model: per-layer all-reduce/all-gather of
activations + EP all-to-all; data: per-step gradient all-reduce; pod: DCN
gradient all-reduce).  The physical MPHX(n, p, D_1..D_D) fabric offers
hop-count/bandwidth trade-offs per dimension: NICs under one switch (p-way,
2 hops), dimension i's full mesh (D_i-way, 3 hops, link multiplicity
links_i/(D_i-1)).

:func:`best_mapping` enumerates assignments of logical axes onto the
physical hierarchy levels and scores them with the netsim alpha-beta model
weighted by each axis's bytes-per-step, reproducing the paper's guidance
(§5.2): bandwidth-hungry axes belong on the p-way switch level or a trunked
dimension; the latency-sensitive small-collective axes tolerate the sparse
inter-dimension links.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field

from .hyperx import MPHX
from .netsim import DEFAULT_NET, NetParams, gbps_to_Bps, _alpha


@dataclass(frozen=True)
class AxisTraffic:
    """Bytes each device moves per train step for one logical axis."""

    name: str
    size: int                    # axis length (devices)
    allreduce_bytes: float = 0.0  # per step (e.g. grads for data axis)
    allgather_bytes: float = 0.0  # per step (e.g. ZeRO params / TP acts)
    alltoall_bytes: float = 0.0   # per step (EP dispatch)
    calls: int = 1                # collectives issued per step (alpha count)


@dataclass(frozen=True)
class Level:
    """One level of the MPHX physical hierarchy."""

    kind: str                    # "switch" | "dim"
    size: int                    # fanout of the level
    hops: float                  # NIC-to-NIC hops within the level
    rel_bandwidth: float         # per-endpoint-pair bandwidth multiplier


def mphx_levels(topo: MPHX) -> list[Level]:
    levels = [Level("switch", topo.p, 2.0, 1.0)]
    for d, l in zip(topo.dims, topo.links_per_dim):
        if d <= 1:
            continue
        mult = l / (d - 1)
        # per-plane pairwise trunk / port bandwidth, all planes sprayed
        levels.append(Level("dim", d, 3.0, mult))
    return levels


def axis_time_on_level(ax: AxisTraffic, lvl: Level, topo: MPHX,
                       net: NetParams = DEFAULT_NET) -> float:
    """alpha-beta time for one axis's per-step traffic on one level."""
    B = gbps_to_Bps(topo.nic_bw_gbps)
    t = 0.0
    m = ax.size
    if ax.allreduce_bytes:
        steps = 2 * (m - 1)
        t += ax.calls * steps * _alpha(topo, lvl.hops, net)
        t += 2 * (m - 1) / m * ax.allreduce_bytes / B
    if ax.allgather_bytes:
        steps = m - 1
        t += ax.calls * steps * _alpha(topo, lvl.hops, net)
        t += (m - 1) / m * ax.allgather_bytes / B
    if ax.alltoall_bytes:
        t += ax.calls * _alpha(topo, lvl.hops, net)
        # direct exchange rides the level's pairwise trunks; the full mesh
        # of a HyperX dim serves A2A at full injection (rel_bandwidth >= 1)
        t += ax.alltoall_bytes / (B * min(lvl.rel_bandwidth * lvl.size /
                                          max(m - 1, 1), 1.0))
    return t


@dataclass
class Mapping:
    assignment: dict             # axis name -> list of (level index, factor)
    time_s: float
    detail: dict = field(default_factory=dict)


def _factorizations(size: int, capacities: list[int]):
    """Yield ways to split `size` across levels (factor per level, product
    == size, each factor <= capacity)."""
    if size == 1:
        yield [1] * len(capacities)
        return
    if not capacities:
        return
    cap = capacities[0]
    f = 1
    while f <= min(size, cap):
        if size % f == 0:
            for rest in _factorizations(size // f, capacities[1:]):
                yield [f] + rest
        f += 1


def best_mapping(topo: MPHX, axes: list[AxisTraffic],
                 net: NetParams = DEFAULT_NET) -> Mapping:
    """Assign each logical axis to physical levels minimizing summed
    collective time.  Axes are placed greedily from most traffic to least,
    consuming level capacity; within an axis we try all factorizations."""
    levels = mphx_levels(topo)
    caps = [l.size for l in levels]
    order = sorted(axes, key=lambda a: -(a.allreduce_bytes
                                         + a.allgather_bytes
                                         + a.alltoall_bytes))
    assignment, detail = {}, {}
    total = 0.0
    for ax in order:
        best = None
        for fac in _factorizations(ax.size, caps):
            # axis spans the levels where factor > 1; time = worst level
            # (phases run sequentially; use sum over levels with >1 factor)
            t = 0.0
            for f, lvl in zip(fac, levels):
                if f > 1:
                    sub = AxisTraffic(ax.name, f, ax.allreduce_bytes,
                                      ax.allgather_bytes, ax.alltoall_bytes,
                                      ax.calls)
                    t += axis_time_on_level(sub, lvl, topo, net)
            if best is None or t < best[0]:
                best = (t, fac)
        if best is None:
            raise ValueError(
                f"axis {ax.name} (size {ax.size}) does not fit on {topo.name}"
                f" remaining capacity {caps}")
        t, fac = best
        total += t
        assignment[ax.name] = [(i, f) for i, f in enumerate(fac) if f > 1]
        detail[ax.name] = t
        caps = [c // f for c, f in zip(caps, fac)]
    return Mapping(assignment, total, detail)


def traffic_from_model(param_bytes: float, act_bytes_per_layer: float,
                       n_layers: int, ep_bytes: float,
                       mesh_shape: dict) -> list[AxisTraffic]:
    """Build per-axis traffic records from model-level quantities.

    * data axis: one gradient all-reduce of param_bytes (ZeRO: RS+AG, same
      bytes) + ZeRO param all-gathers (param_bytes per step).
    * model axis: 2 activation all-gathers + 2 reduce-scatters per layer
      (Megatron sequence-parallel accounting: ~4 x act bytes per layer) and
      the EP all-to-all.
    * pod axis: cross-pod gradient all-reduce of param_bytes.
    """
    axes = []
    if mesh_shape.get("model", 1) > 1:
        axes.append(AxisTraffic(
            "model", mesh_shape["model"],
            allgather_bytes=4 * act_bytes_per_layer * n_layers,
            alltoall_bytes=ep_bytes, calls=4 * n_layers))
    if mesh_shape.get("data", 1) > 1:
        axes.append(AxisTraffic(
            "data", mesh_shape["data"],
            allreduce_bytes=param_bytes,
            allgather_bytes=param_bytes, calls=2 * n_layers))
    if mesh_shape.get("pod", 1) > 1:
        axes.append(AxisTraffic(
            "pod", mesh_shape["pod"], allreduce_bytes=param_bytes, calls=1))
    return axes
