"""CLI for the experiment suites.

Examples::

    PYTHONPATH=src python -m repro.experiments.run --suite table2
    PYTHONPATH=src python -m repro.experiments.run --suite sweep \
        --topos mphx-2p-8x8 mphx-4p-86x9 --scenarios uniform neighbor_shift \
        --modes minimal adaptive --loads 0.25 0.5 1.0
    PYTHONPATH=src python -m repro.experiments.run --suite sim \
        --topos mphx-2p-8x8 --scenarios uniform --loads 0.5 0.9
    PYTHONPATH=src python -m repro.experiments.run --suite failures \
        --topos mphx-2p-8x8 dragonfly-small --failures link:0.01 plane:1
    PYTHONPATH=src python -m repro.experiments.run --suite cosim \
        --config kimi_k2_1t_a32b --ranks 64
    PYTHONPATH=src python -m repro.experiments.run --suite serving \
        --tenants chat burst train --seed 7
    PYTHONPATH=src python -m repro.experiments.run --suite all
    PYTHONPATH=src python -m repro.experiments.run --suite cosim \
        --topos mphx-2p-8x8 --trace step_trace.json

Artifacts land in ``--out`` (default ``results/experiments``):
``{table2,sweep,sim,failures,cosim,serving}.{json,md}``; the JSON schema
(v6) is documented in :mod:`repro.experiments.artifacts` and
``docs/experiments.md`` / ``docs/simulation.md``.  ``--trace OUT.json``
runs every selected suite under the fabric flight recorder
(:mod:`repro.telemetry`) and exports one Chrome/Perfetto ``trace_event``
JSON; suites with nothing to trace (analytic-only paths) leave explicit
skip records in the trace's ``otherData.skipped``, and the artifacts
gain the schema-v5 ``telemetry`` block.  ``--seed`` makes the serving
suite's artifacts byte-reproducible run to run.
"""

from __future__ import annotations

import argparse
import sys
from contextlib import nullcontext

from repro.routing.protection import REROUTE_MODES
from repro.sim.failures import parse_failure_spec
from .cosuite import (DEFAULT_COSIM_CONFIGS, DEFAULT_COSIM_RANKS,
                      DEFAULT_COSIM_TOPOS, run_cosim_suite)
from .scenarios import SCENARIOS
from .servesuite import (DEFAULT_SERVING_TOPOS, DEFAULT_TENANTS,
                         TENANT_PRESETS, run_serving_suite)
from .simsuite import (DEFAULT_FAILURE_SPECS, run_failures_suite,
                       run_sim_suite)
from .sweep import (DEFAULT_OUTDIR, DEFAULT_SWEEP_TOPOS, SWEEP_TOPOLOGIES,
                    run_sweep_suite, run_table2_suite)

SUITES = ["table2", "sweep", "sim", "failures", "cosim", "serving", "all"]


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.experiments.run",
        description="MPHX experiment sweeps (paper §6 evaluation)")
    p.add_argument("--suite", choices=SUITES, default="all")
    p.add_argument("--out", default=DEFAULT_OUTDIR,
                   help="artifact directory (default results/experiments)")
    p.add_argument("--topos", nargs="+", choices=sorted(SWEEP_TOPOLOGIES),
                   default=None, help="sweep topologies (default: "
                   f"{' '.join(DEFAULT_SWEEP_TOPOS)})")
    p.add_argument("--scenarios", nargs="+", choices=sorted(SCENARIOS),
                   default=None, help="scenarios (default: all; inapplicable "
                   "ones are recorded as skipped)")
    p.add_argument("--modes", nargs="+",
                   choices=["minimal", "valiant", "adaptive"], default=None,
                   help="routing modes (default: all three; the sim suite "
                   "always routes minimal — the static path spread both "
                   "engines share)")
    p.add_argument("--engine", choices=["auto", "array", "graph"],
                   default="auto",
                   help="routing engine (auto: array for MPHX, graph "
                   "for baseline topologies; failures always re-route on "
                   "graph — forcing array yields skip records)")
    p.add_argument("--loads", nargs="+", type=float,
                   default=None,
                   help="offered load fractions of NIC bandwidth "
                   "(default: 0.1..1.0 for sweep, 0.5 0.9 for sim)")
    p.add_argument("--msg-bytes", type=float, default=4096)
    p.add_argument("--backend", choices=["auto", "numpy", "jax"],
                   default="auto")
    p.add_argument("--collective-mb", type=float, default=256.0,
                   help="all-reduce payload for the table2 suite")
    p.add_argument("--simulate", action="store_true",
                   help="sweep suite: add measured-FCT columns from the "
                   "flow simulator (minimal mode only)")
    p.add_argument("--flow-time-us", type=float, default=200.0,
                   help="sim: flow size as transfer seconds at the "
                   "offered rate (default 200us)")
    p.add_argument("--sim-collective-mb", type=float, default=16.0,
                   help="sim suite: measured-collective payload per NIC")
    p.add_argument("--sim-backend",
                   choices=["numpy", "jax", "pallas", "auto"],
                   default="numpy",
                   help="sim/sweep suites: fair-share solver path — "
                   "numpy reference loop, jax in-jit while_loop, pallas "
                   "segment kernels (repro.sim.fairshare); jax/pallas "
                   "make the 65K-NIC presets tractable")
    p.add_argument("--failures", nargs="+", default=None,
                   metavar="SPEC",
                   help="failure specs for the failures suite, e.g. "
                   "'link:0.01' 'link:0.01,plane:1' 'switch:0.02,seed:3' "
                   f"(default: {' '.join(DEFAULT_FAILURE_SPECS)}); "
                   "topologies whose engine lacks re-route support get "
                   "explicit skip records")
    p.add_argument("--failure-load", type=float, default=0.5,
                   help="offered load fraction for the failures suite")
    p.add_argument("--failure-mode",
                   choices=["minimal", "valiant", "adaptive"],
                   default="adaptive",
                   help="routing mode for degraded-fabric re-routing")
    p.add_argument("--reroute-modes", nargs="+", default=None,
                   choices=list(REROUTE_MODES), metavar="MODE",
                   help="recovery-curve reroute modes for the failures "
                   "suite: none (global recompute), local (precomputed "
                   "backup paths, no BFS), global (local bridge + full "
                   "reconvergence); default: all three")
    p.add_argument("--protection-layers", type=int, default=4,
                   help="FatPaths/MRC protection layers for "
                   "local/global reroute modes (default 4)")
    p.add_argument("--config", nargs="+", default=None, metavar="ARCH",
                   help="cosim suite: model configs to co-simulate "
                   "(underscores normalize to the registry's hyphenated "
                   f"arch ids; default: {' '.join(DEFAULT_COSIM_CONFIGS)})")
    p.add_argument("--ranks", type=int, default=DEFAULT_COSIM_RANKS,
                   help="cosim suite: training job size in ranks "
                   f"(default {DEFAULT_COSIM_RANKS})")
    p.add_argument("--device-tflops", type=float, default=989.0,
                   help="cosim suite: per-device peak for the overlapped "
                   "compute term (default 989, H100 bf16 dense)")
    p.add_argument("--cosim-method", choices=["steady", "batches"],
                   default="steady",
                   help="cosim phase execution: steady-state step scaling "
                   "or the fully serialized batch schedule")
    p.add_argument("--tenants", nargs="+", choices=sorted(TENANT_PRESETS),
                   default=None,
                   help="serving suite: tenant presets to mix on each "
                   f"fabric (default: {' '.join(DEFAULT_TENANTS)})")
    p.add_argument("--seed", type=int, default=0,
                   help="root seed for workload RNG (one SeedSequence "
                   "spawning a child per tenant) — same seed, same "
                   "artifact, byte for byte")
    p.add_argument("--serving-duration-ms", type=float, default=None,
                   help="serving suite: override every open-loop "
                   "tenant's window (CI smokes shrink it)")
    p.add_argument("--serving-rate-scale", type=float, default=1.0,
                   help="serving suite: scale every open-loop tenant's "
                   "arrival rate")
    p.add_argument("--trace", default=None, metavar="OUT.json",
                   help="run the suites under the fabric flight recorder "
                   "and export a Chrome/Perfetto trace_event JSON "
                   "(docs/observability.md); artifacts gain the "
                   "schema-v5 telemetry block")
    return p


def _note_if_untraced(rec, suite: str, n_before: int, reason: str) -> None:
    """Explicit skip record when a suite path crossed no traced layer."""
    if rec is not None and rec.n_events == n_before:
        rec.note_skip(suite, reason)


def main(argv: "list[str] | None" = None) -> int:
    args = build_parser().parse_args(argv)
    rc = 0
    if args.failures is not None:
        try:
            specs = [parse_failure_spec(s) for s in args.failures]
        except ValueError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
    else:
        specs = None
    rec, ctx = None, nullcontext()
    if args.trace:
        from repro.telemetry import TraceRecorder, recording
        rec = TraceRecorder()
        ctx = recording(rec)
    with ctx:
        rc = _run_suites(args, specs, rec)
    if rec is not None:
        rec.export(args.trace)
        print(f"trace: {rec.n_events} events, "
              f"{len(rec.notes)} untraced suites -> {args.trace}")
    return rc


def _run_suites(args, specs, rec=None) -> int:
    rc = 0
    if args.suite in ("table2", "all"):
        n0 = rec.n_events if rec else 0
        payload = run_table2_suite(args.out, args.collective_mb,
                                   args.msg_bytes)
        print(f"table2: {len(payload['rows'])} topologies -> "
              f"{args.out}/table2.json, {args.out}/table2.md")
        _note_if_untraced(rec, "table2", n0,
                          "analytic cost/diameter table — nothing "
                          "crosses the simulator")
    if args.suite in ("sweep", "all"):
        n0 = rec.n_events if rec else 0
        payload = run_sweep_suite(
            args.out, topo_names=args.topos, scenario_names=args.scenarios,
            modes=args.modes,
            load_fractions=tuple(args.loads) if args.loads
            else (0.1, 0.25, 0.5, 0.75, 0.9, 1.0),
            msg_bytes=args.msg_bytes, backend=args.backend,
            engine=args.engine, simulate=args.simulate,
            flow_time_s=args.flow_time_us * 1e-6,
            sim_backend=args.sim_backend)
        print(f"sweep: {payload['params']['n_routed_rows']} routed rows, "
              f"{payload['params']['n_skipped']} skipped -> "
              f"{args.out}/sweep.json, {args.out}/sweep.md")
        _note_if_untraced(rec, "sweep", n0,
                          "analytic routing sweep without --simulate — "
                          "nothing crosses the simulator")
    if args.suite in ("sim", "all"):
        n0 = rec.n_events if rec else 0
        payload = run_sim_suite(
            args.out, topo_names=args.topos, scenario_names=args.scenarios,
            load_fractions=tuple(args.loads) if args.loads else (0.5, 0.9),
            flow_time_s=args.flow_time_us * 1e-6,
            msg_bytes=args.msg_bytes,
            collective_mb=args.sim_collective_mb,
            backend=args.backend, engine=args.engine,
            sim_backend=args.sim_backend)
        agree = payload["params"]["all_steady_checks_agree_1e-6"]
        print(f"sim: {len(payload['rows'])} rows "
              f"(steady-state agreement: {agree}) -> "
              f"{args.out}/sim.json, {args.out}/sim.md")
        if agree is False:
            # remember the failure but keep going — the failures suite
            # below is independent and its artifacts must still land
            print("sim: FAIL — simulator steady-state loads diverge from "
                  "the analytic engine (>1e-6)", file=sys.stderr)
            rc = 1
        _note_if_untraced(rec, "sim", n0,
                          "suite produced no trace events (all cells "
                          "skipped)")
    if args.suite in ("cosim", "all"):
        n0 = rec.n_events if rec else 0
        # the sim suites interpret --topos as sweep topologies; the cosim
        # default trims to fabrics big enough for the default job
        cosim_topos = args.topos if args.topos else list(DEFAULT_COSIM_TOPOS)
        payload = run_cosim_suite(
            args.out, config_names=args.config, topo_names=cosim_topos,
            n_ranks=args.ranks, device_tflops=args.device_tflops,
            method=args.cosim_method,
            backend=args.backend if args.backend != "auto" else "numpy")
        print(f"cosim: {payload['params']['n_rows']} cells, "
              f"{payload['params']['n_skipped']} skipped -> "
              f"{args.out}/cosim.json, {args.out}/cosim.md")
        _note_if_untraced(rec, "cosim", n0,
                          "suite produced no trace events (all cells "
                          "skipped)")
    if args.suite in ("serving", "all"):
        n0 = rec.n_events if rec else 0
        # serving defaults to its own small-MPHX + baseline trio
        serving_topos = args.topos if args.topos \
            else list(DEFAULT_SERVING_TOPOS)
        payload = run_serving_suite(
            args.out, topo_names=serving_topos,
            tenant_names=args.tenants, seed=args.seed,
            engine=args.engine, backend=args.backend,
            sim_backend=args.sim_backend,
            duration_ms=args.serving_duration_ms,
            rate_scale=args.serving_rate_scale)
        print(f"serving: {payload['params']['n_rows']} tenant rows, "
              f"{payload['params']['n_skipped']} skipped -> "
              f"{args.out}/serving.json, {args.out}/serving.md")
        _note_if_untraced(rec, "serving", n0,
                          "suite produced no trace events (all cells "
                          "skipped)")
    if args.suite in ("failures", "all"):
        n0 = rec.n_events if rec else 0
        payload = run_failures_suite(
            args.out, topo_names=args.topos,
            scenario_names=args.scenarios, failure_specs=specs,
            offered_fraction=args.failure_load, mode=args.failure_mode,
            backend=args.backend, engine=args.engine,
            reroute_modes=args.reroute_modes,
            protection_layers=args.protection_layers)
        print(f"failures: {payload['params']['n_rows']} rows, "
              f"{payload['params']['n_skipped']} skipped -> "
              f"{args.out}/failures.json, {args.out}/failures.md")
        _note_if_untraced(rec, "failures", n0,
                          "suite produced no trace events (all cells "
                          "skipped)")
    return rc


if __name__ == "__main__":
    sys.exit(main())
