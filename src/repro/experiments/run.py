"""CLI for the experiment suites.

Examples::

    PYTHONPATH=src python -m repro.experiments.run --suite table2
    PYTHONPATH=src python -m repro.experiments.run --suite sweep \
        --topos mphx-2p-8x8 mphx-4p-86x9 --scenarios uniform neighbor_shift \
        --modes minimal adaptive --loads 0.25 0.5 1.0
    PYTHONPATH=src python -m repro.experiments.run --suite all

Artifacts land in ``--out`` (default ``results/experiments``):
``table2.json`` / ``table2.md`` and ``sweep.json`` / ``sweep.md``; the JSON
schema is documented in :mod:`repro.experiments.artifacts` and
``docs/experiments.md``.
"""

from __future__ import annotations

import argparse
import sys

from .scenarios import SCENARIOS
from .sweep import (DEFAULT_OUTDIR, DEFAULT_SWEEP_TOPOS, SWEEP_TOPOLOGIES,
                    run_sweep_suite, run_table2_suite)


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.experiments.run",
        description="MPHX experiment sweeps (paper §6 evaluation)")
    p.add_argument("--suite", choices=["table2", "sweep", "all"],
                   default="all")
    p.add_argument("--out", default=DEFAULT_OUTDIR,
                   help="artifact directory (default results/experiments)")
    p.add_argument("--topos", nargs="+", choices=sorted(SWEEP_TOPOLOGIES),
                   default=None, help="sweep topologies (default: "
                   f"{' '.join(DEFAULT_SWEEP_TOPOS)})")
    p.add_argument("--scenarios", nargs="+", choices=sorted(SCENARIOS),
                   default=None, help="scenarios (default: all; inapplicable "
                   "ones are recorded as skipped)")
    p.add_argument("--modes", nargs="+",
                   choices=["minimal", "valiant", "adaptive"], default=None,
                   help="routing modes (default: all three)")
    p.add_argument("--engine", choices=["auto", "array", "graph"],
                   default="auto",
                   help="routing engine (auto: array for MPHX, graph "
                   "for baseline topologies)")
    p.add_argument("--loads", nargs="+", type=float,
                   default=[0.1, 0.25, 0.5, 0.75, 0.9, 1.0],
                   help="offered load fractions of NIC bandwidth")
    p.add_argument("--msg-bytes", type=float, default=4096)
    p.add_argument("--backend", choices=["auto", "numpy", "jax"],
                   default="auto")
    p.add_argument("--collective-mb", type=float, default=256.0,
                   help="all-reduce payload for the table2 suite")
    return p


def main(argv: "list[str] | None" = None) -> int:
    args = build_parser().parse_args(argv)
    if args.suite in ("table2", "all"):
        payload = run_table2_suite(args.out, args.collective_mb,
                                   args.msg_bytes)
        print(f"table2: {len(payload['rows'])} topologies -> "
              f"{args.out}/table2.json, {args.out}/table2.md")
    if args.suite in ("sweep", "all"):
        payload = run_sweep_suite(
            args.out, topo_names=args.topos, scenario_names=args.scenarios,
            modes=args.modes, load_fractions=tuple(args.loads),
            msg_bytes=args.msg_bytes, backend=args.backend,
            engine=args.engine)
        print(f"sweep: {payload['params']['n_routed_rows']} routed rows, "
              f"{payload['params']['n_skipped']} skipped -> "
              f"{args.out}/sweep.json, {args.out}/sweep.md")
    return 0


if __name__ == "__main__":
    sys.exit(main())
