"""Training-step co-simulation suite: measured step time per fabric.

For each (model config, topology) cell, :func:`run_cosim_suite` derives
the step's collective phases from the model's sharding
(:func:`repro.cosim.job_from_model`), places ranks on NICs, and executes
the phase schedule on the flow simulator (:func:`repro.cosim.
simulate_step`) — yielding *measured* communication time, step time and
tokens/sec, next to the alpha-beta closed forms for the same phases.
MPHX cells run on BOTH routing engines (array and graph — the
cross-engine check at training-step granularity) and with both the
linear and the mapping-guided (:func:`repro.core.mapping.best_mapping`)
placements; baseline topologies route on the graph engine.  Cells whose
fabric has fewer NICs than the job has ranks become explicit skip
records, never silent drops.

Writes schema-v4 ``cosim.json`` / ``cosim.md``
(:mod:`~repro.experiments.artifacts`).
"""

from __future__ import annotations

import os
import sys
import time

from repro.core.hyperx import MPHX
from repro.core.netsim import make_router
from repro.cosim import job_from_model, simulate_step
from .artifacts import (artifact_payload, markdown_table, write_json,
                        write_markdown)
from .sweep import DEFAULT_OUTDIR, SWEEP_TOPOLOGIES

DEFAULT_COSIM_CONFIGS = ["kimi-k2-1t-a32b", "mixtral-8x22b"]
DEFAULT_COSIM_TOPOS = ["mphx-2p-8x8", "ft3-small", "dragonfly-small"]
DEFAULT_COSIM_RANKS = 64

# per-arch mesh preference: tp width and the widest ep worth using; dp
# fills the remaining ranks (ep shrinks to a divisor of dp when needed)
_MESH_PREF = {
    "kimi-k2-1t-a32b": {"tp": 16, "ep": 8},
    "mixtral-8x22b": {"tp": 8, "ep": 8},
}


def normalize_arch(name: str) -> str:
    """CLI convenience: ``kimi_k2_1t_a32b`` -> ``kimi-k2-1t-a32b``."""
    return name.replace("_", "-")


def default_mesh(arch_id: str, n_ranks: int, n_experts: "int | None" = None
                 ) -> dict:
    """(dp, tp, ep) split of ``n_ranks`` for one arch.

    ``tp`` shrinks to fit small rank counts; ``ep`` shrinks to the
    largest preference-bounded divisor of both ``dp`` and the expert
    count (1 for dense models).
    """
    pref = _MESH_PREF.get(arch_id, {"tp": 8, "ep": 8})
    tp = pref["tp"]
    while tp > 1 and n_ranks % tp:
        tp //= 2
    dp = max(n_ranks // tp, 1)
    ep = min(pref["ep"], dp) if n_experts else 1
    while ep > 1 and (dp % ep or n_experts % ep):
        ep -= 1
    return {"dp": dp, "tp": tp, "ep": ep}


def _cell_engines(topo) -> "list[tuple[str, str]]":
    """(engine, placement) variants to run for one topology."""
    if isinstance(topo, MPHX):
        return [("array", "linear"), ("array", "mapped"),
                ("graph", "linear")]
    return [("graph", "linear")]


def run_cosim_suite(outdir: str = DEFAULT_OUTDIR,
                    config_names: "list[str] | None" = None,
                    topo_names: "list[str] | None" = None,
                    n_ranks: int = DEFAULT_COSIM_RANKS,
                    shape: str = "train_4k",
                    device_tflops: float = 989.0,
                    method: str = "steady",
                    backend: str = "numpy") -> dict:
    """Co-simulate training steps over (config, topology, engine,
    placement) cells and write ``cosim.json`` / ``cosim.md``."""
    from repro.models.registry import get_config

    configs = [normalize_arch(c) for c in
               (config_names or DEFAULT_COSIM_CONFIGS)]
    names = topo_names or list(DEFAULT_COSIM_TOPOS)
    rows = []
    jobs = {}
    for arch in configs:
        cfg = get_config(arch)
        moe = cfg.moe
        mesh = default_mesh(arch, n_ranks,
                            moe.n_experts if moe is not None else None)
        jobs[arch] = job_from_model(cfg, shape=shape, **mesh)
    for tn in names:
        topo = SWEEP_TOPOLOGIES[tn]
        for arch, job in jobs.items():
            if job.n_ranks > topo.n_nics:
                reason = (f"job needs {job.n_ranks} ranks but {topo.name} "
                          f"has {topo.n_nics} NICs")
                print(f"cosim: skipping {arch!r} on {tn!r}: {reason}",
                      file=sys.stderr)
                rows.append({"topology": tn, "arch": arch,
                             "skipped": True, "reason": reason})
                continue
            for engine, placement in _cell_engines(topo):
                router = make_router(topo, backend="auto", engine=engine)
                t0 = time.perf_counter()
                res = simulate_step(topo, job, engine=engine,
                                    backend=backend, method=method,
                                    device_tflops=device_tflops,
                                    placement=placement, router=router)
                dt = round(time.perf_counter() - t0, 4)
                row = res.row()
                row["topology"] = tn
                rows.append({**row, "mesh": dict(job.mesh),
                             "engine": engine, "placement": placement,
                             "method": method, "sim_wall_s": dt})
    routed = [r for r in rows if not r.get("skipped")]
    payload = artifact_payload(
        "cosim",
        {"configs": configs, "topologies": names, "n_ranks": n_ranks,
         "shape": shape, "device_tflops": device_tflops,
         "method": method, "backend": backend,
         "meshes": {a: dict(j.mesh) for a, j in jobs.items()},
         "n_rows": len(routed),
         "n_skipped": sum(1 for r in rows if r.get("skipped"))},
        rows)
    write_json(os.path.join(outdir, "cosim.json"), payload)
    cols = ["topology", "arch", "engine", "placement", "n_ranks",
            "comm_ms", "compute_ms", "step_ms", "tokens_per_s",
            "analytic_comm_ms", "comm_over_analytic", "comm_fraction"]
    sections = [
        ("", "Measured training-step co-simulation: per-step collective "
             "phases derived from each model's sharding, executed on the "
             "flow-level fabric simulator (`repro.cosim`, see "
             "`docs/cosim.md`)."),
        ("Measured step time & tokens/sec", markdown_table(routed, cols)),
    ]
    phase_rows = [{"topology": r["topology"], "arch": r["arch"],
                   "engine": r["engine"], "placement": r["placement"],
                   **p}
                  for r in routed for p in r.get("phases", ())]
    if phase_rows:
        sections.append(
            ("Per-phase breakdown",
             markdown_table(phase_rows,
                            ["topology", "arch", "engine", "placement",
                             "phase", "kind", "group", "calls", "steps",
                             "measured_us", "analytic_us",
                             "measured_over_analytic"])))
    skipped = [r for r in rows if r.get("skipped")]
    if skipped:
        sections.append(("Skipped",
                         markdown_table(skipped,
                                        ["topology", "arch", "reason"])))
    write_markdown(os.path.join(outdir, "cosim.md"),
                   "Training-step co-simulation — measured step time",
                   sections)
    return payload
