"""Flow-simulator experiment suites: measured FCTs and degraded fabrics.

* :func:`run_sim_suite` — for each (topology, scenario): a steady-state
  cross-validation row (simulator load accounting vs the analytic engine,
  the 1e-6 agreement), measured-FCT rows per offered load from the event
  loop (:mod:`repro.sim.events`), and measured-vs-analytic collective
  rows (:mod:`repro.sim.collective_sim`).
* :func:`run_failures_suite` — degraded-fabric sweeps: for each
  (topology, failure spec, scenario), healthy-vs-degraded throughput and
  the recovery curve in every requested reroute mode — ``none`` (global
  recompute), ``local`` (precomputed-backup fast reroute via
  :mod:`repro.routing.protection`), ``global`` (local bridge + full
  reconvergence) — plus per-mode ``recovery_summary`` rows with the
  measured time-to-90%-throughput (:mod:`repro.sim.failures`).
  Topologies whose engine lacks re-route support (forced ``--engine
  array``, or no explicit switch graph) produce explicit skip records,
  never silent drops.

Both write schema-v3 JSON + markdown artifacts
(:mod:`~repro.experiments.artifacts`).
"""

from __future__ import annotations

import os
import sys
import time

from repro.core.netsim import load_sweep, make_router, resolve_engine
from repro.core.topology import Topology
from repro.sim.collective_sim import SIM_COLLECTIVES, simulate_collective
from repro.routing.protection import ProtectedRouter, REROUTE_MODES, \
    validate_reroute_mode
from repro.sim.failures import (FailureSpec, failure_throughput,
                                parse_failure_spec, recovery_curve,
                                time_to_recover)
from repro.sim.fairshare import flow_incidence
from .artifacts import (artifact_payload, markdown_table, write_json,
                        write_markdown)
from .scenarios import get_scenario
from .sweep import DEFAULT_OUTDIR, SWEEP_TOPOLOGIES

DEFAULT_SIM_TOPOS = ["mphx-2p-8x8", "dragonfly-small"]
DEFAULT_SIM_SCENARIOS = ["uniform", "neighbor_shift"]
DEFAULT_FAILURE_SPECS = ["link:0.01", "link:0.05"]

# the simulator needs a static per-flow path spread; adaptive re-routes
# under load and valiant on the graph engine averages over every
# intermediate — minimal is the mode both engines share
SIM_MODE = "minimal"


# collective schedules serialize O(n_nics) phases — at the 65K-NIC
# Table-2 presets that is ~130k fabric solves per collective, which is a
# dedicated benchmark (``benchmarks/run.py sim-scale``), not a suite row
MAX_COLLECTIVE_NICS = 4096


def _sim_topo_rows(topo: Topology, scenario_names, load_fractions,
                   flow_time_s, msg_bytes, backend, engine,
                   collective_mb, sim_backend="numpy") -> "list[dict]":
    engine_name = resolve_engine(topo, engine)
    router = make_router(topo, backend=backend, engine=engine)
    graph = getattr(router, "graph", None)
    rows = []
    for name in scenario_names:
        sc = get_scenario(name)
        reason = sc.skip_reason(topo)
        if reason is not None:
            print(f"sim: skipping scenario {name!r} on {topo.name!r}: "
                  f"{reason}", file=sys.stderr)
            rows.append({"topology": topo.name, "scenario": name,
                         "kind": "skip", "engine": engine_name,
                         "skipped": True, "reason": reason})
            continue
        build = lambda t, o, sc=sc: sc.build(t, o, graph=graph)
        # steady-state cross-validation at full injection
        dem = build(topo, topo.nic_bw_gbps)
        ll = router.route(dem, SIM_MODE)
        inc = flow_incidence(router, dem, SIM_MODE)
        u_sim = inc.utilization(dem.gbps)
        diff = float(abs(u_sim - ll.utilization_array()).max()) \
            if u_sim.size else 0.0
        rows.append({"topology": topo.name, "scenario": name,
                     "kind": "steady_check", "mode": SIM_MODE,
                     "engine": engine_name,
                     "max_util_analytic": round(ll.max_utilization(), 6),
                     "max_util_sim": round(float(u_sim.max()), 6)
                     if u_sim.size else 0.0,
                     "max_abs_util_diff": diff,
                     "agrees_1e-6": bool(diff < 1e-6)})
        # measured FCTs per load level
        t0 = time.perf_counter()
        sweep = load_sweep(topo, build, mode=SIM_MODE,
                           load_fractions=load_fractions,
                           msg_bytes=msg_bytes, backend=backend,
                           engine=engine, router=router, simulate=True,
                           flow_time_s=flow_time_s,
                           sim_backend=sim_backend)
        dt = time.perf_counter() - t0
        for r in sweep:
            rows.append({"topology": topo.name, "scenario": name,
                         "kind": "fct", "mode": SIM_MODE,
                         "engine": engine_name, **r,
                         "sim_wall_s": round(dt, 4)})
    # measured collectives (every registered collective schedule kind)
    for kind in SIM_COLLECTIVES:
        if topo.n_nics > MAX_COLLECTIVE_NICS:
            reason = (f"{topo.n_nics} NICs > {MAX_COLLECTIVE_NICS}: "
                      "collective schedules serialize O(n_nics) phases; "
                      "use benchmarks/run.py sim-scale for 65K fabrics")
            print(f"sim: skipping collective {kind!r} on {topo.name!r}: "
                  f"{reason}", file=sys.stderr)
            rows.append({"topology": topo.name, "scenario": kind,
                         "kind": "skip", "engine": engine_name,
                         "skipped": True, "reason": reason})
            continue
        t0 = time.perf_counter()
        row = simulate_collective(topo, kind,
                                  collective_mb * 2**20, router=router,
                                  mode=SIM_MODE, backend=sim_backend)
        rows.append({"kind": "collective", "mode": SIM_MODE,
                     "engine": engine_name, **row,
                     "sim_wall_s": round(time.perf_counter() - t0, 4)})
    return rows


def run_sim_suite(outdir: str = DEFAULT_OUTDIR,
                  topo_names: "list[str] | None" = None,
                  scenario_names: "list[str] | None" = None,
                  load_fractions=(0.5, 0.9),
                  flow_time_s: float = 200e-6,
                  msg_bytes: float = 4096,
                  collective_mb: float = 16.0,
                  backend: str = "auto",
                  engine: str = "auto",
                  sim_backend: str = "numpy") -> dict:
    """Run the flow simulator over (topology, scenario, load) cells and
    write ``sim.json`` / ``sim.md``.

    ``backend``/``engine`` select the routing array backend and engine as
    everywhere else; ``sim_backend`` picks the fair-share solver path
    (``numpy`` reference loop, ``jax`` in-jit while_loop, ``pallas``
    segment kernels, or ``auto`` — :mod:`repro.sim.fairshare`).  The jit
    paths make the 65K-NIC Table-2 presets (``mphx-8p-256``,
    ``mphx-4p-86x9``) tractable suite cells."""
    names = topo_names or list(DEFAULT_SIM_TOPOS)
    scenario_names = scenario_names or list(DEFAULT_SIM_SCENARIOS)
    all_rows = []
    for tn in names:
        topo = SWEEP_TOPOLOGIES[tn]
        try:
            resolve_engine(topo, engine)
        except ValueError as e:
            print(f"sim: skipping topology {topo.name!r}: {e}",
                  file=sys.stderr)
            all_rows.append({"topology": topo.name, "scenario": "*",
                             "engine": engine, "skipped": True,
                             "reason": str(e)})
            continue
        all_rows += _sim_topo_rows(topo, scenario_names, load_fractions,
                                   flow_time_s, msg_bytes, backend, engine,
                                   collective_mb, sim_backend=sim_backend)
    checks = [r for r in all_rows if r.get("kind") == "steady_check"]
    payload = artifact_payload(
        "sim",
        {"topologies": names, "scenarios": scenario_names,
         "mode": SIM_MODE, "load_fractions": list(load_fractions),
         "flow_time_s": flow_time_s, "msg_bytes": msg_bytes,
         "collective_mb": collective_mb, "backend": backend,
         "engine": engine, "sim_backend": sim_backend,
         "n_steady_checks": len(checks),
         "all_steady_checks_agree_1e-6":
             bool(all(r["agrees_1e-6"] for r in checks)) if checks
             else None,
         "n_skipped": sum(1 for r in all_rows if r.get("skipped"))},
        all_rows)
    write_json(os.path.join(outdir, "sim.json"), payload)
    sections = [
        ("", "Measured flow-completion times from the event-driven "
             "flow simulator (`repro.sim`), cross-validated against the "
             "analytic routing engines (see `docs/simulation.md`)."),
        ("Steady-state cross-validation (sim vs analytic loads)",
         markdown_table(checks,
                        ["topology", "scenario", "engine",
                         "max_util_analytic", "max_util_sim",
                         "max_abs_util_diff", "agrees_1e-6"])),
        ("Measured FCTs",
         markdown_table([r for r in all_rows if r.get("kind") == "fct"],
                        ["topology", "scenario", "offered_fraction",
                         "max_util", "sim_delivered_fraction",
                         "fct_p50_us", "fct_p99_us", "slowdown_mean",
                         "slowdown_p99", "sim_stalled"])),
        ("Collectives: measured vs analytic",
         markdown_table([r for r in all_rows
                         if r.get("kind") == "collective"],
                        ["topology", "collective", "bytes_per_nic", "steps",
                         "measured_us", "analytic_us", "analytic_algo",
                         "measured_over_analytic"])),
    ]
    skipped = [r for r in all_rows if r.get("skipped")]
    if skipped:
        sections.append(("Skipped",
                         markdown_table(skipped,
                                        ["topology", "scenario",
                                         "reason"])))
    write_markdown(os.path.join(outdir, "sim.md"),
                   "Flow-level simulation — measured FCTs & collectives",
                   sections)
    return payload


def run_failures_suite(outdir: str = DEFAULT_OUTDIR,
                       topo_names: "list[str] | None" = None,
                       scenario_names: "list[str] | None" = None,
                       failure_specs: "list[str | FailureSpec] | None" = None,
                       offered_fraction: float = 0.5,
                       mode: str = "adaptive",
                       backend: str = "auto",
                       engine: str = "auto",
                       reroute_modes: "list[str] | None" = None,
                       protection_layers: int = 4) -> dict:
    """Degraded-fabric sweep over (topology, failure spec, scenario) and
    write ``failures.json`` / ``failures.md``.

    Each routable cell yields one ``throughput`` row, ``recovery`` rows
    per phase of every mode in ``reroute_modes`` (default: all of
    ``none`` / ``local`` / ``global``), and one ``recovery_summary`` row
    per mode carrying the measured ``time_to_90_s``.  One
    :class:`~repro.routing.protection.ProtectedRouter` with
    ``protection_layers`` layers is provisioned per topology and shared
    across its specs/scenarios (as a real fabric would).

    Degraded fabrics re-route on the generic graph engine; a forced
    ``engine="array"`` (no re-route support) or a topology without an
    explicit switch graph yields one explicit skip record per cell.
    """
    names = topo_names or list(DEFAULT_SIM_TOPOS)
    scenario_names = scenario_names or ["uniform"]
    specs = [parse_failure_spec(s) if isinstance(s, str) else s
             for s in (failure_specs or DEFAULT_FAILURE_SPECS)]
    modes = [validate_reroute_mode(m)
             for m in (reroute_modes or list(REROUTE_MODES))]
    rows = []
    for tn in names:
        topo = SWEEP_TOPOLOGIES[tn]
        offered = offered_fraction * topo.nic_bw_gbps
        if engine == "array":
            reason = ("array engine lacks failure re-route support "
                      "(coordinate walks assume an intact mesh); use "
                      "engine=auto/graph")
            print(f"failures: skipping topology {topo.name!r}: {reason}",
                  file=sys.stderr)
            rows.append({"topology": topo.name, "failures": "*",
                         "skipped": True, "reason": reason})
            continue
        try:
            topo.build_graph()
        except NotImplementedError as e:
            print(f"failures: skipping topology {topo.name!r}: {e}",
                  file=sys.stderr)
            rows.append({"topology": topo.name, "failures": "*",
                         "skipped": True, "reason": str(e)})
            continue
        protection = None
        if any(m != "none" for m in modes):
            # provisioned once per fabric, shared across specs/scenarios
            protection = ProtectedRouter(topo, n_layers=protection_layers,
                                         backend=backend)
            protection.backup_next_hops()
        for spec in specs:
            if spec.planes_down >= topo.n_planes:
                rows.append({"topology": topo.name,
                             "failures": spec.label(), "skipped": True,
                             "reason": f"planes_down={spec.planes_down} "
                                       f">= {topo.n_planes} planes"})
                continue
            for name in scenario_names:
                sc = get_scenario(name)
                reason = sc.skip_reason(topo)
                if reason is None and spec.switch_fraction > 0 \
                        and sc.graph_builder is None:
                    # dead switches change the NIC set, so demands must be
                    # rebuilt from the degraded graph — coordinate-only
                    # scenarios cannot
                    reason = (f"scenario {name!r} has no graph builder "
                              "for switch-failure demand rebuild")
                if reason is not None:
                    rows.append({"topology": topo.name,
                                 "failures": spec.label(),
                                 "scenario": name, "skipped": True,
                                 "reason": reason})
                    continue
                if spec.switch_fraction > 0:
                    build = lambda t, o, g, sc=sc: sc.graph_builder(
                        t, o, graph=g)
                else:
                    build = lambda t, o, g, sc=sc: sc.build(t, o, graph=g)
                t0 = time.perf_counter()
                try:
                    ft = failure_throughput(topo, build, spec, offered,
                                            mode=mode, backend=backend)
                    ft_wall = time.perf_counter() - t0
                    curves = {}
                    for rm in modes:
                        curves[rm] = recovery_curve(
                            topo, build, spec, offered, mode=mode,
                            backend=backend, throughput_row=ft,
                            reroute_wall_s=ft_wall, reroute=rm,
                            protection=protection
                            if rm != "none" else None,
                            n_layers=protection_layers)
                except ValueError as e:
                    # survivors disconnected: an explicit skip record
                    # (no silent drops), flagged so it lands in the
                    # markdown skip table and n_skipped
                    rows.append({"topology": topo.name,
                                 "failures": spec.label(),
                                 "scenario": name, "skipped": True,
                                 "disconnected": True, "reason": str(e)})
                    continue
                dt = round(time.perf_counter() - t0, 4)
                rows.append({"topology": topo.name,
                             "failures": spec.label(), "scenario": name,
                             "kind": "throughput",
                             "offered_fraction": offered_fraction,
                             **ft, "sim_wall_s": dt})
                for rm, phases in curves.items():
                    for ph in phases:
                        rows.append({"topology": topo.name,
                                     "failures": spec.label(),
                                     "scenario": name, "kind": "recovery",
                                     "mode": mode, **ph})
                    summary = {"topology": topo.name,
                               "failures": spec.label(),
                               "scenario": name,
                               "kind": "recovery_summary", "mode": mode,
                               "reroute": rm,
                               "time_to_90_s": time_to_recover(phases),
                               "recovered_delivered_fraction":
                                   phases[-1].get("delivered_fraction"),
                               "n_phases": len(phases)}
                    if rm != "none":
                        summary["protection_layers"] = protection_layers
                        summary["protection_coverage"] = round(
                            protection.protection_coverage(), 6)
                    rows.append(summary)
    routed = [r for r in rows if not r.get("skipped")]
    payload = artifact_payload(
        "failures",
        {"topologies": names, "scenarios": scenario_names,
         "failure_specs": [s.label() for s in specs],
         "offered_fraction": offered_fraction, "mode": mode,
         "reroute_modes": modes, "protection_layers": protection_layers,
         "backend": backend, "engine": engine,
         "n_rows": len(routed),
         "n_skipped": sum(1 for r in rows if r.get("skipped"))},
        rows)
    write_json(os.path.join(outdir, "failures.json"), payload)
    sections = [
        ("", "Degraded-fabric evaluation: link/switch/plane failures are "
             "masked out of the switch graph and survivors re-route on "
             "the generic graph engine (see `docs/simulation.md`)."),
        ("Healthy vs degraded throughput",
         markdown_table([r for r in routed
                         if r.get("kind") == "throughput"],
                        ["topology", "failures", "scenario", "mode",
                         "healthy_max_util", "degraded_max_util",
                         "throughput_retained", "plane_capacity_factor",
                         "failed_links", "failed_switches"])),
        ("Recovery phases",
         markdown_table([r for r in routed
                         if r.get("kind") == "recovery"],
                        ["topology", "failures", "scenario", "reroute",
                         "phase", "delivered_fraction", "stalled_share",
                         "max_util", "t_offset_s", "phase_wall_s"])),
        ("Recovery summary (local vs global time-to-90%)",
         markdown_table([r for r in routed
                         if r.get("kind") == "recovery_summary"],
                        ["topology", "failures", "scenario", "reroute",
                         "time_to_90_s", "recovered_delivered_fraction",
                         "protection_coverage"])),
    ]
    skipped = [r for r in rows if r.get("skipped")]
    if skipped:
        sections.append(
            ("Skipped (no re-route support / undefined cell / "
             "disconnected survivors)",
             markdown_table(skipped, ["topology", "failures", "scenario",
                                      "reason"])))
    write_markdown(os.path.join(outdir, "failures.md"),
                   "Failure injection — degraded throughput & recovery",
                   sections)
    return payload
