"""Traffic scenario registry for the sweep runner.

A *scenario* names a switch-level traffic matrix builder over one MPHX
plane: synthetic patterns (the FatPaths/RailX evaluation style) plus
collective chunk schedules whose per-plane load derives from the paper's
NIC spraying model (:mod:`repro.core.planes`) and the JAX chunk
decomposition (:func:`repro.core.collectives.plane_chunk_count`).

Every builder has the signature ``builder(topo, offered_per_nic_gbps) ->
DemandArrays`` where ``offered_per_nic_gbps`` is the *injection* rate per
NIC across all planes; the builder internally takes one plane's share.

Docs: ``docs/experiments.md`` lists every scenario with its CLI invocation
and the artifact schema it emits.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

from repro.core.collectives import plane_chunk_count
from repro.core.hyperx import MPHX
from repro.core.planes import SprayConfig, plane_chunk_fractions
from repro.core.routing_vec import (DemandArrays, bit_complement_demands,
                                    hotspot_demands, neighbor_shift_demands,
                                    ring_demands, transpose_demands,
                                    uniform_demands)


@dataclass(frozen=True)
class Scenario:
    """A named traffic scenario."""

    name: str
    kind: str                 # "synthetic" | "collective"
    description: str
    builder: Callable[[MPHX, float], DemandArrays]
    default_mode: str = "adaptive"
    # cheap precondition; None = applies everywhere.  Kept separate from
    # the builder so applicability checks never materialize demand arrays.
    requires: "Callable[[MPHX], bool] | None" = None

    def applicable(self, topo: MPHX) -> bool:
        return self.requires is None or self.requires(topo)


SCENARIOS: dict[str, Scenario] = {}


def register(scenario: Scenario) -> Scenario:
    if scenario.name in SCENARIOS:
        raise ValueError(f"duplicate scenario {scenario.name}")
    SCENARIOS[scenario.name] = scenario
    return scenario


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; available: "
            f"{', '.join(sorted(SCENARIOS))}") from None


def available_scenarios(topo: MPHX | None = None) -> list[str]:
    names = sorted(SCENARIOS)
    if topo is None:
        return names
    return [n for n in names if SCENARIOS[n].applicable(topo)]


# ---------------------------------------------------------------------------
# Synthetic patterns
# ---------------------------------------------------------------------------

register(Scenario(
    "uniform", "synthetic",
    "Every NIC sprays uniformly over all other switches (best case; "
    "bisection-bound).",
    uniform_demands, default_mode="minimal"))

register(Scenario(
    "neighbor_shift", "synthetic",
    "+1 shift along dimension 0 — the paper's §5.2 adversarial case: one "
    "thin direct trunk per pair, minimal routing collapses, DAL recovers.",
    neighbor_shift_demands))

register(Scenario(
    "bit_complement", "synthetic",
    "Coordinate complement permutation (every dimension mismatched; "
    "classic worst case for dimension-ordered routing).",
    bit_complement_demands))

register(Scenario(
    "transpose", "synthetic",
    "Swap the first two coordinates (requires dims[0] == dims[1]); "
    "adversarial for dimension-ordered minimal routing.",
    transpose_demands,
    requires=lambda t: t.D >= 2 and t.dims[0] == t.dims[1]))

register(Scenario(
    "hotspot", "synthetic",
    "50% of every switch's load targets one hot switch, rest uniform "
    "(incast around the hot spot).",
    hotspot_demands))


# ---------------------------------------------------------------------------
# Collective chunk schedules (plane spraying from planes.py / collectives.py)
# ---------------------------------------------------------------------------


def _spray_imbalance(topo: MPHX, payload_bytes: int) -> float:
    """Hottest plane's share of a sprayed collective, relative to perfect
    1/n spray.  Whole-chunk rounding makes early planes carry more for
    small payloads; the sweep charges the plane fabric at that factor."""
    cfg = SprayConfig(n_planes=topo.n)
    fracs = plane_chunk_fractions(payload_bytes, cfg)
    return max(fracs) * topo.n


def _collective_builder(pattern, payload_bytes: int = 1 << 20,
                        ring_chunked: bool = False):
    """Scale a pattern by the hottest plane's share of the chunk schedule.

    ``ring_chunked``: a ring all-reduce moves ``payload/m`` per step
    (m ring participants = switches per plane), so spray imbalance is
    computed on the per-step chunk — small chunks spray poorly.  An
    all-gather ring moves the full payload every step.
    """

    def build(topo: MPHX, offered_per_nic_gbps: float) -> DemandArrays:
        d = pattern(topo, offered_per_nic_gbps)
        step_bytes = payload_bytes
        if ring_chunked:
            step_bytes = max(payload_bytes // topo.switches_per_plane, 1)
        # when the step payload does not chunk evenly over the planes the
        # JAX decomposition issues ONE ordered collective (collectives.py),
        # so a single plane carries each step in turn -> full n penalty
        if plane_chunk_count(step_bytes, topo.n) == 1:
            scale = float(topo.n)
        else:
            scale = _spray_imbalance(topo, step_bytes)
        return DemandArrays(d.src, d.dst, d.gbps * scale)

    return build


register(Scenario(
    "allreduce_ring", "collective",
    "Steady-state link pattern of a ring all-reduce over switch-ordered "
    "ranks; per-step chunk is payload/m, so the spray schedule is charged "
    "on small chunks.",
    _collective_builder(ring_demands, ring_chunked=True),
    default_mode="minimal"))

register(Scenario(
    "allgather_ring", "collective",
    "Ring all-gather steady-state pattern (same ring links as all-reduce "
    "but the full payload moves every step, so spraying is near-perfect).",
    _collective_builder(ring_demands), default_mode="minimal"))

register(Scenario(
    "alltoall", "collective",
    "All-to-all chunk exchange — uniform all-pairs at full injection, "
    "spray-chunked across planes (bisection-bound).",
    _collective_builder(uniform_demands), default_mode="minimal"))
