"""Traffic scenario registry for the sweep runner.

A *scenario* names a switch-level traffic matrix over one plane of a
topology: synthetic patterns (the FatPaths/RailX evaluation style) plus
collective chunk schedules whose per-plane load derives from the paper's
NIC spraying model (:mod:`repro.core.planes`) and the JAX chunk
decomposition (:func:`repro.core.collectives.plane_chunk_count`).

Every scenario carries up to two builders with the signature
``builder(topo, offered_per_nic_gbps) -> DemandArrays`` where
``offered_per_nic_gbps`` is the *injection* rate per NIC across all
planes (the builder internally takes one plane's share):

* ``builder`` — the MPHX coordinate builder (:mod:`repro.core.routing_vec`
  generators; exact paper semantics, e.g. neighbor shift along dim 0);
* ``graph_builder`` — the generic :class:`~repro.core.topology.SwitchGraph`
  analogue (:mod:`repro.core.routing_graph` generators; NIC-bearing
  switches in id order), used for the Table-2 baseline topologies.

A scenario without a ``graph_builder`` (``transpose`` needs a coordinate
grid) is *skipped with an explicit reason* on non-MPHX topologies —
:meth:`Scenario.skip_reason` is the single source of truth the sweep
runner records in the artifact (no silent drops).

Docs: ``docs/experiments.md`` lists every scenario with its CLI invocation
and the artifact schema it emits; ``docs/routing.md`` covers the engines.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

from repro.core.collectives import plane_chunk_count
from repro.core.hyperx import MPHX
from repro.core.planes import SprayConfig, plane_chunk_fractions
from repro.core.routing_graph import (graph_hotspot_demands,
                                      graph_reverse_demands,
                                      graph_ring_demands, graph_shift_demands,
                                      graph_uniform_demands)
from repro.core.routing_vec import (DemandArrays, bit_complement_demands,
                                    hotspot_demands, neighbor_shift_demands,
                                    ring_demands, transpose_demands,
                                    uniform_demands)
from repro.core.topology import Topology


@dataclass(frozen=True)
class Scenario:
    """A named traffic scenario."""

    name: str
    kind: str                 # "synthetic" | "collective"
    description: str
    builder: Callable[[MPHX, float], DemandArrays]
    default_mode: str = "adaptive"
    # cheap MPHX precondition; None = applies to every MPHX.  Kept separate
    # from the builder so applicability checks never materialize demands.
    requires: "Callable[[MPHX], bool] | None" = None
    requires_reason: str = ""
    # generic SwitchGraph builder; None = MPHX-only scenario
    graph_builder: "Callable[[Topology, float], DemandArrays] | None" = None

    def skip_reason(self, topo: Topology) -> "str | None":
        """Why this scenario does not apply to ``topo`` (None = it does)."""
        if isinstance(topo, MPHX):
            if self.requires is not None and not self.requires(topo):
                return self.requires_reason or "precondition not met"
            return None
        if self.graph_builder is None:
            return ("MPHX-coordinate pattern with no generic graph "
                    "analogue")
        if type(topo).build_graph is Topology.build_graph:
            return f"{topo.name} has no explicit switch graph"
        return None

    def applicable(self, topo: Topology) -> bool:
        return self.skip_reason(topo) is None

    def build(self, topo: Topology, offered_per_nic_gbps: float,
              graph=None) -> DemandArrays:
        """Demand matrix for one plane of ``topo`` (dispatches to the
        coordinate builder on MPHX, the graph builder otherwise).  Pass a
        prebuilt ``graph`` to avoid rebuilding the SwitchGraph per call."""
        if isinstance(topo, MPHX):
            return self.builder(topo, offered_per_nic_gbps)
        if self.graph_builder is None:
            raise ValueError(
                f"scenario {self.name!r} is MPHX-only: "
                f"{self.skip_reason(topo)}")
        return self.graph_builder(topo, offered_per_nic_gbps, graph=graph)


SCENARIOS: dict[str, Scenario] = {}


def register(scenario: Scenario) -> Scenario:
    if scenario.name in SCENARIOS:
        raise ValueError(f"duplicate scenario {scenario.name}")
    SCENARIOS[scenario.name] = scenario
    return scenario


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; available: "
            f"{', '.join(sorted(SCENARIOS))}") from None


def available_scenarios(topo: "Topology | None" = None) -> list[str]:
    names = sorted(SCENARIOS)
    if topo is None:
        return names
    return [n for n in names if SCENARIOS[n].applicable(topo)]


# ---------------------------------------------------------------------------
# Synthetic patterns
# ---------------------------------------------------------------------------

register(Scenario(
    "uniform", "synthetic",
    "Every NIC sprays uniformly over all other NIC-bearing switches "
    "(best case; bisection-bound).",
    uniform_demands, default_mode="minimal",
    graph_builder=graph_uniform_demands))

register(Scenario(
    "neighbor_shift", "synthetic",
    "+1 shift permutation — the paper's §5.2 adversarial case: one thin "
    "direct path per pair, minimal routing collapses, non-minimal "
    "recovers.  MPHX: +1 along dim 0; generic: +1 in NIC-switch id order.",
    neighbor_shift_demands,
    graph_builder=graph_shift_demands))

register(Scenario(
    "bit_complement", "synthetic",
    "Complement permutation (every demand crosses the whole fabric; "
    "classic worst case for dimension-ordered routing).  MPHX: coordinate "
    "complement; generic: reverse pairing in NIC-switch id order.",
    bit_complement_demands,
    graph_builder=graph_reverse_demands))

register(Scenario(
    "transpose", "synthetic",
    "Swap the first two coordinates (requires dims[0] == dims[1]); "
    "adversarial for dimension-ordered minimal routing.",
    transpose_demands,
    requires=lambda t: t.D >= 2 and t.dims[0] == t.dims[1],
    requires_reason="transpose needs a square coordinate grid "
                    "(dims[0] == dims[1])"))

register(Scenario(
    "hotspot", "synthetic",
    "50% of every switch's load targets one hot switch, rest uniform "
    "(incast around the hot spot).",
    hotspot_demands,
    graph_builder=graph_hotspot_demands))


# ---------------------------------------------------------------------------
# Collective chunk schedules (plane spraying from planes.py / collectives.py)
# ---------------------------------------------------------------------------


def _spray_imbalance(n_planes: int, payload_bytes: int) -> float:
    """Hottest plane's share of a sprayed collective, relative to perfect
    1/n spray.  Whole-chunk rounding makes early planes carry more for
    small payloads; the sweep charges the plane fabric at that factor."""
    cfg = SprayConfig(n_planes=n_planes)
    fracs = plane_chunk_fractions(payload_bytes, cfg)
    return max(fracs) * n_planes


def _ring_size(topo: Topology, graph=None) -> int:
    """Ring participants: switches per plane (MPHX) or NIC-bearing
    switches (generic graphs)."""
    if isinstance(topo, MPHX):
        return topo.switches_per_plane
    if graph is None:
        graph = topo.build_graph()
    return len(graph.nic_nodes)


def _collective_builder(pattern, graph_pattern=None,
                        payload_bytes: int = 1 << 20,
                        ring_chunked: bool = False):
    """Scale a pattern by the hottest plane's share of the chunk schedule.

    ``ring_chunked``: a ring all-reduce moves ``payload/m`` per step
    (m ring participants), so spray imbalance is computed on the per-step
    chunk — small chunks spray poorly.  An all-gather ring moves the full
    payload every step.
    """

    def build(topo: Topology, offered_per_nic_gbps: float,
              graph=None) -> DemandArrays:
        if isinstance(topo, MPHX):
            d = pattern(topo, offered_per_nic_gbps)
        else:
            d = graph_pattern(topo, offered_per_nic_gbps, graph=graph)
        step_bytes = payload_bytes
        if ring_chunked:
            step_bytes = max(payload_bytes // _ring_size(topo, graph), 1)
        # when the step payload does not chunk evenly over the planes the
        # JAX decomposition issues ONE ordered collective (collectives.py),
        # so a single plane carries each step in turn -> full n penalty
        n = topo.n_planes
        if plane_chunk_count(step_bytes, n) == 1:
            scale = float(n)
        else:
            scale = _spray_imbalance(n, step_bytes)
        return DemandArrays(d.src, d.dst, d.gbps * scale)

    return build


def _register_collective(name, description, pattern, graph_pattern,
                         **kw):
    both = _collective_builder(pattern, graph_pattern, **kw)
    register(Scenario(name, "collective", description, both,
                      default_mode="minimal", graph_builder=both))


_register_collective(
    "allreduce_ring",
    "Steady-state link pattern of a ring all-reduce over switch-ordered "
    "ranks; per-step chunk is payload/m, so the spray schedule is charged "
    "on small chunks.",
    ring_demands, graph_ring_demands, ring_chunked=True)

_register_collective(
    "allgather_ring",
    "Ring all-gather steady-state pattern (same ring links as all-reduce "
    "but the full payload moves every step, so spraying is near-perfect).",
    ring_demands, graph_ring_demands)

_register_collective(
    "alltoall",
    "All-to-all chunk exchange — uniform all-pairs at full injection, "
    "spray-chunked across planes (bisection-bound).",
    uniform_demands, graph_uniform_demands)
