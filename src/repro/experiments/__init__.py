"""repro.experiments — the paper's deferred §6 evaluation, as a subsystem.

Builds on the batched routing engines — the MPHX coordinate array engine
(:mod:`repro.core.routing_vec`) and the topology-agnostic graph engine
(:mod:`repro.core.routing_graph`, all Table-2 baselines) — to evaluate
whole traffic matrices in one shot:

* :mod:`~repro.experiments.scenarios` — named traffic scenarios (synthetic
  patterns + collective chunk schedules) with a registry;
* :mod:`~repro.experiments.sweep`     — suite runners: Table-2 topology
  comparison, latency/throughput-vs-load sweeps;
* :mod:`~repro.experiments.simsuite`  — flow-simulator suites: measured
  FCTs (``sim``) and degraded fabrics (``failures``), on
  :mod:`repro.sim`;
* :mod:`~repro.experiments.cosuite`   — training-step co-simulation
  (``cosim``): measured step time and tokens/sec per fabric, on
  :mod:`repro.cosim`;
* :mod:`~repro.experiments.servesuite` — multi-tenant serving suite
  (``serving``): per-tenant SLO rows for mixed open-loop tenants, on
  :mod:`repro.workload`;
* :mod:`~repro.experiments.artifacts` — JSON + markdown artifact writers
  (schema v6);
* :mod:`~repro.experiments.run`       — the CLI
  (``python -m repro.experiments.run --suite table2``).
"""

from .cosuite import (DEFAULT_COSIM_CONFIGS, DEFAULT_COSIM_TOPOS,
                      default_mesh, run_cosim_suite)
from .scenarios import SCENARIOS, Scenario, available_scenarios, get_scenario
from .servesuite import (DEFAULT_SERVING_TOPOS, DEFAULT_TENANTS,
                         TENANT_PRESETS, run_serving_suite, tenant_specs)
from .simsuite import (DEFAULT_FAILURE_SPECS, DEFAULT_SIM_SCENARIOS,
                       DEFAULT_SIM_TOPOS, run_failures_suite, run_sim_suite)
from .sweep import (DEFAULT_SWEEP_TOPOS, ROUTING_MODES, SWEEP_TOPOLOGIES,
                    run_sweep_suite, run_table2_suite, sweep_topology)
from .artifacts import markdown_table, write_json, write_markdown

__all__ = [
    "DEFAULT_COSIM_CONFIGS", "DEFAULT_COSIM_TOPOS", "default_mesh",
    "run_cosim_suite",
    "SCENARIOS", "Scenario", "available_scenarios", "get_scenario",
    "DEFAULT_SERVING_TOPOS", "DEFAULT_TENANTS", "TENANT_PRESETS",
    "run_serving_suite", "tenant_specs",
    "DEFAULT_FAILURE_SPECS", "DEFAULT_SIM_SCENARIOS", "DEFAULT_SIM_TOPOS",
    "run_failures_suite", "run_sim_suite",
    "DEFAULT_SWEEP_TOPOS", "ROUTING_MODES", "SWEEP_TOPOLOGIES",
    "run_sweep_suite", "run_table2_suite", "sweep_topology",
    "markdown_table", "write_json", "write_markdown",
]
