"""Experiment suite runners.

* :func:`run_table2_suite` — the paper's Table 2 cost/diameter comparison
  across MPHX, multi-plane Fat-Tree, Dragonfly and Dragonfly+, joined with
  the flow-level latency/throughput model (the §6 evaluation the paper
  defers to future work).
* :func:`run_sweep_suite` — latency/throughput-vs-load sweeps of every
  registered traffic scenario over MPHX instances *and* the Table-2
  baseline topologies, computed with real routed loads: the MPHX array
  engine (:mod:`repro.core.routing_vec`) for HyperX and the generic graph
  engine (:mod:`repro.core.routing_graph`) for everything else.  Every row
  records which ``engine`` produced it; a scenario that does not apply to
  a topology produces an explicit ``skipped`` record (with a reason) in
  the artifact and a stderr note — never a silent drop.

Both write JSON + markdown artifacts (see :mod:`~repro.experiments.artifacts`
for the schema, version 2) and return the JSON payloads.
"""

from __future__ import annotations

import os
import sys
import time

from repro.core import MPHX, PAPER_TABLE2, cost_report, table2_topologies
from repro.core.dragonfly import Dragonfly, DragonflyPlus
from repro.core.fattree import MultiPlaneFatTree, ThreeTierFatTree
from repro.core.netsim import (DEFAULT_NET, allreduce_time, avg_latency,
                               load_sweep, make_router, resolve_engine,
                               uniform_throughput_fraction, zero_load_latency)
from repro.core.topology import Topology
from .artifacts import (artifact_payload, markdown_table, write_json,
                        write_markdown)
from .scenarios import SCENARIOS, get_scenario

DEFAULT_OUTDIR = os.path.join("results", "experiments")

ROUTING_MODES = ("minimal", "valiant", "adaptive")

# Topologies for routed sweeps.  MPHX instances route on the coordinate
# array engine; every other topology routes on the generic graph engine
# over its explicit SwitchGraph — all 8 Table-2 topology classes are
# covered.  The ``*-small`` presets are scaled-down instances of the
# Table-2 baselines for fast default sweeps and CI; the ``*-65536``
# presets are the actual Table-2 rows (opt-in: graph routing at 65K NICs
# takes minutes, not seconds).
SWEEP_TOPOLOGIES: dict[str, Topology] = {
    # -- MPHX (array engine) --
    # small — fast, and exactly comparable against the legacy dict router
    "mphx-2p-8x8": MPHX(n=2, p=8, dims=(8, 8)),
    # medium — 4k NICs
    "mphx-2p-16x16": MPHX(n=2, p=16, dims=(16, 16)),
    # Table 2 row: 66,564 NICs, trunked dim 2
    "mphx-4p-86x9": MPHX(n=4, p=86, dims=(86, 9), links_per_dim=(85, 85),
                         name="4-Plane 2D HyperX"),
    # Table 2 row: 65,536 NICs, single full-mesh dimension
    "mphx-8p-256": MPHX(n=8, p=256, dims=(256,), name="8-Plane 1D HyperX"),
    # -- Table-2 baselines, small presets (graph engine) --
    "ft3-small": ThreeTierFatTree(radix=8, nics=128,
                                  name="3-layer Fat-Tree (small)"),
    "mpft-2p-small": MultiPlaneFatTree(n=2, nics=32, base_radix=4,
                                       name="2-Plane 2-layer Fat-Tree "
                                            "(small)"),
    "dragonfly-small": Dragonfly(p=2, a=4, h=2, groups=9,
                                 name="Dragonfly (small)"),
    "dfplus-small": DragonflyPlus(p=2, leaves=4, spines=4, groups=8,
                                  global_per_spine=7,
                                  name="Dragonfly+ (small)"),
    # -- Table-2 baselines, paper-scale rows (graph engine; opt-in) --
    "ft3-65536": ThreeTierFatTree(radix=64, nics=65_536),
    "mpft-8p-65536": MultiPlaneFatTree(n=8, nics=65_536),
    "dragonfly-65536": Dragonfly(p=16, a=32, h=16, groups=128),
    "dfplus-65536": DragonflyPlus(),
}

# default sweep: the small MPHX preset + all four baseline classes, so a
# bare ``--suite sweep`` exercises both engines end to end
DEFAULT_SWEEP_TOPOS = ["mphx-2p-8x8", "ft3-small", "mpft-2p-small",
                       "dragonfly-small", "dfplus-small"]


# ---------------------------------------------------------------------------
# Table 2 suite
# ---------------------------------------------------------------------------


def run_table2_suite(outdir: str = DEFAULT_OUTDIR,
                     collective_mb: float = 256.0,
                     msg_bytes: float = 4096) -> dict:
    """Reproduce paper Table 2 (§4) and extend it with the flow-level
    latency / throughput / collective model (§6)."""
    rows = []
    paper = {name: (n, ns, no, usd) for name, n, ns, no, usd in PAPER_TABLE2}
    for topo in table2_topologies():
        rep = cost_report(topo)
        ar = allreduce_time(topo, collective_mb * 2**20, net=DEFAULT_NET)
        row = {
            "topology": topo.name,
            "N": topo.n_nics,
            "N_s": topo.n_switches,
            "N_o": rep.n_optics,
            "cost_per_nic_usd": round(rep.per_nic_usd, 2),
            "paper_cost_per_nic_usd": paper.get(topo.name, (0, 0, 0, None))[3],
            "diameter": topo.diameter,
            "avg_hops": round(topo.avg_hops(), 3),
            "zero_load_latency_us":
                round(zero_load_latency(topo, msg_bytes) * 1e6, 3),
            "avg_latency_us": round(avg_latency(topo, msg_bytes) * 1e6, 3),
            "uniform_throughput": round(uniform_throughput_fraction(topo), 3),
            f"allreduce_{int(collective_mb)}MB_ms": round(ar.total_s * 1e3, 3),
            "allreduce_algo": ar.algo,
        }
        if row["paper_cost_per_nic_usd"]:
            row["cost_matches_paper"] = (
                abs(rep.per_nic_usd - row["paper_cost_per_nic_usd"]) < 3.0)
        rows.append(row)
    payload = artifact_payload(
        "table2",
        {"collective_mb": collective_mb, "msg_bytes": msg_bytes,
         "cost_note": "paper §4 prices: $40k switch, 200G/$100 400G/$200 "
                      "800G/$450 1.6T/$1200 optics"},
        rows)
    write_json(os.path.join(outdir, "table2.json"), payload)
    write_markdown(
        os.path.join(outdir, "table2.md"),
        "Table 2 — topology cost & latency comparison (65K-NIC scale)",
        [("", "Reproduces paper Table 2 (§4) and joins the flow-level "
              "latency/throughput model (§6 future-work evaluation)."),
         ("Comparison", markdown_table(rows))])
    return payload


# ---------------------------------------------------------------------------
# Load sweeps
# ---------------------------------------------------------------------------


def sweep_topology(topo: Topology, scenario_names: "list[str] | None" = None,
                   modes: "list[str] | None" = None,
                   load_fractions=(0.1, 0.25, 0.5, 0.75, 0.9, 1.0),
                   msg_bytes: float = 4096,
                   backend: str = "auto",
                   engine: str = "auto",
                   simulate: bool = False,
                   flow_time_s: float = 200e-6,
                   sim_backend: str = "numpy") -> list[dict]:
    """Latency/throughput-vs-load rows for one topology instance.

    Returns routed rows plus, for every requested scenario that does not
    apply to ``topo``, one ``{"skipped": True, "reason": ...}`` record —
    undefined (topology, scenario) cells are never dropped silently
    (a note also goes to stderr).  A forced ``engine`` that cannot route
    ``topo`` (e.g. ``--engine array`` on a Fat-Tree) likewise yields one
    skip record for the whole topology instead of aborting the suite.
    """
    try:
        engine_name = resolve_engine(topo, engine)
    except ValueError as e:
        print(f"sweep: skipping topology {topo.name!r}: {e}",
              file=sys.stderr)
        return [{"topology": topo.name, "scenario": "*", "engine": engine,
                 "skipped": True, "reason": str(e)}]
    # one router per topology: the graph engine's SwitchGraph build and
    # all-pairs BFS are shared across every (scenario, mode, load) cell
    router = make_router(topo, backend=backend, engine=engine)
    graph = getattr(router, "graph", None)
    rows = []
    for name in scenario_names or sorted(SCENARIOS):
        sc = get_scenario(name)
        reason = sc.skip_reason(topo)
        if reason is not None:
            print(f"sweep: skipping scenario {name!r} on {topo.name!r}: "
                  f"{reason}", file=sys.stderr)
            rows.append({"topology": topo.name, "scenario": name,
                         "kind": sc.kind, "engine": engine_name,
                         "skipped": True, "reason": reason})
            continue
        build = lambda t, o, sc=sc: sc.build(t, o, graph=graph)
        mode_list = modes if modes is not None else list(ROUTING_MODES)
        for mode in mode_list:
            # the flow simulator needs a static per-flow path spread —
            # measured FCT columns ride only the minimal-mode rows
            sim_here = simulate and mode == "minimal"
            t0 = time.perf_counter()
            sweep = load_sweep(topo, build, mode=mode,
                               load_fractions=load_fractions,
                               msg_bytes=msg_bytes, backend=backend,
                               engine=engine, router=router,
                               simulate=sim_here, flow_time_s=flow_time_s,
                               sim_backend=sim_backend)
            dt = time.perf_counter() - t0
            for r in sweep:
                rows.append({"topology": topo.name, "scenario": name,
                             "kind": sc.kind, "mode": mode,
                             "engine": engine_name, **r,
                             "sweep_wall_s": round(dt, 4)})
    return rows


def run_sweep_suite(outdir: str = DEFAULT_OUTDIR,
                    topo_names: "list[str] | None" = None,
                    scenario_names: "list[str] | None" = None,
                    modes: "list[str] | None" = None,
                    load_fractions=(0.1, 0.25, 0.5, 0.75, 0.9, 1.0),
                    msg_bytes: float = 4096,
                    backend: str = "auto",
                    engine: str = "auto",
                    simulate: bool = False,
                    flow_time_s: float = 200e-6,
                    sim_backend: str = "numpy") -> dict:
    """Sweep every (topology, scenario, mode, load) cell and write artifacts."""
    names = topo_names or list(DEFAULT_SWEEP_TOPOS)
    all_rows = []
    for tn in names:
        topo = SWEEP_TOPOLOGIES[tn]
        all_rows += sweep_topology(topo, scenario_names, modes,
                                   load_fractions, msg_bytes, backend,
                                   engine, simulate, flow_time_s,
                                   sim_backend=sim_backend)
    routed = [r for r in all_rows if not r.get("skipped")]
    skipped = [r for r in all_rows if r.get("skipped")]
    payload = artifact_payload(
        "sweep",
        {"topologies": names,
         "scenarios": scenario_names or sorted(SCENARIOS),
         "modes": modes or list(ROUTING_MODES),
         "load_fractions": list(load_fractions),
         "msg_bytes": msg_bytes, "backend": backend, "engine": engine,
         "simulate": simulate,
         "n_routed_rows": len(routed), "n_skipped": len(skipped)},
        all_rows)
    write_json(os.path.join(outdir, "sweep.json"), payload)
    # markdown: one table per topology at the highest swept load
    top_load = max(load_fractions)
    sections = []
    for tn in names:
        topo = SWEEP_TOPOLOGIES[tn]
        t_rows = [r for r in routed if r["topology"] == topo.name]
        full = [r for r in t_rows if r["offered_fraction"] == top_load]
        cols = ["scenario", "mode", "engine", "max_util",
                "throughput_fraction", "delivered_fraction", "latency_us"]
        sections.append(
            (f"{topo.name} ({topo.n_nics} NICs) @ {top_load:g}x injection",
             markdown_table(full, cols)))
    if skipped:
        sections.append(
            ("Skipped (scenario undefined for topology)",
             markdown_table(skipped,
                            ["topology", "scenario", "reason"])))
    write_markdown(os.path.join(outdir, "sweep.md"),
                   "Latency / throughput vs offered load", sections)
    return payload
