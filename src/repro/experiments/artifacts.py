"""JSON + markdown artifact writers for experiment suites.

Artifact schema (``schema_version`` 7):

```json
{
  "schema_version": 7,
  "suite": "table2" | "sweep" | "sim" | "failures" | "cosim" | "serving",
  "generated_by": "repro.experiments",
  "params": { ... suite parameters ... },
  "rows": [ { ... flat record ... }, ... ],
  "telemetry": { "counters": ..., "gauges": ..., "timers": ... }
}
```

Every suite writes ``<suite>.json`` (machine-readable, exactly the payload
above) and ``<suite>.md`` (the same rows as a GitHub-flavored markdown
table, for review in PRs).

Schema history:

* **v7** — ``failures`` recovery rows gain a ``reroute`` column (one
  curve per requested reroute mode: ``none`` = global recompute,
  ``local`` = precomputed-backup fast reroute from
  ``repro.routing.protection``, ``global`` = local bridge + full
  reconvergence; ``local``/``global`` curves add a ``local_reroute``
  phase with ``diverted_gbps`` / ``conservation_residual``, ``global``
  ends in a ``reconverged`` phase), and each cell adds per-mode
  ``recovery_summary`` rows with the measured ``time_to_90_s`` and
  ``protection_coverage``; ``failures`` params gain ``reroute_modes`` /
  ``protection_layers``.  All other suites' columns are unchanged.
* **v6** — new ``serving`` suite from the multi-tenant workload
  generator (``repro.workload``): one row per (topology, tenant) with
  measured per-tenant ``fct_p50_us`` / ``fct_p99_us`` / ``fct_p999_us``,
  TTFT-proxy percentiles for serving tenants (``ttft_*_us``),
  ``goodput_gbps``, slowdown-vs-isolation
  (``slowdown_mean`` / ``slowdown_p99``) and stall counts; params carry
  the ``seed`` plus the fully-resolved tenant specs, and the rows hold
  no wall-clock fields — same seed, same bytes.  Undersized fabrics
  produce explicit ``{"skipped": true, ...}`` records.  ``sim`` rows
  gain an optional ``per_tag`` FCT breakdown when the caller attributes
  demand rows with flow tags.  All existing suites' columns are
  unchanged.
* **v5** — optional top-level ``telemetry`` block: the ambient
  :class:`repro.telemetry.MetricsRegistry` snapshot (operational
  counters — engine walks, incidence-cache hit/miss, water-filling
  rounds, event-loop epochs, re-spray events, re-route recomputes — plus
  wall-time timers) captured when a suite runs inside a collecting scope
  (``--trace`` or :func:`repro.telemetry.collecting`).  Absent when
  telemetry is disabled, so v4 consumers are unaffected; all existing
  suites' columns are unchanged.  ``failures`` recovery rows gain
  measured ``phase_wall_s`` / ``t_offset_s`` columns.
* **v4** — new ``cosim`` suite from the training-step co-simulator
  (``repro.cosim``): rows carry the (config, topology, engine,
  placement) cell plus measured ``comm_ms`` / ``compute_ms`` /
  ``step_ms`` / ``tokens_per_s``, the alpha-beta closed form for the
  same phases (``analytic_comm_ms``, ``comm_over_analytic``),
  ``comm_fraction``, the ``mesh`` split, and a nested ``phases`` list
  (per-collective ``measured_us`` / ``analytic_us`` / ``start_us``).
  Undersized fabrics produce explicit ``{"skipped": true, ...}``
  records.  All existing suites' columns are unchanged.
* **v3** — two new suites from the flow-level fabric simulator
  (``repro.sim``): ``sim`` rows carry measured FCT percentiles
  (``fct_p50_us`` / ``fct_p95_us`` / ``fct_p99_us``, ``slowdown_*``,
  ``sim_delivered_fraction``), steady-state cross-validation rows
  (``sim_max_abs_util_diff``), and measured-vs-analytic collective rows;
  ``failures`` rows carry the failure spec label plus degraded-throughput
  and recovery-phase records.  ``sweep`` rows gain the same FCT columns
  when run with ``--simulate``; existing table2/sweep columns are
  unchanged (sweep ``latency_us`` now derives switch hops from the
  routing engine's measured mean instead of the ``avg_hops - 2``
  heuristic).
* **v2** — sweep rows gained an ``engine`` column (``"array"`` = MPHX
  coordinate engine, ``"graph"`` = generic SwitchGraph engine), and
  undefined (topology, scenario) cells are recorded as explicit
  ``{"skipped": true, "reason": ...}`` records instead of being dropped;
  sweep params gained ``engine`` / ``n_routed_rows`` / ``n_skipped``.
  table2 rows are unchanged.
* **v1** — initial: routed sweep rows for MPHX topologies only.
"""

from __future__ import annotations

import json
import os
from typing import Sequence

SCHEMA_VERSION = 7


def artifact_payload(suite: str, params: dict, rows: list[dict]) -> dict:
    payload = {
        "schema_version": SCHEMA_VERSION,
        "suite": suite,
        "generated_by": "repro.experiments",
        "params": params,
        "rows": rows,
    }
    from repro.telemetry import get_metrics
    mx = get_metrics()
    if mx.enabled:
        payload["telemetry"] = mx.snapshot()
    return payload


def write_json(path: str, payload: dict) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=False, default=_coerce)
        f.write("\n")
    return path


def _coerce(obj):
    """Make numpy scalars / arrays JSON-serializable."""
    if hasattr(obj, "item"):
        return obj.item()
    if hasattr(obj, "tolist"):
        return obj.tolist()
    raise TypeError(f"not JSON-serializable: {type(obj)}")


def markdown_table(rows: Sequence[dict], columns: Sequence[str] | None = None
                   ) -> str:
    """Render dict rows as a GitHub markdown table (union of keys, in
    first-seen order, unless ``columns`` pins the selection)."""
    if not rows:
        return "(no rows)\n"
    if columns is None:
        columns = []
        for r in rows:
            for k in r:
                if k not in columns:
                    columns.append(k)
    head = "| " + " | ".join(columns) + " |"
    sep = "|" + "|".join([" --- "] * len(columns)) + "|"
    body = []
    for r in rows:
        body.append("| " + " | ".join(_fmt(r.get(c)) for c in columns) + " |")
    return "\n".join([head, sep, *body]) + "\n"


def _fmt(v) -> str:
    if v is None:
        return "—"
    if isinstance(v, bool):
        return "yes" if v else "no"
    if isinstance(v, float):
        return f"{v:,.4g}" if abs(v) < 1e6 else f"{v:,.0f}"
    if isinstance(v, int):
        return f"{v:,}"
    return str(v)


def write_markdown(path: str, title: str, sections: list[tuple[str, str]]
                   ) -> str:
    """Write a markdown doc: ``sections`` is (heading, body) pairs."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    parts = [f"# {title}", ""]
    for heading, body in sections:
        if heading:
            parts += [f"## {heading}", ""]
        parts += [body.rstrip(), ""]
    with open(path, "w") as f:
        f.write("\n".join(parts))
    return path
