"""Multi-tenant serving suite: per-tenant SLOs per fabric.

For each topology, :func:`run_serving_suite` places a named tenant mix
(serving + training + background presets from :data:`TENANT_PRESETS`)
on the fabric, runs the shared open-loop simulation plus per-tenant
isolation baselines (:func:`repro.workload.run_tenant_mix`), and emits
one SLO row per (topology, tenant): p50/p99/p999 FCT, TTFT-proxy
percentiles for serving tenants, goodput, and slowdown-vs-isolation.
Small MPHX runs next to the Table-2 baselines so the rows answer
"which fabric serves this traffic within SLO".

Every random draw descends from the single ``seed`` parameter through
one :class:`numpy.random.SeedSequence` (no module-level RNG state, no
wall-clock fields in rows), so the artifact is byte-identical across
runs with the same seed.  Fabrics too small for the tenants' NIC
demand become explicit skip records.

Writes schema-v6 ``serving.json`` / ``serving.md``
(:mod:`~repro.experiments.artifacts`).
"""

from __future__ import annotations

import dataclasses
import os
import sys

from repro.core.netsim import make_router
from repro.workload import (BackgroundTenantSpec, ServingTenantSpec,
                            SizeDist, TrainingTenantSpec, run_tenant_mix,
                            slo_rows, tenant_kind)
from .artifacts import (artifact_payload, markdown_table, write_json,
                        write_markdown)
from .sweep import DEFAULT_OUTDIR, SWEEP_TOPOLOGIES

# small MPHX plus two Table-2 baseline fabrics at comparable NIC counts
DEFAULT_SERVING_TOPOS = ["mphx-2p-8x8", "ft3-small", "dragonfly-small"]

# named tenant presets the CLI --tenants flag selects from
TENANT_PRESETS: dict = {
    "chat": ServingTenantSpec(
        "chat", arch="mixtral-8x22b", rate_hz=400.0, duration_s=0.25,
        arrival="poisson",
        prompt_tokens=SizeDist("lognormal", mean=800.0, sigma=1.0),
        prefill_replicas=2, decode_replicas=2, tp=4),
    "burst": ServingTenantSpec(
        "burst", arch="mixtral-8x22b", rate_hz=400.0, duration_s=0.25,
        arrival="mmpp", burstiness=6.0,
        prompt_tokens=SizeDist("pareto", alpha=1.2, lo=128.0, hi=32768.0),
        prefill_replicas=2, decode_replicas=2, tp=4,
        hotspot_fraction=0.5),
    "train": TrainingTenantSpec(
        "train", arch="mixtral-8x22b", n_ranks=16, n_steps=1),
    "web": BackgroundTenantSpec(
        "web", rate_hz=4000.0, duration_s=0.25,
        size_bytes=SizeDist("empirical", name="websearch"), n_nics=8),
}
DEFAULT_TENANTS = ["chat", "burst", "train"]


def tenant_specs(names: "list[str]", duration_ms: "float | None" = None,
                 rate_scale: float = 1.0) -> "list":
    """Resolve preset names to specs, optionally rescaling the open-loop
    window/rate (CI smokes shrink both without new presets)."""
    specs = []
    for n in names:
        if n not in TENANT_PRESETS:
            raise ValueError(f"unknown tenant preset {n!r}; "
                             f"known: {sorted(TENANT_PRESETS)}")
        spec = TENANT_PRESETS[n]
        changes: dict = {}
        if hasattr(spec, "duration_s") and duration_ms is not None:
            changes["duration_s"] = duration_ms * 1e-3
        if hasattr(spec, "rate_hz") and rate_scale != 1.0:
            changes["rate_hz"] = spec.rate_hz * rate_scale
        specs.append(dataclasses.replace(spec, **changes) if changes
                     else spec)
    return specs


def _spec_summary(spec) -> dict:
    d = dataclasses.asdict(spec)
    for k, v in list(d.items()):
        if isinstance(v, dict):            # nested SizeDist
            d[k] = {kk: vv for kk, vv in v.items()}
    return {"kind": tenant_kind(spec), **d}


def run_serving_suite(outdir: str = DEFAULT_OUTDIR,
                      topo_names: "list[str] | None" = None,
                      tenant_names: "list[str] | None" = None,
                      seed: int = 0,
                      engine: str = "auto",
                      backend: str = "auto",
                      sim_backend: str = "numpy",
                      duration_ms: "float | None" = None,
                      rate_scale: float = 1.0) -> dict:
    """Run the tenant mix on every topology; write ``serving.{json,md}``."""
    names = topo_names or list(DEFAULT_SERVING_TOPOS)
    tnames = tenant_names or list(DEFAULT_TENANTS)
    specs = tenant_specs(tnames, duration_ms=duration_ms,
                         rate_scale=rate_scale)
    rows = []
    for tn in names:
        topo = SWEEP_TOPOLOGIES[tn]
        try:
            router = make_router(topo, backend=backend, engine=engine)
        except (ValueError, NotImplementedError) as e:
            print(f"serving: skipping {tn!r}: {e}", file=sys.stderr)
            rows.append({"topology": tn, "skipped": True,
                         "reason": str(e)})
            continue
        try:
            mix = run_tenant_mix(topo, specs, seed=seed,
                                 sim_backend=sim_backend, router=router)
        except ValueError as e:
            print(f"serving: skipping {tn!r}: {e}", file=sys.stderr)
            rows.append({"topology": tn, "skipped": True,
                         "reason": str(e)})
            continue
        for row in slo_rows(mix):
            rows.append({"topology": tn, **row})
    done = [r for r in rows if not r.get("skipped")]
    payload = artifact_payload(
        "serving",
        {"topologies": names, "tenants": tnames, "seed": seed,
         "engine": engine, "backend": backend,
         "sim_backend": sim_backend, "duration_ms": duration_ms,
         "rate_scale": rate_scale,
         "tenant_specs": {n: _spec_summary(s)
                          for n, s in zip(tnames, specs)},
         "n_rows": len(done),
         "n_skipped": sum(1 for r in rows if r.get("skipped"))},
        rows)
    write_json(os.path.join(outdir, "serving.json"), payload)
    cols = ["topology", "tenant", "kind", "n_flows", "n_requests",
            "fct_p50_us", "fct_p99_us", "fct_p999_us",
            "ttft_p50_us", "ttft_p99_us", "ttft_p999_us",
            "goodput_gbps", "slowdown_mean", "slowdown_p99", "n_stalled"]
    sections = [
        ("", "Per-tenant SLOs of a mixed serving + training tenant set "
             "sharing each fabric: open-loop KV-transfer / collective / "
             "background flows with tag-attributed measured FCTs "
             "(`repro.workload`, see `docs/serving.md`).  Slowdown is "
             "vs the same tenant alone on the fabric (same seed)."),
        ("Per-tenant SLOs", markdown_table(done, cols)),
    ]
    skipped = [r for r in rows if r.get("skipped")]
    if skipped:
        sections.append(("Skipped",
                         markdown_table(skipped, ["topology", "reason"])))
    write_markdown(os.path.join(outdir, "serving.md"),
                   "Multi-tenant serving — per-tenant SLOs per fabric",
                   sections)
    return payload
