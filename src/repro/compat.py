"""JAX version compatibility shims.

``jax.shard_map`` became a top-level API (with ``check_vma``) after 0.4.x;
older releases expose ``jax.experimental.shard_map.shard_map`` (with
``check_rep``).  Import :func:`shard_map` from here everywhere so the repo
runs on both.
"""

from __future__ import annotations

import jax


def axis_size(axis_name) -> int:
    """Static mesh-axis size from inside ``shard_map``.

    ``jax.lax.axis_size`` arrived after 0.4.x; there, ``jax.core.axis_frame``
    already returns the bound axis size as a python int.
    """
    from jax import lax

    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    import jax.core as jc

    return jc.axis_frame(axis_name)


def cost_analysis(compiled) -> dict:
    """Normalized ``compiled.cost_analysis()``: newer jax returns a dict,
    0.4.x returns a one-element list of dicts (one per program)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost or {}


def set_mesh(mesh):
    """Context manager binding the ambient mesh: ``jax.sharding.set_mesh``
    where it exists, else the 0.4.x idiom ``with mesh:``."""
    if hasattr(jax.sharding, "set_mesh"):
        return jax.sharding.set_mesh(mesh)
    return mesh


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """Version-portable ``shard_map`` (``check_vma`` maps to the old
    ``check_rep`` on jax < 0.5)."""
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm

    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
