import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (debug override BEFORE jax import; production default is 512 placeholders)
if os.environ.get("DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["DRYRUN_DEVICES"])

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape) cell, on the single-pod 16x16 mesh
and the 2x16x16 multi-pod mesh: build the sharded step function
(train_step / prefill / decode serve_step), ``.lower().compile()`` it with
``ShapeDtypeStruct`` stand-ins (no real allocation), and record

  * ``compiled.memory_analysis()``  — proves the cell fits per-device HBM
  * ``compiled.cost_analysis()``    — HLO FLOPs / bytes for §Roofline
  * collective bytes parsed from the POST-PARTITIONING ``compiled.as_text()``
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute), per collective kind and group size

into ``results/dryrun/<cell>.json`` for the roofline benchmark.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.compat import cost_analysis  # noqa: E402
from repro.configs.base import LM_SHAPES, RunConfig  # noqa: E402
from repro.launch.hloparse import analyze as hlo_analyze  # noqa: E402
from repro.launch.mesh import make_mesh_plan, make_production_mesh  # noqa: E402
from repro.models.registry import (ARCH_IDS, get_config, get_model,  # noqa: E402
                                   supported_shapes)
from repro.models.sharding import batch_spec, shardable  # noqa: E402
from repro.train.trainer import Trainer  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


# --------------------------------------------------------------------------
# HLO collective parsing — lives in repro.launch.hloparse (shared with the
# co-sim traffic layer); re-exported here for back-compat.
# --------------------------------------------------------------------------

from repro.launch.hloparse import (COLLECTIVE_OPS, DTYPE_BYTES,  # noqa: E402,F401
                                   _group_size, _shape_bytes,
                                   parse_collectives)


# --------------------------------------------------------------------------
# cell construction
# --------------------------------------------------------------------------


def _with_sharding(tree_shapes, tree_specs, mesh):
    from repro.models.sharding import sanitize_specs

    tree_specs = sanitize_specs(tree_shapes, tree_specs, mesh)

    def attach(l, s):
        return jax.ShapeDtypeStruct(l.shape, l.dtype,
                                    sharding=NamedSharding(mesh, s))

    return jax.tree.map(attach, tree_shapes, tree_specs,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def default_run_config(arch: str, shape_id: str, multi_pod: bool,
                       **overrides) -> RunConfig:
    """Baseline distribution config (hillclimbs override).

    Training uses full remat + 8 microbatches — required to FIT 16 GB/chip
    HBM at global batch 256 x 4096 (EXPERIMENTS.md §Dry-run memory table);
    the 1T-param config additionally keeps Adam moments in bf16."""
    big = arch in ("kimi-k2-1t-a32b",)
    kw = dict(arch=arch, shape=shape_id, multi_pod=multi_pod,
              remat="full", microbatches=8,
              fsdp_params=True, fsdp_pod=big, ep_moe=True,
              adam_dtype="bfloat16" if big else "float32",
              sequence_parallel=False)
    kw.update(overrides)
    return RunConfig(**kw)


def build_cell(arch: str, shape_id: str, multi_pod: bool, run: RunConfig):
    """Returns (lowered, meta) for one cell."""
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = make_mesh_plan(multi_pod=multi_pod,
                          sequence_parallel=run.sequence_parallel,
                          fsdp=run.fsdp_params, fsdp_pod=run.fsdp_pod,
                          moe_ws=run.moe_weight_stationary)
    model = get_model(cfg, run, mesh, plan)
    shape = LM_SHAPES[shape_id]
    specs = model.input_specs(shape)
    meta = {"arch": arch, "shape": shape_id,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "params": model.param_count(),
            "active_params": model.active_param_count(),
            "seq_len": shape.seq_len, "global_batch": shape.global_batch,
            "kind": shape.kind}

    if shape.kind == "train":
        trainer = Trainer(model, run, mesh, plan)
        state_shapes = jax.eval_shape(
            lambda: trainer.init_state(jax.random.PRNGKey(0)))
        state_sds = _with_sharding(state_shapes, trainer.state_specs(), mesh)
        batch_sds = {
            k: jax.ShapeDtypeStruct(
                v.shape, v.dtype,
                sharding=NamedSharding(mesh, batch_spec(plan, v.ndim)))
            for k, v in specs.items()}
        step = trainer.make_train_step()
        lowered = step.lower(state_sds, batch_sds)
    elif shape.kind == "prefill":
        pspecs = model.param_specs()
        pshapes = model.param_shapes()
        p_sds = _with_sharding(pshapes, pspecs, mesh)
        in_sds = {k: jax.ShapeDtypeStruct(
            v.shape, v.dtype,
            sharding=NamedSharding(mesh, batch_spec(plan, v.ndim)))
            for k, v in specs.items()}
        args = [in_sds["tokens"]]
        if "img_embeds" in in_sds:
            args.append(in_sds["img_embeds"])
        if "frames" in in_sds:
            args.append(in_sds["frames"])
        fn = jax.jit(lambda p, *a: model.prefill(p, *a))
        lowered = fn.lower(p_sds, *args)
    else:  # decode
        pspecs = model.param_specs()
        pshapes = model.param_shapes()
        p_sds = _with_sharding(pshapes, pspecs, mesh)
        B, S = shape.global_batch, shape.seq_len
        cache_shapes = specs["caches"]
        cache_specs = model.cache_specs(B, S)
        cache_sds = _with_sharding(cache_shapes, cache_specs, mesh)
        b_ax = shardable(mesh, plan.batch_axes, B)
        tok_sds = jax.ShapeDtypeStruct(
            (B, 1), jnp.int32,
            sharding=NamedSharding(mesh, P(b_ax, None)))
        fn = jax.jit(model.decode_step, donate_argnums=(2,))
        lowered = fn.lower(p_sds, tok_sds, cache_sds)
    return lowered, meta


def run_cell(arch: str, shape_id: str, multi_pod: bool,
             out_dir: str = RESULTS_DIR, tag: str = "",
             verbose: bool = True, **run_overrides) -> dict:
    t0 = time.time()
    run = default_run_config(arch, shape_id, multi_pod, **run_overrides)
    lowered, meta = build_cell(arch, shape_id, multi_pod, run)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_rec = {attr: int(getattr(mem, attr)) for attr in
               ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes") if hasattr(mem, attr)}
    cost = cost_analysis(compiled)
    cost_rec = {k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float)) and k in
                ("flops", "transcendentals", "bytes accessed")}
    # scan-aware accounting (XLA cost_analysis counts while bodies once;
    # the hloparse walker expands trip counts — EXPERIMENTS.md §Dry-run)
    hlo = hlo_analyze(compiled.as_text())
    colls = hlo["collectives"]

    rec = {**meta,
           "run_config": {k: getattr(run, k) for k in
                          ("remat", "fsdp_params", "ep_moe", "adam_dtype",
                           "sequence_parallel", "microbatches",
                           "grad_compression")},
           "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
           "memory_analysis": mem_rec,
           "cost_analysis": cost_rec,
           "hlo_flops": hlo["flops"],
           "hlo_hbm_bytes": hlo["hbm_bytes"],
           "collectives": colls}
    os.makedirs(out_dir, exist_ok=True)
    name = f"{arch}__{shape_id}__{rec['mesh'].replace('x', '_')}"
    if tag:
        name += f"__{tag}"
    with open(os.path.join(out_dir, name + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    if verbose:
        print(f"[dryrun] {name}: flops={hlo['flops']:.3e} "
              f"hbm={hlo['hbm_bytes']:.3e}B "
              f"mem_args={mem_rec.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
              f"temp={mem_rec.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
              f"coll={colls['total_wire_bytes']/2**30:.3f}GiB/"
              f"{int(colls['total_count'])}ops "
              f"lower={t_lower:.0f}s compile={t_compile:.0f}s")
        print("  memory_analysis:", mem_rec)
    return rec


def all_cells(multi_pod: bool):
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape_id in supported_shapes(cfg):
            yield arch, shape_id, multi_pod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(LM_SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every supported (arch x shape) cell")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    print(f"devices: {jax.device_count()} "
          f"({jax.devices()[0].platform})")
    cells = []
    if args.all:
        meshes = (False, True) if args.both_meshes else (args.multi_pod,)
        for mp in meshes:
            cells.extend(all_cells(mp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        meshes = (False, True) if args.both_meshes else (args.multi_pod,)
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    failed = []
    for arch, shape_id, mp in cells:
        try:
            run_cell(arch, shape_id, mp, out_dir=args.out, tag=args.tag)
        except Exception as e:
            traceback.print_exc()
            failed.append((arch, shape_id, mp, repr(e)[:200]))
    print(f"\n{len(cells) - len(failed)}/{len(cells)} cells passed")
    for f in failed:
        print("FAILED:", f)
    raise SystemExit(1 if failed else 0)


if __name__ == "__main__":
    main()
