"""Serving launcher: batched prefill+decode with the ServeEngine.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \
      --requests 8 --prompt-len 32 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.models.registry import ARCH_IDS, get_config, get_model
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.family in ("audio",):
        raise SystemExit("serve driver targets decoder LMs; whisper decode "
                         "is exercised in tests/benchmarks")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    print(f"[serve] {args.arch} (smoke={args.smoke}) "
          f"params={model.param_count():,}")

    rng = np.random.default_rng(args.seed)
    max_len = args.prompt_len + args.max_new + 1
    if cfg.family == "vlm":
        max_len += cfg.vlm.n_image_tokens
    eng = ServeEngine(model, params, max_batch=args.max_batch,
                      max_len=max_len, temperature=args.temperature,
                      seed=args.seed)
    reqs = [Request(prompt=rng.integers(
        0, cfg.vocab_size, size=args.prompt_len).astype(np.int32),
        max_new_tokens=args.max_new) for _ in range(args.requests)]
    t0 = time.perf_counter()
    eng.run(reqs)
    wall = time.perf_counter() - t0
    s = eng.stats
    print(f"[serve] {len(reqs)} requests in {wall:.2f}s | prefill "
          f"{s.prefill_s:.2f}s decode {s.decode_s:.2f}s | "
          f"{s.tokens_out} tokens | {s.decode_tok_per_s:.1f} tok/s")
    return s


if __name__ == "__main__":
    main()
