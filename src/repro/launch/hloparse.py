"""Scan-aware HLO accounting for the roofline (§Roofline).

``compiled.cost_analysis()`` counts every while-loop body ONCE — a
scan-over-layers program therefore underreports FLOPs/bytes/collectives by
~L x (verified empirically; see EXPERIMENTS.md §Dry-run notes).  This
module parses the POST-PARTITIONING HLO text, reconstructs the computation
call graph (while bodies with their trip counts, fusions, calls), and
expands totals properly:

* ``flops``            — 2*prod(out)*prod(contracting) per dot, everywhere
* ``hbm_bytes``        — operand+output bytes of non-fused instructions
                         (fusion call sites count as one kernel's traffic)
* ``collectives``      — per-kind wire bytes with replica-group sizes

All shapes in partitioned HLO are per-device, so totals are per-device.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

DTYPE_BYTES = {"pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2,
               "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
               "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
               "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w\.\-]+|[\w\.\-]+)\s*=\s*(\(?[^=]*?)\s*"
    r"([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w\.\-]+)\s*(?:\([^)]*\))?\s*"
                          r"(?:->\s*[^{]*)?\{\s*$")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_NUM_PARTITIONS_RE = re.compile(r"num_partitions=(\d+)")
_REPLICA_COUNT_RE = re.compile(r"replica_count=(\d+)")


def parse_replica_groups(line: str, default_size: int = 1) -> "list[int]":
    """Group sizes of a collective instruction line.

    Handles every format XLA emits:

    * ``replica_groups={{0,2},{1,3}}`` — explicit nested lists (the outer
      braces close *after* the last group, so a single-group regex like
      ``\\{\\{([^}]*)\\}`` captures only the first group — the historical
      ``_group_size`` bug this function replaces);
    * ``replica_groups=[2,4]<=[8]`` — iota v2 format, 2 groups of 4;
    * ``replica_groups={}`` — one group of *all* participants, whose size
      is the module's partition count (``default_size``), not 1.
    """
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return [int(m.group(2))] * int(m.group(1))
    idx = line.find("replica_groups={")
    if idx < 0:
        return [default_size]
    i = idx + len("replica_groups=")
    depth = 0
    j = i
    for j in range(i, len(line)):
        if line[j] == "{":
            depth += 1
        elif line[j] == "}":
            depth -= 1
            if depth == 0:
                break
    inner = line[i + 1:j]
    groups = re.findall(r"\{([^{}]*)\}", inner)
    if not groups and inner.strip():     # flat single-group form {0,1,2}
        groups = [inner]
    if not groups or all(not g.strip() for g in groups):
        return [default_size]            # "{}": every participant, one group
    return [len([x for x in g.split(",") if x.strip() != ""])
            for g in groups]


def _group_size(line: str, default_size: int = 1) -> int:
    """Size of (the first of) a collective's replica groups."""
    return parse_replica_groups(line, default_size)[0]


def module_device_count(hlo: str) -> int:
    """Participant count from the ``HloModule`` header line:
    ``num_partitions x replica_count`` (each defaults to 1)."""
    head = hlo[:hlo.find("\n")] if "\n" in hlo else hlo
    if "HloModule" not in head:          # header not first: scan for it
        for ln in hlo.splitlines():
            if ln.lstrip().startswith("HloModule"):
                head = ln
                break
    mp = _NUM_PARTITIONS_RE.search(head)
    mr = _REPLICA_COUNT_RE.search(head)
    return ((int(mp.group(1)) if mp else 1)
            * (int(mr.group(1)) if mr else 1))


def _shape_dims(type_str: str):
    """All (dtype, [dims]) arrays in a type string (handles tuples)."""
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((dt, n))
    return out


def _shape_bytes(type_str: str) -> int:
    return sum(n * DTYPE_BYTES[dt] for dt, n in _shape_dims(type_str))


def _shape_elems(type_str: str) -> int:
    return sum(n for _, n in _shape_dims(type_str))


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str                      # everything after the opening paren
    line: str


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)   # %name -> type string


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, Computation] = {}
        self.fusion_called: set[str] = set()
        self.entry: str | None = None
        self.device_count: int = module_device_count(text)
        self._parse(text)

    @staticmethod
    def _norm(name: str) -> str:
        return name if name.startswith("%") else "%" + name

    def _parse(self, text: str):
        cur: Computation | None = None
        for raw in text.splitlines():
            line = raw.rstrip()
            s = line.strip()
            if not s or s.startswith("//"):
                continue
            if cur is None:
                # computation headers end with '{' and carry a signature,
                # e.g.  %region_0.2 (arg: (s32[], f32[64,64])) -> (...) {
                #       ENTRY %main.29 (Arg_0.1: f32[64,64]) -> f32[64,64] {
                if s.endswith("{") and ("->" in s or s.startswith("ENTRY")):
                    toks = s.split()
                    if s.startswith("ENTRY") and len(toks) > 1:
                        name = toks[1].split("(")[0]
                    else:
                        name = toks[0].split("(")[0]
                    if not name:
                        continue
                    name = self._norm(name)
                    cur = Computation(name)
                    if s.startswith("ENTRY"):
                        self.entry = name
                continue
            if s == "}" or s.startswith("}"):
                self.computations[cur.name] = cur
                cur = None
                continue
            if " = " not in s:
                continue
            name, _, rhs = s.partition(" = ")
            name = name.replace("ROOT ", "").strip()
            if not re.match(r"^%?[\w\.\-]+$", name):
                continue
            # op = first `word(` in the rhs; the type prefix may contain
            # tuple parens and /*index=N*/ comments but never `word(`
            mo = re.search(r"([\w\-]+)\(", rhs)
            if not mo:
                continue
            type_str, op, rest = rhs[:mo.start()], mo.group(1), rhs[mo.end():]
            name = self._norm(name)
            inst = Instr(name, type_str.strip(), op, rest, s)
            cur.instrs.append(inst)
            cur.shapes[name] = type_str.strip()
            if op == "fusion" or "calls=" in rest:
                mm = re.search(r"calls=(%?[\w\.\-]+)", rest)
                if mm:
                    self.fusion_called.add(self._norm(mm.group(1)))
            for mm in re.finditer(r"to_apply=(%?[\w\.\-]+)", rest):
                self.fusion_called.add(self._norm(mm.group(1)))
        if cur is not None:
            self.computations[cur.name] = cur
        if self.entry is None and self.computations:
            # ENTRY line may carry the computation name differently; pick
            # the one never referenced by others.
            referenced = set()
            for c in self.computations.values():
                for i in c.instrs:
                    for mm in re.finditer(r"(?:condition|body|calls|"
                                          r"to_apply)=(%?[\w\.\-]+)", i.rest):
                        referenced.add(self._norm(mm.group(1)))
            cands = [n for n in self.computations if n not in referenced]
            self.entry = cands[-1] if cands else next(iter(self.computations))

    # ------------------------------------------------------- trip counts ----

    def while_trip_count(self, cond_name: str) -> int:
        comp = self.computations.get(cond_name)
        if comp is None:
            return 1
        consts: dict[str, int] = {}
        for i in comp.instrs:
            if i.op == "constant":
                mm = re.match(r"([\d\-]+)\)?", i.rest)
                if mm:
                    try:
                        consts[i.name] = int(mm.group(1))
                    except ValueError:
                        pass
        for i in comp.instrs:
            if i.op == "compare":
                ops = re.findall(r"%[\w\.\-]+", i.rest.split(")")[0])
                for o in ops:
                    if o in consts:
                        return max(1, abs(consts[o]))
        if consts:
            return max(1, max(abs(v) for v in consts.values()))
        return 1

    # ---------------------------------------------------------- walkers ----

    def _children(self, comp: Computation):
        """Yield (child_name, multiplier, kind)."""
        for i in comp.instrs:
            if i.op == "while":
                mb = re.search(r"body=(%?[\w\.\-]+)", i.rest)
                mc = re.search(r"condition=(%?[\w\.\-]+)", i.rest)
                if mb:
                    # XLA records the trip count when it can prove it
                    mt = re.search(r'known_trip_count[^}]*"n":"(\d+)"',
                                   i.rest)
                    if mt:
                        trip = max(1, int(mt.group(1)))
                    elif mc:
                        trip = self.while_trip_count(self._norm(mc.group(1)))
                    else:
                        trip = 1
                    yield self._norm(mb.group(1)), trip, "while"
            elif i.op == "conditional":
                for mm in re.finditer(r"(%?[\w\.\-]+)", i.rest):
                    pass
                for mm in re.finditer(
                        r"(?:true_computation|false_computation|"
                        r"branch_computations=\{)([^,}]*)", i.rest):
                    for nm in re.findall(r"%?[\w\.\-]+", mm.group(1)):
                        yield self._norm(nm), 1, "cond"
            elif i.op == "call":
                mm = re.search(r"to_apply=(%?[\w\.\-]+)", i.rest)
                if mm:
                    yield self._norm(mm.group(1)), 1, "call"
            elif i.op == "fusion":
                mm = re.search(r"calls=(%?[\w\.\-]+)", i.rest)
                if mm:
                    yield self._norm(mm.group(1)), 1, "fusion"

    def _expand(self, fn, include_fusion_bodies: bool,
                _memo=None, comp_name=None) -> float:
        """Sum fn(comp) over the call tree with while-trip multipliers."""
        if _memo is None:
            _memo = {}
        comp_name = comp_name or self.entry
        if comp_name in _memo:
            return _memo[comp_name]
        comp = self.computations.get(comp_name)
        if comp is None:
            return 0.0
        total = fn(comp)
        for child, mult, kind in self._children(comp):
            if kind == "fusion" and not include_fusion_bodies:
                continue
            total += mult * self._expand(fn, include_fusion_bodies, _memo,
                                         child)
        _memo[comp_name] = total
        return total

    # ------------------------------------------------------------ flops ----

    def _dot_flops(self, comp: Computation) -> float:
        total = 0.0
        for i in comp.instrs:
            if i.op not in ("dot", "convolution"):
                continue
            out_elems = _shape_elems(i.type_str)
            if i.op == "dot":
                mm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", i.rest)
                lhs_name = None
                args = re.findall(r"%[\w\.\-]+", i.rest.split(")")[0])
                if args:
                    lhs_name = args[0]
                k = 1
                if mm and lhs_name and lhs_name in comp.shapes:
                    dims_str = _SHAPE_RE.search(comp.shapes[lhs_name])
                    if dims_str:
                        dims = [int(d) for d in dims_str.group(2).split(",")
                                if d]
                        for ci in mm.group(1).split(","):
                            if ci != "" and int(ci) < len(dims):
                                k *= dims[int(ci)]
                total += 2.0 * out_elems * k
            else:  # convolution: 2 * out_elems * prod(kernel spatial+in)
                args = re.findall(r"%[\w\.\-]+", i.rest.split(")")[0])
                k = 1
                if len(args) >= 2 and args[1] in comp.shapes:
                    dims_str = _SHAPE_RE.search(comp.shapes[args[1]])
                    if dims_str:
                        dims = [int(d) for d in dims_str.group(2).split(",")
                                if d]
                        k = max(1, math.prod(dims) // max(dims[-1], 1))
                total += 2.0 * out_elems * k
        return total

    def total_flops(self) -> float:
        return self._expand(self._dot_flops, include_fusion_bodies=True)

    # ------------------------------------------------------------ bytes ----

    _SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                       "bitcast", "while", "conditional", "call",
                       "after-all", "partition-id", "replica-id", "iota",
                       "get-dimension-size", "broadcast", "reshape",
                       # dtype converts are CPU bf16-legalization artifacts;
                       # on the TPU target they fuse into neighbours
                       "convert"}

    # ops that touch only a slice of their big operand: charge slice-sized
    # traffic, NOT the full operand (a scan reading its stacked xs does a
    # dynamic-slice of the (L, ...) stack per iteration — charging the full
    # stack would overcount HBM by L x).
    _SLICE_OPS = {"dynamic-slice", "slice", "gather"}
    _UPDATE_OPS = {"dynamic-update-slice", "scatter", "scatter-add"}

    @staticmethod
    def _operands(instr: Instr) -> list[str]:
        return re.findall(r"%[\w\.\-]+", instr.rest.split(")")[0])

    def _fusion_call_bytes(self, comp: Computation, instr: Instr) -> float:
        """HBM traffic of one fused kernel, slice/alias aware.

        * a call-site operand whose in-fusion consumers are ALL slice ops
          contributes only the slice bytes (a scan body dynamic-slicing its
          stacked (L, ...) xs must NOT be charged the whole stack);
        * if the fusion root is a dynamic-update-slice, the output buffer is
          aliased in place: charge the update region, not the full buffer.
        """
        m = re.search(r"calls=(%?[\w\.\-]+)", instr.rest)
        fc = self.computations.get(self._norm(m.group(1))) if m else None
        ops = self._operands(instr)
        if fc is None:
            return _shape_bytes(instr.type_str) + sum(
                _shape_bytes(comp.shapes.get(o, "")) for o in ops)
        param_name = {}
        for i2 in fc.instrs:
            if i2.op == "parameter":
                mm = re.match(r"(\d+)", i2.rest)
                if mm:
                    param_name[int(mm.group(1))] = i2.name

        # dtype converts / bitcasts / copies are transparent: XLA:CPU
        # legalizes bf16 by round-tripping through f32 (real TPU programs
        # keep bf16), so we trace through them both forwards (consumers)
        # and backwards (alias detection).
        TRANSPARENT = {"convert", "bitcast", "copy", "reshape"}

        def trace_back(name: str) -> str:
            seen = 0
            while seen < 16:
                producer = next((i2 for i2 in fc.instrs if i2.name == name),
                                None)
                if producer is None or producer.op not in TRANSPARENT:
                    return name
                srcs = self._operands(producer)
                if not srcs:
                    return name
                name = srcs[0]
                seen += 1
            return name

        def effective_consumers(name: str, depth=0) -> list:
            out = []
            if depth > 8:
                return out
            for i2 in fc.instrs:
                if i2.op == "parameter" or name not in self._operands(i2):
                    continue
                if i2.op in TRANSPARENT:
                    out.extend(effective_consumers(i2.name, depth + 1))
                else:
                    out.append(i2)
            return out

        # real root: walk back through convert/bitcast/copy wrappers
        root = fc.instrs[-1] if fc.instrs else None
        hops = 0
        while (root is not None and root.op in TRANSPARENT and hops < 8):
            srcs = self._operands(root)
            root = next((i2 for i2 in fc.instrs
                         if srcs and i2.name == srcs[0]), None)
            hops += 1
        root_is_dus = root is not None and root.op == "dynamic-update-slice"
        aliased = set()
        if root_is_dus:
            rops = self._operands(root)
            if rops:
                aliased.add(trace_back(rops[0]))   # in-place buffer

        total = 0.0
        for idx, opname in enumerate(ops):
            full = _shape_bytes(comp.shapes.get(opname, ""))
            pname = param_name.get(idx)
            if pname is None:
                total += full
                continue
            if pname in aliased:
                continue                         # counted via root update
            consumers = effective_consumers(pname)
            charged, needs_full = 0.0, not consumers
            for c in consumers:
                if c.op in self._SLICE_OPS:
                    charged += _shape_bytes(c.type_str)
                elif (c.op == "dynamic-update-slice" and
                      trace_back(self._operands(c)[0]) == pname):
                    pass    # in-place buffer of a non-root DUS: update
                            # region is charged by that DUS's own output
                else:
                    needs_full = True
                    break
            total += full if needs_full else charged
        if root_is_dus:
            rops = self._operands(root)
            upd = _shape_bytes(fc.shapes.get(rops[1], "")) \
                if len(rops) > 1 else 0.0
            total += 2.0 * upd                   # read-modify-write region
        else:
            total += _shape_bytes(instr.type_str)
        return total

    def _hbm_bytes(self, comp: Computation) -> float:
        if comp.name in self.fusion_called:
            return 0.0  # in-register inside a fused kernel
        total = 0.0
        for i in comp.instrs:
            if i.op in self._SKIP_BYTES_OPS:
                continue
            out_bytes = _shape_bytes(i.type_str)
            if i.op == "fusion":
                total += self._fusion_call_bytes(comp, i)
                continue
            if i.op in self._SLICE_OPS:
                total += 2.0 * out_bytes        # read slice + write result
                continue
            if i.op in self._UPDATE_OPS:
                # read-modify-write of the updated region (operand 1)
                ops = self._operands(i)
                upd = _shape_bytes(comp.shapes.get(ops[1], "")) \
                    if len(ops) > 1 else out_bytes
                total += 2.0 * max(upd, 1.0)
                continue
            total += out_bytes
            for o in self._operands(i):
                if o in comp.shapes:
                    total += _shape_bytes(comp.shapes[o])
        return total

    def total_hbm_bytes(self) -> float:
        return self._expand(self._hbm_bytes, include_fusion_bodies=False)

    # ------------------------------------------------------ collectives ----

    def _collectives(self, comp: Computation) -> dict:
        out = {k: {"count": 0.0, "payload_bytes": 0.0, "wire_bytes": 0.0,
                   "by_group": {}} for k in COLLECTIVE_OPS}
        for i in comp.instrs:
            base = i.op
            for k in COLLECTIVE_OPS:
                if base == k or base == k + "-start":
                    break
            else:
                continue
            op = base.replace("-start", "")
            payload = _shape_bytes(i.type_str)
            if base.endswith("-start") and op in ("all-reduce", "all-gather",
                                                  "collective-permute"):
                # started ops' type includes (operand, result) tuples; halve
                payload = payload / 2.0
            g = _group_size(i.line, self.device_count)
            if op == "all-reduce":
                wire = 2 * (g - 1) / max(g, 1) * payload
            elif op == "all-gather":
                wire = (g - 1) / max(g, 1) * payload
            elif op == "reduce-scatter":
                wire = (g - 1) * payload
            elif op == "all-to-all":
                wire = (g - 1) / max(g, 1) * payload
            else:
                wire = payload
            rec = out[op]
            rec["count"] += 1
            rec["payload_bytes"] += payload
            rec["wire_bytes"] += wire
            key = str(g)
            rec["by_group"][key] = rec["by_group"].get(key, 0.0) + wire
        return out

    def total_collectives(self) -> dict:
        def merge(a, b, mult=1.0):
            for k in COLLECTIVE_OPS:
                a[k]["count"] += mult * b[k]["count"]
                a[k]["payload_bytes"] += mult * b[k]["payload_bytes"]
                a[k]["wire_bytes"] += mult * b[k]["wire_bytes"]
                for g, v in b[k]["by_group"].items():
                    a[k]["by_group"][g] = a[k]["by_group"].get(g, 0.0) \
                        + mult * v
            return a

        memo = {}

        def expand(name):
            if name in memo:
                return memo[name]
            comp = self.computations.get(name)
            zero = {k: {"count": 0.0, "payload_bytes": 0.0,
                        "wire_bytes": 0.0, "by_group": {}}
                    for k in COLLECTIVE_OPS}
            if comp is None:
                return zero
            tot = merge(zero, self._collectives(comp))
            for child, mult, kind in self._children(comp):
                tot = merge(tot, expand(child), mult)
            memo[name] = tot
            return tot

        out = expand(self.entry)
        out["total_wire_bytes"] = sum(out[k]["wire_bytes"]
                                      for k in COLLECTIVE_OPS)
        out["total_count"] = sum(out[k]["count"] for k in COLLECTIVE_OPS)
        return out


def analyze(hlo_text: str) -> dict:
    mod = HloModule(hlo_text)
    return {
        "flops": mod.total_flops(),
        "hbm_bytes": mod.total_hbm_bytes(),
        "collectives": mod.total_collectives(),
        "n_computations": len(mod.computations),
    }


def parse_collectives(hlo: str) -> dict:
    """Per-device wire bytes by collective kind, from partitioned HLO.

    Line-by-line accounting (no while-trip expansion — see
    :meth:`HloModule.total_collectives` for the scan-aware totals; this is
    the flat single-pass parser the dry-run and co-sim layers consume).
    Shapes in partitioned HLO are per-device.  Wire-byte accounting per
    device: AR: 2(g-1)/g * payload; AG: (g-1)/g * output; RS: (g-1) *
    output; A2A: (g-1)/g * payload; permute: payload.  Group sizes come
    from :func:`parse_replica_groups` (nested-brace, iota, and empty
    ``replica_groups={}`` formats all handled; empty = every participant,
    using the module header's ``num_partitions x replica_count``)."""
    default_g = module_device_count(hlo)
    out = {k: {"count": 0, "payload_bytes": 0.0, "wire_bytes": 0.0,
               "by_group": {}} for k in COLLECTIVE_OPS}
    for line in hlo.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w\.\-]+ = (.*?) (all-reduce|all-gather|"
                     r"reduce-scatter|all-to-all|collective-permute)"
                     r"(-start|-done)?\(", line)
        if not m:
            continue
        if m.group(3) == "-done":
            continue  # counted at -start
        typ, op = m.group(1), m.group(2)
        payload = _shape_bytes(typ)
        g = _group_size(line, default_g)
        if op == "all-reduce":
            wire = 2 * (g - 1) / max(g, 1) * payload
        elif op == "all-gather":
            wire = (g - 1) / max(g, 1) * payload          # payload = output
        elif op == "reduce-scatter":
            wire = (g - 1) * payload                       # payload = output
        elif op == "all-to-all":
            wire = (g - 1) / max(g, 1) * payload
        else:
            wire = payload
        rec = out[op]
        rec["count"] += 1
        rec["payload_bytes"] += payload
        rec["wire_bytes"] += wire
        key = str(g)
        rec["by_group"][key] = rec["by_group"].get(key, 0.0) + wire
    out["total_wire_bytes"] = sum(out[k]["wire_bytes"]
                                  for k in COLLECTIVE_OPS)
    out["total_count"] = sum(out[k]["count"] for k in COLLECTIVE_OPS)
    return out
