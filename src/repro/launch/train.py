"""Training launcher.

CPU-scale end-to-end runs (examples/) and the entry point a real cluster
would use (mesh + sharded state + checkpoint/restart + straggler monitor).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --smoke \
      --steps 100 --seq-len 128 --global-batch 8 --ckpt-dir /tmp/ck
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticDataset, \
    loss_floor
from repro.models.registry import ARCH_IDS, get_config, get_model
from repro.models.sharding import MeshPlan
from repro.train.checkpoint import Checkpointer
from repro.train.fault import StragglerMonitor, checkpoint_cadence_steps
from repro.train.trainer import Trainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="0 = Young/Daly auto cadence")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--data", default="lcg", choices=["lcg", "copy",
                                                      "uniform"])
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    run = RunConfig(arch=args.arch, lr=args.lr, total_steps=args.steps,
                    warmup_steps=max(args.steps // 10, 1),
                    microbatches=args.microbatches,
                    grad_compression=args.grad_compression, seed=args.seed)
    model = get_model(cfg, run)
    trainer = Trainer(model, run)

    dcfg = DataConfig(kind=args.data, vocab_size=cfg.vocab_size,
                      seq_len=args.seq_len, global_batch=args.global_batch,
                      seed=args.seed)
    ds = SyntheticDataset(dcfg)
    print(f"[train] {args.arch} (smoke={args.smoke}) "
          f"params={model.param_count():,} "
          f"floor={loss_floor(dcfg):.3f} nats")

    state = trainer.init_state(jax.random.PRNGKey(args.seed))
    start_step = 0
    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ck and args.resume and ck.latest_step() is not None:
        state, start_step = ck.restore(state)
        print(f"[train] resumed from step {start_step}")

    cadence = args.ckpt_every or checkpoint_cadence_steps(
        n_hosts=jax.device_count(), save_cost_s=1.0, step_time_s=1.0)
    straggler = StragglerMonitor()
    step_fn = trainer.make_train_step()
    pf = Prefetcher(ds, start_step=start_step)
    hist = []
    t_last = time.perf_counter()
    try:
        for i in range(start_step, args.steps):
            _, batch = next(pf)
            batch = jax.tree.map(jnp.asarray, batch)
            state, metrics = step_fn(state, batch)
            dt = time.perf_counter() - t_last
            t_last = time.perf_counter()
            if straggler.observe(dt):
                print(f"[train] straggler event at step {i + 1}: {dt:.2f}s")
            if (i + 1) % args.log_every == 0 or i + 1 == args.steps:
                m = {k: round(float(v), 4) for k, v in metrics.items()}
                m.update(step=i + 1, sec_per_step=round(dt, 3))
                hist.append(m)
                print(f"[train] {json.dumps(m)}")
            if ck and (i + 1) % cadence == 0:
                ck.save(i + 1, state, blocking=False)
    finally:
        pf.close()
    if ck:
        ck.wait()
        ck.save(args.steps, state)
    return hist


if __name__ == "__main__":
    main()
