"""Roofline analysis from dry-run artifacts (deliverable g, §Roofline).

Terms (seconds, per chip, TPU v5e constants):

  compute_s    = HLO_FLOPs / peak_FLOPs        (197 TFLOP/s bf16)
  memory_s     = HLO_bytes / HBM_bw            (819 GB/s)
  collective_s = wire_bytes / ICI_link_bw      (~50 GB/s/link; wire bytes
                 are per-device from the partitioned HLO, so no further
                 chip division; DCN-scale pod collectives are called out
                 separately in EXPERIMENTS.md §Perf)

FLOPs/bytes come from the scan-aware HLO walker (launch/hloparse.py), NOT
``cost_analysis()`` — XLA counts while bodies once (EXPERIMENTS.md
§Dry-run).  MODEL_FLOPS = 6*N*D for training (N_active for MoE), 2*N*tokens
for single-forward serving steps; useful_ratio = MODEL_FLOPS / HLO_FLOPs
catches remat/masked-attention/dispatch waste.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass

PEAK_FLOPS = 197e12         # bf16 / chip
HBM_BW = 819e9              # B/s
ICI_BW = 50e9               # B/s per link
MESH_CHIPS = {"16x16": 256, "2x16x16": 512}


def model_flops_per_chip(rec: dict) -> float:
    """6*N_active*D train; 2*N_active*tokens for prefill/decode."""
    n = rec["active_params"]
    chips = MESH_CHIPS[rec["mesh"]]
    B, S = rec["global_batch"], rec["seq_len"]
    if rec["kind"] == "train":
        tokens = B * S
        return 6.0 * n * tokens / chips
    if rec["kind"] == "prefill":
        return 2.0 * n * B * S / chips
    return 2.0 * n * B / chips          # decode: one token per sequence


def roofline_row(rec: dict) -> dict:
    compute_s = rec["hlo_flops"] / PEAK_FLOPS
    memory_s = rec["hlo_hbm_bytes"] / HBM_BW
    coll_s = rec["collectives"]["total_wire_bytes"] / ICI_BW
    dominant_s = max(compute_s, memory_s, coll_s)
    bound = ("compute" if dominant_s == compute_s else
             "memory" if dominant_s == memory_s else "collective")
    mf = model_flops_per_chip(rec)
    useful = mf / rec["hlo_flops"] if rec["hlo_flops"] else 0.0
    ideal_s = mf / PEAK_FLOPS
    return {
        "cell": f"{rec['arch']}/{rec['shape']}/{rec['mesh']}",
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant_s": dominant_s, "bound": bound,
        "model_flops_per_chip": mf,
        "useful_ratio": useful,
        "roofline_fraction": (ideal_s / dominant_s) if dominant_s else 0.0,
        "hbm_gib": (rec["memory_analysis"].get("argument_size_in_bytes", 0)
                    + rec["memory_analysis"].get("temp_size_in_bytes", 0))
        / 2**30,
        "fits_16gib": (rec["memory_analysis"].get("argument_size_in_bytes", 0)
                       + rec["memory_analysis"].get("temp_size_in_bytes", 0))
        < 16 * 2**30,
        "tag": rec.get("tag", ""),
    }


def load_cells(d: str, include_tagged: bool = False) -> list[dict]:
    out = []
    for name in sorted(os.listdir(d)):
        if not name.endswith(".json"):
            continue
        parts = name[:-5].split("__")
        tagged = len(parts) > 3
        if tagged and not include_tagged:
            continue
        with open(os.path.join(d, name)) as f:
            rec = json.load(f)
        rec["tag"] = parts[3] if tagged else ""
        out.append(rec)
    return out


def roofline_table(d: str, include_tagged: bool = False) -> list[dict]:
    return [roofline_row(r) for r in load_cells(d, include_tagged)]


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| cell | bound | compute s | memory s | collective s | "
           "MODEL/HLO | roofline frac | HBM GiB | fits |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['cell']} | {r['bound']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} | "
            f"{r['hbm_gib']:.1f} | {'Y' if r['fits_16gib'] else 'N'} |")
    return hdr + "\n".join(lines)


if __name__ == "__main__":
    import sys

    d = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")
    rows = roofline_table(d, include_tagged="--tagged" in sys.argv)
    print(markdown_table(rows))
