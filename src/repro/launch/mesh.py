"""Production mesh construction.

Importing this module never touches jax device state; both helpers are
functions.  The dry-run (and ONLY the dry-run) sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import so these meshes can be built on one CPU host.
"""

from __future__ import annotations

import jax

from repro.models.sharding import MeshPlan, MULTI_POD, SINGLE_POD


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_plan(*, multi_pod: bool = False,
                   sequence_parallel: bool = False,
                   fsdp: bool = True, fsdp_pod: bool = False,
                   moe_ws: bool = False) -> MeshPlan:
    base = MULTI_POD if multi_pod else SINGLE_POD
    if not fsdp:
        fsdp_axes = None
    elif multi_pod and fsdp_pod:
        fsdp_axes = ("pod", "data")    # ZeRO over DCN too (1T config)
    else:
        fsdp_axes = "data"
    return MeshPlan(batch=base.batch, sp=sequence_parallel, fsdp=fsdp_axes,
                    moe_ws=moe_ws)


def make_debug_mesh(data: int = 2, model: int = 2, pod: int | None = None):
    """Small host-device mesh for CPU multi-device tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count set in a subprocess)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
