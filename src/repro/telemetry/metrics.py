"""Counters / gauges / timers for the whole sim stack (zero-cost off).

A :class:`MetricsRegistry` is a plain dict-backed sink for the stack's
operational metrics: engine walks and incidence-cache hits
(:mod:`repro.core.routing_vec` / :mod:`repro.core.routing_graph`),
water-filling round counts and event-loop epochs (:mod:`repro.sim`),
jit compile-vs-execute wall time, dead-plane re-spray events
(:mod:`repro.sim.spray`), and re-route recomputes
(:mod:`repro.sim.failures`).  The catalog lives in
``docs/observability.md``.

Two attachment points:

* **per-object** — both routing engines own a registry
  (``router.metrics``), replacing PR 7's bare ``incidence_calls`` int
  (kept as a deprecated property shim);
* **ambient** — :func:`get_metrics` returns the process-wide registry,
  which defaults to the no-op :class:`NullRegistry` singleton.  Code
  instruments unconditionally against the ambient registry; when nothing
  is collecting, every call hits a ``pass`` body — and the jitted
  solver/event-loop paths are never instrumented *inside* jit at all, so
  disabled telemetry cannot perturb the compiled code or the golden
  float sequences (``tests/test_telemetry.py`` pins this against
  ``tests/golden/fairshare_golden.json``).

Enable collection with :func:`collecting` (or, for traces too,
:func:`repro.telemetry.trace.recording`)::

    with collecting() as mx:
        simulate_demands(router, dem, 200e-6)
    print(mx.snapshot())
"""

from __future__ import annotations

import time
from contextlib import contextmanager

__all__ = ["MetricsRegistry", "NullRegistry", "NULL_METRICS",
           "get_metrics", "collecting"]


class MetricsRegistry:
    """Named counters, gauges, and wall-time observations.

    * counters — monotonically incremented event counts (:meth:`inc`);
    * gauges — last-write-wins values (:meth:`gauge`);
    * timers — count/total/min/max wall-time stats (:meth:`observe` or
      the :meth:`timer` context manager).

    All methods are cheap dict operations; :meth:`snapshot` returns a
    JSON-ready dict (the artifact schema-v5 ``telemetry`` block).
    """

    enabled: bool = True

    def __init__(self):
        self._counters: dict = {}
        self._gauges: dict = {}
        self._timers: dict = {}

    # --------------------------------------------------------- counters ----

    def inc(self, name: str, n: "int | float" = 1) -> None:
        self._counters[name] = self._counters.get(name, 0) + n

    def set_counter(self, name: str, value: "int | float") -> None:
        self._counters[name] = value

    def value(self, name: str) -> "int | float":
        """Current counter value (0 if never incremented)."""
        return self._counters.get(name, 0)

    # ----------------------------------------------------------- gauges ----

    def gauge(self, name: str, value) -> None:
        self._gauges[name] = value

    # ----------------------------------------------------------- timers ----

    def observe(self, name: str, seconds: float) -> None:
        st = self._timers.get(name)
        if st is None:
            st = self._timers[name] = {"count": 0, "total_s": 0.0,
                                       "min_s": float("inf"), "max_s": 0.0}
        st["count"] += 1
        st["total_s"] += seconds
        st["min_s"] = min(st["min_s"], seconds)
        st["max_s"] = max(st["max_s"], seconds)

    @contextmanager
    def timer(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.observe(name, time.perf_counter() - t0)

    # ------------------------------------------------------------- views ----

    def snapshot(self) -> dict:
        """JSON-ready view: ``{"counters": ..., "gauges": ...,
        "timers": ...}`` (timers rounded to stay diff-friendly)."""
        return {
            "counters": dict(self._counters),
            "gauges": dict(self._gauges),
            "timers": {k: {"count": v["count"],
                           "total_s": round(v["total_s"], 6),
                           "min_s": round(v["min_s"], 6),
                           "max_s": round(v["max_s"], 6)}
                       for k, v in self._timers.items()},
        }

    def merge(self, other: "MetricsRegistry", prefix: str = "") -> None:
        """Fold ``other``'s counters/gauges/timers into this registry
        (e.g. a router's local registry into the run-wide one)."""
        snap = other.snapshot()
        for k, v in snap["counters"].items():
            self.inc(prefix + k, v)
        for k, v in snap["gauges"].items():
            self.gauge(prefix + k, v)
        for k, st in snap["timers"].items():
            t = self._timers.setdefault(
                prefix + k, {"count": 0, "total_s": 0.0,
                             "min_s": float("inf"), "max_s": 0.0})
            t["count"] += st["count"]
            t["total_s"] += st["total_s"]
            t["min_s"] = min(t["min_s"], st["min_s"])
            t["max_s"] = max(t["max_s"], st["max_s"])


class _NullTimer:
    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_TIMER = _NullTimer()


class NullRegistry(MetricsRegistry):
    """The disabled sink: every method is a no-op, ``enabled`` is False.

    This is the ambient default — instrumented code pays one attribute
    lookup and a ``pass`` per event, and nothing is ever stored.
    """

    enabled = False

    def __init__(self):  # no dicts — nothing is ever stored
        pass

    def inc(self, name, n=1):
        pass

    def set_counter(self, name, value):
        pass

    def value(self, name):
        return 0

    def gauge(self, name, value):
        pass

    def observe(self, name, seconds):
        pass

    def timer(self, name):
        return _NULL_TIMER

    def snapshot(self):
        return {"counters": {}, "gauges": {}, "timers": {}}

    def merge(self, other, prefix=""):
        pass


NULL_METRICS = NullRegistry()

_ambient: MetricsRegistry = NULL_METRICS


def get_metrics() -> MetricsRegistry:
    """The ambient registry (the :class:`NullRegistry` singleton unless a
    :func:`collecting` / ``recording`` scope is active)."""
    return _ambient


@contextmanager
def collecting(registry: "MetricsRegistry | None" = None):
    """Install ``registry`` (default: a fresh one) as the ambient metrics
    sink for the scope; restores the previous sink on exit."""
    global _ambient
    reg = registry if registry is not None else MetricsRegistry()
    prev = _ambient
    _ambient = reg
    try:
        yield reg
    finally:
        _ambient = prev
