"""Observability for the whole sim stack: metrics, traces, dashboards.

Zero-cost when disabled — the ambient registry defaults to a no-op
singleton and the jitted solver paths are never instrumented inside jit.
See ``docs/observability.md`` for the metrics catalog and usage.
"""

from .metrics import (MetricsRegistry, NullRegistry, NULL_METRICS,
                      get_metrics, collecting)
from .trace import (LinkSeriesPolicy, TraceRecorder, get_recorder,
                    recording, validate_trace)

__all__ = [
    "MetricsRegistry", "NullRegistry", "NULL_METRICS", "get_metrics",
    "collecting",
    "LinkSeriesPolicy", "TraceRecorder", "get_recorder", "recording",
    "validate_trace",
]
