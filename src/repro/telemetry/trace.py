"""Fabric flight recorder: spans, counters, and Perfetto export.

A :class:`TraceRecorder` journals what the simulated fabric *did over
time* — the flow-level visibility FatPaths argues for and the endpoint
scalars (FCT percentiles, step time) cannot give:

* per-epoch event-loop state (epoch clock, active-flow counts, per-link
  utilization series) journaled out of both event-loop backends — the
  numpy reference loop and the jitted ``lax.while_loop`` journal the
  SAME rows (``tests/test_telemetry.py`` pins count + ordering);
* per-flow start/finish spans (budgeted — see below);
* co-sim collective phases as named spans on per-plane tracks
  (:mod:`repro.cosim.stepsim`), so a training step renders as a timeline;
* failure-recovery windows (detect / re-route / recover) as spans
  (:mod:`repro.sim.failures`).

Everything exports as Chrome/Perfetto ``trace_event`` JSON
(:meth:`TraceRecorder.export`): open the file at https://ui.perfetto.dev
or ``chrome://tracing``.  Simulated-fabric time maps to trace time
(1 simulated second = 1e6 trace microseconds).

Scale is bounded by policy, never silently: a 65K-NIC run journals only
the :class:`LinkSeriesPolicy` link subset (top-K by expected load plus a
seeded reservoir of the remaining used links), at most
``max_epochs`` journal rows, and at most ``max_flow_events`` flow spans
— everything dropped is counted in the recorder's metrics
(``trace.dropped_epochs`` / ``trace.dropped_flow_events``).

Enable with :func:`recording` — it also installs the recorder's
:class:`~repro.telemetry.metrics.MetricsRegistry` as the ambient sink::

    with recording() as rec:
        simulate_step(topo, job)
    rec.export("step_trace.json")
"""

from __future__ import annotations

import json
import time
from contextlib import contextmanager
from dataclasses import dataclass

import numpy as np

from .metrics import MetricsRegistry, collecting

__all__ = ["LinkSeriesPolicy", "TraceRecorder", "get_recorder",
           "recording", "validate_trace"]

_US = 1e6   # simulated seconds -> trace_event microseconds


@dataclass(frozen=True)
class LinkSeriesPolicy:
    """Which links get a per-epoch utilization series, and how long.

    ``top_k`` links by expected load (incidence at demand-cap rates,
    deterministic load-then-id ordering) plus a ``reservoir`` sampled
    without replacement (seeded) from the remaining *used* links — so a
    65K-NIC fabric journals a fixed-width series instead of ~72K columns.
    ``max_epochs`` caps journal rows per simulation; overflow is counted
    (``trace.dropped_epochs``), never silently truncated.
    """

    top_k: int = 16
    reservoir: int = 8
    seed: int = 0
    max_epochs: int = 4096

    def select(self, inc, rate_caps_gbps) -> np.ndarray:
        """(K',) sorted global edge ids to journal for one incidence
        tensor (K' <= top_k + reservoir; only used edges qualify)."""
        caps = np.broadcast_to(np.asarray(rate_caps_gbps, dtype=np.float64),
                               (inc.n_flows,))
        load = inc.loads(caps)
        used = np.flatnonzero(load > 0)
        if used.size == 0:
            return used
        order = used[np.lexsort((used, -load[used]))]
        top = order[:self.top_k]
        rest = np.setdiff1d(used, top, assume_unique=False)
        if rest.size and self.reservoir > 0:
            rng = np.random.default_rng(self.seed)
            res = rng.choice(rest, size=min(self.reservoir, rest.size),
                             replace=False)
            top = np.concatenate([top, res])
        return np.sort(top)


class TraceRecorder:
    """Collects trace events + metrics; exports Perfetto JSON.

    Tracks are named ``(process, thread)`` pairs mapped to stable
    ``(pid, tid)`` ids with ``process_name`` / ``thread_name`` metadata,
    so Perfetto renders e.g. one process per co-simulated topology with
    one thread per plane.
    """

    def __init__(self, link_policy: "LinkSeriesPolicy | None" =
                 LinkSeriesPolicy(),
                 max_flow_events: int = 256):
        self.metrics = MetricsRegistry()
        self.link_policy = link_policy
        self.max_flow_events = max_flow_events
        self.events: "list[dict]" = []
        self.journals: "list[dict]" = []
        self.notes: "list[dict]" = []
        self._procs: dict = {}
        self._threads: dict = {}
        self._meta: "list[dict]" = []
        self._flow_budget = max_flow_events
        self._wall0 = time.perf_counter()

    # ------------------------------------------------------------ tracks ----

    def track(self, process: str = "sim", thread: str = "main"
              ) -> "tuple[int, int]":
        """(pid, tid) of a named track, registering display metadata on
        first use."""
        pid = self._procs.get(process)
        if pid is None:
            pid = self._procs[process] = len(self._procs) + 1
            self._meta.append({"name": "process_name", "ph": "M",
                               "pid": pid, "tid": 0,
                               "args": {"name": process}})
        tid = self._threads.get((pid, thread))
        if tid is None:
            tid = self._threads[(pid, thread)] = \
                len([1 for (p, _) in self._threads if p == pid]) + 1
            self._meta.append({"name": "thread_name", "ph": "M",
                               "pid": pid, "tid": tid,
                               "args": {"name": thread}})
        return pid, tid

    # ------------------------------------------------------------ events ----

    @property
    def n_events(self) -> int:
        return len(self.events)

    def span(self, name: str, start_s: float, dur_s: float,
             process: str = "sim", thread: str = "main",
             cat: str = "sim", args: "dict | None" = None) -> None:
        """A complete ("X") span on the simulated-time clock."""
        pid, tid = self.track(process, thread)
        ev = {"name": name, "ph": "X", "cat": cat,
              "ts": float(start_s) * _US, "dur": float(dur_s) * _US,
              "pid": pid, "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, name: str, ts_s: float, process: str = "sim",
                thread: str = "main", cat: str = "sim",
                args: "dict | None" = None) -> None:
        pid, tid = self.track(process, thread)
        ev = {"name": name, "ph": "i", "cat": cat, "s": "t",
              "ts": float(ts_s) * _US, "pid": pid, "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, name: str, ts_s: float, values: dict,
                process: str = "sim", cat: str = "sim") -> None:
        """A counter ("C") sample; ``values`` maps series name -> value."""
        pid, _ = self.track(process, "main")
        self.events.append({"name": name, "ph": "C", "cat": cat,
                            "ts": float(ts_s) * _US, "pid": pid,
                            "args": {k: float(v)
                                     for k, v in values.items()}})

    @contextmanager
    def wall_span(self, name: str, process: str = "wall",
                  thread: str = "main", cat: str = "wall",
                  args: "dict | None" = None):
        """A span on the host wall clock (relative to recorder start) —
        for solver/compile wall time, not simulated fabric time."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            t1 = time.perf_counter()
            self.span(name, t0 - self._wall0, t1 - t0, process=process,
                      thread=thread, cat=cat, args=args)

    def note_skip(self, name: str, reason: str) -> None:
        """Explicit record that a suite/bench path produced no trace."""
        self.notes.append({"name": name, "traced": False,
                           "reason": reason})

    # ------------------------------------------------- sim-layer hooks ----

    def record_flow_sim(self, res, label: str = "flows") -> None:
        """Per-flow transfer spans from a
        :class:`~repro.sim.events.FlowSimResult` (budgeted to
        ``max_flow_events`` total, longest transfers first)."""
        done = np.flatnonzero(np.isfinite(res.finish_s))
        take = done
        if take.size > self._flow_budget:
            transfer = res.finish_s[done] - res.start_s[done]
            keep = np.argsort(-transfer, kind="stable")[:self._flow_budget]
            take = np.sort(done[keep])
            self.metrics.inc("trace.dropped_flow_events",
                             int(done.size - take.size))
        self._flow_budget -= int(take.size)
        tags = getattr(res, "tags", None)
        for f in take.tolist():
            args = {"bytes": float(res.size_bytes[f])}
            if tags is not None and tags[f] is not None:
                args["tag"] = str(tags[f])
            self.span(f"flow[{f}]", float(res.start_s[f]),
                      float(res.finish_s[f] - res.start_s[f]),
                      process="sim", thread=label, cat="flow",
                      args=args)
        stalled = int(res.stalled.sum())
        if stalled:
            self.metrics.inc("sim.stalled_flows", stalled)

    def record_epoch_journal(self, t_s, dt_s, active, edge_ids, util,
                             label: str = "epochs",
                             dropped: int = 0) -> None:
        """Per-epoch journal rows (from either event-loop backend):
        epoch clock, active-flow count, per-selected-link utilization.
        Stored raw in :attr:`journals` and emitted as counter samples."""
        t_s = np.asarray(t_s, dtype=np.float64)
        self.journals.append({
            "label": label,
            "t_s": t_s.tolist(),
            "dt_s": np.asarray(dt_s, dtype=np.float64).tolist(),
            "active_flows": np.asarray(active).astype(int).tolist(),
            "edge_ids": np.asarray(edge_ids).astype(int).tolist(),
            "util": np.asarray(util, dtype=np.float64).tolist(),
            "dropped_epochs": int(dropped),
        })
        if dropped:
            self.metrics.inc("trace.dropped_epochs", int(dropped))
        ids = [f"e{int(e)}" for e in np.asarray(edge_ids).tolist()]
        for i in range(t_s.shape[0]):
            self.counter("active_flows", float(t_s[i]),
                         {label: int(np.asarray(active)[i])})
            if ids:
                self.counter("link_util", float(t_s[i]),
                             dict(zip(ids, np.asarray(util)[i])))

    # ------------------------------------------------------------ export ----

    def to_json(self) -> dict:
        """The Perfetto ``trace_event`` payload (JSON object format)."""
        return {
            "traceEvents": self._meta + self.events,
            "displayTimeUnit": "ms",
            "otherData": {
                "generated_by": "repro.telemetry",
                "clock": "1 simulated second = 1e6 trace us "
                         "(wall tracks use host wall clock)",
                "skipped": self.notes,
                "metrics": self.metrics.snapshot(),
            },
        }

    def export(self, path: "str | None" = None) -> dict:
        payload = self.to_json()
        if path is not None:
            with open(path, "w") as f:
                json.dump(payload, f, indent=1)
                f.write("\n")
        return payload


def validate_trace(payload: dict) -> "list[str]":
    """Schema-check a ``trace_event`` payload; returns problems (empty =
    valid).  Covers the event phases this module emits (M/X/i/C)."""
    problems = []
    events = payload.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    required = {"M": ("name", "ph", "pid", "args"),
                "X": ("name", "ph", "ts", "dur", "pid", "tid"),
                "i": ("name", "ph", "ts", "pid", "tid", "s"),
                "C": ("name", "ph", "ts", "pid", "args")}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in required:
            problems.append(f"event {i}: unknown ph {ph!r}")
            continue
        for key in required[ph]:
            if key not in ev:
                problems.append(f"event {i} (ph={ph}): missing {key!r}")
        for key in ("ts", "dur"):
            if key in ev and (not isinstance(ev[key], (int, float))
                              or ev[key] < 0):
                problems.append(f"event {i}: bad {key}={ev[key]!r}")
    return problems


_recorder: "TraceRecorder | None" = None


def get_recorder() -> "TraceRecorder | None":
    """The ambient recorder (None unless a :func:`recording` scope is
    active) — the sim/cosim layers consult this, so tracing needs no
    signature changes anywhere."""
    return _recorder


@contextmanager
def recording(recorder: "TraceRecorder | None" = None):
    """Install ``recorder`` (default: a fresh one) as the ambient flight
    recorder AND its metrics registry as the ambient metrics sink."""
    global _recorder
    rec = recorder if recorder is not None else TraceRecorder()
    prev = _recorder
    _recorder = rec
    try:
        with collecting(rec.metrics):
            yield rec
    finally:
        _recorder = prev
