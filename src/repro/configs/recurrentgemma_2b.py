"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2
[arXiv:2402.19427; hf]: 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000.  Unit (rec, rec, attn) x8 + 2 trailing rec; local window 2048;
bounded state -> long_500k runs."""

from .base import HybridConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256_000,
    local_window=2048,
    tie_embeddings=True,
    hybrid=HybridConfig(pattern=("rec", "rec", "attn"), lru_width=2560,
                        conv_width=4),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="recurrentgemma-2b",
        family="hybrid",
        n_layers=5,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=128,
        vocab_size=512,
        local_window=8,
        tie_embeddings=True,
        hybrid=HybridConfig(pattern=("rec", "rec", "attn"), lru_width=64,
                            conv_width=4),
        param_dtype="float32",
        activation_dtype="float32",
    )
