"""Model / run configuration dataclasses.

One :class:`ModelConfig` per assigned architecture lives in
``repro/configs/<arch>.py``; every config also provides a ``smoke()``
reduction of the same family for CPU tests.  Input shapes are separate
(:class:`ShapeConfig`) so every (arch x shape) cell is well-defined.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                  # per-expert FFN hidden size
    n_shared_experts: int = 0      # always-active shared experts (DeepSeek/Kimi)
    first_k_dense: int = 0         # leading dense layers (Kimi: 1)
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclass(frozen=True)
class EncDecConfig:
    n_encoder_layers: int
    # encoder frames come from the modality stub at d_model width
    encoder_bidirectional: bool = True


@dataclass(frozen=True)
class VLMConfig:
    # anyres tiling stub: patch embeddings are precomputed (frontend stub)
    n_image_tokens: int = 1024
    image_token_dtype: str = "bfloat16"


@dataclass(frozen=True)
class HybridConfig:
    """Block pattern for SSM/hybrid stacks.

    ``pattern`` is the repeating unit, e.g. ("rec", "rec", "attn") for
    RecurrentGemma (1 local-attn : 2 RG-LRU), or ("mlstm", "slstm") for
    alternating xLSTM.  ``n_layers`` need not be a multiple of the unit;
    the trailing remainder is taken from the unit prefix.
    """

    pattern: tuple[str, ...]
    lru_width: int | None = None       # RG-LRU recurrent width (None = d_model)
    conv_width: int = 4                # temporal conv in recurrent block
    mlstm_proj_factor: float = 2.0     # xLSTM mLSTM up-projection
    slstm_proj_factor: float = 4.0 / 3.0
    chunk_size: int = 256              # chunkwise-parallel scan chunk


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                    # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # attention flavor
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: Optional[int] = None   # SWA window (tokens), None = full
    local_window: int = 2048               # hybrid local-attention window
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # sub-configs
    moe: Optional[MoEConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # numerics
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    logits_dtype: str = "float32"

    def __post_init__(self):
        if self.n_heads % max(self.n_kv_heads, 1):
            raise ValueError("n_heads must be divisible by n_kv_heads")
        if self.family == "moe" and self.moe is None:
            raise ValueError("moe family requires MoEConfig")
        if self.family == "audio" and self.encdec is None:
            raise ValueError("audio family requires EncDecConfig")
        if self.family in ("ssm", "hybrid") and self.hybrid is None:
            raise ValueError(f"{self.family} family requires HybridConfig")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm" and self.hybrid is not None and \
            all(k in ("mlstm", "slstm", "rec") for k in self.hybrid.pattern)

    @property
    def subquadratic(self) -> bool:
        """Can this arch run ``long_500k`` (bounded decode state)?"""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---------------------------------------------------------- params ----

    def param_count(self) -> int:
        """Total parameters N (analytic; used for MODEL_FLOPS = 6*N*D)."""
        from repro.models.registry import get_model
        return get_model(self).param_count()

    def active_param_count(self) -> int:
        from repro.models.registry import get_model
        return get_model(self).active_param_count()


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned per-architecture shape set)."""

    shape_id: str                 # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


LM_SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class RunConfig:
    """Distribution / training hyperparameters for a launch."""

    arch: str = "yi-9b"
    shape: str = "train_4k"
    multi_pod: bool = False
    # sharding knobs (hillclimbed in EXPERIMENTS.md §Perf)
    fsdp_params: bool = True           # ZeRO-3 param sharding on data axis
    fsdp_pod: bool = False             # extend ZeRO over the pod (DCN) axis
                                       # (needed for the 1T config to fit)
    sequence_parallel: bool = False    # shard activations' seq dim on model
    remat: str = "none"                # none | full | dots
    microbatches: int = 1              # gradient accumulation
    ep_moe: bool = True                # expert-parallel MoE via shard_map A2A
    moe_tp_f: bool = False             # few-expert (E < TP) models: local
                                       # dispatch + f-sharded experts +
                                       # one output psum over the TP axis
                                       # instead of GSPMD dispatch einsums
    moe_weight_stationary: bool = False  # shard expert FFN dim over fsdp and
                                       # psum outputs, instead of gathering
                                       # ZeRO-sharded expert weights per use
                                       # (beyond-paper §Perf optimization)
    grad_compression: str = "none"     # none | int8_ef (cross-pod axis)
    decomposed_allreduce: bool = False # RS+AG instead of AR (plane analogue)
    # optimizer
    lr: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    warmup_steps: int = 100
    total_steps: int = 1000
    adam_dtype: str = "float32"        # bf16 for the 1T config to fit HBM
    master_weights: bool = False
    seed: int = 0
