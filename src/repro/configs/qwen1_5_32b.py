"""qwen1.5-32b [dense] — QKV bias, MHA (kv=40) [hf:Qwen/Qwen1.5-0.5B; hf]:
64L d_model=5120 40H (GQA kv=40) d_ff=27392 vocab=152064."""

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab_size=152_064,
    qkv_bias=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen1.5-32b",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=160,
        vocab_size=512,
        qkv_bias=True,
        param_dtype="float32",
        activation_dtype="float32",
    )
