"""kimi-k2-1t-a32b [moe] — Kimi K2 trillion-param MoE (paper-table)
[arXiv:2501.kimi2; unverified]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384 experts top-8 (+1 shared expert, first layer dense)."""

from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=163_840,
    moe=MoEConfig(n_experts=384, top_k=8, d_expert=2048,
                  n_shared_experts=1, first_k_dense=1),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="kimi-k2-1t-a32b",
        family="moe",
        n_layers=3,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=96,
        vocab_size=512,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=96,
                      n_shared_experts=1, first_k_dense=1,
                      capacity_factor=4.0),
        param_dtype="float32",
        activation_dtype="float32",
    )
