"""qwen3-32b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]:
64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936."""

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    vocab_size=151_936,
    qk_norm=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen3-32b",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=160,
        vocab_size=512,
        qk_norm=True,
        param_dtype="float32",
        activation_dtype="float32",
    )
