"""xlstm-125m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified]:
12L d_model=768 4H d_ff=0 vocab=50304.  d_ff=0: xLSTM blocks carry their own
internal up-projections (mLSTM pf=2.0, sLSTM post-FFN pf=4/3).
Alternating (mlstm, slstm) units; attention-free -> long_500k runs."""

from .base import HybridConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    tie_embeddings=True,
    hybrid=HybridConfig(pattern=("mlstm", "slstm"), chunk_size=256),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="xlstm-125m",
        family="ssm",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=512,
        tie_embeddings=True,
        hybrid=HybridConfig(pattern=("mlstm", "slstm"), chunk_size=16),
        param_dtype="float32",
        activation_dtype="float32",
    )
