"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention
[arXiv:2401.04088; hf]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768.  SWA makes ``long_500k`` runnable (window KV cache)."""

from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32_768,
    sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=16384),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="mixtral-8x22b",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        sliding_window=8,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=128,
                      capacity_factor=4.0),
        param_dtype="float32",
        activation_dtype="float32",
    )
