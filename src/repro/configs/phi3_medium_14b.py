"""phi3-medium-14b [dense] — RoPE SwiGLU GQA [arXiv:2404.14219; unverified]:
40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352."""

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    d_ff=17920,
    vocab_size=100_352,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="phi3-medium-14b",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=192,
        vocab_size=512,
        param_dtype="float32",
        activation_dtype="float32",
    )
