"""llava-next-34b [vlm] — anyres tiling (frontend STUB)
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]:
60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.

The vision tower / anyres tiling is a stub per the assignment:
``input_specs()`` provides precomputed patch embeddings
(B, n_image_tokens, d_model); of each shape's seq_len, the first
n_image_tokens positions are image, the rest text."""

from .base import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    arch_id="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64_000,
    vlm=VLMConfig(n_image_tokens=1024),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="llava-next-34b",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        vlm=VLMConfig(n_image_tokens=8),
        param_dtype="float32",
        activation_dtype="float32",
    )
