"""whisper-small [audio] — enc-dec, conv frontend (STUB)
[arXiv:2212.04356; unverified]: 12L d_model=768 12H (kv=12) d_ff=3072
vocab=51865.  12 encoder + 12 decoder layers; the mel/conv frontend is a
stub — ``input_specs()`` provides precomputed frame embeddings
(B, seq_len//2, d_model), the conv stack's 2x downsampling ratio."""

from .base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51_865,
    encdec=EncDecConfig(n_encoder_layers=12),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="whisper-small",
        family="audio",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        encdec=EncDecConfig(n_encoder_layers=2),
        param_dtype="float32",
        activation_dtype="float32",
    )
