"""RecurrentGemma / Griffin hybrid (arXiv:2402.19427): RG-LRU recurrent
blocks + local (sliding-window) attention, pattern 1 attention : 2 recurrent.

* RG-LRU: h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t * x_t), with
  a_t = exp(-c * softplus(Lambda) * r_t), r_t/i_t input-sigmoid gates.
  Training/prefill use ``lax.associative_scan`` (O(S log S) depth, no S^2
  anywhere) — this is what makes ``long_500k`` runnable; decode keeps O(w)
  state.  A Pallas kernel for the scan lives in repro/kernels/rg_lru.
* Every temporal block (recurrent or local-attn) is followed by a gated MLP
  block, as in Griffin.
* 26 layers with unit (rec, rec, attn): 8 scanned units + 2 trailing rec.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from . import layers as L
from .sharding import MeshPlan, activation_spec, build_param_specs

LRU_C = 8.0


# --------------------------------------------------------------------------
# RG-LRU core
# --------------------------------------------------------------------------


def rg_lru_init(key, width: int):
    ks = jax.random.split(key, 3)
    # Lambda init so that a ~ Uniform(0.9, 0.999)^c at r=1 (Griffin A.2-ish)
    u = jax.random.uniform(ks[0], (width,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / LRU_C))  # softplus^{-1}
    return {
        "wa": L.dense_init(ks[1], (width, width), jnp.float32),
        "ba": jnp.zeros((width,), jnp.float32),
        "wg": L.dense_init(ks[2], (width, width), jnp.float32),
        "bg": jnp.zeros((width,), jnp.float32),
        "lam": lam,
    }


def _rg_lru_coeffs(p, x):
    """x (..., w) -> (a, b) of the recurrence h = a*h_prev + b."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["wa"] + p["ba"])
    i = jax.nn.sigmoid(xf @ p["wg"] + p["bg"])
    log_a = -LRU_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)
    return a, b, log_a


def rg_lru_scan(p, x, h0=None):
    """x: (B,S,w) -> (y (B,S,w) float32, h_last (B,w))."""
    a, b, log_a = _rg_lru_coeffs(p, x)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    acc_a, y = lax.associative_scan(combine, (a, b), axis=1)
    if h0 is not None:
        # contribution of the initial state: prod of a's up to t
        y = y + acc_a * h0[:, None, :]
    return y, y[:, -1, :]


def rg_lru_step(p, x_t, h_prev):
    """x_t (B,w), h_prev (B,w) -> (y_t, h_t)."""
    a, b, _ = _rg_lru_coeffs(p, x_t)
    h = a * h_prev + b
    return h, h


def rg_lru_sequential(p, x, h0=None):
    """Oracle for tests: plain scan over time."""
    B, S, w = x.shape
    h = h0 if h0 is not None else jnp.zeros((B, w), jnp.float32)

    def step(h, xt):
        h, y = rg_lru_step(p, xt, h)
        return h, y

    h, ys = lax.scan(step, h, x.swapaxes(0, 1))
    return ys.swapaxes(0, 1), h


# --------------------------------------------------------------------------
# causal depthwise conv1d
# --------------------------------------------------------------------------


def conv1d_init(key, width: int, k: int):
    return {"w": (jax.random.truncated_normal(key, -2, 2, (k, width),
                                              jnp.float32) / math.sqrt(k)),
            "b": jnp.zeros((width,), jnp.float32)}


def conv1d_causal(p, x):
    """x (B,S,w); y_t = sum_i w_i x_{t-i} + b."""
    k = p["w"].shape[0]
    xf = x.astype(jnp.float32)
    y = xf * p["w"][0]
    for i in range(1, k):
        shifted = jnp.pad(xf, ((0, 0), (i, 0), (0, 0)))[:, :-i or None]
        shifted = shifted[:, :xf.shape[1]]
        y = y + shifted * p["w"][i]
    return (y + p["b"]).astype(x.dtype)


def conv1d_step(p, x_t, buf):
    """x_t (B,w); buf (B,k-1,w) holds previous inputs (newest last)."""
    k = p["w"].shape[0]
    xf = x_t.astype(jnp.float32)
    y = xf * p["w"][0] + p["b"]
    for i in range(1, k):
        y = y + buf[:, -i].astype(jnp.float32) * p["w"][i]
    new_buf = jnp.concatenate([buf[:, 1:], x_t[:, None]], axis=1)
    return y.astype(x_t.dtype), new_buf


# --------------------------------------------------------------------------
# model
# --------------------------------------------------------------------------


class RGLRUModel:
    """Griffin-style hybrid LM (family 'hybrid')."""

    def __init__(self, cfg: ModelConfig, run: RunConfig | None = None,
                 mesh: Mesh | None = None, plan: MeshPlan | None = None):
        assert cfg.hybrid is not None
        self.cfg = cfg
        self.run = run or RunConfig()
        self.mesh = mesh
        self.plan = plan or MeshPlan()
        self.dtype = jnp.dtype(cfg.param_dtype)
        self.adtype = jnp.dtype(cfg.activation_dtype)
        pat = cfg.hybrid.pattern
        self.unit = pat
        self.n_units = cfg.n_layers // len(pat)
        self.tail = pat[:cfg.n_layers - self.n_units * len(pat)]
        self.width = cfg.hybrid.lru_width or cfg.d_model

    def _constrain(self, x):
        if self.mesh is not None:
            from jax.sharding import NamedSharding
            return lax.with_sharding_constraint(
                x, NamedSharding(self.mesh, activation_spec(self.plan)))
        return x

    # ---------------------------------------------------------------- init

    def _rec_block_init(self, key):
        cfg, dt = self.cfg, self.dtype
        d, w = cfg.d_model, self.width
        ks = jax.random.split(key, 6)
        return {
            "norm": L.rmsnorm_init(d, dt),
            "rec": {
                "wx": L.dense_init(ks[0], (d, w), dt),
                "wy": L.dense_init(ks[1], (d, w), dt),
                "conv": conv1d_init(ks[2], w, cfg.hybrid.conv_width),
                "lru": rg_lru_init(ks[3], w),
                "wo": L.dense_init(ks[4], (w, d), dt, in_axis_size=w),
            },
            "mlp_norm": L.rmsnorm_init(d, dt),
            "mlp": L.swiglu_init(ks[5], d, cfg.d_ff, dt),
        }

    def _attn_block_init(self, key):
        cfg, dt = self.cfg, self.dtype
        ks = jax.random.split(key, 2)
        return {
            "norm": L.rmsnorm_init(cfg.d_model, dt),
            "attn": L.mha_init(ks[0], cfg, dt),
            "mlp_norm": L.rmsnorm_init(cfg.d_model, dt),
            "mlp": L.swiglu_init(ks[1], cfg.d_model, cfg.d_ff, dt),
        }

    def _unit_init(self, key):
        ks = jax.random.split(key, len(self.unit))
        out = {}
        for i, kind in enumerate(self.unit):
            init = (self._rec_block_init if kind == "rec"
                    else self._attn_block_init)
            out[f"{kind}_{i}"] = init(ks[i])
        return out

    def init(self, key):
        cfg, dt = self.cfg, self.dtype
        ks = jax.random.split(key, 3)
        params = {
            "embed": L.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
            "units": L.stack_layer_params(self._unit_init, ks[1],
                                          self.n_units),
            "final_norm": L.rmsnorm_init(cfg.d_model, dt),
        }
        if self.tail:
            tks = jax.random.split(ks[2], len(self.tail))
            params["tail"] = [
                (self._rec_block_init if kind == "rec"
                 else self._attn_block_init)(k)
                for kind, k in zip(self.tail, tks)]
        return params

    def param_shapes(self):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    def param_specs(self):
        return build_param_specs(self.param_shapes(), self.plan, self.mesh)

    def param_count(self) -> int:
        return sum(int(np.prod(l.shape))
                   for l in jax.tree.leaves(self.param_shapes()))

    def active_param_count(self) -> int:
        return self.param_count()

    # -------------------------------------------------------------- blocks

    def _rec_states_init(self, batch: int):
        k = self.cfg.hybrid.conv_width
        return {"h": jnp.zeros((batch, self.width), jnp.float32),
                "conv": jnp.zeros((batch, k - 1, self.width), self.adtype)}

    def _attn_cache_init(self, batch: int, max_len: int):
        cap = min(max_len, self.cfg.local_window)
        return L.make_kv_cache(self.cfg, batch, cap, self.adtype)

    def _rec_block(self, p, x, positions, state=None, decode=False):
        cfg = self.cfg
        h = L.rmsnorm(p["norm"], x, cfg.norm_eps)
        u = h @ p["rec"]["wx"]
        g = jax.nn.gelu((h @ p["rec"]["wy"]).astype(jnp.float32))
        new_state = state
        if decode:
            u1, conv_buf = conv1d_step(p["rec"]["conv"], u[:, 0],
                                       state["conv"])
            hs, _ = rg_lru_step(p["rec"]["lru"], u1, state["h"])
            y = hs[:, None]
            new_state = {"h": hs, "conv": conv_buf}
        else:
            u1 = conv1d_causal(p["rec"]["conv"], u)
            y, h_last = rg_lru_scan(p["rec"]["lru"], u1,
                                    h0=state["h"] if state else None)
            if state is not None:
                k = cfg.hybrid.conv_width
                new_state = {"h": h_last,
                             "conv": u[:, -(k - 1):].astype(self.adtype)}
        y = (y.astype(jnp.float32) * g).astype(x.dtype)
        x = x + y @ p["rec"]["wo"]
        h = L.rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
        x = x + L.swiglu(p["mlp"], h)
        return self._constrain(x), new_state

    def _attn_block(self, p, x, positions, cache=None, decode=False,
                    pos=None):
        cfg = self.cfg
        h = L.rmsnorm(p["norm"], x, cfg.norm_eps)
        if decode:
            h, cache = L.self_attention_decode(p["attn"], h, cfg, cache, pos,
                                               window=cfg.local_window)
        else:
            B, S, _ = x.shape
            q, k, v = L.mha_project_qkv(p["attn"], h, cfg, positions)
            o = L.attention(q, k, v, positions, positions, causal=True,
                            window=cfg.local_window)
            h = L.mha_out(p["attn"], o, B, S)
            if cache is not None:
                cache = L.cache_write_prefill(cache, k, v)
        x = x + h
        h2 = L.rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
        x = x + L.swiglu(p["mlp"], h2)
        return self._constrain(x), cache

    def _apply_unit(self, up, x, positions, states=None, decode=False,
                    pos=None, max_len=None, batch=None):
        new_states = {}
        for i, kind in enumerate(self.unit):
            name = f"{kind}_{i}"
            st = states[name] if states is not None else None
            if kind == "rec":
                x, new_states[name] = self._rec_block(
                    up[name], x, positions, st, decode)
            else:
                x, new_states[name] = self._attn_block(
                    up[name], x, positions, st, decode, pos)
        return x, new_states

    # ------------------------------------------------------------- forward

    def _unit_states(self, batch: int, max_len: int):
        out = {}
        for i, kind in enumerate(self.unit):
            out[f"{kind}_{i}"] = (self._rec_states_init(batch)
                                  if kind == "rec"
                                  else self._attn_cache_init(batch, max_len))
        return out

    def _states_init(self, batch: int, max_len: int):
        states = {}
        if self.n_units:
            states["units"] = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[self._unit_states(batch, max_len)
                  for _ in range(self.n_units)])
        else:
            states["units"] = {}
        if self.tail:
            states["tail"] = [
                self._rec_states_init(batch) if kind == "rec"
                else self._attn_cache_init(batch, max_len)
                for kind in self.tail]
        states["pos"] = jnp.zeros((), jnp.int32)
        return states

    def forward(self, params, tokens, img_embeds=None):
        cfg = self.cfg
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.adtype)
        x = self._constrain(x)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        def body(xx, up):
            xx, _ = self._apply_unit(up, xx, positions)
            return xx, None

        x, _ = lax.scan(body, x, params["units"])
        for kind, p in zip(self.tail, params.get("tail", [])):
            fn = self._rec_block if kind == "rec" else self._attn_block
            x, _ = fn(p, x, positions)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = (x @ params["embed"].T).astype(jnp.dtype(cfg.logits_dtype))
        return logits, jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch["tokens"])
        ce = L.cross_entropy_loss(logits, batch["labels"])
        return ce, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------- serving

    def init_cache(self, batch: int, max_len: int):
        return self._states_init(batch, max_len)

    def prefill(self, params, tokens, img_embeds=None,
                max_len: int | None = None):
        cfg = self.cfg
        B, S = tokens.shape
        max_len = max_len or S
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.adtype)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        states = self._states_init(B, max_len)

        def body(xx, xs):
            up, st = xs
            xx, st = self._apply_unit(up, xx, positions, st)
            return xx, st

        new = {"pos": jnp.asarray(S, jnp.int32)}
        if self.n_units:
            x, new["units"] = lax.scan(body, x,
                                       (params["units"], states["units"]))
        else:
            new["units"] = {}
        if self.tail:
            new["tail"] = []
            for kind, p, st in zip(self.tail, params["tail"],
                                   states["tail"]):
                fn = self._rec_block if kind == "rec" else self._attn_block
                x, st = fn(p, x, positions, st)
                new["tail"].append(st)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = (x[:, -1:] @ params["embed"].T).astype(
            jnp.dtype(cfg.logits_dtype))[:, 0]
        return logits, new

    def decode_step(self, params, token, caches):
        cfg = self.cfg
        B = token.shape[0]
        pos = caches["pos"]
        x = jnp.take(params["embed"], token, axis=0).astype(self.adtype)
        positions = jnp.full((B, 1), pos, jnp.int32)

        def body(xx, xs):
            up, st = xs
            xx, st = self._apply_unit(up, xx, positions, st, decode=True,
                                      pos=pos)
            return xx, st

        new = dict(caches)
        if self.n_units:
            x, new["units"] = lax.scan(body, x,
                                       (params["units"], caches["units"]))
        if self.tail:
            new["tail"] = []
            for kind, p, st in zip(self.tail, params["tail"], caches["tail"]):
                fn = self._rec_block if kind == "rec" else self._attn_block
                x, st = fn(p, x, positions, st, decode=True) if kind == "rec" \
                    else fn(p, x, positions, st, decode=True, pos=pos)
                new["tail"].append(st)
        new["pos"] = pos + 1
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = (x @ params["embed"].T).astype(
            jnp.dtype(cfg.logits_dtype))[:, 0]
        return logits, new

    def cache_specs(self, batch: int, max_len: int):
        from .sharding import path_str, shardable
        plan, mesh = self.plan, self.mesh
        b_ax = shardable(mesh, plan.batch_axes, batch)
        cap = min(max_len, self.cfg.local_window)
        tp = plan.tp
        cap_ax = tp if cap % mesh.shape[tp] == 0 else None
        shapes = jax.eval_shape(lambda: self.init_cache(batch, max_len))

        def spec(path, l):
            s = path_str(path)
            if l.ndim == 0:
                return P()
            if s.endswith("/k") or s.endswith("/v"):
                # (units?, B, cap, K, Dh)
                parts = [None] * l.ndim
                parts[l.ndim - 4] = b_ax
                parts[l.ndim - 3] = cap_ax
                return P(*parts)
            # recurrent h/conv/kv_pos: batch-only where present
            parts = [None] * l.ndim
            for i, d in enumerate(l.shape):
                if d == batch and i <= 1 and l.ndim > 1:
                    parts[i] = b_ax
                    break
            return P(*parts)

        return jax.tree_util.tree_map_with_path(spec, shapes)

    # --------------------------------------------------------- input specs

    def input_specs(self, shape: ShapeConfig) -> dict:
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                    "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        caches = jax.eval_shape(lambda: self.init_cache(B, S))
        return {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "caches": caches}
