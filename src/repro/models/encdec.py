"""Whisper-style encoder-decoder (audio family).

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, S_enc, d_model) — the encoder
consumes them directly (the real conv1d x2 downsampling happens upstream).
We map the assigned shape's ``seq_len`` to the decoder length and use
``seq_len // 2`` encoder frames (the conv stack's 2x downsampling ratio),
recorded in DESIGN.md.

Whisper uses LayerNorm, GELU MLPs, sinusoidal encoder positions, absolute
decoder positions, full (non-GQA) attention: n_kv_heads == n_heads.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from . import layers as L
from .sharding import MeshPlan, activation_spec, build_param_specs


def _xattn_init(key, cfg: ModelConfig, dtype):
    return L.mha_init(key, cfg, dtype)


class EncDecLM:
    def __init__(self, cfg: ModelConfig, run: RunConfig | None = None,
                 mesh: Mesh | None = None, plan: MeshPlan | None = None):
        assert cfg.encdec is not None
        self.cfg = cfg
        self.run = run or RunConfig()
        self.mesh = mesh
        self.plan = plan or MeshPlan()
        self.dtype = jnp.dtype(cfg.param_dtype)
        self.adtype = jnp.dtype(cfg.activation_dtype)

    def _constrain(self, x, spec):
        if self.mesh is not None:
            from jax.sharding import NamedSharding
            return lax.with_sharding_constraint(
                x, NamedSharding(self.mesh, spec))
        return x

    # ---------------------------------------------------------------- init

    def _enc_block_init(self, key):
        cfg, dt = self.cfg, self.dtype
        ks = jax.random.split(key, 2)
        return {
            "attn_norm": L.layernorm_init(cfg.d_model, dt),
            "attn": L.mha_init(ks[0], cfg, dt),
            "mlp_norm": L.layernorm_init(cfg.d_model, dt),
            "mlp": L.gelu_mlp_init(ks[1], cfg.d_model, cfg.d_ff, dt),
        }

    def _dec_block_init(self, key):
        cfg, dt = self.cfg, self.dtype
        ks = jax.random.split(key, 3)
        return {
            "attn_norm": L.layernorm_init(cfg.d_model, dt),
            "attn": L.mha_init(ks[0], cfg, dt),
            "xattn_norm": L.layernorm_init(cfg.d_model, dt),
            "xattn": _xattn_init(ks[1], cfg, dt),
            "mlp_norm": L.layernorm_init(cfg.d_model, dt),
            "mlp": L.gelu_mlp_init(ks[2], cfg.d_model, cfg.d_ff, dt),
        }

    def init(self, key):
        cfg, dt = self.cfg, self.dtype
        ks = jax.random.split(key, 4)
        return {
            "embed": L.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
            "enc_layers": L.stack_layer_params(
                self._enc_block_init, ks[1], cfg.encdec.n_encoder_layers),
            "dec_layers": L.stack_layer_params(
                self._dec_block_init, ks[2], cfg.n_layers),
            "enc_norm": L.layernorm_init(cfg.d_model, dt),
            "final_norm": L.layernorm_init(cfg.d_model, dt),
        }

    def param_shapes(self):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    def param_specs(self):
        return build_param_specs(self.param_shapes(), self.plan, self.mesh)

    def param_count(self) -> int:
        return sum(int(np.prod(l.shape))
                   for l in jax.tree.leaves(self.param_shapes()))

    def active_param_count(self) -> int:
        return self.param_count()

    # -------------------------------------------------------------- encode

    def encode(self, params, frames):
        """frames: (B, S_enc, d_model) — stub frontend output."""
        cfg = self.cfg
        B, S, d = frames.shape
        pe = L.sinusoidal_positions(S, d).astype(self.adtype)
        x = frames.astype(self.adtype) + pe[None]
        x = self._constrain(x, activation_spec(self.plan))
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        def body(xx, lp):
            h = L.layernorm(lp["attn_norm"], xx)
            h = L.self_attention(lp["attn"], h, cfg, positions, causal=False,
                                 rope=False)
            xx = xx + h
            h = L.layernorm(lp["mlp_norm"], xx)
            xx = xx + L.gelu_mlp(lp["mlp"], h)
            xx = self._constrain(xx, activation_spec(self.plan))
            return xx, None

        x, _ = lax.scan(body, x, params["enc_layers"])
        return L.layernorm(params["enc_norm"], x)

    # -------------------------------------------------------------- decode

    def _cross_attention(self, p, x, enc_out, positions_q, enc_positions):
        cfg = self.cfg
        B, S, _ = x.shape
        H, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
        G = H // K
        q = (x @ p["wq"]).reshape(B, S, K, G, Dh)
        k = (enc_out @ p["wk"]).reshape(B, -1, K, Dh)
        v = (enc_out @ p["wv"]).reshape(B, -1, K, Dh)
        o = L.attention(q, k, v, positions_q, enc_positions, causal=False)
        return L.mha_out(p, o, B, S)

    def _dec_block(self, lp, x, enc_out, positions, enc_positions):
        cfg = self.cfg
        h = L.layernorm(lp["attn_norm"], x)
        h = L.self_attention(lp["attn"], h, cfg, positions, causal=True,
                             rope=False)
        x = x + h
        h = L.layernorm(lp["xattn_norm"], x)
        x = x + self._cross_attention(lp["xattn"], h, enc_out, positions,
                                      enc_positions)
        h = L.layernorm(lp["mlp_norm"], x)
        x = x + L.gelu_mlp(lp["mlp"], h)
        return self._constrain(x, activation_spec(self.plan))

    def forward(self, params, tokens, frames):
        """Teacher-forced training forward -> logits (B, S_dec, V)."""
        cfg = self.cfg
        enc_out = self.encode(params, frames)
        B, S = tokens.shape
        S_enc = enc_out.shape[1]
        pe = L.sinusoidal_positions(S, cfg.d_model).astype(self.adtype)
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.adtype)
        x = x + pe[None]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        enc_positions = jnp.broadcast_to(
            jnp.arange(S_enc, dtype=jnp.int32), (B, S_enc))

        def body(xx, lp):
            return self._dec_block(lp, xx, enc_out, positions,
                                   enc_positions), None

        x, _ = lax.scan(body, x, params["dec_layers"])
        x = L.layernorm(params["final_norm"], x)
        logits = (x @ params["embed"].T).astype(
            jnp.dtype(cfg.logits_dtype))
        return logits, jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch["tokens"], batch["frames"])
        ce = L.cross_entropy_loss(logits, batch["labels"])
        return ce, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------- serving

    def init_cache(self, batch: int, max_len: int, enc_len: int):
        cfg = self.cfg
        nl = cfg.n_layers
        K, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
        return {
            "self": L.make_kv_cache(cfg, batch, max_len, self.adtype,
                                    n_layers=nl),
            "cross_k": jnp.zeros((nl, batch, enc_len, K, Dh), self.adtype),
            "cross_v": jnp.zeros((nl, batch, enc_len, K, Dh), self.adtype),
            "pos": jnp.zeros((), jnp.int32),
        }

    def prefill(self, params, tokens, frames, max_len: int | None = None):
        """Encode audio + run the decoder prompt; build caches."""
        cfg = self.cfg
        enc_out = self.encode(params, frames)
        B, S = tokens.shape
        S_enc = enc_out.shape[1]
        max_len = max_len or S
        pe = L.sinusoidal_positions(S, cfg.d_model).astype(self.adtype)
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.adtype) + pe
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        enc_positions = jnp.broadcast_to(
            jnp.arange(S_enc, dtype=jnp.int32), (B, S_enc))
        K, Dh = cfg.n_kv_heads, cfg.resolved_head_dim

        def body(xx, lp):
            h = L.layernorm(lp["attn_norm"], xx)
            q, k, v = L.mha_project_qkv(lp["attn"], h, cfg, positions,
                                        rope=False)
            o = L.attention(q, k, v, positions, positions, causal=True)
            xx = xx + L.mha_out(lp["attn"], o, B, S)
            h = L.layernorm(lp["xattn_norm"], xx)
            xx = xx + self._cross_attention(lp["xattn"], h, enc_out,
                                            positions, enc_positions)
            h = L.layernorm(lp["mlp_norm"], xx)
            xx = xx + L.gelu_mlp(lp["mlp"], h)
            cache = L.make_kv_cache(cfg, B, max_len, self.adtype)
            cache = L.cache_write_prefill(cache, k, v)
            ck = (enc_out @ lp["xattn"]["wk"]).reshape(B, S_enc, K, Dh)
            cv = (enc_out @ lp["xattn"]["wv"]).reshape(B, S_enc, K, Dh)
            return xx, (cache, ck, cv)

        x, (self_cache, cross_k, cross_v) = lax.scan(
            body, x, params["dec_layers"])
        x = L.layernorm(params["final_norm"], x)
        logits = (x[:, -1:] @ params["embed"].T).astype(
            jnp.dtype(cfg.logits_dtype))[:, 0]
        caches = {"self": self_cache, "cross_k": cross_k, "cross_v": cross_v,
                  "pos": jnp.asarray(S, jnp.int32)}
        return logits, caches

    def decode_step(self, params, token, caches):
        cfg = self.cfg
        B = token.shape[0]
        pos = caches["pos"]
        x = jnp.take(params["embed"], token, axis=0).astype(self.adtype)
        x = x + L.sinusoidal_position_at(pos, cfg.d_model).astype(
            self.adtype)[None]
        S_enc = caches["cross_k"].shape[2]
        enc_positions = jnp.broadcast_to(
            jnp.arange(S_enc, dtype=jnp.int32), (B, S_enc))
        positions = jnp.full((B, 1), pos, jnp.int32)
        H, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
        G = H // K

        def body(xx, layer):
            lp, cache, ck, cv = layer
            h = L.layernorm(lp["attn_norm"], xx)
            h, cache = L.self_attention_decode(lp["attn"], h, cfg, cache, pos,
                                               rope=False)
            xx = xx + h
            h = L.layernorm(lp["xattn_norm"], xx)
            q = (h @ lp["xattn"]["wq"]).reshape(B, 1, K, G, Dh)
            o = L.attention_ref(q, ck, cv, positions, enc_positions,
                                causal=False)
            xx = xx + L.mha_out(lp["xattn"], o, B, 1)
            h = L.layernorm(lp["mlp_norm"], xx)
            xx = xx + L.gelu_mlp(lp["mlp"], h)
            return xx, cache

        x, self_cache = lax.scan(
            body, x, (params["dec_layers"], caches["self"],
                      caches["cross_k"], caches["cross_v"]))
        new = dict(caches)
        new["self"] = self_cache
        new["pos"] = pos + 1
        x = L.layernorm(params["final_norm"], x)
        logits = (x @ params["embed"].T).astype(
            jnp.dtype(cfg.logits_dtype))[:, 0]
        return logits, new

    def cache_specs(self, batch: int, max_len: int):
        from .sharding import kv_cache_specs, shardable
        cfg = self.cfg
        layer = kv_cache_specs(self.plan, self.mesh, batch, max_len,
                               cfg.n_kv_heads)
        b_ax = shardable(self.mesh, self.plan.batch_axes, batch)
        enc = self.enc_len(max_len)
        tp = self.plan.tp
        if cfg.n_kv_heads % self.mesh.shape[tp] == 0:
            cross = P(None, b_ax, None, tp, None)
        elif enc % self.mesh.shape[tp] == 0:
            cross = P(None, b_ax, tp, None, None)
        else:
            cross = P(None, b_ax, None, None, None)
        return {"self": layer, "cross_k": cross, "cross_v": cross,
                "pos": P()}

    # --------------------------------------------------------- input specs

    def enc_len(self, seq_len: int) -> int:
        return max(seq_len // 2, 8)

    def input_specs(self, shape: ShapeConfig) -> dict:
        B, S = shape.global_batch, shape.seq_len
        cfg = self.cfg
        frames = jax.ShapeDtypeStruct(
            (B, self.enc_len(S), cfg.d_model),
            jnp.dtype(cfg.activation_dtype))
        if shape.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                    "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
                    "frames": frames}
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                    "frames": frames}
        caches = jax.eval_shape(
            lambda: self.init_cache(B, S, self.enc_len(S)))
        return {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "caches": caches}
