"""xLSTM LM (Beck et al., arXiv:2405.04517): alternating mLSTM / sLSTM blocks.

* mLSTM — matrix-memory LSTM with exponential gating.  Training/prefill use
  the **chunkwise-parallel form** (intra-chunk quadratic + inter-chunk
  recurrent state, like GLA/Mamba-2 chunking) so long sequences never
  materialize S^2; decode uses the O(1)-state recurrent form.  The two forms
  agree to numerical tolerance (tests/test_models_xlstm.py).
* sLSTM — scalar-memory LSTM with exponential gating and per-head
  block-diagonal recurrence; inherently sequential -> ``lax.scan`` over time.

Deviations from the paper (recorded in DESIGN.md): forget gate uses
log-sigmoid gating (the paper allows sigmoid or exp; log-sigmoid is the
numerically stable choice), and the mLSTM causal conv is omitted.

State is O(d^2/H) per layer -> ``long_500k`` decode is supported (ssm family).
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from . import layers as L
from .sharding import MeshPlan, activation_spec, build_param_specs


# --------------------------------------------------------------------------
# mLSTM core
# --------------------------------------------------------------------------


def mlstm_init(key, d_in: int, H: int, dtype):
    """Projections at width d_in with H heads (Dh = d_in // H)."""
    Dh = d_in // H
    ks = jax.random.split(key, 6)
    return {
        "wq": L.dense_init(ks[0], (d_in, d_in), dtype),
        "wk": L.dense_init(ks[1], (d_in, d_in), dtype),
        "wv": L.dense_init(ks[2], (d_in, d_in), dtype),
        # scalar i/f gate preactivations per head
        "w_gates": L.dense_init(ks[3], (d_in, 2 * H), jnp.float32),
        "b_gates": jnp.zeros((2 * H,), jnp.float32),
        "out_norm": {"scale": jnp.ones((d_in,), dtype)},
    }


def _mlstm_qkv(p, x, H):
    B, S, d = x.shape
    Dh = d // H
    q = (x @ p["wq"]).reshape(B, S, H, Dh) / math.sqrt(Dh)
    k = (x @ p["wk"]).reshape(B, S, H, Dh) / math.sqrt(Dh)
    v = (x @ p["wv"]).reshape(B, S, H, Dh)
    gates = x.astype(jnp.float32) @ p["w_gates"] + p["b_gates"]  # (B,S,2H)
    i_pre, f_pre = gates[..., :H], gates[..., H:]
    log_f = jax.nn.log_sigmoid(f_pre)                            # <= 0
    return q, k, v, i_pre, log_f


def mlstm_state_init(batch: int, H: int, Dh: int):
    return {
        "C": jnp.zeros((batch, H, Dh, Dh), jnp.float32),
        "n": jnp.zeros((batch, H, Dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def mlstm_recurrent_step(state, q, k, v, i_pre, log_f):
    """One timestep.  q,k,v: (B,H,Dh); i_pre,log_f: (B,H)."""
    m_new = jnp.maximum(log_f + state["m"], i_pre)
    f_eff = jnp.exp(log_f + state["m"] - m_new)[..., None]
    i_eff = jnp.exp(i_pre - m_new)[..., None]
    C = state["C"] * f_eff[..., None] + \
        i_eff[..., None] * v[..., None, :] * k[..., :, None]
    n = state["n"] * f_eff + i_eff * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)),
                        jnp.exp(-m_new))[..., None]
    h = jnp.einsum("bhde,bhd->bhe", C, q) / denom
    return {"C": C, "n": n, "m": m_new}, h


def mlstm_sequential(p, x, H, state=None):
    """Oracle: scan the recurrent form over time.  x: (B,S,d_in)."""
    B, S, d = x.shape
    Dh = d // H
    q, k, v, i_pre, log_f = _mlstm_qkv(p, x, H)
    state = state or mlstm_state_init(B, H, Dh)

    def step(st, t):
        qt, kt, vt, it, ft = t
        st, h = mlstm_recurrent_step(st, qt, kt, vt, it, ft)
        return st, h

    xs = (q.transpose(1, 0, 2, 3).astype(jnp.float32),
          k.transpose(1, 0, 2, 3).astype(jnp.float32),
          v.transpose(1, 0, 2, 3).astype(jnp.float32),
          i_pre.transpose(1, 0, 2), log_f.transpose(1, 0, 2))
    state, hs = lax.scan(step, state, xs)
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, d)
    return h.astype(x.dtype), state


def mlstm_chunkwise(p, x, H, chunk: int = 256, state=None):
    """Chunkwise-parallel mLSTM.  Matches :func:`mlstm_sequential`."""
    B, S, d = x.shape
    Dh = d // H
    q, k, v, i_pre, log_f = _mlstm_qkv(p, x, H)
    W = min(chunk, S)
    pad = (-S) % W
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # padded steps: i = -inf (no input), f = 0 (keep state)
        i_pre = jnp.pad(i_pre, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
    NC = (S + pad) // W

    def to_chunks(a):
        return a.reshape(B, NC, W, *a.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = to_chunks(q.astype(jnp.float32)), \
        to_chunks(k.astype(jnp.float32)), to_chunks(v.astype(jnp.float32))
    ic, fc = to_chunks(i_pre), to_chunks(log_f)

    state = state or mlstm_state_init(B, H, Dh)

    def chunk_step(st, ch):
        qi, ki, vi, ii, fi = ch          # (B,W,H,*) / gates (B,W,H)
        F = jnp.cumsum(fi, axis=1)       # (B,W,H) inclusive cumsum of log f
        Ftot = F[:, -1]                  # (B,H)
        # intra-chunk log weights: logD[b,h,t,j] = F_t - F_j + i_j, j <= t
        logD = (F[:, :, None, :] - F[:, None, :, :]
                + ii[:, None, :, :])                     # (B,Wq,Wk,H)
        tidx = jnp.arange(qi.shape[1])
        causal = tidx[:, None] >= tidx[None, :]
        logD = jnp.where(causal[None, :, :, None], logD, -jnp.inf)
        m_intra = jnp.max(logD, axis=2)                  # (B,W,H)
        # inter-chunk: state decayed to step t has log-scale F_t + m_prev
        m_inter = F + st["m"][:, None, :]
        m_t = jnp.maximum(m_intra, m_inter)              # (B,W,H)
        D = jnp.exp(logD - m_t[:, :, None, :])           # (B,Wq,Wk,H)
        inter_scale = jnp.exp(m_inter - m_t)             # (B,W,H)
        # scores
        s = jnp.einsum("bthd,bjhd->btjh", qi, ki) * D
        h_intra = jnp.einsum("btjh,bjhd->bthd", s, vi)
        n_intra = jnp.einsum("btjh,bjhd->bthd", D, ki)
        h_inter = jnp.einsum("bthd,bhde->bthe", qi * inter_scale[..., None],
                             st["C"])
        n_inter = st["n"][:, None] * inter_scale[..., None]
        n_t = n_intra + n_inter
        denom = jnp.maximum(jnp.abs(jnp.einsum("bthd,bthd->bth", n_t, qi)),
                            jnp.exp(-m_t))[..., None]
        h = (h_intra + h_inter) / denom                  # (B,W,H,Dh)
        # ---- state update to end of chunk
        m_next = jnp.maximum(Ftot + st["m"],
                             jnp.max(Ftot[:, None] - F + ii, axis=1))
        carry_scale = jnp.exp(Ftot + st["m"] - m_next)   # (B,H)
        w_j = jnp.exp(Ftot[:, None] - F + ii - m_next[:, None])  # (B,W,H)
        C_new = st["C"] * carry_scale[..., None, None] + jnp.einsum(
            "bjh,bjhd,bjhe->bhde", w_j, ki, vi)
        n_new = st["n"] * carry_scale[..., None] + jnp.einsum(
            "bjh,bjhd->bhd", w_j, ki)
        return {"C": C_new, "n": n_new, "m": m_next}, h

    state, hs = lax.scan(chunk_step, state, (qc, kc, vc, ic, fc))
    h = hs.swapaxes(0, 1).reshape(B, NC * W, H, Dh)[:, :S]
    return h.reshape(B, S, d).astype(x.dtype), state


# --------------------------------------------------------------------------
# sLSTM core
# --------------------------------------------------------------------------


def slstm_init(key, d: int, H: int, dtype):
    Dh = d // H
    ks = jax.random.split(key, 2)
    return {
        "wx": L.dense_init(ks[0], (d, 4 * d), jnp.float32),
        # per-head recurrent weights (H, Dh, 4*Dh)
        "wr": (jax.random.truncated_normal(ks[1], -2, 2, (H, Dh, 4 * Dh),
                                           jnp.float32) / math.sqrt(Dh)),
        "b": jnp.zeros((4 * d,), jnp.float32),
    }


def slstm_state_init(batch: int, d: int, H: int):
    Dh = d // H
    z = jnp.zeros((batch, H, Dh), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((batch, H, Dh), -1e30), "h": z}


def slstm_cell(p, st, x_pre, H):
    """One recurrence step from PRECOMPUTED input preactivations.

    x_pre: (B, 4d) = x_t @ wx + b, computed outside the time scan so the
    d-sharded GEMM (and its TP collective) runs once for the whole sequence
    instead of once per timestep (cuts the per-step collectives that
    dominated the xlstm prefill dry-run)."""
    B = x_pre.shape[0]
    d = x_pre.shape[1] // 4
    Dh = d // H
    rec = jnp.einsum("bhd,hde->bhe", st["h"], p["wr"])     # (B,H,4Dh)
    pre = x_pre.reshape(B, H, 4 * Dh) + rec
    i_pre, f_pre, z_pre, o_pre = jnp.split(pre, 4, axis=-1)
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + st["m"], i_pre)
    i_eff = jnp.exp(i_pre - m_new)
    f_eff = jnp.exp(log_f + st["m"] - m_new)
    c = f_eff * st["c"] + i_eff * jnp.tanh(z_pre)
    n = f_eff * st["n"] + i_eff
    h = jax.nn.sigmoid(o_pre) * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "m": m_new, "h": h}, h.reshape(B, d)


def slstm_step(p, st, x_t, H):
    """x_t: (B, d) -> (state, h (B,d)).  Decode-path single step."""
    pre = x_t.astype(jnp.float32) @ p["wx"] + p["b"]      # (B, 4d)
    return slstm_cell(p, st, pre, H)


def slstm_sequential(p, x, H, state=None):
    B, S, d = x.shape
    state = state or slstm_state_init(B, d, H)
    # input preactivations for the WHOLE sequence in one sharded GEMM
    x_pre = x.astype(jnp.float32) @ p["wx"] + p["b"]      # (B, S, 4d)

    def step(st, pre_t):
        st, h = slstm_cell(p, st, pre_t, H)
        return st, h

    state, hs = lax.scan(step, state, x_pre.swapaxes(0, 1))
    return hs.swapaxes(0, 1).astype(x.dtype), state


# --------------------------------------------------------------------------
# blocks / model
# --------------------------------------------------------------------------


class XLSTMModel:
    """Alternating mLSTM/sLSTM LM (family 'ssm')."""

    def __init__(self, cfg: ModelConfig, run: RunConfig | None = None,
                 mesh: Mesh | None = None, plan: MeshPlan | None = None):
        assert cfg.hybrid is not None
        self.cfg = cfg
        self.run = run or RunConfig()
        self.mesh = mesh
        self.plan = plan or MeshPlan()
        self.dtype = jnp.dtype(cfg.param_dtype)
        self.adtype = jnp.dtype(cfg.activation_dtype)
        pat = cfg.hybrid.pattern
        reps = cfg.n_layers // len(pat)
        rem = cfg.n_layers - reps * len(pat)
        self.unit = pat
        self.n_units = reps
        self.tail = pat[:rem]

    @property
    def H(self):
        return self.cfg.n_heads

    def _constrain(self, x):
        if self.mesh is not None:
            from jax.sharding import NamedSharding
            return lax.with_sharding_constraint(
                x, NamedSharding(self.mesh, activation_spec(self.plan)))
        return x

    # ---------------------------------------------------------------- init

    def _mlstm_block_init(self, key):
        cfg, dt = self.cfg, self.dtype
        d = cfg.d_model
        pf = cfg.hybrid.mlstm_proj_factor
        d_in = int(d * pf)
        d_in -= d_in % self.H
        ks = jax.random.split(key, 3)
        return {
            "kind": "mlstm",
            "norm": L.rmsnorm_init(d, dt),
            "w_up": L.dense_init(ks[0], (d, 2 * d_in), dt),
            "mlstm": mlstm_init(ks[1], d_in, self.H, dt),
            "w_down": L.dense_init(ks[2], (d_in, d), dt, in_axis_size=d_in),
        }

    def _slstm_block_init(self, key):
        cfg, dt = self.cfg, self.dtype
        d = cfg.d_model
        pf = cfg.hybrid.slstm_proj_factor
        d_ff = int(d * pf)
        ks = jax.random.split(key, 3)
        return {
            "kind": "slstm",
            "norm": L.rmsnorm_init(d, dt),
            "slstm": slstm_init(ks[0], d, self.H, jnp.float32),
            "ffn_norm": L.rmsnorm_init(d, dt),
            "ffn": L.swiglu_init(ks[1], d, d_ff, dt),
        }

    def _unit_init(self, key):
        ks = jax.random.split(key, len(self.unit))
        out = {}
        for i, kind in enumerate(self.unit):
            init = (self._mlstm_block_init if kind == "mlstm"
                    else self._slstm_block_init)
            blk = init(ks[i])
            blk.pop("kind")
            out[f"{kind}_{i}"] = blk
        return out

    def init(self, key):
        cfg, dt = self.cfg, self.dtype
        ks = jax.random.split(key, 4)
        params = {
            "embed": L.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
            "units": L.stack_layer_params(self._unit_init, ks[1],
                                          self.n_units),
            "final_norm": L.rmsnorm_init(cfg.d_model, dt),
        }
        if self.tail:
            tail_ks = jax.random.split(ks[2], len(self.tail))
            params["tail"] = []
            for kind, k in zip(self.tail, tail_ks):
                init = (self._mlstm_block_init if kind == "mlstm"
                        else self._slstm_block_init)
                blk = init(k)
                blk.pop("kind")
                params["tail"].append(blk)
        return params

    def param_shapes(self):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    def param_specs(self):
        return build_param_specs(self.param_shapes(), self.plan, self.mesh)

    def param_count(self) -> int:
        return sum(int(np.prod(l.shape))
                   for l in jax.tree.leaves(self.param_shapes()))

    def active_param_count(self) -> int:
        return self.param_count()

    # -------------------------------------------------------------- blocks

    def _mlstm_block(self, p, x, state=None, decode=False):
        cfg = self.cfg
        h = L.rmsnorm(p["norm"], x, cfg.norm_eps)
        u = h @ p["w_up"]
        d_in = u.shape[-1] // 2
        core_in, z = u[..., :d_in], u[..., d_in:]
        if decode:
            B = x.shape[0]
            Dh = d_in // self.H
            q, k, v, i_pre, log_f = _mlstm_qkv(p["mlstm"], core_in, self.H)
            state, hh = mlstm_recurrent_step(
                state, q[:, 0].astype(jnp.float32),
                k[:, 0].astype(jnp.float32),
                v[:, 0].astype(jnp.float32), i_pre[:, 0], log_f[:, 0])
            hh = hh.reshape(B, 1, d_in).astype(x.dtype)
        else:
            hh, state = mlstm_chunkwise(p["mlstm"], core_in, self.H,
                                        chunk=cfg.hybrid.chunk_size,
                                        state=state)
        hh = L.rmsnorm(p["mlstm"]["out_norm"], hh, cfg.norm_eps)
        hh = hh * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
        return x + hh @ p["w_down"], state

    def _slstm_block(self, p, x, state=None, decode=False):
        cfg = self.cfg
        h = L.rmsnorm(p["norm"], x, cfg.norm_eps)
        if decode:
            state, hh = slstm_step(p["slstm"], state, h[:, 0], self.H)
            hh = hh[:, None].astype(x.dtype)
        else:
            hh, state = slstm_sequential(p["slstm"], h, self.H, state)
        x = x + hh
        h = L.rmsnorm(p["ffn_norm"], x, cfg.norm_eps)
        return x + L.swiglu(p["ffn"], h), state

    def _apply_unit(self, unit_p, x, states=None, decode=False):
        new_states = {}
        for i, kind in enumerate(self.unit):
            name = f"{kind}_{i}"
            st = states[name] if states else None
            fn = self._mlstm_block if kind == "mlstm" else self._slstm_block
            x, new_states[name] = fn(unit_p[name], x, st, decode)
        return x, new_states

    # ------------------------------------------------------------- forward

    def _states_init(self, batch: int):
        cfg = self.cfg
        d = cfg.d_model
        pf = cfg.hybrid.mlstm_proj_factor
        d_in = int(d * pf)
        d_in -= d_in % self.H
        Dh_m = d_in // self.H

        def unit_states():
            out = {}
            for i, kind in enumerate(self.unit):
                out[f"{kind}_{i}"] = (
                    mlstm_state_init(batch, self.H, Dh_m) if kind == "mlstm"
                    else slstm_state_init(batch, d, self.H))
            return out

        states = {"units": jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[unit_states() for _ in range(self.n_units)])} \
            if self.n_units else {"units": {}}
        if self.tail:
            states["tail"] = [
                mlstm_state_init(batch, self.H, Dh_m) if kind == "mlstm"
                else slstm_state_init(batch, d, self.H)
                for kind in self.tail]
        states["pos"] = jnp.zeros((), jnp.int32)
        return states

    def forward(self, params, tokens, img_embeds=None):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.adtype)
        x = self._constrain(x)

        def body(xx, up):
            xx, _ = self._apply_unit(up, xx)
            return xx, None

        x, _ = lax.scan(body, x, params["units"])
        for kind, p in zip(self.tail, params.get("tail", [])):
            fn = self._mlstm_block if kind == "mlstm" else self._slstm_block
            x, _ = fn(p, x)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = (x @ params["embed"].T).astype(jnp.dtype(cfg.logits_dtype))
        return logits, jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch["tokens"])
        ce = L.cross_entropy_loss(logits, batch["labels"])
        return ce, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------- serving

    def init_cache(self, batch: int, max_len: int):
        # recurrent state only — independent of max_len (that's the point)
        return self._states_init(batch)

    def prefill(self, params, tokens, img_embeds=None,
                max_len: int | None = None):
        cfg = self.cfg
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.adtype)

        def body(xx, xs):
            up, st = xs
            xx, st = self._apply_unit(up, xx, st)
            return xx, st

        states = self._states_init(B)
        if self.n_units:
            x, unit_states = lax.scan(body, x,
                                      (params["units"], states["units"]))
        else:
            unit_states = states["units"]
        new = {"units": unit_states, "pos": jnp.asarray(S, jnp.int32)}
        if self.tail:
            new["tail"] = []
            for kind, p, st in zip(self.tail, params["tail"],
                                   states["tail"]):
                fn = (self._mlstm_block if kind == "mlstm"
                      else self._slstm_block)
                x, st = fn(p, x, st)
                new["tail"].append(st)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = (x[:, -1:] @ params["embed"].T).astype(
            jnp.dtype(cfg.logits_dtype))[:, 0]
        return logits, new

    def decode_step(self, params, token, caches):
        cfg = self.cfg
        x = jnp.take(params["embed"], token, axis=0).astype(self.adtype)

        def body(xx, xs):
            up, st = xs
            xx, st = self._apply_unit(up, xx, st, decode=True)
            return xx, st

        new = dict(caches)
        if self.n_units:
            x, new["units"] = lax.scan(body, x,
                                       (params["units"], caches["units"]))
        if self.tail:
            new["tail"] = []
            for kind, p, st in zip(self.tail, params["tail"], caches["tail"]):
                fn = (self._mlstm_block if kind == "mlstm"
                      else self._slstm_block)
                x, st = fn(p, x, st, decode=True)
                new["tail"].append(st)
        new["pos"] = caches["pos"] + 1
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = (x @ params["embed"].T).astype(
            jnp.dtype(cfg.logits_dtype))[:, 0]
        return logits, new

    def cache_specs(self, batch: int, max_len: int):
        from .sharding import batch_only_specs
        shapes = jax.eval_shape(lambda: self.init_cache(batch, max_len))
        return batch_only_specs(shapes, self.plan, self.mesh, batch)

    # --------------------------------------------------------- input specs

    def input_specs(self, shape: ShapeConfig) -> dict:
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                    "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        caches = jax.eval_shape(lambda: self.init_cache(B, S))
        return {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "caches": caches}
