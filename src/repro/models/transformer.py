"""Decoder-only transformer LM covering the dense / moe / vlm families.

Supports: GQA (+qk-norm, +QKV-bias), RoPE, SwiGLU FFN, sliding-window
attention (Mixtral), MoE FFN (dispatch / expert-parallel), VLM prefix
(precomputed patch embeddings — frontend stub per assignment), scan-over-
layers with optional remat, ring-buffer KV caches for serving.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional

import jax

from repro.compat import shard_map
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from . import layers as L
from . import moe as M
from .sharding import MeshPlan, activation_spec, build_param_specs

AUX_LOSS_WEIGHT = 0.01


class DecoderLM:
    """Functional decoder LM; all state lives in explicit pytrees."""

    def __init__(self, cfg: ModelConfig, run: RunConfig | None = None,
                 mesh: Mesh | None = None, plan: MeshPlan | None = None):
        self.cfg = cfg
        self.run = run or RunConfig()
        self.mesh = mesh
        self.plan = plan or MeshPlan()
        self.dtype = jnp.dtype(cfg.param_dtype)
        self.adtype = jnp.dtype(cfg.activation_dtype)

    # ------------------------------------------------------------- helpers

    def _constrain(self, x, spec: P):
        if self.mesh is not None:
            return lax.with_sharding_constraint(
                x, NamedSharding(self.mesh, spec))
        return x

    @property
    def _n_moe_layers(self) -> int:
        if self.cfg.moe is None:
            return 0
        return self.cfg.n_layers - self.cfg.moe.first_k_dense

    @property
    def _n_dense_layers(self) -> int:
        if self.cfg.moe is None:
            return self.cfg.n_layers
        return self.cfg.moe.first_k_dense

    # ---------------------------------------------------------------- init

    def _dense_block_init(self, key):
        cfg, dt = self.cfg, self.dtype
        ks = jax.random.split(key, 2)
        return {
            "attn_norm": L.rmsnorm_init(cfg.d_model, dt),
            "attn": L.mha_init(ks[0], cfg, dt),
            "ffn_norm": L.rmsnorm_init(cfg.d_model, dt),
            "ffn": L.swiglu_init(ks[1], cfg.d_model, cfg.d_ff, dt),
        }

    def _moe_block_init(self, key):
        cfg, dt = self.cfg, self.dtype
        ks = jax.random.split(key, 2)
        return {
            "attn_norm": L.rmsnorm_init(cfg.d_model, dt),
            "attn": L.mha_init(ks[0], cfg, dt),
            "ffn_norm": L.rmsnorm_init(cfg.d_model, dt),
            "moe": M.moe_init(ks[1], cfg, dt),
        }

    def init(self, key):
        cfg, dt = self.cfg, self.dtype
        ks = jax.random.split(key, 4)
        params = {"embed": L.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
                  "final_norm": L.rmsnorm_init(cfg.d_model, dt)}
        if not cfg.tie_embeddings:
            params["unembed"] = L.dense_init(
                ks[1], (cfg.d_model, cfg.vocab_size), dt)
        if self._n_dense_layers and cfg.moe is not None:
            params["dense_layers"] = L.stack_layer_params(
                self._dense_block_init, ks[2], self._n_dense_layers)
        if cfg.moe is not None:
            params["layers"] = L.stack_layer_params(
                self._moe_block_init, ks[3], self._n_moe_layers)
        else:
            params["layers"] = L.stack_layer_params(
                self._dense_block_init, ks[3], cfg.n_layers)
        return params

    def param_shapes(self):
        return jax.eval_shape(
            lambda: self.init(jax.random.PRNGKey(0)))

    def param_specs(self):
        return build_param_specs(self.param_shapes(), self.plan, self.mesh)

    def param_count(self) -> int:
        return sum(int(np.prod(l.shape))
                   for l in jax.tree.leaves(self.param_shapes()))

    def active_param_count(self) -> int:
        total = self.param_count()
        if self.cfg.moe is None:
            return total
        shapes = self.param_shapes()
        expert = sum(
            int(np.prod(l.shape)) for l in
            jax.tree.leaves(shapes["layers"]["moe"]["experts"]))
        m = self.cfg.moe
        return total - expert + int(expert * m.top_k / m.n_experts)

    # ------------------------------------------------------------- blocks

    def _ffn_apply(self, p, x, *, decode: bool = False):
        """Returns (y, aux_loss)."""
        if "ffn" in p:
            return L.swiglu(p["ffn"], x), jnp.zeros((), jnp.float32)
        # MoE
        S = x.shape[1]
        divisible = (self.mesh is not None and not decode
                     and x.shape[0] % self._dp_size() == 0
                     and self._ep_size() > 1)
        use_ep = (divisible and self.run.ep_moe
                  and S % self._ep_size() == 0
                  and self.cfg.moe.n_experts % self._ep_size() == 0)
        if use_ep:
            return self._moe_ep(p["moe"], x)
        if divisible and self.run.moe_tp_f and not self.plan.sp:
            return self._moe_tp_f(p["moe"], x)
        return M.moe_ffn_dispatch(p["moe"], x, self.cfg)

    def _ep_size(self) -> int:
        return self.mesh.shape[self.plan.ep] if self.mesh else 1

    def _dp_size(self) -> int:
        if not self.mesh:
            return 1
        n = 1
        for a in (self.plan.batch if isinstance(self.plan.batch, tuple)
                  else (self.plan.batch,)):
            n *= self.mesh.shape[a]
        return n

    def _moe_ep(self, p, x):
        """shard_map-wrapped expert-parallel MoE (DESIGN.md §3.1).

        Two FSDP treatments of the expert weights:
        * default (ZeRO-3): weights sharded on the fsdp axis along d_model,
          all-gathered per use;
        * weight-stationary (run.moe_weight_stationary): weights sharded
          along the FFN-hidden dim, never gathered — the down-projection's
          partial sums are psum'd instead (activation bytes << weight
          bytes for large experts; §Perf hillclimb)."""
        plan = self.plan
        dp = plan.batch_axes
        ws = self.run.moe_weight_stationary and plan.fsdp is not None
        x_spec = P(dp, plan.ep, None)             # tokens: B over dp, S over ep
        if ws:
            expert_spec = {
                "w_gate": P(plan.ep, None, plan.fsdp),
                "w_up": P(plan.ep, None, plan.fsdp),
                "w_down": P(plan.ep, plan.fsdp, None),
            }
        else:
            expert_spec = {
                "w_gate": P(plan.ep, plan.fsdp, None),
                "w_up": P(plan.ep, plan.fsdp, None),
                "w_down": P(plan.ep, None, plan.fsdp),
            }
        p_specs = {"router": P(None, None), "experts": expert_spec}
        if "shared" in p:
            p_specs["shared"] = {k: P(None, None) for k in p["shared"]}

        fsdp = plan.fsdp

        def body(pp, xx):
            if fsdp is not None and not ws:
                # ZeRO-3: gather sharded expert weights before the GEMMs
                pp = dict(pp)
                pp["experts"] = {
                    "w_gate": lax.all_gather(pp["experts"]["w_gate"], fsdp,
                                             axis=1, tiled=True),
                    "w_up": lax.all_gather(pp["experts"]["w_up"], fsdp,
                                           axis=1, tiled=True),
                    "w_down": lax.all_gather(pp["experts"]["w_down"], fsdp,
                                             axis=2, tiled=True),
                }
            y, aux = M.moe_ffn_ep(pp, xx, self.cfg, plan.ep,
                                  partial_ffn_axis=fsdp if ws else None)
            aux = lax.pmean(aux, plan.batch_axes)
            return y, aux

        y, aux = shard_map(
            body, mesh=self.mesh,
            in_specs=(p_specs, x_spec),
            out_specs=(x_spec, P()),
            check_vma=False,
        )(p, x)
        return y, aux

    def _moe_tp_f(self, p, x):
        """shard_map-wrapped TP-f MoE for few-expert archs (Mixtral)."""
        plan = self.plan
        dp = plan.batch_axes
        x_spec = P(dp, None, None)      # tokens replicated across tp
        p_specs = {
            "router": P(None, None),
            "experts": {
                "w_gate": P(None, plan.fsdp, plan.tp),
                "w_up": P(None, plan.fsdp, plan.tp),
                "w_down": P(None, plan.tp, plan.fsdp),
            },
        }
        if "shared" in p:
            p_specs["shared"] = {k: P(None, None) for k in p["shared"]}

        def body(pp, xx):
            y, aux = M.moe_ffn_tp_f(pp, xx, self.cfg, plan.tp,
                                    fsdp_axis=plan.fsdp)
            return y, lax.pmean(aux, plan.batch_axes)

        return shard_map(
            body, mesh=self.mesh, in_specs=(p_specs, x_spec),
            out_specs=(x_spec, P()), check_vma=False)(p, x)

    def _block(self, p, x, positions, *, window):
        h = L.rmsnorm(p["attn_norm"], x, self.cfg.norm_eps)
        h = L.self_attention(p["attn"], h, self.cfg, positions,
                             causal=True, window=window)
        x = x + h
        h = L.rmsnorm(p["ffn_norm"], x, self.cfg.norm_eps)
        h, aux = self._ffn_apply(p, h)
        x = x + h
        x = self._constrain(x, activation_spec(self.plan))
        return x, aux

    def _block_decode(self, p, x, cache, pos, *, window):
        h = L.rmsnorm(p["attn_norm"], x, self.cfg.norm_eps)
        h, cache = L.self_attention_decode(p["attn"], h, self.cfg, cache, pos,
                                           window=window)
        x = x + h
        h = L.rmsnorm(p["ffn_norm"], x, self.cfg.norm_eps)
        h, _ = self._ffn_apply(p, h, decode=True)
        return x + h, cache

    def _scan_blocks(self, stacked, x, positions, *, window):
        block = functools.partial(self._block, window=window)
        if self.run.remat != "none":
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if self.run.remat == "dots" else None)
            block = jax.checkpoint(block, policy=policy)

        def body(carry, lp):
            xx, aux = carry
            xx, a = block(lp, xx, positions)
            return (xx, aux + a), None

        (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)), stacked)
        return x, aux

    # ------------------------------------------------------------ forward

    def _embed_tokens(self, params, tokens):
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.adtype)
        return x

    def _assemble_input(self, params, tokens, img_embeds=None):
        x = self._embed_tokens(params, tokens)
        if self.cfg.vlm is not None:
            if img_embeds is None:
                raise ValueError("vlm model requires img_embeds")
            x = jnp.concatenate([img_embeds.astype(self.adtype), x], axis=1)
        return x

    def forward(self, params, tokens, img_embeds=None):
        """Training/prefill forward over the full sequence -> logits (B,S,V).

        For VLM the returned logits cover only the text positions."""
        cfg = self.cfg
        x = self._assemble_input(params, tokens, img_embeds)
        B, S, _ = x.shape
        x = self._constrain(x, activation_spec(self.plan))
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        aux = jnp.zeros((), jnp.float32)
        if "dense_layers" in params:
            x, a = self._scan_blocks(params["dense_layers"], x, positions,
                                     window=cfg.sliding_window)
            aux += a
        x, a = self._scan_blocks(params["layers"], x, positions,
                                 window=cfg.sliding_window)
        aux += a
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if cfg.vlm is not None:
            x = x[:, self.cfg.vlm.n_image_tokens:]
        logits = self._unembed(params, x)
        return logits, aux

    def _unembed(self, params, x):
        w = params["embed"].T if self.cfg.tie_embeddings else params["unembed"]
        logits = (x @ w).astype(jnp.dtype(self.cfg.logits_dtype))
        return logits

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch["tokens"],
                                   batch.get("img_embeds"))
        ce = L.cross_entropy_loss(logits, batch["labels"],
                                  batch.get("valid"))
        total = ce + AUX_LOSS_WEIGHT * aux
        return total, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------ serving

    def cache_capacity(self, max_len: int) -> int:
        if self.cfg.sliding_window is not None:
            return min(max_len, self.cfg.sliding_window)
        return max_len

    def init_cache(self, batch: int, max_len: int):
        cap = self.cache_capacity(max_len)
        nl = self.cfg.n_layers if self.cfg.moe is None else self._n_moe_layers
        caches = {"layers": L.make_kv_cache(self.cfg, batch, cap, self.adtype,
                                            n_layers=nl),
                  "pos": jnp.zeros((), jnp.int32)}
        if self.cfg.moe is not None and self._n_dense_layers:
            caches["dense_layers"] = L.make_kv_cache(
                self.cfg, batch, cap, self.adtype,
                n_layers=self._n_dense_layers)
        return caches

    def prefill(self, params, tokens, img_embeds=None, max_len: int | None = None):
        """Run the prompt, build decode caches; returns (last_logits, caches)."""
        cfg = self.cfg
        x = self._assemble_input(params, tokens, img_embeds)
        B, S, _ = x.shape
        max_len = max_len or S
        cap = self.cache_capacity(max_len)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        def prefill_block(p, xx):
            h = L.rmsnorm(p["attn_norm"], xx, cfg.norm_eps)
            q, k, v = L.mha_project_qkv(p["attn"], h, cfg, positions)
            o = L.attention(q, k, v, positions, positions, causal=True,
                            window=cfg.sliding_window)
            xx = xx + L.mha_out(p["attn"], o, B, S)
            h = L.rmsnorm(p["ffn_norm"], xx, cfg.norm_eps)
            h, _ = self._ffn_apply(p, h)
            cache = L.make_kv_cache(cfg, B, cap, self.adtype)
            cache = L.cache_write_prefill(cache, k, v)
            return xx + h, cache

        def body(xx, lp):
            xx, cache = prefill_block(lp, xx)
            return xx, cache

        caches = {}
        if "dense_layers" in params:
            x, caches["dense_layers"] = lax.scan(body, x,
                                                 params["dense_layers"])
        x, caches["layers"] = lax.scan(body, x, params["layers"])
        caches["pos"] = jnp.asarray(S, jnp.int32)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = self._unembed(params, x[:, -1:])[:, 0]
        return logits, caches

    def decode_step(self, params, token, caches):
        """token (B,1) int32 -> (logits (B,V), new caches)."""
        cfg = self.cfg
        x = self._embed_tokens(params, token)
        pos = caches["pos"]
        window = cfg.sliding_window

        def body(xx, layer):
            lp, cache = layer
            xx, cache = self._block_decode(lp, xx, cache, pos, window=window)
            return xx, cache

        new = dict(caches)
        if "dense_layers" in params:
            x, new["dense_layers"] = lax.scan(
                body, x, (params["dense_layers"], caches["dense_layers"]))
        x, new["layers"] = lax.scan(
            body, x, (params["layers"], caches["layers"]))
        new["pos"] = pos + 1
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = self._unembed(params, x)[:, 0]
        return logits, new

    def cache_specs(self, batch: int, max_len: int):
        """PartitionSpec tree matching init_cache (for decode in_shardings)."""
        from .sharding import kv_cache_specs
        cap = self.cache_capacity(max_len)
        layer = kv_cache_specs(self.plan, self.mesh, batch, cap,
                               self.cfg.n_kv_heads)
        out = {"layers": dict(layer), "pos": P()}
        if self.cfg.moe is not None and self._n_dense_layers:
            out["dense_layers"] = dict(layer)
        return out

    # -------------------------------------------------------- input specs

    def input_specs(self, shape: ShapeConfig) -> dict:
        """ShapeDtypeStruct stand-ins for every model input (dry-run)."""
        B, S = shape.global_batch, shape.seq_len
        cfg = self.cfg
        f32 = jnp.float32
        if shape.kind == "train":
            n_img = cfg.vlm.n_image_tokens if cfg.vlm else 0
            d = {"tokens": jax.ShapeDtypeStruct((B, S - n_img), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((B, S - n_img), jnp.int32)}
            if cfg.vlm:
                d["img_embeds"] = jax.ShapeDtypeStruct(
                    (B, n_img, cfg.d_model), jnp.dtype(cfg.activation_dtype))
            return d
        if shape.kind == "prefill":
            n_img = cfg.vlm.n_image_tokens if cfg.vlm else 0
            d = {"tokens": jax.ShapeDtypeStruct((B, S - n_img), jnp.int32)}
            if cfg.vlm:
                d["img_embeds"] = jax.ShapeDtypeStruct(
                    (B, n_img, cfg.d_model), jnp.dtype(cfg.activation_dtype))
            return d
        # decode: one token with a cache of seq_len
        caches = jax.eval_shape(lambda: self.init_cache(B, S))
        return {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "caches": caches}
