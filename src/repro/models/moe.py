"""Mixture-of-Experts FFN.

Three execution paths, same math:

* ``moe_ffn_ref``      — O(E) python loop, no capacity drops.  Oracle for
                         tests (small E only).
* ``moe_ffn_dispatch`` — scatter-based capacity dispatch, single logical
                         device (jit/GSPMD).  Used for decode steps and
                         CPU smoke tests.
* ``moe_ffn_ep``       — expert-parallel production path: runs inside
                         ``shard_map``; local top-k routing -> local capacity
                         dispatch -> ``all_to_all`` over the EP axis ->
                         local expert GEMMs -> reverse ``all_to_all`` ->
                         weighted combine.  This is the all-to-all traffic
                         the paper's full-mesh HyperX dimensions serve well
                         (DESIGN.md §3); EP shard bytes feed the collective
                         roofline term.

Routing: softmax over experts, top-k, weights renormalized over the chosen k
(Mixtral-style).  A Switch-style load-balance auxiliary loss is returned by
each path.  Tokens beyond an expert's capacity are dropped (standard GShard
behaviour); the reference path never drops, and tests use capacity_factor
large enough that dispatch == ref.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import axis_size

from repro.configs.base import ModelConfig
from .layers import dense_init, swiglu, swiglu_init


def moe_init(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, m.n_experts), jnp.float32),
        "experts": {
            "w_gate": jax.vmap(
                lambda k: dense_init(k, (d, m.d_expert), dtype))(
                    jax.random.split(ks[1], m.n_experts)),
            "w_up": jax.vmap(
                lambda k: dense_init(k, (d, m.d_expert), dtype))(
                    jax.random.split(ks[2], m.n_experts)),
            "w_down": jax.vmap(
                lambda k: dense_init(k, (m.d_expert, d), dtype,
                                     in_axis_size=m.d_expert))(
                    jax.random.split(ks[3], m.n_experts)),
        },
    }
    if m.n_shared_experts:
        p["shared"] = swiglu_init(ks[4], d, m.d_expert * m.n_shared_experts,
                                  dtype)
    return p


def _route(router_w, x_flat, cfg: ModelConfig):
    """x_flat (T, d) -> (top_w (T,k) f32, top_i (T,k) i32, aux_loss f32)."""
    m = cfg.moe
    logits = (x_flat.astype(jnp.float32) @ router_w)            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = lax.top_k(probs, m.top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    E = m.n_experts
    f = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    f = f / jnp.maximum(top_i.size, 1)
    pbar = probs.mean(0)
    aux = E * jnp.sum(f * pbar)
    return top_w, top_i, aux


def _expert_ffn(experts, h):
    """h (E, C, d) -> (E, C, d) via per-expert SwiGLU (batched GEMMs)."""
    g = jnp.einsum("ecd,edf->ecf", h, experts["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", h, experts["w_up"])
    a = (jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)) * u
    return jnp.einsum("ecf,efd->ecd", a, experts["w_down"])


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    return max(1, math.ceil(n_tokens * m.top_k / m.n_experts
                            * m.capacity_factor))


# --------------------------------------------------------------------------
# reference (no drops, python loop over experts)
# --------------------------------------------------------------------------


def moe_ffn_ref(p, x, cfg: ModelConfig):
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    top_w, top_i, aux = _route(p["router"], xf, cfg)
    y = jnp.zeros_like(xf, dtype=jnp.float32)
    for e in range(cfg.moe.n_experts):
        w_e = jnp.sum(top_w * (top_i == e), axis=-1)            # (T,)
        ex = {k: v[e] for k, v in p["experts"].items()}
        h = swiglu({"w_gate": ex["w_gate"], "w_up": ex["w_up"],
                    "w_down": ex["w_down"]}, xf)
        y = y + w_e[:, None] * h.astype(jnp.float32)
    y = y.astype(x.dtype)
    if "shared" in p:
        y = y + swiglu(p["shared"], xf)
    return y.reshape(B, S, d), aux


# --------------------------------------------------------------------------
# scatter dispatch (single logical device / GSPMD)
# --------------------------------------------------------------------------


def _dispatch(xf, top_w, top_i, E: int, C: int):
    """Pack routed tokens into (E, C, d) buffers.

    Returns (buf, eid, pos, keep, wflat):
      eid/pos/keep/wflat are (T*k,) routing records for the combine step.
    """
    T, d = xf.shape
    k = top_i.shape[1]
    eid = top_i.reshape(-1)                                     # (T*k,)
    wflat = top_w.reshape(-1)
    # position of each (token, slot) within its expert's capacity buffer:
    # rank among earlier records routed to the same expert.
    onehot = jax.nn.one_hot(eid, E, dtype=jnp.int32)            # (T*k, E)
    pos = (jnp.cumsum(onehot, axis=0) - 1)                      # (T*k, E)
    pos = jnp.take_along_axis(pos, eid[:, None], axis=1)[:, 0]  # (T*k,)
    keep = pos < C
    x_rep = jnp.repeat(xf, k, axis=0)                           # (T*k, d)
    safe_e = jnp.where(keep, eid, 0)
    safe_p = jnp.where(keep, pos, 0)
    buf = jnp.zeros((E, C, d), xf.dtype)
    buf = buf.at[safe_e, safe_p].add(
        jnp.where(keep[:, None], x_rep, 0).astype(xf.dtype))
    return buf, eid, pos, keep, wflat


def _combine(h, eid, pos, keep, wflat, T: int, k: int):
    """Gather expert outputs back to tokens and weight-sum over k slots."""
    safe_e = jnp.where(keep, eid, 0)
    safe_p = jnp.where(keep, pos, 0)
    y_rep = h[safe_e, safe_p]                                   # (T*k, d)
    y_rep = jnp.where(keep[:, None], y_rep, 0)
    y_rep = y_rep * wflat[:, None].astype(y_rep.dtype)
    return y_rep.reshape(T, k, -1).sum(axis=1)


def moe_ffn_dispatch(p, x, cfg: ModelConfig,
                     capacity: Optional[int] = None):
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    T = xf.shape[0]
    m = cfg.moe
    C = capacity or _capacity(T, cfg)
    top_w, top_i, aux = _route(p["router"], xf, cfg)
    buf, eid, pos, keep, wflat = _dispatch(xf, top_w, top_i, m.n_experts, C)
    h = _expert_ffn(p["experts"], buf)
    y = _combine(h, eid, pos, keep, wflat, T, m.top_k).astype(x.dtype)
    if "shared" in p:
        y = y + swiglu(p["shared"], xf)
    return y.reshape(B, S, d), aux


# --------------------------------------------------------------------------
# tensor-parallel-f MoE (inside shard_map) — for E < TP degree (Mixtral)
# --------------------------------------------------------------------------


def moe_ffn_tp_f(p, x, cfg: ModelConfig, tp_axis: str,
                 fsdp_axis=None, capacity: Optional[int] = None):
    """Megatron-style MoE for few-expert models: call INSIDE shard_map.

    x: (B_loc, S, d) — batch sharded on the dp axes, REPLICATED across
    ``tp_axis`` (the non-sequence-parallel activation layout).  Experts
    keep all E locally but shard the FFN-hidden dim over ``tp_axis``
    (stored spec P(None, fsdp, tp) / P(None, tp, fsdp)); dispatch is fully
    local, the down-projection's f-partials are psum'd over tp — exact
    because every tp shard holds identical tokens.  Replaces the
    partitioner's (E, C_global, d) dispatch-buffer all-reduces (~8.8 TiB
    per step on mixtral train_4k) with one (E, C_loc, d) psum per call.
    """
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    T = xf.shape[0]
    m = cfg.moe
    C = capacity or _capacity(T, cfg)
    top_w, top_i, aux = _route(p["router"], xf, cfg)
    buf, eid, pos, keep, wflat = _dispatch(xf, top_w, top_i, m.n_experts, C)
    experts = p["experts"]
    if fsdp_axis is not None:
        # ZeRO gather of the d-model dim only (the f dim stays tp-sharded)
        experts = {
            "w_gate": lax.all_gather(experts["w_gate"], fsdp_axis, axis=1,
                                     tiled=True),
            "w_up": lax.all_gather(experts["w_up"], fsdp_axis, axis=1,
                                   tiled=True),
            "w_down": lax.all_gather(experts["w_down"], fsdp_axis, axis=2,
                                     tiled=True),
        }
    h = _expert_ffn(experts, buf)          # (E, C, d), partial over f-shards
    h = lax.psum(h, tp_axis)
    y = _combine(h, eid, pos, keep, wflat, T, m.top_k).astype(x.dtype)
    aux = lax.pmean(aux, tp_axis)
    if "shared" in p:
        y = y + swiglu(p["shared"], xf)
    return y.reshape(B, S, d), aux


# --------------------------------------------------------------------------
# expert-parallel (inside shard_map)
# --------------------------------------------------------------------------


def moe_ffn_ep(p, x, cfg: ModelConfig, ep_axis: str,
               capacity: Optional[int] = None,
               partial_ffn_axis: Optional[str] = None):
    """Expert-parallel MoE FFN — call INSIDE shard_map.

    x: local tokens (B_loc, S_loc, d).
    p["experts"]: local expert shard, leaves (E_loc, ...).
    p["router"]/p["shared"]: replicated.

    The EP axis carries two all-to-alls of (E, C_loc, d) bytes per call —
    this is the collective the MPHX mapping optimizes (core/mapping.py).

    ``partial_ffn_axis``: weight-stationary mode — expert weights arrive
    sharded on the FFN-hidden dim over this axis and are NEVER gathered.
    Since tokens differ across that axis, the dispatch buffer is
    all-gathered over it first (every shard sees every shard's tokens for
    its local experts), each shard computes its f-slice of the FFN, and the
    partial outputs are reduce-scattered back to the owning shard.  Trades
    per-use expert-weight all-gathers (ZeRO-3) for activation
    gather+scatter — a win whenever token bytes < expert-weight bytes
    (EXPERIMENTS.md §Perf).
    """
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    T = xf.shape[0]
    m = cfg.moe
    ep = axis_size(ep_axis)
    E_loc = m.n_experts // ep
    if E_loc * ep != m.n_experts:
        raise ValueError(f"{m.n_experts} experts not divisible by EP={ep}")
    C = capacity or _capacity(T, cfg)

    top_w, top_i, aux = _route(p["router"], xf, cfg)
    buf, eid, pos, keep, wflat = _dispatch(xf, top_w, top_i, m.n_experts, C)
    # (E, C, d) -> (ep, E_loc, C, d) -> exchange: each peer receives the
    # slice of my buffer destined for its experts; afterwards axis 0 indexes
    # the SOURCE shard.
    buf = buf.reshape(ep, E_loc, C, d)
    buf = lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=0, tiled=False)
    # local experts see tokens from every source shard
    buf = buf.transpose(1, 0, 2, 3).reshape(E_loc, ep * C, d)
    if partial_ffn_axis is not None:
        # weight-stationary: gather every fsdp-shard's tokens, compute the
        # local f-slice for all of them, reduce-scatter outputs back so
        # each shard keeps full-FFN results for its OWN tokens.
        buf = lax.all_gather(buf, partial_ffn_axis, axis=1, tiled=True)
        h = _expert_ffn(p["experts"], buf)      # partial over the f dim
        h = lax.psum_scatter(h, partial_ffn_axis, scatter_dimension=1,
                             tiled=True)        # (E_loc, ep*C, d), exact
    else:
        h = _expert_ffn(p["experts"], buf)                      # (E_loc, ep*C, d)
    # reverse exchange: axis 0 = destination (source) shard
    h = h.reshape(E_loc, ep, C, d).transpose(1, 0, 2, 3)
    h = lax.all_to_all(h, ep_axis, split_axis=0, concat_axis=0, tiled=False)
    # axis 0 = expert-owner shard -> global expert index order
    h = h.reshape(m.n_experts, C, d)
    y = _combine(h, eid, pos, keep, wflat, T, m.top_k).astype(x.dtype)
    # aux loss: average over EP shards (tokens differ per shard)
    aux = lax.pmean(aux, ep_axis)
    if "shared" in p:
        y = y + swiglu(p["shared"], xf)
    return y.reshape(B, S, d), aux
