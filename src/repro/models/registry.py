"""Model factory: family -> model class, and the arch-config registry."""

from __future__ import annotations

import importlib
from typing import Any

from repro.configs.base import ModelConfig, RunConfig

ARCH_IDS = [
    "kimi-k2-1t-a32b",
    "mixtral-8x22b",
    "phi3-medium-14b",
    "qwen3-32b",
    "yi-9b",
    "qwen1.5-32b",
    "llava-next-34b",
    "whisper-small",
    "xlstm-125m",
    "recurrentgemma-2b",
]


def _module_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    """Load ``repro/configs/<arch>.py`` and return CONFIG (or smoke())."""
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch '{arch_id}'; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_module_name(arch_id)}")
    return mod.smoke() if smoke else mod.CONFIG


def get_model(cfg: ModelConfig, run: RunConfig | None = None,
              mesh=None, plan=None) -> Any:
    from .transformer import DecoderLM
    from .encdec import EncDecLM
    from .xlstm import XLSTMModel
    from .rglru import RGLRUModel

    if cfg.family in ("dense", "moe", "vlm"):
        return DecoderLM(cfg, run, mesh, plan)
    if cfg.family == "audio":
        return EncDecLM(cfg, run, mesh, plan)
    if cfg.family == "ssm":
        return XLSTMModel(cfg, run, mesh, plan)
    if cfg.family == "hybrid":
        return RGLRUModel(cfg, run, mesh, plan)
    raise KeyError(f"unknown family {cfg.family}")


def supported_shapes(cfg: ModelConfig) -> list[str]:
    """Which of the 4 LM shape cells this arch runs (spec: skip long_500k
    for pure full-attention archs; note in DESIGN.md §Arch-applicability)."""
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        shapes.append("long_500k")
    return shapes
