"""Parameter / activation sharding rules.

The logical mesh always has a tensor-parallel axis ``model`` and one or two
data axes (``data`` or ``("pod", "data")``).  Rules:

* TP (``model``): attention head projections, FFN hidden, vocab, experts (EP)
* FSDP/ZeRO-3 (``data``): the other large matrix dimension of every weight
* DP batch: ``("pod", "data")`` — the pod axis carries only data parallelism
  (gradient all-reduce over DCN), never parameter shards, so cross-pod
  traffic stays small (DESIGN.md §5).

Specs are built *by path pattern* over the param pytree from
``jax.eval_shape``, so models never hand-maintain a parallel spec tree.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class MeshPlan:
    """Names of the logical axes in the active mesh."""

    tp: str = "model"
    fsdp: str | tuple | None = "data"  # None disables ZeRO param sharding
    batch: tuple[str, ...] = ("data",)  # axes carrying the batch dim
    ep: str = "model"                  # expert-parallel axis
    sp: bool = False                   # sequence-parallel activations
    moe_ws: bool = False               # weight-stationary expert sharding
                                       # (FFN dim over fsdp, no per-use AG)

    @property
    def batch_axes(self):
        return self.batch if len(self.batch) > 1 else self.batch[0]


SINGLE_POD = MeshPlan(batch=("data",))
MULTI_POD = MeshPlan(batch=("pod", "data"))


# (path regex, spec builder) — first match wins; rank refers to the leaf
# WITHOUT the stacked (L,) prefix, which is re-added automatically.
def _rules(plan: MeshPlan):
    tp, fs = plan.tp, plan.fsdp
    return [
        # vocab dim unsharded: a sharded-vocab gather forces the SPMD
        # partitioner into full rematerialization (replicate-then-reshard);
        # d-only sharding keeps the token gather local.  The unembed
        # projection still gets TP on the vocab dim.
        (r"embed$",                 lambda r: P(None, fs)),
        (r"unembed$",               lambda r: P(fs, tp)),
        (r"attn.*(wq|wk|wv)$",      lambda r: P(fs, tp)),
        (r"attn.*wo$",              lambda r: P(tp, fs)),
        (r"attn.*(bq|bk|bv)$",      lambda r: P(tp)),
        (r"(router)$",              lambda r: P(fs, None)),
        (r"experts.*(w_gate|w_up)$",
         lambda r: P(tp, None, fs) if plan.moe_ws else P(tp, fs, None)),
        (r"experts.*w_down$",
         lambda r: P(tp, fs, None) if plan.moe_ws else P(tp, None, fs)),
        (r"(shared|ffn|mlp).*(w_gate|w_up|w1)$", lambda r: P(fs, tp)),
        (r"(shared|ffn|mlp).*(w_down|w2)$",      lambda r: P(tp, fs)),
        (r"(ffn|mlp).*b1$",         lambda r: P(tp)),
        (r"(ffn|mlp).*b2$",         lambda r: P(None)),
        # recurrent blocks (xLSTM / RG-LRU): project d -> width
        (r"(rec|lru|mlstm|slstm).*(w_in|wi|wq|wk|wv|w_gates|wx|wy)$",
         lambda r: P(fs, tp) if r == 2 else P(None)),
        (r"(rec|lru|mlstm|slstm).*(w_out|wo)$",
         lambda r: P(tp, fs) if r == 2 else P(None)),
        (r".*",                     lambda r: P(*([None] * r))),
    ]


def path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def build_param_specs(param_shapes, plan: MeshPlan, mesh: Mesh | None = None,
                      stacked_prefixes: tuple[str, ...] = ("layers",
                                                           "dense_layers",
                                                           "units",
                                                           "enc_layers",
                                                           "dec_layers")):
    """param_shapes: pytree of ShapeDtypeStruct (from jax.eval_shape(init)).

    Returns a matching pytree of PartitionSpec.  Leaves under a stacked
    prefix get a leading ``None`` for the (L, ...) axis.  With ``mesh``
    given, expert weights whose E dim does not divide the TP axis (e.g.
    Mixtral's 8 experts on a 16-way axis) shard the FFN dim on TP instead —
    otherwise they would end up replicated over TP and blow HBM.
    """
    rules = _rules(plan)
    tp_size = mesh.shape[plan.tp] if mesh is not None else None

    def expert_spec(name: str, dims) -> P:
        tp, fs = plan.tp, plan.fsdp
        e_ok = tp_size is None or dims[0] % tp_size == 0
        if name == "w_down":
            if e_ok:
                return P(tp, fs, None) if plan.moe_ws else P(tp, None, fs)
            return P(None, tp, fs)
        if e_ok:
            return P(tp, None, fs) if plan.moe_ws else P(tp, fs, None)
        return P(None, fs, tp)

    def spec_for(path, leaf):
        s = path_str(path)
        stacked = any(pfx in s.split("/") for pfx in stacked_prefixes)
        rank = leaf.ndim - (1 if stacked else 0)
        dims = leaf.shape[1:] if stacked else leaf.shape
        m_exp = re.search(r"experts.*(w_gate|w_up|w_down)$", s)
        if m_exp:
            spec = expert_spec(m_exp.group(1), dims)
            parts = list(spec)
            if stacked:
                parts = [None] + parts
            return P(*parts)
        for pat, fn in rules:
            if re.search(pat, s):
                spec = fn(rank)
                # pad/trim to rank
                parts = list(spec) + [None] * (rank - len(spec))
                parts = parts[:rank]
                # drop axis names on dims too small to shard meaningfully:
                # leave 1-d tiny vectors replicated
                if rank <= 1 and leaf.size < 1 << 14:
                    parts = [None] * rank
                if stacked:
                    parts = [None] + parts
                return P(*parts)
        raise AssertionError("unreachable")

    return jax.tree_util.tree_map_with_path(spec_for, param_shapes)


def named_shardings(mesh: Mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def axes_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def shardable(mesh: Mesh, axes, dim_size: int):
    """Return the axes name(s) if dim_size divides evenly, else None."""
    return axes if dim_size % axes_size(mesh, axes) == 0 else None


def kv_cache_specs(plan: MeshPlan, mesh: Mesh, batch: int, capacity: int,
                   n_kv_heads: int, stacked: bool = True):
    """PartitionSpec for a ring KV cache {k,v:(L,B,cap,K,Dh), kv_pos:(L,cap)}.

    Preference order for the big k/v tensors: shard KV heads on tp (local
    cache update), else the capacity dim, else batch-only."""
    b_ax = shardable(mesh, plan.batch_axes, batch)
    tp = plan.tp
    if n_kv_heads % mesh.shape[tp] == 0:
        kv = (None, b_ax, None, tp, None)
    elif capacity % mesh.shape[tp] == 0:
        kv = (None, b_ax, tp, None, None)
    else:
        kv = (None, b_ax, None, None, None)
    if not stacked:
        kv = kv[1:]
    kvp = (None, None) if stacked else (None,)
    return {"k": P(*kv), "v": P(*kv), "kv_pos": P(*kvp)}


def sanitize_specs(shapes, specs, mesh: Mesh):
    """Drop axis names from dims the mesh axes don't divide (explicit
    in_shardings require divisibility; e.g. xLSTM's (.., 2H=8) gate dims
    cannot take the 16-way model axis)."""
    def fix(shape_leaf, spec):
        parts = list(spec) + [None] * (shape_leaf.ndim - len(spec))
        out = []
        for dim, ax in zip(shape_leaf.shape, parts):
            if ax is None or dim % axes_size(mesh, ax) == 0:
                out.append(ax)
            else:
                out.append(None)
        return P(*out)

    return jax.tree.map(fix, shapes, specs,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def batch_only_specs(shapes, plan: MeshPlan, mesh: Mesh, batch: int,
                     batch_dim_of: int = 1):
    """Generic state specs: shard the batch dim where it matches, replicate
    everything else (used for the small recurrent states of ssm/hybrid)."""
    b_ax = shardable(mesh, plan.batch_axes, batch)

    def leaf_spec(l):
        parts = [None] * l.ndim
        for i, s in enumerate(l.shape):
            if s == batch and i <= batch_dim_of and l.ndim > 1:
                parts[i] = b_ax
                break
        return P(*parts)

    return jax.tree.map(leaf_spec, shapes)


def batch_spec(plan: MeshPlan, rank: int = 2) -> P:
    """Input batch (B, S, ...) sharding: B over batch axes."""
    return P(plan.batch_axes, *([None] * (rank - 1)))


def activation_spec(plan: MeshPlan) -> P:
    """(B, S, d) activations: batch-sharded; seq over tp if sequence-parallel."""
    if plan.sp:
        return P(plan.batch_axes, plan.tp, None)
    return P(plan.batch_axes, None, None)
