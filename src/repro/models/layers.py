"""Shared neural-net layers (pure JAX, functional).

Conventions
-----------
* Params are nested dicts of ``jnp.ndarray``; layer stacks store each leaf
  with a leading ``(L, ...)`` axis and run under ``jax.lax.scan``.
* Activations: ``x`` is ``(B, S, d_model)``.
* Attention is GQA throughout: ``n_heads`` query heads grouped over
  ``n_kv_heads`` KV heads; supports causal masks, sliding windows, KV caches
  (decode), bidirectional (encoder), qk-norm (Qwen3) and QKV bias (Qwen1.5).
* Long sequences use a memory-bounded chunked attention (online softmax over
  KV blocks inside a scan over Q blocks) — same math as the reference, peak
  memory O(S * block) instead of O(S^2).  The Pallas flash-attention kernel
  in ``repro.kernels`` is the TPU-optimized version of the same schedule.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig

Params = Any  # nested dict pytree


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------


def dense_init(key, shape, dtype, in_axis_size: int | None = None):
    """Truncated-normal fan-in init (std = 1/sqrt(fan_in))."""
    fan_in = in_axis_size or shape[0]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, (vocab, d), jnp.float32)
            ).astype(dtype)


def stack_layer_params(init_one, key, n_layers: int):
    """Initialize ``n_layers`` identical layers with stacked (L, ...) leaves."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(init_one)(keys)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * lax.rsqrt(var + eps)
    out = x * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(dt)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (B, S, ..., Dh); positions: (B, S) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,Dh/2)
    # broadcast over head axes between S and Dh
    extra = x.ndim - 3
    for _ in range(extra):
        angles = angles[:, :, None]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    xf1 = x1.astype(jnp.float32)
    xf2 = x2.astype(jnp.float32)
    o1 = xf1 * cos - xf2 * sin
    o2 = xf2 * cos + xf1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention core
# --------------------------------------------------------------------------

NEG_INF = -1e30


def _build_mask(q_pos, kv_pos, causal: bool, window: Optional[int],
                kv_valid=None):
    """q_pos: (B,Sq) kv_pos: (B,Skv) -> bool (B,1,1,Sq,Skv) True=attend."""
    qp = q_pos[:, None, None, :, None]
    kp = kv_pos[:, None, None, None, :]
    m = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), dtype=bool)
    if causal:
        m &= kp <= qp
    if window is not None:
        m &= (qp - kp) < window
    if kv_valid is not None:
        m &= kv_valid[:, None, None, None, :]
    return m


def attention_ref(q, k, v, q_pos, kv_pos, *, causal=True,
                  window: Optional[int] = None, kv_valid=None):
    """Reference attention.

    q: (B, Sq, K, G, Dh)   — K kv-heads x G query groups
    k,v: (B, Skv, K, Dh)
    returns (B, Sq, K, G, Dh)
    """
    dh = q.shape[-1]
    scale = 1.0 / math.sqrt(dh)
    # bf16 operands, f32 MXU accumulation — never materializes an f32 copy
    # of K/V (with a stacked KV cache that copy costs 2x cache bytes/step)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    mask = _build_mask(q_pos, kv_pos, causal, window, kv_valid)  # (B,1,1,Sq,Skv)
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def attention_chunked(q, k, v, q_pos, kv_pos, *, causal=True,
                      window: Optional[int] = None, kv_valid=None,
                      q_block: int = 512, kv_block: int = 1024):
    """Memory-bounded attention: scan over Q blocks, inner scan over KV
    blocks with online softmax.  Equivalent to :func:`attention_ref`."""
    B, Sq, K, G, Dh = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(Dh)

    qb = min(q_block, Sq)
    kb = min(kv_block, Skv)
    n_qb = -(-Sq // qb)
    n_kb = -(-Skv // kb)
    pad_q = n_qb * qb - Sq
    pad_k = n_kb * kb - Skv

    if kv_valid is None:
        kv_valid = jnp.ones((B, Skv), dtype=bool)
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad_k)))
        kv_valid = jnp.pad(kv_valid, ((0, 0), (0, pad_k)))

    # (n_qb, B, qb, ...) blocks
    qs = q.reshape(B, n_qb, qb, K, G, Dh).swapaxes(0, 1)
    qps = q_pos.reshape(B, n_qb, qb).swapaxes(0, 1)
    ks = k.reshape(B, n_kb, kb, K, Dh).swapaxes(0, 1)
    vs = v.reshape(B, n_kb, kb, K, Dh).swapaxes(0, 1)
    kps = kv_pos.reshape(B, n_kb, kb).swapaxes(0, 1)
    kvs = kv_valid.reshape(B, n_kb, kb).swapaxes(0, 1)

    def q_step(_, qblk):
        qi, qp = qblk

        def kv_step(carry, kblk):
            m_run, l_run, acc = carry
            ki, vi, kp, kval = kblk
            s = jnp.einsum("bqkgd,bskd->bkgqs", qi, ki,
                           preferred_element_type=jnp.float32) * scale
            msk = _build_mask(qp, kp, causal, window, kval)  # (B,1,1,qb,kb)
            s = jnp.where(msk, s, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(vi.dtype), vi,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, K, G, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, qb), jnp.float32)
        a0 = jnp.zeros((B, K, G, qb, Dh), jnp.float32)
        (m_f, l_f, acc), _ = lax.scan(kv_step, (m0, l0, a0),
                                      (ks, vs, kps, kvs))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]   # (B,K,G,qb,Dh)
        return None, out.astype(q.dtype)

    _, outs = lax.scan(q_step, None, (qs, qps))          # (n_qb,B,K,G,qb,Dh)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, n_qb * qb, K, G, Dh)
    return out[:, :Sq]


def attention(q, k, v, q_pos, kv_pos, *, causal=True, window=None,
              kv_valid=None, force_chunked: bool | None = None):
    """Dispatch between reference and chunked attention by working-set size."""
    Sq, Skv = q.shape[1], k.shape[1]
    use_chunked = (Sq * Skv > (1 << 22)) if force_chunked is None \
        else force_chunked
    if use_chunked and Sq > 1:
        return attention_chunked(q, k, v, q_pos, kv_pos, causal=causal,
                                 window=window, kv_valid=kv_valid)
    return attention_ref(q, k, v, q_pos, kv_pos, causal=causal, window=window,
                         kv_valid=kv_valid)


# --------------------------------------------------------------------------
# multi-head attention layer (projections + rope + cache)
# --------------------------------------------------------------------------


def mha_init(key, cfg: ModelConfig, dtype, cross: bool = False):
    d = cfg.d_model
    H, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H * Dh), dtype),
        "wk": dense_init(ks[1], (d, K * Dh), dtype),
        "wv": dense_init(ks[2], (d, K * Dh), dtype),
        "wo": dense_init(ks[3], (H * Dh, d), dtype, in_axis_size=H * Dh),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * Dh,), dtype)
        p["bk"] = jnp.zeros((K * Dh,), dtype)
        p["bv"] = jnp.zeros((K * Dh,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(Dh, dtype)
        p["k_norm"] = rmsnorm_init(Dh, dtype)
    return p


def mha_project_qkv(p, x, cfg: ModelConfig, positions, rope: bool = True):
    """Project to q (B,S,K,G,Dh) and k,v (B,S,K,Dh), with rope + qk-norm."""
    B, S, _ = x.shape
    H, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    G = H // K
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, K, G, Dh)
    k = k.reshape(B, S, K, Dh)
    v = v.reshape(B, S, K, Dh)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def mha_out(p, attn_out, B, S):
    return attn_out.reshape(B, S, -1) @ p["wo"]


def self_attention(p, x, cfg: ModelConfig, positions, *, causal=True,
                   window=None, rope=True):
    B, S, _ = x.shape
    q, k, v = mha_project_qkv(p, x, cfg, positions, rope)
    o = attention(q, k, v, positions, positions, causal=causal, window=window)
    return mha_out(p, o, B, S)


# -- KV cache: a ring buffer of ``capacity`` slots.  A full cache is simply
#    capacity == max_len; a sliding-window cache sets capacity == window so
#    decode state stays O(window) for ``long_500k`` (SWA archs).
#    ``kv_pos[slot]`` is the absolute position stored there (-1 = empty);
#    ``pos`` is the next position to write.


def make_kv_cache(cfg: ModelConfig, batch: int, capacity: int, dtype,
                  n_layers: int | None = None):
    """Cache leaves; with n_layers, leaves get a leading (L, ...) axis so the
    decode step can scan over layers."""
    K, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    lead = (n_layers,) if n_layers else ()
    return {
        "k": jnp.zeros((*lead, batch, capacity, K, Dh), dtype),
        "v": jnp.zeros((*lead, batch, capacity, K, Dh), dtype),
        "kv_pos": jnp.full((*lead, capacity), -1, jnp.int32),
    }


def cache_write_prefill(cache, k_new, v_new):
    """Write S prefill positions 0..S-1 into one layer's cache (ring)."""
    S = k_new.shape[1]
    cap = cache["k"].shape[1]
    if S >= cap:
        start = S - cap
        slots = (jnp.arange(start, S, dtype=jnp.int32)) % cap
        k_new, v_new = k_new[:, -cap:], v_new[:, -cap:]
        positions = jnp.arange(start, S, dtype=jnp.int32)
    else:
        slots = jnp.arange(S, dtype=jnp.int32)
        positions = slots
    return {
        "k": cache["k"].at[:, slots].set(k_new),
        "v": cache["v"].at[:, slots].set(v_new),
        "kv_pos": cache["kv_pos"].at[slots].set(positions),
    }


def cache_write_decode(cache, k_new, v_new, pos):
    """Write one token at absolute position ``pos`` (traced scalar)."""
    cap = cache["k"].shape[1]
    slot = pos % cap
    return {
        "k": cache["k"].at[:, slot].set(k_new[:, 0]),
        "v": cache["v"].at[:, slot].set(v_new[:, 0]),
        "kv_pos": cache["kv_pos"].at[slot].set(pos),
    }


def self_attention_decode(p, x, cfg: ModelConfig, cache: dict, pos, *,
                          window=None, rope=True):
    """One-token decode: x (B,1,d); ``cache`` is ONE layer's ring cache;
    ``pos`` is the absolute position (traced scalar).  Returns (out, cache)."""
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = mha_project_qkv(p, x, cfg, positions, rope)
    cache = cache_write_decode(cache, k_new, v_new, pos)
    cap = cache["k"].shape[1]
    kv_pos = jnp.broadcast_to(cache["kv_pos"], (B, cap))
    kv_valid = cache["kv_pos"] >= 0
    o = attention_ref(q, cache["k"], cache["v"], positions, kv_pos,
                      causal=True, window=window,
                      kv_valid=jnp.broadcast_to(kv_valid, (B, cap)))
    return mha_out(p, o, B, 1), cache


# --------------------------------------------------------------------------
# FFN variants
# --------------------------------------------------------------------------


def swiglu_init(key, d: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d, d_ff), dtype),
        "w_up": dense_init(ks[1], (d, d_ff), dtype),
        "w_down": dense_init(ks[2], (d_ff, d), dtype, in_axis_size=d_ff),
    }


def swiglu(p, x):
    g = jax.nn.silu((x @ p["w_gate"]).astype(jnp.float32)).astype(x.dtype)
    return (g * (x @ p["w_up"])) @ p["w_down"]


def gelu_mlp_init(key, d: int, d_ff: int, dtype):
    ks = jax.random.split(key, 2)
    return {
        "w1": dense_init(ks[0], (d, d_ff), dtype),
        "b1": jnp.zeros((d_ff,), dtype),
        "w2": dense_init(ks[1], (d_ff, d), dtype, in_axis_size=d_ff),
        "b2": jnp.zeros((d,), dtype),
    }


def gelu_mlp(p, x):
    h = jax.nn.gelu((x @ p["w1"] + p["b1"]).astype(jnp.float32)).astype(x.dtype)
    return h @ p["w2"] + p["b2"]


# --------------------------------------------------------------------------
# embeddings / head
# --------------------------------------------------------------------------


def sinusoidal_positions(max_len: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(max_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10_000.0, dim / d)
    pe = jnp.zeros((max_len, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


def sinusoidal_position_at(pos, d: int) -> jnp.ndarray:
    """Sinusoidal embedding for a single (traced) position -> (1, d)."""
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    angle = pos.astype(jnp.float32) / jnp.power(10_000.0, dim / d)
    pe = jnp.zeros((1, d), jnp.float32)
    pe = pe.at[0, 0::2].set(jnp.sin(angle))
    pe = pe.at[0, 1::2].set(jnp.cos(angle))
    return pe


def cross_entropy_loss(logits, labels, valid=None):
    """logits (B,S,V) [any dtype, upcast], labels (B,S) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if valid is None:
        return jnp.mean(nll)
    valid = valid.astype(jnp.float32)
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0)
