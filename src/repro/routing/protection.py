"""Fast reroute under failure: layered multipath + precomputed backups.

Two resilience mechanisms from the literature, built over any
:class:`~repro.core.routing_graph.CSRGraph`:

* **FatPaths-style routing layers** (Besta et al.) — ``n_layers`` copies
  of the fabric, each a deterministic subgraph of the full multigraph.
  Layer 0 is the primary (every edge); protection layer ``l >= 1``
  excludes the undirected edges assigned to it round-robin (edge ``uid``
  is excluded from layer ``1 + uid % (n_layers - 1)``), plus an optional
  seeded ``rho`` subsample for extra path diversity.  Minimal routing
  *within* a layer is loop-free by construction (distances strictly
  decrease), and because every edge is excluded from exactly one
  protection layer, that layer can always carry traffic around it.
* **MRC-style precomputed backup next-hops** (maximally redundant
  cover / SRv6 fast-reroute) — for every directed edge ``e = (u -> v)``
  and destination ``d``, :meth:`ProtectedRouter.backup_next_hops` holds
  the first hop out of ``u`` toward ``d`` in the layer protecting ``e``.
  The table is computed from the per-layer BFS distances *before* any
  failure, so when ``e`` dies the reroute is a table lookup — no BFS, no
  graph rebuild, no reconvergence.

:meth:`ProtectedRouter.local_reroute_loads` is the measured consequence:
given a healthy demand matrix and a
:class:`~repro.sim.failures.DegradedGraph`, it propagates traffic over
the *stale* healthy shortest-path DAG, renormalizing each node's ECMP
split over surviving downhill edges (local ECMP sibling reroute) and
diverting shares with no surviving downhill edge into the failed edge's
protection layer (the MRC switch-over).  Shares that exhaust
``max_redirects`` layer switches, enter a layer that cannot reach the
destination, or originate/terminate at dead switches are *stalled* —
they wait for global reconvergence, and the result accounts for them
explicitly: ``injected == delivered + stalled`` to 1e-9, and no load is
ever placed on a failed element (both pinned by
``results/BENCH_reroute.json``).

Everything here is numpy: protection state is precomputed once per
fabric at suite scale (the 65K presets route on the jit engines and do
not build protection tables by default).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.routing_graph import CSRGraph, GraphLinkLoads, GraphRouter
from repro.core.routing_vec import DemandArrays
from repro.core.topology import SwitchGraph, Topology
from repro.telemetry import get_metrics

REROUTE_MODES = ("none", "local", "global")


def validate_reroute_mode(mode: str) -> str:
    if mode not in REROUTE_MODES:
        raise ValueError(f"unknown reroute mode {mode!r}; expected one of "
                         f"{REROUTE_MODES}")
    return mode


def _masked_hops(csr: CSRGraph, edge_mask: np.ndarray) -> np.ndarray:
    """(S, S) hop distances over the masked edge set via batched frontier
    BFS; ``-1`` marks unreachable pairs (masked layers may disconnect —
    callers treat unreachable as "this layer cannot protect the pair")."""
    S = csr.n_switches
    adj = np.zeros((S, S), dtype=np.float32)
    adj[csr.src[edge_mask], csr.dst[edge_mask]] = 1.0
    frontier = np.eye(S, dtype=bool)
    visited = frontier.copy()
    dist = np.full((S, S), -1, dtype=np.int32)
    np.fill_diagonal(dist, 0)
    d = 0
    while True:
        d += 1
        nxt = ((frontier.astype(np.float32) @ adj) > 0) & ~visited
        if not nxt.any():
            break
        dist[nxt] = d
        visited |= nxt
        frontier = nxt
    return dist


class LocalRerouteResult:
    """Load accounting of one precomputed-backup local reroute.

    ``loads`` lives on the HEALTHY directed-edge ids (zero on every
    failed edge by construction); ``cap_deg`` is the surviving capacity
    of each healthy edge (zero where fully failed, reduced on degraded
    trunks).  ``injected == delivered + stalled`` to float precision.
    """

    def __init__(self, loads, cap_deg, injected_gbps, delivered_gbps,
                 stalled_gbps, diverted_gbps, layer_gbps, n_pulls):
        self.loads = loads
        self.cap_deg = cap_deg
        self.injected_gbps = injected_gbps
        self.delivered_gbps = delivered_gbps
        self.stalled_gbps = stalled_gbps
        self.diverted_gbps = diverted_gbps
        self.layer_gbps = layer_gbps          # (L,) gbps entering each layer
        self.n_pulls = n_pulls

    @property
    def delivered_share(self) -> float:
        return self.delivered_gbps / self.injected_gbps \
            if self.injected_gbps else 1.0

    @property
    def stalled_share(self) -> float:
        return self.stalled_gbps / self.injected_gbps \
            if self.injected_gbps else 0.0

    @property
    def conservation_residual(self) -> float:
        """|injected - delivered - stalled| / injected (0 when idle)."""
        if not self.injected_gbps:
            return 0.0
        return abs(self.injected_gbps - self.delivered_gbps
                   - self.stalled_gbps) / self.injected_gbps

    def max_utilization(self) -> float:
        with np.errstate(divide="ignore", invalid="ignore"):
            u = np.where(self.cap_deg > 0, self.loads / self.cap_deg, 0.0)
        return float(u.max()) if u.size else 0.0

    def saturation_throughput(self) -> float:
        mx = self.max_utilization()
        return 1.0 if mx == 0 else min(1.0, 1.0 / mx)

    def info(self) -> dict:
        return {
            "delivered_share": round(self.delivered_share, 6),
            "stalled_share": round(self.stalled_share, 6),
            "diverted_gbps": round(self.diverted_gbps, 6),
            "conservation_residual": self.conservation_residual,
            "max_util": round(self.max_utilization(), 6),
        }


class ProtectedRouter:
    """A :class:`GraphRouter` plus precomputed protection state.

    Construction cost (the part a real fabric pays at *provisioning*
    time, not at failure time): one BFS per layer plus the backup
    next-hop table — recorded in the ``protection.build_wall_s`` timer.
    """

    def __init__(self, topo_or_graph: "Topology | SwitchGraph | GraphRouter",
                 n_layers: int = 4, rho: float = 1.0, seed: int = 0,
                 backend: str = "auto", dst_chunk: "int | None" = None):
        if n_layers < 2:
            raise ValueError("protection needs n_layers >= 2 "
                             "(layer 0 is the primary)")
        if not (0.0 < rho <= 1.0):
            raise ValueError("rho must be in (0, 1]")
        t0 = time.perf_counter()
        if isinstance(topo_or_graph, GraphRouter):
            self.router = topo_or_graph
        else:
            self.router = GraphRouter(topo_or_graph, backend=backend)
        self.graph = self.router.graph
        self.csr = self.router.csr
        self.n_layers = n_layers
        self.rho = rho
        self.seed = seed
        csr = self.csr
        E, S = csr.n_edges, csr.n_switches
        if dst_chunk is None:
            dst_chunk = max(1, int(8e6 // max(E, 1)))
        self.dst_chunk = dst_chunk
        # undirected edge ids (both directions of a physical edge share one)
        lo = np.minimum(csr.src, csr.dst)
        hi = np.maximum(csr.src, csr.dst)
        upairs, uid = np.unique(np.stack([lo, hi], axis=1), axis=0,
                                return_inverse=True)
        self.n_uedges = int(upairs.shape[0])
        protect_u = 1 + (np.arange(self.n_uedges) % (n_layers - 1))
        # layer that PROTECTS each directed edge (== the layer excluding it)
        self.protect_layer = protect_u[uid].astype(np.int32)       # (E,)
        self.layer_mask = np.ones((n_layers, E), dtype=bool)
        for l in range(1, n_layers):
            self.layer_mask[l] = self.protect_layer != l
        if rho < 1.0:
            rng = np.random.default_rng(seed)
            for l in range(1, n_layers):
                drop_u = rng.random(self.n_uedges) >= rho
                self.layer_mask[l] &= ~drop_u[uid]
        self._hops: "list[np.ndarray | None]" = [None] * n_layers
        self._bnh: "np.ndarray | None" = None
        mx = get_metrics()
        mx.inc("protection.routers_built")
        mx.observe("protection.build_wall_s", time.perf_counter() - t0)

    # ----------------------------------------------------------- layers ----

    def layer_hops(self, layer: int) -> np.ndarray:
        """(S, S) hop distances within ``layer`` (lazy, cached; -1 =
        unreachable in this layer)."""
        if self._hops[layer] is None:
            t0 = time.perf_counter()
            self._hops[layer] = _masked_hops(self.csr,
                                             self.layer_mask[layer])
            mx = get_metrics()
            mx.inc("protection.layer_bfs")
            mx.observe("protection.layer_bfs_wall_s",
                       time.perf_counter() - t0)
        return self._hops[layer]

    def layer_connected(self, layer: int) -> bool:
        return bool((self.layer_hops(layer) >= 0).all())

    def connected_layers(self) -> "list[int]":
        return [l for l in range(self.n_layers) if self.layer_connected(l)]

    def layer_edge_counts(self) -> np.ndarray:
        """(L,) directed edges present in each layer."""
        return self.layer_mask.sum(axis=1)

    # ------------------------------------------------ backup next-hops ----

    def _first_downhill_table(self, layer: int) -> np.ndarray:
        """(S, S) int32: lowest-id downhill neighbor toward every
        destination within ``layer`` (-1 where none — unreachable)."""
        csr = self.csr
        S = csr.n_switches
        dist = self.layer_hops(layer)
        m = self.layer_mask[layer]
        NH = np.full((S, S), -1, dtype=np.int32)
        for lolim in range(0, S, self.dst_chunk):
            cols = np.arange(lolim, min(lolim + self.dst_chunk, S))
            d = dist[:, cols]                                   # (S, C)
            down = (m[:, None] & (d[csr.dst] == d[csr.src] - 1)
                    & (d[csr.src] > 0))
            e_idx, c_idx = np.nonzero(down)
            tmp = np.full((S, cols.shape[0]), S, dtype=np.int64)
            np.minimum.at(tmp, (csr.src[e_idx], c_idx), csr.dst[e_idx])
            NH[:, cols] = np.where(tmp < S, tmp, -1).astype(np.int32)
        return NH

    def backup_next_hops(self) -> np.ndarray:
        """(E, S) int32 MRC table: ``bnh[e, d]`` is the precomputed first
        hop out of ``src[e]`` toward ``d`` in the layer protecting edge
        ``e`` (which excludes ``e`` by construction), or -1 when that
        layer cannot reach ``d`` from ``src[e]`` (the share stalls until
        reconvergence).  Lazy; cached."""
        if self._bnh is None:
            t0 = time.perf_counter()
            csr = self.csr
            bnh = np.full((csr.n_edges, csr.n_switches), -1, dtype=np.int32)
            for l in range(1, self.n_layers):
                edges_l = np.flatnonzero(self.protect_layer == l)
                if not edges_l.size:
                    continue
                NH = self._first_downhill_table(l)
                bnh[edges_l] = NH[csr.src[edges_l]]
            self._bnh = bnh
            mx = get_metrics()
            mx.inc("protection.backup_tables_built")
            mx.observe("protection.backup_table_wall_s",
                       time.perf_counter() - t0)
        return self._bnh

    def protection_coverage(self) -> float:
        """Fraction of (edge, destination) cells with a usable backup
        next-hop, excluding the trivial ``src[e] == d`` diagonal (1.0
        when every protection layer stays connected)."""
        bnh = self.backup_next_hops()
        valid = (self.csr.src[:, None]
                 != np.arange(self.csr.n_switches)[None, :])
        return float((bnh >= 0)[valid].mean()) if bnh.size else 1.0

    # ------------------------------------------------- degraded mapping ----

    def _degraded_state(self, dg) -> "tuple[np.ndarray, np.ndarray, np.ndarray]":
        """(surv_mult (E,), cap_deg (E,), alive_node (S,)) of a
        :class:`~repro.sim.failures.DegradedGraph` in HEALTHY ids."""
        csr = self.csr
        nm = np.asarray(dg.node_map)
        alive_node = nm >= 0
        surv_mult = np.zeros(csr.n_edges)
        adj = dg.graph.adj
        for e in range(csr.n_edges):
            u, v = int(csr.src[e]), int(csr.dst[e])
            if alive_node[u] and alive_node[v]:
                surv_mult[e] = adj[int(nm[u])].get(int(nm[v]), 0.0)
        cap_deg = surv_mult * self.graph.link_gbps
        return surv_mult, cap_deg, alive_node

    # --------------------------------------------------- local reroute ----

    def _pull(self, layer: int, dests: np.ndarray, inject: np.ndarray,
              surv: np.ndarray, surv_mult: np.ndarray,
              alive_node: np.ndarray, loads: np.ndarray):
        """One level-ordered pull of ``inject`` (S, C) toward ``dests``
        within ``layer``, splitting over *surviving* downhill edges.

        Returns ``(delivered (C,), stalled_gbps, diversions)`` where
        ``diversions`` maps protection-layer id -> (S, C) injections that
        must continue there (shares whose downhill edges all failed).
        Adds edge loads into ``loads`` in place.
        """
        csr = self.csr
        S, C_all = inject.shape
        # diverted re-injections touch few destinations: drop empty
        # columns so protection-layer pulls only pay for live traffic
        live = np.flatnonzero(inject.sum(axis=0) > 0)
        if live.size < C_all:
            if not live.size:
                return np.zeros(C_all), 0.0, {}
            d_live, st, divs = self._pull(layer, dests[live],
                                          inject[:, live], surv,
                                          surv_mult, alive_node, loads)
            delivered = np.zeros(C_all)
            delivered[live] = d_live
            wide = {}
            for l2, arr in divs.items():
                full = np.zeros((S, C_all))
                full[:, live] = arr
                wide[l2] = full
            return delivered, st, wide
        C = C_all
        dist = self.layer_hops(layer)[:, dests]                  # (S, C)
        ok = ((dist >= 0) & alive_node[:, None]
              & alive_node[dests][None, :])
        stalled = float(inject[~ok].sum())
        f = np.where(ok, inject, 0.0)
        if f.sum() <= 0:
            return np.zeros(C), stalled, {}
        m = self.layer_mask[layer]
        d_src = dist[csr.src]                                    # (E, C)
        down = m[:, None] & (dist[csr.dst] == d_src - 1) & (d_src > 0)
        alive_down = down & surv[:, None]
        w = surv_mult[:, None] * alive_down
        denom = np.zeros((S, C))
        np.add.at(denom, csr.src, w)
        with np.errstate(divide="ignore", invalid="ignore"):
            frac = np.where(alive_down, w / denom[csr.src], 0.0)
        has_down = np.zeros((S, C), dtype=bool)
        np.logical_or.at(has_down, csr.src, down)
        stuck = has_down & (denom <= 0)       # every downhill edge failed
        pls, pl_count = [], None
        if stuck.any():
            # diverted shares split evenly over the distinct protection
            # layers of the failed downhill edges (spreads detour load)
            failed_down = down & ~surv[:, None]
            for l2 in range(1, self.n_layers):
                sel_e = failed_down & (self.protect_layer == l2)[:, None]
                has = np.zeros((S, C), dtype=bool)
                el, cl = np.nonzero(sel_e)
                has[csr.src[el], cl] = True
                pls.append(has)
            pl_count = np.maximum(
                np.sum([h.astype(np.int64) for h in pls], axis=0), 1)
        divs: dict = {}
        # mass only moves downhill from where it was injected
        top = int(dist[f > 0].max())
        for level in range(top, 0, -1):
            at = dist == level
            if stuck.any():
                dm = at & stuck & (f > 0)
                if dm.any():
                    for l2, has in zip(range(1, self.n_layers), pls):
                        sel = dm & has
                        if not sel.any():
                            continue
                        if l2 not in divs:
                            divs[l2] = np.zeros((S, C))
                        divs[l2] += np.where(sel, f / pl_count, 0.0)
                    f = np.where(dm, 0.0, f)
            fa = f * at
            contrib = frac * fa[csr.src]                         # (E, C)
            loads += contrib.sum(axis=1)
            np.add.at(f, csr.dst, contrib)
        delivered = f[dests, np.arange(C)].copy()
        return delivered, stalled, divs

    def local_reroute_loads(self, demands: DemandArrays, dg,
                            max_redirects: "int | None" = None
                            ) -> LocalRerouteResult:
        """Reroute a HEALTHY demand matrix around the failures of ``dg``
        using only precomputed state — the sub-ms local path.

        No BFS and no graph rebuild happens here: every per-layer
        distance table was computed at protection time, so the
        failure-time work is renormalizing ECMP splits over surviving
        edges and switching dead shares into their protection layers
        (exactly what a switch does with an MRC/SRv6 backup-table hit).
        """
        csr = self.csr
        validate = demands  # noqa: F841  (keep signature obvious)
        surv_mult, cap_deg, alive_node = self._degraded_state(dg)
        surv = surv_mult > 0
        src = np.asarray(demands.src, dtype=np.int64)
        dst = np.asarray(demands.dst, dtype=np.int64)
        gbps = np.asarray(demands.gbps, dtype=np.float64)
        keep = src != dst
        src, dst, gbps = src[keep], dst[keep], gbps[keep]
        if max_redirects is None:
            max_redirects = self.n_layers
        loads = np.zeros(csr.n_edges)
        injected = float(gbps.sum())
        delivered = stalled = diverted = 0.0
        layer_gbps = np.zeros(self.n_layers)
        n_pulls = 0
        dests_u, inv = np.unique(dst, return_inverse=True)
        S = csr.n_switches
        chunk = self.dst_chunk
        for lolim in range(0, dests_u.shape[0], chunk):
            cols = np.arange(lolim, min(lolim + chunk, dests_u.shape[0]))
            sel = (inv >= cols[0]) & (inv <= cols[-1])
            inject = np.zeros((S, cols.shape[0]))
            np.add.at(inject, (src[sel], inv[sel] - cols[0]), gbps[sel])
            queue = {0: inject}
            for depth in range(max_redirects + 1):
                nxt: dict = {}
                for layer, inj in sorted(queue.items()):
                    tot = float(inj.sum())
                    if tot <= 0:
                        continue
                    layer_gbps[layer] += tot
                    if layer > 0:
                        diverted += tot
                    d, st, divs = self._pull(layer, dests_u[cols], inj,
                                             surv, surv_mult, alive_node,
                                             loads)
                    n_pulls += 1
                    delivered += float(d.sum())
                    stalled += st
                    for l2, arr in divs.items():
                        if l2 < 0:
                            stalled += float(arr.sum())
                            continue
                        if l2 in nxt:
                            nxt[l2] += arr
                        else:
                            nxt[l2] = arr
                queue = nxt
                if not queue:
                    break
            for _, inj in queue.items():      # redirect budget exhausted
                stalled += float(inj.sum())
        mx = get_metrics()
        mx.inc("protection.local_reroutes")
        mx.inc("protection.pulls", n_pulls)
        return LocalRerouteResult(loads, cap_deg, injected, delivered,
                                  stalled, diverted, layer_gbps, n_pulls)

    # ------------------------------------------------ layered multipath ----

    def route_layered(self, demands: DemandArrays,
                      flowlet_bytes: int = 1 << 17,
                      msg_bytes: float = 1 << 22,
                      seed: int = 0) -> GraphLinkLoads:
        """FatPaths-style layered multipath on the healthy fabric: each
        demand's rate is split across connected layers by hashing
        flowlets (``msg_bytes`` worth per flow, ``flowlet_bytes`` each)
        over the layer set — :func:`repro.sim.spray.flowlet_split` — and
        each share routes minimally *within its layer*.  Returns healthy
        loads on the full edge set (same :class:`GraphLinkLoads` API as
        the plain engine)."""
        from repro.sim.spray import flowlet_split
        csr = self.csr
        src = np.asarray(demands.src, dtype=np.int64)
        dst = np.asarray(demands.dst, dtype=np.int64)
        gbps = np.asarray(demands.gbps, dtype=np.float64)
        keep = src != dst
        src, dst, gbps = src[keep], dst[keep], gbps[keep]
        loads = np.zeros(csr.n_edges)
        if not src.size:
            return GraphLinkLoads(csr, loads)
        alive = np.array([self.layer_connected(l)
                          for l in range(self.n_layers)])
        sizes = np.full(src.shape[0], float(msg_bytes))
        bts, _counts = flowlet_split(sizes, self.n_layers, flowlet_bytes,
                                     seed=seed, alive=alive)
        weights = bts / sizes[:, None]
        surv = np.ones(csr.n_edges, dtype=bool)
        alive_node = np.ones(csr.n_switches, dtype=bool)
        dests_u, inv = np.unique(dst, return_inverse=True)
        S = csr.n_switches
        stalled = 0.0
        for l in np.flatnonzero(alive):
            wl = gbps * weights[:, l]
            if not wl.sum():
                continue
            for lolim in range(0, dests_u.shape[0], self.dst_chunk):
                cols = np.arange(lolim, min(lolim + self.dst_chunk,
                                            dests_u.shape[0]))
                sel = (inv >= cols[0]) & (inv <= cols[-1]) & (wl > 0)
                inject = np.zeros((S, cols.shape[0]))
                np.add.at(inject, (src[sel], inv[sel] - cols[0]), wl[sel])
                _, st, divs = self._pull(int(l), dests_u[cols], inject,
                                         surv, csr.mult, alive_node, loads)
                stalled += st
                assert not divs, "no diversions on a healthy fabric"
        assert stalled == 0.0, "connected layers deliver everything"
        get_metrics().inc("protection.layered_routes")
        return GraphLinkLoads(csr, loads)
