"""repro.routing — routing resilience layers on top of the core engines.

:mod:`.protection` is the fast-reroute subsystem: FatPaths-style layered
multipath over any :class:`~repro.core.routing_graph.CSRGraph` plus
MRC-style precomputed backup next-hop tables, so degraded fabrics can
reroute *locally* (table lookups, no BFS) instead of waiting for a
global reconvergence.  ``docs/resilience.md`` is the guide.
"""

from .protection import (LocalRerouteResult, ProtectedRouter,
                         REROUTE_MODES, validate_reroute_mode)

__all__ = ["LocalRerouteResult", "ProtectedRouter", "REROUTE_MODES",
           "validate_reroute_mode"]
