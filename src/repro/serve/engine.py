"""Batched serving engine: prefill + lockstep decode with ring-buffer KV
caches, greedy/temperature sampling, EOS handling, and throughput stats.

Static batching: up to ``max_batch`` equal-length prompts are admitted per
wave (the assignment's serve shapes are fixed (B, S) cells; per-request
continuous batching would need per-slot position counters — noted as
roadmap in DESIGN.md).  The jit'd ``prefill`` / ``decode_step`` closures are
compiled once per (B, S) and reused across waves.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 32
    eos_id: int | None = None
    # filled by the engine:
    output: list = field(default_factory=list)
    done: bool = False


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_out: int = 0
    waves: int = 0

    @property
    def decode_tok_per_s(self) -> float:
        return self.tokens_out / self.decode_s if self.decode_s else 0.0


class ServeEngine:
    def __init__(self, model, params, max_batch: int = 8,
                 max_len: int = 512, temperature: float = 0.0, seed: int = 0):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.temperature = temperature
        self.rng = jax.random.PRNGKey(seed)
        self.stats = ServeStats()
        self._prefill = jax.jit(
            lambda p, toks: model.prefill(p, toks, max_len=max_len))
        self._decode = jax.jit(model.decode_step)

    # ---------------------------------------------------------- sampling ----

    def _sample(self, logits, key):
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits / self.temperature, axis=-1)

    # ------------------------------------------------------------- serve ----

    def run(self, requests: list[Request]) -> list[Request]:
        """Process all requests in waves of ``max_batch``."""
        for i in range(0, len(requests), self.max_batch):
            self._run_wave(requests[i:i + self.max_batch])
        return requests

    def _run_wave(self, wave: list[Request]):
        B = len(wave)
        S = len(wave[0].prompt)
        if any(len(r.prompt) != S for r in wave):
            raise ValueError("static batching: equal prompt lengths per wave")
        prompts = jnp.asarray(np.stack([r.prompt for r in wave]), jnp.int32)

        t0 = time.perf_counter()
        logits, caches = self._prefill(self.params, prompts)
        logits.block_until_ready()
        self.stats.prefill_s += time.perf_counter() - t0

        max_new = max(r.max_new_tokens for r in wave)
        done = np.zeros(B, bool)
        t0 = time.perf_counter()
        for step in range(max_new):
            self.rng, k = jax.random.split(self.rng)
            tok = self._sample(logits, k).astype(jnp.int32)[:, None]
            tok_np = np.asarray(tok[:, 0])
            for b, r in enumerate(wave):
                if done[b]:
                    continue
                if step >= r.max_new_tokens or (
                        r.eos_id is not None and tok_np[b] == r.eos_id):
                    done[b] = True
                    r.done = True
                    continue
                r.output.append(int(tok_np[b]))
                self.stats.tokens_out += 1
            if done.all():
                break
            logits, caches = self._decode(self.params, tok, caches)
        jax.block_until_ready(logits)
        self.stats.decode_s += time.perf_counter() - t0
        for r in wave:
            r.done = True
        self.stats.waves += 1
