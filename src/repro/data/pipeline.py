"""Synthetic-but-learnable data pipeline.

Produces next-token-predictable streams so the end-to-end example can show a
falling loss without external datasets (offline container).  Three sources:

* ``lcg``     — order-k Markov stream with a fixed random transition table
                (learnable by any LM; entropy tunable via temperature)
* ``copy``    — delimiter + random span + the same span again (induction)
* ``uniform`` — i.i.d. tokens (loss floor = log V; useful for benchmarks)

The pipeline is deterministic per (seed, step, shard), supports host-sharded
loading (each data-parallel host materializes only its batch slice — the
``Batch.shard_slice`` used by the trainer), and prefetches on a background
thread.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    kind: str = "lcg"          # lcg | copy | uniform
    vocab_size: int = 512
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 0
    markov_order: int = 2
    temperature: float = 0.3   # lower = more predictable


class SyntheticDataset:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        if cfg.kind == "lcg":
            # order-k Markov: context hash -> logits over vocab
            self.n_states = min(4096, cfg.vocab_size ** min(cfg.markov_order, 2))
            logits = rng.normal(size=(self.n_states, cfg.vocab_size))
            probs = np.exp(logits / cfg.temperature)
            self.table = probs / probs.sum(-1, keepdims=True)
            self.mults = rng.integers(
                1, self.n_states, size=cfg.markov_order) * 2 + 1

    def _ctx_state(self, ctx: np.ndarray) -> np.ndarray:
        s = np.zeros(ctx.shape[0], dtype=np.int64)
        for i in range(self.cfg.markov_order):
            s = s + ctx[:, i] * self.mults[i]
        return s % self.n_states

    def batch(self, step: int, shard: int = 0, n_shards: int = 1) -> dict:
        """Deterministic batch for (step, shard).  Returns numpy arrays
        tokens/labels of the LOCAL slice (global_batch / n_shards rows)."""
        cfg = self.cfg
        if cfg.global_batch % n_shards:
            raise ValueError("global_batch must divide by n_shards")
        B = cfg.global_batch // n_shards
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 97 + shard)
        if cfg.kind == "uniform":
            toks = rng.integers(0, cfg.vocab_size, size=(B, cfg.seq_len + 1))
        elif cfg.kind == "copy":
            half = (cfg.seq_len + 1) // 2
            span = rng.integers(1, cfg.vocab_size,
                                size=(B, half))
            toks = np.zeros((B, cfg.seq_len + 1), dtype=np.int64)
            toks[:, :half] = span
            toks[:, half:half * 2] = span[:, :cfg.seq_len + 1 - half]
        else:  # lcg markov
            k = cfg.markov_order
            toks = np.zeros((B, cfg.seq_len + 1 + k), dtype=np.int64)
            toks[:, :k] = rng.integers(0, cfg.vocab_size, size=(B, k))
            for t in range(k, cfg.seq_len + 1 + k):
                state = self._ctx_state(toks[:, t - k:t])
                p = self.table[state]
                c = p.cumsum(-1)
                u = rng.random(size=(B, 1))
                toks[:, t] = (u > c).sum(-1)
            toks = toks[:, k:]
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


class Prefetcher:
    """Background-thread prefetch of `SyntheticDataset.batch` results."""

    def __init__(self, ds: SyntheticDataset, start_step: int = 0,
                 shard: int = 0, n_shards: int = 1, depth: int = 2):
        self.ds = ds
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._args = (shard, n_shards)
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            b = self.ds.batch(step, *self._args)
            while not self._stop.is_set():
                try:
                    self.q.put((step, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        step, b = self.q.get()
        return step, b

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)


def loss_floor(cfg: DataConfig) -> float:
    """Entropy of the generating process (nats/token) — the trainer's
    convergence tests check loss approaches this, not zero."""
    if cfg.kind == "uniform":
        return float(np.log(cfg.vocab_size))
    if cfg.kind == "copy":
        return float(np.log(cfg.vocab_size) / 2 + 0.01)
    ds = SyntheticDataset(cfg)
    p = ds.table
    ent = -(p * np.log(np.maximum(p, 1e-12))).sum(-1)
    return float(ent.mean())
