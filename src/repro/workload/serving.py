"""Disaggregated LLM serving traffic for one tenant.

Models the fabric-visible side of prefill/decode-disaggregated serving:
each request arrives open-loop (:mod:`.arrivals`), runs prefill on a
prefill replica, then streams its KV cache to a decode replica — the
KV-cache transfer is the serving fabric flow.  Byte accounting comes
from the tenant's :class:`~repro.configs.base.ModelConfig` exactly the
way :mod:`repro.cosim.traffic` sizes collectives:

``kv_bytes_per_token = 2 (K+V) * n_layers * n_kv_heads * head_dim *
dtype_bytes``

Replicas are tensor-parallel groups of ``tp`` ranks placed on
consecutive NICs (the linear layout of
:func:`repro.cosim.placement.rank_to_switch`); a request's KV transfer
is ``tp`` shard flows between corresponding prefill/decode ranks,
merged per switch pair (same-switch shards ride the intra-switch path
and cost no fabric traffic — the 2-hop alpha covers them, matching
``phase_step_flows``).  ``hotspot_fraction`` routes that share of
requests to decode replica 0 — the incast-toward-a-hot-replica pattern
FatPaths evaluates.

Every flow carries ``tag=(tenant, request_index)`` so the simulator's
per-flow records attribute straight back to requests
(:class:`repro.sim.events.FlowSpec`).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.configs.base import ModelConfig
from repro.cosim.traffic import _dtype_bytes
from repro.sim.events import FlowSpec
from .arrivals import SizeDist, mmpp_arrivals, poisson_arrivals, sample_sizes

ARRIVAL_KINDS = ("poisson", "mmpp")


def kv_bytes_per_token(cfg: ModelConfig) -> float:
    """KV-cache bytes one token occupies across all layers (K and V,
    grouped-query heads, activation dtype) — the per-token payload of a
    prefill -> decode KV transfer."""
    return (2.0 * cfg.n_layers * cfg.n_kv_heads * cfg.resolved_head_dim
            * _dtype_bytes(cfg))


@dataclass(frozen=True)
class ServingTenantSpec:
    """One serving tenant: arrival process, model, replica geometry.

    ``rate_hz`` requests arrive over ``duration_s``; each samples its
    prompt length from ``prompt_tokens`` (tokens).  Prefill replicas are
    chosen round-robin (they are stateless for placement purposes);
    decode replicas uniformly except that ``hotspot_fraction`` of
    requests pin to decode replica 0.  ``prefill_tokens_per_s`` sets the
    prefill-compute delay between arrival and the KV transfer start.
    """

    name: str
    arch: str = "mixtral-8x22b"
    rate_hz: float = 400.0
    duration_s: float = 0.25
    arrival: str = "poisson"
    burstiness: float = 4.0          # mmpp only
    prompt_tokens: SizeDist = field(
        default_factory=lambda: SizeDist("lognormal", mean=800.0, sigma=1.0))
    prefill_replicas: int = 2
    decode_replicas: int = 2
    tp: int = 4                      # ranks (NICs) per replica
    hotspot_fraction: float = 0.0
    prefill_tokens_per_s: float = 60_000.0

    def __post_init__(self):
        if self.arrival not in ARRIVAL_KINDS:
            raise ValueError(f"unknown arrival {self.arrival!r}; "
                             f"known: {ARRIVAL_KINDS}")
        if min(self.prefill_replicas, self.decode_replicas, self.tp) < 1:
            raise ValueError("replica counts and tp must be >= 1")

    @property
    def n_nics(self) -> int:
        return (self.prefill_replicas + self.decode_replicas) * self.tp


@dataclass
class ServingWorkload:
    """Materialized request trace + fabric flows of one serving tenant.

    Request arrays are index-aligned; ``flows[k]`` carries
    ``tag=(name, request)`` and ``caps_gbps[k]`` its injection cap
    (merged shards x one port's rate).  ``intra_bytes`` is KV payload
    that stayed inside a switch (no fabric flow; byte conservation is
    ``sum(flow bytes) + intra_bytes == kv_bytes.sum()``).
    ``local_requests`` lists requests whose shards were ALL
    intra-switch — their transfer is alpha-only.
    """

    spec: ServingTenantSpec
    arrival_s: np.ndarray        # (R,)
    prompt_tokens: np.ndarray    # (R,)
    kv_bytes: np.ndarray         # (R,)
    kv_start_s: np.ndarray       # (R,) arrival + prefill compute
    prefill_replica: np.ndarray  # (R,)
    decode_replica: np.ndarray   # (R,)
    flows: "list[FlowSpec]"
    caps_gbps: np.ndarray        # (F,) injection cap per merged flow
    intra_bytes: float
    local_requests: np.ndarray   # request ids with zero fabric flows

    @property
    def n_requests(self) -> int:
        return int(self.arrival_s.shape[0])

    def offered_bytes(self) -> float:
        return float(self.kv_bytes.sum())


def replica_switches(switch_of_nic: np.ndarray, nic_base: int,
                     n_replicas: int, tp: int) -> np.ndarray:
    """(n_replicas, tp) switch id of each replica's ranks, placed on
    consecutive NICs starting at ``nic_base``."""
    need = nic_base + n_replicas * tp
    if need > switch_of_nic.shape[0]:
        raise ValueError(f"placement needs NICs [{nic_base}, {need}) but "
                         f"fabric has {switch_of_nic.shape[0]}")
    nics = nic_base + np.arange(n_replicas * tp)
    return switch_of_nic[nics].reshape(n_replicas, tp)


def build_serving_workload(spec: ServingTenantSpec,
                           switch_of_nic: np.ndarray, nic_base: int,
                           port_gbps: float, rng: np.random.Generator,
                           kv_per_token: "float | None" = None
                           ) -> ServingWorkload:
    """Materialize one tenant's request trace and KV-transfer flows.

    ``switch_of_nic`` is the fabric's per-NIC switch map
    (:func:`repro.cosim.placement.rank_to_switch`); the tenant occupies
    NICs ``[nic_base, nic_base + spec.n_nics)`` — prefill replicas
    first, then decode replicas.  ``kv_per_token`` overrides the
    registry model's byte accounting (tests).
    """
    if kv_per_token is None:
        from repro.models.registry import get_config
        kv_per_token = kv_bytes_per_token(get_config(spec.arch))
    if spec.arrival == "mmpp":
        arrival = mmpp_arrivals(spec.rate_hz, spec.duration_s, rng,
                                burstiness=spec.burstiness)
    else:
        arrival = poisson_arrivals(spec.rate_hz, spec.duration_s, rng)
    R = arrival.shape[0]
    tokens = np.maximum(np.rint(sample_sizes(spec.prompt_tokens, R, rng)),
                        1.0)
    kv = tokens * kv_per_token
    start = arrival + tokens / spec.prefill_tokens_per_s
    pre = np.arange(R) % spec.prefill_replicas
    dec = rng.integers(0, spec.decode_replicas, size=R)
    if spec.hotspot_fraction > 0:
        hot = rng.random(R) < spec.hotspot_fraction
        dec = np.where(hot, 0, dec)
    pre_sw = replica_switches(switch_of_nic, nic_base,
                              spec.prefill_replicas, spec.tp)
    dec_sw = replica_switches(switch_of_nic,
                              nic_base + spec.prefill_replicas * spec.tp,
                              spec.decode_replicas, spec.tp)
    flows: "list[FlowSpec]" = []
    caps: "list[float]" = []
    intra = 0.0
    local: "list[int]" = []
    for r in range(R):
        shard = kv[r] / spec.tp
        pairs: "dict[tuple[int, int], tuple[float, int]]" = {}
        for i in range(spec.tp):
            s = int(pre_sw[pre[r], i])
            d = int(dec_sw[dec[r], i])
            if s == d:
                intra += shard
                continue
            b, n = pairs.get((s, d), (0.0, 0))
            pairs[(s, d)] = (b + shard, n + 1)
        if not pairs:
            local.append(r)
            continue
        for (s, d), (b, n) in sorted(pairs.items()):
            flows.append(FlowSpec(s, d, b, start_s=float(start[r]),
                                  tag=(spec.name, r)))
            caps.append(port_gbps * n)
    return ServingWorkload(
        spec=spec, arrival_s=arrival, prompt_tokens=tokens, kv_bytes=kv,
        kv_start_s=start, prefill_replica=pre, decode_replica=dec,
        flows=flows, caps_gbps=np.asarray(caps, dtype=np.float64),
        intra_bytes=intra,
        local_requests=np.asarray(local, dtype=np.int64))
