"""Open-loop multi-tenant workload generation on the simulated fabric.

Seeded arrival processes and heavy-tailed size samplers
(:mod:`.arrivals`), disaggregated prefill/decode serving traffic with
model-derived KV-cache byte accounting (:mod:`.serving`), mixed
serving + training + background tenants sharing one fabric with
tag-attributed measured FCTs (:mod:`.tenants`), and per-tenant SLO
rows — FCT/TTFT percentiles, goodput, slowdown-vs-isolation
(:mod:`.slo`).  See ``docs/serving.md``.
"""

from .arrivals import (EMPIRICAL_CDFS, SizeDist, mean_size, mmpp_arrivals,
                       poisson_arrivals, sample_sizes)
from .serving import (ServingTenantSpec, ServingWorkload,
                      build_serving_workload, kv_bytes_per_token,
                      replica_switches)
from .slo import serving_ttft_s, slo_rows, tenant_slo_row
from .tenants import (BackgroundTenantSpec, MixResult, TenantTraffic,
                      TrainingTenantSpec, build_tenant_traffic,
                      run_tenant_mix, tenant_kind, tenant_mask, tenant_of)

__all__ = [
    "EMPIRICAL_CDFS", "SizeDist", "mean_size", "mmpp_arrivals",
    "poisson_arrivals", "sample_sizes",
    "ServingTenantSpec", "ServingWorkload", "build_serving_workload",
    "kv_bytes_per_token", "replica_switches",
    "serving_ttft_s", "slo_rows", "tenant_slo_row",
    "BackgroundTenantSpec", "MixResult", "TenantTraffic",
    "TrainingTenantSpec", "build_tenant_traffic", "run_tenant_mix",
    "tenant_kind", "tenant_mask", "tenant_of",
]
