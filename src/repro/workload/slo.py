"""Per-tenant SLO accounting over a multi-tenant mix.

Turns a :class:`~repro.workload.tenants.MixResult` into per-tenant SLO
rows: FCT percentiles (p50/p99/p999), TTFT-proxy percentiles for
serving tenants (request arrival -> prefill compute -> KV-transfer
completion, including the path alpha; intra-switch-only requests pay
the 2-hop alpha), goodput, and slowdown-vs-isolation (the same tenant's
identical seed-derived trace alone on the fabric).

Attribution is entirely tag-driven (``tag=(tenant, key)`` on every
flow); nothing here re-derives ownership from flow indices.
"""

from __future__ import annotations

import numpy as np

from .tenants import MixResult, TenantTraffic, tenant_mask

SLO_PERCENTILES = (50, 99, 99.9)


def _pcts(values: np.ndarray, unit: float = 1e6,
          prefix: str = "fct") -> dict:
    """p50/p99/p999 of ``values`` (seconds in, microseconds out)."""
    keys = [f"{prefix}_p{str(q).replace('.', '')}_us"
            for q in SLO_PERCENTILES]
    if values.size == 0:
        return {k: None for k in keys}
    return {k: round(float(np.percentile(values, q)) * unit, 3)
            for k, q in zip(keys, SLO_PERCENTILES)}


def serving_ttft_s(mix: MixResult, name: str
                   ) -> "tuple[np.ndarray, np.ndarray]":
    """(R,) TTFT proxy per request of serving tenant ``name`` plus an
    (R,) validity mask (False = a KV shard flow stalled).

    TTFT proxy = (KV-transfer completion on the fabric clock, i.e. the
    last shard's ``finish + path alpha``) minus the request's arrival;
    prefill compute is inside because the KV flow starts at
    ``arrival + prompt_tokens / prefill_tokens_per_s``.  Requests whose
    shards all stayed intra-switch complete at ``kv_start + 2-hop
    alpha``.
    """
    t = mix.tenant(name)
    w = t.serving
    if w is None:
        raise ValueError(f"tenant {name!r} is not a serving tenant")
    res = mix.mixed
    comp = np.full(w.n_requests, -np.inf)
    valid = np.ones(w.n_requests, dtype=bool)
    for i in np.flatnonzero(tenant_mask(res, name)):
        r = int(res.tags[i][1])
        if not np.isfinite(res.finish_s[i]):
            valid[r] = False
            continue
        comp[r] = max(comp[r], float(res.finish_s[i] + res.latency_s[i]))
    if w.local_requests.size:
        comp[w.local_requests] = (w.kv_start_s[w.local_requests]
                                  + mix.alpha_local_s)
    valid &= np.isfinite(comp)
    return comp - w.arrival_s, valid


def tenant_slo_row(mix: MixResult, t: TenantTraffic) -> dict:
    """One tenant's flat SLO record (the serving suite's row unit)."""
    res = mix.mixed
    m = tenant_mask(res, t.name)
    fin = np.isfinite(res.finish_s) & m
    fct = res.fct_s[fin]
    row = {
        "tenant": t.name,
        "kind": t.kind,
        "n_nics": t.n_nics,
        "n_flows": int(m.sum()),
        "n_stalled": int((m & ~np.isfinite(res.finish_s)).sum()),
        **_pcts(fct),
    }
    # goodput: full (all-planes) payload of finished flows plus
    # intra-switch bytes, over the tenant's active span
    full = res.size_bytes * mix.n_planes
    intra = float(t.meta.get("intra_bytes", 0.0))
    if t.serving is not None:
        intra = t.serving.intra_bytes
    delivered = float(full[fin].sum()) + intra
    if fin.any():
        span = float(res.finish_s[fin].max() - res.start_s[m].min())
        row["goodput_gbps"] = round(delivered * 8 / 1e9 / span, 3) \
            if span > 0 else None
    else:
        row["goodput_gbps"] = None
    iso = mix.isolated.get(t.name)
    if iso is not None:
        both = fin[m] & np.isfinite(iso.finish_s)
        if both.any():
            slow = res.fct_s[m][both] / iso.fct_s[both]
            row["slowdown_mean"] = round(float(slow.mean()), 4)
            row["slowdown_p99"] = round(float(np.percentile(slow, 99)), 4)
        else:
            row["slowdown_mean"] = row["slowdown_p99"] = None
    if t.serving is not None:
        ttft, valid = serving_ttft_s(mix, t.name)
        row["n_requests"] = t.serving.n_requests
        row["n_requests_stalled"] = int((~valid).sum())
        row.update(_pcts(ttft[valid], prefix="ttft"))
    return row


def slo_rows(mix: MixResult) -> "list[dict]":
    """Per-tenant SLO rows for every tenant of the mix, spec order."""
    return [tenant_slo_row(mix, t) for t in mix.traffic]
