"""Seeded arrival processes and heavy-tailed size samplers.

The open-loop traffic primitives every tenant kind builds on: request
*arrival times* (Poisson, or a 2-state Markov-modulated Poisson process
for bursty tenants) and request/flow *sizes* (lognormal, bounded Pareto,
or named empirical CDFs in the FatPaths style — piecewise-linear inverse
transform over published datacenter flow-size distributions).

Everything takes an explicit :class:`numpy.random.Generator` — there is
no module-level RNG state anywhere in this package, so a single ``--seed``
threaded from the CLI makes whole artifacts bit-reproducible.  All
samplers are pure functions of ``(spec, rng)``.

Units: arrival times in seconds, sizes in whatever unit the caller
declares (``tokens`` for serving prompts, ``bytes`` for background
flows); :func:`mean_size` gives the analytic mean for offered-load
accounting.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# FatPaths-style named empirical flow-size CDFs (bytes, cum. prob) —
# piecewise-linear approximations of the classic datacenter traces
# (DCTCP web search, the data-mining trace, a Hadoop-style shuffle mix).
# Sampling interpolates linearly in size within each segment, so the
# analytic mean below is exact for the sampler.
EMPIRICAL_CDFS: "dict[str, list[tuple[float, float]]]" = {
    "websearch": [
        (1.0e3, 0.00), (6.0e3, 0.15), (1.3e4, 0.30), (1.9e4, 0.50),
        (3.3e4, 0.60), (5.3e4, 0.70), (1.33e5, 0.80), (6.67e5, 0.90),
        (1.33e6, 0.95), (6.67e6, 0.98), (2.0e7, 1.00),
    ],
    "datamining": [
        (1.0e2, 0.00), (3.0e2, 0.30), (1.0e3, 0.50), (2.0e3, 0.60),
        (1.0e4, 0.70), (1.0e5, 0.80), (1.0e6, 0.90), (1.0e7, 0.95),
        (1.0e8, 0.99), (1.0e9, 1.00),
    ],
    "hadoop": [
        (5.0e2, 0.00), (1.0e3, 0.20), (1.0e4, 0.40), (1.0e5, 0.60),
        (1.0e6, 0.80), (1.0e7, 0.95), (1.0e8, 1.00),
    ],
}


@dataclass(frozen=True)
class SizeDist:
    """One size distribution: ``kind`` picks the sampler.

    * ``fixed`` — point mass at ``mean``.
    * ``lognormal`` — ``sigma`` in log space, scaled so the analytic
      mean is exactly ``mean``.
    * ``pareto`` — bounded Pareto on ``[lo, hi]`` with tail index
      ``alpha`` (heavy tail, finite support).
    * ``empirical`` — a named CDF from :data:`EMPIRICAL_CDFS`
      (``name``), inverse-transform sampled.
    """

    kind: str = "fixed"
    mean: float = 1.0
    sigma: float = 1.0           # lognormal log-space sigma
    alpha: float = 1.2           # pareto tail index
    lo: float = 1.0              # pareto lower bound
    hi: float = 1e6              # pareto upper bound
    name: str = "websearch"      # empirical CDF name

    def __post_init__(self):
        known = ("fixed", "lognormal", "pareto", "empirical")
        if self.kind not in known:
            raise ValueError(f"unknown size dist {self.kind!r}; "
                             f"known: {known}")
        if self.kind == "empirical" and self.name not in EMPIRICAL_CDFS:
            raise ValueError(f"unknown empirical CDF {self.name!r}; "
                             f"known: {sorted(EMPIRICAL_CDFS)}")
        if self.kind == "pareto" and not (self.hi > self.lo > 0):
            raise ValueError("pareto needs hi > lo > 0")


def sample_sizes(dist: SizeDist, n: int, rng: np.random.Generator
                 ) -> np.ndarray:
    """(n,) sizes drawn from ``dist`` using ``rng`` only."""
    if n <= 0:
        return np.zeros(0)
    if dist.kind == "fixed":
        return np.full(n, float(dist.mean))
    if dist.kind == "lognormal":
        # mean of lognormal(mu, sigma) is exp(mu + sigma^2/2); pick mu so
        # the analytic mean is dist.mean
        mu = np.log(dist.mean) - 0.5 * dist.sigma ** 2
        return rng.lognormal(mu, dist.sigma, size=n)
    if dist.kind == "pareto":
        # bounded Pareto inverse transform on [lo, hi]
        a, lo, hi = dist.alpha, dist.lo, dist.hi
        u = rng.random(n)
        return (lo ** -a - u * (lo ** -a - hi ** -a)) ** (-1.0 / a)
    pts = EMPIRICAL_CDFS[dist.name]
    x = np.array([p[0] for p in pts])
    c = np.array([p[1] for p in pts])
    return np.interp(rng.random(n), c, x)


def mean_size(dist: SizeDist) -> float:
    """Analytic mean of ``dist`` (exact for each sampler)."""
    if dist.kind in ("fixed", "lognormal"):
        return float(dist.mean)
    if dist.kind == "pareto":
        a, lo, hi = dist.alpha, dist.lo, dist.hi
        if a == 1.0:
            return float(lo * hi / (hi - lo) * np.log(hi / lo))
        return float((a / (a - 1.0))
                     * (lo ** -(a - 1) - hi ** -(a - 1))
                     / (lo ** -a - hi ** -a))
    pts = EMPIRICAL_CDFS[dist.name]
    x = np.array([p[0] for p in pts])
    c = np.array([p[1] for p in pts])
    # linear-in-x interpolation within a segment -> segment mean is the
    # midpoint, weighted by the segment's probability mass
    return float(np.sum(np.diff(c) * (x[:-1] + x[1:]) / 2.0))


def poisson_arrivals(rate_hz: float, duration_s: float,
                     rng: np.random.Generator) -> np.ndarray:
    """Arrival times of a homogeneous Poisson process on [0, duration).

    Exponential inter-arrival sampling; the returned array is sorted and
    strictly inside the window.
    """
    if rate_hz <= 0 or duration_s <= 0:
        return np.zeros(0)
    # draw in chunks until the window is covered (expected count + slack)
    out: "list[np.ndarray]" = []
    t = 0.0
    while t < duration_s:
        n = max(int(rate_hz * (duration_s - t) * 1.5) + 16, 16)
        gaps = rng.exponential(1.0 / rate_hz, size=n)
        times = t + np.cumsum(gaps)
        out.append(times)
        t = float(times[-1])
    arr = np.concatenate(out)
    return arr[arr < duration_s]


def mmpp_arrivals(rate_hz: float, duration_s: float,
                  rng: np.random.Generator, burstiness: float = 4.0,
                  dwell_s: float = 0.01) -> np.ndarray:
    """2-state Markov-modulated Poisson process on [0, duration).

    The process alternates between a *calm* and a *burst* state with
    exponential dwell times of mean ``dwell_s``; the burst state's rate
    is ``burstiness`` times the calm state's, scaled so the long-run
    mean rate is ``rate_hz`` (equal expected dwell in both states).
    ``burstiness=1`` degenerates to plain Poisson.
    """
    if rate_hz <= 0 or duration_s <= 0:
        return np.zeros(0)
    b = max(float(burstiness), 1.0)
    # equal dwell -> mean rate = (r_lo + r_hi)/2 = rate_hz
    r_lo = 2.0 * rate_hz / (1.0 + b)
    r_hi = b * r_lo
    out: "list[np.ndarray]" = []
    t = 0.0
    state_hi = bool(rng.random() < 0.5)
    while t < duration_s:
        dwell = float(rng.exponential(dwell_s))
        end = min(t + dwell, duration_s)
        rate = r_hi if state_hi else r_lo
        seg = poisson_arrivals(rate, end - t, rng)
        out.append(t + seg)
        t = end
        state_hi = not state_hi
    arr = np.concatenate(out) if out else np.zeros(0)
    return np.sort(arr[arr < duration_s])
