"""Multi-tenant traffic mixing on one simulated fabric.

Three tenant kinds share the fabric:

* **serving** (:class:`~repro.workload.serving.ServingTenantSpec`) —
  open-loop prefill/decode KV-transfer flows;
* **training** (:class:`TrainingTenantSpec`) — a :mod:`repro.cosim`
  phase schedule: each step's collective phases become aggregated
  switch-pair flows (``phase_step_flows`` geometry x steps x calls)
  admitted at their analytic phase-start offsets, repeated per step —
  the open-loop view of a training job that keeps issuing on its
  isolated-schedule clock while contention shows up as slowdown;
* **background** (:class:`BackgroundTenantSpec`) — FatPaths-style
  point-to-point flows with empirical-CDF sizes between the tenant's
  own NICs.

Tenants get disjoint consecutive NIC blocks (allocation order = spec
order) over the fabric's NIC->switch map, and their flows run in ONE
:func:`repro.sim.events.simulate_incidence` call — every flow stamped
``tag=(tenant, key)`` so measured FCTs attribute back without index
arithmetic.  All planes are identical fabric copies under even spray,
so one plane simulates each flow's ``1/n_planes`` byte share at its
port-rate injection cap (the :mod:`repro.cosim.stepsim` batches idiom).

Per-tenant isolation baselines (each tenant alone on the fabric, same
seed-derived trace) give slowdown-vs-isolation; RNG is one
``SeedSequence(seed)`` spawning one child per tenant, so adding a
tenant never perturbs another tenant's trace.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.netsim import DEFAULT_NET, NetParams, _alpha, make_router
from repro.core.topology import Topology
from repro.cosim.placement import phase_step_flows, rank_to_switch
from repro.cosim.stepsim import analytic_phase_time
from repro.sim.events import FlowSpec, flows_to_demands, simulate_incidence
from repro.sim.fairshare import flow_incidence
from repro.telemetry import get_metrics, get_recorder
from .arrivals import SizeDist, mmpp_arrivals, poisson_arrivals, sample_sizes
from .serving import ServingTenantSpec, ServingWorkload, build_serving_workload


@dataclass(frozen=True)
class TrainingTenantSpec:
    """One training tenant: a co-sim job issuing its phase schedule."""

    name: str
    arch: str = "mixtral-8x22b"
    n_ranks: int = 16
    n_steps: int = 1
    shape: str = "train_4k"
    device_tflops: float = 989.0

    @property
    def n_nics(self) -> int:
        return self.n_ranks


@dataclass(frozen=True)
class BackgroundTenantSpec:
    """Open-loop point-to-point background flows between own NICs."""

    name: str
    rate_hz: float = 2000.0
    duration_s: float = 0.25
    arrival: str = "poisson"
    burstiness: float = 4.0
    size_bytes: SizeDist = field(
        default_factory=lambda: SizeDist("empirical", name="websearch"))
    n_nics: int = 8


TENANT_SPECS = (ServingTenantSpec, TrainingTenantSpec, BackgroundTenantSpec)


def tenant_of(tag) -> str:
    """Tenant name from a flow tag (``(tenant, key)`` tuple or bare)."""
    return tag[0] if isinstance(tag, tuple) else tag


def tenant_mask(res, name: str) -> np.ndarray:
    """(F,) bool — flows of a simulation belonging to tenant ``name``
    (via the opaque per-flow tags, never index arithmetic)."""
    if res.tags is None:
        raise ValueError("simulation was run without flow tags")
    return np.array([tenant_of(t) == name for t in res.tags], dtype=bool)


def tenant_kind(spec) -> str:
    if isinstance(spec, ServingTenantSpec):
        return "serving"
    if isinstance(spec, TrainingTenantSpec):
        return "training"
    if isinstance(spec, BackgroundTenantSpec):
        return "background"
    raise TypeError(f"unknown tenant spec type {type(spec).__name__}")


@dataclass
class TenantTraffic:
    """One tenant's materialized flows on the shared fabric clock."""

    name: str
    kind: str                      # serving | training | background
    flows: "list[FlowSpec]"        # full (all-planes) bytes
    caps_gbps: np.ndarray          # (F,) per-plane injection caps
    nic_base: int
    n_nics: int
    payload_bytes: float           # total tenant payload incl. intra
    serving: "ServingWorkload | None" = None
    meta: dict = field(default_factory=dict)


def training_traffic(spec: TrainingTenantSpec, topo: Topology,
                     switch_of_nic: np.ndarray, nic_base: int,
                     net: NetParams = DEFAULT_NET) -> TenantTraffic:
    """Aggregated per-phase flows of ``spec`` at analytic offsets.

    Each phase of each step becomes its steady-state switch-pair flows
    (:func:`~repro.cosim.placement.phase_step_flows`) carrying the FULL
    phase payload (``steps x calls`` times the per-step bytes), admitted
    at the phase's analytic start offset on the isolated schedule —
    so under zero contention the phases drain roughly on schedule, and
    a congested fabric shows up as per-flow slowdown.
    """
    from repro.experiments.cosuite import default_mesh
    from repro.models.registry import get_config
    from repro.cosim import job_from_model

    cfg = get_config(spec.arch)
    moe = cfg.moe
    mesh = default_mesh(spec.arch, spec.n_ranks,
                        moe.n_experts if moe is not None else None)
    job = job_from_model(cfg, shape=spec.shape, **mesh)
    need = nic_base + spec.n_ranks
    if need > switch_of_nic.shape[0]:
        raise ValueError(f"tenant {spec.name!r} needs NICs "
                         f"[{nic_base}, {need}) but fabric has "
                         f"{switch_of_nic.shape[0]}")
    switch_of = switch_of_nic[nic_base:need]
    compute_s = (6.0 * job.active_params * job.tokens_per_step
                 / (job.n_ranks * spec.device_tflops * 1e12))
    flows: "list[FlowSpec]" = []
    caps: "list[float]" = []
    payload = 0.0
    t = 0.0
    for step in range(spec.n_steps):
        for phase in job.phases:
            base, ring_steps, senders = phase_step_flows(
                phase, switch_of, job.n_ranks, start_s=t)
            scale = ring_steps * phase.calls
            for k, f in enumerate(base):
                flows.append(FlowSpec(
                    f.src, f.dst, f.size_bytes * scale, start_s=f.start_s,
                    tag=(spec.name, f"s{step}.{phase.name}.{k}")))
                caps.append(topo.port_gbps * float(senders[k]))
            payload += sum(f.size_bytes * scale for f in base)
            t += analytic_phase_time(topo, phase, net)
        t += compute_s
    return TenantTraffic(
        name=spec.name, kind="training", flows=flows,
        caps_gbps=np.asarray(caps, dtype=np.float64),
        nic_base=nic_base, n_nics=spec.n_ranks, payload_bytes=payload,
        meta={"mesh": dict(job.mesh), "n_steps": spec.n_steps,
              "compute_s": compute_s, "schedule_s": t})


def background_traffic(spec: BackgroundTenantSpec, topo: Topology,
                       switch_of_nic: np.ndarray, nic_base: int,
                       rng: np.random.Generator) -> TenantTraffic:
    """Point-to-point open-loop flows between the tenant's own NICs."""
    need = nic_base + spec.n_nics
    if need > switch_of_nic.shape[0]:
        raise ValueError(f"tenant {spec.name!r} needs NICs "
                         f"[{nic_base}, {need}) but fabric has "
                         f"{switch_of_nic.shape[0]}")
    if spec.arrival == "mmpp":
        arrival = mmpp_arrivals(spec.rate_hz, spec.duration_s, rng,
                                burstiness=spec.burstiness)
    else:
        arrival = poisson_arrivals(spec.rate_hz, spec.duration_s, rng)
    R = arrival.shape[0]
    sizes = sample_sizes(spec.size_bytes, R, rng)
    src_nic = rng.integers(0, spec.n_nics, size=R)
    # destination: a uniformly random OTHER nic of the block
    off = rng.integers(1, max(spec.n_nics, 2), size=R)
    dst_nic = (src_nic + off) % spec.n_nics
    sw = switch_of_nic[nic_base + np.arange(spec.n_nics)]
    flows: "list[FlowSpec]" = []
    caps: "list[float]" = []
    intra = 0.0
    for r in range(R):
        s, d = int(sw[src_nic[r]]), int(sw[dst_nic[r]])
        if s == d:
            intra += float(sizes[r])
            continue
        flows.append(FlowSpec(s, d, float(sizes[r]),
                              start_s=float(arrival[r]),
                              tag=(spec.name, r)))
        caps.append(topo.port_gbps)
    return TenantTraffic(
        name=spec.name, kind="background", flows=flows,
        caps_gbps=np.asarray(caps, dtype=np.float64),
        nic_base=nic_base, n_nics=spec.n_nics,
        payload_bytes=float(sizes.sum()),
        meta={"n_requests": int(R), "intra_bytes": intra})


def build_tenant_traffic(spec, topo: Topology, switch_of_nic: np.ndarray,
                         nic_base: int, rng: np.random.Generator,
                         net: NetParams = DEFAULT_NET) -> TenantTraffic:
    """Materialize one tenant's flows (dispatch on spec type)."""
    if isinstance(spec, ServingTenantSpec):
        w = build_serving_workload(spec, switch_of_nic, nic_base,
                                   topo.port_gbps, rng)
        return TenantTraffic(
            name=spec.name, kind="serving", flows=w.flows,
            caps_gbps=w.caps_gbps, nic_base=nic_base, n_nics=spec.n_nics,
            payload_bytes=w.offered_bytes(), serving=w,
            meta={"n_requests": w.n_requests,
                  "intra_bytes": w.intra_bytes})
    if isinstance(spec, TrainingTenantSpec):
        return training_traffic(spec, topo, switch_of_nic, nic_base, net)
    if isinstance(spec, BackgroundTenantSpec):
        return background_traffic(spec, topo, switch_of_nic, nic_base, rng)
    raise TypeError(f"unknown tenant spec type {type(spec).__name__}")


@dataclass
class MixResult:
    """Outcome of all tenants sharing one fabric.

    ``mixed`` is the shared-fabric simulation (tags = (tenant, key));
    ``isolated[name]`` re-runs that tenant's identical flow trace alone.
    ``alpha_local_s`` is the 2-hop intra-switch alpha used for requests
    whose shards never touched the fabric.
    """

    topology: str
    n_planes: int
    traffic: "list[TenantTraffic]"
    mixed: object                  # FlowSimResult
    isolated: dict                 # name -> FlowSimResult
    caps_gbps: np.ndarray          # (F,) concatenated per-plane caps
    alpha_local_s: float
    seed: int

    def tenant(self, name: str) -> TenantTraffic:
        for t in self.traffic:
            if t.name == name:
                return t
        raise KeyError(name)


def _simulate(router, flows, caps, n_planes, net, sim_backend):
    share = np.array([f.size_bytes for f in flows]) / n_planes
    starts = np.array([f.start_s for f in flows])
    tags = [f.tag for f in flows]
    dem = flows_to_demands(flows)
    inc = flow_incidence(router, dem, "minimal", cached=True)
    return simulate_incidence(inc, share, caps, start_s=starts, net=net,
                              backend=sim_backend, tags=tags)


def run_tenant_mix(topo: Topology, specs: "list", seed: int = 0,
                   engine: str = "auto", backend: str = "auto",
                   sim_backend: str = "numpy",
                   net: NetParams = DEFAULT_NET,
                   include_isolated: bool = True,
                   router=None) -> MixResult:
    """Simulate all tenants sharing ``topo``; per-tenant isolation too.

    Raises :class:`ValueError` when the tenants' NIC demand exceeds the
    fabric (the suite turns that into an explicit skip record).
    """
    if router is None:
        router = make_router(topo, backend=backend, engine=engine)
    switch_of = rank_to_switch(topo, getattr(router, "graph", None))
    children = np.random.SeedSequence(seed).spawn(len(specs))
    traffic: "list[TenantTraffic]" = []
    base = 0
    for spec, child in zip(specs, children):
        rng = np.random.default_rng(child)
        t = build_tenant_traffic(spec, topo, switch_of, base, rng, net)
        traffic.append(t)
        base += t.n_nics
    all_flows = [f for t in traffic for f in t.flows]
    if not all_flows:
        raise ValueError("tenant mix produced no fabric flows")
    caps = np.concatenate([t.caps_gbps for t in traffic])
    mx = get_metrics()
    rec = get_recorder()
    mixed = _simulate(router, all_flows, caps, topo.n_planes, net,
                      sim_backend)
    for t in traffic:
        mx.inc(f"workload.flows.{t.name}", len(t.flows))
        mx.inc(f"workload.bytes.{t.name}", t.payload_bytes)
        if t.serving is not None:
            mx.inc(f"workload.requests.{t.name}", t.serving.n_requests)
    mx.inc("workload.mixes")
    if rec is not None and all_flows:
        proc = f"workload:{topo.name}"
        for t in traffic:
            m = tenant_mask(mixed, t.name)
            fin = mixed.finish_s[m]
            fin = fin[np.isfinite(fin)]
            if fin.size:
                t0 = float(mixed.start_s[m].min())
                rec.span(t.name, t0, float(fin.max()) - t0,
                         process=proc, thread=t.kind, cat="tenant",
                         args={"flows": int(m.sum()),
                               "bytes": t.payload_bytes})
    isolated: dict = {}
    if include_isolated:
        off = 0
        for t in traffic:
            n = len(t.flows)
            if n:
                isolated[t.name] = _simulate(
                    router, t.flows, caps[off:off + n], topo.n_planes,
                    net, sim_backend)
            off += n
    return MixResult(
        topology=topo.name, n_planes=topo.n_planes, traffic=traffic,
        mixed=mixed, isolated=isolated, caps_gbps=caps,
        alpha_local_s=_alpha(topo, 2.0, net), seed=seed)
