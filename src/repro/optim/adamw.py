"""AdamW optimizer, built from scratch in JAX (no optax).

State dtype is configurable: the 1T-param Kimi config uses bf16 moments to
fit HBM (EXPERIMENTS.md §Dry-run records the memory trade-off); master
weights (fp32 copies of bf16 params) are optional.

State layout mirrors the param pytree leaf-for-leaf, so every moment tensor
inherits the param's sharding (ZeRO: the optimizer step is fully sharded,
no replicated state anywhere).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig


class AdamWState(NamedTuple):
    step: jnp.ndarray           # () int32
    m: Any                      # pytree like params
    v: Any
    master: Any | None          # fp32 params if enabled


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: str = "float32"
    master_weights: bool = False
    grad_clip_norm: float | None = 1.0

    @staticmethod
    def from_run(run: RunConfig) -> "AdamW":
        return AdamW(lr=run.lr, beta1=run.beta1, beta2=run.beta2,
                     eps=run.eps, weight_decay=run.weight_decay,
                     state_dtype=run.adam_dtype,
                     master_weights=run.master_weights)

    def init(self, params) -> AdamWState:
        dt = jnp.dtype(self.state_dtype)
        zeros = lambda p: jnp.zeros(p.shape, dt)
        master = jax.tree.map(lambda p: p.astype(jnp.float32), params) \
            if self.master_weights else None
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params),
                          master=master)

    def _decayed(self, path) -> bool:
        """No weight decay on norms/biases (1-d leaves handled by caller)."""
        from repro.models.sharding import path_str
        s = path_str(path)
        return not any(t in s for t in ("norm", "bias", "b_gates", "ba",
                                        "bg", "lam"))

    def update(self, grads, state: AdamWState, params, lr_scale=1.0):
        """Returns (new_params, new_state).  ``lr_scale`` comes from the LR
        schedule (traced scalar ok)."""
        step = state.step + 1
        b1, b2 = self.beta1, self.beta2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self.lr * lr_scale
        dt = jnp.dtype(self.state_dtype)

        if self.grad_clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.grad_clip_norm
                                / jnp.maximum(gnorm, 1e-12))
        else:
            gnorm = jnp.zeros(())
            scale = 1.0

        base = state.master if self.master_weights else params

        def leaf_update(path, g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
            v32 = v.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g)
            mhat = m32 / bc1
            vhat = v32 / bc2
            upd = mhat / (jnp.sqrt(vhat) + self.eps)
            p32 = p.astype(jnp.float32)
            if p.ndim >= 2 and self.weight_decay and self._decayed(path):
                upd = upd + self.weight_decay * p32
            p32 = p32 - lr * upd
            return p32, m32.astype(dt), v32.astype(dt)

        flat = jax.tree_util.tree_map_with_path(
            leaf_update, grads, state.m, state.v, base)
        new_base = jax.tree.map(lambda t: t[0], flat,
                                is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        if self.master_weights:
            new_params = jax.tree.map(
                lambda b, p: b.astype(p.dtype), new_base, params)
            new_state = AdamWState(step, new_m, new_v, new_base)
        else:
            new_params = jax.tree.map(
                lambda b, p: b.astype(p.dtype), new_base, params)
            new_state = AdamWState(step, new_m, new_v, None)
        return new_params, new_state, {"grad_norm": gnorm}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))
