"""Pure-jnp oracle for the fused RMSNorm kernel."""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def rmsnorm_ref(x, scale, eps: float = 1e-6):
    """x: (N, D); scale: (D,) -> (N, D), fp32 accumulation."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * lax.rsqrt(var + eps) *
            scale.astype(jnp.float32)).astype(x.dtype)
