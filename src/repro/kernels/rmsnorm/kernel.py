"""Pallas TPU fused RMSNorm kernel.

Bandwidth-bound: one pass over x per row block.  Grid (N/bn,); each step
loads a (bn, D) tile into VMEM, computes the row rms in fp32, scales, and
writes back — no HBM round-trip for the variance (what the unfused jnp
version pays).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float, d: int):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.sum(x * x, axis=-1, keepdims=True) / d
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x, scale, *, eps: float = 1e-6, block_n: int = 256,
            interpret: bool = True):
    """x: (N, D); scale: (D,)."""
    N, D = x.shape
    bn = min(block_n, N)
    pn = (-N) % bn
    if pn:
        x = jnp.pad(x, ((0, pn), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps, d=D),
        grid=((N + pn) // bn,),
        in_specs=[
            pl.BlockSpec((bn, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N + pn, D), x.dtype),
        interpret=interpret,
    )(x, scale)
    return out[:N]
