"""jit'd wrapper for the fused RMSNorm kernel (model layout (B,S,d))."""

from __future__ import annotations

import functools

import jax

from .kernel import rmsnorm


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm_model_layout(x, scale, *, eps: float = 1e-6,
                         interpret: bool = True):
    B, S, d = x.shape
    return rmsnorm(x.reshape(B * S, d), scale, eps=eps,
                   interpret=interpret).reshape(B, S, d)
