from .kernel import rmsnorm
from .ops import rmsnorm_model_layout
from .ref import rmsnorm_ref

__all__ = ["rmsnorm", "rmsnorm_model_layout", "rmsnorm_ref"]
