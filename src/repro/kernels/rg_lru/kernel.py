"""Pallas TPU kernel for the RG-LRU linear recurrence (Griffin,
RecurrentGemma's temporal-mixing hot loop).

h_t = a_t * h_{t-1} + b_t, elementwise over the width dim.

Schedule: grid (B, W/bw, S/chunk) with the chunk axis innermost
(sequential); the running state h lives in a VMEM scratch tile (bw,) that
persists across chunk steps.  Inside a chunk we unroll a fori_loop over
time — each step is a fused multiply-add over the width tile (VPU work;
there is no MXU here, the kernel is bandwidth-bound, so the tiling goal is
purely to stream a/b through VMEM in large contiguous blocks).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lru_kernel(a_ref, b_ref, h0_ref, y_ref, h_scr, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _():
        h_scr[...] = h0_ref[...][0]

    def step(t, h):
        h = a_ref[0, t] * h + b_ref[0, t]
        y_ref[0, t] = h
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h


def lru_scan(a, b, h0=None, *, chunk: int = 128, block_w: int = 512,
             interpret: bool = True):
    """a, b: (B, S, W) float32; h0 (B, W) -> (y (B,S,W), h_last (B,W)).

    The final state is returned by reading the last time row of y.
    """
    B, S, W = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)
    bw = min(block_w, W)
    ck = min(chunk, S)
    pw, ps = (-W) % bw, (-S) % ck
    if pw or ps:
        a = jnp.pad(a, ((0, 0), (0, ps), (0, pw)))
        # pad b with zeros and a with ones so padded steps keep state
        a = a.at[:, S:, :].set(1.0) if ps else a
        b = jnp.pad(b, ((0, 0), (0, ps), (0, pw)))
        h0 = jnp.pad(h0, ((0, 0), (0, pw)))
    gs = (S + ps) // ck
    gw = (W + pw) // bw

    y = pl.pallas_call(
        functools.partial(_lru_kernel, chunk=ck),
        grid=(B, gw, gs),
        in_specs=[
            pl.BlockSpec((1, ck, bw), lambda bi, wi, ci: (bi, ci, wi)),
            pl.BlockSpec((1, ck, bw), lambda bi, wi, ci: (bi, ci, wi)),
            pl.BlockSpec((1, bw), lambda bi, wi, ci: (bi, wi)),
        ],
        out_specs=pl.BlockSpec((1, ck, bw), lambda bi, wi, ci: (bi, ci, wi)),
        out_shape=jax.ShapeDtypeStruct((B, S + ps, W + pw), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    y = y[:, :S, :W]
    return y, y[:, -1, :]
