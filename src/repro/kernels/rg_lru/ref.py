"""Pure-jnp oracle for the RG-LRU scan kernel: sequential linear recurrence
h_t = a_t * h_{t-1} + b_t over time."""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def lru_scan_ref(a, b, h0=None):
    """a, b: (B, S, W) float32 -> (y (B,S,W), h_last (B,W))."""
    B, S, W = a.shape
    h = h0 if h0 is not None else jnp.zeros((B, W), jnp.float32)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    h, ys = lax.scan(step, h, (a.swapaxes(0, 1), b.swapaxes(0, 1)))
    return ys.swapaxes(0, 1), h
