"""jit'd wrapper: full RG-LRU block (gates computed in jnp, scan in Pallas)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.rglru import _rg_lru_coeffs
from .kernel import lru_scan


@functools.partial(jax.jit, static_argnames=("chunk", "block_w", "interpret"))
def rg_lru_pallas(params, x, h0=None, *, chunk: int = 128,
                  block_w: int = 512, interpret: bool = True):
    """Drop-in replacement for repro.models.rglru.rg_lru_scan (fwd only)."""
    a, bcoef, _ = _rg_lru_coeffs(params, x)
    return lru_scan(a, bcoef, h0, chunk=chunk, block_w=block_w,
                    interpret=interpret)
