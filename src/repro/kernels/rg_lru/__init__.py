from .kernel import lru_scan
from .ops import rg_lru_pallas
from .ref import lru_scan_ref

__all__ = ["lru_scan", "rg_lru_pallas", "lru_scan_ref"]
