"""Pure-jnp oracle for the COO segment reductions of the water-filling
solver (`jax.ops.segment_sum` / `segment_min` — the XLA scatter path the
Pallas kernel must reproduce bit-for-near-bit)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum_ref(values, segment_ids, num_segments: int):
    """(NNZ,) values scatter-added into (num_segments,) bins."""
    return jax.ops.segment_sum(values, segment_ids,
                               num_segments=num_segments)


def segment_min_ref(values, segment_ids, num_segments: int):
    """(NNZ,) values segment-min'd into (num_segments,) bins; empty
    segments hold +inf (the water-filling 'no constraint' identity)."""
    init = jnp.full(num_segments, jnp.inf, dtype=values.dtype)
    return init.at[segment_ids].min(values)
