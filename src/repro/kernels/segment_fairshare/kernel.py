"""Pallas segment-reduce kernels for the COO flow-link incidence tensor.

The max-min water-filling solver (:mod:`repro.sim.fairshare`) spends its
rounds in two sparse reductions over the coalesced COO incidence arrays:

* ``segment_sum``  — per-edge live weight ``sum_f frac[f,e]`` (and the
  per-flow saturated-fraction sum on the freeze step);
* ``segment_min``  — per-flow bottleneck ``min_e cap[e]/frac[f,e]``.

Both are scatter reductions with data-dependent indices, which TPUs hate
in their natural form.  The kernels below recast them as **one-hot
contractions**: the grid tiles (segment blocks x entry blocks), each step
builds a ``(block_nnz, block_seg)`` one-hot mask of which entries land in
this segment tile and reduces it on the VPU/MXU, accumulating into the
resident output tile across the entry-block axis (the classic Pallas
revisiting-output accumulation pattern; the entry axis is innermost so
each output tile is initialized once at entry-block 0 and stays in VMEM).

Entries are padded with an out-of-range segment id, so padding never hits
a real bin.  ``interpret=True`` (the default) runs the same kernel on CPU
via the Pallas interpreter at float64 — the cross-validation fallback the
test layer uses; pass ``interpret=False`` on a real TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _onehot(ids, lo, block_seg: int, dtype):
    """(bn, block_seg) mask of entries whose segment falls in this tile."""
    local = ids - lo
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, block_seg), 1)
    return (local[:, None] == iota).astype(dtype)


def _segment_sum_kernel(ids_ref, val_ref, o_ref, *, block_seg: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ids = ids_ref[...]
    vals = val_ref[...]
    hot = _onehot(ids, pl.program_id(0) * block_seg, block_seg, vals.dtype)
    o_ref[...] += (vals[:, None] * hot).sum(axis=0)


def _segment_min_kernel(ids_ref, val_ref, o_ref, *, block_seg: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, jnp.inf)

    ids = ids_ref[...]
    vals = val_ref[...]
    hot = _onehot(ids, pl.program_id(0) * block_seg, block_seg,
                  vals.dtype) > 0
    cand = jnp.where(hot, vals[:, None], jnp.inf).min(axis=0)
    o_ref[...] = jnp.minimum(o_ref[...], cand)


def _pad_coo(values, segment_ids, num_segments: int, block_nnz: int):
    n = values.shape[0]
    bn = max(min(block_nnz, n), 1)
    pad = (-n) % bn if n else bn
    if pad:
        values = jnp.pad(values, (0, pad))
        # out-of-range id: the padded entries miss every segment tile
        segment_ids = jnp.pad(segment_ids, (0, pad),
                              constant_values=num_segments)
    return values, segment_ids.astype(jnp.int32), bn


def _segment_call(kernel, values, segment_ids, num_segments: int,
                  block_nnz: int, block_seg: int, interpret: bool):
    values, ids, bn = _pad_coo(values, segment_ids, num_segments, block_nnz)
    bs = max(min(block_seg, num_segments), 1)
    ps = (-num_segments) % bs
    grid = ((num_segments + ps) // bs, values.shape[0] // bn)
    out = pl.pallas_call(
        functools.partial(kernel, block_seg=bs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn,), lambda s, i: (i,)),
            pl.BlockSpec((bn,), lambda s, i: (i,)),
        ],
        out_specs=pl.BlockSpec((bs,), lambda s, i: (s,)),
        out_shape=jax.ShapeDtypeStruct((num_segments + ps,), values.dtype),
        interpret=interpret,
    )(ids, values)
    return out[:num_segments]


def segment_sum(values, segment_ids, num_segments: int, *,
                block_nnz: int = 1024, block_seg: int = 512,
                interpret: bool = True):
    """Scatter-add ``values`` (NNZ,) into ``num_segments`` bins."""
    if num_segments == 0:
        return jnp.zeros((0,), dtype=values.dtype)
    return _segment_call(_segment_sum_kernel, values, segment_ids,
                         num_segments, block_nnz, block_seg, interpret)


def segment_min(values, segment_ids, num_segments: int, *,
                block_nnz: int = 1024, block_seg: int = 512,
                interpret: bool = True):
    """Per-segment min of ``values`` (NNZ,); empty segments hold +inf."""
    if num_segments == 0:
        return jnp.zeros((0,), dtype=values.dtype)
    return _segment_call(_segment_min_kernel, values, segment_ids,
                         num_segments, block_nnz, block_seg, interpret)
