"""COO segment reductions for the water-filling solver (Pallas)."""

from .kernel import segment_min, segment_sum
from .ops import coo_segment_min, coo_segment_sum
from .ref import segment_min_ref, segment_sum_ref

__all__ = ["segment_sum", "segment_min", "coo_segment_sum",
           "coo_segment_min", "segment_sum_ref", "segment_min_ref"]
