"""jit'd wrappers for the segment-reduce kernels (static segment count)."""

from __future__ import annotations

import functools

import jax

from .kernel import segment_min, segment_sum


@functools.partial(jax.jit, static_argnames=("num_segments", "interpret"))
def coo_segment_sum(values, segment_ids, *, num_segments: int,
                    interpret: bool = True):
    return segment_sum(values, segment_ids, num_segments,
                       interpret=interpret)


@functools.partial(jax.jit, static_argnames=("num_segments", "interpret"))
def coo_segment_min(values, segment_ids, *, num_segments: int,
                    interpret: bool = True):
    return segment_min(values, segment_ids, num_segments,
                       interpret=interpret)
