"""Pallas TPU flash-attention kernel (blockwise online softmax).

Grid: (B, H, n_q_blocks, n_kv_blocks) — the kv axis is the innermost
(sequential) dimension; running max / denominator / accumulator live in
VMEM scratch and persist across kv steps (the canonical TPU flash
schedule).  BlockSpecs tile q/k/v/o into VMEM with MXU-aligned
(block, head_dim) tiles; GQA is expressed in the k/v index_map
(query head h reads kv head h // G), so no repeated KV is ever
materialized.

Supports causal and sliding-window masks.  Fully-masked kv blocks are
skipped via ``pl.when`` (their compute is predicated off — on TPU this
saves the MXU issue; in interpret mode it just skips the branch).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int | None,
                 block_q: int, block_k: int, sq: int, skv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    n_kv = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # absolute positions (queries right-aligned to the kv tail: decode-safe)
    q_off = skv - sq
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + q_off
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # block-level skip test (static per (qi, ki) under causal/window)
    blk_q_min = qi * block_q + q_off
    blk_q_max = blk_q_min + block_q - 1
    blk_k_min = ki * block_k
    run = True
    if causal:
        run = blk_k_min <= blk_q_max
    if window is not None:
        run = jnp.logical_and(run,
                              (blk_q_min - (blk_k_min + block_k - 1))
                              < window)

    @pl.when(run if not isinstance(run, bool) else True)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, (q_pos - k_pos) < window)
        # out-of-range padding rows/cols
        mask = jnp.logical_and(mask, k_pos < skv)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1)
        m_scr[...] = m_new
        v = v_ref[0, 0].astype(jnp.float32)              # (bk, d)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv

    @pl.when(ki == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q: (B, H, Sq, Dh); k, v: (B, K, Skv, Dh); GQA via H % K == 0."""
    B, H, Sq, Dh = q.shape
    K, Skv = k.shape[1], k.shape[2]
    if H % K:
        raise ValueError("H must be a multiple of K")
    G = H // K
    scale = 1.0 / math.sqrt(Dh)

    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Skv, 8))
    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    n_q = (Sq + pad_q) // block_q
    n_k = (Skv + pad_k) // block_k

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, sq=Sq, skv=Skv)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, Dh),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, Dh),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, block_k, Dh),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dh),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq + pad_q, Dh), q.dtype),
        scratch_shapes=[
            # (bq,) running max / denom, (bq, Dh) accumulator — fp32 VMEM
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
