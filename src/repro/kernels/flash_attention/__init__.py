from .kernel import flash_attention
from .ops import flash_attention_kernel_layout, flash_attention_model_layout
from .ref import attention_ref

__all__ = ["flash_attention", "flash_attention_kernel_layout",
           "flash_attention_model_layout", "attention_ref"]
