"""Pure-jnp oracle for the flash-attention kernel.

Layout (kernel-native): q (B, H, Sq, Dh); k, v (B, K, Skv, Dh) with GQA
grouping G = H // K (query head h reads kv head h // G).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True,
                  window: int | None = None) -> jnp.ndarray:
    B, H, Sq, Dh = q.shape
    K = k.shape[1]
    G = H // K
    scale = 1.0 / math.sqrt(Dh)
    kk = jnp.repeat(k, G, axis=1)          # (B, H, Skv, Dh)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    Skv = k.shape[2]
    qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)   # right-aligned (decode ok)
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
