"""jit'd public wrapper for the flash-attention kernel.

Accepts the model-layout tensors q (B, S, K, G, Dh), k/v (B, S, K, Dh)
(as produced by ``repro.models.layers.mha_project_qkv``) and handles the
transpose to kernel layout, dtype preservation, and block-size selection.
``interpret=True`` is the validated CPU path; on real TPU pass
``interpret=False``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention_model_layout(q, k, v, *, causal: bool = True,
                                 window: int | None = None,
                                 block_q: int = 128, block_k: int = 128,
                                 interpret: bool = True):
    """q: (B, S, K, G, Dh); k, v: (B, S, K, Dh) -> (B, S, K, G, Dh)."""
    B, S, K, G, Dh = q.shape
    qk = q.transpose(0, 2, 3, 1, 4).reshape(B, K * G, S, Dh)
    kk = k.transpose(0, 2, 1, 3)
    vv = v.transpose(0, 2, 1, 3)
    o = flash_attention(qk, kk, vv, causal=causal, window=window,
                        block_q=block_q, block_k=block_k,
                        interpret=interpret)
    return o.reshape(B, K, G, S, Dh).transpose(0, 3, 1, 2, 4)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention_kernel_layout(q, k, v, *, causal: bool = True,
                                  window: int | None = None,
                                  block_q: int = 128, block_k: int = 128,
                                  interpret: bool = True):
    """q: (B, H, Sq, Dh); k, v: (B, K, Skv, Dh)."""
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=block_q, block_k=block_k,
                           interpret=interpret)
