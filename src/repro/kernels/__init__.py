"""Pallas TPU kernels for the perf-critical compute layers.

Each subpackage: kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd wrapper), ref.py (pure-jnp oracle).  All validated in
interpret=True mode on CPU (tests/test_kernels.py); pass interpret=False
on real TPU.  The dry-run / cost-analysis paths use the jnp reference
implementations so HLO FLOP counts stay visible (DESIGN.md §6).
"""
