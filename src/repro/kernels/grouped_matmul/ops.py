"""jit'd wrappers for the grouped-matmul kernels (MoE expert GEMMs)."""

from __future__ import annotations

import functools

import jax

from .kernel import grouped_matmul, ragged_grouped_matmul


@functools.partial(jax.jit, static_argnames=("interpret",))
def expert_ffn_matmul(x, w, *, interpret: bool = True):
    """(E, C, d) x (E, d, f) -> (E, C, f); drop-in for the einsums in
    repro.models.moe._expert_ffn."""
    return grouped_matmul(x, w, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def megablocks_matmul(x, w, group_sizes, *, interpret: bool = True):
    """Ragged (T, K) x per-group (E, K, N) -> (T, N)."""
    return ragged_grouped_matmul(x, w, group_sizes, interpret=interpret)
