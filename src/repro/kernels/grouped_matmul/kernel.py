"""Pallas TPU grouped matmul (MoE expert GEMMs).

Two variants:

* :func:`grouped_matmul` — dense-batched (E, M, K) x (E, K, N): grid
  (E, M/bm, N/bn, K/bk) with an fp32 VMEM accumulator tile; the K axis is
  innermost/sequential, M/N parallel.  This is the compute core of
  ``repro.models.moe._expert_ffn`` (capacity-padded buffers).
* :func:`ragged_grouped_matmul` — MegaBlocks-style: rows of x (T, K) sorted
  by expert with ``group_sizes`` (E,); each (row-block, expert) pair is
  mapped through a precomputed block->group table (scalar-prefetch
  analogue, computed on host side of the call); rows outside their group's
  range are masked.  Avoids compute on capacity padding entirely.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc, *, n_k: int):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _():
        o_ref[0] = acc[...].astype(o_ref.dtype)


def grouped_matmul(x, w, *, block_m: int = 128, block_n: int = 128,
                   block_k: int = 128, interpret: bool = True):
    """x: (E, M, K); w: (E, K, N) -> (E, M, N)."""
    E, M, K = x.shape
    _, _, N = w.shape
    bm, bn, bk = (min(block_m, M), min(block_n, N), min(block_k, K))
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        x = jnp.pad(x, ((0, 0), (0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, 0), (0, pk), (0, pn)))
    gm, gn, gk = (M + pm) // bm, (N + pn) // bn, (K + pk) // bk

    out = pl.pallas_call(
        functools.partial(_gmm_kernel, n_k=gk),
        grid=(E, gm, gn, gk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda e, mi, ni, ki: (e, mi, ki)),
            pl.BlockSpec((1, bk, bn), lambda e, mi, ni, ki: (e, ki, ni)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, mi, ni, ki: (e, mi, ni)),
        out_shape=jax.ShapeDtypeStruct((E, M + pm, N + pn), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
    return out[:, :M, :N]


def _ragged_kernel(gid_ref, start_ref, size_ref, x_ref, w_ref, o_ref, acc,
                   *, block_m: int, n_k: int):
    mi = pl.program_id(0)
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _():
        # mask rows that belong to a different group than this block's owner
        row = mi * block_m + jax.lax.broadcasted_iota(
            jnp.int32, (block_m, 1), 0)
        g0 = start_ref[mi]
        g1 = g0 + size_ref[mi]
        ok = jnp.logical_and(row >= g0, row < g1)
        o_ref[...] = jnp.where(ok, acc[...], 0.0).astype(o_ref.dtype)


def ragged_grouped_matmul(x, w, group_sizes, *, block_m: int = 128,
                          block_k: int = 128, interpret: bool = True):
    """x: (T, K) rows sorted by group; w: (E, K, N); group_sizes: (E,).

    The block->group table is a scalar-prefetch operand: the w BlockSpec's
    index_map reads ``gid[mi]`` so each row block streams exactly its own
    expert's weights — no compute on other experts, no gather of w.
    Each row block is owned by the group of its FIRST row; foreign rows in
    the block are masked.  Callers that pad every group to a multiple of
    ``block_m`` (as the MoE capacity buffers do) get exact ownership.
    """
    T, K = x.shape
    E, _, N = w.shape
    bm = min(block_m, T)
    bk = min(block_k, K)
    pm, pk = (-T) % bm, (-K) % bk
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk:
        w = jnp.pad(w, ((0, 0), (0, pk), (0, 0)))
    gm, gk = (T + pm) // bm, (K + pk) // bk

    # host-side block->group table (scalar prefetch)
    ends = jnp.cumsum(group_sizes)
    starts = ends - group_sizes
    block_first_row = jnp.arange(gm) * bm
    gid = jnp.sum(block_first_row[:, None] >= ends[None, :],
                  axis=1).astype(jnp.int32)              # (gm,)
    gid = jnp.minimum(gid, E - 1)
    blk_start = starts[gid].astype(jnp.int32)
    blk_size = group_sizes[gid].astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(gm, gk),
        in_specs=[
            pl.BlockSpec((bm, bk),
                         lambda mi, ki, gid, start, size: (mi, ki)),
            pl.BlockSpec((1, bk, N),
                         lambda mi, ki, gid, start, size: (gid[mi], ki, 0)),
        ],
        out_specs=pl.BlockSpec((bm, N),
                               lambda mi, ki, gid, start, size: (mi, 0)),
        scratch_shapes=[pltpu.VMEM((bm, N), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_ragged_kernel, block_m=bm, n_k=gk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T + pm, N), x.dtype),
        interpret=interpret,
    )(gid, blk_start, blk_size, x, w)
    return out[:T]
