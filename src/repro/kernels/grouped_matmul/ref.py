"""Pure-jnp oracle for the grouped (per-expert) matmul kernel."""

from __future__ import annotations

import jax.numpy as jnp


def grouped_matmul_ref(x, w):
    """x: (E, M, K); w: (E, K, N) -> (E, M, N) per-group matmul."""
    return jnp.einsum("emk,ekn->emn", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


def ragged_grouped_matmul_ref(x, w, group_sizes):
    """MegaBlocks-style ragged: x (T, K) rows sorted by group; w (E, K, N);
    group_sizes (E,) with sum == T.  Returns (T, N)."""
    import numpy as np
    T = x.shape[0]
    out = jnp.zeros((T, w.shape[2]), jnp.float32)
    start = 0
    for e, size in enumerate(np.asarray(group_sizes)):
        if size:
            out = out.at[start:start + size].set(
                x[start:start + size].astype(jnp.float32) @
                w[e].astype(jnp.float32))
        start += size
    return out.astype(x.dtype)
