from .kernel import grouped_matmul, ragged_grouped_matmul
from .ops import expert_ffn_matmul, megablocks_matmul
from .ref import grouped_matmul_ref, ragged_grouped_matmul_ref

__all__ = ["grouped_matmul", "ragged_grouped_matmul", "expert_ffn_matmul",
           "megablocks_matmul", "grouped_matmul_ref",
           "ragged_grouped_matmul_ref"]
