"""Sharded checkpointing with elastic resharding and async save.

Design (fault tolerance, DESIGN.md §5):

* **Layout**: one ``.npz`` per host process holding that host's shard of
  every leaf, plus a JSON manifest (step, tree structure, global shapes,
  mesh shape, PartitionSpecs).  In this single-process container there is
  one shard file; the format is multi-host ready (``process_index`` key).
* **Resharding restore**: the loader reassembles global arrays from shard
  files and re-shards onto the CURRENT mesh — which may be a different shape
  than at save time (elastic restart after node loss: 2x16x16 -> 16x16, or
  16x16 -> 15x16 is rejected with a clear error since the axes must stay
  rectangular; use fault.plan_remesh to pick a feasible shape).
* **Async save**: snapshot to host memory synchronously (cheap), write to
  disk on a background thread so the train loop keeps stepping.
* **Integrity**: every file carries a content checksum; restore verifies.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import threading
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


SEP = "/"


def flatten_with_paths(tree) -> dict[str, Any]:
    flat = {}

    def visit(path, leaf):
        from repro.models.sharding import path_str
        flat[path_str(path)] = leaf

    jax.tree_util.tree_map_with_path(visit, tree)
    return flat


def unflatten_like(template, flat: dict[str, Any]):
    from repro.models.sharding import path_str

    def pick(path, tleaf):
        key = path_str(path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        return flat[key]

    return jax.tree_util.tree_map_with_path(pick, template)


def _to_savable(arr: np.ndarray) -> np.ndarray:
    """npz can't store ml_dtypes (bfloat16 etc.); store a raw bit view."""
    try:
        np.dtype(arr.dtype.name)  # raises for non-native dtypes
        return arr
    except TypeError:
        return arr.view(np.dtype(f"u{arr.dtype.itemsize}"))


def _from_savable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if str(arr.dtype) == dtype_name:
        return arr
    import ml_dtypes  # registers bfloat16/fp8 with numpy  # noqa: F401
    return arr.view(np.dtype(dtype_name))


def _checksum(arrays: dict[str, np.ndarray]) -> str:
    h = hashlib.sha256()
    for k in sorted(arrays):
        h.update(k.encode())
        h.update(np.ascontiguousarray(arrays[k]).tobytes()[:1 << 20])
    return h.hexdigest()[:16]


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # ------------------------------------------------------------- save ----

    def save(self, step: int, tree, blocking: bool = True,
             extra: dict | None = None) -> str:
        """Snapshot ``tree`` (params/opt state pytree) at ``step``."""
        flat = flatten_with_paths(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}
        manifest = {
            "step": int(step),
            "time": time.time(),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in host.items()},
            "process_index": jax.process_index(),
            "process_count": jax.process_count(),
            "extra": extra or {},
            "checksum": _checksum(host),
        }
        path = os.path.join(self.dir, f"step_{step:08d}")
        if blocking:
            self._write(path, host, manifest)
        else:
            self.wait()  # one in-flight save at a time
            self._thread = threading.Thread(
                target=self._write_safe, args=(path, host, manifest),
                daemon=True)
            self._thread.start()
        return path

    def _write_safe(self, path, host, manifest):
        try:
            self._write(path, host, manifest)
        except Exception as e:  # surfaced on next wait()
            self._error = e

    def _write(self, path, host, manifest):
        os.makedirs(path, exist_ok=True)
        shard = os.path.join(path, f"shard_{manifest['process_index']}.npz")
        tmp = shard + ".tmp"
        with open(tmp, "wb") as f:
            np.savez(f, **{k: _to_savable(v) for k, v in host.items()})
        os.replace(tmp, shard)
        mpath = os.path.join(path, "manifest.json")
        with open(mpath + ".tmp", "w") as f:
            json.dump(manifest, f)
        os.replace(mpath + ".tmp", mpath)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _gc(self):
        steps = self.list_steps()
        for s in steps[:-self.keep]:
            import shutil
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore ----

    def list_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, "manifest.json")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: int | None = None,
                shardings=None, verify: bool = True):
        """Restore into the structure of ``template``.

        ``shardings``: optional pytree of NamedSharding for the CURRENT mesh
        — enables elastic resharding (save mesh != load mesh).
        Returns (tree, step).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        host: dict[str, np.ndarray] = {}
        for name in os.listdir(path):
            if name.startswith("shard_") and name.endswith(".npz"):
                with np.load(os.path.join(path, name)) as z:
                    for k in z.files:
                        host[k] = _from_savable(
                            z[k], manifest["leaves"][k]["dtype"])
        if verify and _checksum(host) != manifest["checksum"]:
            raise IOError(f"checkpoint {path} failed checksum")

        flat_shard = flatten_with_paths(shardings) if shardings is not None \
            else None

        def restore_leaf(key, tleaf):
            arr = host[key]
            if tuple(arr.shape) != tuple(tleaf.shape):
                raise ValueError(
                    f"{key}: saved {arr.shape} != expected {tleaf.shape}")
            if flat_shard is not None:
                return jax.device_put(arr, flat_shard[key])
            return jnp.asarray(arr, dtype=tleaf.dtype)

        flat_t = flatten_with_paths(template)
        flat_new = {k: restore_leaf(k, v) for k, v in flat_t.items()}
        return unflatten_like(template, flat_new), manifest["step"]
