"""Fault tolerance: failure detection, elastic remeshing, straggler
mitigation.

The paper's multi-plane design is itself a fault-tolerance story at the
*network* level ("driven by considerations such as fault tolerance, NICs ...
are equipped with multiple ports", §2): a dead plane degrades bandwidth to
(n-1)/n instead of killing the job (core/planes.plane_failure_degradation).
This module is the *job* level counterpart:

* :class:`HeartbeatMonitor` — declares ranks dead after a missed-beat
  timeout (injectable clock for tests).
* :func:`plan_remesh` — after losing hosts, pick the largest feasible
  rectangular mesh that preserves the model axis (TP degree must not change
  — param shards must stay valid), shrinking data/pod axes; the checkpoint
  is then restored with the new shardings (train/checkpoint.py).
* :class:`StragglerMonitor` — EMA/z-score step-time outlier detection, the
  signal used to evict or re-spray a slow host.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field


class HeartbeatMonitor:
    def __init__(self, ranks: int, timeout_s: float = 30.0, clock=time.time):
        self.timeout = timeout_s
        self.clock = clock
        self.last = {r: clock() for r in range(ranks)}

    def beat(self, rank: int):
        self.last[rank] = self.clock()

    def dead(self) -> list[int]:
        now = self.clock()
        return [r for r, t in self.last.items() if now - t > self.timeout]

    def alive(self) -> list[int]:
        now = self.clock()
        return [r for r, t in self.last.items() if now - t <= self.timeout]


@dataclass(frozen=True)
class RemeshPlan:
    old_shape: tuple[int, ...]
    new_shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    hosts_used: int
    hosts_available: int

    @property
    def usable_fraction(self) -> float:
        return self.hosts_used / max(math.prod(self.old_shape), 1)


def plan_remesh(old_shape: tuple[int, ...], axis_names: tuple[str, ...],
                available: int) -> RemeshPlan:
    """Largest feasible mesh after failures.

    Keeps the last axis ("model", TP) fixed — checkpoint param shards remain
    valid — and shrinks the leading data/pod axes.  Raises if even TP=model
    cannot be satisfied.
    """
    model = old_shape[-1]
    if available < model:
        raise RuntimeError(
            f"only {available} hosts left; cannot sustain model axis "
            f"{model} — full restart with a smaller TP degree required")
    lead = available // model
    if len(old_shape) == 2:
        new = (lead, model)
    elif len(old_shape) == 3:
        pod, data = old_shape[0], old_shape[1]
        # prefer keeping pods; shrink data; collapse pods if necessary
        best = None
        for p in range(min(pod, lead), 0, -1):
            d = lead // p
            if d == 0:
                continue
            cand = (p, d, model)
            if best is None or math.prod(cand) > math.prod(best):
                best = cand
        new = best
    else:
        raise ValueError("unsupported mesh rank")
    return RemeshPlan(old_shape, new, axis_names,
                      hosts_used=math.prod(new), hosts_available=available)


@dataclass
class StragglerMonitor:
    """EMA mean/var of step time; flags ranks whose reported step time is a
    z-score outlier (straggler mitigation hook)."""

    alpha: float = 0.1
    z_threshold: float = 3.0
    warmup: int = 5
    _mean: float = 0.0
    _var: float = 0.0
    _n: int = 0
    flagged: list = field(default_factory=list)

    def observe(self, step_time_s: float, rank: int = 0) -> bool:
        """Returns True if this observation is a straggler event."""
        self._n += 1
        if self._n <= self.warmup:
            # prime the EMA
            self._mean = (self._mean * (self._n - 1) + step_time_s) / self._n
            self._var = max(self._var, (step_time_s - self._mean) ** 2)
            return False
        z = (step_time_s - self._mean) / max(math.sqrt(self._var), 1e-9)
        is_straggler = z > self.z_threshold
        if is_straggler:
            self.flagged.append((self._n, rank, step_time_s, z))
        else:
            # only track healthy steps so a persistent straggler stays flagged
            d = step_time_s - self._mean
            self._mean += self.alpha * d
            self._var = (1 - self.alpha) * (self._var + self.alpha * d * d)
        return is_straggler

    @property
    def mean(self) -> float:
        return self._mean


def failure_mttf_steps(n_hosts: int, mtbf_hours_per_host: float = 5_000.0,
                       step_time_s: float = 10.0) -> float:
    """Expected steps between failures at scale — the design-sizing number
    behind checkpoint cadence (1000+ nodes: a failure every few hours)."""
    cluster_mtbf_s = mtbf_hours_per_host * 3600.0 / max(n_hosts, 1)
    return cluster_mtbf_s / step_time_s


def checkpoint_cadence_steps(n_hosts: int, save_cost_s: float,
                             step_time_s: float = 10.0,
                             mtbf_hours_per_host: float = 5_000.0) -> int:
    """Young/Daly optimal checkpoint interval, in steps."""
    mttf_s = mtbf_hours_per_host * 3600.0 / max(n_hosts, 1)
    interval_s = math.sqrt(2.0 * save_cost_s * mttf_s)
    return max(1, int(interval_s / step_time_s))
