"""Training loop: jit-compiled train step with gradient accumulation,
ZeRO-sharded optimizer, optional int8 error-feedback gradient compression,
and (when a mesh is present) fully sharded state.

The same ``build_train_step`` powers the CPU examples (no mesh), the smoke
tests, and the multi-pod dry-run (mesh of 512 host devices).
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig
from repro.models.sharding import MeshPlan, batch_spec, named_shardings
from repro.optim.adamw import AdamW, AdamWState
from repro.optim.schedule import warmup_cosine


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    ef: Any | None          # int8 error-feedback residual (grad compression)


# --------------------------------------------------------------------------
# int8 error-feedback gradient compression (numerics model; the wire-level
# compressed all-reduce lives in core/collectives.int8_psum)
# --------------------------------------------------------------------------


def _quantize_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads_ef(grads, ef):
    """g' = dequant(quant(g + ef)); ef' = (g + ef) - g'."""
    def leaf(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = _quantize_int8(g32)
        deq = q.astype(jnp.float32) * s
        return deq.astype(g.dtype), g32 - deq

    pairs = jax.tree.map(leaf, grads, ef)
    new_g = jax.tree.map(lambda t: t[0], pairs,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], pairs,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_g, new_ef


# --------------------------------------------------------------------------
# Trainer
# --------------------------------------------------------------------------


class Trainer:
    def __init__(self, model, run: RunConfig, mesh: Mesh | None = None,
                 plan: MeshPlan | None = None):
        self.model = model
        self.run = run
        self.mesh = mesh
        self.plan = plan or MeshPlan()
        self.opt = AdamW.from_run(run)

    # ------------------------------------------------------------ state ----

    def init_state(self, rng) -> TrainState:
        params = self.model.init(rng)
        opt = self.opt.init(params)
        ef = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params) \
            if self.run.grad_compression == "int8_ef" else None
        return TrainState(params, opt, ef)

    def state_specs(self):
        """PartitionSpec pytree mirroring TrainState (moments like params)."""
        pspecs = self.model.param_specs()
        opt_specs = AdamWState(
            step=P(),
            m=pspecs, v=pspecs,
            master=pspecs if self.opt.master_weights else None)
        ef = pspecs if self.run.grad_compression == "int8_ef" else None
        return TrainState(pspecs, opt_specs, ef)

    def state_shardings(self):
        assert self.mesh is not None
        from repro.models.sharding import sanitize_specs

        shapes = jax.eval_shape(
            lambda: self.init_state(jax.random.PRNGKey(0)))
        specs = sanitize_specs(shapes, self.state_specs(), self.mesh)
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P))

    def batch_shardings(self, batch_like):
        assert self.mesh is not None
        spec = lambda l: NamedSharding(self.mesh,
                                       batch_spec(self.plan, l.ndim))
        return jax.tree.map(spec, batch_like)

    # ------------------------------------------------------- train step ----

    def _loss_fn(self, params, batch):
        loss, metrics = self.model.loss(params, batch)
        return loss, metrics

    def _grads(self, params, batch):
        k = self.run.microbatches
        if k <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                self._loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads

        # gradient accumulation: scan over k microbatches (B must divide)
        def split(x):
            B = x.shape[0]
            if B % k:
                raise ValueError(f"batch {B} not divisible by "
                                 f"microbatches {k}")
            return x.reshape(k, B // k, *x.shape[1:])

        micro = jax.tree.map(split, batch)

        def step(carry, mb):
            acc, loss_acc = carry
            (loss, _), g = jax.value_and_grad(
                self._loss_fn, has_aux=True)(params, mb)
            acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32) / k, acc, g)
            return (acc, loss_acc + loss / k), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (grads, loss), _ = jax.lax.scan(step, (zeros, jnp.zeros(())), micro)
        grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads, params)
        return loss, {"ce": loss, "aux": jnp.zeros(())}, grads

    def make_train_step(self) -> Callable:
        run = self.run

        def train_step(state: TrainState, batch):
            loss, metrics, grads = self._grads(state.params, batch)
            ef = state.ef
            if run.grad_compression == "int8_ef":
                grads, ef = compress_grads_ef(grads, ef)
            lr_scale = warmup_cosine(state.opt.step, run.warmup_steps,
                                     run.total_steps)
            params, opt, opt_metrics = self.opt.update(
                grads, state.opt, state.params, lr_scale)
            out_metrics = {"loss": loss, "lr_scale": lr_scale,
                           **{k: v for k, v in metrics.items()},
                           **opt_metrics}
            return TrainState(params, opt, ef), out_metrics

        if self.mesh is None:
            return jax.jit(train_step, donate_argnums=(0,))
        ss = self.state_shardings()
        return jax.jit(
            train_step,
            in_shardings=(ss, None),
            out_shardings=(ss, None),
            donate_argnums=(0,),
        )

    # ------------------------------------------------------------- loop ----

    def fit(self, state: TrainState, batches, steps: int,
            log_every: int = 10, callback=None):
        """Simple synchronous loop over an iterator of host batches."""
        step_fn = self.make_train_step()
        history = []
        t0 = time.perf_counter()
        for i in range(steps):
            _, batch = next(batches)
            batch = jax.tree.map(jnp.asarray, batch)
            state, metrics = step_fn(state, batch)
            if (i + 1) % log_every == 0 or i == steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = i + 1
                m["elapsed_s"] = time.perf_counter() - t0
                history.append(m)
                if callback:
                    callback(m)
        return state, history
