"""Measured training-step time on the simulated fabric.

Executes a :class:`~repro.cosim.traffic.TrainJob`'s collective schedule
on :mod:`repro.sim` — every phase becomes sprayed, plane-split flow
batches over the real routed fabric — and returns *measured* step time
and tokens/sec per topology, next to the alpha-beta closed forms of
:mod:`repro.core.netsim` for the same phases.  In the uncontended
single-collective limit (zero per-hop latencies, even plane spray, no
chunk overhead) the measured times collapse to the closed forms exactly
— ``tests/test_cosim.py`` pins the agreement at 1e-6 relative.

Two execution methods per phase:

* ``steady`` (default) — ring collectives are steady-state symmetric,
  so one step's flows (all concurrent groups, contention included) are
  sprayed over the planes and scaled by the step count — the
  :mod:`repro.sim.collective_sim` idiom.
* ``batches`` — the full serialized ring schedule through
  :func:`repro.sim.events.simulate_flow_batches`: step ``k``'s flows
  arrive at step ``k-1``'s delivery time (per-flow arrival offsets), so
  dependent collective phases serialize exactly.  Single-plane at full
  NIC rate; in the even-spray/zero-overhead limit the two methods agree
  (pinned by the differential tests).

Dependent phases of one step never overlap on the fabric — each phase
starts when the previous one drains — so the step's communication time
is the sum of staggered phase times (``stagger=True`` stamps each
phase's flows with its fabric-clock start offset).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.netsim import (DEFAULT_NET, NetParams, _alpha,
                               allgather_time, alltoall_time, make_router,
                               ring_allreduce_time)
from repro.core.planes import SprayConfig
from repro.core.topology import Topology
from repro.sim.events import (flows_to_demands, path_latency,
                              simulate_flow_batches)
from repro.sim.fairshare import flow_incidence
from repro.sim.spray import simulate_sprayed
from repro.telemetry import get_metrics, get_recorder
from .placement import mphx_rank_layout, phase_step_flows, rank_to_switch
from .traffic import CollectivePhase, TrainJob, decompose_phase

PHASE_METHODS = ("steady", "batches")


def analytic_phase_time(topo: Topology, phase: CollectivePhase,
                        net: NetParams = DEFAULT_NET) -> float:
    """Alpha-beta closed-form time for all calls of one phase."""
    if phase.kind == "allreduce":
        t = ring_allreduce_time(topo, phase.bytes_per_rank, m=phase.size,
                                net=net).total_s
    elif phase.kind in ("allgather", "reducescatter"):
        t = allgather_time(topo, phase.bytes_per_rank, m=phase.size,
                           net=net).total_s
    else:
        t = alltoall_time(topo, phase.bytes_per_rank, net=net).total_s
    return phase.calls * t


@dataclass
class PhaseTime:
    """Measured + analytic time of one collective phase of the step."""

    name: str
    kind: str
    size: int
    calls: int
    steps: int
    n_flows: int
    start_s: float            # fabric-clock offset within the step
    comm_s: float             # measured, all calls
    analytic_s: float         # closed form, all calls

    def row(self) -> dict:
        return {
            "phase": self.name, "kind": self.kind, "group": self.size,
            "calls": self.calls, "steps": self.steps,
            "sim_flows_per_step": self.n_flows,
            "start_us": round(self.start_s * 1e6, 3),
            "measured_us": round(self.comm_s * 1e6, 3),
            "analytic_us": round(self.analytic_s * 1e6, 3),
            "measured_over_analytic": round(self.comm_s / self.analytic_s, 4)
                if self.analytic_s > 0 else None,
        }


@dataclass
class StepResult:
    """Measured training-step outcome of one (job, topology) cell."""

    topology: str
    arch: str
    n_ranks: int
    comm_s: float
    compute_s: float
    step_s: float
    tokens_per_s: float
    analytic_comm_s: float
    phases: "list[PhaseTime]" = field(default_factory=list)

    def row(self) -> dict:
        return {
            "topology": self.topology, "arch": self.arch,
            "n_ranks": self.n_ranks,
            "comm_ms": round(self.comm_s * 1e3, 4),
            "compute_ms": round(self.compute_s * 1e3, 4),
            "step_ms": round(self.step_s * 1e3, 4),
            "tokens_per_s": round(self.tokens_per_s, 1),
            "analytic_comm_ms": round(self.analytic_comm_s * 1e3, 4),
            "comm_over_analytic":
                round(self.comm_s / self.analytic_comm_s, 4)
                if self.analytic_comm_s > 0 else None,
            "comm_fraction": round(self.comm_s / self.step_s, 4)
                if self.step_s > 0 else None,
            "phases": [p.row() for p in self.phases],
        }


def _phase_time_batches(router, topo, flows, steps, caps_gbps, n_planes,
                        net, backend) -> float:
    """Serialized ring schedule: step k's flows arrive when step k-1's
    data is delivered (transfer finish + path alpha + software alpha).

    All planes are identical fabric copies, so one plane is simulated
    carrying the even-spray ``1/n_planes`` byte share at its port rate
    (chunk rounding and plane skew are the ``steady`` method's job).
    """
    from repro.sim.events import FlowSpec
    # batch admission supplies the serialization clock; the phase-level
    # stagger offset must not be re-added once per ring step
    share = [FlowSpec(f.src, f.dst, f.size_bytes / n_planes)
             for f in flows]
    inc = flow_incidence(router, flows_to_demands(share), "minimal")
    lat = float(path_latency(inc, net).max())
    gap = lat + net.software_alpha
    res = simulate_flow_batches(router, [share] * steps,
                                rate_cap_gbps=caps_gbps,
                                gap_s=gap, net=net, backend=backend)
    return res.makespan_s + lat + net.software_alpha


def _phase_chain(phase: CollectivePhase, job: TrainJob, layout
                 ) -> "list[tuple[int, int]]":
    """Level-factor chain of the mesh axis a phase runs over (mapped
    placement); phases matching no known axis stay undecomposed."""
    tp = job.mesh.get("tp", 1)
    ep = job.mesh.get("ep", 1)
    dp = job.mesh.get("dp", 1)
    name = {(tp, 1): "tp", (ep, tp): "ep", (dp, tp): "dp"}.get(
        (phase.size, phase.stride))
    chain = layout.factors.get(name) if name else None
    return chain if chain else [(phase.size, phase.stride)]


def simulate_step(topo: Topology, job: TrainJob,
                  cfg: "SprayConfig | None" = None,
                  net: NetParams = DEFAULT_NET,
                  mode: str = "minimal", engine: str = "auto",
                  backend: str = "numpy",
                  device_tflops: float = 989.0,
                  plane_skew: "list[float] | None" = None,
                  method: str = "steady",
                  stagger: bool = True,
                  placement: str = "linear",
                  router=None) -> StepResult:
    """Co-simulate one training step of ``job`` on ``topo``.

    Phases run back-to-back on the fabric clock; each phase's flows are
    built from the rank placement (:mod:`.placement`), sprayed over the
    planes, and routed with the topology's ``engine``.  ``plane_skew``
    degrades planes exactly as :func:`repro.sim.spray.simulate_sprayed`
    (``inf`` = dead plane, bytes re-sprayed over survivors).
    ``device_tflops`` sets the overlapped-compute term via the 6ND
    model-FLOPs rule.  Intra-switch phases (every group inside one
    switch) cost only their per-step 2-hop alpha.

    ``placement="linear"`` packs rank ``r`` on NIC ``r``;
    ``placement="mapped"`` (MPHX only) places mesh axes on physical
    levels via :func:`repro.core.mapping.best_mapping`
    (:func:`~repro.cosim.placement.mphx_rank_layout`).
    """
    if method not in PHASE_METHODS:
        raise ValueError(f"unknown method {method!r}; known {PHASE_METHODS}")
    if job.n_ranks > topo.n_nics:
        raise ValueError(f"job needs {job.n_ranks} ranks but {topo.name} "
                         f"has {topo.n_nics} NICs")
    if router is None:
        router = make_router(topo, backend="auto", engine=engine)
    phases = list(job.phases)
    if placement == "mapped":
        from repro.core.hyperx import MPHX
        if not isinstance(topo, MPHX):
            raise ValueError("placement='mapped' is MPHX-only")
        layout = mphx_rank_layout(topo, job, net=net)
        switch_of = layout.nic // topo.p
        phases = [sub for ph in phases
                  for sub in decompose_phase(ph, _phase_chain(ph, job,
                                                              layout))]
    elif placement == "linear":
        switch_of = rank_to_switch(topo, getattr(router, "graph", None))
    else:
        raise ValueError(f"unknown placement {placement!r}")
    t_acc = 0.0
    rows = []
    analytic_total = 0.0
    rec = get_recorder()
    proc = f"cosim:{topo.name}"
    for phase in phases:
        start = t_acc if stagger else 0.0
        span_start = t_acc      # spans always tile the step clock
        flows, steps, senders = phase_step_flows(
            phase, switch_of, job.n_ranks, start_s=start)
        analytic = analytic_phase_time(topo, phase, net)
        analytic_total += analytic
        # a merged flow aggregates `senders` NIC ports of injection
        caps = topo.port_gbps * senders.astype(np.float64)
        res = None
        if not flows:
            # all groups intra-switch: alpha-only schedule
            comm = phase.calls * steps * _alpha(topo, 2.0, net)
        elif method == "batches":
            n_planes = (cfg or SprayConfig(n_planes=topo.n_planes)).n_planes
            comm = phase.calls * _phase_time_batches(
                router, topo, flows, steps, caps, n_planes, net, backend)
        else:
            res = simulate_sprayed(topo, flows, cfg=cfg, mode=mode,
                                   plane_skew=plane_skew,
                                   rate_cap_gbps=caps, net=net,
                                   backend=backend, router=router)
            if bool(res.stalled.any()):
                raise RuntimeError(
                    f"phase {phase.name}: stalled flows on {topo.name}")
            comm = phase.calls * steps * (res.makespan_s
                                          + net.software_alpha)
        if rec is not None:
            # one span per phase on the step track — their durations sum
            # to comm_s exactly (the trace IS the step breakdown)
            rec.span(phase.name, span_start, comm, process=proc,
                     thread="step", cat="phase",
                     args={"kind": phase.kind, "group": phase.size,
                           "calls": phase.calls, "steps": steps,
                           "flows": len(flows), "analytic_s": analytic})
            if res is not None:
                # per-plane busy windows under the phase span
                for k in range(res.plane_transfer_s.shape[1]):
                    busy = float(res.plane_transfer_s[:, k].max())
                    if busy > 0:
                        rec.span(phase.name, span_start,
                                 min(phase.calls * steps * busy, comm),
                                 process=proc, thread=f"plane {k}",
                                 cat="plane")
        rows.append(PhaseTime(phase.name, phase.kind, phase.size,
                              phase.calls, steps, len(flows), start,
                              comm, analytic))
        t_acc += comm
    get_metrics().inc("cosim.phases", len(phases))
    comm_s = t_acc
    compute_s = (6.0 * job.active_params * job.tokens_per_step
                 / (job.n_ranks * device_tflops * 1e12))
    step_s = comm_s + compute_s
    return StepResult(
        topology=topo.name, arch=job.arch, n_ranks=job.n_ranks,
        comm_s=comm_s, compute_s=compute_s, step_s=step_s,
        tokens_per_s=job.tokens_per_step / step_s if step_s > 0 else 0.0,
        analytic_comm_s=analytic_total, phases=rows)
