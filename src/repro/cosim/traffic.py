"""Collective traffic of one training step, derived from sharding.

A :class:`TrainJob` is the co-simulator's unit of work: the model's
per-step collective *phases* (gradient all-reduce on the data axis,
activation all-gather/reduce-scatter on the tensor axis, MoE token
all-to-all on the expert axis) with exact byte counts, participant group
sizes, and rank strides.  Two constructors:

* :func:`job_from_model` — analytic, from a :class:`ModelConfig` and its
  mesh split (the accounting :func:`repro.core.mapping.traffic_from_model`
  uses, but phase-resolved so each collective can be executed on the
  fabric separately);
* :func:`phases_from_collectives` — measured, from the wire accounting of
  :func:`repro.launch.hloparse.parse_collectives` over a real partitioned
  HLO dump (``launch/dryrun.py``), so the co-sim can replay exactly what
  XLA emitted.

Byte semantics per kind (matched to :mod:`repro.core.netsim`):
``allreduce`` — full tensor per rank; ``allgather``/``reducescatter`` —
the per-rank shard; ``alltoall`` — total *off-rank* bytes each rank
injects (the ``(g-1)/g`` share of its dispatch tensor).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

from repro.configs.base import LM_SHAPES, ModelConfig, ShapeConfig

PHASE_KINDS = ("allreduce", "allgather", "reducescatter", "alltoall")

_HLO_KINDS = {
    "all-reduce": "allreduce",
    "all-gather": "allgather",
    "reduce-scatter": "reducescatter",
    "all-to-all": "alltoall",
}


@dataclass(frozen=True)
class CollectivePhase:
    """One dependent collective phase of the training step.

    The ``n_ranks // (size * stride)`` x ``stride`` concurrent groups
    tile the rank space: group ``(outer, inner)`` holds ranks
    ``outer*size*stride + inner + k*stride`` for ``k < size`` — the
    standard mesh-axis layout (a fastest-varying axis has stride 1).
    """

    name: str                 # e.g. "dp_grad_allreduce"
    kind: str                 # one of PHASE_KINDS
    size: int                 # participants per group
    stride: int               # rank stride between group members
    bytes_per_rank: float     # per participating rank per call (see above)
    calls: int = 1            # issues per training step

    def __post_init__(self):
        if self.kind not in PHASE_KINDS:
            raise ValueError(f"unknown kind {self.kind!r}; "
                             f"known: {PHASE_KINDS}")
        if self.size < 2:
            raise ValueError(f"phase {self.name}: group size must be >= 2")

    def wire_bytes_per_rank(self) -> float:
        """Bytes each rank actually injects per call (ring/direct algo)."""
        m, b = self.size, self.bytes_per_rank
        if self.kind == "allreduce":
            return 2 * (m - 1) / m * b
        if self.kind in ("allgather", "reducescatter"):
            return (m - 1) * b
        return b  # alltoall: bytes_per_rank IS the injected total


@dataclass(frozen=True)
class TrainJob:
    """One model x shape x mesh cell ready for fabric co-simulation."""

    arch: str
    n_ranks: int
    mesh: dict                       # axis name -> size (dp/tp/ep)
    tokens_per_step: int
    active_params: int               # for the compute-time term
    phases: tuple = field(default_factory=tuple)

    def __post_init__(self):
        for ph in self.phases:
            span = ph.size * ph.stride
            if self.n_ranks % span:
                raise ValueError(
                    f"phase {ph.name}: size*stride {span} does not tile "
                    f"{self.n_ranks} ranks")

    def total_wire_bytes(self) -> float:
        return sum(ph.calls * self.n_ranks * ph.wire_bytes_per_rank()
                   for ph in self.phases)


def _dtype_bytes(cfg: ModelConfig) -> int:
    return {"float32": 4, "bfloat16": 2, "float16": 2}.get(
        cfg.activation_dtype, 2)


def job_from_model(cfg: ModelConfig, dp: int, tp: int = 1, ep: int = 1,
                   shape: "ShapeConfig | str" = "train_4k",
                   param_count: "int | None" = None,
                   active_params: "int | None" = None) -> TrainJob:
    """Analytic per-step collective phases of ``cfg`` on a dp x tp mesh.

    ``tp`` is the fastest-varying axis (stride 1, so TP groups pack onto
    as few switches as possible — the §5.2 placement guidance), ``dp``
    strides over it; ``ep`` is the fastest-varying sub-axis of ``dp``
    (stride ``tp``) and must divide both ``dp`` and the expert count.
    ``param_count``/``active_params`` override the registry's analytic
    count (handy in tests, where importing the model stack is overkill).

    Accounting per step (Megatron-style sequence-parallel training):

    * TP: one activation all-gather + one reduce-scatter per layer per
      pass -> ``2 * n_layers`` calls each, shard =
      ``tokens_per_rank * d_model`` activation bytes.
    * EP: dispatch + combine all-to-all per MoE layer per pass ->
      ``2 * n_moe_layers`` calls, each rank sends
      ``tokens_per_rank * top_k * d_model`` bytes.
    * DP: one bucketed gradient all-reduce of the rank's parameter shard
      (``params / tp`` after tensor-parallel split).
    """
    if isinstance(shape, str):
        shape = LM_SHAPES[shape]
    if not shape.is_train:
        raise ValueError(f"co-sim models train steps, got {shape.shape_id}")
    n_ranks = dp * tp
    if ep > 1 and dp % ep:
        raise ValueError(f"ep={ep} must divide dp={dp}")
    moe = cfg.moe
    if moe is not None and ep > 1 and moe.n_experts % ep:
        raise ValueError(f"ep={ep} must divide n_experts={moe.n_experts}")
    if param_count is None:
        param_count = cfg.param_count()
    if active_params is None:
        active_params = (cfg.active_param_count() if moe is not None
                         else param_count)
    act_bytes = _dtype_bytes(cfg)
    tokens = shape.seq_len * shape.global_batch
    tokens_per_rank = tokens / n_ranks
    phases = []
    if tp > 1:
        shard = tokens_per_rank * cfg.d_model * act_bytes
        phases.append(CollectivePhase(
            "tp_act_allgather", "allgather", tp, 1, shard,
            calls=2 * cfg.n_layers))
        phases.append(CollectivePhase(
            "tp_act_reducescatter", "reducescatter", tp, 1, shard,
            calls=2 * cfg.n_layers))
    if moe is not None and ep > 1:
        n_moe_layers = cfg.n_layers - moe.first_k_dense
        dispatch = tokens_per_rank * moe.top_k * cfg.d_model * act_bytes
        phases.append(CollectivePhase(
            "ep_token_alltoall", "alltoall", ep, tp,
            (ep - 1) / ep * dispatch, calls=2 * n_moe_layers))
    if dp > 1:
        phases.append(CollectivePhase(
            "dp_grad_allreduce", "allreduce", dp, tp,
            param_count * 2 / tp, calls=1))
    return TrainJob(cfg.arch_id, n_ranks, {"dp": dp, "tp": tp, "ep": ep},
                    tokens, int(active_params), tuple(phases))


def decompose_phase(phase: CollectivePhase,
                    chain: "list[tuple[int, int]]"
                    ) -> "list[CollectivePhase]":
    """Hierarchical split of a ring phase across placement levels.

    ``chain`` lists the axis's level factors as ``(factor, rank_stride)``
    in fastest-varying order (:class:`~repro.cosim.placement.
    MappedLayout`).  A flat ring over an axis split across levels would
    cross switches on almost every step; the hierarchical schedule runs
    one sub-collective per level instead — all-gather grows its shard
    level by level, reduce-scatter shrinks it mirror-wise, all-reduce is
    the RS-down/AG-up ladder — moving the same wire bytes in far fewer,
    better-localized steps.  All-to-all and single-level chains pass
    through unchanged.
    """
    fs = [f for f, _ in chain]
    if math.prod(fs) != phase.size:
        raise ValueError(f"chain {fs} does not factor group {phase.size}")
    if len(chain) <= 1 or phase.kind == "alltoall":
        return [phase]
    subs = []
    if phase.kind == "allgather":
        shard = phase.bytes_per_rank
        for i, (f, stride) in enumerate(chain):
            subs.append(CollectivePhase(
                f"{phase.name}_l{i}", "allgather", f, stride, shard,
                calls=phase.calls))
            shard *= f
    elif phase.kind == "reducescatter":
        # mirror of allgather: outermost level first, shrinking output
        inp = phase.size * phase.bytes_per_rank
        for i, (f, stride) in reversed(list(enumerate(chain))):
            inp /= f
            subs.append(CollectivePhase(
                f"{phase.name}_l{i}", "reducescatter", f, stride, inp,
                calls=phase.calls))
    else:  # allreduce: RS down the hierarchy, AG back up
        out = phase.bytes_per_rank
        down = []
        for i, (f, stride) in enumerate(chain):
            out /= f
            down.append(CollectivePhase(
                f"{phase.name}_rs_l{i}", "reducescatter", f, stride, out,
                calls=phase.calls))
        subs.extend(down)
        for i, (f, stride) in reversed(list(enumerate(chain))):
            subs.append(CollectivePhase(
                f"{phase.name}_ag_l{i}", "allgather", f, stride, out,
                calls=phase.calls))
            out *= f
    return subs


def phases_from_collectives(parsed: dict, device_count: int,
                            calls: int = 1) -> "list[CollectivePhase]":
    """HLO-measured phases from ``parse_collectives`` wire accounting.

    Each (kind, group-size) bucket becomes one phase; per-rank payloads
    are recovered by inverting the parser's ring wire formulas.  Group
    stride is unknown from the flat parse, so groups are taken contiguous
    (stride 1) — the XLA default device order.  ``collective-permute``
    rows carry no group structure and are skipped.
    """
    out = []
    for hlo_kind, kind in _HLO_KINDS.items():
        rec = parsed.get(hlo_kind)
        if not rec or not rec.get("count"):
            continue
        for g_str, wire in sorted(rec["by_group"].items(),
                                  key=lambda kv: int(kv[0])):
            g = int(g_str)
            if g < 2 or wire <= 0:
                continue
            if kind == "allreduce":
                per_rank = wire * g / (2 * (g - 1))
            elif kind in ("allgather", "reducescatter"):
                per_rank = wire / (g - 1)
            else:
                per_rank = wire  # alltoall wire IS the off-rank total
            if device_count % g:
                raise ValueError(
                    f"group size {g} does not divide {device_count} devices")
            out.append(CollectivePhase(
                f"hlo_{kind}_g{g}", kind, g, 1, per_rank, calls=calls))
    return out
