"""repro.cosim — training-step co-simulation on the fabric simulator.

Derives real collective traffic (DP/TP/EP all-reduce, all-gather, MoE
all-to-all) from a model config's sharding (:mod:`.traffic` — or from a
partitioned HLO dump via :func:`~.traffic.phases_from_collectives`),
maps participants onto NICs and switches (:mod:`.placement`), and
executes the step's collective schedule on :mod:`repro.sim` as sprayed,
plane-split flow batches with staggered start times (:mod:`.stepsim`) —
yielding *measured* step time and tokens/sec per topology.
``docs/cosim.md`` is the guide; ``tests/test_cosim.py`` pins the
uncontended collapse to the :mod:`repro.core.netsim` closed forms.
"""

from .placement import (RING_STEPS, MappedLayout, group_members,
                        mphx_rank_layout, phase_step_flows, rank_to_switch)
from .stepsim import (PHASE_METHODS, PhaseTime, StepResult,
                      analytic_phase_time, simulate_step)
from .traffic import (PHASE_KINDS, CollectivePhase, TrainJob,
                      decompose_phase, job_from_model,
                      phases_from_collectives)

__all__ = [
    "RING_STEPS", "MappedLayout", "group_members", "mphx_rank_layout",
    "phase_step_flows", "rank_to_switch",
    "PHASE_METHODS", "PhaseTime", "StepResult", "analytic_phase_time",
    "simulate_step",
    "PHASE_KINDS", "CollectivePhase", "TrainJob", "decompose_phase",
    "job_from_model", "phases_from_collectives",
]
