"""Rank -> NIC -> switch placement and per-phase flow construction.

Ranks are laid out linearly over NICs (rank ``r`` on NIC ``r``), so a
stride-1 mesh axis packs onto as few switches as possible — e.g. a TP
group of size <= p disappears into one switch and costs no fabric
traffic, exactly the placement the paper's §5.2 mapping guidance (and
:func:`repro.core.mapping.best_mapping`) rewards.  On MPHX the NIC's
switch comes from the topology's coordinate layout (``p`` NICs per
switch per plane); on graph topologies from the ``nic_nodes`` order the
collective simulator already uses (:func:`~repro.sim.collective_sim.
ring_participants`).

Flow construction mirrors :mod:`repro.sim.collective_sim`: ring
collectives are steady-state symmetric, so one step's flows (ALL
concurrent groups of the phase at once — that's where the contention
is) are built and the step simulation is scaled by the step count.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.hyperx import MPHX
from repro.core.topology import Topology
from repro.sim.events import FlowSpec
from .traffic import CollectivePhase

RING_STEPS = {
    # step count and per-step bytes as a function of (size, bytes_per_rank)
    "allreduce": lambda m, b: (2 * (m - 1), b / m),
    "allgather": lambda m, b: (m - 1, b),
    "reducescatter": lambda m, b: (m - 1, b),
}


def rank_to_switch(topo: Topology, graph=None) -> np.ndarray:
    """(n_nics,) per-plane switch id hosting each rank's NIC."""
    if isinstance(topo, MPHX):
        return np.repeat(np.arange(topo.switches_per_plane, dtype=np.int64),
                         topo.p)
    g = graph if graph is not None else topo.build_graph()
    nodes = np.asarray(g.nic_nodes, dtype=np.int64)
    return np.repeat(nodes, g.nics_per_switch)


@dataclass
class MappedLayout:
    """Mapping-guided placement: rank -> NIC plus per-axis level splits.

    ``factors[axis]`` lists the axis's assigned level factors in
    fastest-varying digit order (switch level first when present) —
    the chain :func:`repro.cosim.traffic.decompose_phase` turns into
    hierarchical sub-collectives.  ``dp`` is the concatenation of the
    ``ep`` and residual-dp chains (``ep`` is ``dp``'s fast sub-axis).
    """

    nic: np.ndarray               # (n_ranks,) NIC id per rank
    factors: dict                 # axis name -> list of (f, rank_stride)


def mphx_rank_layout(topo: MPHX, job, net=None) -> MappedLayout:
    """Mapping-guided rank -> NIC layout for MPHX.

    Runs :func:`repro.core.mapping.best_mapping` over the job's per-axis
    traffic (tp / ep / residual-dp axes, bytes summed from the phases)
    and realizes the winning level assignment as a mixed-radix NIC
    numbering: an axis assigned to the switch level varies the NIC port
    under one switch, an axis assigned to dimension ``i`` varies that
    coordinate — so e.g. a bandwidth-hungry EP axis lands on a full-mesh
    dimension instead of colliding with the DP ring on one link (the
    linear layout's failure mode when the fabric is underpopulated).
    """
    from repro.core.mapping import (AxisTraffic, best_mapping, mphx_levels)
    from repro.core.netsim import DEFAULT_NET

    net = net or DEFAULT_NET
    tp = job.mesh.get("tp", 1)
    ep = job.mesh.get("ep", 1)
    dp = job.mesh.get("dp", 1)
    dpo = dp // max(ep, 1)
    r = np.arange(job.n_ranks)
    axis_index = {"tp": r % tp, "ep": (r // tp) % max(ep, 1),
                  "dpo": r // (tp * max(ep, 1))}
    axis_size = {"tp": tp, "ep": ep, "dpo": dpo}
    traffic = {}
    for ph in job.phases:
        if (ph.size, ph.stride) == (tp, 1):
            name = "tp"
        elif (ph.size, ph.stride) == (ep, tp):
            name = "ep"
        else:
            name = "dpo"   # dp-spanning phases ride the residual-dp axis
        t = traffic.setdefault(name, {"allreduce_bytes": 0.0,
                                      "allgather_bytes": 0.0,
                                      "alltoall_bytes": 0.0, "calls": 1})
        key = {"allreduce": "allreduce_bytes", "allgather":
               "allgather_bytes", "reducescatter": "allgather_bytes",
               "alltoall": "alltoall_bytes"}[ph.kind]
        t[key] += ph.calls * ph.bytes_per_rank
        t["calls"] = max(t["calls"], ph.calls)
    axes = [AxisTraffic(name, axis_size[name], **traffic.get(name, {}))
            for name in ("tp", "ep", "dpo") if axis_size[name] > 1]
    mapping = best_mapping(topo, axes, net=net)
    levels = mphx_levels(topo)
    level_digit = np.zeros((job.n_ranks, len(levels)), dtype=np.int64)
    level_mult = [1] * len(levels)
    axis_stride = {"tp": 1, "ep": tp, "dpo": tp * max(ep, 1)}
    factors = {name: [] for name in ("tp", "ep", "dpo")}
    for ax in axes:   # same traffic-descending order best_mapping used
        rem = axis_index[ax.name].copy()
        stride = axis_stride[ax.name]
        for li, f in mapping.assignment[ax.name]:
            level_digit[:, li] += (rem % f) * level_mult[li]
            level_mult[li] *= f
            factors[ax.name].append((f, stride))
            stride *= f
            rem //= f
    # dp spans the ep chain (fast) then the residual-dp chain
    factors["dp"] = factors["ep"] + factors["dpo"]
    port = level_digit[:, 0]
    dim_of_level = [i for i, d in enumerate(topo.dims) if d > 1]
    coords = np.zeros((job.n_ranks, len(topo.dims)), dtype=np.int64)
    for li, di in enumerate(dim_of_level, start=1):
        coords[:, di] = level_digit[:, li]
    switch = np.zeros(job.n_ranks, dtype=np.int64)
    for di, d in enumerate(topo.dims):
        switch = switch * d + coords[:, di]
    return MappedLayout(switch * topo.p + port, factors)


def group_members(n_ranks: int, size: int, stride: int) -> "list[list[int]]":
    """All groups of a mesh axis with the given (size, stride) tiling."""
    span = size * stride
    if n_ranks % span:
        raise ValueError(f"size*stride {span} does not tile {n_ranks} ranks")
    return [[outer * span + inner + k * stride for k in range(size)]
            for outer in range(n_ranks // span)
            for inner in range(stride)]


def _merge_pairs(pairs: dict, start_s: float
                 ) -> "tuple[list[FlowSpec], np.ndarray]":
    flows = [FlowSpec(s, d, b, start_s)
             for (s, d), (b, _) in sorted(pairs.items())]
    senders = np.array([len(snd) for _, snd in
                        (pairs[k] for k in sorted(pairs))], dtype=np.int64)
    return flows, senders


def _add(pairs: dict, s: int, d: int, b: float, rank: int) -> None:
    rec = pairs.setdefault((s, d), [0.0, set()])
    rec[0] += b
    rec[1].add(rank)


def phase_step_flows(phase: CollectivePhase, switch_of: np.ndarray,
                     n_ranks: int, start_s: float = 0.0
                     ) -> "tuple[list[FlowSpec], int, np.ndarray]":
    """(one step's flows across all groups, step count, senders per flow).

    Ring kinds emit each group's rank ``k -> k+1`` neighbor flow for one
    steady-state step; all-to-all emits the full direct exchange (one
    step).  Same-switch rank pairs produce no fabric flow — they ride
    the intra-switch path the 2-hop alpha already covers.  Parallel
    rank pairs that land on the same switch pair are merged into one
    flow carrying the summed bytes; the returned per-flow sender count
    sizes that flow's injection cap (``senders x port_gbps`` — a merged
    flow is an aggregate of that many NIC ports).
    """
    groups = group_members(n_ranks, phase.size, phase.stride)
    pairs: dict = {}
    if phase.kind in RING_STEPS:
        steps, step_bytes = RING_STEPS[phase.kind](phase.size,
                                                   phase.bytes_per_rank)
        for members in groups:
            for k, r in enumerate(members):
                s = int(switch_of[r])
                d = int(switch_of[members[(k + 1) % len(members)]])
                if s != d:
                    _add(pairs, s, d, step_bytes, r)
        flows, senders = _merge_pairs(pairs, start_s)
        return flows, int(steps), senders
    # alltoall: direct exchange, bytes_per_rank spread over the m-1 peers
    per_peer = phase.bytes_per_rank / max(phase.size - 1, 1)
    for members in groups:
        for r in members:
            s = int(switch_of[r])
            for q in members:
                if q == r:
                    continue
                d = int(switch_of[q])
                if s != d:
                    _add(pairs, s, d, per_peer, r)
    flows, senders = _merge_pairs(pairs, start_s)
    return flows, 1, senders
