"""Property-based tests for failure injection (repro.sim.failures).

Invariants of ``degrade_graph`` under random failure specs: survivors
never include a failed element, the node compaction is a bijection onto
0..S'-1, capacity only ever shrinks, the ``info()`` ledger reconciles
with the surviving adjacency, and ``parse_failure_spec`` rejects every
malformed spec with a ``ValueError`` that names the offending part.
"""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep — deterministic fallback shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.dragonfly import Dragonfly
from repro.core.hyperx import MPHX
from repro.sim.failures import (FailureSpec, degrade_graph,
                                parse_failure_spec)

MPHX_SMALL = MPHX(n=2, p=8, dims=(8, 8))
DF_SMALL = Dragonfly(p=2, a=4, h=2, groups=9, name="Dragonfly (small)")
GRAPHS = {"mphx": MPHX_SMALL.build_graph(), "df": DF_SMALL.build_graph()}

# encode (link, switch, seed) in one integer so the shim (no st.builds)
# still enumerates the full cross product, boundaries first
spec_st = st.integers(0, 159).map(lambda i: FailureSpec(
    link_fraction=[0.0, 0.01, 0.05, 0.2, 0.5][i % 5],
    switch_fraction=[0.0, 0.02, 0.1, 0.3][(i // 5) % 4],
    seed=i // 20))
graph_st = st.sampled_from(sorted(GRAPHS))


def _undirected_links(g) -> float:
    return sum(m for u in range(g.n_switches)
               for v, m in g.adj[u].items() if v > u)


@given(name=graph_st, spec=spec_st)
@settings(max_examples=40, deadline=None)
def test_degrade_survivors_exclude_failed_elements(name, spec):
    g = GRAPHS[name]
    dg = degrade_graph(g, spec)
    # every failed switch maps to -1; every survivor to a unique new id
    for u in dg.failed_switches:
        assert dg.node_map[u] == -1
    alive = dg.node_map[dg.node_map >= 0]
    assert len(set(alive.tolist())) == dg.graph.n_switches
    assert sorted(alive.tolist()) == list(range(dg.graph.n_switches))
    # fully-failed edges are gone from the surviving adjacency
    for u, v in dg.fully_failed_edges:
        nu, nv = int(dg.node_map[u]), int(dg.node_map[v])
        assert nu >= 0 and nv >= 0          # else it'd be a switch kill
        assert nv not in dg.graph.adj[nu]


@given(name=graph_st, spec=spec_st)
@settings(max_examples=40, deadline=None)
def test_degrade_capacity_only_shrinks(name, spec):
    g = GRAPHS[name]
    dg = degrade_graph(g, spec)
    # per surviving edge: multiplicity never grows
    for u in range(g.n_switches):
        nu = int(dg.node_map[u])
        if nu < 0:
            continue
        for v, m in g.adj[u].items():
            nv = int(dg.node_map[v])
            if nv < 0:
                continue
            assert dg.graph.adj[nu].get(nv, 0.0) <= m + 1e-12


@given(name=graph_st, spec=spec_st)
@settings(max_examples=40, deadline=None)
def test_degrade_info_ledger_reconciles(name, spec):
    g = GRAPHS[name]
    dg = degrade_graph(g, spec)
    info = dg.info()
    total = _undirected_links(g)
    assert dg.total_links == pytest.approx(total)
    surviving = _undirected_links(dg.graph)
    # removed + surviving == healthy total (the byte ledger of links)
    assert dg.failed_links + surviving == pytest.approx(total)
    assert 0.0 <= info["failed_link_fraction"] <= 1.0
    assert info["failed_switches"] == len(dg.failed_switches)
    assert info["fully_failed_edges"] == len(dg.fully_failed_edges)
    if spec.is_noop:
        assert dg.failed_links == 0.0
        assert not dg.fully_failed_edges
        assert surviving == pytest.approx(total)


@given(name=graph_st, spec=spec_st)
@settings(max_examples=25, deadline=None)
def test_degrade_nics_follow_surviving_switches(name, spec):
    g = GRAPHS[name]
    dg = degrade_graph(g, spec)
    expect = [int(dg.node_map[u]) for u in g.nic_nodes
              if dg.node_map[u] >= 0]
    assert dg.graph.nic_nodes == expect


@given(seed=st.integers(0, 31))
@settings(max_examples=32, deadline=None)
def test_degrade_deterministic_in_seed(seed):
    spec = FailureSpec(link_fraction=0.1, switch_fraction=0.05, seed=seed)
    a = degrade_graph(GRAPHS["mphx"], spec)
    b = degrade_graph(GRAPHS["mphx"], spec)
    assert a.failed_switches == b.failed_switches
    assert a.fully_failed_edges == b.fully_failed_edges
    assert a.failed_links == b.failed_links


# ----------------------------------------------- spec parsing rejection ----


def test_parse_failure_spec_roundtrip():
    spec = parse_failure_spec("link:0.05,plane:1,seed:3")
    assert spec == FailureSpec(link_fraction=0.05, planes_down=1, seed=3)
    assert parse_failure_spec(spec.label()).link_fraction == 0.05
    assert parse_failure_spec("") == FailureSpec()
    assert parse_failure_spec(" link:0.1 , switch:0.2 ") \
        == FailureSpec(link_fraction=0.1, switch_fraction=0.2)


@pytest.mark.parametrize("bad,needle", [
    ("link:0.01,link:0.02", "duplicate"),
    ("bogus:1", "unknown"),
    ("link:-0.1", "negative"),
    ("plane:-1", "negative"),
    ("seed:-2", "negative"),
    ("link:abc", "expected a number"),
    ("plane:1.5", "expected an integer"),
    ("link0.01", "expected key:value"),
    ("link:0.01,,switch:x", "expected a number"),
])
def test_parse_failure_spec_rejects(bad, needle):
    with pytest.raises(ValueError, match=needle):
        parse_failure_spec(bad)


def test_failure_spec_bounds():
    with pytest.raises(ValueError):
        FailureSpec(link_fraction=1.0)
    with pytest.raises(ValueError):
        FailureSpec(switch_fraction=-0.1)
    with pytest.raises(ValueError):
        FailureSpec(planes_down=-1)
