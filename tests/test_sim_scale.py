"""65K-scale sim path: BENCH artifact schema, incidence caching, smoke.

Three layers:

  * schema smoke on ``results/BENCH_sim_scale.json`` — the committed
    artifact must pin the >=10x jit speedup at the largest
    all-backends-timed rung (a 65K-NIC Table-2 preset) and three-way
    1e-6 agreement at every rung,
  * the pair-level incidence cache (``IncidenceCacheMixin``): cached
    extraction is byte-identical to the engine walk, repeated flow sets
    walk the engine exactly once (counted by ``incidence_calls``), and
    the batch simulator rides the cache,
  * a slow-marked smoke that actually routes + simulates a 65,536-NIC
    preset through the jit path and cross-checks numpy at 1e-6.
"""

import json
import os

import numpy as np
import pytest

from repro.core.hyperx import MPHX
from repro.core.netsim import make_router
from repro.core.routing_vec import neighbor_shift_demands, uniform_demands
from repro.sim.events import FlowSpec, simulate_flow_batches
from repro.sim.fairshare import flow_incidence, max_min_rates

BENCH = os.path.join(os.path.dirname(__file__), "..", "results",
                     "BENCH_sim_scale.json")

ROW_KEYS = {"preset", "topology", "n_nics", "n_flows", "n_edges", "nnz",
            "n_epochs", "fct_p50_us", "fct_p99_us", "reference_timed",
            "wall_s", "wall_reps_s", "agreement"}


@pytest.fixture(scope="module")
def bench():
    with open(BENCH) as f:
        return json.load(f)


def test_bench_artifact_schema(bench):
    assert bench["schema_version"] == 1
    assert bench["bench"] == "sim_scale"
    assert set(bench["backends"]) == {"numpy", "jax", "pallas"}
    assert bench["workload"]["scenario"] == "neighbor_shift"
    for row in bench["scales"]:
        assert ROW_KEYS <= set(row)
        for b in bench["backends"]:
            assert row["wall_s"][b] > 0
            assert len(row["wall_reps_s"][b]) >= 1
        for agree in row["agreement"].values():
            assert agree["within_1e-6"] is True
            assert agree["max_rel_link_load_err"] < 1e-6
            assert agree["max_rel_fct_pct_err"] < 1e-6
        if row["reference_timed"]:
            assert row["speedup_jax"] > 0
    assert bench["all_within_1e-6"] is True


def test_bench_pins_10x_at_65k(bench):
    largest = {r["preset"]: r for r in bench["scales"]}[
        bench["largest_common_scale"]]
    assert largest["reference_timed"] is True
    assert largest["n_nics"] >= 65536          # a Table-2 65K-NIC fabric
    assert largest["speedup_jax"] >= 10.0
    assert bench["speedup_at_largest_common_scale"] == \
        largest["speedup_jax"]
    assert bench["meets_10x"] is True
    # the 65K sweep rows ran through the jit path and delivered
    for preset, row in bench["sweep_65k"].items():
        assert row["n_nics"] >= 65536, preset
        assert row["sim_delivered_fraction"] == 1.0


# ------------------------------------------------- incidence caching ----


def _small_router():
    return make_router(MPHX(n=2, p=8, dims=(8, 8)), backend="numpy")


def test_cached_incidence_identical_to_engine_walk():
    router = _small_router()
    dem = neighbor_shift_demands(router.topo, 800.0)
    flow, edge, frac = router.incidence(dem, "minimal")
    cf, ce, cfr = router.incidence_cached(dem, "minimal")
    assert np.array_equal(cf, flow)
    assert np.array_equal(ce, edge)
    assert np.array_equal(cfr, frac)


def test_repeated_flow_sets_walk_engine_once():
    router = _small_router()
    dem = uniform_demands(router.topo, 400.0)
    assert router.incidence_calls == 0
    for _ in range(3):
        flow_incidence(router, dem, "minimal", cached=True)
    # one walk covered all three extractions: every (src, dst) pair was
    # cached on the first pass
    assert router.incidence_calls == 1
    # a new mode is a different path spread: exactly one more walk
    flow_incidence(router, dem, "valiant", cached=True)
    flow_incidence(router, dem, "valiant", cached=True)
    assert router.incidence_calls == 2
    router.reset_incidence_cache()
    flow_incidence(router, dem, "minimal", cached=True)
    assert router.incidence_calls == 3


def test_partial_overlap_walks_only_new_pairs():
    router = _small_router()
    a = neighbor_shift_demands(router.topo, 800.0)
    flow_incidence(router, a, "minimal", cached=True)
    calls = router.incidence_calls
    # a flow set whose pairs are a subset of what's cached: no new walk
    sub = neighbor_shift_demands(router.topo, 800.0)
    flow_incidence(router, sub, "minimal", cached=True)
    assert router.incidence_calls == calls


def test_batch_simulator_rides_the_cache():
    router = _small_router()
    batches = [[FlowSpec(src=0, dst=1, size_bytes=1 << 20),
                FlowSpec(src=1, dst=2, size_bytes=1 << 20)]
               for _ in range(4)]
    res = simulate_flow_batches(router, batches, rate_cap_gbps=200.0)
    assert len(res.results) == 4
    # 4 identical phases, 1 engine walk
    assert router.incidence_calls == 1


# ----------------------------------------------------- 65K sim smoke ----


@pytest.mark.slow
def test_65k_preset_sim_smoke():
    from repro.experiments.sweep import SWEEP_TOPOLOGIES

    topo = SWEEP_TOPOLOGIES["mphx-8p-256"]
    assert topo.n_nics == 65536
    router = make_router(topo, backend="numpy")
    dem = neighbor_shift_demands(topo, 0.9 * topo.nic_bw_gbps)
    inc = flow_incidence(router, dem, "minimal")
    caps = np.asarray(dem.gbps)
    ref = max_min_rates(inc, caps, backend="numpy")
    jit = max_min_rates(inc, caps, backend="jax")
    scale = max(float(caps.max()), 1.0)
    assert np.abs(jit - ref).max() <= 1e-6 * scale

    from repro.sim.events import simulate_incidence
    rng = np.random.default_rng(7)
    size = rng.uniform(0.2, 1.0, inc.n_flows) * (1 << 24)
    start = rng.uniform(0.0, 200e-6, inc.n_flows)
    res = simulate_incidence(inc, size, caps, start_s=start, backend="jax")
    assert np.isfinite(res.finish_s).all()
    assert res.n_epochs > inc.n_flows      # staggered arrivals re-solve
    np.testing.assert_allclose(
        res.edge_bytes.sum(), (size * inc.switch_hops()).sum(), rtol=1e-9)
