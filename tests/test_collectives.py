"""Wrapper: run the multi-device checks in a subprocess with 8 forced host
devices (the main test process must keep seeing 1 device)."""

import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_multidevice_checks_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    script = os.path.join(os.path.dirname(__file__), "multidevice_checks.py")
    proc = subprocess.run([sys.executable, script], env=env,
                          capture_output=True, text=True, timeout=900)
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, "multidevice checks failed"
    assert "ALL MULTIDEVICE CHECKS PASSED" in proc.stdout
