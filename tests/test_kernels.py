"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
in interpret mode (CPU).  Per instructions: every kernel sweeps shapes and
dtypes and asserts allclose against ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep — deterministic fallback shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.kernels.flash_attention import (attention_ref, flash_attention,
                                           flash_attention_model_layout)
from repro.kernels.grouped_matmul import (grouped_matmul, grouped_matmul_ref,
                                          ragged_grouped_matmul,
                                          ragged_grouped_matmul_ref)
from repro.kernels.rg_lru import lru_scan, lru_scan_ref, rg_lru_pallas
from repro.kernels.rmsnorm import rmsnorm, rmsnorm_model_layout, rmsnorm_ref


def tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------ flash attn


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,K,Sq,Skv,Dh", [
    (1, 4, 4, 64, 64, 64),        # MHA square
    (2, 4, 2, 100, 100, 32),      # GQA, non-multiple seq
    (1, 8, 1, 128, 128, 64),      # MQA
    (2, 4, 2, 1, 96, 64),         # decode: q len 1, right-aligned
    (1, 2, 2, 33, 77, 128),       # cross-ish ragged
])
def test_flash_attention_sweep(dtype, B, H, K, Sq, Skv, Dh):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, Dh), dtype)
    k = jax.random.normal(ks[1], (B, K, Skv, Dh), dtype)
    v = jax.random.normal(ks[2], (B, K, Skv, Dh), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


@pytest.mark.parametrize("window", [1, 8, 64, None])
def test_flash_attention_windows(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 4, 80, 32))
    k = jax.random.normal(ks[1], (2, 2, 80, 32))
    v = jax.random.normal(ks[2], (2, 2, 80, 32))
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=16, block_k=16)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_bidirectional():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 2, 48, 64))
    k = jax.random.normal(ks[1], (1, 2, 80, 64))
    v = jax.random.normal(ks[2], (1, 2, 80, 64))
    out = flash_attention(q, k, v, causal=False, block_q=16, block_k=32)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_model_layout_matches_layers():
    """Kernel == the model layer's attention math (same inputs)."""
    from repro.models import layers as L

    B, S, K, G, Dh = 2, 64, 2, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, S, K, G, Dh))
    k = jax.random.normal(ks[1], (B, S, K, Dh))
    v = jax.random.normal(ks[2], (B, S, K, Dh))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    ref = L.attention_ref(q, k, v, pos, pos, causal=True)
    out = flash_attention_model_layout(q, k, v, causal=True, block_q=16,
                                       block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.slow
@given(sq=st.integers(1, 80), skv=st.integers(1, 80),
       bq=st.sampled_from([8, 16, 32]), bk=st.sampled_from([8, 16, 32]))
@settings(max_examples=15, deadline=None)
def test_flash_attention_property_shapes(sq, skv, bq, bk):
    if sq > skv:
        sq = skv  # causal right-aligned requires Sq <= Skv
    ks = jax.random.split(jax.random.PRNGKey(sq * 81 + skv), 3)
    q = jax.random.normal(ks[0], (1, 2, sq, 32))
    k = jax.random.normal(ks[1], (1, 1, skv, 32))
    v = jax.random.normal(ks[2], (1, 1, skv, 32))
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)


# -------------------------------------------------------- grouped matmul


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,M,K,N,bm", [
    (1, 32, 32, 32, 16),
    (4, 50, 40, 30, 16),      # non-multiples everywhere
    (8, 128, 64, 96, 64),
])
def test_grouped_matmul_sweep(dtype, E, M, K, N, bm):
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    x = jax.random.normal(ks[0], (E, M, K), dtype)
    w = jax.random.normal(ks[1], (E, K, N), dtype)
    out = grouped_matmul(x, w, block_m=bm, block_n=16, block_k=16)
    ref = grouped_matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


@pytest.mark.parametrize("sizes", [
    [64, 64, 64, 64],
    [128, 0, 64, 64],          # empty group
    [256, 0, 0, 0],            # all one group
    [32, 96, 64, 64],          # non-block-multiple boundaries -> masked
])
def test_ragged_grouped_matmul(sizes):
    gs = jnp.asarray(sizes)
    T = int(gs.sum())
    ks = jax.random.split(jax.random.PRNGKey(5), 2)
    x = jax.random.normal(ks[0], (T, 32))
    w = jax.random.normal(ks[1], (4, 32, 16))
    out = ragged_grouped_matmul(x, w, gs, block_m=32, block_k=16)
    ref = ragged_grouped_matmul_ref(x, w, gs)
    # rows whose block straddles a group boundary are masked to 0 in the
    # kernel (callers pad groups to block multiples); compare only rows
    # whose block is fully owned.
    owned = np.ones(T, bool)
    start = 0
    for size in sizes:
        if start % 32 and size:
            blk0 = start - (start % 32)
            owned[blk0:start] &= False  # previous block spills into group
            owned[start:blk0 + 32] &= False
        start += size
    np.testing.assert_allclose(np.asarray(out)[owned],
                               np.asarray(ref)[owned], atol=2e-5, rtol=2e-5)


def test_ragged_block_aligned_exact():
    """With block-aligned group sizes the ragged kernel is exact."""
    gs = jnp.asarray([64, 128, 0, 64])
    T = 256
    ks = jax.random.split(jax.random.PRNGKey(6), 2)
    x = jax.random.normal(ks[0], (T, 48))
    w = jax.random.normal(ks[1], (4, 48, 24))
    out = ragged_grouped_matmul(x, w, gs, block_m=64, block_k=16)
    ref = ragged_grouped_matmul_ref(x, w, gs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------- rg-lru


@pytest.mark.parametrize("B,S,W,chunk,bw", [
    (1, 16, 32, 8, 16),
    (2, 75, 96, 16, 32),       # non-multiples
    (3, 128, 64, 128, 64),     # single chunk
    (1, 200, 48, 32, 48),
])
def test_lru_scan_sweep(B, S, W, chunk, bw):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    a = jax.random.uniform(ks[0], (B, S, W), minval=0.4, maxval=0.999)
    b = jax.random.normal(ks[1], (B, S, W))
    h0 = jax.random.normal(ks[2], (B, W))
    y, hl = lru_scan(a, b, h0, chunk=chunk, block_w=bw)
    yr, hr = lru_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hr), atol=1e-5,
                               rtol=1e-5)


def test_rg_lru_pallas_matches_model_scan():
    """Full-block wrapper == the model's associative-scan implementation."""
    from repro.models.rglru import rg_lru_init, rg_lru_scan

    B, S, W = 2, 40, 64
    p = rg_lru_init(jax.random.PRNGKey(8), W)
    x = jax.random.normal(jax.random.PRNGKey(9), (B, S, W))
    h0 = jnp.zeros((B, W))
    y_ref, h_ref = rg_lru_scan(p, x, h0=h0)
    y_k, h_k = rg_lru_pallas(p, x, h0, chunk=16, block_w=32)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_ref),
                               atol=1e-5, rtol=1e-4)


# --------------------------------------------------------------- rmsnorm


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("N,D,bn", [(7, 64, 8), (100, 256, 32),
                                    (256, 1024, 256)])
def test_rmsnorm_sweep(dtype, N, D, bn):
    ks = jax.random.split(jax.random.PRNGKey(10), 2)
    x = jax.random.normal(ks[0], (N, D), dtype)
    s = jax.random.normal(ks[1], (D,))
    out = rmsnorm(x, s, block_n=bn)
    ref = rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


def test_rmsnorm_model_layout_matches_layers():
    from repro.models import layers as L

    x = jax.random.normal(jax.random.PRNGKey(11), (2, 10, 64))
    s = jax.random.normal(jax.random.PRNGKey(12), (64,))
    ref = L.rmsnorm({"scale": s}, x)
    out = rmsnorm_model_layout(x, s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
