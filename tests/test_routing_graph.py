"""Generic graph routing engine (repro.core.routing_graph).

Load-bearing guarantees:

* cross-engine equivalence — on untrunked MPHX (equal per-dim link
  multiplicity) the graph engine's multiplicity-proportional ECMP equals
  the array engine's ordering-ECMP *and* the legacy per-flow dict router,
  to 1e-9;
* flow conservation — for every switch, injected + inflow equals
  delivered + outflow (checked on Fat-Tree and Dragonfly, all modes);
* the schema-v2 sweep artifact round-trips, records the engine per row,
  and turns undefined (topology, scenario) cells into explicit skipped
  records instead of dropping them.
"""

import json

import numpy as np
import pytest

from repro.experiments.artifacts import SCHEMA_VERSION
from repro.core import MPHX
from repro.core.dragonfly import Dragonfly, DragonflyPlus
from repro.core.fattree import MultiPlaneFatTree, ThreeTierFatTree
from repro.core.netsim import load_sweep, make_router, resolve_engine
from repro.core.routing import HyperXRouter, uniform_traffic
from repro.core.routing_graph import (CSRGraph, GraphRouter,
                                      graph_hotspot_demands,
                                      graph_reverse_demands,
                                      graph_ring_demands,
                                      graph_shift_demands,
                                      graph_uniform_demands)
from repro.core.routing_vec import (VectorizedHyperXRouter,
                                    neighbor_shift_demands, uniform_demands)
from repro.experiments import SCENARIOS, run_sweep_suite

# untrunked MPHX (multiplicity 1 in every dim): multiplicity-proportional
# next-hop ECMP == equal ordering ECMP, so all three engines must agree
UNTRUNKED = [
    MPHX(n=2, p=8, dims=(8, 8)),
    MPHX(n=1, p=4, dims=(4, 3)),
    MPHX(n=2, p=3, dims=(3, 3, 3)),
    MPHX(n=8, p=16, dims=(16,)),
]

BASELINES = [
    ThreeTierFatTree(radix=8, nics=128, name="3-layer Fat-Tree (small)"),
    MultiPlaneFatTree(n=2, nics=32, base_radix=4,
                      name="2-Plane 2-layer Fat-Tree (small)"),
    Dragonfly(p=2, a=4, h=2, groups=9, name="Dragonfly (small)"),
    DragonflyPlus(p=2, leaves=4, spines=4, groups=8, global_per_spine=7,
                  name="Dragonfly+ (small)"),
]


def _dict_diff(a: dict, b: dict) -> float:
    keys = set(a) | set(b)
    return max(abs(a.get(k, 0.0) - b.get(k, 0.0)) for k in keys)


# ------------------------------------------------------------ structure ----


@pytest.mark.parametrize("topo", BASELINES, ids=lambda t: t.name)
def test_bfs_matches_switchgraph(topo):
    g = topo.build_graph()
    csr = CSRGraph(g)
    hops = csr.all_pairs_hops()
    for src in range(0, g.n_switches, max(1, g.n_switches // 7)):
        assert hops[src].tolist() == g.bfs_dist(src)
    # NIC-to-NIC worst case stays within the paper diameter.  Transit-only
    # switch pairs may be farther (Dragonfly+ spine to spine bounces
    # through a leaf), and the built Dragonfly+ graph realizes leaf-leaf
    # distance 3 (leaf-spine-spine-leaf) where the class keeps the paper's
    # conservative diameter 6 — hence <=, with equality on the other three.
    nic = np.asarray(g.nic_nodes)
    nic_max = hops[np.ix_(nic, nic)].max()
    assert 2 <= nic_max <= topo.diameter - 2
    if not isinstance(topo, DragonflyPlus):
        assert nic_max == topo.diameter - 2


def test_csr_capacity_matches_multigraph():
    topo = BASELINES[2]
    g = topo.build_graph()
    csr = CSRGraph(g)
    for e in range(csr.n_edges):
        u, v = int(csr.src[e]), int(csr.dst[e])
        assert csr.mult[e] == pytest.approx(g.multiplicity(u, v))
        assert csr.cap[e] == pytest.approx(g.multiplicity(u, v) * g.link_gbps)


def test_disconnected_graph_raises():
    from repro.core.topology import SwitchGraph

    g = SwitchGraph(4, 1, 100.0)
    g.add_edge(0, 1)
    g.add_edge(2, 3)
    with pytest.raises(ValueError, match="disconnected"):
        CSRGraph(g).all_pairs_hops()


# --------------------------------------------------- cross-engine checks ----


@pytest.mark.parametrize("topo", UNTRUNKED, ids=lambda t: t.name)
@pytest.mark.parametrize("pattern", ["uniform", "neighbor_shift"])
def test_graph_matches_array_engine_minimal(topo, pattern):
    build = uniform_demands if pattern == "uniform" else neighbor_shift_demands
    d = build(topo, 1600.0)
    vec = VectorizedHyperXRouter(topo).route(d, "minimal")
    gr = GraphRouter(topo).route(d, "minimal")
    assert _dict_diff(vec.to_dict(), gr.to_dict()) < 1e-9
    assert gr.max_utilization() == pytest.approx(vec.max_utilization(),
                                                 abs=1e-9)
    assert gr.saturation_throughput() == pytest.approx(
        vec.saturation_throughput(), abs=1e-9)


def test_three_engines_agree_on_mphx():
    """graph vs array vs legacy per-flow dict, one small MPHX."""
    topo = MPHX(n=2, p=4, dims=(4, 4))
    demands = uniform_traffic(topo, 1600.0)
    legacy = HyperXRouter(topo).route(demands, mode="minimal")
    from repro.core.routing_vec import demands_from_dict

    arr = demands_from_dict(demands)
    vec = VectorizedHyperXRouter(topo).route(arr, "minimal")
    gr = GraphRouter(topo).route(arr, "minimal")
    ld = {k: v for k, v in legacy.loads.items() if v > 0}
    assert _dict_diff(ld, gr.to_dict()) < 1e-9
    assert _dict_diff(vec.to_dict(), gr.to_dict()) < 1e-9


def test_jax_backend_matches_numpy_graph():
    jax = pytest.importorskip("jax")
    topo = Dragonfly(p=2, a=4, h=2, groups=9)
    d = graph_shift_demands(topo, 1600.0)
    ref = GraphRouter(topo, backend="numpy").route(d, "adaptive")
    old = jax.config.jax_enable_x64
    try:
        jax.config.update("jax_enable_x64", True)
        jx = GraphRouter(topo, backend="jax").route(d, "adaptive")
        assert np.allclose(np.asarray(jx.loads), np.asarray(ref.loads),
                           atol=1e-9)
    finally:
        jax.config.update("jax_enable_x64", old)


# ----------------------------------------------------- flow conservation ----


def _node_balance(topo, demands, mode):
    """max |injected + inflow - delivered - outflow| over switches."""
    router = GraphRouter(topo)
    ll = router.route(demands, mode)
    S = router.csr.n_switches
    inflow = np.zeros(S)
    outflow = np.zeros(S)
    np.add.at(outflow, router.csr.src, ll._np_loads())
    np.add.at(inflow, router.csr.dst, ll._np_loads())
    injected = np.zeros(S)
    delivered = np.zeros(S)
    np.add.at(injected, demands.src, demands.gbps)
    np.add.at(delivered, demands.dst, demands.gbps)
    return np.abs(injected + inflow - delivered - outflow).max()


@pytest.mark.parametrize("topo", [BASELINES[0], BASELINES[2]],
                         ids=["fattree", "dragonfly"])
@pytest.mark.parametrize("mode", ["minimal", "valiant", "adaptive"])
@pytest.mark.parametrize("pattern", [graph_uniform_demands,
                                     graph_shift_demands],
                         ids=["uniform", "shift"])
def test_ecmp_load_conservation(topo, mode, pattern):
    """Total in == total out at every switch: what enters the fabric (or a
    transit switch) leaves it.  Valiant balances too — stage-1 delivery at
    each via equals stage-2 injection there."""
    d = pattern(topo, 1600.0)
    assert _node_balance(topo, d, mode) < 1e-6


@pytest.mark.parametrize("topo", [BASELINES[0], BASELINES[2]],
                         ids=["fattree", "dragonfly"])
def test_minimal_total_load_is_hop_weighted_demand(topo):
    router = GraphRouter(topo)
    d = graph_uniform_demands(topo, 1600.0)
    ll = router.route(d, "minimal")
    expect = float((d.gbps * router.hops[d.src, d.dst]).sum())
    assert ll.total_load() == pytest.approx(expect, rel=1e-9)


def test_adaptive_improves_dragonfly_adversarial():
    """UGAL must beat minimal on the canonical Dragonfly adversarial
    pattern (+1 group shift concentrates on single global trunks)."""
    topo = Dragonfly(p=2, a=4, h=2, groups=9)
    d = graph_shift_demands(topo, 1600.0)
    router = GraphRouter(topo)
    mn = router.route(d, "minimal").max_utilization()
    vl = router.route(d, "valiant").max_utilization()
    ad = router.route(d, "adaptive").max_utilization()
    assert vl < mn
    assert ad < mn / 1.5
    # and adaptive never loses to pure VLB here
    assert ad <= vl + 1e-9


# ------------------------------------------------ generic demand builders ----


@pytest.mark.parametrize("topo", BASELINES, ids=lambda t: t.name)
def test_generic_builders_use_nic_switches_only(topo):
    g = topo.build_graph()
    nic = set(g.nic_nodes)
    total_nics = g.total_nics
    assert total_nics == topo.n_nics
    per_plane = total_nics * 1600.0 / topo.n_planes
    per_switch = g.nics_per_switch * 1600.0 / topo.n_planes
    for build in (graph_uniform_demands, graph_shift_demands,
                  graph_reverse_demands, graph_hotspot_demands,
                  graph_ring_demands):
        d = build(topo, 1600.0)
        assert d.n > 0
        assert set(d.src.tolist()) <= nic
        assert set(d.dst.tolist()) <= nic
        assert np.all(d.src != d.dst)
        # every builder injects one plane's share of total NIC bandwidth
        # (hotspot: the hot switch keeps its own incast share, like the
        # MPHX hotspot builder)
        expect = per_plane
        if build is graph_hotspot_demands:
            expect -= 0.5 * per_switch
        assert d.total_gbps() == pytest.approx(expect)


def test_transit_switches_bear_no_nics():
    ft = BASELINES[0].build_graph()
    counts = np.asarray(ft.nic_counts())
    assert counts[np.asarray(ft.nic_nodes)].all()
    assert counts.sum() == BASELINES[0].n_nics  # edge switches only
    dfp = BASELINES[3].build_graph()
    assert len(dfp.nic_nodes) == 4 * 8  # leaves x groups
    assert np.asarray(dfp.nic_counts()).sum() == BASELINES[3].n_nics


# ------------------------------------------------- sweep integration (v2) ----


def test_resolve_engine_and_make_router():
    mphx = UNTRUNKED[0]
    df = BASELINES[2]
    assert resolve_engine(mphx) == "array"
    assert resolve_engine(df) == "graph"
    assert resolve_engine(mphx, "graph") == "graph"
    with pytest.raises(ValueError):
        resolve_engine(df, "array")
    with pytest.raises(ValueError):
        resolve_engine(df, "quantum")
    assert isinstance(make_router(df), GraphRouter)
    assert isinstance(make_router(mphx), VectorizedHyperXRouter)
    assert isinstance(make_router(mphx, engine="graph"), GraphRouter)


def test_load_sweep_graph_engine_matches_array_on_mphx():
    topo = MPHX(n=2, p=8, dims=(8, 8))
    kw = dict(mode="minimal", load_fractions=(0.5, 1.0))
    arr = load_sweep(topo, uniform_demands, engine="array", **kw)
    gr = load_sweep(topo, uniform_demands, engine="graph", **kw)
    for a, g in zip(arr, gr):
        # rows round max_util to 6 decimals; engines agree to 1e-9 before
        # rounding, so allow one ulp of the rounded representation
        assert g["max_util"] == pytest.approx(a["max_util"], abs=2e-6)
        assert g["latency_us"] == pytest.approx(a["latency_us"], abs=1e-3)


def test_scenarios_apply_to_baselines():
    df = BASELINES[2]
    for name, sc in SCENARIOS.items():
        if name == "transpose":
            assert sc.skip_reason(df) is not None
            continue
        assert sc.skip_reason(df) is None
        d = sc.build(df, 1600.0)
        assert d.n > 0 and np.all(d.gbps > 0)


def test_sweep_schema_v2_roundtrip_and_skips(tmp_path, capsys):
    payload = run_sweep_suite(
        outdir=str(tmp_path), topo_names=["dragonfly-small"],
        scenario_names=["uniform", "transpose"],
        modes=["minimal"], load_fractions=(0.5, 1.0))
    disk = json.loads((tmp_path / "sweep.json").read_text())
    assert disk == payload
    assert disk["schema_version"] == SCHEMA_VERSION
    assert disk["params"]["n_routed_rows"] == 2
    assert disk["params"]["n_skipped"] == 1
    routed = [r for r in disk["rows"] if not r.get("skipped")]
    skipped = [r for r in disk["rows"] if r.get("skipped")]
    assert all(r["engine"] == "graph" for r in routed)
    assert all(r["scenario"] == "uniform" for r in routed)
    assert skipped[0]["scenario"] == "transpose"
    assert "coordinate" in skipped[0]["reason"]
    # the skip is announced on stderr, per the no-silent-caps rule
    assert "transpose" in capsys.readouterr().err
    # and surfaces in the markdown for PR review
    assert "Skipped" in (tmp_path / "sweep.md").read_text()


def test_sweep_forced_incompatible_engine_skips_topology(tmp_path, capsys):
    """--engine array on a baseline topology must yield an explicit skip
    record for that topology, not abort the suite."""
    payload = run_sweep_suite(
        outdir=str(tmp_path), topo_names=["dragonfly-small", "mphx-2p-8x8"],
        scenario_names=["uniform"], modes=["minimal"],
        load_fractions=(1.0,), engine="array")
    skipped = [r for r in payload["rows"] if r.get("skipped")]
    routed = [r for r in payload["rows"] if not r.get("skipped")]
    assert len(skipped) == 1
    assert skipped[0]["topology"] == "Dragonfly (small)"
    assert "MPHX-only" in skipped[0]["reason"]
    assert routed and all(r["topology"] == "MPHX(2,8,8,8)" for r in routed)
    assert "skipping topology" in capsys.readouterr().err


def test_sweep_mphx_rows_keep_array_engine(tmp_path):
    payload = run_sweep_suite(
        outdir=str(tmp_path), topo_names=["mphx-2p-8x8"],
        scenario_names=["uniform"], modes=["minimal"],
        load_fractions=(1.0,))
    rows = [r for r in payload["rows"] if not r.get("skipped")]
    assert rows and all(r["engine"] == "array" for r in rows)
