"""Property-based tests for max-min fair water-filling.

Invariants on random COO flow-incidence tensors, checked against the
numpy reference solver and (at fixed shapes, so jit compiles once) the
in-jit jax and Pallas paths:

  * no edge ever carries more than its capacity,
  * every active flow below its demand cap crosses a saturated edge
    (the max-min "bottlenecked" fixpoint condition),
  * rates stay within [0, cap] and below the flow's alone-on-the-fabric
    bottleneck rate; inactive flows hold exactly 0,
  * relabeling flows permutes the rates and nothing else,
  * the three solver backends agree to 1e-9.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep — deterministic fallback shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.sim.fairshare import (FlowIncidence, _compress_edges,
                                 max_min_rates)

seed_st = st.integers(0, 10_000)

# jax/pallas recompile per (F, NNZ, compressed-E) signature, so the
# cross-backend tests pin the shape and vary only the values; the
# numpy-only invariants sample shapes freely.
FIXED_F, FIXED_E, FIXED_NNZ = 8, 12, 16


def random_incidence(seed: int, fixed_shape: bool = False):
    """A random coalesced incidence + finite caps + active mask."""
    rng = np.random.default_rng(seed)
    if fixed_shape:
        F, E, nnz = FIXED_F, FIXED_E, FIXED_NNZ
    else:
        F = int(rng.integers(1, 13))
        E = int(rng.integers(1, 17))
        nnz = int(rng.integers(0, min(F * E, 24) + 1))
    pairs = rng.choice(F * E, size=min(nnz, F * E), replace=False)
    flow = (pairs // E).astype(np.int64)
    edge = (pairs % E).astype(np.int64)
    order = np.argsort(flow, kind="stable")
    inc = FlowIncidence(
        flow=flow[order], edge=edge[order],
        frac=rng.uniform(0.1, 2.0, flow.size),
        n_flows=F,
        capacity=rng.uniform(0.5, 10.0, E))
    caps = rng.uniform(0.1, 5.0, F)
    active = rng.random(F) < 0.8
    if not active.any():
        active[0] = True
    return inc, caps, active


def solver_tol(inc, caps) -> float:
    scale = max(inc.capacity.max(initial=0.0),
                caps.max() if caps.size else 0.0, 1.0)
    return 1e-7 * scale


@given(seed=seed_st)
@settings(max_examples=80, deadline=None)
def test_no_edge_over_capacity(seed):
    inc, caps, active = random_incidence(seed)
    rates = max_min_rates(inc, caps, active=active, backend="numpy")
    loads = inc.loads(rates)
    assert np.all(loads <= inc.capacity + solver_tol(inc, caps))


@given(seed=seed_st)
@settings(max_examples=80, deadline=None)
def test_every_uncapped_flow_is_bottlenecked(seed):
    inc, caps, active = random_incidence(seed)
    rates = max_min_rates(inc, caps, active=active, backend="numpy")
    loads = inc.loads(rates)
    tol = solver_tol(inc, caps)
    saturated = loads >= inc.capacity - tol
    for f in range(inc.n_flows):
        if not active[f] or rates[f] >= caps[f] - tol:
            continue
        my_edges = inc.edge[inc.flow == f]
        # a flow held below its cap must be blocked by the fabric: it
        # has fabric edges and at least one of them is saturated
        assert my_edges.size > 0
        assert saturated[my_edges].any()


@given(seed=seed_st)
@settings(max_examples=80, deadline=None)
def test_rate_bounds_and_inactive_flows(seed):
    inc, caps, active = random_incidence(seed)
    rates = max_min_rates(inc, caps, active=active, backend="numpy")
    tol = solver_tol(inc, caps)
    assert np.all(rates >= 0.0)
    assert np.all(rates <= caps + tol)
    assert np.all(rates[~active] == 0.0)
    alone = inc.bottleneck_gbps()
    assert np.all(rates <= np.minimum(caps, alone) + tol)


@given(seed=seed_st)
@settings(max_examples=40, deadline=None)
def test_flow_permutation_invariance(seed):
    inc, caps, active = random_incidence(seed)
    rates = max_min_rates(inc, caps, active=active, backend="numpy")
    rng = np.random.default_rng(seed + 1)
    perm = rng.permutation(inc.n_flows)
    inc_p = FlowIncidence(
        flow=perm[inc.flow], edge=inc.edge, frac=inc.frac,
        n_flows=inc.n_flows, capacity=inc.capacity)
    caps_p = np.empty_like(caps)
    caps_p[perm] = caps
    active_p = np.zeros_like(active)
    active_p[perm] = active
    rates_p = max_min_rates(inc_p, caps_p, active=active_p,
                            backend="numpy")
    scale = max(float(caps.max()), 1.0)
    assert np.abs(rates_p[perm] - rates).max() <= 1e-9 * scale


@pytest.mark.parametrize("backend", ["jax", "pallas"])
@given(seed=seed_st)
@settings(max_examples=15, deadline=None)
def test_backends_agree_with_reference(backend, seed):
    inc, caps, active = random_incidence(seed, fixed_shape=True)
    ref = max_min_rates(inc, caps, active=active, backend="numpy")
    got = max_min_rates(inc, caps, active=active, backend=backend)
    scale = max(float(caps.max()), 1.0)
    assert np.abs(got - ref).max() <= 1e-9 * scale


def test_empty_flow_set():
    inc = FlowIncidence(flow=np.zeros(0, dtype=np.int64),
                        edge=np.zeros(0, dtype=np.int64),
                        frac=np.zeros(0), n_flows=0,
                        capacity=np.ones(4))
    assert max_min_rates(inc, np.zeros(0), backend="numpy").shape == (0,)


def test_single_flow_takes_min_of_cap_and_bottleneck():
    inc = FlowIncidence(flow=np.array([0, 0]), edge=np.array([1, 3]),
                        frac=np.array([1.0, 0.5]), n_flows=1,
                        capacity=np.array([9.0, 4.0, 9.0, 1.0]))
    # bottleneck: min(4.0/1.0, 1.0/0.5) = 2.0
    for backend in ("numpy", "jax", "pallas"):
        r = max_min_rates(inc, np.array([10.0]), backend=backend)
        assert abs(float(r[0]) - 2.0) <= 1e-9
        r = max_min_rates(inc, np.array([1.5]), backend=backend)
        assert abs(float(r[0]) - 1.5) <= 1e-9


def test_infinite_caps_rejected():
    inc = FlowIncidence(flow=np.array([0]), edge=np.array([0]),
                        frac=np.array([1.0]), n_flows=2,
                        capacity=np.array([1.0]))
    with pytest.raises(ValueError, match="finite"):
        max_min_rates(inc, np.array([1.0, np.inf]), backend="numpy")


def test_compress_edges_preserves_solution():
    inc, caps, active = random_incidence(123)
    used, edge_c, cap_c = _compress_edges(inc)
    assert np.array_equal(used[edge_c], inc.edge)
    assert np.array_equal(cap_c, inc.capacity[used])
    inc_c = FlowIncidence(flow=inc.flow, edge=edge_c, frac=inc.frac,
                          n_flows=inc.n_flows, capacity=cap_c)
    ref = max_min_rates(inc, caps, active=active, backend="numpy")
    got = max_min_rates(inc_c, caps, active=active, backend="numpy")
    assert np.array_equal(got, ref)
