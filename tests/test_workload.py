"""Tests for the multi-tenant workload subsystem (repro.workload).

Load-bearing pins: seeded determinism of every sampler and of the whole
serving artifact (same seed -> byte-identical rows), byte conservation
between request KV payloads and emitted flows, offered load matching
the Poisson rate within statistical tolerance, the uncontended
closed-form KV-transfer FCT at 1e-6, and tag-driven attribution through
``sim/events.py`` (no index arithmetic anywhere).
"""

import numpy as np
import pytest

from repro.experiments.artifacts import SCHEMA_VERSION
from repro.core.hyperx import MPHX
from repro.core.netsim import make_router, gbps_to_Bps
from repro.cosim.placement import rank_to_switch
from repro.sim.events import (FlowSpec, flows_to_demands, path_latency,
                              simulate_demands, simulate_flow_batches,
                              simulate_flows, simulate_incidence)
from repro.sim.fairshare import flow_incidence
from repro.workload import (EMPIRICAL_CDFS, BackgroundTenantSpec,
                            ServingTenantSpec, SizeDist,
                            TrainingTenantSpec, build_serving_workload,
                            kv_bytes_per_token, mean_size, mmpp_arrivals,
                            poisson_arrivals, run_tenant_mix,
                            sample_sizes, serving_ttft_s, slo_rows,
                            tenant_mask, tenant_of)


def _topo() -> MPHX:
    return MPHX(n=2, p=8, dims=(8, 8))


def _switch_of(topo):
    return rank_to_switch(topo, None)


# ------------------------------------------------------------ samplers ----


@pytest.mark.parametrize("dist", [
    SizeDist("fixed", mean=100.0),
    SizeDist("lognormal", mean=800.0, sigma=1.0),
    SizeDist("pareto", alpha=1.2, lo=128.0, hi=32768.0),
    SizeDist("empirical", name="websearch"),
    SizeDist("empirical", name="datamining"),
    SizeDist("empirical", name="hadoop"),
])
def test_sampler_seeded_determinism(dist):
    a = sample_sizes(dist, 500, np.random.default_rng(42))
    b = sample_sizes(dist, 500, np.random.default_rng(42))
    c = sample_sizes(dist, 500, np.random.default_rng(43))
    np.testing.assert_array_equal(a, b)
    if dist.kind != "fixed":
        assert not np.array_equal(a, c)
    assert (a > 0).all()


@pytest.mark.parametrize("dist", [
    SizeDist("lognormal", mean=1000.0, sigma=0.7),
    SizeDist("pareto", alpha=1.5, lo=100.0, hi=1e6),
    SizeDist("empirical", name="websearch"),
])
def test_sampler_mean_matches_analytic(dist):
    # law of large numbers: the empirical mean approaches mean_size()
    s = sample_sizes(dist, 200_000, np.random.default_rng(0))
    assert s.mean() == pytest.approx(mean_size(dist), rel=0.05)


def test_sampler_bounds():
    d = SizeDist("pareto", alpha=1.1, lo=64.0, hi=4096.0)
    s = sample_sizes(d, 10_000, np.random.default_rng(1))
    assert s.min() >= 64.0 and s.max() <= 4096.0
    for name, pts in EMPIRICAL_CDFS.items():
        e = sample_sizes(SizeDist("empirical", name=name), 10_000,
                         np.random.default_rng(2))
        assert e.min() >= pts[0][0] and e.max() <= pts[-1][0]


def test_sampler_unknown_kind_raises():
    with pytest.raises(ValueError):
        SizeDist("zipf")
    with pytest.raises(ValueError):
        SizeDist("empirical", name="nope")


def test_poisson_rate_within_tolerance():
    # offered load matches the Poisson rate: ~N(rate*T, rate*T), so a
    # 5-sigma band around the expectation is a deterministic-seed-safe
    # statistical check
    rate, T = 2000.0, 2.0
    arr = poisson_arrivals(rate, T, np.random.default_rng(3))
    expect = rate * T
    assert abs(arr.size - expect) < 5 * np.sqrt(expect)
    assert (np.diff(arr) >= 0).all() and arr.min() >= 0 and arr.max() < T
    a2 = poisson_arrivals(rate, T, np.random.default_rng(3))
    np.testing.assert_array_equal(arr, a2)


def test_mmpp_rate_and_burstiness():
    rate, T = 2000.0, 4.0
    arr = mmpp_arrivals(rate, T, np.random.default_rng(4), burstiness=6.0)
    # long-run mean rate is preserved (looser band: dwell correlation)
    assert arr.size == pytest.approx(rate * T, rel=0.25)
    assert (np.diff(arr) >= 0).all() and arr.max() < T
    # burstier than Poisson: variance of per-bin counts exceeds the mean
    bins = np.histogram(arr, bins=int(T / 0.005))[0]
    assert bins.var() > 1.5 * bins.mean()
    # burstiness=1 degenerates to plain Poisson statistics
    calm = mmpp_arrivals(rate, T, np.random.default_rng(5), burstiness=1.0)
    cbins = np.histogram(calm, bins=int(T / 0.005))[0]
    assert cbins.var() < 1.5 * cbins.mean()


# ----------------------------------------------------- serving tenant ----


def test_kv_bytes_per_token_accounting():
    from repro.models.registry import get_config
    cfg = get_config("mixtral-8x22b")
    kv = kv_bytes_per_token(cfg)
    assert kv == 2.0 * cfg.n_layers * cfg.n_kv_heads \
        * cfg.resolved_head_dim * 2  # bfloat16


def test_serving_byte_conservation():
    # KV payload is conserved between requests and emitted flows + the
    # intra-switch remainder
    topo = _topo()
    spec = ServingTenantSpec("t", rate_hz=400.0, duration_s=0.1,
                             hotspot_fraction=0.3)
    w = build_serving_workload(spec, _switch_of(topo), 0, topo.port_gbps,
                               np.random.default_rng(7))
    assert w.n_requests > 0
    flow_bytes = sum(f.size_bytes for f in w.flows)
    assert flow_bytes + w.intra_bytes == pytest.approx(
        w.kv_bytes.sum(), rel=1e-12)
    # every flow is tagged (tenant, request) and starts at the request's
    # prefill-complete time
    start_of = {r: float(w.kv_start_s[r]) for r in range(w.n_requests)}
    for f in w.flows:
        assert tenant_of(f.tag) == "t"
        assert f.start_s == pytest.approx(start_of[f.tag[1]])


def test_serving_workload_determinism():
    topo = _topo()
    spec = ServingTenantSpec("t", rate_hz=300.0, duration_s=0.1)
    a = build_serving_workload(spec, _switch_of(topo), 0, topo.port_gbps,
                               np.random.default_rng(11))
    b = build_serving_workload(spec, _switch_of(topo), 0, topo.port_gbps,
                               np.random.default_rng(11))
    np.testing.assert_array_equal(a.arrival_s, b.arrival_s)
    np.testing.assert_array_equal(a.kv_bytes, b.kv_bytes)
    np.testing.assert_array_equal(a.decode_replica, b.decode_replica)
    assert a.flows == b.flows


def test_serving_hotspot_incast():
    topo = _topo()
    spec = ServingTenantSpec("t", rate_hz=2000.0, duration_s=0.1,
                             decode_replicas=4, hotspot_fraction=0.9)
    w = build_serving_workload(spec, _switch_of(topo), 0, topo.port_gbps,
                               np.random.default_rng(13))
    share = (w.decode_replica == 0).mean()
    assert share > 0.8   # ~0.9 + 0.1/4 of requests pin to replica 0


def test_serving_placement_overflow_raises():
    topo = _topo()
    spec = ServingTenantSpec("t", tp=topo.n_nics)   # cannot fit
    with pytest.raises(ValueError):
        build_serving_workload(spec, _switch_of(topo), 0, topo.port_gbps,
                               np.random.default_rng(0))


def test_closed_form_uncontended_kv_fct():
    # a single uncontended request's KV-transfer FCT ==
    # share_bytes / min(cap, bottleneck) + path alpha, exactly
    topo = _topo()
    router = make_router(topo, engine="array")
    spec = ServingTenantSpec(
        "pin", rate_hz=40.0, duration_s=0.05,
        prompt_tokens=SizeDist("fixed", mean=1000.0),
        prefill_replicas=1, decode_replicas=1, tp=topo.p)
    w = build_serving_workload(spec, _switch_of(topo), 0, topo.port_gbps,
                               np.random.default_rng(17))
    assert len(w.flows) >= 1
    f = w.flows[0]
    share = f.size_bytes / topo.n_planes
    cap = float(w.caps_gbps[0])
    inc = flow_incidence(router, flows_to_demands([f]), "minimal")
    res = simulate_incidence(inc, share, cap, start_s=f.start_s)
    expected = (share / gbps_to_Bps(min(cap, float(
        inc.bottleneck_gbps()[0]))) + float(path_latency(inc)[0]))
    assert float(res.fct_s[0]) == pytest.approx(expected, rel=1e-6)


# ------------------------------------------------------- tag threading ----


def test_flowspec_tag_threads_through_simulate_flows():
    topo = _topo()
    router = make_router(topo, engine="array")
    flows = [FlowSpec(0, 9, 1e6, tag=("a", 0)),
             FlowSpec(1, 10, 2e6, tag=("b", 0)),
             FlowSpec(2, 11, 1e6, tag=("a", 1))]
    res = simulate_flows(router, flows)
    assert res.tags is not None
    assert [tenant_of(t) for t in res.tags] == ["a", "b", "a"]
    np.testing.assert_array_equal(tenant_mask(res, "a"),
                                  [True, False, True])
    recs = res.flow_records()
    assert recs[1]["tag"] == ("b", 0)
    assert recs[1]["size_bytes"] == 2e6
    # untagged flows -> no tags array, tag-dependent helpers refuse
    res2 = simulate_flows(router, [FlowSpec(0, 9, 1e6)])
    assert res2.tags is None
    with pytest.raises(ValueError):
        tenant_mask(res2, "a")


def test_tags_do_not_perturb_simulation():
    topo = _topo()
    router = make_router(topo, engine="array")
    plain = [FlowSpec(0, 9, 1e6), FlowSpec(1, 10, 2e6)]
    tagged = [FlowSpec(0, 9, 1e6, tag="x"), FlowSpec(1, 10, 2e6, tag="y")]
    a = simulate_flows(router, plain)
    b = simulate_flows(router, tagged)
    np.testing.assert_array_equal(a.fct_s, b.fct_s)
    np.testing.assert_array_equal(a.edge_bytes, b.edge_bytes)


def test_simulate_demands_per_tag_breakdown():
    topo = _topo()
    router = make_router(topo, engine="array")
    dem = flows_to_demands([FlowSpec(0, 9, 1.0), FlowSpec(1, 10, 1.0),
                            FlowSpec(2, 11, 1.0)])
    dem = type(dem)(dem.src, dem.dst, np.full(3, 10.0))
    row = simulate_demands(router, dem, 1e-4,
                           tags=["a", "a", "b"])
    assert set(row["per_tag"]) == {"a", "b"}
    assert row["per_tag"]["a"]["flows"] == 2
    assert row["per_tag"]["b"]["flows"] == 1
    assert row["per_tag"]["a"]["fct_p50_us"] is not None
    # no tags -> no per_tag key (v5 consumers see identical rows)
    assert "per_tag" not in simulate_demands(router, dem, 1e-4)


def test_simulate_flow_batches_carries_tags():
    topo = _topo()
    router = make_router(topo, engine="array")
    batches = [[FlowSpec(0, 9, 1e6, tag=("t", 0))],
               [FlowSpec(0, 9, 1e6, tag=("t", 1))]]
    out = simulate_flow_batches(router, batches)
    assert out.results[0].tags[0] == ("t", 0)
    assert out.results[1].tags[0] == ("t", 1)


def test_flow_span_tag_in_trace():
    from repro.telemetry import TraceRecorder, recording
    topo = _topo()
    router = make_router(topo, engine="array")
    rec = TraceRecorder()
    with recording(rec):
        simulate_flows(router, [FlowSpec(0, 9, 1e6, tag=("chat", 3))])
    spans = [e for e in rec.events
             if e.get("cat") == "flow" and "tag" in e.get("args", {})]
    assert spans and spans[0]["args"]["tag"] == "('chat', 3)"


# --------------------------------------------------------- tenant mix ----


def _mix(seed=0, **kw):
    specs = [
        ServingTenantSpec("chat", rate_hz=200.0, duration_s=0.05),
        TrainingTenantSpec("train", n_ranks=16),
        # 16 NICs so the block spans two 8-port switches and actually
        # emits fabric flows (an 8-NIC block would be all intra-switch)
        BackgroundTenantSpec("web", rate_hz=1000.0, duration_s=0.05,
                             n_nics=16),
    ]
    return run_tenant_mix(_topo(), specs, seed=seed, **kw)


def test_tenant_mix_rows_and_attribution():
    mix = _mix()
    rows = slo_rows(mix)
    assert [r["tenant"] for r in rows] == ["chat", "train", "web"]
    assert {r["kind"] for r in rows} == {"serving", "training",
                                         "background"}
    for r in rows:
        assert r["n_stalled"] == 0
        assert r["fct_p50_us"] is not None
        assert r["fct_p50_us"] <= r["fct_p99_us"] <= r["fct_p999_us"]
        assert r["slowdown_mean"] >= 1.0 - 1e-9
    chat = rows[0]
    assert chat["n_requests"] > 0
    assert chat["ttft_p50_us"] is not None
    # TTFT includes prefill compute, so it dominates the bare fct
    assert chat["ttft_p50_us"] > chat["fct_p50_us"]
    # tag attribution partitions the mixed flows exactly
    n = sum(int(tenant_mask(mix.mixed, t.name).sum())
            for t in mix.traffic)
    assert n == mix.mixed.size_bytes.shape[0]


def test_tenant_mix_seed_determinism_and_sensitivity():
    a = slo_rows(_mix(seed=0))
    b = slo_rows(_mix(seed=0))
    c = slo_rows(_mix(seed=1))
    assert a == b
    assert a != c


def test_tenant_mix_ttft_validity():
    mix = _mix()
    ttft, valid = serving_ttft_s(mix, "chat")
    w = mix.tenant("chat").serving
    assert ttft.shape == (w.n_requests,)
    assert valid.all()
    # TTFT >= prefill compute delay for every request
    assert (ttft[valid] >= (w.kv_start_s - w.arrival_s)[valid] - 1e-12).all()


def test_tenant_mix_overflow_is_value_error():
    topo = MPHX(n=2, p=2, dims=(2, 2))   # 8 NICs total
    with pytest.raises(ValueError):
        run_tenant_mix(topo, [TrainingTenantSpec("big", n_ranks=16)])


# ------------------------------------------------------ serving suite ----


def test_serving_suite_artifact(tmp_path):
    import json
    from repro.experiments import run_serving_suite

    p1 = run_serving_suite(str(tmp_path / "a"), seed=0, duration_ms=20.0)
    p2 = run_serving_suite(str(tmp_path / "b"), seed=0, duration_ms=20.0)
    assert p1["schema_version"] == SCHEMA_VERSION
    assert p1 == p2   # same seed, same payload
    assert (tmp_path / "a" / "serving.json").exists()
    assert (tmp_path / "a" / "serving.md").exists()
    disk = json.loads((tmp_path / "a" / "serving.json").read_text())
    assert disk["schema_version"] == SCHEMA_VERSION
    assert disk["suite"] == "serving"
    assert disk["params"]["seed"] == 0
    assert disk["params"]["n_skipped"] == 0
    topos = {r["topology"] for r in disk["rows"]}
    assert topos == {"mphx-2p-8x8", "ft3-small", "dragonfly-small"}
    for r in disk["rows"]:
        assert not r.get("skipped")
        assert "fct_p50_us" in r and "fct_p999_us" in r
        if r["kind"] == "serving":
            assert "ttft_p99_us" in r


def test_serving_suite_skip_record(tmp_path):
    from repro.experiments.servesuite import run_serving_suite
    # the default tenant mix needs 40 NICs; mpft-2p-small has only 32,
    # which must yield an explicit skip record instead of a crash
    payload = run_serving_suite(str(tmp_path),
                                topo_names=["mpft-2p-small"],
                                seed=0, duration_ms=10.0)
    assert payload["params"]["n_skipped"] == 1
    row = payload["rows"][0]
    assert row["skipped"] and "NIC" in row["reason"] \
        or "needs" in row["reason"]


def test_serving_cli(tmp_path):
    from repro.experiments.run import main
    rc = main(["--suite", "serving", "--out", str(tmp_path),
               "--topos", "mphx-2p-8x8", "--tenants", "chat", "train",
               "--seed", "3", "--serving-duration-ms", "10"])
    assert rc == 0
    assert (tmp_path / "serving.json").exists()
