"""Golden regression tests for the water-filling rewrite.

``tests/golden/fairshare_golden.json`` was captured from the *pre-jit*
reference solver (see ``scripts/make_fairshare_golden.py``).  These
tests prove the rewrite did not move the model:

  * the numpy path still reproduces the fixture bit-for-bit (1e-12),
  * the in-jit jax path reproduces it to 1e-9 on steady-state rates,
    link loads and measured-FCT percentiles, on BOTH routing engines,
  * the full staggered-arrival event-loop trace (per-flow finish times,
    per-edge byte counts, exact epoch count) matches on every backend —
    the epoch semantics are identical, not merely statistically close.

Pallas runs where the interpreter-mode kernels are cheap (the small
flow sets); the jax path covers every cell.
"""

import json
import os

import numpy as np
import pytest

from repro.core.dragonfly import Dragonfly
from repro.core.hyperx import MPHX
from repro.core.netsim import make_router
from repro.core.routing_graph import graph_uniform_demands
from repro.core.routing_vec import (hotspot_demands, neighbor_shift_demands,
                                    uniform_demands)
from repro.sim.events import simulate_demands, simulate_incidence
from repro.sim.fairshare import flow_incidence, max_min_rates

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "fairshare_golden.json")

# mirrors scripts/make_fairshare_golden.py CELLS
CELLS = {
    "array/mphx-2p-8x8/uniform":
        (lambda: MPHX(n=2, p=8, dims=(8, 8)), uniform_demands, "minimal"),
    "array/mphx-2p-8x8/neighbor_shift":
        (lambda: MPHX(n=2, p=8, dims=(8, 8)), neighbor_shift_demands,
         "minimal"),
    "array/mphx-2p-8x8/hotspot_valiant":
        (lambda: MPHX(n=2, p=8, dims=(8, 8)), hotspot_demands, "valiant"),
    "graph/dragonfly-small/uniform":
        (lambda: Dragonfly(p=2, a=4, h=2, groups=9,
                           name="Dragonfly (small)"),
         graph_uniform_demands, "minimal"),
}

# small-flow-set cells where interpreter-mode Pallas is fast enough
PALLAS_CELLS = ("array/mphx-2p-8x8/neighbor_shift",)


@pytest.fixture(scope="module")
def fixture():
    with open(GOLDEN) as f:
        return json.load(f)


def _cell_setup(name, load_key):
    topo_fn, build, mode = CELLS[name]
    topo = topo_fn()
    router = make_router(topo, backend="numpy")
    dem = build(topo, float(load_key) * topo.nic_bw_gbps)
    inc = flow_incidence(router, dem, mode)
    caps = np.asarray(dem.gbps, dtype=np.float64)
    return router, dem, inc, caps, mode


@pytest.mark.parametrize("name", sorted(CELLS))
def test_cells_match_golden(fixture, name):
    cell = fixture["cells"][name]
    for load_key, want in cell["loads"].items():
        router, dem, inc, caps, mode = _cell_setup(name, load_key)
        assert inc.n_flows == want["n_flows"]
        assert inc.n_edges == want["n_edges"]
        assert inc.nnz == want["nnz"]

        golden_rates = np.asarray(want["rates_gbps"])
        scale = max(float(caps.max()), 1.0)
        # the reference loop is untouched by the rewrite: exact pin
        ref = max_min_rates(inc, caps, backend="numpy")
        np.testing.assert_allclose(ref, golden_rates, rtol=0,
                                   atol=1e-12 * scale)
        # the jit path must be the same solver to 1e-9
        jax_rates = max_min_rates(inc, caps, backend="jax")
        np.testing.assert_allclose(jax_rates, golden_rates, rtol=0,
                                   atol=1e-9 * scale)

        loads = inc.loads(jax_rates)
        golden_loads = np.zeros(inc.n_edges)
        for e, v in want["link_loads_gbps_nonzero"].items():
            golden_loads[int(e)] = v
        np.testing.assert_allclose(loads, golden_loads, rtol=0,
                                   atol=1e-9 * scale)

        # measured-FCT percentiles through the full event loop
        row = simulate_demands(router, dem, fixture["flow_time_s"],
                               mode=mode, backend="jax", inc=inc)
        for k, v in want["fct"].items():
            got = row[k]
            if isinstance(v, float) and v != 0:
                assert abs(got - v) <= 1e-9 * abs(v) + 1e-12, (k, got, v)
            else:
                assert got == v, (k, got, v)


@pytest.mark.parametrize("name", PALLAS_CELLS)
def test_pallas_cells_match_golden(fixture, name):
    cell = fixture["cells"][name]
    for load_key, want in cell["loads"].items():
        _, _, inc, caps, _ = _cell_setup(name, load_key)
        scale = max(float(caps.max()), 1.0)
        rates = max_min_rates(inc, caps, backend="pallas")
        np.testing.assert_allclose(rates, np.asarray(want["rates_gbps"]),
                                   rtol=0, atol=1e-9 * scale)


def _staggered_setup(fixture):
    rec = fixture["staggered"]
    topo = MPHX(n=2, p=8, dims=(8, 8))
    router = make_router(topo, backend="numpy")
    dem = neighbor_shift_demands(topo, 800.0)
    inc = flow_incidence(router, dem, "minimal")
    return rec, inc, (np.asarray(rec["size_bytes"]),
                      np.asarray(rec["rate_caps_gbps"]),
                      np.asarray(rec["start_s"]))


@pytest.mark.parametrize("backend", ["numpy", "jax", "pallas"])
def test_staggered_trace_matches_golden(fixture, backend):
    rec, inc, (size, caps, start) = _staggered_setup(fixture)
    res = simulate_incidence(inc, size, caps, start_s=start,
                             backend=backend)
    tight = 1e-12 if backend == "numpy" else 1e-9
    makespan = rec["makespan_s"]

    np.testing.assert_allclose(res.finish_s, np.asarray(rec["finish_s"]),
                               rtol=0, atol=tight * makespan)
    np.testing.assert_allclose(res.fct_s, np.asarray(rec["fct_s"]),
                               rtol=0, atol=tight * makespan)
    assert abs(res.makespan_s - makespan) <= tight * makespan
    # exact epoch count: the jit loop replicates the reference's event
    # semantics (arrival batching, dead-flow stalling), not just totals
    assert res.n_epochs == rec["n_epochs"]

    golden_bytes = np.zeros(inc.n_edges)
    for e, v in rec["edge_bytes_nonzero"].items():
        golden_bytes[int(e)] = v
    np.testing.assert_allclose(res.edge_bytes, golden_bytes,
                               rtol=tight, atol=tight * size.sum())
