"""Routing (DAL, §5.2) and flow-level simulator invariants."""

import math

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep — deterministic fallback shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import MPHX, SprayConfig, split_chunks, spray_completion_time
from repro.core.netsim import (
    DEFAULT_NET,
    allreduce_time,
    alltoall_time,
    hd_allreduce_time,
    hierarchical_allreduce_time,
    ring_allreduce_time,
    uniform_throughput_fraction,
    zero_load_latency,
)
from repro.core.planes import plane_failure_degradation, spray_efficiency
from repro.core.routing import (
    HyperXRouter,
    bit_complement_traffic,
    minimal_vs_adaptive_report,
    neighbor_shift_traffic,
    uniform_traffic,
)


@pytest.fixture(scope="module")
def small():
    return MPHX(n=2, p=8, dims=(8, 8))


# ------------------------------------------------------------------ routing


def test_minimal_paths_are_minimal(small):
    r = HyperXRouter(small)
    for src, dst in [(0, 63), (5, 40), (0, 7)]:
        paths = r.minimal_paths(src, dst)
        mism = len(r.mismatched_dims(src, dst))
        for p in paths:
            assert len(p) == mism + 1
            assert p[0] == src and p[-1] == dst
            for u, v in zip(p, p[1:]):
                assert r.graph.multiplicity(u, v) > 0, "hop must be a link"


def test_deroute_paths_valid(small):
    r = HyperXRouter(small)
    for p in r.deroute_paths(0, 63):
        assert p[0] == 0 and p[-1] == 63
        for u, v in zip(p, p[1:]):
            assert r.graph.multiplicity(u, v) > 0
        # DAL: at most one deroute -> <= mismatched+1 switch hops
        assert len(p) - 1 <= len(r.mismatched_dims(0, 63)) + 1


def test_load_conservation(small):
    """Total link load == sum over demands of (gbps * path_length)."""
    r = HyperXRouter(small)
    demands = neighbor_shift_traffic(small, 100.0)
    ll = r.route(demands, mode="minimal")
    total = sum(ll.loads.values())
    expect = sum(demands.values())  # all paths are 1 switch-hop
    assert total == pytest.approx(expect, rel=1e-9)


def test_section52_minimal_is_thin(small):
    """§5.2: minimal paths between adjacent switches are bandwidth-thin;
    adaptive (non-minimal) recovers >= 3x throughput on this instance."""
    rep = minimal_vs_adaptive_report(small, offered_per_nic_gbps=1600.0)
    assert rep["minimal"]["max_util"] == pytest.approx(
        rep["analytic_minimal_max_util"], rel=1e-6)
    assert rep["adaptive"]["throughput_fraction"] >= \
        3.0 * rep["minimal"]["throughput_fraction"]
    assert rep["valiant"]["throughput_fraction"] > \
        rep["minimal"]["throughput_fraction"]


def test_uniform_traffic_is_feasible(small):
    r = HyperXRouter(small)
    ll = r.route(uniform_traffic(small, 1600.0), mode="minimal")
    # uniform traffic at full injection should be near-sustainable on HyperX
    assert ll.max_utilization() < 1.6


def test_bit_complement_adaptive_beats_minimal(small):
    r = HyperXRouter(small)
    d = bit_complement_traffic(small, 1600.0)
    mn = r.route(d, mode="minimal").max_utilization()
    ad = r.route(d, mode="adaptive").max_utilization()
    assert ad <= mn + 1e-9


# ------------------------------------------------------------------- netsim


def test_latency_ordering_matches_diameter():
    """§1: MPHX(8,256,256) has the lowest zero-load latency (diameter 3)."""
    from repro.core import table2_topologies

    topos = table2_topologies()
    lat = {t.name: zero_load_latency(t) for t in topos}
    assert min(lat, key=lat.get) == "8-Plane 1D HyperX"


def test_allreduce_estimates_positive(small):
    for fn in (ring_allreduce_time, hd_allreduce_time,
               hierarchical_allreduce_time):
        est = fn(small, 2**20)
        assert est.latency_s > 0 and est.bandwidth_s > 0
    best = allreduce_time(small, 2**20)
    assert best.total_s <= hd_allreduce_time(small, 2**20).total_s


@given(mb=st.floats(0.25, 1024))
@settings(max_examples=20, deadline=None)
def test_allreduce_bandwidth_term_scales_linearly(mb):
    t = MPHX(n=8, p=256, dims=(256,))
    a = hierarchical_allreduce_time(t, mb * 2**20)
    b = hierarchical_allreduce_time(t, 2 * mb * 2**20)
    assert b.bandwidth_s == pytest.approx(2 * a.bandwidth_s, rel=1e-6)
    assert b.latency_s == pytest.approx(a.latency_s, rel=1e-6)


def test_uniform_throughput_full_bisection_networks():
    from repro.core import table2_topologies

    for t in table2_topologies():
        f = uniform_throughput_fraction(t)
        assert 0.5 <= f <= 1.0, t.name


# ------------------------------------------------------------------- planes


@given(total=st.integers(1, 1 << 28), n=st.integers(1, 8))
@settings(max_examples=60, deadline=None)
def test_spray_chunks_conserve_bytes(total, n):
    cfg = SprayConfig(n_planes=n)
    per = split_chunks(total, cfg)
    assert sum(per) == total
    assert max(per) - min(per) <= cfg.chunk_bytes


def test_spray_efficiency_high_for_large_flows():
    cfg = SprayConfig(n_planes=8)
    assert spray_efficiency(1 << 30, 1600.0, cfg) > 0.95
    # small flows pay chunk overhead
    assert spray_efficiency(1 << 12, 1600.0, cfg) < 0.95


def test_plane_failure_respray():
    cfg = SprayConfig(n_planes=4)
    t_ok = spray_completion_time(1 << 26, 1600.0, cfg)
    t_deg = spray_completion_time(1 << 26, 1600.0, cfg,
                                  plane_skew=[1.0, 1.0, 1.0, math.inf])
    assert t_deg > t_ok
    assert plane_failure_degradation(cfg) == pytest.approx(0.75)


def test_all_planes_down_raises():
    cfg = SprayConfig(n_planes=2)
    with pytest.raises(RuntimeError):
        spray_completion_time(1 << 20, 1600.0, cfg,
                              plane_skew=[math.inf, math.inf])
