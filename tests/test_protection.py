"""Fast-reroute protection state (repro.routing.protection).

The acceptance contract of the resilience PR:

* every undirected edge is excluded from exactly one protection layer
  (MRC round-robin coverage) and layer 0 is the full graph;
* the precomputed backup next-hop table only ever points at a neighbor
  reachable *without* the protected edge, strictly downhill in that
  edge's protection layer;
* ``local_reroute_loads`` conserves bytes (injected == delivered +
  stalled to 1e-9), never places load on a failed element, and is a
  no-op on the healthy fabric;
* FatPaths-style ``route_layered`` flowlet spraying conserves demand on
  the healthy fabric and is deterministic in the seed;
* ``recovery_curve`` produces the documented phase sequence per reroute
  mode and ``time_to_recover`` measures the first recovering phase.
"""

import numpy as np
import pytest

from repro.core.dragonfly import Dragonfly
from repro.core.hyperx import MPHX
from repro.core.routing_graph import GraphRouter, graph_uniform_demands
from repro.routing.protection import (REROUTE_MODES, ProtectedRouter,
                                      validate_reroute_mode)
from repro.sim.failures import (FailureSpec, degrade_graph,
                                parse_failure_spec, recovery_curve,
                                time_to_recover)

MPHX_SMALL = MPHX(n=2, p=8, dims=(8, 8))
DF_SMALL = Dragonfly(p=2, a=4, h=2, groups=9, name="Dragonfly (small)")


@pytest.fixture(scope="module")
def prot():
    return ProtectedRouter(MPHX_SMALL, n_layers=4)


# ------------------------------------------------------------ validation ----


def test_reroute_mode_validation():
    assert REROUTE_MODES == ("none", "local", "global")
    for m in REROUTE_MODES:
        assert validate_reroute_mode(m) == m
    with pytest.raises(ValueError):
        validate_reroute_mode("bogus")


def test_constructor_rejects_bad_params():
    with pytest.raises(ValueError):
        ProtectedRouter(MPHX_SMALL, n_layers=1)
    with pytest.raises(ValueError):
        ProtectedRouter(MPHX_SMALL, rho=0.0)
    with pytest.raises(ValueError):
        ProtectedRouter(MPHX_SMALL, rho=1.5)


def test_accepts_topology_graph_and_router():
    g = MPHX_SMALL.build_graph()
    for src in (MPHX_SMALL, g, GraphRouter(g, backend="numpy")):
        p = ProtectedRouter(src, n_layers=3)
        assert p.csr.n_edges == p.layer_mask.shape[1]


# ----------------------------------------------------------- layer masks ----


def test_layer_zero_is_full_graph(prot):
    assert prot.layer_mask[0].all()


def test_every_edge_protected_exactly_once(prot):
    """Round-robin layer assignment: each directed edge is excluded from
    its protect layer and present everywhere else (rho=1)."""
    L, E = prot.layer_mask.shape
    excluded = (~prot.layer_mask[1:]).sum(axis=0)       # per-edge count
    assert (excluded == 1).all()
    for e in range(0, E, max(1, E // 64)):              # sampled check
        pl = int(prot.protect_layer[e])
        assert 1 <= pl < L
        assert not prot.layer_mask[pl, e]


def test_both_directions_share_protect_layer(prot):
    """An undirected failure kills both directed edges — they must map
    to the same protection layer or one direction would be unprotected."""
    csr = prot.csr
    key = {}
    for e in range(csr.n_edges):
        u, v = int(csr.src[e]), int(csr.dst[e])
        k = (min(u, v), max(u, v))
        pl = int(prot.protect_layer[e])
        assert key.setdefault(k, pl) == pl


def test_layers_connected_on_mphx(prot):
    assert prot.connected_layers() == list(range(prot.n_layers))
    counts = prot.layer_edge_counts()
    assert counts[0] == prot.csr.n_edges
    assert (counts[1:] < counts[0]).all()


def test_rho_subsampling_thins_layers():
    full = ProtectedRouter(MPHX_SMALL, n_layers=4, rho=1.0)
    thin = ProtectedRouter(MPHX_SMALL, n_layers=4, rho=0.5, seed=3)
    assert thin.layer_edge_counts()[1:].sum() \
        < full.layer_edge_counts()[1:].sum()


# ------------------------------------------------------ backup next-hops ----


def test_backup_table_shape_and_coverage(prot):
    bnh = prot.backup_next_hops()
    assert bnh.shape == (prot.csr.n_edges, prot.csr.n_switches)
    assert prot.protection_coverage() == pytest.approx(1.0)


def test_backup_hop_is_downhill_and_avoids_protected_edge(prot):
    """bnh[e, d] must be a layer-adjacent neighbor of src[e], strictly
    closer to d in e's protection layer, and never dst[e] itself (every
    parallel (src,dst) edge shares the protection layer exclusion)."""
    csr = prot.csr
    bnh = prot.backup_next_hops()
    rng = np.random.default_rng(0)
    for e in rng.choice(csr.n_edges, size=32, replace=False):
        pl = int(prot.protect_layer[e])
        dist = prot.layer_hops(pl)
        s = int(csr.src[e])
        neigh = set(csr.dst[np.flatnonzero(
            (csr.src == s) & prot.layer_mask[pl])].tolist())
        for d in rng.choice(csr.n_switches, size=8, replace=False):
            h = int(bnh[e, d])
            if s == int(d):
                assert h == -1
                continue
            assert h >= 0
            assert h != int(csr.dst[e])
            assert h in neigh
            assert dist[h, d] == dist[s, d] - 1


# ------------------------------------------------------- local reroute ----


def _demands(topo, dg=None):
    return graph_uniform_demands(topo, 400.0,
                                 graph=None if dg is None else dg.graph)


@pytest.mark.parametrize("spec_text", ["link:0.05", "link:0.1,seed:2",
                                       "switch:0.03,seed:1"])
def test_local_reroute_conserves_and_avoids_dead(spec_text):
    prot = ProtectedRouter(MPHX_SMALL, n_layers=8)
    dg = degrade_graph(prot.graph, parse_failure_spec(spec_text))
    lr = prot.local_reroute_loads(_demands(MPHX_SMALL), dg)
    assert lr.conservation_residual < 1e-9
    assert lr.delivered_share + lr.stalled_share == pytest.approx(1.0)
    surv_mult, _, _ = prot._degraded_state(dg)
    assert float(np.abs(lr.loads[surv_mult <= 0]).max(initial=0.0)) == 0.0
    assert np.isfinite(lr.max_utilization())
    info = lr.info()
    assert info["conservation_residual"] < 1e-9


def test_local_reroute_noop_on_healthy_fabric():
    prot = ProtectedRouter(DF_SMALL, n_layers=4)
    dg = degrade_graph(prot.graph, FailureSpec())
    lr = prot.local_reroute_loads(_demands(DF_SMALL), dg)
    assert lr.stalled_gbps == 0.0
    assert lr.diverted_gbps == 0.0
    assert lr.delivered_share == pytest.approx(1.0)
    # healthy reroute == the plain minimal route, load for load
    ll = prot.router.route(_demands(DF_SMALL), "minimal")
    assert np.abs(lr.loads - ll.loads).max() < 1e-6


def test_local_reroute_diverts_on_full_edge_failure():
    """Killing whole undirected edges forces shares onto protection
    layers: diverted > 0 and the per-layer byte ledger reconciles."""
    prot = ProtectedRouter(MPHX_SMALL, n_layers=8)
    dg = degrade_graph(prot.graph,
                       FailureSpec(link_fraction=0.15, seed=4))
    assert dg.fully_failed_edges, "spec must fully fail some edges"
    lr = prot.local_reroute_loads(_demands(MPHX_SMALL), dg)
    assert lr.diverted_gbps > 0
    assert lr.layer_gbps[1:].sum() == pytest.approx(lr.diverted_gbps)
    assert lr.conservation_residual < 1e-9


# ---------------------------------------------------- layered multipath ----


def test_route_layered_healthy_conservation(prot):
    dem = _demands(MPHX_SMALL)
    ll = prot.route_layered(dem, seed=1)
    assert (ll.loads >= 0).all()
    # layered totals == minimal totals is NOT required (longer detours
    # add hop-bytes) but delivery is asserted inside route_layered; the
    # external pin: utilization finite and within a detour factor.
    base = prot.router.route(dem, "minimal")
    assert np.isfinite(ll.max_utilization())
    assert ll.loads.sum() >= base.loads.sum() - 1e-6


def test_route_layered_deterministic_in_seed(prot):
    dem = _demands(MPHX_SMALL)
    a = prot.route_layered(dem, seed=7).loads
    b = prot.route_layered(dem, seed=7).loads
    c = prot.route_layered(dem, seed=8).loads
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)


# ------------------------------------------------- recovery-curve modes ----


def _curve(reroute, **kw):
    build = lambda t, o, g: graph_uniform_demands(t, o, graph=g)
    spec = parse_failure_spec("link:0.05,seed:1")
    return recovery_curve(MPHX_SMALL, build, spec, 400.0, mode="minimal",
                          reroute=reroute, **kw)


def test_recovery_curve_phase_names_per_mode():
    assert [r["phase"] for r in _curve("none")] \
        == ["healthy", "failed", "rerouted"]
    assert [r["phase"] for r in _curve("local")] \
        == ["healthy", "failed", "local_reroute"]
    assert [r["phase"] for r in _curve("global")] \
        == ["healthy", "failed", "local_reroute", "reconverged"]


def test_recovery_curve_rows_tagged_and_measured():
    prot = ProtectedRouter(MPHX_SMALL, n_layers=8)
    rows = _curve("global", protection=prot)
    assert all(r["reroute"] == "global" for r in rows)
    assert all(r["phase_wall_s"] >= 0 for r in rows)
    lr = rows[2]
    assert lr["phase"] == "local_reroute"
    assert lr["conservation_residual"] < 1e-9
    assert lr["delivered_fraction"] >= rows[1]["delivered_fraction"] - 1e-9


def test_recovery_curve_rejects_unknown_mode():
    with pytest.raises(ValueError):
        _curve("fastest")


def test_time_to_recover_semantics():
    rows = [
        {"phase": "healthy", "delivered_fraction": 1.0,
         "t_offset_s": 0.0, "phase_wall_s": 0.01},
        {"phase": "failed", "delivered_fraction": 0.6,
         "t_offset_s": 0.2, "phase_wall_s": 0.05},
        {"phase": "local_reroute", "delivered_fraction": 0.95,
         "t_offset_s": 0.25, "phase_wall_s": 0.04},
    ]
    # failure at t=0.2; recovery lands at 0.25 + 0.04 = 0.29
    assert time_to_recover(rows) == pytest.approx(0.09)
    rows[2]["delivered_fraction"] = 0.85       # never re-crosses 90%
    assert time_to_recover(rows) is None
    assert time_to_recover(rows, target=0.8) == pytest.approx(0.09)
    assert time_to_recover(rows[:1]) is None   # no failed phase


# -------------------------------------------------------- suite wiring ----


def test_failures_suite_recovery_summary(tmp_path):
    from repro.experiments.simsuite import run_failures_suite

    payload = run_failures_suite(outdir=str(tmp_path),
                                 topo_names=["mphx-2p-8x8"],
                                 scenario_names=["uniform"],
                                 failure_specs=["link:0.05"],
                                 mode="minimal",
                                 reroute_modes=["none", "local"],
                                 protection_layers=8)
    assert payload["params"]["reroute_modes"] == ["none", "local"]
    assert payload["params"]["protection_layers"] == 8
    summaries = [r for r in payload["rows"]
                 if r.get("kind") == "recovery_summary"]
    assert {r["reroute"] for r in summaries} == {"none", "local"}
    local = next(r for r in summaries if r["reroute"] == "local")
    assert local["protection_coverage"] == pytest.approx(1.0)
    assert local["protection_layers"] == 8
    recs = [r for r in payload["rows"] if r.get("kind") == "recovery"]
    assert {r["reroute"] for r in recs} == {"none", "local"}
