"""Fabric flight recorder: metrics, traces, and the perf dashboard.

Pins the observability layer's core contracts:

  * the ambient registry defaults to the no-op ``NullRegistry`` and
    disabled telemetry does not move the jitted solver/event-loop
    outputs off ``tests/golden/fairshare_golden.json`` (record is a
    static jit argument — off compiles the identical graph);
  * the numpy reference loop and the jitted ``lax.while_loop`` journal
    the SAME trace (event count, ordering, epoch rows);
  * the Perfetto ``trace_event`` export round-trips and validates;
  * cosim phase spans tile the step clock — their durations sum to the
    reported communication time (1e-6 relative);
  * `incidence_calls` survives as a deprecated shim and both routing
    engines count cache hits/misses uniformly;
  * ``benchmarks/report.py --check`` passes on the committed BENCH
    history and fails on a synthetic 2x slowdown;
  * a 65K-NIC run's link series stays bounded by ``LinkSeriesPolicy``
    (slow-marked), with drops counted, never silent.
"""

import importlib.util
import json
import os
import shutil

import numpy as np
import pytest

from repro.experiments.artifacts import SCHEMA_VERSION
from repro.core.dragonfly import Dragonfly
from repro.core.hyperx import MPHX
from repro.core.netsim import make_router
from repro.core.routing_graph import graph_uniform_demands
from repro.core.routing_vec import neighbor_shift_demands, uniform_demands
from repro.sim.events import simulate_incidence
from repro.sim.fairshare import flow_incidence
from repro.telemetry import (NULL_METRICS, LinkSeriesPolicy,
                             MetricsRegistry, NullRegistry, TraceRecorder,
                             collecting, get_metrics, get_recorder,
                             recording, validate_trace)

REPO = os.path.join(os.path.dirname(__file__), "..")
GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "fairshare_golden.json")


def _load_report_module():
    path = os.path.join(REPO, "benchmarks", "report.py")
    spec = importlib.util.spec_from_file_location("bench_report", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ------------------------------------------------------------ registry ----


def test_registry_counters_gauges_timers():
    mx = MetricsRegistry()
    assert mx.enabled is True
    mx.inc("a")
    mx.inc("a", 2)
    assert mx.value("a") == 3
    assert mx.value("never") == 0
    mx.set_counter("a", 7)
    assert mx.value("a") == 7
    mx.gauge("g", "jax")
    mx.observe("t", 0.25)
    with mx.timer("t"):
        pass
    snap = mx.snapshot()
    assert snap["counters"]["a"] == 7
    assert snap["gauges"]["g"] == "jax"
    assert snap["timers"]["t"]["count"] == 2
    assert snap["timers"]["t"]["total_s"] >= 0.25
    json.dumps(snap)                      # JSON-ready


def test_registry_merge():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.inc("x", 2)
    b.inc("x", 3)
    b.observe("w", 0.5)
    a.merge(b, prefix="sub.")
    assert a.value("x") == 2
    assert a.value("sub.x") == 3
    assert a.snapshot()["timers"]["sub.w"]["count"] == 1


def test_null_registry_is_noop_and_ambient_default():
    assert get_metrics() is NULL_METRICS
    assert isinstance(NULL_METRICS, NullRegistry)
    assert NULL_METRICS.enabled is False
    NULL_METRICS.inc("x", 5)
    NULL_METRICS.gauge("g", 1)
    NULL_METRICS.observe("t", 1.0)
    with NULL_METRICS.timer("t"):
        pass
    assert NULL_METRICS.value("x") == 0
    assert NULL_METRICS.snapshot() == {"counters": {}, "gauges": {},
                                       "timers": {}}


def test_collecting_swaps_ambient_and_restores():
    assert get_metrics() is NULL_METRICS
    with collecting() as outer:
        assert get_metrics() is outer
        inner = MetricsRegistry()
        with collecting(inner):
            assert get_metrics() is inner
            get_metrics().inc("seen")
        assert get_metrics() is outer
        assert inner.value("seen") == 1
    assert get_metrics() is NULL_METRICS
    assert get_recorder() is None


# ------------------------------------------- routing-engine counters ----


def _engines():
    mphx = MPHX(n=2, p=8, dims=(8, 8))
    dfly = Dragonfly(p=2, a=4, h=2, groups=9, name="Dragonfly (small)")
    return {
        "array": (make_router(mphx, backend="numpy"),
                  uniform_demands(mphx, 400.0)),
        "graph": (make_router(dfly, backend="numpy"),
                  graph_uniform_demands(dfly, 400.0)),
    }


def test_incidence_calls_shim_reads_metrics():
    for name, (router, dem) in _engines().items():
        assert router.incidence_calls == 0, name
        router.incidence(dem, "minimal")
        assert router.incidence_calls == 1, name
        assert router.metrics.value("incidence.walks") == 1, name


def test_incidence_calls_setter_warns_deprecation():
    router, _ = _engines()["array"]
    with pytest.warns(DeprecationWarning):
        router.incidence_calls = 0
    assert router.incidence_calls == 0


def test_cache_hit_miss_uniform_on_both_engines():
    for name, (router, dem) in _engines().items():
        with collecting() as mx:
            router.incidence_cached(dem, "minimal")
            misses = mx.value("incidence.cache_misses")
            assert misses > 0, name
            assert mx.value("incidence.cache_hits") == 0, name
            router.incidence_cached(dem, "minimal")
            assert mx.value("incidence.cache_hits") == misses, name
            assert mx.value("incidence.cache_misses") == misses, name
        # the router's own registry mirrors the ambient counts
        assert router.metrics.value("incidence.cache_hits") == misses, name


def test_solver_and_sim_counters_flow():
    router, build = _engines()["array"]
    dem = neighbor_shift_demands(router.topo, 800.0)
    inc = flow_incidence(router, dem, "minimal")
    caps = np.asarray(dem.gbps, dtype=np.float64)
    with collecting() as mx:
        simulate_incidence(inc, np.full(inc.n_flows, 1 << 20), caps,
                           backend="numpy")
    snap = mx.snapshot()
    assert snap["counters"]["sim.runs"] == 1
    assert snap["counters"]["sim.flows"] == inc.n_flows
    assert snap["counters"]["sim.epochs"] >= 1
    assert snap["counters"]["waterfill.solves"] >= 1
    assert snap["counters"]["waterfill.rounds"] >= \
        snap["counters"]["waterfill.solves"]
    assert snap["timers"]["sim.wall_s"]["count"] == 1


# ------------------------------------------------- trace determinism ----


def _staggered_case():
    topo = MPHX(n=2, p=8, dims=(8, 8))
    router = make_router(topo, backend="numpy")
    dem = neighbor_shift_demands(topo, 800.0)
    inc = flow_incidence(router, dem, "minimal")
    caps = np.asarray(dem.gbps, dtype=np.float64)
    rng = np.random.default_rng(11)
    size = rng.uniform(0.2, 1.0, inc.n_flows) * (1 << 22)
    start = rng.uniform(0.0, 200e-6, inc.n_flows)
    return inc, size, caps, start


def _traced_run(backend):
    inc, size, caps, start = _staggered_case()
    rec = TraceRecorder()
    with recording(rec):
        res = simulate_incidence(inc, size, caps, start_s=start,
                                 backend=backend)
    return rec, res


def test_numpy_and_jax_journal_the_same_trace():
    pytest.importorskip("jax")
    rec_np, res_np = _traced_run("numpy")
    rec_jx, res_jx = _traced_run("jax")
    assert res_np.n_epochs == res_jx.n_epochs
    # same events in the same order — the jit loop replays the reference
    # loop's journaling semantics, not just its totals
    assert [(e["ph"], e["name"]) for e in rec_np.events] == \
        [(e["ph"], e["name"]) for e in rec_jx.events]
    jn, jj = rec_np.journals[0], rec_jx.journals[0]
    assert jn["edge_ids"] == jj["edge_ids"]
    assert jn["active_flows"] == jj["active_flows"]
    assert jn["dropped_epochs"] == jj["dropped_epochs"] == 0
    scale = max(res_np.makespan_s, 1e-30)
    np.testing.assert_allclose(jn["t_s"], jj["t_s"], rtol=0,
                               atol=1e-9 * scale)
    np.testing.assert_allclose(jn["dt_s"], jj["dt_s"], rtol=0,
                               atol=1e-9 * scale)
    np.testing.assert_allclose(jn["util"], jj["util"], rtol=0, atol=1e-9)


def test_epoch_journal_rows_match_epoch_count():
    rec, res = _traced_run("numpy")
    j = rec.journals[0]
    assert len(j["t_s"]) == res.n_epochs
    assert len(j["util"]) == res.n_epochs
    k = len(j["edge_ids"])
    pol = LinkSeriesPolicy()
    assert 0 < k <= pol.top_k + pol.reservoir
    assert all(len(row) == k for row in j["util"])


def test_link_policy_selection_is_deterministic_and_bounded():
    inc, size, caps, start = _staggered_case()
    pol = LinkSeriesPolicy(top_k=4, reservoir=2, seed=3)
    a = pol.select(inc, caps)
    b = pol.select(inc, caps)
    assert np.array_equal(a, b)
    assert a.size <= 6
    assert np.array_equal(a, np.sort(a))
    load = inc.loads(np.broadcast_to(caps, (inc.n_flows,)))
    assert (load[a] > 0).all()            # only used edges qualify


# -------------------------------------------------- golden pinning ----


def test_disabled_telemetry_pins_jit_outputs_to_golden():
    pytest.importorskip("jax")
    with open(GOLDEN) as f:
        rec = json.load(f)["staggered"]
    topo = MPHX(n=2, p=8, dims=(8, 8))
    router = make_router(topo, backend="numpy")
    dem = neighbor_shift_demands(topo, 800.0)
    inc = flow_incidence(router, dem, "minimal")
    size = np.asarray(rec["size_bytes"])
    caps = np.asarray(rec["rate_caps_gbps"])
    start = np.asarray(rec["start_s"])
    assert get_metrics() is NULL_METRICS   # telemetry is OFF
    res = simulate_incidence(inc, size, caps, start_s=start,
                             backend="jax")
    tol = 1e-9 * rec["makespan_s"]
    np.testing.assert_allclose(res.finish_s, np.asarray(rec["finish_s"]),
                               rtol=0, atol=tol)
    assert res.n_epochs == rec["n_epochs"]
    # and recording must not move the outputs either (the journal is
    # numerically inert — it never feeds back into the solver state)
    with recording():
        res2 = simulate_incidence(inc, size, caps, start_s=start,
                                  backend="jax")
    np.testing.assert_allclose(res2.finish_s, res.finish_s, rtol=0,
                               atol=1e-12 * rec["makespan_s"])
    assert res2.n_epochs == res.n_epochs


# -------------------------------------------------- perfetto export ----


def test_perfetto_round_trip(tmp_path):
    rec = TraceRecorder()
    rec.span("phase_a", 0.0, 1e-3, process="cosim:t", thread="step",
             args={"kind": "allreduce"})
    rec.span("plane busy", 0.0, 5e-4, process="cosim:t", thread="plane 0")
    rec.instant("failure", 2e-3, process="failures")
    rec.counter("active_flows", 0.0, {"epochs": 4})
    rec.note_skip("table2", "analytic only")
    rec.metrics.inc("sim.runs")
    path = tmp_path / "trace.json"
    rec.export(str(path))
    payload = json.loads(path.read_text())
    assert validate_trace(payload) == []
    assert payload["displayTimeUnit"] == "ms"
    evs = payload["traceEvents"]
    # metadata tracks precede the data events and name every track
    metas = [e for e in evs if e["ph"] == "M"]
    assert {m["name"] for m in metas} == {"process_name", "thread_name"}
    span = next(e for e in evs if e["ph"] == "X" and
                e["name"] == "phase_a")
    assert span["ts"] == 0.0 and span["dur"] == pytest.approx(1e3)
    other = payload["otherData"]
    assert other["skipped"] == [{"name": "table2", "traced": False,
                                 "reason": "analytic only"}]
    assert other["metrics"]["counters"]["sim.runs"] == 1


def test_validate_trace_flags_malformed_events():
    bad = {"traceEvents": [{"ph": "X", "name": "x", "ts": -1.0},
                           {"ph": "?"}, "nope"]}
    problems = validate_trace(bad)
    assert any("missing" in p for p in problems)
    assert any("unknown ph" in p for p in problems)
    assert any("not an object" in p for p in problems)
    assert validate_trace({}) == ["traceEvents missing or not a list"]


# -------------------------------------------------- cosim span sums ----


def test_cosim_phase_spans_sum_to_comm_time():
    from repro.cosim import CollectivePhase, TrainJob
    from repro.cosim.stepsim import simulate_step

    topo = MPHX(n=2, p=8, dims=(8, 8))
    phases = (
        CollectivePhase("tp_ag", "allgather", 4, 1, 1 << 22, calls=4),
        CollectivePhase("ep_a2a", "alltoall", 4, 4, 1 << 22, calls=2),
        CollectivePhase("dp_ar", "allreduce", 8, 4, 1 << 26),
    )
    job = TrainJob("toy", 32, {"dp": 8, "tp": 4, "ep": 4},
                   tokens_per_step=4096, active_params=int(1e9),
                   phases=phases)
    rec = TraceRecorder()
    with recording(rec):
        res = simulate_step(topo, job)
    spans = [e for e in rec.events
             if e["ph"] == "X" and e.get("cat") == "phase"]
    assert len(spans) == len(phases)
    total_s = sum(e["dur"] for e in spans) / 1e6
    assert abs(total_s - res.comm_s) <= 1e-6 * res.comm_s
    # the spans tile the step clock back to back
    spans.sort(key=lambda e: e["ts"])
    assert spans[0]["ts"] == 0.0
    for prev, nxt in zip(spans, spans[1:]):
        assert nxt["ts"] == pytest.approx(prev["ts"] + prev["dur"],
                                          rel=1e-9)
    # per-plane busy windows ride their own tracks under the phase
    assert any(e.get("cat") == "plane" for e in rec.events)
    assert rec.metrics.value("cosim.phases") == len(phases)
    assert validate_trace(rec.to_json()) == []


# --------------------------------------------- failures phase spans ----


def test_recovery_curve_emits_phase_walls_and_spans():
    from repro.experiments.scenarios import SCENARIOS
    from repro.sim.failures import parse_failure_spec, recovery_curve

    topo = MPHX(n=2, p=8, dims=(8, 8))
    spec = parse_failure_spec("link:0.05")
    rec = TraceRecorder()
    with recording(rec):
        rows = recovery_curve(topo, SCENARIOS["uniform"].build, spec,
                              0.5 * topo.nic_bw_gbps)
    assert [r["phase"] for r in rows] == ["healthy", "failed", "rerouted"]
    offset = 0.0
    for r in rows:
        assert r["phase_wall_s"] >= 0.0
        # both columns are rounded to 6dp independently, so the
        # re-accumulated offset can drift a few ulps of the rounding
        assert r["t_offset_s"] == pytest.approx(offset, abs=5e-6)
        offset += r["phase_wall_s"]
    spans = [e for e in rec.events
             if e["ph"] == "X" and e.get("cat") == "recovery"]
    assert len(spans) == 3
    assert rec.metrics.value("failures.reroute_recomputes") >= 1
    assert rec.metrics.snapshot()["timers"][
        "failures.reroute_wall_s"]["count"] == 1


# --------------------------------------------------- CLI --trace ----


def test_experiments_cli_trace_records_skips(tmp_path):
    from repro.experiments.run import main

    out = str(tmp_path / "arts")
    trace = str(tmp_path / "trace.json")
    rc = main(["--suite", "table2", "--out", out, "--trace", trace])
    assert rc == 0
    payload = json.loads(open(trace).read())
    assert validate_trace(payload) == []
    skips = {n["name"]: n for n in payload["otherData"]["skipped"]}
    assert skips["table2"]["traced"] is False
    # analytic-only suite: explicit skip, not silence


def test_experiments_cli_trace_cosim_has_spans(tmp_path):
    from repro.experiments.run import main

    out = str(tmp_path / "arts")
    trace = str(tmp_path / "trace.json")
    rc = main(["--suite", "cosim", "--config", "mixtral_8x22b",
               "--ranks", "16", "--topos", "mphx-2p-8x8",
               "--out", out, "--trace", trace])
    assert rc == 0
    payload = json.loads(open(trace).read())
    assert validate_trace(payload) == []
    assert any(e.get("cat") == "phase"
               for e in payload["traceEvents"])
    # the artifacts written inside the recording scope carry the v5
    # telemetry block
    disk = json.loads(open(os.path.join(out, "cosim.json")).read())
    assert disk["schema_version"] == SCHEMA_VERSION
    assert disk["telemetry"]["counters"]["cosim.phases"] > 0


def test_bench_cli_trace_records_skips(tmp_path, capsys):
    import sys
    sys.path.insert(0, os.path.join(REPO, "benchmarks"))
    try:
        import run as bench_run
    finally:
        sys.path.pop(0)
    trace = str(tmp_path / "bench_trace.json")
    rc = bench_run.main(["flattening", "--trace", trace])
    assert rc == 0
    payload = json.loads(open(trace).read())
    assert validate_trace(payload) == []
    names = [n["name"] for n in payload["otherData"]["skipped"]]
    assert "bench:flattening" in names


def test_artifact_payload_telemetry_block():
    from repro.experiments.artifacts import SCHEMA_VERSION, artifact_payload

    off = artifact_payload("table2", {}, [])
    assert "telemetry" not in off
    with collecting() as mx:
        mx.inc("incidence.walks", 3)
        on = artifact_payload("table2", {}, [])
    assert on["telemetry"]["counters"]["incidence.walks"] == 3


# ----------------------------------------------- report dashboard ----


def test_report_check_passes_on_committed_history():
    report = _load_report_module()
    rc = report.main(["--check", "--results-dir",
                      os.path.join(REPO, "results")])
    assert rc == 0


def test_report_check_fails_on_synthetic_slowdown(tmp_path):
    report = _load_report_module()
    results = os.path.join(REPO, "results")
    for f in os.listdir(results):
        if f.startswith("BENCH_") and f.endswith(".json") \
                and f != "BENCH_report.json":
            shutil.copy(os.path.join(results, f), tmp_path / f)
    p = tmp_path / "BENCH_vectorized_routing.json"
    d = json.loads(p.read_text())
    d["scale"]["vectorized_s"] *= 2.0
    p.write_text(json.dumps(d))
    rc = report.main(["--check", "--results-dir", str(tmp_path),
                      "--baseline",
                      os.path.join(results, "BENCH_report.json")])
    assert rc == 1


def test_report_check_fails_on_false_flag(tmp_path):
    report = _load_report_module()
    results = os.path.join(REPO, "results")
    shutil.copy(os.path.join(results, "BENCH_vectorized_routing.json"),
                tmp_path / "BENCH_vectorized_routing.json")
    p = tmp_path / "BENCH_vectorized_routing.json"
    d = json.loads(p.read_text())
    d["scale"]["meets_target"] = False
    p.write_text(json.dumps(d))
    rc = report.main(["--check", "--results-dir", str(tmp_path),
                      "--baseline",
                      os.path.join(results, "BENCH_report.json")])
    assert rc == 1


def test_report_write_mode_builds_history_and_removes_stale_csv(tmp_path):
    report = _load_report_module()
    results = os.path.join(REPO, "results")
    shutil.copy(os.path.join(results, "BENCH_vectorized_routing.json"),
                tmp_path / "BENCH_vectorized_routing.json")
    (tmp_path / "bench_results.csv").write_text("stale\n")
    for label in ("one", "two"):
        rc = report.main(["--results-dir", str(tmp_path),
                          "--label", label])
        assert rc == 0
    assert not (tmp_path / "bench_results.csv").exists()
    hist = json.loads((tmp_path / "BENCH_report.json").read_text())
    assert [s["label"] for s in hist["snapshots"]] == ["one", "two"]
    md = (tmp_path / "BENCH_report.md").read_text()
    assert "vectorized_routing.scale.speedup" in md
    # and the freshly written history passes its own gate
    assert report.main(["--check", "--results-dir", str(tmp_path),
                        "--baseline",
                        str(tmp_path / "BENCH_report.json")]) == 0


# --------------------------------------------- 65K bounded series ----


@pytest.mark.slow
def test_65k_link_series_stays_bounded():
    pytest.importorskip("jax")
    from repro.experiments.sweep import SWEEP_TOPOLOGIES

    topo = SWEEP_TOPOLOGIES["mphx-8p-256"]
    assert topo.n_nics == 65536
    router = make_router(topo, backend="numpy")
    dem = neighbor_shift_demands(topo, 0.9 * topo.nic_bw_gbps)
    inc = flow_incidence(router, dem, "minimal")
    caps = np.asarray(dem.gbps)
    rng = np.random.default_rng(7)
    size = rng.uniform(0.2, 1.0, inc.n_flows) * (1 << 24)
    start = rng.uniform(0.0, 200e-6, inc.n_flows)
    pol = LinkSeriesPolicy(top_k=8, reservoir=4, max_epochs=64)
    rec = TraceRecorder(link_policy=pol, max_flow_events=32)
    with recording(rec):
        res = simulate_incidence(inc, size, caps, start_s=start,
                                 backend="jax")
    assert res.n_epochs > pol.max_epochs   # the cap actually bit
    j = rec.journals[0]
    assert len(j["t_s"]) == pol.max_epochs
    assert len(j["edge_ids"]) <= pol.top_k + pol.reservoir
    assert j["dropped_epochs"] == res.n_epochs - pol.max_epochs
    assert rec.metrics.value("trace.dropped_epochs") == \
        j["dropped_epochs"]
    assert rec.metrics.value("trace.dropped_flow_events") == \
        inc.n_flows - 32
    assert validate_trace(rec.to_json()) == []
