"""Logical-mesh -> MPHX placement (core/mapping.py)."""

import pytest

from repro.core.hyperx import MPHX, table2_mphx_rows
from repro.core.mapping import (AxisTraffic, axis_time_on_level, best_mapping,
                                mphx_levels, traffic_from_model)


@pytest.fixture
def mphx8():
    return MPHX(n=8, p=256, dims=(256,))


def test_levels(mphx8):
    lv = mphx_levels(mphx8)
    assert lv[0].kind == "switch" and lv[0].size == 256
    assert lv[1].kind == "dim" and lv[1].size == 256
    t = MPHX(n=4, p=86, dims=(86, 9), links_per_dim=(85, 85))
    lv = mphx_levels(t)
    assert [l.size for l in lv] == [86, 86, 9]
    assert lv[2].rel_bandwidth == pytest.approx(85 / 8)  # trunked dim


def test_best_mapping_prefers_switch_level_for_heavy_axis(mphx8):
    """The bandwidth-heavy TP axis lands on the p-way switch level (2 hops,
    full port bandwidth), the light pod axis on the sparse dimension."""
    axes = [
        AxisTraffic("model", 16, allgather_bytes=200e9, calls=400),
        AxisTraffic("data", 16, allreduce_bytes=20e9, calls=2),
    ]
    m = best_mapping(mphx8, axes)
    model_levels = dict(m.assignment)["model"]
    assert model_levels[0][0] == 0, "heavy axis should use switch level"
    assert m.time_s > 0
    assert m.detail["model"] >= m.detail["data"] * 0  # both scored


def test_mapping_capacity_respected(mphx8):
    # total logical size exceeds p*dims -> must raise
    axes = [AxisTraffic("model", 300, allgather_bytes=1e9),
            AxisTraffic("data", 300, allreduce_bytes=1e9)]
    with pytest.raises(ValueError):
        best_mapping(mphx8, axes)


def test_mapping_512_chips_on_table2_rows():
    """The production 2x16x16 job maps onto every Table-2 MPHX fabric."""
    axes = traffic_from_model(
        param_bytes=18e9, act_bytes_per_layer=70e6, n_layers=48,
        ep_bytes=0.0, mesh_shape={"pod": 2, "data": 16, "model": 16})
    for t in table2_mphx_rows():
        m = best_mapping(t, axes)
        placed = {name for name, _ in m.assignment.items()}
        assert placed == {"pod", "data", "model"}
        assert m.time_s > 0


def test_ep_alltoall_prefers_full_mesh_dim(mphx8):
    """A2A-heavy EP axis maps better onto the HyperX full-mesh dimension
    than onto a tree topology would suggest — the paper's §5.1 point that
    full-mesh dims serve all-to-all at full injection."""
    ax = AxisTraffic("ep", 16, alltoall_bytes=1e9, calls=60)
    lv = mphx_levels(mphx8)
    t_switch = axis_time_on_level(ax, lv[0], mphx8)
    t_dim = axis_time_on_level(ax, lv[1], mphx8)
    # both are fast; the dim level must be within 2x of the switch level
    assert t_dim < 2 * t_switch


def test_traffic_from_model_axes():
    axes = traffic_from_model(1e9, 1e6, 10, 5e8,
                              {"pod": 2, "data": 16, "model": 16})
    names = [a.name for a in axes]
    assert names == ["model", "data", "pod"]
    model = axes[0]
    assert model.alltoall_bytes == 5e8
    assert axes[2].allreduce_bytes == 1e9
