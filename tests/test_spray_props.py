"""Property-based tests for plane spraying byte accounting.

Invariants under random payloads, plane counts and chunk sizes: the
whole-chunk round-robin split conserves bytes and stays balanced within
one chunk, the vectorized simulator split matches the scalar reference,
sprayed-collective chunk counts follow ``plane_chunk_count``'s contract,
and dead-plane re-spray conserves bytes while never assigning work to a
dead plane.
"""

import math

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep — deterministic fallback shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.collectives import plane_chunk_count
from repro.core.hyperx import MPHX
from repro.core.planes import SprayConfig, split_chunks
from repro.sim.events import FlowSpec
from repro.sim.spray import _per_plane_bytes, simulate_sprayed

planes_st = st.integers(1, 8)
chunk_st = st.sampled_from([1, 7, 1 << 10, 1 << 17, 1 << 20])
bytes_st = st.integers(0, 1 << 24)


def _bounded(total: int, chunk: int) -> int:
    """Cap the chunk count per example: the scalar ``split_chunks``
    reference loops once per chunk, so tiny chunks on 16MB payloads
    would grind (the invariant doesn't need millions of chunks)."""
    return total % (chunk * 512 + 1)


@given(total=bytes_st, n=planes_st, chunk=chunk_st)
@settings(max_examples=60, deadline=None)
def test_split_chunks_conserves_bytes(total, n, chunk):
    total = _bounded(total, chunk)
    cfg = SprayConfig(n_planes=n, chunk_bytes=chunk)
    per = split_chunks(total, cfg)
    assert len(per) == n
    assert sum(per) == total
    assert all(b >= 0 for b in per)


@given(total=bytes_st, n=planes_st, chunk=chunk_st)
@settings(max_examples=60, deadline=None)
def test_split_chunks_balanced_within_one_chunk(total, n, chunk):
    total = _bounded(total, chunk)
    cfg = SprayConfig(n_planes=n, chunk_bytes=chunk)
    per = split_chunks(total, cfg)
    assert max(per) - min(per) <= chunk


@given(total=bytes_st, n=planes_st, chunk=chunk_st)
@settings(max_examples=60, deadline=None)
def test_vectorized_split_matches_scalar_reference(total, n, chunk):
    """``repro.sim.spray._per_plane_bytes`` is the vectorized
    ``planes.split_chunks`` — they must agree byte-for-byte."""
    total = _bounded(total, chunk)
    cfg = SprayConfig(n_planes=n, chunk_bytes=chunk)
    vec = _per_plane_bytes(np.array([float(total)]), cfg)[0]
    assert vec.tolist() == pytest.approx(split_chunks(total, cfg))


@given(size=st.integers(1, 4096), n=planes_st)
@settings(max_examples=80, deadline=None)
def test_plane_chunk_count_contract(size, n):
    """Largest even divisor <= n_planes, else no split — and an exact
    ``size % count == 0`` guarantee either way."""
    c = plane_chunk_count(size, n)
    assert 1 <= c <= min(n, size)
    assert size % c == 0
    if size % min(n, size) == 0:
        assert c == min(n, size)
    else:
        assert c == 1
    # a c-way split of `size` elements is perfectly even: the sprayed
    # collective's per-plane chunks all carry size/c elements
    assert len({size // c}) == 1


@given(total=st.integers(1, 1 << 22), dead=st.integers(0, 3),
       chunk=st.sampled_from([1 << 10, 1 << 17]))
@settings(max_examples=15, deadline=None)
def test_dead_plane_respray_conserves_bytes(total, dead, chunk):
    topo = MPHX(n=4, p=2, dims=(4,))
    cfg = SprayConfig(n_planes=4, chunk_bytes=chunk,
                      per_chunk_overhead_s=0.0)
    skew = [1.0] * 4
    skew[dead] = math.inf
    flows = [FlowSpec(0, 1, total), FlowSpec(2, 3, total // 2)]
    res = simulate_sprayed(topo, flows, cfg=cfg, plane_skew=skew)
    # re-spray conserves every flow's bytes...
    assert res.per_plane_bytes.sum(axis=1) == pytest.approx(
        [total, total // 2])
    # ...and the dead plane carries none of them and no transfer time
    assert res.per_plane_bytes[:, dead].tolist() == [0.0, 0.0]
    assert res.plane_transfer_s[:, dead].tolist() == [0.0, 0.0]
    assert not res.stalled.any()


@given(total=st.integers(1 << 16, 1 << 24))
@settings(max_examples=10, deadline=None)
def test_dead_plane_never_beats_healthy_fabric(total):
    topo = MPHX(n=4, p=2, dims=(4,))
    cfg = SprayConfig(n_planes=4, per_chunk_overhead_s=0.0)
    flows = [FlowSpec(0, 1, total)]
    healthy = simulate_sprayed(topo, flows, cfg=cfg)
    degraded = simulate_sprayed(topo, flows, cfg=cfg,
                                plane_skew=[1.0, 1.0, 1.0, math.inf])
    assert degraded.makespan_s >= healthy.makespan_s


# -------------------------------------------------- flowlet switching ----

from repro.sim.spray import flowlet_split  # noqa: E402

fl_sizes_st = st.lists(st.integers(0, 1 << 21), min_size=1, max_size=32)
fl_bytes_st = st.sampled_from([1, 4096, 1 << 17])


@given(sizes=fl_sizes_st, n=planes_st, fl=fl_bytes_st,
       seed=st.integers(0, 3))
@settings(max_examples=40, deadline=None)
def test_flowlet_split_conserves_bytes_and_counts(sizes, n, fl, seed):
    sizes = np.array(sizes, dtype=np.float64)
    by, cnt = flowlet_split(sizes, n, fl, seed=seed)
    assert by.shape == cnt.shape == (sizes.shape[0], n)
    assert by.sum(axis=1) == pytest.approx(sizes)
    assert (cnt.sum(axis=1) == np.ceil(sizes / fl)).all()
    assert (by >= 0).all() and (cnt >= 0).all()


@given(sizes=fl_sizes_st, n=st.integers(2, 8), seed=st.integers(0, 3),
       dead=st.integers(0, 7))
@settings(max_examples=40, deadline=None)
def test_flowlet_dead_bucket_rehash_is_local(sizes, n, seed, dead):
    """Killing one bucket only moves the flowlets that were ON it: every
    surviving bucket's assignment is a superset of its healthy one."""
    dead = dead % n
    sizes = np.array(sizes, dtype=np.float64)
    alive = np.ones(n, dtype=bool)
    alive[dead] = False
    healthy_b, healthy_c = flowlet_split(sizes, n, 4096, seed=seed)
    degr_b, degr_c = flowlet_split(sizes, n, 4096, seed=seed, alive=alive)
    assert degr_b[:, dead].sum() == 0 and degr_c[:, dead].sum() == 0
    assert degr_b.sum(axis=1) == pytest.approx(sizes)
    keep = alive.nonzero()[0]
    assert (degr_c[:, keep] >= healthy_c[:, keep]).all()
    assert (degr_b[:, keep] >= healthy_b[:, keep] - 1e-9).all()


def test_flowlet_split_rejects_bad_args():
    sizes = np.array([1024.0])
    with pytest.raises(ValueError, match="flowlet_bytes"):
        flowlet_split(sizes, 2, 0)
    with pytest.raises(ValueError, match="n_buckets"):
        flowlet_split(sizes, 0, 4096)
    with pytest.raises(ValueError, match="alive"):
        flowlet_split(sizes, 2, 4096, alive=np.ones(3, dtype=bool))
    with pytest.raises(RuntimeError, match="all buckets down"):
        flowlet_split(sizes, 2, 4096, alive=np.zeros(2, dtype=bool))


def test_flowlet_split_zero_sized_flows():
    by, cnt = flowlet_split(np.array([0.0, 0.0]), 4, 4096)
    assert by.sum() == 0 and cnt.sum() == 0


@given(total=st.integers(1 << 12, 1 << 22), dead=st.integers(0, 3))
@settings(max_examples=10, deadline=None)
def test_simulate_sprayed_flowlet_granularity(total, dead):
    topo = MPHX(n=4, p=2, dims=(4,))
    cfg = SprayConfig(n_planes=4, per_chunk_overhead_s=0.0)
    skew = [1.0] * 4
    skew[dead] = math.inf
    flows = [FlowSpec(0, 1, total)]
    res = simulate_sprayed(topo, flows, cfg=cfg, plane_skew=skew,
                           granularity="flowlet", flowlet_bytes=4096)
    assert res.per_plane_bytes.sum() == pytest.approx(total)
    assert res.per_plane_bytes[0, dead] == 0.0
    assert not res.stalled.any()
    with pytest.raises(ValueError, match="granularity"):
        simulate_sprayed(topo, flows, cfg=cfg, granularity="bogus")
