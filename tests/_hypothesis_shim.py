"""Minimal, deterministic stand-in for ``hypothesis`` when it is not installed.

The real library is preferred (``pip install -r requirements-dev.txt``); this
shim keeps the property tests *running* — not skipped — in bare environments
by sampling a fixed number of pseudo-random examples per test.  It implements
only the API surface this repo uses:

  * ``given(*strategies, **strategies)`` / ``settings(max_examples, deadline)``
  * ``strategies.integers / floats / sampled_from / booleans / lists``
  * strategy ``.map(f)`` and ``.filter(pred)``

Examples are seeded from the wrapped test's name, so failures reproduce
across runs.  Boundary values (min/max) are always tried first, which is
where most of the real library's bug-finding power comes from for the
invariants tested here.
"""

from __future__ import annotations

import inspect
import math
import random
import zlib
from functools import wraps

DEFAULT_MAX_EXAMPLES = 25
_FILTER_ATTEMPTS = 1000


class Strategy:
    """A lazily-evaluated example generator.

    ``draw(rng, i)`` returns the i-th example; indices 0.. hit boundary
    values first when the strategy has natural boundaries.
    """

    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random, i: int):
        return self._draw(rng, i)

    def map(self, f):
        return Strategy(lambda rng, i: f(self._draw(rng, i)))

    def filter(self, pred):
        def draw(rng, i):
            x = self._draw(rng, i)
            for _ in range(_FILTER_ATTEMPTS):
                if pred(x):
                    return x
                x = self._draw(rng, rng.randrange(1 << 30))
            raise ValueError("filter predicate rejected all examples")

        return Strategy(draw)


class strategies:  # noqa: N801 — mimics the ``hypothesis.strategies`` module
    @staticmethod
    def integers(min_value: int, max_value: int) -> Strategy:
        bounds = [min_value, max_value]

        def draw(rng, i):
            if i < len(bounds):
                return bounds[i]
            return rng.randint(min_value, max_value)

        return Strategy(draw)

    @staticmethod
    def floats(min_value: float, max_value: float) -> Strategy:
        bounds = [min_value, max_value,
                  (min_value + max_value) / 2.0]

        def draw(rng, i):
            if i < len(bounds):
                return bounds[i]
            # log-uniform when the range spans decades, else uniform
            if min_value > 0 and max_value / min_value > 100:
                lo, hi = math.log(min_value), math.log(max_value)
                return math.exp(rng.uniform(lo, hi))
            return rng.uniform(min_value, max_value)

        return Strategy(draw)

    @staticmethod
    def sampled_from(options) -> Strategy:
        options = list(options)

        def draw(rng, i):
            if i < len(options):
                return options[i]
            return rng.choice(options)

        return Strategy(draw)

    @staticmethod
    def booleans() -> Strategy:
        return strategies.sampled_from([False, True])

    @staticmethod
    def lists(elements: Strategy, min_size: int = 0,
              max_size: int = 10) -> Strategy:
        def draw(rng, i):
            # cycle sizes so every length in [min_size, max_size] is hit
            span = max_size - min_size + 1
            size = min_size + (i % span) if i < 2 * span \
                else rng.randint(min_size, max_size)
            return [elements.draw(rng, rng.randrange(1 << 30))
                    for _ in range(size)]

        return Strategy(draw)


st = strategies


class settings:  # noqa: N801 — decorator, like hypothesis.settings
    def __init__(self, max_examples: int = DEFAULT_MAX_EXAMPLES,
                 deadline=None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, f):
        f._shim_settings = self
        return f


def given(*arg_strategies, **kw_strategies):
    """Run the test once per generated example (deterministic seed)."""

    def decorate(f):
        cfg = getattr(f, "_shim_settings", None)
        n = cfg.max_examples if cfg else DEFAULT_MAX_EXAMPLES

        @wraps(f)
        def wrapper(*args, **kwargs):
            seed = zlib.crc32(f.__qualname__.encode())
            rng = random.Random(seed)
            for i in range(n):
                gen_args = tuple(s.draw(rng, i) for s in arg_strategies)
                gen_kw = {k: s.draw(rng, i) for k, s in kw_strategies.items()}
                try:
                    f(*args, *gen_args, **kwargs, **gen_kw)
                except _Assumption:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"shim-hypothesis example #{i} failed: "
                        f"args={gen_args} kwargs={gen_kw}") from e

        # pytest must not see the generated parameters as fixtures: expose
        # only the test's own (fixture) params in the wrapper's signature.
        sig = inspect.signature(f)
        params = list(sig.parameters.values())
        params = params[len(arg_strategies):]
        params = [p for p in params if p.name not in kw_strategies]
        wrapper.__signature__ = sig.replace(parameters=params)
        del wrapper.__wrapped__
        return wrapper

    return decorate


def assume(condition: bool) -> None:
    """Degraded ``assume``: treat a failed assumption as a pass."""
    if not condition:
        raise _Assumption()


class _Assumption(Exception):
    pass
