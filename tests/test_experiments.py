"""Vectorized routing engine + experiment sweep subsystem.

The load-bearing guarantee: the batched array engine reproduces the legacy
dict-based router's link loads bit-for-bit (well, to 1e-9 — float summation
order differs) on small MPHX instances, for every traffic pattern and for
both deterministic modes.  Plus smoke tests of the sweep runner's JSON and
markdown artifacts.
"""

import json
import os

import numpy as np
import pytest

from repro.experiments.artifacts import SCHEMA_VERSION
from repro.core import MPHX
from repro.core.routing import (HyperXRouter, bit_complement_traffic,
                                neighbor_shift_traffic, route_demands,
                                uniform_traffic)
from repro.core.routing_vec import (EdgeIndex, VectorizedHyperXRouter,
                                    bit_complement_demands, demands_from_dict,
                                    get_backend, neighbor_shift_demands,
                                    ring_demands, transpose_demands,
                                    uniform_demands)
from repro.core.netsim import load_sweep, pattern_throughput
from repro.experiments import (SCENARIOS, available_scenarios, get_scenario,
                               markdown_table, run_sweep_suite,
                               run_table2_suite)

# small instances where the legacy router never subsamples paths
# (m! <= 24 orderings, deroutes <= 16), so equivalence is exact
SMALL_TOPOS = [
    MPHX(n=2, p=4, dims=(4, 4)),
    MPHX(n=2, p=8, dims=(8, 8)),
    MPHX(n=1, p=4, dims=(4, 3)),      # asymmetric dims
    MPHX(n=2, p=3, dims=(3, 3, 3)),   # 3D: 6 orderings
]

PATTERNS = [
    ("uniform", uniform_traffic, uniform_demands),
    ("neighbor_shift", neighbor_shift_traffic, neighbor_shift_demands),
    ("bit_complement", bit_complement_traffic, bit_complement_demands),
]


def _edge_diff(legacy_ll, array_ll) -> float:
    ld = {k: v for k, v in legacy_ll.loads.items() if v > 0}
    vd = array_ll.to_dict()
    keys = set(ld) | set(vd)
    return max(abs(ld.get(k, 0.0) - vd.get(k, 0.0)) for k in keys)


# ---------------------------------------------------------------- engine ----


@pytest.mark.parametrize("topo", SMALL_TOPOS, ids=lambda t: t.name)
@pytest.mark.parametrize("pattern", [p[0] for p in PATTERNS])
@pytest.mark.parametrize("mode", ["minimal", "valiant"])
def test_vectorized_matches_legacy(topo, pattern, mode):
    _, dict_fn, arr_fn = next(p for p in PATTERNS if p[0] == pattern)
    demands = dict_fn(topo, 1600.0)
    legacy = HyperXRouter(topo).route(demands, mode=mode)
    vec = VectorizedHyperXRouter(topo).route(arr_fn(topo, 1600.0), mode=mode)
    assert _edge_diff(legacy, vec) < 1e-9
    assert vec.max_utilization() == pytest.approx(
        legacy.max_utilization(), abs=1e-9)
    assert vec.saturation_throughput() == pytest.approx(
        legacy.saturation_throughput(1600.0), abs=1e-9)


def test_demand_builders_match_dict_generators():
    topo = MPHX(n=2, p=4, dims=(4, 4))
    for _, dict_fn, arr_fn in PATTERNS:
        assert arr_fn(topo, 800.0).to_dict() == pytest.approx(
            dict_fn(topo, 800.0))


def test_route_demands_dispatcher_equivalence():
    topo = MPHX(n=2, p=4, dims=(4, 4))
    demands = neighbor_shift_traffic(topo, 1600.0)
    a = route_demands(topo, demands, mode="minimal", engine="dict")
    b = route_demands(topo, demands, mode="minimal", engine="array")
    assert b.max_utilization() == pytest.approx(a.max_utilization(), abs=1e-9)
    with pytest.raises(ValueError):
        route_demands(topo, demands, engine="quantum")


def test_edge_index_roundtrips():
    topo = MPHX(n=4, p=86, dims=(86, 9), links_per_dim=(85, 85))
    idx = EdgeIndex(topo)
    ids = np.arange(topo.switches_per_plane, dtype=np.int64)
    coords = idx.ids_to_coords(ids)
    assert np.array_equal(idx.coords_to_ids(coords), ids)
    # spot-check slot -> edge against topo coordinates
    u, v = idx.slot_to_edge(idx.n_slots - 1)
    cu, cv = topo.id_to_coord(u), topo.id_to_coord(v)
    assert sum(a != b for a, b in zip(cu, cv)) <= 1


def test_edge_slots_match_switch_graph():
    """Every loaded edge slot must be a real link of the built multigraph,
    with the same trunking multiplicity the capacity model assumes."""
    topo = MPHX(n=1, p=4, dims=(4, 3))
    us, vs, mult = topo.build_graph().directed_edge_arrays()
    graph_edges = {(u, v): m for u, v, m in zip(us, vs, mult)}
    ll = VectorizedHyperXRouter(topo).route(
        uniform_demands(topo, 1600.0), "valiant")
    idx = ll.index
    for slot in np.nonzero(np.asarray(ll.loads))[0]:
        u, v = idx.slot_to_edge(int(slot))
        assert (u, v) in graph_edges
        assert idx.capacity[slot] == pytest.approx(
            graph_edges[(u, v)] * topo.port_gbps)


def test_hotspot_to_dict_accumulates_duplicates():
    """hotspot lists (s, hot) twice (uniform + incast part); to_dict must
    sum them, not drop one."""
    from repro.core.routing_vec import hotspot_demands

    topo = MPHX(n=2, p=4, dims=(4, 4))
    d = hotspot_demands(topo, 800.0)
    assert sum(d.to_dict().values()) == pytest.approx(d.total_gbps())


def test_adaptive_improves_adversarial():
    """Parallel UGAL must beat minimal on the §5.2 neighbor-shift pattern."""
    topo = MPHX(n=2, p=8, dims=(8, 8))
    d = neighbor_shift_demands(topo, 1600.0)
    router = VectorizedHyperXRouter(topo)
    mn = router.route(d, "minimal").max_utilization()
    ad = router.route(d, "adaptive").max_utilization()
    assert ad < mn / 2


def test_adaptive_conserves_demand():
    topo = MPHX(n=2, p=4, dims=(4, 4))
    d = neighbor_shift_demands(topo, 1600.0)
    ll = VectorizedHyperXRouter(topo).route(d, "adaptive")
    # every quantum lands on a path of >= 1 hops: total load >= total demand
    assert ll.total_load() >= d.total_gbps() - 1e-6


def test_jax_backend_matches_numpy():
    jax = pytest.importorskip("jax")
    topo = MPHX(n=2, p=4, dims=(4, 4))
    d = uniform_demands(topo, 1600.0)
    ref = VectorizedHyperXRouter(topo, backend="numpy").route(d, "minimal")
    old = jax.config.jax_enable_x64
    try:
        jax.config.update("jax_enable_x64", True)
        jx = VectorizedHyperXRouter(topo, backend="jax").route(d, "minimal")
        assert np.allclose(np.asarray(jx.loads), ref.loads, atol=1e-9)
    finally:
        jax.config.update("jax_enable_x64", old)
    assert get_backend("numpy")[0] == "numpy"


# ------------------------------------------------------------- scenarios ----


def test_scenario_registry_complete():
    expected = {"uniform", "neighbor_shift", "bit_complement", "transpose",
                "hotspot", "allreduce_ring", "allgather_ring", "alltoall"}
    assert expected <= set(SCENARIOS)
    with pytest.raises(KeyError):
        get_scenario("nope")


def test_scenarios_applicability_and_sanity():
    square = MPHX(n=2, p=4, dims=(4, 4))
    skewed = MPHX(n=1, p=4, dims=(4, 3))
    assert "transpose" in available_scenarios(square)
    assert "transpose" not in available_scenarios(skewed)
    for name in available_scenarios(square):
        d = SCENARIOS[name].builder(square, 1600.0)
        assert d.n > 0
        assert np.all(d.src != d.dst)
        assert np.all(d.gbps > 0)


def test_transpose_requires_square():
    with pytest.raises(ValueError):
        transpose_demands(MPHX(n=1, p=4, dims=(4, 3)), 1600.0)


def test_collective_scenarios_scale_with_spray():
    """Collective schedules charge the plane fabric at >= the perfect-spray
    rate (whole-chunk rounding can only concentrate load)."""
    topo = MPHX(n=4, p=4, dims=(4, 4))
    plain = ring_demands(topo, 1600.0)
    coll = SCENARIOS["allreduce_ring"].builder(topo, 1600.0)
    assert np.all(coll.gbps >= plain.gbps - 1e-9)


def test_ring_collective_scenarios_differ():
    """allreduce_ring charges the spray schedule on payload/m per-step
    chunks; allgather_ring moves the full payload per step — on a topology
    where the small chunk sprays onto one plane they must differ."""
    topo = MPHX(n=4, p=4, dims=(4, 4))
    ar = SCENARIOS["allreduce_ring"].builder(topo, 1600.0)
    ag = SCENARIOS["allgather_ring"].builder(topo, 1600.0)
    assert ar.gbps.sum() > ag.gbps.sum()


# ----------------------------------------------------------------- sweeps ----


def test_load_sweep_zero_first_load():
    """A sweep starting at 0 offered load must not divide by zero."""
    topo = MPHX(n=2, p=8, dims=(8, 8))
    rows = load_sweep(topo, neighbor_shift_demands, mode="minimal",
                      load_fractions=(0.0, 0.5, 1.0))
    assert rows[0]["max_util"] == 0.0
    assert rows[0]["throughput_fraction"] == 1.0
    assert rows[0]["latency_us"] > 0
    assert rows[2]["max_util"] == pytest.approx(2 * rows[1]["max_util"])


def test_load_sweep_monotone_and_linear():
    topo = MPHX(n=2, p=8, dims=(8, 8))
    rows = load_sweep(topo, neighbor_shift_demands, mode="minimal",
                      load_fractions=(0.25, 0.5, 1.0))
    utils = [r["max_util"] for r in rows]
    assert utils == sorted(utils)
    # fixed path spread -> utilization linear in offered load
    assert utils[1] == pytest.approx(2 * utils[0], rel=1e-9)
    sat = [r for r in rows if r["max_util"] >= 1.0]
    assert all(r["latency_us"] is None for r in sat)
    ok = [r for r in rows if r["max_util"] < 1.0]
    assert all(r["latency_us"] > 0 for r in ok)


def test_pattern_throughput_keys():
    topo = MPHX(n=2, p=4, dims=(4, 4))
    rep = pattern_throughput(topo, uniform_demands(topo, 1600.0), "minimal")
    assert {"max_util", "mean_util", "throughput_fraction",
            "total_load_gbps"} <= set(rep)


def test_table2_suite_artifact(tmp_path):
    payload = run_table2_suite(outdir=str(tmp_path))
    assert (tmp_path / "table2.json").exists()
    assert (tmp_path / "table2.md").exists()
    disk = json.loads((tmp_path / "table2.json").read_text())
    assert disk["schema_version"] == SCHEMA_VERSION
    assert disk["suite"] == "table2"
    assert len(disk["rows"]) == 8
    by_name = {r["topology"]: r for r in disk["rows"]}
    assert by_name["8-Plane 1D HyperX"]["diameter"] == 3
    # the reproduction matches the paper's published cost column
    assert all(r["cost_matches_paper"] for r in disk["rows"]
               if "cost_matches_paper" in r)
    assert payload["rows"] == disk["rows"]


def test_sweep_suite_artifact(tmp_path):
    payload = run_sweep_suite(
        outdir=str(tmp_path), topo_names=["mphx-2p-8x8"],
        scenario_names=["uniform", "neighbor_shift"],
        modes=["minimal"], load_fractions=(0.5, 1.0))
    disk = json.loads((tmp_path / "sweep.json").read_text())
    assert disk["suite"] == "sweep"
    assert disk["schema_version"] == SCHEMA_VERSION
    assert len(disk["rows"]) == 2 * 2  # 2 scenarios x 2 load levels
    for r in disk["rows"]:
        assert {"topology", "scenario", "mode", "engine", "offered_fraction",
                "max_util", "throughput_fraction"} <= set(r)
    assert (tmp_path / "sweep.md").read_text().startswith("# Latency")
    assert payload["rows"] == disk["rows"]


def test_cli_main(tmp_path):
    from repro.experiments.run import main

    rc = main(["--suite", "sweep", "--out", str(tmp_path),
               "--topos", "mphx-2p-8x8", "--scenarios", "uniform",
               "--modes", "minimal", "--loads", "1.0"])
    assert rc == 0
    assert (tmp_path / "sweep.json").exists()


def test_markdown_table_formatting():
    md = markdown_table([{"a": 1, "b": None}, {"a": 2.5, "c": True}],
                        columns=["a", "b", "c"])
    lines = md.strip().splitlines()
    assert lines[0] == "| a | b | c |"
    assert "—" in lines[2] and "yes" in lines[3]
