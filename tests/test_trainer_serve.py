"""End-to-end behaviour: training reduces loss; serving engine works;
checkpoint-restart resumes identically; grad compression still converges."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, RunConfig
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticDataset, \
    loss_floor
from repro.models.transformer import DecoderLM
from repro.serve.engine import Request, ServeEngine
from repro.train.checkpoint import Checkpointer
from repro.train.trainer import Trainer


def tiny_cfg(vocab=64):
    return ModelConfig(arch_id="tiny", family="dense", n_layers=2,
                       d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                       vocab_size=vocab, param_dtype="float32",
                       activation_dtype="float32")


def make_setup(vocab=64, steps=60, **run_kw):
    cfg = tiny_cfg(vocab)
    run = RunConfig(lr=3e-3, warmup_steps=10, total_steps=steps, **run_kw)
    model = DecoderLM(cfg, run)
    trainer = Trainer(model, run)
    dcfg = DataConfig(vocab_size=vocab, seq_len=32, global_batch=8,
                      temperature=0.25)
    ds = SyntheticDataset(dcfg)
    return cfg, model, trainer, ds, dcfg


def test_training_reduces_loss():
    cfg, model, trainer, ds, dcfg = make_setup()
    state = trainer.init_state(jax.random.PRNGKey(0))
    pf = Prefetcher(ds)
    state, hist = trainer.fit(state, pf, steps=60, log_every=5)
    pf.close()
    first, last = hist[0]["loss"], hist[-1]["loss"]
    floor = loss_floor(dcfg)
    assert last < first - 0.5, f"no learning: {first} -> {last}"
    assert last < np.log(dcfg.vocab_size), "below uniform baseline"
    assert last > floor - 0.05, "cannot beat the entropy floor"


def test_grad_accumulation_matches_single_batch():
    """k microbatches == one big batch (same grads => same first step)."""
    cfg, model, _, ds, _ = make_setup()
    batch = jax.tree.map(jnp.asarray, ds.batch(0))
    run1 = RunConfig(lr=1e-2, microbatches=1, warmup_steps=0, total_steps=10)
    runk = RunConfig(lr=1e-2, microbatches=4, warmup_steps=0, total_steps=10)
    t1 = Trainer(DecoderLM(cfg, run1), run1)
    tk = Trainer(DecoderLM(cfg, runk), runk)
    s1 = t1.init_state(jax.random.PRNGKey(0))
    sk = tk.init_state(jax.random.PRNGKey(0))
    s1b, m1 = t1.make_train_step()(s1, batch)
    skb, mk = tk.make_train_step()(sk, batch)
    for l1, lk in zip(jax.tree.leaves(s1b.params),
                      jax.tree.leaves(skb.params)):
        # accumulation order differs (k partial means vs one mean); through
        # AdamW's rsqrt that is worth up to ~1e-4 in float32 on some builds
        np.testing.assert_allclose(np.asarray(l1), np.asarray(lk),
                                   atol=1e-4, rtol=1e-3)


def test_int8_ef_training_converges():
    cfg, model, _, ds, dcfg = make_setup(grad_compression="int8_ef")
    run = RunConfig(lr=3e-3, warmup_steps=10, total_steps=60,
                    grad_compression="int8_ef")
    trainer = Trainer(DecoderLM(cfg, run), run)
    state = trainer.init_state(jax.random.PRNGKey(0))
    pf = Prefetcher(ds)
    state, hist = trainer.fit(state, pf, steps=60, log_every=5)
    pf.close()
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.4


def test_checkpoint_restart_resumes_identically(tmp_path):
    cfg, model, trainer, ds, _ = make_setup()
    state = trainer.init_state(jax.random.PRNGKey(0))
    step_fn = trainer.make_train_step()

    for i in range(5):
        batch = jax.tree.map(jnp.asarray, ds.batch(i))
        state, _ = step_fn(state, batch)
    ck = Checkpointer(str(tmp_path))
    ck.save(5, state)

    # continue 3 more steps
    cont = state
    for i in range(5, 8):
        batch = jax.tree.map(jnp.asarray, ds.batch(i))
        cont, m_direct = step_fn(cont, batch)

    # restart from checkpoint and replay
    template = trainer.init_state(jax.random.PRNGKey(0))
    restored, step = ck.restore(template)
    assert step == 5
    assert int(restored.opt.step) == 5
    for i in range(5, 8):
        batch = jax.tree.map(jnp.asarray, ds.batch(i))
        restored, m_replay = step_fn(restored, batch)
    assert m_direct["loss"] == pytest.approx(m_replay["loss"], abs=1e-5)
    for a, b in zip(jax.tree.leaves(cont.params),
                    jax.tree.leaves(restored.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_serve_engine_greedy_matches_manual_decode():
    cfg = tiny_cfg()
    model = DecoderLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (3, 8), 0,
                           cfg.vocab_size), np.int32)
    eng = ServeEngine(model, params, max_batch=2, max_len=32)
    reqs = [Request(prompt=p, max_new_tokens=5) for p in prompts]
    eng.run(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.output) == 5 for r in reqs)
    assert eng.stats.tokens_out == 15
    # manual greedy for request 0
    toks = jnp.asarray(prompts[:1])
    last, caches = model.prefill(params, toks, max_len=32)
    outs = []
    for _ in range(5):
        nxt = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
        outs.append(int(nxt[0, 0]))
        last, caches = model.decode_step(params, nxt, caches)
    assert outs == reqs[0].output


def test_serve_engine_eos_stops_early():
    cfg = tiny_cfg()
    model = DecoderLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.zeros((4,), np.int32)
    # discover the first greedy token, then use it as "EOS"
    last, _ = model.prefill(params, jnp.asarray(prompt)[None], max_len=16)
    eos = int(jnp.argmax(last, -1)[0])
    eng = ServeEngine(model, params, max_batch=1, max_len=16)
    r = Request(prompt=prompt, max_new_tokens=8, eos_id=eos)
    eng.run([r])
    assert r.output == [] and r.done
