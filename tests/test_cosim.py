"""Differential tests for the training-step co-simulator (repro.cosim).

The load-bearing pins: in the uncontended single-collective limit (zero
per-hop latencies, even plane spray, no chunk overhead) the *measured*
co-sim phase time must collapse to the alpha-beta closed forms of
``repro.core.netsim`` within 1e-6 relative — for both phase execution
methods.  Around that: contention monotonicity (model size, plane
skew/failure), routing-engine and numpy/jax backend agreement, the
serialized batch scheduler, hierarchical phase decomposition, placement
properties, and the ``cosim`` experiment-suite artifact.
"""

import math

import numpy as np
import pytest

from repro.experiments.artifacts import SCHEMA_VERSION
from repro.core.hyperx import MPHX
from repro.core.netsim import (DEFAULT_NET, NetParams, _alpha,
                               allgather_time, make_router,
                               ring_allreduce_time)
from repro.core.planes import SprayConfig
from repro.cosim import (CollectivePhase, TrainJob, decompose_phase,
                         group_members, job_from_model, mphx_rank_layout,
                         phase_step_flows, phases_from_collectives,
                         rank_to_switch, simulate_step)
from repro.sim import FlowSpec, simulate_flow_batches

# ------------------------------------------------ uncontended collapse ----
# Zero per-hop latencies kill the hop-count asymmetry between paths, a
# chunk-aligned payload sprays evenly, and full-mesh ring flows are
# link-disjoint — so the measured time must equal the closed form.

UNCONTENDED_NET = NetParams(t_switch=0.0, t_prop_per_hop=0.0)
UNCONTENDED_CFG = SprayConfig(n_planes=2, chunk_bytes=1 << 17,
                              per_chunk_overhead_s=0.0)


def _uncontended_topo() -> MPHX:
    return MPHX(n=2, p=1, dims=(8,))


def _single_phase_job(phase: CollectivePhase, n_ranks: int) -> TrainJob:
    # active_params=0 -> compute_s == 0 -> step time IS the comm time
    return TrainJob("toy", n_ranks, {"dp": n_ranks, "tp": 1, "ep": 1},
                    tokens_per_step=1, active_params=0, phases=(phase,))


@pytest.mark.parametrize("method", ["steady", "batches"])
def test_uncontended_allreduce_collapses_to_closed_form(method):
    topo = _uncontended_topo()
    m = 8
    b = m * 2 * (1 << 17) * 4   # step chunk = b/m = whole chunks per plane
    job = _single_phase_job(
        CollectivePhase("ar", "allreduce", m, 1, b), m)
    res = simulate_step(topo, job, cfg=UNCONTENDED_CFG,
                        net=UNCONTENDED_NET, method=method)
    closed = ring_allreduce_time(topo, b, m=m, net=UNCONTENDED_NET).total_s
    assert abs(res.comm_s - closed) / closed < 1e-6
    assert res.step_s == res.comm_s


@pytest.mark.parametrize("method", ["steady", "batches"])
def test_uncontended_allgather_collapses_to_closed_form(method):
    topo = _uncontended_topo()
    m = 8
    b = 2 * (1 << 17) * 4       # shard = whole chunks per plane
    job = _single_phase_job(
        CollectivePhase("ag", "allgather", m, 1, b), m)
    res = simulate_step(topo, job, cfg=UNCONTENDED_CFG,
                        net=UNCONTENDED_NET, method=method)
    closed = allgather_time(topo, b, m=m, net=UNCONTENDED_NET).total_s
    assert abs(res.comm_s - closed) / closed < 1e-6


def test_uncontended_steady_and_batches_agree():
    topo = _uncontended_topo()
    b = 8 * 2 * (1 << 17) * 4
    job = _single_phase_job(CollectivePhase("ar", "allreduce", 8, 1, b), 8)
    out = [simulate_step(topo, job, cfg=UNCONTENDED_CFG,
                         net=UNCONTENDED_NET, method=m).comm_s
           for m in ("steady", "batches")]
    assert abs(out[0] - out[1]) / out[0] < 1e-6


# --------------------------------------------------------- monotonicity ----


def _toy_job(scale: float = 1.0, n_ranks: int = 32) -> TrainJob:
    phases = (
        CollectivePhase("tp_ag", "allgather", 4, 1, scale * (1 << 22),
                        calls=4),
        CollectivePhase("ep_a2a", "alltoall", 4, 4, scale * (1 << 22),
                        calls=2),
        CollectivePhase("dp_ar", "allreduce", n_ranks // 4, 4,
                        scale * (1 << 26)),
    )
    return TrainJob("toy", n_ranks, {"dp": n_ranks // 4, "tp": 4, "ep": 4},
                    tokens_per_step=4096, active_params=int(1e9),
                    phases=phases)


def test_step_time_monotone_in_model_size():
    topo = MPHX(n=2, p=4, dims=(8,))
    comms = [simulate_step(topo, _toy_job(s)).comm_s for s in (1, 2, 4)]
    assert comms[0] < comms[1] < comms[2]
    # doubling every payload at fixed alpha at most doubles the time
    assert comms[1] <= 2 * comms[0] + 1e-12


def test_step_time_monotone_in_plane_failure():
    topo = MPHX(n=2, p=4, dims=(8,))
    job = _toy_job()
    comms = [simulate_step(topo, job, plane_skew=skew).comm_s
             for skew in ([1.0, 1.0], [1.0, 2.0], [1.0, math.inf])]
    assert comms[0] <= comms[1] <= comms[2]
    assert comms[0] < comms[2]


def test_routing_engines_agree_on_mphx():
    topo = MPHX(n=2, p=4, dims=(8,))
    job = _toy_job()
    by_engine = {e: simulate_step(topo, job, engine=e).comm_s
                 for e in ("array", "graph")}
    rel = abs(by_engine["array"] - by_engine["graph"]) / by_engine["array"]
    assert rel < 1e-9


def test_numpy_and_jax_backends_agree():
    pytest.importorskip("jax")
    topo = MPHX(n=2, p=4, dims=(8,))
    job = _toy_job()
    a = simulate_step(topo, job, backend="numpy").comm_s
    b = simulate_step(topo, job, backend="jax").comm_s
    assert abs(a - b) / a < 1e-6


def test_intra_switch_phase_costs_alpha_only():
    topo = MPHX(n=2, p=8, dims=(8,))
    job = _single_phase_job(
        CollectivePhase("tp", "allgather", 8, 1, 1 << 20, calls=3), 16)
    res = simulate_step(topo, job)
    ph = res.phases[0]
    assert ph.n_flows == 0    # every group fits inside one switch
    assert res.comm_s == pytest.approx(
        3 * 7 * _alpha(topo, 2.0, DEFAULT_NET))


def test_compute_term_follows_6nd():
    topo = _uncontended_topo()
    b = 8 * 2 * (1 << 17) * 4
    job = TrainJob("toy", 8, {"dp": 8, "tp": 1, "ep": 1},
                   tokens_per_step=4096, active_params=int(1e9),
                   phases=(CollectivePhase("ar", "allreduce", 8, 1, b),))
    res = simulate_step(topo, job, device_tflops=100.0)
    expect = 6.0 * 1e9 * 4096 / (8 * 100.0 * 1e12)
    assert res.compute_s == pytest.approx(expect)
    assert res.step_s == pytest.approx(res.comm_s + res.compute_s)
    assert res.tokens_per_s == pytest.approx(4096 / res.step_s)


def test_oversized_job_rejected():
    topo = MPHX(n=2, p=1, dims=(4,))   # 4 NICs
    with pytest.raises(ValueError, match="ranks"):
        simulate_step(topo, _toy_job(n_ranks=32))


# ------------------------------------------------- serialized batches ----


def test_flow_batches_serialize_on_the_fabric_clock():
    topo = MPHX(n=2, p=2, dims=(4,))
    router = make_router(topo)
    batch = [FlowSpec(0, 1, 1 << 24), FlowSpec(2, 3, 1 << 24)]
    res = simulate_flow_batches(router, [batch, batch, batch])
    assert np.all(np.diff(res.batch_start_s) > 0)
    assert np.all(res.batch_finish_s >= res.batch_start_s)
    # batch k is admitted exactly at batch k-1's transfer finish (gap 0)
    assert res.batch_start_s[1] == pytest.approx(res.batch_finish_s[0])
    assert res.makespan_s == pytest.approx(float(res.batch_finish_s[-1]))
    # identical batches on an idle fabric take identical spans
    spans = res.batch_span_s()
    assert spans[1] == pytest.approx(spans[0])


def test_flow_batches_gap_shifts_later_batches():
    topo = MPHX(n=2, p=2, dims=(4,))
    router = make_router(topo)
    batch = [FlowSpec(0, 1, 1 << 24)]
    r0 = simulate_flow_batches(router, [batch, batch], gap_s=0.0)
    r1 = simulate_flow_batches(router, [batch, batch], gap_s=1e-3)
    assert r1.makespan_s == pytest.approx(r0.makespan_s + 1e-3)


def test_flow_batches_empty_batch_costs_nothing():
    topo = MPHX(n=2, p=2, dims=(4,))
    router = make_router(topo)
    batch = [FlowSpec(0, 1, 1 << 24)]
    res = simulate_flow_batches(router, [batch, [], batch])
    assert res.results[1] is None
    assert res.batch_start_s[1] == pytest.approx(res.batch_finish_s[1])
    full = simulate_flow_batches(router, [batch, batch])
    assert res.makespan_s == pytest.approx(full.makespan_s)


def test_flow_batches_within_batch_start_offsets():
    topo = MPHX(n=2, p=2, dims=(4,))
    router = make_router(topo)
    off = 5e-4
    plain = simulate_flow_batches(router, [[FlowSpec(0, 1, 1 << 24)]])
    late = simulate_flow_batches(
        router, [[FlowSpec(0, 1, 1 << 24, start_s=off)]])
    assert late.makespan_s == pytest.approx(plain.makespan_s + off)


# --------------------------------------------- traffic & decomposition ----


def test_wire_bytes_per_rank_formulas():
    ar = CollectivePhase("a", "allreduce", 8, 1, 800.0)
    ag = CollectivePhase("b", "allgather", 8, 1, 100.0)
    a2a = CollectivePhase("c", "alltoall", 8, 1, 700.0)
    assert ar.wire_bytes_per_rank() == pytest.approx(2 * 7 / 8 * 800.0)
    assert ag.wire_bytes_per_rank() == pytest.approx(7 * 100.0)
    assert a2a.wire_bytes_per_rank() == pytest.approx(700.0)


@pytest.mark.parametrize("kind", ["allreduce", "allgather",
                                  "reducescatter"])
def test_decompose_phase_conserves_wire_bytes(kind):
    phase = CollectivePhase("x", kind, 16, 1, float(1 << 20), calls=3)
    subs = decompose_phase(phase, [(4, 1), (4, 4)])
    assert len(subs) == (4 if kind == "allreduce" else 2)
    total = sum(s.wire_bytes_per_rank() for s in subs)
    assert total == pytest.approx(phase.wire_bytes_per_rank())
    assert all(s.calls == 3 for s in subs)


def test_decompose_phase_passthrough_cases():
    a2a = CollectivePhase("x", "alltoall", 16, 1, 1.0)
    assert decompose_phase(a2a, [(4, 1), (4, 4)]) == [a2a]
    ar = CollectivePhase("y", "allreduce", 16, 1, 1.0)
    assert decompose_phase(ar, [(16, 1)]) == [ar]
    with pytest.raises(ValueError, match="factor"):
        decompose_phase(ar, [(4, 1), (2, 4)])


def test_job_from_model_phase_accounting():
    from repro.models.registry import get_config
    cfg = get_config("mixtral-8x22b")
    job = job_from_model(cfg, dp=8, tp=8, ep=8,
                         param_count=int(141e9), active_params=int(39e9))
    kinds = {p.name: p for p in job.phases}
    assert set(kinds) == {"tp_act_allgather", "tp_act_reducescatter",
                          "ep_token_alltoall", "dp_grad_allreduce"}
    ag = kinds["tp_act_allgather"]
    assert (ag.size, ag.stride, ag.calls) == (8, 1, 2 * cfg.n_layers)
    a2a = kinds["ep_token_alltoall"]
    assert (a2a.size, a2a.stride) == (8, 8)
    ar = kinds["dp_grad_allreduce"]
    # bf16 grads of the rank's 1/tp parameter shard
    assert ar.bytes_per_rank == pytest.approx(141e9 * 2 / 8)
    assert job.total_wire_bytes() > 0


def test_job_from_model_validates_mesh():
    from repro.models.registry import get_config
    cfg = get_config("mixtral-8x22b")
    with pytest.raises(ValueError, match="divide dp"):
        job_from_model(cfg, dp=4, tp=2, ep=3, param_count=1, active_params=1)
    with pytest.raises(ValueError, match="n_experts"):
        job_from_model(cfg, dp=6, tp=2, ep=6, param_count=1, active_params=1)


def test_phases_from_collectives_inverts_wire_accounting():
    parsed = {
        "all-reduce": {"count": 2, "by_group": {"8": 1400.0}},
        "all-gather": {"count": 1, "by_group": {"4": 300.0}},
        "all-to-all": {"count": 1, "by_group": {"4": 512.0}},
        "collective-permute": {"count": 3, "by_group": {}},
    }
    phases = {p.kind: p for p in phases_from_collectives(parsed, 16)}
    assert set(phases) == {"allreduce", "allgather", "alltoall"}
    assert phases["allreduce"].bytes_per_rank == pytest.approx(
        1400.0 * 8 / (2 * 7))
    assert phases["allgather"].bytes_per_rank == pytest.approx(100.0)
    assert phases["alltoall"].bytes_per_rank == pytest.approx(512.0)
    # each recovered phase re-emits the parsed wire bytes
    for p in phases.values():
        assert p.wire_bytes_per_rank() == pytest.approx(
            {"allreduce": 1400.0, "allgather": 300.0,
             "alltoall": 512.0}[p.kind])
    with pytest.raises(ValueError, match="divide"):
        phases_from_collectives(
            {"all-reduce": {"count": 1, "by_group": {"3": 9.0}}}, 16)


# ------------------------------------------------------------ placement ----


def test_group_members_partition_rank_space():
    groups = group_members(24, 4, 2)
    flat = sorted(r for g in groups for r in g)
    assert flat == list(range(24))           # disjoint cover
    assert all(len(g) == 4 for g in groups)
    for g in groups:
        assert all(b - a == 2 for a, b in zip(g, g[1:]))


def test_phase_step_flows_conserve_crossing_bytes():
    topo = MPHX(n=2, p=2, dims=(4,))
    switch_of = rank_to_switch(topo)
    phase = CollectivePhase("ar", "allreduce", 8, 1, 8 * 1024.0)
    flows, steps, senders = phase_step_flows(phase, switch_of, 8)
    assert steps == 2 * 7
    # per ring step each rank sends b/m; same-switch hops stay off-fabric
    crossing = sum(1 for k in range(8)
                   if switch_of[k] != switch_of[(k + 1) % 8])
    assert sum(f.size_bytes for f in flows) == pytest.approx(
        crossing * 1024.0)
    assert senders.sum() == crossing
    assert len(senders) == len(flows)


def test_mphx_rank_layout_is_a_nic_permutation():
    topo = MPHX(n=2, p=2, dims=(4, 2))      # 16 NICs
    from repro.models.registry import get_config
    job = job_from_model(get_config("mixtral-8x22b"), dp=4, tp=4, ep=4,
                         param_count=int(1e9), active_params=int(1e9))
    layout = mphx_rank_layout(topo, job)
    assert sorted(layout.nic.tolist()) == list(range(16))
    for axis in ("tp", "ep", "dp"):
        fs = [f for f, _ in layout.factors[axis]]
        assert math.prod(fs) == job.mesh[axis]


def test_mapped_placement_runs_and_reports_phases():
    topo = MPHX(n=2, p=2, dims=(4, 2))
    from repro.models.registry import get_config
    job = job_from_model(get_config("mixtral-8x22b"), dp=4, tp=4, ep=4,
                         param_count=int(1e9), active_params=int(1e9))
    res = simulate_step(topo, job, placement="mapped")
    assert res.comm_s > 0
    # hierarchical decomposition may split phases, never drop traffic
    assert len(res.phases) >= len(job.phases)
    with pytest.raises(ValueError, match="MPHX"):
        from repro.core.dragonfly import Dragonfly
        simulate_step(Dragonfly(p=2, a=4, h=2, groups=9), job,
                      placement="mapped")


# ------------------------------------------------------ suite artifact ----


@pytest.mark.slow
def test_cosim_suite_writes_v4_artifacts(tmp_path):
    import json

    from repro.experiments import run_cosim_suite

    payload = run_cosim_suite(str(tmp_path),
                              config_names=["mixtral_8x22b"],
                              topo_names=["mphx-2p-8x8"], n_ranks=16)
    disk = json.load(open(tmp_path / "cosim.json"))
    assert disk["schema_version"] == SCHEMA_VERSION
    assert disk["suite"] == "cosim"
    rows = [r for r in disk["rows"] if not r.get("skipped")]
    # MPHX runs both engines plus the mapped placement
    assert {(r["engine"], r["placement"]) for r in rows} == {
        ("array", "linear"), ("array", "mapped"), ("graph", "linear")}
    for r in rows:
        assert r["tokens_per_s"] > 0
        assert r["step_ms"] >= r["comm_ms"]
        assert r["phases"]
    md = (tmp_path / "cosim.md").read_text()
    assert "tokens_per_s" in md
    assert payload["params"]["meshes"]["mixtral-8x22b"]["tp"] > 1


@pytest.mark.slow
def test_cosim_suite_skips_undersized_fabrics(tmp_path):
    import json

    from repro.experiments import run_cosim_suite

    run_cosim_suite(str(tmp_path), config_names=["mixtral-8x22b"],
                    topo_names=["dragonfly-small"], n_ranks=128)
    disk = json.load(open(tmp_path / "cosim.json"))
    assert disk["params"]["n_rows"] == 0
    [row] = disk["rows"]
    assert row["skipped"] and "NIC" in row["reason"]
