"""Property-based tests (hypothesis) for the topology layer's invariants."""

import math

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep — deterministic fallback shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import (
    DEFAULT_SWITCH,
    Dragonfly,
    MPHX,
    MultiPlaneFatTree,
    ThreeTierFatTree,
    cost_report,
)


dims_st = st.lists(st.integers(2, 12), min_size=1, max_size=3).map(tuple)
planes_st = st.integers(1, 8)
p_st = st.integers(1, 16)


@given(n=planes_st, p=p_st, dims=dims_st)
@settings(max_examples=80, deadline=None)
def test_eq1_nic_count(n, p, dims):
    """Eq. 1: N = p * prod(D_i)."""
    t = MPHX(n=n, p=p, dims=dims)
    assert t.n_nics == p * math.prod(dims)
    assert t.n_switches == n * math.prod(dims)


@given(n=planes_st, D=st.integers(1, 4))
@settings(max_examples=40, deadline=None)
def test_eq2_balanced_max_scale(n, D):
    """Eq. 2: N_max = (nk/(D+1))^(D+1), and the balanced instance achieves it
    within the radix budget."""
    k = 64
    side = n * k // (D + 1)
    if side < 2:
        return
    t = MPHX.balanced(n=n, k=k, D=D)
    assert t.n_nics == MPHX.max_scale(n, k, D) == side ** (D + 1)
    # balanced config exactly saturates the broken-out radix when divisible
    assert t.radix_used == side + D * (side - 1)
    assert t.radix_used <= n * k


@given(n=planes_st, p=p_st, dims=dims_st)
@settings(max_examples=60, deadline=None)
def test_optics_even_and_consistent(n, p, dims):
    t = MPHX(n=n, p=p, dims=dims)
    assert t.n_optics % 2 == 0
    assert t.n_optics == sum(lc.transceivers for lc in t.link_classes())
    # every optical link has exactly 2 transceivers
    assert t.n_optics == 2 * sum(lc.count for lc in t.link_classes())


@given(n=planes_st, p=p_st, dims=dims_st)
@settings(max_examples=60, deadline=None)
def test_diameter_vs_avg_hops(n, p, dims):
    t = MPHX(n=n, p=p, dims=dims)
    assert 2 <= t.avg_hops() <= t.diameter
    assert t.diameter == 2 + len([d for d in dims if d > 1])


@given(n=planes_st, p=p_st, dims=dims_st)
@settings(max_examples=30, deadline=None)
def test_graph_matches_analytics(n, p, dims):
    """Explicit graph: link totals, degree, diameter agree with closed forms."""
    t = MPHX(n=n, p=p, dims=dims)
    if t.switches_per_plane > 400:
        return
    g = t.build_graph()
    per_plane_links = sum(lc.count for lc in t.link_classes()
                          if lc.tier.startswith("dim")) / n
    assert abs(g.total_links() - per_plane_links) < 1e-6
    if t.switches_per_plane > 1:
        assert g.switch_diameter() == t.diameter - 2


@given(n=planes_st, p=p_st, dims=dims_st)
@settings(max_examples=60, deadline=None)
def test_cost_positive_and_additive(n, p, dims):
    t = MPHX(n=n, p=p, dims=dims)
    try:
        rep = cost_report(t)
    except KeyError:
        return  # port speed without a listed transceiver price
    assert rep.total_usd > 0
    assert rep.total_usd == pytest.approx(rep.switches_usd + rep.optics_usd)
    # copper access strictly reduces optics cost
    t.access_copper = True
    rep2 = cost_report(t)
    assert rep2.optics_usd < rep.optics_usd
    assert rep2.n_optics < rep.n_optics


@given(st.integers(1, 8).filter(lambda n: 65536 % (n * 64 // 2) == 0))
@settings(max_examples=8, deadline=None)
def test_more_planes_fewer_switches_mpft(n):
    """More planes (finer breakout) -> fewer physical switches for the same
    NIC count in the 2-layer multi-plane Fat-Tree (§2 motivation)."""
    try:
        t = MultiPlaneFatTree(n=n, nics=65_536)
    except ValueError:
        return
    t8 = MultiPlaneFatTree(n=8, nics=65_536)
    assert t8.n_switches <= t.n_switches


@given(n=st.sampled_from([1, 2, 4, 8]))
@settings(max_examples=4, deadline=None)
def test_mphx_planes_monotone_cost(n):
    """Paper §4: 'as the number of network planes increases, the MPHX topology
    progressively demonstrates superior cost-effectiveness' — verified on the
    Table-2 family."""
    rows = {1: MPHX(n=1, p=16, dims=(16, 16, 16)),
            2: MPHX(n=2, p=41, dims=(41, 41)),
            4: MPHX(n=4, p=86, dims=(86, 9), links_per_dim=(85, 85)),
            8: MPHX(n=8, p=256, dims=(256,))}
    costs = {k: cost_report(v).per_nic_usd for k, v in rows.items()}
    ordered = sorted(costs)
    for a, b in zip(ordered, ordered[1:]):
        assert costs[b] < costs[a]


def test_radix_infeasible_raises():
    t = MPHX(n=1, p=40, dims=(40, 40))  # radix 40+39+39=118 > 64
    with pytest.raises(ValueError):
        t.validate(DEFAULT_SWITCH)


def test_breakout_beyond_max_ports_raises():
    with pytest.raises(ValueError):
        DEFAULT_SWITCH.radix_at(100.0)  # would need radix 1024 > 512


@given(p=st.integers(1, 32), a=st.integers(2, 32), h=st.integers(1, 16),
       frac=st.floats(0.1, 1.0))
@settings(max_examples=40, deadline=None)
def test_dragonfly_counts(p, a, h, frac):
    gmax = a * h + 1
    g = max(2, int(gmax * frac))
    if (g * a * h) % 2:
        g -= 1
    if g < 2:
        return
    t = Dragonfly(p=p, a=a, h=h, groups=g)
    assert t.n_nics == p * a * g
    assert t.n_switches == a * g
    # link endpoint conservation: access + 2*(local+global) port usage
    local = g * a * (a - 1) // 2
    glob = g * a * h // 2
    assert sum(lc.count for lc in t.link_classes()) == t.n_nics + local + glob
