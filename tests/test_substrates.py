"""Tests for optimizer, data pipeline, checkpointing, fault tolerance."""

import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep — deterministic fallback shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.data.pipeline import DataConfig, Prefetcher, SyntheticDataset, \
    loss_floor
from repro.optim.adamw import AdamW, global_norm
from repro.optim.schedule import warmup_cosine
from repro.train.checkpoint import Checkpointer
from repro.train.fault import (HeartbeatMonitor, StragglerMonitor,
                               checkpoint_cadence_steps, plan_remesh)
from repro.train.trainer import TrainState, compress_grads_ef


# ------------------------------------------------------------------ AdamW


def test_adamw_matches_reference_numpy():
    """Our AdamW against a hand-rolled numpy reference on a small problem."""
    opt = AdamW(lr=1e-2, beta1=0.9, beta2=0.999, eps=1e-8, weight_decay=0.0,
                grad_clip_norm=None)
    p = {"w": jnp.array([1.0, -2.0, 3.0]), "norm_scale": jnp.array([1.0])}
    st_ = opt.init(p)
    m = {k: np.zeros_like(np.asarray(v)) for k, v in p.items()}
    v = {k: np.zeros_like(np.asarray(v)) for k, v in p.items()}
    pn = {k: np.asarray(x).copy() for k, x in p.items()}
    for t in range(1, 6):
        g = {"w": jnp.array([0.1, 0.2, -0.3]) * t,
             "norm_scale": jnp.array([0.05]) * t}
        p, st_, _ = opt.update(g, st_, p)
        for k in pn:
            gn = np.asarray(g[k])
            m[k] = 0.9 * m[k] + 0.1 * gn
            v[k] = 0.999 * v[k] + 0.001 * gn**2
            mh = m[k] / (1 - 0.9**t)
            vh = v[k] / (1 - 0.999**t)
            pn[k] -= 1e-2 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(p["w"]), pn["w"], rtol=1e-5)
    np.testing.assert_allclose(np.asarray(p["norm_scale"]), pn["norm_scale"],
                               rtol=1e-5)


def test_adamw_weight_decay_skips_norms_and_vectors():
    opt = AdamW(lr=1e-2, weight_decay=0.5, grad_clip_norm=None)
    p = {"ffn": {"w_up": jnp.ones((4, 4))}, "attn_norm": {"scale": jnp.ones((4, 4))}}
    st_ = opt.init(p)
    g = jax.tree.map(jnp.zeros_like, p)
    p2, _, _ = opt.update(g, st_, p)
    assert float(jnp.abs(p2["ffn"]["w_up"] - 1).max()) > 0  # decayed
    assert float(jnp.abs(p2["attn_norm"]["scale"] - 1).max()) == 0  # skipped


def test_adamw_grad_clip():
    opt = AdamW(lr=1.0, grad_clip_norm=1.0, weight_decay=0.0)
    p = {"w": jnp.zeros((3,))}
    st_ = opt.init(p)
    g = {"w": jnp.array([30.0, 40.0, 0.0])}  # norm 50
    _, _, metrics = opt.update(g, st_, p)
    assert metrics["grad_norm"] == pytest.approx(50.0)


def test_adamw_bf16_state_dtype():
    opt = AdamW(state_dtype="bfloat16")
    p = {"w": jnp.ones((4,), jnp.bfloat16)}
    st_ = opt.init(p)
    assert st_.m["w"].dtype == jnp.bfloat16


def test_schedule_shape():
    s0 = float(warmup_cosine(jnp.asarray(0), 10, 100))
    s10 = float(warmup_cosine(jnp.asarray(10), 10, 100))
    s100 = float(warmup_cosine(jnp.asarray(100), 10, 100))
    assert s0 == 0.0 and abs(s10 - 1.0) < 1e-6 and s100 == pytest.approx(0.1)


# ------------------------------------------------------------------- data


def test_data_deterministic_and_sharded():
    cfg = DataConfig(vocab_size=64, seq_len=32, global_batch=8)
    ds = SyntheticDataset(cfg)
    b1, b2 = ds.batch(3), ds.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # shards partition the batch deterministically and differ
    s0 = ds.batch(3, shard=0, n_shards=2)
    s1 = ds.batch(3, shard=1, n_shards=2)
    assert s0["tokens"].shape == (4, 32)
    assert not np.array_equal(s0["tokens"], s1["tokens"])
    # labels are next tokens
    b = ds.batch(0)
    full = ds.batch(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], full["tokens"][:, 1:])


def test_data_markov_is_predictable():
    cfg = DataConfig(vocab_size=64, seq_len=64, global_batch=4,
                     temperature=0.2)
    assert loss_floor(cfg) < 0.7 * math.log(64)


def test_prefetcher():
    cfg = DataConfig(vocab_size=32, seq_len=16, global_batch=2)
    ds = SyntheticDataset(cfg)
    pf = Prefetcher(ds, start_step=5)
    step, b = next(pf)
    assert step == 5 and b["tokens"].shape == (2, 16)
    step, _ = next(pf)
    assert step == 6
    pf.close()


# ------------------------------------------------------------- checkpoint


def test_checkpoint_roundtrip_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    for s in (1, 2, 3):
        ck.save(s, jax.tree.map(lambda x: x * s, tree))
    assert ck.list_steps() == [2, 3]  # keep=2 gc'd step 1
    restored, step = ck.restore(tree)
    assert step == 3
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.asarray(tree["a"]) * 3)
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_async(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.ones((128, 128))}
    ck.save(7, tree, blocking=False)
    ck.wait()
    r, s = ck.restore(tree)
    assert s == 7


def test_checkpoint_detects_corruption(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.ones((8,))}
    path = ck.save(1, tree)
    shard = os.path.join(path, "shard_0.npz")
    data = dict(np.load(shard))
    data["w"][0] = 99.0
    np.savez(shard, **data)
    with pytest.raises(IOError):
        ck.restore(tree)


def test_checkpoint_missing_leaf_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"a": jnp.ones(3)})
    with pytest.raises(KeyError):
        ck.restore({"a": jnp.ones(3), "new": jnp.ones(2)})


# ------------------------------------------------------------------ fault


def test_heartbeat_monitor():
    t = [0.0]
    hb = HeartbeatMonitor(4, timeout_s=10, clock=lambda: t[0])
    t[0] = 5.0
    hb.beat(0)
    hb.beat(1)
    t[0] = 12.0
    assert set(hb.dead()) == {2, 3}
    assert set(hb.alive()) == {0, 1}


@given(lost=st.integers(0, 200))
@settings(max_examples=40, deadline=None)
def test_plan_remesh_preserves_model_axis(lost):
    avail = 512 - lost
    plan = plan_remesh((2, 16, 16), ("pod", "data", "model"), avail)
    assert plan.new_shape[-1] == 16
    assert math.prod(plan.new_shape) <= avail
    # greedy: uses at least model*floor(avail/model) - model hosts
    assert math.prod(plan.new_shape) >= (avail // 16) * 16 - 16


def test_plan_remesh_too_few_hosts():
    with pytest.raises(RuntimeError):
        plan_remesh((16, 16), ("data", "model"), 15)


def test_straggler_monitor_flags_outlier():
    sm = StragglerMonitor(warmup=5)
    for _ in range(20):
        assert not sm.observe(1.0 + np.random.default_rng(0).normal() * 0)
    assert sm.observe(10.0)          # 10x step time -> straggler
    assert not sm.observe(1.0)       # healthy again
    assert len(sm.flagged) == 1


def test_checkpoint_cadence_reasonable():
    c = checkpoint_cadence_steps(n_hosts=1024, save_cost_s=60,
                                 step_time_s=10)
    assert 10 <= c <= 10_000


# ------------------------------------------------- gradient compression


def test_int8_ef_compression_unbiased_over_time():
    """Error feedback: accumulated compressed grads converge to the true sum."""
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64,)) * 1e-3)}
    ef = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), g)
    total = jnp.zeros((64,))
    for _ in range(50):
        cg, ef = compress_grads_ef(g, ef)
        total = total + cg["w"]
    true = 50 * g["w"]
    rel = float(jnp.linalg.norm(total - true) / jnp.linalg.norm(true))
    assert rel < 0.02, rel
