"""Multi-device correctness checks — run in a SUBPROCESS with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (tests/test_collectives.py
wrapper).  Never import this module in-process: smoke tests must see 1 device.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
from repro.compat import cost_analysis, set_mesh, shard_map
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core.collectives import (decomposed_psum, hierarchical_psum,  # noqa: E402
                                    int8_psum, multiplane_all_gather,
                                    multiplane_psum, psum_auto)


def check(name, ok, detail=""):
    status = "OK" if ok else "FAIL"
    print(f"[{status}] {name} {detail}")
    if not ok:
        raise SystemExit(f"check failed: {name} {detail}")


def mesh2d(data=4, model=2):
    return jax.make_mesh((data, model), ("data", "model"))


def test_collectives_match_psum():
    mesh = mesh2d()
    x = jnp.arange(8 * 16 * 4, dtype=jnp.float32).reshape(8, 16, 4) / 100.0

    def run(fn):
        return jax.jit(shard_map(
            fn, mesh=mesh, in_specs=P("data", None, None),
            out_specs=P("data", None, None), check_vma=False))(x)

    oracle = run(lambda v: jax.lax.psum(v, "model"))
    for name, fn in [
        ("multiplane_psum", lambda v: multiplane_psum(v, "model", 4,
                                                      split_axis=1)),
        ("decomposed_psum", lambda v: decomposed_psum(v, "model",
                                                      split_axis=1)),
        ("psum_auto", lambda v: psum_auto(v, "model", 4)),
    ]:
        out = run(fn)
        err = float(jnp.abs(out - oracle).max())
        check(name, err < 1e-5, f"err={err:.2e}")

    # hierarchical over both axes == psum over both
    def o2(v):
        return jax.lax.psum(v, ("data", "model"))

    def h2(v):
        return hierarchical_psum(v, ("data", "model"), split_axis=1)

    run2 = lambda fn: jax.jit(shard_map(
        fn, mesh=mesh, in_specs=P(None, None, None),
        out_specs=P(None, None, None), check_vma=False))(x)
    err = float(jnp.abs(run2(h2) - run2(o2)).max())
    check("hierarchical_psum", err < 1e-5, f"err={err:.2e}")

    # int8 compressed: within quantization error of true psum
    o = run(lambda v: jax.lax.psum(v, "model"))
    c = run(lambda v: int8_psum(v, "model"))
    scale = float(jnp.abs(x).max()) / 127.0
    err = float(jnp.abs(o - c).max())
    check("int8_psum", err <= 2 * 2 * scale + 1e-6, f"err={err:.2e}")

    # multiplane all-gather == all-gather
    def ag(v):
        return jax.lax.all_gather(v, "model", axis=1, tiled=True)

    def mag(v):
        return multiplane_all_gather(v, "model", 4, gather_axis=1,
                                     chunk_axis=2)

    ga = run(ag)
    gm = run(mag)
    err = float(jnp.abs(ga - gm).max())
    check("multiplane_all_gather", err < 1e-6, f"err={err:.2e}")


def test_ep_moe_matches_dispatch():
    from repro.configs.base import ModelConfig, MoEConfig, RunConfig
    from repro.models.moe import moe_ffn_dispatch, moe_init
    from repro.models.sharding import MeshPlan
    from repro.models.transformer import DecoderLM

    cfg = ModelConfig(
        arch_id="tiny-moe", family="moe", n_layers=2, d_model=32,
        n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=48,
                      n_shared_experts=1, first_k_dense=1,
                      capacity_factor=8.0),
        param_dtype="float32", activation_dtype="float32")
    mesh = mesh2d(data=4, model=2)
    run = RunConfig(ep_moe=True)
    model = DecoderLM(cfg, run, mesh=mesh, plan=MeshPlan())
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 32))

    moe_p = params["layers"]["moe"]
    moe_p0 = jax.tree.map(lambda l: l[0], moe_p)
    with set_mesh(mesh):
        y_ep, aux_ep = model._moe_ep(moe_p0, x)
    y_ref, aux_ref = moe_ffn_dispatch(moe_p0, x, cfg)
    # EP computes capacity per *local* shard; with capacity_factor=8 no
    # drops occur in either path, so results agree.
    err = float(jnp.abs(y_ep - y_ref).max())
    check("moe_ep_vs_dispatch", err < 1e-4, f"err={err:.2e}")
    # EP computes the load-balance aux per shard and averages (standard
    # Switch/GShard practice) — close to, but not bit-equal with, the
    # global estimator.
    rel = abs(float(aux_ep - aux_ref)) / max(abs(float(aux_ref)), 1e-6)
    check("moe_ep_aux", rel < 0.25, f"rel={rel:.3f}")

    # weight-stationary EP (gather tokens, partial-f GEMM, reduce-scatter)
    run_ws = RunConfig(ep_moe=True, moe_weight_stationary=True)
    model_ws = DecoderLM(cfg, run_ws, mesh=mesh,
                         plan=MeshPlan(moe_ws=True))
    with set_mesh(mesh):
        y_ws, _ = model_ws._moe_ep(moe_p0, x)
    err = float(jnp.abs(y_ws - y_ref).max())
    check("moe_ep_weight_stationary", err < 1e-4, f"err={err:.2e}")

    # TP-f MoE (few-expert path): local dispatch + f-sharded experts
    run_tpf = RunConfig(ep_moe=False, moe_tp_f=True)
    model_tpf = DecoderLM(cfg, run_tpf, mesh=mesh, plan=MeshPlan())
    with set_mesh(mesh):
        y_tpf, _ = model_tpf._moe_tp_f(moe_p0, x)
    err = float(jnp.abs(y_tpf - y_ref).max())
    check("moe_tp_f", err < 1e-4, f"err={err:.2e}")

    # full train CE with mesh (EP active) == without mesh (CE is exact;
    # total loss differs only by the per-shard aux estimator * 0.01)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, 128)
    batch = {"tokens": tokens, "labels": tokens}
    with set_mesh(mesh):
        _, metr_mesh = jax.jit(model.loss)(params, batch)
    model0 = DecoderLM(cfg, RunConfig(ep_moe=False))
    _, metr_ref = jax.jit(model0.loss)(params, batch)
    err = abs(float(metr_mesh["ce"] - metr_ref["ce"]))
    check("moe_ep_model_ce", err < 1e-4,
          f"{float(metr_mesh['ce'])} vs {float(metr_ref['ce'])}")


def test_sharded_trainer_matches_unsharded():
    from repro.configs.base import ModelConfig, RunConfig
    from repro.models.sharding import MeshPlan
    from repro.models.transformer import DecoderLM
    from repro.train.trainer import Trainer

    cfg = ModelConfig(arch_id="t", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
                      param_dtype="float32", activation_dtype="float32")
    run = RunConfig(lr=1e-2, warmup_steps=0, total_steps=10)
    tokens = np.asarray(
        jax.random.randint(jax.random.PRNGKey(3), (8, 16), 0, 128))
    batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(tokens)}

    t0 = Trainer(DecoderLM(cfg, run), run)
    s0 = t0.init_state(jax.random.PRNGKey(0))
    s0b, m0 = t0.make_train_step()(s0, batch)

    mesh = mesh2d()
    model = DecoderLM(cfg, run, mesh=mesh, plan=MeshPlan())
    t1 = Trainer(model, run, mesh=mesh, plan=MeshPlan())
    s1 = t1.init_state(jax.random.PRNGKey(0))
    s1 = jax.device_put(s1, t1.state_shardings())
    step = t1.make_train_step()
    s1b, m1 = step(s1, batch)
    err = abs(float(m0["loss"]) - float(m1["loss"]))
    check("sharded_loss_matches", err < 1e-5, f"err={err:.2e}")
    for a, b in zip(jax.tree.leaves(s0b.params), jax.tree.leaves(s1b.params)):
        if not np.allclose(np.asarray(a), np.asarray(b), atol=1e-5):
            check("sharded_params_match", False,
                  f"max {np.abs(np.asarray(a) - np.asarray(b)).max()}")
    check("sharded_params_match", True)

    # elastic: restore this sharded state onto a DIFFERENT mesh shape
    import tempfile
    from repro.train.checkpoint import Checkpointer
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(1, s1b)
        mesh2 = jax.make_mesh((2, 2), ("data", "model"))
        model2 = DecoderLM(cfg, run, mesh=mesh2, plan=MeshPlan())
        t2 = Trainer(model2, run, mesh=mesh2, plan=MeshPlan())
        template = jax.eval_shape(lambda: t2.init_state(jax.random.PRNGKey(0)))
        restored, st = ck.restore(template, shardings=t2.state_shardings())
        s2b, m2 = t2.make_train_step()(restored, batch)
        check("elastic_resharded_step",
              abs(float(m2["loss"]) - 0.0) >= 0.0, f"loss={float(m2['loss'])}")
        # same numbers as continuing on the original mesh
        s1c, m1c = step(s1b, batch)
        err = abs(float(m2["loss"]) - float(m1c["loss"]))
        check("elastic_loss_matches", err < 1e-5, f"err={err:.2e}")


def test_mini_dryrun_multipod():
    """Tiny end-to-end dry-run: lower+compile a sharded train step on a
    (2,2,2) pod mesh with ShapeDtypeStructs only."""
    from repro.configs.base import ModelConfig, RunConfig
    from repro.models.sharding import MeshPlan, MULTI_POD
    from repro.models.transformer import DecoderLM
    from repro.train.trainer import Trainer

    cfg = ModelConfig(arch_id="t", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    plan = MULTI_POD
    run = RunConfig()
    model = DecoderLM(cfg, run, mesh=mesh, plan=plan)
    trainer = Trainer(model, run, mesh=mesh, plan=plan)
    state_shapes = jax.eval_shape(
        lambda: trainer.init_state(jax.random.PRNGKey(0)))
    batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    step = trainer.make_train_step()
    lowered = step.lower(state_shapes, batch)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = cost_analysis(compiled)
    check("mini_dryrun_compiles", True,
          f"flops={cost.get('flops', 0):.2e}")
    # collectives exist only POST-partitioning: parse compiled HLO, not the
    # lowered (pre-SPMD) module — same source the roofline parser uses.
    hlo = compiled.as_text()
    n_coll = sum(hlo.count(f" {op}") for op in
                 ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute"))
    check("mini_dryrun_has_collectives", n_coll > 0, f"{n_coll} collectives")


if __name__ == "__main__":
    print(f"devices: {jax.device_count()}")
    assert jax.device_count() >= 8, "subprocess must force 8 host devices"
    test_collectives_match_psum()
    test_ep_moe_matches_dispatch()
    test_sharded_trainer_matches_unsharded()
    test_mini_dryrun_multipod()
    print("ALL MULTIDEVICE CHECKS PASSED")
