"""Scan-aware HLO accounting (launch/hloparse.py) — validated against
known-FLOPs programs.  These run on the default 1-device CPU backend (no
sharding needed for the loop-expansion logic)."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hloparse import HloModule, analyze


def compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_plain_matmul_flops_exact():
    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    r = analyze(compile_text(lambda x, y: x @ y, a, b))
    assert r["flops"] == pytest.approx(2 * 256 * 512 * 128, rel=0.01)


def test_scan_flops_expand_trip_count():
    """The while-body-once fix: a scan of L matmuls counts L x."""
    L, n = 25, 128

    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c / 100), None
        r, _ = jax.lax.scan(body, x, None, length=L)
        return r

    r = analyze(compile_text(f, jax.ShapeDtypeStruct((n, n), jnp.float32)))
    assert r["flops"] == pytest.approx(L * 2 * n**3, rel=0.01)


def test_nested_scan_flops_multiply():
    L_out, L_in, n = 4, 6, 64

    def f(x):
        def inner(c, _):
            return jnp.tanh(c @ c / 100), None

        def outer(c, _):
            c, _ = jax.lax.scan(inner, c, None, length=L_in)
            return c, None

        r, _ = jax.lax.scan(outer, x, None, length=L_out)
        return r

    r = analyze(compile_text(f, jax.ShapeDtypeStruct((n, n), jnp.float32)))
    assert r["flops"] == pytest.approx(L_out * L_in * 2 * n**3, rel=0.01)


def test_scan_hbm_bytes_not_charged_full_stack():
    """Consuming stacked xs per-iteration must charge slice bytes, not the
    whole (L, ...) stack per iteration."""
    L, n = 32, 256

    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        r, _ = jax.lax.scan(body, x, ws)
        return r

    x = jax.ShapeDtypeStruct((n, n), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, n, n), jnp.float32)
    r = analyze(compile_text(f, x, ws))
    stack_bytes = L * n * n * 4
    # each iteration touches the slice a handful of times (slice-read, dot
    # operands+output, tanh in/out ~ 8 slice-sized buffers) — but NOT ~L x
    # the full stack (which would be 32 stacks here, 96 with operands)
    assert r["hbm_bytes"] < 10 * stack_bytes, \
        f"{r['hbm_bytes'] / stack_bytes:.1f} stacks charged"
    assert r["hbm_bytes"] > 1.5 * stack_bytes


def test_decode_style_cache_update_not_quadratic():
    """A scan that dynamic-update-slices one row per step into a carried
    buffer must charge ~rows, not ~L x full-buffer."""
    L, n = 64, 512

    def f(buf, xs):
        def body(b, i):
            b = jax.lax.dynamic_update_slice_in_dim(
                b, xs[i][None], i, axis=0)
            return b, None
        b, _ = jax.lax.scan(body, buf, jnp.arange(L))
        return b

    buf = jax.ShapeDtypeStruct((L, n), jnp.bfloat16)
    xs = jax.ShapeDtypeStruct((L, n), jnp.bfloat16)
    r = analyze(compile_text(f, buf, xs))
    buf_bytes = L * n * 2
    assert r["hbm_bytes"] < 12 * buf_bytes, \
        f"{r['hbm_bytes'] / buf_bytes:.1f} buffers charged"


def test_collectives_empty_on_single_device():
    r = analyze(compile_text(lambda x: x * 2,
                             jax.ShapeDtypeStruct((8, 8), jnp.float32)))
    assert r["collectives"]["total_count"] == 0


def test_module_parses_entry_and_computations():
    def f(x):
        def body(c, _):
            return c @ c, None
        r, _ = jax.lax.scan(body, x, None, length=3)
        return r

    mod = HloModule(compile_text(f, jax.ShapeDtypeStruct((16, 16),
                                                         jnp.float32)))
    assert mod.entry is not None
    assert len(mod.computations) >= 3
    whiles = [i for c in mod.computations.values() for i in c.instrs
              if i.op == "while"]
    assert len(whiles) >= 1
