"""Exact reproduction of paper Table 2 (the paper's headline experiment)."""

import pytest

from repro.core import table2, PAPER_TABLE2, table2_topologies, DEFAULT_SWITCH


@pytest.fixture(scope="module")
def reports():
    return table2()


def test_row_count(reports):
    assert len(reports) == len(PAPER_TABLE2) == 8


@pytest.mark.parametrize("idx", range(8))
def test_table2_row(reports, idx):
    rep = reports[idx]
    name, n, ns, no, per_nic = PAPER_TABLE2[idx]
    assert rep.name == name
    assert rep.n_nics == n, f"{name}: N {rep.n_nics} != {n}"
    assert rep.n_switches == ns, f"{name}: N_s {rep.n_switches} != {ns}"
    assert rep.n_optics == no, f"{name}: N_o {rep.n_optics} != {no}"
    # cost/NIC matches the paper to the dollar (paper's FT3 row recomputed
    # from the corrected 393,216 optic count -> $10,325 vs printed $10,323).
    assert abs(rep.per_nic_usd - per_nic) < 1.0, (
        f"{name}: ${rep.per_nic_usd:.1f} != ${per_nic}")


def test_mphx_beats_mpft_by_28_percent(reports):
    """Paper §4: 'Compared to the multi-plane Fat-Tree network, the average
    cost per NIC is reduced by 28.0%.'"""
    mpft = next(r for r in reports if "2-layer Fat-Tree" in r.name)
    mphx8 = next(r for r in reports if "8-Plane 1D HyperX" in r.name)
    reduction = 1.0 - mphx8.per_nic_usd / mpft.per_nic_usd
    assert abs(reduction - 0.280) < 0.005


def test_diameters():
    """§1/§4: MPHX has the smallest diameter of the compared topologies."""
    topos = {t.name: t for t in table2_topologies()}
    assert topos["3-layer Fat-Tree"].diameter == 6
    assert topos["8-Plane 2-layer Fat-Tree"].diameter == 4
    assert topos["Dragonfly"].diameter == 5
    assert topos["Dragonfly+"].diameter == 6
    assert topos["1-Plane 3D HyperX"].diameter == 5
    assert topos["2-Plane 2D HyperX"].diameter == 4
    assert topos["4-Plane 2D HyperX"].diameter == 4
    assert topos["8-Plane 1D HyperX"].diameter == 3
    d_mphx8 = topos["8-Plane 1D HyperX"].diameter
    assert all(d_mphx8 <= t.diameter for t in topos.values())


def test_all_rows_feasible():
    for t in table2_topologies():
        t.validate(DEFAULT_SWITCH)


def test_mphx_4plane_trunk_radix_exactly_256():
    """Table 2 note: MPHX(4,86,86,9) dim-2 keeps 85 links -> radix 86+85+85
    uses the 256x400G breakout exactly."""
    t = next(t for t in table2_topologies() if "4-Plane" in t.name)
    assert t.radix_used == 256
    assert DEFAULT_SWITCH.radix_at(t.port_gbps) == 256


def test_copper_access_amplifies_advantage():
    """§4: with copper NIC-access links MPHX cost-effectiveness improves
    further relative to multi-plane Fat-Tree."""
    optical = {r.name: r.per_nic_usd for r in table2()}
    copper = {r.name: r.per_nic_usd for r in table2(access_copper=True)}
    mphx, mpft = "8-Plane 1D HyperX", "8-Plane 2-layer Fat-Tree"
    red_opt = 1 - optical[mphx] / optical[mpft]
    red_cu = 1 - copper[mphx] / copper[mpft]
    assert red_cu > red_opt


def test_graph_diameter_matches_analytic():
    """Explicit per-plane graphs agree with the closed-form diameters."""
    from repro.core import table2_mphx_rows

    for t in table2_mphx_rows():
        if t.switches_per_plane > 2000:
            continue  # keep the test fast; BFS on 774/256/1681 nodes is fine
        g = t.build_graph()
        assert g.switch_diameter(sample=32) == t.diameter - 2
