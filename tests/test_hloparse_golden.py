"""Golden-fixture regression tests for the HLO collective parser.

The fixtures under ``tests/fixtures/hlo/`` are small post-partitioning
HLO programs in XLA's text format (dense DP all-reduce, TP
all-gather/reduce-scatter, MoE all-to-all, and the empty
``replica_groups={}`` all-devices form).  Every byte count below is
computed by hand from the fixture shapes — these tests pin the exact
wire-byte accounting the dry-run and co-sim layers consume, plus the
``_group_size`` fix (nested-brace group lists used to be cut off at the
first ``}``, and empty group lists silently parsed as size 1).
"""

import os

import pytest

from repro.launch.hloparse import (HloModule, _group_size,
                                   module_device_count,
                                   parse_collectives, parse_replica_groups)

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "hlo")


def fixture(name: str) -> str:
    with open(os.path.join(FIXTURES, name)) as f:
        return f.read()


# ---------------------------------------------------------------------------
# parse_replica_groups — the regex-bug regression surface
# ---------------------------------------------------------------------------


def test_nested_groups_all_parsed():
    # the old _GROUPS_LIST_RE stopped at the first '}' and saw one group
    assert parse_replica_groups(
        "replica_groups={{0,2,4,6},{1,3,5,7}},") == [4, 4]


def test_single_full_group():
    assert parse_replica_groups(
        "replica_groups={{0,1,2,3,4,5,6,7}},") == [8]


def test_empty_groups_use_module_default():
    # replica_groups={} means ALL participants — the old parser returned 1
    assert parse_replica_groups("replica_groups={},", 32) == [32]


def test_iota_v2_format():
    assert parse_replica_groups("replica_groups=[2,4]<=[8],") == [4, 4]


def test_flat_single_group_form():
    assert parse_replica_groups("replica_groups={0,1,2},") == [3]


def test_no_groups_attribute_defaults():
    assert parse_replica_groups("no groups here", 16) == [16]


def test_group_size_is_first_group():
    assert _group_size("replica_groups={{0,1,2,3},{4,5,6,7}},") == 4
    assert _group_size("replica_groups={},", 12) == 12


def test_module_device_count_partitions_times_replicas():
    assert module_device_count(
        "HloModule m, num_partitions=4, replica_count=2\n") == 8
    assert module_device_count("HloModule m, num_partitions=512\n") == 512
    assert module_device_count("HloModule m, is_scheduled=true\n") == 1


# ---------------------------------------------------------------------------
# dense DP all-reduce fixture (nested groups + empty groups, num_partitions=8)
# ---------------------------------------------------------------------------


def test_dense_dp_counts_and_kinds():
    c = parse_collectives(fixture("dense_dp_allreduce.txt"))
    assert c["all-reduce"]["count"] == 2
    assert c["total_count"] == 2
    for kind in ("all-gather", "reduce-scatter", "all-to-all",
                 "collective-permute"):
        assert c[kind]["count"] == 0


def test_dense_dp_group_sizes():
    c = parse_collectives(fixture("dense_dp_allreduce.txt"))
    # grad AR over 2 groups of 4; the {} AR spans all 8 partitions
    assert set(c["all-reduce"]["by_group"]) == {"4", "8"}


def test_dense_dp_exact_wire_bytes():
    c = parse_collectives(fixture("dense_dp_allreduce.txt"))
    grad_payload = 1024 * 512 * 4            # f32[1024,512]
    full_payload = 256 * 4                   # f32[256]
    assert c["all-reduce"]["payload_bytes"] == grad_payload + full_payload
    # ring AR wire: 2(g-1)/g * payload
    assert c["all-reduce"]["by_group"]["4"] == \
        pytest.approx(2 * 3 / 4 * grad_payload)
    assert c["all-reduce"]["by_group"]["8"] == \
        pytest.approx(2 * 7 / 8 * full_payload)
    assert c["total_wire_bytes"] == pytest.approx(
        2 * 3 / 4 * grad_payload + 2 * 7 / 8 * full_payload)


def test_dense_dp_module_walker_agrees_with_flat_parser():
    text = fixture("dense_dp_allreduce.txt")
    flat = parse_collectives(text)
    walked = HloModule(text).total_collectives()
    assert walked["total_count"] == flat["total_count"]
    assert walked["total_wire_bytes"] == \
        pytest.approx(flat["total_wire_bytes"])
    assert walked["all-reduce"]["by_group"].keys() == \
        flat["all-reduce"]["by_group"].keys()


# ---------------------------------------------------------------------------
# TP all-gather + reduce-scatter fixture (iota + nested formats)
# ---------------------------------------------------------------------------


def test_tp_kinds_and_groups():
    c = parse_collectives(fixture("tp_allgather_rs.txt"))
    assert c["all-gather"]["count"] == 1
    assert c["reduce-scatter"]["count"] == 1
    assert c["all-reduce"]["count"] == 0
    assert set(c["all-gather"]["by_group"]) == {"4"}      # [2,4]<=[8]
    assert set(c["reduce-scatter"]["by_group"]) == {"4"}  # {{0..3},{4..7}}


def test_tp_exact_wire_bytes():
    c = parse_collectives(fixture("tp_allgather_rs.txt"))
    ag_out = 4096 * 1024 * 2                 # bf16[4096,1024] output
    rs_out = 1024 * 1024 * 2                 # bf16[1024,1024] output
    assert c["all-gather"]["payload_bytes"] == ag_out
    assert c["reduce-scatter"]["payload_bytes"] == rs_out
    assert c["all-gather"]["wire_bytes"] == pytest.approx(3 / 4 * ag_out)
    # RS wire: (g-1) * output shard == (g-1)/g * input
    assert c["reduce-scatter"]["wire_bytes"] == pytest.approx(3 * rs_out)
    # the two are inverse ops over the same tensor: equal wire traffic
    assert c["all-gather"]["wire_bytes"] == \
        pytest.approx(c["reduce-scatter"]["wire_bytes"])


# ---------------------------------------------------------------------------
# MoE all-to-all fixture (16-wide EP group + a pipeline permute)
# ---------------------------------------------------------------------------


def test_moe_alltoall_kind_and_group():
    c = parse_collectives(fixture("moe_alltoall.txt"))
    assert c["all-to-all"]["count"] == 1
    assert set(c["all-to-all"]["by_group"]) == {"16"}
    assert c["collective-permute"]["count"] == 1


def test_moe_alltoall_exact_wire_bytes():
    c = parse_collectives(fixture("moe_alltoall.txt"))
    a2a_payload = 16 * 32 * 512 * 2          # bf16[16,32,512]
    perm_payload = 8 * 128 * 4               # f32[8,128]
    assert c["all-to-all"]["payload_bytes"] == a2a_payload
    assert c["all-to-all"]["wire_bytes"] == \
        pytest.approx(15 / 16 * a2a_payload)
    assert c["collective-permute"]["wire_bytes"] == perm_payload
    assert c["total_wire_bytes"] == \
        pytest.approx(15 / 16 * a2a_payload + perm_payload)


# ---------------------------------------------------------------------------
# empty replica_groups={} fixture (num_partitions=4 x replica_count=2)
# ---------------------------------------------------------------------------


def test_empty_groups_span_all_devices():
    text = fixture("empty_groups_allreduce.txt")
    assert module_device_count(text) == 8
    c = parse_collectives(text)
    # the whole point of the fix: group is 8, not 1 (which would zero wire)
    assert set(c["all-reduce"]["by_group"]) == {"8"}
    payload = 256 * 256 * 4
    assert c["all-reduce"]["wire_bytes"] == pytest.approx(2 * 7 / 8 * payload)
    assert c["all-reduce"]["wire_bytes"] > 0


def test_empty_groups_module_walker_sees_device_count():
    mod = HloModule(fixture("empty_groups_allreduce.txt"))
    assert mod.device_count == 8
    walked = mod.total_collectives()
    assert set(walked["all-reduce"]["by_group"]) == {"8"}


def test_dryrun_reexports_parser():
    # back-compat: the dry-run module re-exports the moved parser
    import importlib
    spec = importlib.util.find_spec("repro.launch.dryrun")
    assert spec is not None
    src = open(spec.origin).read()
    assert "from repro.launch.hloparse import" in src
    assert "parse_collectives" in src
