"""Flow-level simulator (repro.sim): cross-validation and semantics.

The acceptance contract of PR 3:

* steady-state simulator loads match the analytic engines to 1e-6 on
  small MPHX (array engine) AND a graph-engine baseline;
* a single uncontended flow's FCT matches the closed-form
  bytes/bandwidth + latency bound;
* spraying reproduces ``planes.spray_completion_time``;
* failure injection masks edges/switches, re-routes survivors, and the
  CLI produces schema-v3 artifacts with explicit skip records.
"""

import json
import math

import numpy as np
import pytest

from repro.experiments.artifacts import SCHEMA_VERSION
from repro.core.dragonfly import Dragonfly
from repro.core.fattree import ThreeTierFatTree
from repro.core.hyperx import MPHX
from repro.core.netsim import (DEFAULT_NET, gbps_to_Bps, latency_under_load,
                               load_sweep, make_router, pattern_throughput)
from repro.core.planes import SprayConfig, spray_completion_time, split_chunks
from repro.core.routing_graph import GraphRouter, graph_uniform_demands
from repro.core.routing_vec import (VectorizedHyperXRouter, hotspot_demands,
                                    neighbor_shift_demands, uniform_demands)
from repro.sim import (FailureSpec, FlowIncidence, FlowSpec, degrade_graph,
                       degraded_router, failure_throughput, flow_incidence,
                       max_min_rates, parse_failure_spec,
                       plane_capacity_factor, recovery_curve,
                       simulate_collective, simulate_demands, simulate_flows,
                       simulate_sprayed)
from repro.sim.events import path_latency, simulate_incidence
from repro.sim.spray import _per_plane_bytes

MPHX_SMALL = MPHX(n=2, p=8, dims=(8, 8))
DF_SMALL = Dragonfly(p=2, a=4, h=2, groups=9, name="Dragonfly (small)")


# ------------------------------------------------- steady-state agreement ----


@pytest.mark.parametrize("mode", ["minimal", "valiant"])
@pytest.mark.parametrize("builder", [uniform_demands, neighbor_shift_demands,
                                     hotspot_demands])
def test_steady_state_matches_array_engine(mode, builder):
    """Sim load accounting == array-engine loads (utilizations to 1e-6)."""
    router = VectorizedHyperXRouter(MPHX_SMALL, backend="numpy")
    dem = builder(MPHX_SMALL, 1600.0)
    ll = router.route(dem, mode)
    inc = flow_incidence(router, dem, mode)
    diff = np.abs(inc.utilization(dem.gbps) - ll.utilization_array()).max()
    assert diff < 1e-6


@pytest.mark.parametrize("topo", [DF_SMALL,
                                  ThreeTierFatTree(radix=8, nics=128,
                                                   name="FT3 (small)")])
def test_steady_state_matches_graph_engine(topo):
    router = GraphRouter(topo, backend="numpy")
    dem = graph_uniform_demands(topo, 1600.0)
    ll = router.route(dem, "minimal")
    inc = flow_incidence(router, dem, "minimal")
    diff = np.abs(inc.utilization(dem.gbps) - ll.utilization_array()).max()
    assert diff < 1e-6


def test_pattern_throughput_simulate_cross_check():
    rep = pattern_throughput(MPHX_SMALL,
                             uniform_demands(MPHX_SMALL, 1600.0),
                             mode="minimal", backend="numpy", simulate=True)
    assert rep["sim_max_abs_util_diff"] < 1e-6
    assert rep["max_util_sim"] == pytest.approx(rep["max_util"], abs=1e-6)


def test_simulate_flags_reject_adaptive_up_front():
    """simulate=True with the (default) adaptive mode fails with a clear
    error instead of deep inside incidence extraction."""
    dem = uniform_demands(MPHX_SMALL, 100.0)
    with pytest.raises(ValueError, match="static path spread"):
        pattern_throughput(MPHX_SMALL, dem, simulate=True)
    with pytest.raises(ValueError, match="static path spread"):
        load_sweep(MPHX_SMALL, uniform_demands, mode="adaptive",
                   load_fractions=(0.5,), simulate=True)


def test_incidence_rejects_adaptive():
    router = make_router(MPHX_SMALL, backend="numpy")
    with pytest.raises(ValueError, match="adaptive"):
        flow_incidence(router, uniform_demands(MPHX_SMALL, 100.0),
                       "adaptive")
    groute = GraphRouter(DF_SMALL, backend="numpy")
    with pytest.raises(ValueError, match="minimal"):
        flow_incidence(groute, graph_uniform_demands(DF_SMALL, 100.0),
                       "valiant")


def test_incidence_hop_counts():
    """sum of fracs per flow == expected switch hops (minimal ECMP)."""
    router = VectorizedHyperXRouter(MPHX_SMALL, backend="numpy")
    dem = neighbor_shift_demands(MPHX_SMALL, 100.0)   # 1 mismatched dim
    inc = flow_incidence(router, dem, "minimal")
    assert np.allclose(inc.switch_hops(), 1.0)
    dem2 = uniform_demands(MPHX_SMALL, 100.0)
    inc2 = flow_incidence(router, dem2, "minimal")
    # mean over all (distinct) pairs = avg_hops - 2 rescaled to exclude
    # same-switch pairs: S/(S-1) * sum (d-1)/d
    S = MPHX_SMALL.switches_per_plane
    expect = S / (S - 1) * sum((d - 1) / d for d in MPHX_SMALL.dims)
    assert inc2.switch_hops().mean() == pytest.approx(expect, rel=1e-12)


# ----------------------------------------------------------- water-filling ----


def _toy_incidence(entries, n_flows, capacity):
    flow = np.array([e[0] for e in entries], dtype=np.int64)
    edge = np.array([e[1] for e in entries], dtype=np.int64)
    frac = np.array([e[2] for e in entries], dtype=np.float64)
    return FlowIncidence(flow, edge, frac, n_flows,
                         np.asarray(capacity, dtype=np.float64))


def test_max_min_two_flows_share_one_link():
    inc = _toy_incidence([(0, 0, 1.0), (1, 0, 1.0)], 2, [10.0])
    rates = max_min_rates(inc, np.array([100.0, 100.0]))
    assert rates == pytest.approx([5.0, 5.0])


def test_max_min_progressive_filling():
    """Classic 3-flow example: flows A,B share link 1 (cap 10); B,C also
    cross link 2 (cap 16).  A=B=5 on the first bottleneck, C fills the
    rest of link 2 -> 11."""
    inc = _toy_incidence([(0, 0, 1.0), (1, 0, 1.0),
                          (1, 1, 1.0), (2, 1, 1.0)], 3, [10.0, 16.0])
    rates = max_min_rates(inc, np.full(3, 100.0))
    assert rates == pytest.approx([5.0, 5.0, 11.0])


def test_max_min_respects_demand_caps():
    inc = _toy_incidence([(0, 0, 1.0), (1, 0, 1.0)], 2, [10.0])
    rates = max_min_rates(inc, np.array([2.0, 100.0]))
    # flow 0 capped at 2, flow 1 takes the remaining 8
    assert rates == pytest.approx([2.0, 8.0])


def test_max_min_feasible_caps_returned_exactly():
    router = make_router(MPHX_SMALL, backend="numpy")
    dem = uniform_demands(MPHX_SMALL, 1600.0)
    inc = flow_incidence(router, dem, "minimal")
    caps = np.asarray(dem.gbps) * 0.5     # comfortably below saturation
    rates = max_min_rates(inc, caps)
    assert np.abs(rates - caps).max() < 1e-9


def test_max_min_fractional_incidence():
    """ECMP split: a flow crossing an edge with frac 0.5 consumes half
    its rate there."""
    inc = _toy_incidence([(0, 0, 0.5), (0, 1, 0.5)], 1, [10.0, 10.0])
    rates = max_min_rates(inc, np.array([100.0]))
    assert rates == pytest.approx([20.0])


def test_max_min_jax_backend_matches_numpy():
    jax = pytest.importorskip("jax")
    if not jax.config.jax_enable_x64:
        pytest.skip("jax without x64: float32 accumulators")
    router = make_router(MPHX_SMALL, backend="numpy")
    dem = neighbor_shift_demands(MPHX_SMALL, 1600.0)
    inc = flow_incidence(router, dem, "minimal")
    caps = np.full(inc.n_flows, 2000.0)
    r_np = max_min_rates(inc, caps, backend="numpy")
    r_jx = max_min_rates(inc, caps, backend="jax")
    assert np.abs(r_np - r_jx).max() < 1e-9


# ------------------------------------------------------------- event loop ----


def test_single_flow_fct_closed_form():
    """Uncontended FCT == bytes / min(cap, bottleneck) + path alpha."""
    router = make_router(MPHX_SMALL, backend="numpy")
    res = simulate_flows(router, [FlowSpec(0, 5, 1 << 24)])
    inc = res.incidence
    rate = min(MPHX_SMALL.port_gbps, float(inc.bottleneck_gbps()[0]))
    closed = (1 << 24) / gbps_to_Bps(rate) + float(path_latency(inc)[0])
    assert res.fct_s[0] == pytest.approx(closed, rel=1e-12)
    assert not res.stalled.any()


def test_fair_sharing_doubles_fct():
    """Two identical flows forced over the same single-path route finish
    in twice the solo time (minus nothing: serial fair share)."""
    router = make_router(MPHX_SMALL, backend="numpy")
    solo = simulate_flows(router, [FlowSpec(0, 1, 1 << 24)],
                          rate_cap_gbps=1600.0)
    both = simulate_flows(router, [FlowSpec(0, 1, 1 << 24),
                                   FlowSpec(0, 1, 1 << 24)],
                          rate_cap_gbps=1600.0)
    t_solo = float(solo.transfer_s()[0])
    assert both.transfer_s() == pytest.approx([2 * t_solo, 2 * t_solo],
                                              rel=1e-9)


def test_staggered_arrivals():
    """A flow arriving halfway through another gets the leftover share;
    total bytes conserve on every edge."""
    router = make_router(MPHX_SMALL, backend="numpy")
    size = 1 << 24
    t_half = size / gbps_to_Bps(800.0) / 2
    res = simulate_flows(router, [FlowSpec(0, 1, size),
                                  FlowSpec(0, 1, size, start_s=t_half)],
                         rate_cap_gbps=800.0)
    assert res.finish_s[1] > res.finish_s[0]
    # conservation: edge bytes == sum over flows of bytes * frac
    expect = res.incidence.loads(np.full(2, size))  # "rate"=bytes trick
    assert np.allclose(res.edge_bytes, expect, rtol=1e-9)


def test_simulate_demands_row_keys_and_delivered():
    router = make_router(MPHX_SMALL, backend="numpy")
    row = simulate_demands(router, neighbor_shift_demands(MPHX_SMALL, 800.0),
                           200e-6)
    assert {"sim_flows", "sim_delivered_fraction", "fct_p50_us",
            "fct_p99_us", "slowdown_mean", "sim_stalled"} <= set(row)
    # shift @ 0.5 load saturates the single minimal path 4x over
    assert row["sim_delivered_fraction"] == pytest.approx(0.25, rel=1e-6)
    assert row["sim_stalled"] == 0


def test_load_sweep_simulate_columns():
    rows = load_sweep(MPHX_SMALL, uniform_demands, mode="minimal",
                      load_fractions=(0.5, 1.0), backend="numpy",
                      simulate=True, flow_time_s=100e-6)
    for r in rows:
        assert "fct_p50_us" in r and "sim_delivered_fraction" in r
        assert r["sim_delivered_fraction"] <= 1.0 + 1e-9
    # uncontended level: slowdown exactly 1
    assert rows[0]["slowdown_mean"] == pytest.approx(1.0, abs=1e-6)


# ------------------------------------------------- latency satellite fix ----


def test_latency_under_load_uses_router_hops():
    """Graph-engine router supplies measured mean hops: on a fat-tree the
    heuristic avg_hops-2 over-counts (it was tuned for MPHX)."""
    ft = ThreeTierFatTree(radix=8, nics=128, name="FT3 (small)")
    router = GraphRouter(ft, backend="numpy")
    with_router = latency_under_load(ft, 0.5, router=router)
    heuristic = latency_under_load(ft, 0.5)
    assert with_router != heuristic
    measured = router.mean_switch_hops()
    base = latency_under_load(ft, 0.0, router=router)
    expect = base + measured * DEFAULT_NET.t_switch * 0.5 / 0.5
    assert with_router == pytest.approx(expect, rel=1e-12)


def test_mean_switch_hops_consistent_across_engines():
    """On untrunked MPHX the graph engine's NIC-weighted measured mean
    equals the array engine's closed form."""
    arr = VectorizedHyperXRouter(MPHX_SMALL)
    gr = GraphRouter(MPHX_SMALL, backend="numpy")
    assert gr.mean_switch_hops() == pytest.approx(arr.mean_switch_hops(),
                                                  rel=1e-12)
    assert arr.mean_switch_hops() == pytest.approx(
        MPHX_SMALL.avg_hops() - 2.0, rel=1e-12)


# ---------------------------------------------------------------- spraying ----


def test_per_plane_bytes_matches_split_chunks():
    cfg = SprayConfig(n_planes=4)
    sizes = [0, 1, cfg.chunk_bytes, cfg.chunk_bytes + 1,
             5 * cfg.chunk_bytes + 17, 1 << 24]
    got = _per_plane_bytes(np.array(sizes, dtype=np.float64), cfg)
    for i, s in enumerate(sizes):
        assert got[i].tolist() == pytest.approx(split_chunks(s, cfg))


def test_spray_sim_matches_planes_closed_form():
    cfg = SprayConfig(n_planes=2)
    size = 10 << 20
    res = simulate_sprayed(MPHX_SMALL, [FlowSpec(0, 5, size)], cfg=cfg)
    expect = spray_completion_time(size, MPHX_SMALL.nic_bw_gbps, cfg)
    assert (res.completion_s[0] - res.latency_s[0]
            == pytest.approx(expect, rel=1e-12))


def test_spray_sim_skewed_plane():
    cfg = SprayConfig(n_planes=2)
    size = 10 << 20
    skew = [1.0, 1.5]
    res = simulate_sprayed(MPHX_SMALL, [FlowSpec(0, 5, size)], cfg=cfg,
                           plane_skew=skew)
    expect = spray_completion_time(size, MPHX_SMALL.nic_bw_gbps, cfg, skew)
    assert (res.completion_s[0] - res.latency_s[0]
            == pytest.approx(expect, rel=1e-12))


def test_spray_sim_dead_plane_resprays():
    """One dead plane: bytes re-spray over survivors (chunk overhead off
    so the re-spray accounting matches planes.py exactly)."""
    cfg = SprayConfig(n_planes=2, per_chunk_overhead_s=0.0)
    size = 10 << 20
    skew = [1.0, math.inf]
    res = simulate_sprayed(MPHX_SMALL, [FlowSpec(0, 5, size)], cfg=cfg,
                           plane_skew=skew)
    expect = spray_completion_time(size, MPHX_SMALL.nic_bw_gbps, cfg, skew)
    assert (res.completion_s[0] - res.latency_s[0]
            == pytest.approx(expect, rel=1e-12))
    # dead plane carried nothing
    assert res.per_plane_bytes[0, 1] == 0.0
    assert res.per_plane_bytes[0, 0] == size


# ------------------------------------------------------------- collectives ----


def test_collective_sim_brackets_analytic():
    """Measured collectives land within a small factor of the alpha-beta
    closed forms (>= 1x: the fabric cannot beat wire speed + rounding)."""
    router = make_router(MPHX_SMALL, backend="numpy")
    for kind in ("allreduce_ring", "allgather_ring", "alltoall"):
        row = simulate_collective(MPHX_SMALL, kind, 1 << 24, router=router)
        assert row["measured_us"] > 0
        ratio = row["measured_over_analytic"]
        assert 0.9 <= ratio <= 5.0, (kind, ratio)


def test_collective_sim_unknown_kind():
    with pytest.raises(ValueError, match="unknown collective"):
        simulate_collective(MPHX_SMALL, "bcast", 1 << 20)


# ----------------------------------------------------------------- failures ----


def test_parse_failure_spec():
    s = parse_failure_spec("link:0.05,plane:1,seed:3")
    assert s == FailureSpec(link_fraction=0.05, planes_down=1, seed=3)
    assert s.label() == "link:0.05,plane:1"
    assert parse_failure_spec("switch:0.1").switch_fraction == 0.1
    with pytest.raises(ValueError, match="unknown failure key"):
        parse_failure_spec("nic:0.5")
    with pytest.raises(ValueError, match="key:value"):
        parse_failure_spec("link=0.5")
    with pytest.raises(ValueError):
        FailureSpec(link_fraction=1.5)


def test_degrade_graph_removes_links_deterministically():
    g = DF_SMALL.build_graph()
    spec = FailureSpec(link_fraction=0.2, seed=7)
    d1 = degrade_graph(g, spec)
    d2 = degrade_graph(g, spec)
    assert d1.failed_links == d2.failed_links > 0
    assert d1.graph.total_links() == pytest.approx(
        g.total_links() - d1.failed_links)
    # node ids preserved under link-only failures
    assert np.array_equal(d1.node_map, np.arange(g.n_switches))


def test_degrade_graph_switch_failures_compact():
    g = DF_SMALL.build_graph()
    d = degrade_graph(g, FailureSpec(switch_fraction=0.2, seed=1))
    assert len(d.failed_switches) > 0
    assert d.graph.n_switches == g.n_switches - len(d.failed_switches)
    assert len(d.graph.nic_nodes) < len(g.nic_nodes)
    # surviving ids are a clean renumbering
    alive = d.node_map[d.node_map >= 0]
    assert np.array_equal(np.sort(alive), np.arange(d.graph.n_switches))


def test_degraded_router_reroutes():
    spec = FailureSpec(link_fraction=0.1, seed=0)
    router, dg = degraded_router(DF_SMALL, spec)
    dem = graph_uniform_demands(DF_SMALL, 800.0, graph=dg.graph)
    ll = router.route(dem, "adaptive")
    assert np.isfinite(ll.max_utilization())
    # fewer links, same demand -> at least as hot
    healthy = GraphRouter(DF_SMALL, backend="numpy").route(
        graph_uniform_demands(DF_SMALL, 800.0), "adaptive")
    assert ll.max_utilization() >= healthy.max_utilization() - 1e-9


def test_failure_throughput_and_recovery_curve():
    spec = parse_failure_spec("link:0.05,seed:1")
    build = lambda t, o, g: graph_uniform_demands(t, o, graph=g)
    ft = failure_throughput(MPHX_SMALL, build, spec, 800.0, mode="minimal")
    assert 0 < ft["throughput_retained"] <= 1.0
    assert ft["degraded_max_util"] >= ft["healthy_max_util"] - 1e-9
    phases = recovery_curve(MPHX_SMALL, build, spec, 800.0, mode="minimal")
    names = [p["phase"] for p in phases]
    assert names == ["healthy", "failed", "rerouted"]
    # pre-reroute stall cuts delivery below (or at) healthy
    assert phases[1]["delivered_fraction"] <= phases[0]["delivered_fraction"]
    assert phases[1]["stalled_share"] > 0


def test_plane_capacity_factor():
    assert plane_capacity_factor(MPHX_SMALL, FailureSpec(planes_down=1)) \
        == pytest.approx(0.5)
    with pytest.raises(ValueError):
        plane_capacity_factor(MPHX_SMALL, FailureSpec(planes_down=2))


def test_stalled_flows_marked_not_spun():
    """A flow whose only path crosses a fully-failed edge stalls with
    finish = inf instead of looping."""
    inc = FlowIncidence(np.array([0], dtype=np.int64),
                        np.array([0], dtype=np.int64),
                        np.array([1.0]), 1, np.array([0.0]))
    res = simulate_incidence(inc, np.array([1e9]), np.array([100.0]))
    assert res.stalled[0]
    assert not np.isfinite(res.finish_s[0])


# ----------------------------------------------------- suites / CLI / docs ----


def test_sim_suite_artifact(tmp_path):
    from repro.experiments.simsuite import run_sim_suite

    payload = run_sim_suite(outdir=str(tmp_path),
                            topo_names=["mphx-2p-8x8"],
                            scenario_names=["uniform"],
                            load_fractions=(0.5,))
    disk = json.loads((tmp_path / "sim.json").read_text())
    assert disk == payload
    assert disk["schema_version"] == SCHEMA_VERSION
    assert disk["suite"] == "sim"
    assert disk["params"]["all_steady_checks_agree_1e-6"] is True
    kinds = {r.get("kind") for r in disk["rows"]}
    assert {"steady_check", "fct", "collective"} <= kinds
    checks = [r for r in disk["rows"] if r.get("kind") == "steady_check"]
    assert all(r["max_abs_util_diff"] < 1e-6 for r in checks)
    assert (tmp_path / "sim.md").read_text().startswith("# Flow-level")


def test_failures_suite_artifact_and_cli(tmp_path):
    from repro.experiments.run import main

    rc = main(["--suite", "failures", "--out", str(tmp_path),
               "--topos", "mphx-2p-8x8", "--scenarios", "uniform",
               "--failures", "link:0.1", "--failure-mode", "minimal"])
    assert rc == 0
    disk = json.loads((tmp_path / "failures.json").read_text())
    assert disk["schema_version"] == SCHEMA_VERSION
    assert disk["suite"] == "failures"
    assert disk["params"]["failure_specs"] == ["link:0.1"]
    kinds = [r.get("kind") for r in disk["rows"]]
    assert "throughput" in kinds and "recovery" in kinds


def test_failures_suite_array_engine_skips(tmp_path, capsys):
    from repro.experiments.simsuite import run_failures_suite

    payload = run_failures_suite(outdir=str(tmp_path),
                                 topo_names=["mphx-2p-8x8"],
                                 engine="array")
    assert payload["params"]["n_rows"] == 0
    skipped = [r for r in payload["rows"] if r.get("skipped")]
    assert skipped and "re-route" in skipped[0]["reason"]
    assert "re-route" in capsys.readouterr().err


def test_failures_cli_bad_spec(tmp_path):
    from repro.experiments.run import main

    rc = main(["--suite", "failures", "--out", str(tmp_path),
               "--failures", "bogus:1"])
    assert rc == 2


def test_sweep_suite_simulate_flag(tmp_path):
    from repro.experiments.sweep import run_sweep_suite

    payload = run_sweep_suite(outdir=str(tmp_path),
                              topo_names=["mphx-2p-8x8"],
                              scenario_names=["uniform"],
                              modes=["minimal", "adaptive"],
                              load_fractions=(0.5,), simulate=True)
    routed = [r for r in payload["rows"] if not r.get("skipped")]
    minimal = [r for r in routed if r["mode"] == "minimal"]
    adaptive = [r for r in routed if r["mode"] == "adaptive"]
    assert all("fct_p50_us" in r for r in minimal)
    assert all("fct_p50_us" not in r for r in adaptive)


def test_docs_smoke_registers_simulation_doc():
    """CI's docs smoke must cover docs/simulation.md (and the doc must
    actually quote runnable bash blocks)."""
    import os

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    smoke = open(os.path.join(repo, "scripts", "docs_smoke.py")).read()
    assert "simulation.md" in smoke
    doc = open(os.path.join(repo, "docs", "simulation.md")).read()
    assert "```bash" in doc
    assert "--suite sim" in doc and "--suite failures" in doc


def test_bench_flow_sim_writes_artifact():
    import importlib.util
    import os

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    spec = importlib.util.spec_from_file_location(
        "bench_run", os.path.join(repo, "benchmarks", "run.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    mod.bench_flow_sim()
    path = os.path.join(repo, "results", "BENCH_flow_sim.json")
    rec = json.load(open(path))
    assert all(v["within_1e-6"]
               for v in rec["steady_state_agreement"].values())
    assert rec["single_flow_fct"]["matches_closed_form"]
    assert rec["failure_sweep"]
